"""QAT from scratch: jointly train master weights W and scaling factors (B,A)
with STE fake quantization (paper §3.3) on a small LM.

    PYTHONPATH=src python examples/qat_pretrain.py [--steps 200]
"""
import argparse

from repro.configs import ShapeCfg, get_config
from repro.core.lords import QuantSpec
from repro.launch.train import run_training

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cfg = get_config("qwen3-4b").with_(
    name="qwen3-tiny-qat", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=512, vocab_size=8192, head_dim=64,
    vocab_pad_multiple=256,
    quant=QuantSpec(method="lords", codebook="int4", block_size=64,
                    mode="qat"),
)
shape = ShapeCfg("qat", 128, 8, "train")
out = run_training(cfg, shape, steps=args.steps, lr=1e-3)
print(f"QAT loss: {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}")
