"""End-to-end driver: LoRDS-PEFT fine-tune a ~100M-param LM for a few
hundred steps on the deterministic synthetic stream (CPU-friendly).

    PYTHONPATH=src python examples/finetune_peft.py [--steps 300]

~100M params: 4 layers, d_model=512, d_ff=2048, vocab 32768.
Only B/A scale factors train (frozen packed NF4 Q) — the paper's §3.4 regime.
"""
import argparse

from repro.configs import ShapeCfg, get_config
from repro.core.lords import QuantSpec
from repro.launch.train import run_training

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq-len", type=int, default=256)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

cfg = get_config("llama3-8b").with_(
    name="llama3-100m", num_layers=4, d_model=512, num_heads=8,
    num_kv_heads=8, d_ff=2048, vocab_size=32768, head_dim=64,
    quant=QuantSpec(method="lords", codebook="nf4", block_size=64,
                    mode="peft"),
)
shape = ShapeCfg("ft", args.seq_len, args.batch, "train")
out = run_training(cfg, shape, steps=args.steps, lr=2e-3,
                   ckpt_dir="/tmp/lords_peft_ckpt", ckpt_every=100)
print(f"loss: {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
      f"over {len(out['losses'])} steps")
