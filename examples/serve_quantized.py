"""Serve a quantized model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_quantized.py
"""
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.launch.serve import serve_batch

cfg = smoke_variant(get_config("qwen3-8b"))
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 24))
out = serve_batch(cfg, batch=4, prompt_len=24, gen=16, prompts=prompts)
print(f"prefill: {out['prefill_tok_s']:.1f} tok/s   "
      f"decode: {out['decode_tok_s']:.1f} tok/s")
for i, row in enumerate(out["tokens"]):
    print(f"request {i}: {row.tolist()}")
