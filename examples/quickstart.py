"""Quickstart: quantize a weight matrix with LoRDS, refine it (Alg. 1),
compare against block-wise NF4, and run the fused kernel.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import metrics, ptq_refine, quantize
from repro.core.scaling import scale_matrix
from repro.kernels import ops

# 1. a "pretrained" weight (here random; shape = llama3-8b q_proj / 4)
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (1024, 1024)) * 0.02

# 2. baseline: block-wise NF4 (bitsandbytes-style)
qb, sb = quantize.quantize_blockwise(w, 128, "nf4")
w_nf4 = quantize.dequantize_blockwise(qb, sb, 128, "nf4")
print(f"block-wise NF4  quant error (nuclear): "
      f"{float(metrics.quant_error(w, w_nf4)):.3f}")

# 3. LoRDS: SVD init + 300 refinement steps at the SAME parameter budget
res = ptq_refine(w, "nf4", block_size=128, steps=300, lr=0.05)
s = scale_matrix(res.b, res.a)
codes = quantize.unpack_codes(res.q_packed, "nf4")
w_lords = quantize.dequantize_codes(codes, s, "nf4")
print(f"LoRDS (refined) quant error (nuclear): "
      f"{float(metrics.quant_error(w, w_lords)):.3f}")

# 4. inference with the fused kernel (interpret=True executes the Pallas
#    kernel body on CPU; on TPU drop interpret for the real thing)
x = jax.random.normal(key, (8, 1024))
y = ops.lords_matmul(x, res.q_packed, res.b, res.a, "nf4",
                     use_pallas=True, interpret=True, bm=8, bn=256, bk=512)
y_ref = x @ w_lords.T
print(f"fused-kernel max err vs dequant matmul: "
      f"{float(jnp.max(jnp.abs(y - y_ref))):.2e}")
