# Convenience targets mirroring CI. PYTHONPATH is optional on pytest>=7
# (pyproject pythonpath), kept for older runners.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-interpret test-multidevice bench bench-serve bench-train \
	bench-attn serve-smoke serve-smoke-interpret serve-trace-smoke \
	train-smoke-interpret chaos-smoke ptq-stream-smoke lowbit-smoke \
	dist-chaos-smoke

test:            ## tier-1 suite (CPU; kernels in interpret mode where tested)
	$(PY) -m pytest -x -q

# every qmatmul/qattention forced through the Pallas interpreter: executes
# the fused kernel bodies on CPU
test-interpret:  ## kernel + dispatch + train-bwd + attention suites in interpret mode
	REPRO_KERNEL_BACKEND=interpret $(PY) -m pytest -x -q \
		tests/test_dispatch.py tests/test_kernels.py \
		tests/test_train_bwd.py tests/test_attn_fastpath.py

# the sharded suite: conftest forces 8 host CPU devices (REPRO_MULTIDEVICE=1
# must be set before pytest imports jax), builds real data×tensor-parallel
# meshes, and checks sharded-vs-single-device parity for the fused forward /
# psum'd backward / generate loop plus sharded checkpoint save→restore→resume
test-multidevice:  ## sharded e2e + checkpoint suites on a forced 8-way host-CPU mesh
	REPRO_MULTIDEVICE=1 $(PY) -m pytest -x -q -m multidevice

bench:           ## kernel-level fused-vs-oracle benchmark (Fig. 2 analogue)
	$(PY) -m benchmarks.run kernels

bench-serve:     ## decode fast path: prefill/decode timings + bytes/token roofline -> BENCH_serve.json
	$(PY) -m benchmarks.bench_serve

serve-smoke:     ## end-to-end quantized serving smoke run (on-device decode loop)
	$(PY) -m repro.launch.serve --arch llama3-8b --smoke \
		--batch 2 --prompt-len 16 --gen 8

# decode path through the Pallas interpreter: the fused decode GEMV kernel
# bodies execute on CPU inside the jitted generation loop
serve-smoke-interpret:  ## serve smoke with fused kernels in interpret mode + int8 KV
	$(PY) -m repro.launch.serve --arch llama3-8b --smoke \
		--batch 2 --prompt-len 8 --gen 4 \
		--kernel-backend interpret --kv-cache int8

# continuous-batching engine smoke: a Poisson request trace replayed through
# the paged int8 KV pipeline (chunked prefill interleaved with burst decode,
# small page pool) with the fused kernels in interpret mode
serve-trace-smoke:  ## engine trace replay: paged int8 pool + chunked prefill, interpret kernels
	$(PY) -m benchmarks.bench_serve --trace 4 --backend interpret \
		--slots 2 --page-size 8 --total-pages 8 --max-pages 5 --chunk 16

# seeded fault-injection smoke: the same trace replayed clean vs under a
# deterministic FaultPlan (page-alloc failures, a step failure, a NaN burst,
# overload + preemption); the scenarios self-assert exactly-one-terminal-
# status, failure isolation (token-identical untouched requests) and a clean
# page-pool audit, and also run the hardened-engine robustness tests
chaos-smoke:     ## fault-injected serving: chaos scenarios + hardened-engine tests
	$(PY) -m benchmarks.bench_chaos
	$(PY) -m pytest -x -q tests/test_faults.py
	$(PY) -m pytest -x -q tests/test_paged_engine.py \
		-k "timeout or deadline or sheds or quarantine or step_failure \
		or preemption or chaos or audit"

# crash-safe streaming PTQ: the CLI self-check kills the pipeline at a
# block boundary, mid-shard-write, pre-ledger-commit and under bitrot,
# resumes each run, and asserts the artifact is bit-identical to an
# uninterrupted run (clean ledger/checksum audit included); the test
# suite then covers the resume contract point by point
ptq-stream-smoke:  ## streaming-PTQ kill/resume/bitrot self-check + resume-contract tests
	$(PY) -m repro.launch.ptq_stream --selfcheck --out /tmp/ptq_stream_sc \
		--blocks 4 --d 64 --dff 96 --tokens 32 --steps 8 --rank 4
	$(PY) -m pytest -x -q tests/test_ptq_stream.py

# sub-4-bit frontier: a reduced accuracy-vs-bytes/token Pareto sweep
# (self-asserting: true 3-bit packing undercuts 4-bit on bytes/token at
# matched error-reduction, LoRDS leads LoftQ at 2-bit, allocator respects
# its budget, nf3 serving config <= 0.40 bytes/weight incl. scales) plus
# the sub-byte pack/parity suites with fused kernels in interpret mode
lowbit-smoke:    ## reduced lowbit Pareto sweep + sub-byte parity suites -> BENCH_lowbit.json
	$(PY) -m benchmarks.bench_lowbit --smoke
	REPRO_KERNEL_BACKEND=interpret $(PY) -m pytest -x -q \
		tests/test_quantize.py tests/test_allocate.py \
		tests/test_kernels.py -k "subbyte or nf3 or pack"

# elastic distributed recovery under a forced 8-device host mesh: injected
# device loss -> mesh rebuild + elastic checkpoint reshard (train) / param
# reshard with bit-identical tokens (engine), replica-desync detect +
# rollback, host-crash resume, and the sharded streaming-PTQ crash +
# mesh-shrink drill — every invariant self-asserted into
# BENCH_dist_chaos.json — plus the elastic multidevice test suite
dist-chaos-smoke:  ## elastic recovery drills + multidevice elastic tests -> BENCH_dist_chaos.json
	$(PY) -m benchmarks.run dist_chaos
	REPRO_MULTIDEVICE=1 $(PY) -m pytest -x -q -m multidevice \
		tests/test_dist_elastic.py

bench-train:     ## training fast path: fused vs dequant backward step time + bwd-bytes roofline -> BENCH_train.json
	$(PY) -m benchmarks.bench_train

bench-attn:      ## attention fast path: fused flash kernels vs einsum oracle + cache bytes/token -> BENCH_attn.json
	$(PY) -m benchmarks.bench_attn

# training path through the Pallas interpreter: fused forward AND the fused
# transposed/grad-reduction backward kernels execute on CPU inside jitted
# train steps (both peft and qat STE modes)
train-smoke-interpret:  ## 3-step train smoke, fused fwd+bwd in interpret mode (peft + qat)
	$(PY) -m repro.launch.train --arch llama3-8b --smoke --steps 3 \
		--seq-len 16 --global-batch 2 --kernel-backend interpret
	$(PY) -m repro.launch.train --arch llama3-8b --smoke --steps 3 \
		--seq-len 16 --global-batch 2 --mode qat --kernel-backend interpret
