"""Required per-arch smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, smoke_variant
from repro.core import peft
from repro.models import forward_train, model_init, split_tree

ALL_ARCHS = [
    "minicpm3-4b", "minitron-4b", "llama3-405b", "granite-20b",
    "phi3.5-moe-42b-a6.6b", "kimi-k2-1t-a32b", "internvl2-1b", "xlstm-1.3b",
    "musicgen-medium", "jamba-1.5-large-398b",
    # the paper's own models
    "llama3-8b", "qwen3-8b", "qwen3-4b",
]


def test_registry_covers_assignment():
    have = set(list_configs())
    for arch in ALL_ARCHS:
        assert arch in have, f"missing assigned arch {arch}"
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


def _batch(cfg, key, b=2, s=32):
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.input_kind == "tokens":
        return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
                "labels": labels}
    return {"embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                        jnp.float32),
            "labels": labels}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = smoke_variant(get_config(arch))
    params, axes = split_tree(model_init(key, cfg))
    batch = _batch(cfg, key)

    loss, metrics = forward_train(params, cfg, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss {float(loss)}"

    # one PEFT train step: grads flow to B/A only and update them
    trainable, frozen = peft.partition(params, cfg.quant)

    def loss_fn(t):
        return forward_train(peft.combine(t, frozen), cfg, batch)[0]

    grads = jax.grad(loss_fn)(trainable)
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"
    # exact-shape parity between grads and trainable
    for g, t in zip(jax.tree.leaves(grads), jax.tree.leaves(trainable)):
        assert g.shape == t.shape


@pytest.mark.parametrize("arch", ["llama3-8b", "kimi-k2-1t-a32b"])
def test_smoke_qat_mode(arch, key):
    cfg = smoke_variant(get_config(arch))
    cfg = cfg.with_(quant=cfg.quant.with_(mode="qat"))
    params, _ = split_tree(model_init(key, cfg))
    batch = _batch(cfg, key)
    trainable, frozen = peft.partition(params, cfg.quant)

    def loss_fn(t):
        return forward_train(peft.combine(t, frozen), cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(trainable)
    assert np.isfinite(float(loss))
    # master weights receive STE gradients
    gw = grads["layers"]["blk0"]["mixer"]["wq"]["w"]
    assert float(jnp.sum(jnp.abs(gw))) > 0
