"""Codebook properties + nearest-code correctness (incl. property tests)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import lut
from repro.core.quantize import nearest_code

CANON_NF4 = np.array([
    -1.0, -0.6962, -0.5251, -0.3949, -0.2844, -0.1848, -0.0911, 0.0,
    0.0796, 0.1609, 0.2461, 0.3379, 0.4407, 0.5626, 0.7230, 1.0,
])


@pytest.mark.parametrize("name", lut.CODEBOOKS)
def test_codebook_sorted_normalized(name):
    cb = np.asarray(lut.codebook(name))
    assert np.all(np.diff(cb) > 0), "levels must be strictly increasing"
    assert np.isclose(np.abs(cb).max(), 1.0)
    assert len(cb) <= 2 ** lut.codebook_bits(name)


@pytest.mark.parametrize("name", ["nf4", "nf3", "nf2"])
def test_nf_codebooks_have_exact_zero(name):
    cb = np.asarray(lut.codebook(name))
    assert 0.0 in cb


def test_nf4_matches_qlora_table():
    cb = np.asarray(lut.codebook("nf4"))
    np.testing.assert_allclose(cb, CANON_NF4, atol=2e-3)


def test_midpoints_between_levels():
    for name in lut.CODEBOOKS:
        cb = np.asarray(lut.codebook(name))
        mids = np.asarray(lut.midpoints(name))
        assert len(mids) == len(cb) - 1
        assert np.all(mids > cb[:-1]) and np.all(mids < cb[1:])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-3, 3, allow_nan=False), min_size=1, max_size=64),
       st.sampled_from(["nf4", "nf2", "int4", "fp4"]))
def test_nearest_code_is_argmin(xs, name):
    x = jnp.asarray(xs, jnp.float32)
    cb = lut.codebook(name)
    codes = nearest_code(x, name)
    brute = jnp.argmin(jnp.abs(x[:, None] - cb[None, :]), axis=1)
    picked = jnp.take(cb, codes.astype(jnp.int32))
    best = jnp.take(cb, brute)
    # ties can pick either neighbour; distances must match exactly
    np.testing.assert_allclose(np.abs(np.asarray(picked - x)),
                               np.abs(np.asarray(best - x)), rtol=1e-6)


def test_mixed_precision_schedule_fractions():
    # Table 3: 3-bit = 50% nf4 + 50% nf2; 2.5 = 25%; 2.25 = 12.5%
    sched = lut.mixed_precision_schedule(32, 3.0)
    assert sched.count("nf4") == 16 and sched.count("nf2") == 16
    sched = lut.mixed_precision_schedule(32, 2.5)
    assert sched.count("nf4") == 8
    sched = lut.mixed_precision_schedule(32, 2.25)
    assert sched.count("nf4") == 4
    with pytest.raises(ValueError):
        lut.mixed_precision_schedule(32, 5.0)
