"""Pallas kernels vs pure-jnp oracles: shape/dtype/codebook sweeps in
interpret mode (the kernel body executes on CPU), exactly as required.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import quantize, scaling
from repro.kernels import ops, ref

SHAPES = [  # (M, N, K, blocks)
    (64, 128, 256, dict(bm=32, bn=64, bk=128)),
    (128, 256, 512, dict(bm=128, bn=128, bk=256)),
    (8, 128, 128, dict(bm=8, bn=128, bk=128)),
]


def _setup(m, n, k, r, codebook, seed=0, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (n, k), jnp.float32) * 0.02
    b, a = scaling.lords_init_from_weight(w, 128, rank=r)
    s = scaling.scale_matrix(b, a)
    codes = quantize.quantize_codes(w, s, codebook)
    qp = quantize.pack_codes(codes, codebook)
    return x, w, qp, b, a


@pytest.mark.parametrize("m,n,k,blocks", SHAPES)
@pytest.mark.parametrize("codebook", ["nf4", "nf2"])
def test_lords_matmul_shapes(m, n, k, blocks, codebook):
    x, w, qp, b, a = _setup(m, n, k, 4, codebook)
    y_ref = ref.lords_matmul_ref(x, qp, b, a, codebook)
    y = ops.lords_matmul(x, qp, b, a, codebook, use_pallas=True,
                         interpret=True, **blocks)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lords_matmul_dtypes(dtype):
    x, w, qp, b, a = _setup(64, 128, 256, 4, "nf4", dtype=dtype)
    y_ref = ref.lords_matmul_ref(x, qp, b, a, "nf4")
    y = ops.lords_matmul(x, qp, b, a, "nf4", use_pallas=True, interpret=True,
                         bm=32, bn=64, bk=128)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 10_000),
       st.sampled_from(["nf4", "nf2", "int8"]))
def test_lut_quantize_matches_oracle(rank, seed, codebook):
    _, w, _, b, a = _setup(8, 128, 256, rank, codebook, seed=seed)
    got = ops.lut_quantize(w, b, a, codebook, use_pallas=True, interpret=True,
                           bn=64, bk=128)
    want = ref.lut_quantize_ref(w, b, a, codebook)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bk", [64, 128, 256])
def test_block_matmul_both_tiling_regimes(bk):
    """bk >= block_size and bk < block_size paths must both be exact."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 512)) * 0.02
    qb, sb = quantize.quantize_blockwise(w, 128, "nf4")
    y_ref = ref.block_matmul_ref(x, qb, sb, 128, "nf4")
    y = ops.block_matmul(x, qb, sb, 128, "nf4", use_pallas=True,
                         interpret=True, bm=32, bn=64, bk=bk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_ops_dispatch_cpu_falls_back_to_ref():
    x, w, qp, b, a = _setup(16, 128, 128, 2, "nf4")
    y_auto = ops.lords_matmul(x, qp, b, a, "nf4")  # cpu -> ref path
    y_ref = ref.lords_matmul_ref(x, qp, b, a, "nf4")
    np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_ref))


def test_kernel_matches_core_dequant_semantics():
    """ops.lords_matmul == x @ dequantize_weight(...)ᵀ from repro.core."""
    from repro.core import QuantSpec, dequantize_weight

    x, w, qp, b, a = _setup(32, 128, 256, 4, "nf4")
    spec = QuantSpec(method="lords", block_size=128, rank=4,
                     compute_dtype=jnp.float32)
    params = {"q": qp, "b": b, "a": a}
    w_hat = dequantize_weight(params, spec, 128, 256)
    y_core = x @ w_hat.T
    y_kern = ops.lords_matmul(x, qp, b, a, "nf4", use_pallas=True,
                              interpret=True, bm=32, bn=64, bk=128)
    np.testing.assert_allclose(np.asarray(y_core), np.asarray(y_kern),
                               rtol=3e-5, atol=3e-5)
