"""Pallas kernels vs pure-jnp oracles: shape/dtype/codebook sweeps in
interpret mode (the kernel body executes on CPU), exactly as required.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import quantize, scaling
from repro.kernels import ops, ref

SHAPES = [  # (M, N, K, blocks)
    (64, 128, 256, dict(bm=32, bn=64, bk=128)),
    (128, 256, 512, dict(bm=128, bn=128, bk=256)),
    (8, 128, 128, dict(bm=8, bn=128, bk=128)),
]


def _setup(m, n, k, r, codebook, seed=0, dtype=jnp.float32):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (n, k), jnp.float32) * 0.02
    b, a = scaling.lords_init_from_weight(w, 128, rank=r)
    s = scaling.scale_matrix(b, a)
    codes = quantize.quantize_codes(w, s, codebook)
    qp = quantize.pack_codes(codes, codebook)
    return x, w, qp, b, a


@pytest.mark.parametrize("m,n,k,blocks", SHAPES)
@pytest.mark.parametrize("codebook", ["nf4", "nf3", "nf2"])
def test_lords_matmul_shapes(m, n, k, blocks, codebook):
    x, w, qp, b, a = _setup(m, n, k, 4, codebook)
    y_ref = ref.lords_matmul_ref(x, qp, b, a, codebook)
    y = ops.lords_matmul(x, qp, b, a, codebook, use_pallas=True,
                         interpret=True, **blocks)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lords_matmul_dtypes(dtype):
    x, w, qp, b, a = _setup(64, 128, 256, 4, "nf4", dtype=dtype)
    y_ref = ref.lords_matmul_ref(x, qp, b, a, "nf4")
    y = ops.lords_matmul(x, qp, b, a, "nf4", use_pallas=True, interpret=True,
                         bm=32, bn=64, bk=128)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("codebook", ["nf3", "nf2"])
@pytest.mark.parametrize("n,k", [(96, 160), (72, 328)])
def test_subbyte_dispatch_parity_non_tile_aligned(codebook, n, k):
    """Fused path (pad-to-tile + in-kernel sub-byte unpack) vs the ref
    oracle on shapes that divide neither the tile nor the lane width —
    forward at GEMV and GEMM widths, backward through x/b/a."""
    from repro.core import QuantSpec, init_quantized_linear
    from repro.kernels import dispatch

    spec = QuantSpec(method="lords", codebook=codebook, block_size=8,
                     rank=4, mode="peft")
    kw, kx = jax.random.split(jax.random.PRNGKey(n + k))
    w = jax.random.normal(kw, (n, k), jnp.float32) * 0.02
    params = init_quantized_linear(kw, n, k, spec, w)
    for m in (3, 16):
        x = jax.random.normal(kx, (m, k), jnp.float32)
        y_ref = dispatch.qmatmul(params, x, spec, n, k, backend="ref")
        y_int = dispatch.qmatmul(params, x, spec, n, k, backend="interpret")
        np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                                   rtol=3e-5, atol=3e-5)

    def loss(backend):
        def f(x_, b_, a_):
            p = {**params, "b": b_, "a": a_}
            return jnp.sum(dispatch.qmatmul(p, x_, spec, n, k,
                                            backend=backend) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))(x, params["b"], params["a"])

    for g_ref, g_int in zip(loss("ref"), loss("interpret")):
        np.testing.assert_allclose(np.asarray(g_int), np.asarray(g_ref),
                                   rtol=2e-3, atol=2e-3)


def test_subbyte_decode_has_no_dense_unpack_temporary():
    """The fused sub-byte path must unpack shift/mask *inside the tile*:
    no integer-typed (N, K) code array may appear anywhere in the jaxpr
    (that full-width temporary is exactly what true packing removes)."""
    m, n, k, r = 8, 128, 512, 4
    x, w, qp, b, a = _setup(m, n, k, r, "nf3")

    def fused(x, qp, b, a):
        return ops.lords_matmul(x, qp, b, a, "nf3", use_pallas=True,
                                interpret=True, bm=8, bn=64, bk=128)

    jaxpr = jax.make_jaxpr(fused)(x, qp, b, a)

    def int_avals(jx):
        for eqn in jx.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    if jnp.issubdtype(aval.dtype, jnp.integer):
                        yield aval
            for sub in jax.core.jaxprs_in_params(eqn.params):
                yield from int_avals(sub)

    # a dense unpack temporary would be a 2-D integer (N, K) code matrix;
    # the tile-level one-hot (bn, bk, levels) is 3-D and allowed — it IS
    # the MXU gather
    offenders = [a_ for a_ in int_avals(jaxpr.jaxpr)
                 if a_.ndim == 2 and a_.size >= n * k]
    assert not offenders, f"full-width unpack temporaries: {offenders}"


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 10_000),
       st.sampled_from(["nf4", "nf3", "nf2", "int8"]))
def test_lut_quantize_matches_oracle(rank, seed, codebook):
    _, w, _, b, a = _setup(8, 128, 256, rank, codebook, seed=seed)
    got = ops.lut_quantize(w, b, a, codebook, use_pallas=True, interpret=True,
                           bn=64, bk=128)
    want = ref.lut_quantize_ref(w, b, a, codebook)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bk", [64, 128, 256])
def test_block_matmul_both_tiling_regimes(bk):
    """bk >= block_size and bk < block_size paths must both be exact."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 512)) * 0.02
    qb, sb = quantize.quantize_blockwise(w, 128, "nf4")
    y_ref = ref.block_matmul_ref(x, qb, sb, 128, "nf4")
    y = ops.block_matmul(x, qb, sb, 128, "nf4", use_pallas=True,
                         interpret=True, bm=32, bn=64, bk=bk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_ops_dispatch_cpu_falls_back_to_ref():
    x, w, qp, b, a = _setup(16, 128, 128, 2, "nf4")
    y_auto = ops.lords_matmul(x, qp, b, a, "nf4")  # cpu -> ref path
    y_ref = ref.lords_matmul_ref(x, qp, b, a, "nf4")
    np.testing.assert_array_equal(np.asarray(y_auto), np.asarray(y_ref))


def test_kernel_matches_core_dequant_semantics():
    """ops.lords_matmul == x @ dequantize_weight(...)ᵀ from repro.core."""
    from repro.core import QuantSpec, dequantize_weight

    x, w, qp, b, a = _setup(32, 128, 256, 4, "nf4")
    spec = QuantSpec(method="lords", block_size=128, rank=4,
                     compute_dtype=jnp.float32)
    params = {"q": qp, "b": b, "a": a}
    w_hat = dequantize_weight(params, spec, 128, 256)
    y_core = x @ w_hat.T
    y_kern = ops.lords_matmul(x, qp, b, a, "nf4", use_pallas=True,
                              interpret=True, bm=32, bn=64, bk=128)
    np.testing.assert_allclose(np.asarray(y_core), np.asarray(y_kern),
                               rtol=3e-5, atol=3e-5)
