"""Attention fast path: fused flash-prefill / quantized-KV flash-decode
kernels vs the materializing ref oracles — parity (cosine + max-abs-err) on
non-tile-aligned shapes for GQA and MLA, ragged per-sequence positions,
causal-mask boundary rows, int8 and bf16 caches, the jaxpr guard that the
jitted decode step never materializes a score matrix or a dequantized
cache, attention autotune-key persistence, and sharded-vs-single-device
parity under the 8-device harness."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from multidevice_compat import multidevice, single_mesh, tp_mesh
from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import autotune_qattention, qattention
from repro.models import attention as attn
from repro.models import split_tree
from repro.models.common import kv_quantize


def _cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-30))


def _maxerr(a, b):
    return float(np.max(np.abs(np.asarray(a, np.float32)
                               - np.asarray(b, np.float32))))


# ---------------------------------------------------------------------------
# prefill kernel: fused vs ref on non-tile-aligned shapes
# ---------------------------------------------------------------------------

# deliberately off the 8/128 tile grid: odd seq lengths, GQA group > 1
PREFILL_SHAPES = [(2, 17, 4, 2, 16), (1, 23, 8, 2, 16), (2, 33, 4, 4, 24)]


@pytest.mark.parametrize("b,s,nh,nkv,hd", PREFILL_SHAPES)
def test_prefill_fused_matches_ref_nonaligned(b, s, nh, nkv, hd):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    sc = 1.0 / hd ** 0.5
    y_ref = qattention("prefill", q, k, v, pos, logit_scale=sc,
                       backend="ref")
    y_int = qattention("prefill", q, k, v, pos, logit_scale=sc,
                       backend="interpret")
    assert _cos(y_int, y_ref) > 0.9999
    assert _maxerr(y_int, y_ref) < 3e-5


def test_prefill_causal_boundary_rows():
    """Row 0 (sees only itself) and the last row (sees everything) are the
    mask boundary cases the tiled kernel must get exactly right."""
    b, s, nh, nkv, hd = 1, 16, 2, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(3), (b, s, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, nkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    sc = 1.0 / hd ** 0.5
    y = qattention("prefill", q, k, v, pos, logit_scale=sc,
                   backend="interpret")
    # row 0 attends only to key 0: softmax over one element == v[0]
    np.testing.assert_allclose(
        np.asarray(y[0, 0, 0], np.float32),
        np.asarray(v[0, 0, 0], np.float32), rtol=3e-5, atol=3e-5)
    # the last row's softmax spans every key — pin it to the dense oracle
    y_ref = qattention("prefill", q, k, v, pos, logit_scale=sc,
                       backend="ref")
    np.testing.assert_allclose(np.asarray(y[0, -1], np.float32),
                               np.asarray(y_ref[0, -1], np.float32),
                               rtol=3e-5, atol=3e-5)


def test_prefill_ragged_positions_and_padding_rows():
    """Per-sequence ragged positions: one sequence ends early (pos = -1
    padding rows), the other is shifted — fused and ref must agree on every
    live row, on tile-unaligned lengths."""
    b, s, nh, nkv, hd = 2, 19, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(6), (b, s, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, nkv, hd))
    pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None], (b, s)).copy()
    pos[0, 13:] = -1                      # sequence 0: dead tail
    pos[1] = np.arange(7, 7 + s)          # sequence 1: shifted window
    pos = jnp.asarray(pos)
    sc = 1.0 / hd ** 0.5
    y_ref = qattention("prefill", q, k, v, pos, logit_scale=sc,
                       backend="ref")
    y_int = qattention("prefill", q, k, v, pos, logit_scale=sc,
                       backend="interpret")
    live = np.asarray(pos) >= 0
    d = np.abs(np.asarray(y_int, np.float32)
               - np.asarray(y_ref, np.float32))[live]
    assert d.max() < 3e-5
    # dead rows (pos == -1) are zeroed by the kernel's l == 0 guard —
    # the documented contract, not softmax-of-all-masked garbage
    np.testing.assert_array_equal(
        np.asarray(y_int, np.float32)[~live], 0.0)


def test_prefill_fused_gradients_match_ref():
    """The fused prefill carries a custom VJP (backward recomputes through
    the oracle): grads wrt q/k/v must match differentiating the ref."""
    b, s, nh, nkv, hd = 1, 12, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(9), (b, s, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(10), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(11), (b, s, nkv, hd))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    sc = 1.0 / hd ** 0.5

    def loss(backend):
        def f(qq, kk, vv):
            return jnp.sum(qattention("prefill", qq, kk, vv, pos,
                                      logit_scale=sc, backend=backend) ** 2)
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    for gi, gr in zip(loss("interpret"), loss("ref")):
        np.testing.assert_allclose(np.asarray(gi), np.asarray(gr),
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# decode kernels: GQA + MLA, int8 + bf16 caches, non-aligned cache lengths
# ---------------------------------------------------------------------------

DECODE_SHAPES = [(2, 23, 8, 2, 16), (1, 30, 4, 4, 24), (3, 9, 8, 1, 16)]


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("b,cap,nh,nkv,hd", DECODE_SHAPES)
def test_gqa_decode_fused_matches_ref(b, cap, nh, nkv, hd, quantized):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, nh, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (b, cap, nkv, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (b, cap, nkv, hd))
    # ragged live lengths incl. the pos=0 boundary (single live slot)
    pos = jnp.asarray(np.linspace(0, cap - 1, b).astype(np.int32))
    sc = 1.0 / hd ** 0.5
    if quantized:
        kcod, ks = kv_quantize(kc)
        vcod, vs = kv_quantize(vc)
        args = (q, kcod, vcod, pos, ks, vs)
    else:
        args = (q, kc, vc, pos)
    y_ref = qattention("decode", *args, logit_scale=sc, backend="ref")
    y_int = qattention("decode", *args, logit_scale=sc, backend="interpret")
    assert _cos(y_int, y_ref) > 0.9999
    assert _maxerr(y_int, y_ref) < 3e-5


@pytest.mark.parametrize("quantized", [False, True])
def test_mla_decode_fused_matches_ref(quantized):
    b, cap, nh, lat, rope = 2, 21, 4, 16, 8
    ql = jax.random.normal(jax.random.PRNGKey(3), (b, nh, lat))
    qr = jax.random.normal(jax.random.PRNGKey(4), (b, nh, rope))
    c = jax.random.normal(jax.random.PRNGKey(5), (b, cap, lat))
    kr = jax.random.normal(jax.random.PRNGKey(6), (b, cap, rope))
    pos = jnp.array([0, cap - 1], jnp.int32)
    sc = 1.0 / (lat + rope) ** 0.5
    if quantized:
        ccod, cs = kv_quantize(c)
        args = (ql, qr, ccod, kr, pos, cs)
    else:
        args = (ql, qr, c, kr, pos)
    y_ref = qattention("mla_decode", *args, logit_scale=sc, backend="ref")
    y_int = qattention("mla_decode", *args, logit_scale=sc,
                       backend="interpret")
    assert _cos(y_int, y_ref) > 0.9999
    assert _maxerr(y_int, y_ref) < 3e-5


# ---------------------------------------------------------------------------
# model level: fused attention inside gqa/mla decode tracks the ref backend
# ---------------------------------------------------------------------------


def _attn_setup(arch, kv, seed=0):
    cfg = smoke_variant(get_config(arch)).with_(kv_cache_dtype=kv)
    key = jax.random.PRNGKey(seed)
    init = attn.mla_init if cfg.attn_kind == "mla" else attn.gqa_init
    cache_init_fn = (attn.mla_cache_init if cfg.attn_kind == "mla"
                     else attn.gqa_cache_init)
    params, _ = split_tree(init(key, cfg, cfg.quant))
    cache, _ = split_tree(cache_init_fn(cfg, 2, 12))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    return cfg, params, cache, x


@pytest.mark.parametrize("arch", ["llama3-8b", "minicpm3-4b"])
@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_model_decode_fused_vs_ref_backend(arch, kv):
    """Full mixer prefill + ragged decode step: the interpret (fused
    kernels) and ref backends must agree through the real cache plumbing."""
    outs = {}
    for backend in ("ref", "interpret"):
        cfg, params, cache, x = _attn_setup(arch, kv)
        pre = attn.mla_prefill if cfg.attn_kind == "mla" else attn.gqa_prefill
        dec = attn.mla_decode if cfg.attn_kind == "mla" else attn.gqa_decode
        positions = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None],
                                     (2, 8))
        with dispatch.backend_scope(backend):
            _, cache = pre(params, x, cfg, cfg.quant, positions, cache)
            pos = jnp.array([3, 8], jnp.int32)  # ragged
            y, _ = dec(params, x[:, :1], cfg, cfg.quant, cache, pos)
        outs[backend] = np.asarray(y, np.float32)
    assert _cos(outs["interpret"], outs["ref"]) > 0.999


# ---------------------------------------------------------------------------
# jaxpr guard: the jitted decode step materializes neither a score matrix
# nor a dequantized cache (the PR 3 no-(N,K)-temporary check, for serving)
# ---------------------------------------------------------------------------


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue  # tile-level internals live in VMEM, not HBM
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _walk_eqns(sub)


def _subjaxprs(val):
    if isinstance(val, jax.extend.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _subjaxprs(v)


@pytest.mark.parametrize("arch", ["llama3-8b", "minicpm3-4b"])
def test_decode_step_jaxpr_no_score_or_dequant_temporary(arch):
    """The fused decode step's jaxpr must contain (a) no f32 tensor with a
    trailing cache-capacity axis of per-head score shape — the (b, n, S)
    temporary the einsum path materializes — and (b) no float tensor of the
    full cache's shape outside kernel launches — the out-of-kernel bf16
    dequant of the int8 cache."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_plan
    from repro.models import cache_init, model_init

    cfg = smoke_variant(get_config(arch)).with_(num_layers=2,
                                                kv_cache_dtype="int8")
    # capacity deliberately distinct from every model dim of both smoke
    # configs (hd=16, d=64, qk=24, q_lora=32, ...) so a trailing-40 float
    # axis can only be a cache-length score
    batch, cap = 2, 40
    mesh = make_host_mesh()
    plan = build_plan(cfg, mesh, ShapeCfg("d", cap, batch, "decode"),
                      kernel_backend="interpret")
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    cache, _ = split_tree(cache_init(cfg, batch, cap))
    tok = {"tokens": jnp.zeros((batch,), jnp.int32)}
    pos = jnp.zeros((batch,), jnp.int32)

    # shapes of the int8 cache leaves: a float array of any of these shapes
    # outside a pallas_call is a full-cache dequant temporary
    cache_shapes = {tuple(l.shape[1:]) for l in jax.tree.leaves(cache)
                    if l.dtype == jnp.int8}

    def temporaries(step_fn):
        jaxpr = jax.make_jaxpr(step_fn)(params, tok, cache, pos)
        bad = []
        for eqn in _walk_eqns(jaxpr.jaxpr):
            for v in eqn.outvars:
                aval = v.aval
                shape = tuple(getattr(aval, "shape", ()))
                if not shape or not jnp.issubdtype(aval.dtype, jnp.floating):
                    continue
                # (a) score temporary: the einsum path's (b, n, g, S) /
                # (b, n, 1, S) per-(query-head, token) f32 scores.  3D
                # (b, heads, S) f32 is *allowed*: that is the
                # per-(token, head) scale layout the fused roofline
                # budgets for.
                if (len(shape) >= 4 and shape[-1] == cap
                        and shape[0] == batch
                        and any(d in (cfg.num_heads, cfg.num_kv_heads)
                                for d in shape[1:-1])):
                    bad.append(("score", eqn.primitive.name, shape))
                # (b) full-cache dequant temporary (per stacked layer)
                if shape in cache_shapes or shape[1:] in cache_shapes:
                    bad.append(("dequant", eqn.primitive.name, shape))
        return bad

    bad = temporaries(plan.step_fn)
    assert not bad, f"serving-path temporaries found: {bad}"

    # negative control: the einsum/ref step must trip both detectors —
    # otherwise the guard above is vacuous
    ref_plan = build_plan(cfg, mesh, ShapeCfg("d", cap, batch, "decode"),
                          kernel_backend="ref")
    ref_bad = temporaries(ref_plan.step_fn)
    assert any(kind == "score" for kind, *_ in ref_bad), ref_bad
    assert any(kind == "dequant" for kind, *_ in ref_bad), ref_bad


# ---------------------------------------------------------------------------
# autotune-key persistence for attention entries (REPRO_AUTOTUNE_CACHE)
# ---------------------------------------------------------------------------


def test_attention_autotune_key_roundtrips(tmp_path, monkeypatch):
    path = str(tmp_path / "tiles.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    b, cap, nh, nkv, hd = 1, 16, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, nh, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (b, cap, nkv, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (b, cap, nkv, hd))
    kcod, ks = kv_quantize(kc)
    vcod, vs = kv_quantize(vc)
    pos = jnp.array([cap - 1], jnp.int32)
    best, timings = autotune_qattention(
        "decode", q, kcod, vcod, pos, ks, vs, logit_scale=1.0 / hd ** 0.5,
        backend="interpret", candidates=[(8, 8), (8, 16)], iters=1)
    assert best is not None and timings and os.path.exists(path)
    akey = dispatch.autotune_key("attn_gqa", cap, nh, hd, "attn", jnp.int8)
    assert akey in dispatch.autotune_table()
    # simulate a fresh process: drop the entry, reload from the JSON cache
    dispatch._AUTOTUNE.pop(akey)
    assert dispatch.load_autotune_table() >= 1
    got = dispatch.lookup_tiles("attn_gqa", cap, nh, hd, "attn", jnp.int8)
    assert got == (best[0], best[1], 1)
    entries = json.load(open(path))["entries"]
    assert any(e["key"][0] == "attn_gqa" for e in entries)
    dispatch._AUTOTUNE.pop(akey, None)  # don't leak tuned tiles to others


# ---------------------------------------------------------------------------
# sharded-vs-single-device parity (8-way host-CPU harness from PR 4)
# ---------------------------------------------------------------------------


@multidevice
def test_sharded_fused_attention_generate_matches_single_device():
    """int8-KV generate with the fused attention kernels under a pure
    tensor-parallel mesh (heads shard over 'model' inside qattention's
    shard_map): token-for-token identical to the 1x1 mesh."""
    from repro.launch.serve import serve_batch

    cfg = smoke_variant(get_config("llama3-8b")).with_(num_layers=2)
    prompts = np.random.default_rng(7).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    kw = dict(batch=2, prompt_len=8, gen=4, seed=11, prompts=prompts,
              kernel_backend="interpret", kv_cache="int8")
    out_1 = serve_batch(cfg, mesh=single_mesh(), **kw)
    out_8 = serve_batch(cfg, mesh=tp_mesh(), **kw)
    assert out_1["attention"] == "fused"
    np.testing.assert_array_equal(out_8["tokens"], out_1["tokens"])


@multidevice
def test_sharded_qattention_decode_matches_unsharded():
    """Kernel-level: qattention('decode') under shard_scope over an 8-way
    model mesh must match the unsharded fused call (heads 8 % 8 == 0,
    nkv 8 % 8 == 0 — the head-local psum-free route)."""
    b, cap, nh, nkv, hd = 2, 16, 8, 8, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, nh, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (b, cap, nkv, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (b, cap, nkv, hd))
    kcod, ks = kv_quantize(kc)
    vcod, vs = kv_quantize(vc)
    pos = jnp.array([5, 15], jnp.int32)
    sc = 1.0 / hd ** 0.5
    y0 = qattention("decode", q, kcod, vcod, pos, ks, vs, logit_scale=sc,
                    backend="interpret")
    mesh = tp_mesh()
    with dispatch.shard_scope(mesh):
        y8 = qattention("decode", q, kcod, vcod, pos, ks, vs,
                        logit_scale=sc, backend="interpret")
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y0),
                               rtol=3e-5, atol=3e-5)
