"""Quantize/dequantize/pack invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import lut, quantize, scaling


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 16),
       st.sampled_from(["nf4", "nf2", "int8", "nf3", "fp4"]),
       st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(rows, groups, name, seed):
    cpb = {8: 1, 4: 2, 3: 1, 2: 4}[lut.codebook_bits(name)]
    cols = groups * cpb
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, len(lut.codebook(name)),
                         (rows, cols)).astype(np.uint8)
    packed = quantize.pack_codes(jnp.asarray(codes), name)
    assert packed.shape == (rows, cols // cpb)
    out = quantize.unpack_codes(packed, name)
    np.testing.assert_array_equal(codes, np.asarray(out))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["nf4", "nf2", "int4"]))
def test_blockwise_error_bounded_by_half_gap(seed, name):
    """|w - dequant(quant(w))| <= scale * max_half_gap, elementwise."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    q, s_blk = quantize.quantize_blockwise(w, 32, name)
    w_hat = quantize.dequantize_blockwise(q, s_blk, 32, name)
    cb = np.asarray(lut.codebook(name))
    half_gap = np.max(np.diff(cb)) / 2
    bound = np.repeat(np.asarray(s_blk), 32, axis=1) * half_gap + 1e-6
    assert np.all(np.abs(np.asarray(w - w_hat)) <= bound)


def test_blockwise_idempotent():
    """Quantizing an already-dequantized weight is a fixed point."""
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 128)) * 0.1
    q1, s1 = quantize.quantize_blockwise(w, 64, "nf4")
    w1 = quantize.dequantize_blockwise(q1, s1, 64, "nf4")
    q2, s2 = quantize.quantize_blockwise(w1, 64, "nf4")
    w2 = quantize.dequantize_blockwise(q2, s2, 64, "nf4")
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)


def test_quantize_codes_negative_scale_argmin():
    """Alg.1 quantization step must be exact for negative scales too."""
    w = jnp.asarray([[0.5, -0.5, 0.2]], jnp.float32)
    s = jnp.asarray([[-1.0, -1.0, -0.5]], jnp.float32)
    codes = quantize.quantize_codes(w, s, "nf4")
    cb = np.asarray(lut.codebook("nf4"))
    picked = cb[np.asarray(codes, np.int32)[0]]
    for j in range(3):
        errs = (float(s[0, j]) * cb - float(w[0, j])) ** 2
        assert np.isclose((float(s[0, j]) * picked[j] - float(w[0, j])) ** 2,
                          errs.min(), atol=1e-10)


def test_fake_quant_matches_two_step():
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 64)) * 0.05
    b, a = scaling.lords_init_from_weight(w, 32, rank=2)
    s = scaling.scale_matrix(b, a)
    fq = quantize.fake_quant(w, s, "nf4")
    codes = quantize.quantize_codes(w, s, "nf4")
    two = quantize.dequantize_codes(codes, s, "nf4", dtype=w.dtype)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(two), atol=1e-7)


@pytest.mark.parametrize("m,bs", [(16, 32), (64, 128), (128, 128)])
def test_eff_block_clamps(m, bs):
    w = jax.random.normal(jax.random.PRNGKey(3), (4, m))
    s_blk = scaling.blockwise_scales(w, bs)
    assert s_blk.shape == (4, m // min(bs, m))
