"""Quantize/dequantize/pack invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.core import lut, quantize, scaling


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 16),
       st.sampled_from(["nf4", "nf2", "int8", "nf3", "fp4"]),
       st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(rows, groups, name, seed):
    ps = quantize.pack_spec(name)
    cols = groups * ps.group_codes  # cross-byte: nf3 = 8 codes / 3 bytes
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, len(lut.codebook(name)),
                         (rows, cols)).astype(np.uint8)
    packed = quantize.pack_codes(jnp.asarray(codes), name)
    assert packed.shape == (rows, groups * ps.group_bytes)
    out = quantize.unpack_codes(packed, name)
    np.testing.assert_array_equal(codes, np.asarray(out))


def test_pack_spec_layout():
    """Storage contract: true bit-packing densities, little-endian groups."""
    assert quantize.pack_spec("nf4").packed_width(256) == 128
    assert quantize.pack_spec("nf3").packed_width(256) == 96  # 3 bits/code
    assert quantize.pack_spec("nf2").packed_width(256) == 64
    assert quantize.pack_spec("int8").packed_width(256) == 256
    # nf4/nf2 stay byte-identical to the historical single-byte layout:
    # code i lives at bits [bits*i, bits*(i+1)) of its byte
    codes = jnp.asarray([[1, 2, 3, 0]], jnp.uint8)
    assert np.asarray(quantize.pack_codes(codes, "nf4")).tolist() \
        == [[1 | (2 << 4), 3]]
    assert np.asarray(quantize.pack_codes(codes, "nf2")).tolist() \
        == [[1 | (2 << 2) | (3 << 4)]]
    # nf3 group: 8 codes -> one little-endian 24-bit word -> 3 bytes
    codes = jnp.asarray([[5, 1, 7, 2, 0, 3, 6, 4]], jnp.uint8)
    word = sum(c << (3 * i) for i, c in enumerate([5, 1, 7, 2, 0, 3, 6, 4]))
    assert np.asarray(quantize.pack_codes(codes, "nf3")).tolist() \
        == [[word & 0xFF, (word >> 8) & 0xFF, (word >> 16) & 0xFF]]


def test_pack_errors_are_descriptive():
    with pytest.raises(ValueError, match="pack_spec"):
        quantize.codes_per_byte("nf3")  # cross-byte: no integer codes/byte
    with pytest.raises(ValueError, match="unknown codebook"):
        quantize.pack_spec("nf5")
    with pytest.raises(ValueError, match="divisible"):
        quantize.pack_spec("nf3").packed_width(12)  # 12 % 8 != 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["nf4", "nf2", "int4"]))
def test_blockwise_error_bounded_by_half_gap(seed, name):
    """|w - dequant(quant(w))| <= scale * max_half_gap, elementwise."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((8, 64)).astype(np.float32))
    q, s_blk = quantize.quantize_blockwise(w, 32, name)
    w_hat = quantize.dequantize_blockwise(q, s_blk, 32, name)
    cb = np.asarray(lut.codebook(name))
    half_gap = np.max(np.diff(cb)) / 2
    bound = np.repeat(np.asarray(s_blk), 32, axis=1) * half_gap + 1e-6
    assert np.all(np.abs(np.asarray(w - w_hat)) <= bound)


def test_blockwise_idempotent():
    """Quantizing an already-dequantized weight is a fixed point."""
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 128)) * 0.1
    q1, s1 = quantize.quantize_blockwise(w, 64, "nf4")
    w1 = quantize.dequantize_blockwise(q1, s1, 64, "nf4")
    q2, s2 = quantize.quantize_blockwise(w1, 64, "nf4")
    w2 = quantize.dequantize_blockwise(q2, s2, 64, "nf4")
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)


def test_quantize_codes_negative_scale_argmin():
    """Alg.1 quantization step must be exact for negative scales too."""
    w = jnp.asarray([[0.5, -0.5, 0.2]], jnp.float32)
    s = jnp.asarray([[-1.0, -1.0, -0.5]], jnp.float32)
    codes = quantize.quantize_codes(w, s, "nf4")
    cb = np.asarray(lut.codebook("nf4"))
    picked = cb[np.asarray(codes, np.int32)[0]]
    for j in range(3):
        errs = (float(s[0, j]) * cb - float(w[0, j])) ** 2
        assert np.isclose((float(s[0, j]) * picked[j] - float(w[0, j])) ** 2,
                          errs.min(), atol=1e-10)


def test_fake_quant_matches_two_step():
    w = jax.random.normal(jax.random.PRNGKey(2), (8, 64)) * 0.05
    b, a = scaling.lords_init_from_weight(w, 32, rank=2)
    s = scaling.scale_matrix(b, a)
    fq = quantize.fake_quant(w, s, "nf4")
    codes = quantize.quantize_codes(w, s, "nf4")
    two = quantize.dequantize_codes(codes, s, "nf4", dtype=w.dtype)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(two), atol=1e-7)


@pytest.mark.parametrize("m,bs", [(16, 32), (64, 128), (128, 128)])
def test_eff_block_clamps(m, bs):
    w = jax.random.normal(jax.random.PRNGKey(3), (4, m))
    s_blk = scaling.blockwise_scales(w, bs)
    assert s_blk.shape == (4, m // min(bs, m))
