"""Training fast path: fused transposed/grad-reduction backward kernels.

Covers the PR-3 acceptance criteria: fused-vs-ref gradient parity (dx, dB,
dA, ds, dW) on non-tile-aligned shapes, vmap over MoE expert stacks, a
jaxpr check that no (N, K) dequantized-weight f32 temporary exists in any
lords/qat/peft backward, 3-step loss-decrease smokes for qat and peft
through the interpreter, and transposed-key autotune persistence.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, init_quantized_linear
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import qmatmul
from repro.kernels.lords_grad import lords_grad_pallas
from repro.kernels.lords_matmul_t import lords_matmul_t_pallas

# deliberately NOT tile-aligned: M odd/small, N/K off the 128/256/512 grid
SHAPES = [(5, 96, 160), (33, 200, 96), (1, 130, 320)]


def _lords_setup(n, m, mode="peft", seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n, m)) * 0.02
    spec = QuantSpec(method="lords", block_size=32, rank=3, mode=mode,
                     compute_dtype=jnp.float32)
    return init_quantized_linear(key, n, m, spec, w=w), spec


# ---------------------------------------------------------------------------
# kernel-level parity: transposed matmul + grad reduction vs the ref oracle
# ---------------------------------------------------------------------------


def test_transposed_kernel_matches_oracle_aligned():
    mtok, n, k = 16, 128, 256
    params, spec = _lords_setup(n, k)
    g = jax.random.normal(jax.random.PRNGKey(1), (mtok, n))
    dx_k = lords_matmul_t_pallas(g, params["q"], params["b"], params["a"],
                                 bm=8, bn=128, bk=128, interpret=True)
    dx_r = ref.lords_matmul_t_ref(g, params["q"], params["b"], params["a"])
    np.testing.assert_allclose(np.asarray(dx_k), np.asarray(dx_r),
                               rtol=3e-5, atol=3e-5)


def test_ops_wrappers_normalize_both_paths():
    """ops.lords_matmul_t / ops.lords_grad: kernel-path layout normalization
    (dbT transpose, da_part sum) must match the ref path's direct layout."""
    from repro.kernels import ops

    mtok, n, k = 16, 128, 256
    params, _ = _lords_setup(n, k, mode="qat")
    from repro.core.quantize import pack_codes, quantize_codes
    from repro.core.scaling import scale_matrix
    q = pack_codes(quantize_codes(
        params["w"], scale_matrix(params["b"], params["a"]), "nf4"), "nf4")
    g = jax.random.normal(jax.random.PRNGKey(15), (mtok, n))
    x = jax.random.normal(jax.random.PRNGKey(16), (mtok, k))
    kw = dict(interpret=True, bm=8, bn=128, bk=128)
    dx_k = ops.lords_matmul_t(g, q, params["b"], params["a"],
                              use_pallas=True, **kw)
    dx_r = ops.lords_matmul_t(g, q, params["b"], params["a"],
                              use_pallas=False)
    np.testing.assert_allclose(np.asarray(dx_k), np.asarray(dx_r),
                               rtol=3e-5, atol=3e-5)
    for w_arg in (None, params["w"]):
        g_k = ops.lords_grad(x, g, q, params["b"], params["a"], w=w_arg,
                             use_pallas=True, **kw)
        g_r = ops.lords_grad(x, g, q, params["b"], params["a"], w=w_arg,
                             use_pallas=False)
        assert len(g_k) == len(g_r) == (3 if w_arg is not None else 2)
        for name, gk, gr in zip(("db", "da", "dw"), g_k, g_r):
            np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                       rtol=3e-5, atol=3e-5, err_msg=name)


def test_grad_kernel_matches_oracle_aligned():
    mtok, n, k = 16, 128, 256
    params, spec = _lords_setup(n, k)
    g = jax.random.normal(jax.random.PRNGKey(2), (mtok, n))
    x = jax.random.normal(jax.random.PRNGKey(3), (mtok, k))
    dbt, da_part = lords_grad_pallas(x, g, params["q"], params["b"],
                                     params["a"], bm=8, bn=128, bk=128,
                                     interpret=True)
    _, db_r, da_r = ref.lords_grads_ref(g, x, params["q"], params["b"],
                                        params["a"])
    np.testing.assert_allclose(np.asarray(dbt.T), np.asarray(db_r),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(da_part.sum(0)), np.asarray(da_r),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# dispatch-level gradient parity on non-tile-aligned shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mtok,n,m", SHAPES)
def test_peft_bwd_parity_nonaligned(mtok, n, m):
    """dx, dB, dA: fused interpret backward == ref == legacy dense."""
    params, spec = _lords_setup(n, m, mode="peft")
    x = jax.random.normal(jax.random.PRNGKey(4), (mtok, m))

    def loss(t, xx, bk):
        p = dict(params, b=t[0], a=t[1])
        return jnp.sum(qmatmul(p, xx, spec, n, m, backend=bk) ** 2)

    t0 = (params["b"], params["a"])
    for bk in ("interpret", "ref"):
        g_f = jax.grad(loss)(t0, x, bk)
        g_d = jax.grad(loss)(t0, x, "dense")
        for name, gf, gd in zip("ba", g_f, g_d):
            np.testing.assert_allclose(np.asarray(gf), np.asarray(gd),
                                       rtol=1e-4, atol=1e-5,
                                       err_msg=f"{bk} d{name}")
        gx_f = jax.grad(loss, argnums=1)(t0, x, bk)
        gx_d = jax.grad(loss, argnums=1)(t0, x, "dense")
        np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_d),
                                   rtol=1e-4, atol=1e-5, err_msg=f"{bk} dx")


@pytest.mark.parametrize("mtok,n,m", SHAPES)
def test_qat_bwd_parity_nonaligned(mtok, n, m):
    """dx, dW, dB, dA: fused STE backward (Eq. 4/5) == dense autodiff."""
    params, spec = _lords_setup(n, m, mode="qat")
    x = jax.random.normal(jax.random.PRNGKey(5), (mtok, m))

    def loss(t, xx, bk):
        p = dict(params, w=t[0], b=t[1], a=t[2])
        return jnp.sum(qmatmul(p, xx, spec, n, m, backend=bk) ** 2)

    t0 = (params["w"], params["b"], params["a"])
    g_f = jax.grad(loss)(t0, x, "interpret")
    g_d = jax.grad(loss)(t0, x, "dense")
    for name, gf, gd in zip("wba", g_f, g_d):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gd),
                                   rtol=1e-4, atol=1e-5, err_msg=f"d{name}")


@pytest.mark.parametrize("mtok,n,m,bs", [(5, 96, 160, 32), (7, 64, 192, 96)])
def test_block_bwd_parity(mtok, n, m, bs):
    """ds_blk + dx parity, incl. a block spanning multiple k tiles."""
    key = jax.random.PRNGKey(6)
    spec = QuantSpec(method="blockwise", block_size=bs,
                     compute_dtype=jnp.float32)
    params = init_quantized_linear(key, n, m, spec,
                                   w=jax.random.normal(key, (n, m)) * 0.02)
    x = jax.random.normal(jax.random.PRNGKey(7), (mtok, m))

    def loss(s, xx, bk):
        return jnp.sum(qmatmul(dict(params, s_blk=s), xx, spec, n, m,
                               backend=bk) ** 2)

    gs_f = jax.grad(loss)(params["s_blk"], x, "interpret")
    gs_d = jax.grad(loss)(params["s_blk"], x, "dense")
    np.testing.assert_allclose(np.asarray(gs_f), np.asarray(gs_d),
                               rtol=1e-4, atol=1e-5)
    gx_f = jax.grad(loss, argnums=1)(params["s_blk"], x, "interpret")
    gx_d = jax.grad(loss, argnums=1)(params["s_blk"], x, "dense")
    np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-5)


def test_vmap_moe_expert_stack_grads():
    """Backward through a vmapped expert stack (the MoE training path)."""
    spec = QuantSpec(method="lords", block_size=32, rank=2, mode="peft",
                     compute_dtype=jnp.float32)
    e, n, m = 3, 64, 96
    keys = jax.random.split(jax.random.PRNGKey(8), e)
    stack = jax.vmap(lambda k: init_quantized_linear(k, n, m, spec))(keys)
    xd = jax.random.normal(jax.random.PRNGKey(9), (e, 16, m))

    def loss(ba, bk):
        y = jax.vmap(
            lambda bb, aa, q, xe: qmatmul({"q": q, "b": bb, "a": aa}, xe,
                                          spec, n, m, backend=bk)
        )(ba[0], ba[1], stack["q"], xd)
        return jnp.sum(y ** 2)

    g_f = jax.grad(loss)((stack["b"], stack["a"]), "interpret")
    g_d = jax.grad(loss)((stack["b"], stack["a"]), "dense")
    for name, gf, gd in zip("ba", g_f, g_d):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gd),
                                   rtol=1e-4, atol=1e-5, err_msg=f"d{name}")


# ---------------------------------------------------------------------------
# no (N, K) f32 dequantized-weight temporary in the fused backward (jaxpr)
# ---------------------------------------------------------------------------

# primitives allowed to produce (>=N, >=K)-shaped float arrays in the fused
# path: kernel launches (their tile-level internals live in VMEM, not HBM),
# operand padding, slicing kernel outputs (the QAT dW *parameter gradient*
# flows through these), and call boundaries (pjit: pass-through — their
# bodies are walked separately).  Anything else — dot_general for S=B·A,
# gather for lut[Q], mul for vals⊙S — is dense-path dequantization.
_ALLOWED = {"pallas_call", "pad", "slice", "dynamic_slice", "squeeze",
            "reshape", "copy", "transpose", "pjit"}


def _nk_float_eqns(fn, *args, n, k):
    """(primitive, shape) of every eqn output with a (>=n, >=k) float shape,
    walking nested jaxprs but not into pallas_call kernel bodies."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    found = []

    def walk(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                aval = v.aval
                shape = getattr(aval, "shape", ())
                if (len(shape) == 2 and shape[0] >= n and shape[1] >= k
                        and jnp.issubdtype(aval.dtype, jnp.floating)):
                    found.append((eqn.primitive.name, shape))
            if eqn.primitive.name == "pallas_call":
                continue
            for val in eqn.params.values():
                for sub in _subjaxprs(val):
                    walk(sub)

    def _subjaxprs(val):
        if isinstance(val, jax.core.ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, jax.core.Jaxpr):
            yield val
        elif isinstance(val, (tuple, list)):
            for v in val:
                yield from _subjaxprs(v)

    walk(jaxpr.jaxpr)
    return found


@pytest.mark.parametrize("mode", ["peft", "qat"])
def test_no_dense_weight_temp_in_fused_bwd(mode):
    n, m = 96, 160
    params, spec = _lords_setup(n, m, mode=mode)
    x = jax.random.normal(jax.random.PRNGKey(10), (5, m))
    keys = ("w", "b", "a") if mode == "qat" else ("b", "a")

    def make_loss(bk):
        def loss(t):
            return jnp.sum(
                qmatmul(dict(params, **dict(zip(keys, t))), x, spec, n, m,
                        backend=bk) ** 2)
        return loss

    t0 = tuple(params[kk] for kk in keys)
    fused = _nk_float_eqns(jax.grad(make_loss("interpret")), t0, n=n, k=m)
    bad = [f for f in fused if f[0] not in _ALLOWED]
    assert not bad, f"dense (N,K) temporaries in fused {mode} bwd: {bad}"
    # sanity: the checker does flag the legacy dequantize-then-einsum path
    dense = _nk_float_eqns(jax.grad(make_loss("dense")), t0, n=n, k=m)
    assert len([f for f in dense if f[0] not in _ALLOWED]) >= 3


# ---------------------------------------------------------------------------
# 3-step loss-decrease smokes through the interpreter (fused fwd + bwd)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["peft", "qat"])
def test_three_step_loss_decrease_interpret(mode):
    n, m = 64, 96
    params, spec = _lords_setup(n, m, mode=mode, seed=11)
    x = jax.random.normal(jax.random.PRNGKey(12), (32, m))
    y = jax.random.normal(jax.random.PRNGKey(13), (32, n)) * 0.1
    keys = ("w", "b", "a") if mode == "qat" else ("b", "a")
    t = {kk: params[kk] for kk in keys}

    def loss_fn(t):
        p = dict(params, **t)
        return jnp.mean((qmatmul(p, x, spec, n, m, backend="interpret") - y)
                        ** 2)

    vg = jax.jit(jax.value_and_grad(loss_fn))
    losses = []
    for _ in range(3):
        l, g = vg(t)
        losses.append(float(l))
        t = jax.tree.map(lambda p, gg: p - 0.05 * gg, t, g)
    losses.append(float(vg(t)[0]))
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses).all()


# ---------------------------------------------------------------------------
# transposed-key autotune: registration, numerics, persistence
# ---------------------------------------------------------------------------


def test_bwd_autotune_registers_and_persists(tmp_path, monkeypatch):
    cache = tmp_path / "tiles.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    n, m = 96, 160
    params, spec = _lords_setup(n, m, mode="peft")
    x = jax.random.normal(jax.random.PRNGKey(14), (5, m))
    best, timings = dispatch.autotune_qmatmul_bwd(
        params, x, spec, n, m, backend="interpret",
        candidates=[(8, 128, 256), (8, 128, 512)], iters=1)
    assert best in timings and len(timings) >= 1
    assert dispatch.lookup_tiles("lords_t", 5, n, m, spec.codebook,
                                 jnp.float32) == best
    data = json.loads(cache.read_text())
    assert any(e["key"][0] == "lords_t" for e in data["entries"])
    # backward with the registered transposed tiles still matches the oracle
    def loss(t, bk):
        p = dict(params, b=t[0], a=t[1])
        return jnp.sum(qmatmul(p, x, spec, n, m, backend=bk) ** 2)
    g_f = jax.grad(loss)((params["b"], params["a"]), "interpret")
    g_r = jax.grad(loss)((params["b"], params["a"]), "ref")
    for gf, gr in zip(g_f, g_r):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                                   rtol=1e-4, atol=1e-5)
