"""Elastic fault-tolerant distributed execution (PR 10), pinned under the
8-device harness: injected ``dist.device_loss`` must shrink the host mesh
and elastically restore (train: checkpoint reshard + data reseek; engine:
param reshard + full recompute with **bit-identical** tokens), an injected
replica desync must be detected within one digest interval and rolled
back (or quarantine the run when there is nothing to roll back to), the
straggler watchdog must flag injected slow shards, and the data-parallel
streaming PTQ must reproduce the single-host artifact byte-for-byte —
including across a kill-plus-mesh-shrink resume.  The deadline-cancel and
preemption-drain-under-eviction engine paths are re-pinned here on a
mesh-backed engine (single-device coverage lives in test_paged_engine).
"""
import os

import jax
import numpy as np
import pytest

from multidevice_compat import dp_tp_mesh, multidevice, tp_mesh
from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.launch.engine import Engine, Request
from repro.launch.train import run_training
from repro.models import model_init, split_tree
from repro.ptq_stream import (
    ResidualMLPSource,
    StreamPlan,
    audit_artifact,
    read_shard,
    stream_quantize,
)
from repro.ptq_stream.shards import shard_name
from repro.robustness import NO_FAULTS, FaultPlan, InjectedFault

STEPS = 6
N_BLOCKS = 3


def _tiny():
    cfg = smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64)
    return cfg, ShapeCfg("t", 32, 4, "train")


# ---------------------------------------------------------------------------
# training: device loss -> mesh rebuild + elastic restore
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def train_ref():
    cfg, shape = _tiny()
    out = run_training(cfg, shape, steps=STEPS, lr=1e-3, log_every=1000)
    return float(out["losses"][-1])


@multidevice
def test_train_device_loss_rebuilds_mesh_and_restores(train_ref, tmp_path):
    """A device loss at step 3 shrinks 2x4 -> 1x4, restores the step-2
    checkpoint through elastic resharding, reseeks the data iterator and
    finishes; the final loss lands within tolerance of the fault-free
    run (the restored trajectory replays the lost steps)."""
    cfg, shape = _tiny()
    out = run_training(cfg, shape, steps=STEPS, lr=1e-3, log_every=1000,
                       mesh=dp_tp_mesh(), ckpt_dir=str(tmp_path / "ck"),
                       ckpt_every=2,
                       faults=FaultPlan(0, {"dist.device_loss": {"at": (3,)}}))
    assert out["status"] == "complete"
    assert out["mesh_rebuilds"] == 1
    assert out["lost_devices"] == 4          # 2x4 -> 1x4
    assert out["resharded_restores"] == 1
    assert out["final_mesh"] == {"data": 1, "model": 4}
    tol = 0.15 * abs(train_ref) + 0.05
    assert abs(float(out["losses"][-1]) - train_ref) <= tol


@multidevice
def test_train_device_loss_without_checkpoint_live_reshards(train_ref):
    """No checkpoint dir: the surviving state is device_put onto the new
    mesh in place (live reshard, no restore) and training continues."""
    cfg, shape = _tiny()
    out = run_training(cfg, shape, steps=STEPS, lr=1e-3, log_every=1000,
                       mesh=dp_tp_mesh(),
                       faults=FaultPlan(0, {"dist.device_loss": {"at": (3,)}}))
    assert out["status"] == "complete"
    assert out["mesh_rebuilds"] == 1
    assert out["resharded_restores"] == 0    # nothing to restore from
    tol = 0.15 * abs(train_ref) + 0.05
    assert abs(float(out["losses"][-1]) - train_ref) <= tol


# ---------------------------------------------------------------------------
# training: replica desync -> detect within one interval, rollback
# ---------------------------------------------------------------------------


@multidevice
def test_train_desync_detected_within_one_interval_and_rolled_back(tmp_path):
    cfg, shape = _tiny()
    out = run_training(
        cfg, shape, steps=STEPS, lr=1e-3, log_every=1000,
        mesh=dp_tp_mesh(), desync_every=2, ckpt_dir=str(tmp_path / "ck"),
        ckpt_every=1,
        faults=FaultPlan(0, {"dist.replica_desync":
                             {"prob": 1.0, "max_fires": 1, "only_index": 1}}))
    assert out["status"] == "complete"
    assert out["desyncs_detected"] == 1      # caught at the first digest
    assert out["desync_rollbacks"] == 1
    assert len(out["losses"]) == STEPS
    assert all(np.isfinite(out["losses"]))


@multidevice
def test_train_desync_without_checkpoint_quarantines():
    """Divergence with no checkpoint to roll back to must stop the run
    with status 'quarantined' — never silently continue desynced."""
    cfg, shape = _tiny()
    out = run_training(
        cfg, shape, steps=STEPS, lr=1e-3, log_every=1000,
        mesh=dp_tp_mesh(), desync_every=2,
        faults=FaultPlan(0, {"dist.replica_desync":
                             {"prob": 1.0, "max_fires": 1, "only_index": 1}}))
    assert out["status"] == "quarantined"
    assert out["desyncs_detected"] == 1
    assert out["desync_rollbacks"] == 0


# ---------------------------------------------------------------------------
# engine: elastic rebuild, straggler watchdog, mesh-backed deadline/preempt
# ---------------------------------------------------------------------------


def _ecfg():
    return smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64, kv_cache_dtype="int8")


def _ereqs(cfg, plens, gens, gap=0.0, seed=7, deadline=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, (p,))
                    .astype(np.int32),
                    max_new=g, arrival=gap * i, deadline_s=deadline)
            for i, (p, g) in enumerate(zip(plens, gens))]


@pytest.fixture(scope="module")
def engine_params():
    cfg = _ecfg()
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    return cfg, params


@pytest.fixture(scope="module")
def engine_baseline(engine_params):
    """Single-device oracle tokens for the elastic-recovery traces."""
    cfg, params = engine_params
    eng = Engine(cfg, slots=2, total_pages=12, page_size=8, max_pages=4,
                 chunk=16, burst=4, kernel_backend="interpret", params=params)
    stats = eng.run(_ereqs(cfg, [10, 6, 13], [5, 5, 5]), timeout_s=600)
    assert stats["all_completed"]
    return {r["rid"]: r["tokens"] for r in stats["records"]}


@multidevice
def test_engine_device_loss_rebuild_tokens_bit_identical(
        engine_params, engine_baseline):
    """Device loss at tick 3 on a 2x4 mesh: the engine rebuilds 1x4,
    reshards params, requeues in-flight work without charging retries,
    and — greedy decoding plus full recompute — every output token stays
    bit-identical to the single-device run."""
    cfg, params = engine_params
    eng = Engine(cfg, mesh=dp_tp_mesh(), slots=2, total_pages=12,
                 page_size=8, max_pages=4, chunk=16, burst=4,
                 kernel_backend="interpret", params=params,
                 faults=FaultPlan(0, {"dist.device_loss": {"at": (3,)}}))
    stats = eng.run(_ereqs(cfg, [10, 6, 13], [5, 5, 5]), timeout_s=600)
    assert stats["all_completed"], stats["statuses"]
    assert stats["mesh_rebuilds"] == 1
    assert stats["lost_devices"] == 4
    assert stats["resharded_restores"] == 1
    assert stats["page_audit"]["ok"], stats["page_audit"]
    toks = {r["rid"]: r["tokens"] for r in stats["records"]}
    assert toks == engine_baseline


@multidevice
def test_engine_straggler_watchdog_flags_injected_shards(
        engine_params, engine_baseline):
    """Per-shard dist.straggler injections are caught by the watchdog and
    reported in stats['straggler_flags'] with the shard indices; injected
    collective timeouts ride the retry/requeue path and the run still
    produces oracle-identical tokens."""
    cfg, params = engine_params
    eng = Engine(cfg, mesh=dp_tp_mesh(), slots=2, total_pages=12,
                 page_size=8, max_pages=4, chunk=16, burst=4,
                 kernel_backend="interpret", params=params,
                 faults=FaultPlan(0, {
                     "dist.collective_timeout": {"at": (1,)},
                     "dist.straggler": {"prob": 0.3, "delay_s": 0.05,
                                        "max_fires": 3}}))
    stats = eng.run(_ereqs(cfg, [10, 6, 13], [5, 5, 5]), timeout_s=600)
    assert stats["all_completed"], stats["statuses"]
    assert stats["collective_timeouts"] == 1
    injected = [f for f in stats["straggler_flags"] if f["injected"]]
    assert injected, "injected stragglers never flagged"
    for f in injected:
        assert f["shards"] and all(0 <= s < 8 for s in f["shards"])
    toks = {r["rid"]: r["tokens"] for r in stats["records"]}
    assert toks == engine_baseline


@pytest.fixture(scope="module")
def mesh_engine(engine_params):
    """Mesh-backed engine with the hardened-suite pool geometry (7 usable
    pages, 5-page tables) so the eviction-pressure traces carry over."""
    cfg, params = engine_params
    eng = Engine(cfg, mesh=tp_mesh(), slots=2, total_pages=8, page_size=8,
                 max_pages=5, chunk=16, burst=4, kernel_backend="interpret",
                 params=params)
    eng.warmup()
    return cfg, eng


@pytest.fixture
def meng(mesh_engine):
    cfg, eng = mesh_engine
    yield cfg, eng
    eng.faults = NO_FAULTS


@multidevice
def test_engine_deadline_cancels_on_mesh(meng):
    """Satellite: deadline-cancel re-pinned on a sharded engine.  The
    deadline-stretched request alone is cancelled with partial output;
    its deadline-free sibling completes identically to the clean run."""
    cfg, eng = meng
    reqs = _ereqs(cfg, [10, 6], [10, 24], seed=5)
    clean = eng.run([Request(0, reqs[0].tokens, 10),
                     Request(1, reqs[1].tokens, 24)], timeout_s=600)
    assert clean["all_completed"]
    clean_toks = {r["rid"]: r["tokens"] for r in clean["records"]}

    eng.faults = FaultPlan(0, {"engine.straggler": {"at": (2,),
                                                    "delay_s": 1.0}})
    stats = eng.run([Request(0, reqs[0].tokens, 10),
                     Request(1, reqs[1].tokens, 24, deadline_s=0.5)],
                    timeout_s=600)
    rec = {r["rid"]: r for r in stats["records"]}
    assert rec[1]["status"] == "timeout" and rec[1]["reason"] == "deadline"
    assert stats["deadline_cancels"] >= 1
    assert rec[0]["status"] == "completed"
    assert rec[0]["tokens"] == clean_toks[0]
    assert stats["page_audit"]["ok"], stats["page_audit"]


@multidevice
def test_engine_preemption_drain_under_eviction_on_mesh(meng):
    """Satellite: preemption-drain x eviction on a sharded engine.  The
    eviction-heavy trace (two concurrent 5-page requests over a 7-page
    pool) is preempted mid-run: in-flight work drains to terminal states,
    late arrivals are rejected 'preempted', and the page-pool audit stays
    clean through the stall/evict/recompute churn."""
    cfg, eng = meng
    reqs = _ereqs(cfg, [8, 8, 10, 8, 9], [32, 32, 12, 24, 8],
                  gap=0.02, seed=13)
    clean = eng.run(reqs, timeout_s=600)
    assert clean["all_completed"], clean["statuses"]
    assert clean["evictions"] > 0, "trace was sized to force eviction"

    eng.faults = FaultPlan(0, {"engine.preempt": {"at": (12,)}})
    stats = eng.run(reqs, timeout_s=600)
    assert stats["preempted"] and stats["drained"] == "preempted"
    assert len(stats["records"]) == len(reqs)
    st = stats["statuses"]
    assert st.get("completed", 0) >= 1, st      # in-flight work drained
    assert st.get("rejected", 0) >= 1, st       # late arrivals shed
    assert all(r["reason"] == "preempted"
               for r in stats["records"] if r["status"] == "rejected")
    assert stats["page_audit"]["ok"], stats["page_audit"]


# ---------------------------------------------------------------------------
# sharded streaming PTQ: mesh parity + crash/resume across a mesh shrink
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def ptq_source(tmp_path_factory):
    return ResidualMLPSource.create(
        str(tmp_path_factory.mktemp("model")),
        num_blocks=N_BLOCKS, d=48, d_ff=64, tokens=16, seed=0)


@pytest.fixture(scope="module")
def ptq_plan():
    return StreamPlan(block_size=16, rank=3, refine_steps=6)


@pytest.fixture(scope="module")
def ptq_reference(ptq_source, ptq_plan, tmp_path_factory):
    out = str(tmp_path_factory.mktemp("ref"))
    stream_quantize(ptq_source, out, ptq_plan)
    return [read_shard(os.path.join(out, shard_name(i)))
            for i in range(N_BLOCKS)]


def _assert_identical(ref_shards, out_dir):
    for i, want in enumerate(ref_shards):
        got = read_shard(os.path.join(out_dir, shard_name(i)))
        assert sorted(got) == sorted(want), f"block {i}: key set differs"
        for k in want:
            np.testing.assert_array_equal(got[k], want[k],
                                          err_msg=f"block {i} key {k}")


@multidevice
def test_ptq_sharded_run_bit_identical_to_single_host(
        ptq_source, ptq_plan, ptq_reference, tmp_path):
    """The mesh is placement only: a clean 2x4 data-parallel streamed run
    must produce byte-identical shards and a clean audit."""
    out = str(tmp_path / "out")
    s = stream_quantize(ptq_source, out, ptq_plan, mesh=dp_tp_mesh())
    assert s["status"] == "complete"
    assert s["recomputed"] == list(range(N_BLOCKS))
    _assert_identical(ptq_reference, out)
    assert audit_artifact(out, ptq_source, ptq_plan)["clean"]


@multidevice
def test_ptq_sharded_kill_resume_across_mesh_shrink(
        ptq_source, ptq_plan, ptq_reference, tmp_path):
    """Killed at a block boundary on 2x4, resumed on the shrunken 1x4
    mesh: proven blocks are reused, the rest recomputed, and the final
    artifact is bit-identical to the uninterrupted single-host run."""
    out = str(tmp_path / "out")
    with pytest.raises(InjectedFault):
        stream_quantize(ptq_source, out, ptq_plan, mesh=dp_tp_mesh(),
                        faults=FaultPlan(17, {"ptq.kill_at_block":
                                              {"at": (1,)}}))
    s = stream_quantize(ptq_source, out, ptq_plan, resume=True,
                        mesh=dp_tp_mesh(1, 4))
    assert s["status"] == "complete"
    assert s["reused"] == 1 and s["recomputed"] == [1, 2]
    _assert_identical(ptq_reference, out)
    assert audit_artifact(out, ptq_source, ptq_plan)["clean"]
