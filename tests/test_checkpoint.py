"""Checkpointer round-trips — packed-code bit-exactness, retention,
manifest robustness — plus the sharded save/restore layout and the
PreemptionGuard → checkpoint → restore integration path.

Single-device cases run in tier-1; the `multidevice` cases (per-shard
save files, sharded train resume) need the 8-way forced host mesh
(make test-multidevice)."""
import json
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from multidevice_compat import dp_tp_mesh, multidevice, single_mesh, tp_mesh
from repro.checkpoint import Checkpointer
from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.distributed.fault_tolerance import PreemptionGuard
from repro.launch.train import run_training


def _quant_state(seed=0):
    """A LoRDS-shaped tree: packed uint8 codes + f32 factors + step."""
    key = jax.random.PRNGKey(seed)
    return {
        "params": {
            "q": jax.random.randint(key, (64, 16), 0, 255).astype(jnp.uint8),
            "b": jax.random.normal(key, (64, 3)),
            "a": jax.random.normal(key, (3, 32)),
            "emb": jax.random.normal(key, (8, 4), jnp.bfloat16),
        },
        "data_step": 7,
    }


# ---------------------------------------------------------------------------
# single-device round-trips
# ---------------------------------------------------------------------------


def test_packed_codes_roundtrip_bit_exact(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _quant_state()
    ck.save(3, state)
    r = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(r["params"]["q"]),
                                  np.asarray(state["params"]["q"]))
    assert np.asarray(r["params"]["q"]).dtype == np.uint8
    np.testing.assert_array_equal(np.asarray(r["params"]["b"]),
                                  np.asarray(state["params"]["b"]))
    assert int(np.asarray(r["data_step"])) == 7


def test_bf16_leaves_roundtrip_bit_exact(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _quant_state()
    ck.save(1, state)
    r = ck.restore(state)
    got = np.asarray(r["params"]["emb"])
    want = np.asarray(state["params"]["emb"])
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(got.view(np.uint16), want.view(np.uint16))


def test_keep3_gc_prunes_oldest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3)
    for s in (1, 2, 3, 4, 5):
        ck.save(s, _quant_state())
    assert ck.all_steps() == [3, 4, 5]
    assert ck.latest_step() == 5


def test_keep_zero_disables_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=0)
    for s in (1, 2):
        ck.save(s, _quant_state())
    assert ck.all_steps() == [1, 2]


def test_latest_step_survives_corrupt_manifest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(4, _quant_state())
    with open(tmp_path / "MANIFEST.json", "w") as f:
        f.write("{not json")
    assert ck.latest_step() == 4
    # and restore still works off the recovered step
    assert ck.restore(_quant_state()) is not None


def test_latest_step_partial_manifest_ignores_gcd_steps(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(2, _quant_state())
    with open(tmp_path / "MANIFEST.json", "w") as f:
        json.dump({"steps": [2, 9], "latest": 9}, f)  # 9 never materialized
    assert ck.latest_step() == 2


def test_latest_step_manifest_wrong_type(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(6, _quant_state())
    with open(tmp_path / "MANIFEST.json", "w") as f:
        json.dump([1, 2, 3], f)  # valid JSON, wrong shape
    assert ck.latest_step() == 6


def test_empty_dir_restore_returns_none(tmp_path):
    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() is None
    assert ck.restore(_quant_state()) is None


def test_v1_layout_read_compat(tmp_path):
    """Checkpoints written by the pre-sharding layout (flat `names` list)
    must keep restoring."""
    state = {"w": jnp.arange(12.0).reshape(3, 4), "data_step": 5}
    leaves, _ = jax.tree_util.tree_flatten(state)
    step_dir = tmp_path / "step_8"
    os.makedirs(step_dir)
    names = []
    for i, leaf in enumerate(leaves):
        name = f"leaf_{i:05d}_p0.npy"
        np.save(step_dir / name, np.asarray(leaf))
        names.append(name)
    with open(step_dir / "spec.json", "w") as f:
        json.dump({"treedef": "legacy", "names": names, "step": 8,
                   "num_leaves": len(names)}, f)
    ck = Checkpointer(str(tmp_path))
    r = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(state["w"]))


def test_manifest_records_pspecs_unsharded(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _quant_state())
    specs = ck.saved_pspecs()
    assert specs is not None and all(s is None for s in specs)


def test_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _quant_state())
    bad = _quant_state()
    bad["params"]["extra"] = jnp.zeros(2)
    with pytest.raises(ValueError):
        ck.restore(bad)


# ---------------------------------------------------------------------------
# preemption-guard integration
# ---------------------------------------------------------------------------


def test_preemption_guard_checkpoint_restore_smoke(tmp_path):
    """The production exit path: SIGTERM flips the guard mid-loop, the loop
    checkpoints and stops, a fresh 'process' restores exactly there."""
    ck = Checkpointer(str(tmp_path))
    guard = PreemptionGuard(signals=(signal.SIGUSR1,))
    try:
        state = _quant_state()
        stopped_at = None
        for step in range(10):
            state["data_step"] = step + 1
            if step == 2:
                os.kill(os.getpid(), signal.SIGUSR1)
            if guard.preempted:
                ck.save(step + 1, state)
                stopped_at = step + 1
                break
        assert stopped_at == 3  # handler runs before the same-step poll
        r = Checkpointer(str(tmp_path)).restore(_quant_state())
        assert int(np.asarray(r["data_step"])) == stopped_at
    finally:
        guard.restore()


# ---------------------------------------------------------------------------
# sharded save/restore (8-way forced host mesh)
# ---------------------------------------------------------------------------


def _sharded_state(mesh):
    row = NamedSharding(mesh, P("model", None))
    rep = NamedSharding(mesh, P())
    s = _quant_state()
    s["params"]["q"] = jax.device_put(s["params"]["q"], row)
    s["params"]["b"] = jax.device_put(s["params"]["b"], row)
    s["params"]["a"] = jax.device_put(s["params"]["a"], rep)
    s["params"]["emb"] = jax.device_put(s["params"]["emb"], rep)
    return s


@multidevice
def test_sharded_save_writes_per_shard_files_and_pspecs(tmp_path):
    mesh = dp_tp_mesh()  # 2×4: codes split 4-way, replicated over data
    state = _sharded_state(mesh)
    ck = Checkpointer(str(tmp_path))
    ck.save(5, state)
    with open(tmp_path / "step_5" / "spec.json") as f:
        spec = json.load(f)
    assert spec["version"] == 2
    sharded = [e for e in spec["leaves"] if e.get("indices")]
    # q and b row-shard 4-way; replication over 'data' must NOT double the
    # shard files (distinct index windows only)
    assert {len(e["files"]) for e in sharded} == {4}
    assert all("'model'" in e["pspec"] for e in sharded)
    reps = [e for e in spec["leaves"] if not e.get("indices")]
    assert reps, "replicated factors should save as single files"


@multidevice
def test_sharded_roundtrip_bit_exact_same_mesh(tmp_path):
    mesh = dp_tp_mesh()
    state = _sharded_state(mesh)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, state)
    sh = jax.tree.map(lambda x: x.sharding, state["params"])
    r = ck.restore(state, shardings={"params": sh,
                                     "data_step": NamedSharding(mesh, P())})
    for k in ("q", "b", "a"):
        np.testing.assert_array_equal(np.asarray(r["params"][k]),
                                      np.asarray(state["params"][k]))
        assert r["params"][k].sharding.spec == state["params"][k].sharding.spec


@multidevice
def test_sharded_elastic_restore_other_mesh(tmp_path):
    """Save on 2×4, restore onto 1×8 (scale-out of the model axis) and onto
    a single device (scale-in) — same bits either way."""
    mesh = dp_tp_mesh()
    state = _sharded_state(mesh)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, state)

    mesh8 = tp_mesh()
    row8 = NamedSharding(mesh8, P("model", None))
    rep8 = NamedSharding(mesh8, P())
    sh8 = {"params": {"q": row8, "b": row8, "a": rep8, "emb": rep8},
           "data_step": rep8}
    r8 = ck.restore(state, shardings=sh8)
    np.testing.assert_array_equal(np.asarray(r8["params"]["q"]),
                                  np.asarray(state["params"]["q"]))
    assert len(r8["params"]["q"].sharding.device_set) == 8

    r1 = ck.restore(state)  # no shardings: reassembled host arrays
    np.testing.assert_array_equal(np.asarray(r1["params"]["q"]),
                                  np.asarray(state["params"]["q"]))


@multidevice
def test_sharded_train_save_restore_resume_bit_exact(tmp_path):
    """The acceptance-criterion path: a data+tensor-parallel PEFT step
    checkpoints sharded (per-shard codes, replicated factors), restores
    onto the same mesh, and the resumed run is bit-exact with an
    uninterrupted one."""
    cfg = smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64)
    shape = ShapeCfg("t", 32, 4, "train")
    mesh = dp_tp_mesh()

    out_a = run_training(cfg, shape, steps=4, lr=1e-3, mesh=mesh,
                         log_every=1000)

    ckdir = str(tmp_path / "ck")
    run_training(cfg, shape, steps=2, lr=1e-3, mesh=mesh, ckpt_dir=ckdir,
                 ckpt_every=2, log_every=1000)
    # the checkpoint itself must be sharded: some leaf saved as >1 file
    ck = Checkpointer(ckdir)
    specs = ck.saved_pspecs()
    assert any(s and "'model'" in s for s in specs), specs
    out_b = run_training(cfg, shape, steps=2, lr=1e-3, mesh=mesh,
                         ckpt_dir=ckdir, ckpt_every=100, log_every=1000)

    la = jax.tree.leaves(out_a["trainable"])
    lb = jax.tree.leaves(out_b["trainable"])
    assert la and len(la) == len(lb)
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ---------------------------------------------------------------------------
# transient-IO retry (PR 7): every save/load file op runs behind
# retry_on_transient — flaky filesystems don't kill runs, permanent
# failures still raise after the bounded budget
# ---------------------------------------------------------------------------


class _FlakyIO:
    """np.save stand-in that raises OSError for the first ``n`` calls."""

    def __init__(self, n):
        self.remaining = n
        self.calls = 0
        self._real = np.save

    def __call__(self, *args, **kwargs):
        self.calls += 1
        if self.remaining > 0:
            self.remaining -= 1
            raise OSError("simulated transient IO failure")
        return self._real(*args, **kwargs)


def test_save_retries_transient_oserror(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path), io_retries=2, io_backoff=0.0)
    state = _quant_state()
    flaky = _FlakyIO(2)
    monkeypatch.setattr(np, "save", flaky)
    ck.save(1, state)          # 2 transient failures absorbed by retries
    monkeypatch.undo()
    assert flaky.remaining == 0 and flaky.calls > 2
    r = ck.restore(jax.tree.map(np.zeros_like, state))
    np.testing.assert_array_equal(np.asarray(r["params"]["q"]),
                                  np.asarray(state["params"]["q"]))
    np.testing.assert_array_equal(np.asarray(r["params"]["b"]),
                                  np.asarray(state["params"]["b"]))


def test_load_retries_transient_oserror(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path), io_retries=2, io_backoff=0.0)
    state = _quant_state()
    ck.save(3, state)
    real_load = np.load
    fails = {"n": 2}

    def flaky_load(*args, **kwargs):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("simulated transient read failure")
        return real_load(*args, **kwargs)

    monkeypatch.setattr(np, "load", flaky_load)
    r = ck.restore(jax.tree.map(np.zeros_like, state))
    monkeypatch.undo()
    assert fails["n"] == 0
    np.testing.assert_array_equal(np.asarray(r["params"]["q"]),
                                  np.asarray(state["params"]["q"]))


def test_save_raises_after_retry_budget(tmp_path, monkeypatch):
    """Permanent IO failure: the bounded retry budget is spent, the error
    propagates, and no committed checkpoint appears (atomicity holds —
    the tmp dir never got renamed into place)."""
    ck = Checkpointer(str(tmp_path), io_retries=1, io_backoff=0.0)
    flaky = _FlakyIO(10**6)
    monkeypatch.setattr(np, "save", flaky)
    with pytest.raises(OSError, match="transient"):
        ck.save(1, _quant_state())
    monkeypatch.undo()
    assert flaky.calls == 2        # first try + io_retries=1
    assert ck.latest_step() is None


def test_kill_mid_save_keeps_previous_checkpoint_restorable(tmp_path):
    """An injected crash partway through ``save`` (ckpt.save_crash, fired
    mid-leaf-loop) must leave the previous step as ``latest_step()`` and
    fully restorable — the atomic tmp-dir protocol never exposes a torn
    checkpoint."""
    from repro.robustness import FaultPlan, InjectedFault

    state = _quant_state()
    faults = FaultPlan(0, {"ckpt.save_crash": {"at": (6,)}})  # 2nd save,
    ck = Checkpointer(str(tmp_path), faults=faults)           # leaf 2 of 5
    ck.save(1, state)
    with pytest.raises(InjectedFault):
        ck.save(2, _quant_state(seed=1))
    assert ck.latest_step() == 1
    r = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(r["params"]["q"]),
                                  np.asarray(state["params"]["q"]))
    # the half-written attempt is only a .tmp dir; a retried save wins
    assert os.path.isdir(str(tmp_path / "step_2.tmp"))
    ck.save(2, _quant_state(seed=1))
    assert ck.latest_step() == 2
    assert ck.restore(state, step=2) is not None
