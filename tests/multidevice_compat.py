"""Shared harness for the opt-in 8-way host-CPU mesh suite.

Usage from a test module::

    from multidevice_compat import multidevice, dp_tp_mesh, single_mesh

    @multidevice
    def test_something_sharded():
        mesh = dp_tp_mesh()          # 2 data × 4 model over forced devices
        ...

The ``multidevice`` marker (registered in pyproject.toml) is auto-skipped by
conftest when fewer than 8 devices are visible, so tier-1 collection stays
green on a single CPU.  The 8 devices themselves come from
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, which conftest sets
*before the first jax import* when ``REPRO_MULTIDEVICE=1`` — the env a
dedicated pytest session / the ``multidevice-smoke`` CI job provides
(``make test-multidevice`` locally).
"""
from __future__ import annotations

import jax
import pytest

from repro.launch.mesh import make_host_mesh

REQUIRED_DEVICES = 8

multidevice = pytest.mark.multidevice


def device_count() -> int:
    return jax.device_count()


def tp_mesh(model: int = REQUIRED_DEVICES):
    """Pure tensor-parallel host mesh: (1, model)."""
    return make_host_mesh(data=1, model=model)


def dp_tp_mesh(data: int = 2, model: int = 4):
    """Data × tensor-parallel host mesh (default 2×4 over the 8 devices)."""
    return make_host_mesh(data=data, model=model)


def single_mesh():
    """The degenerate 1×1 mesh — the single-device parity oracle side."""
    return make_host_mesh()
