"""Sharded-vs-single-device parity for the fused LoRDS pipeline.

Everything here runs on the 8-way forced host-CPU mesh (`multidevice`
marker, auto-skipped otherwise): the same fused kernel bodies that serve on
TPU execute per shard under shard_map, and their results must match the
unsharded path to fp tolerance — forward, the psum'd backward, a full
data+tensor-parallel train step, and a 4-token on-device generate
(including the int8 KV cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from multidevice_compat import dp_tp_mesh, multidevice, single_mesh, tp_mesh
from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.core import QuantSpec, init_quantized_linear
from repro.kernels import dispatch
from repro.kernels.dispatch import qmatmul
from repro.launch.serve import serve_batch
from repro.launch.train import run_training

N, M = 128, 160  # N divides the 4- and 8-way model axes


def _setup(method="lords", mode="frozen", n=N, m=M, seed=0):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n, m)) * 0.02
    spec = QuantSpec(method=method, block_size=32, rank=3, mode=mode,
                     compute_dtype=jnp.float32)
    params = init_quantized_linear(key, n, m, spec, w=w, use_bias=True)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (9, m))
    return params, spec, x


# ---------------------------------------------------------------------------
# fused qmatmul: forward parity
# ---------------------------------------------------------------------------


@multidevice
@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("mesh_kind", ["tp8", "dp2tp4"])
def test_sharded_lords_forward_parity(backend, mesh_kind):
    mesh = tp_mesh() if mesh_kind == "tp8" else dp_tp_mesh()
    params, spec, x = _setup()
    y0 = qmatmul(params, x, spec, N, M, backend=backend)
    with dispatch.shard_scope(mesh):
        y1 = qmatmul(params, x, spec, N, M, backend=backend)
        y2 = jax.jit(
            lambda p, xx: qmatmul(p, xx, spec, N, M, backend=backend)
        )(params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)


@multidevice
@pytest.mark.parametrize("method", ["blockwise", "qlora"])
def test_sharded_block_forward_parity(method):
    mesh = tp_mesh()
    params, spec, x = _setup(method=method)
    y0 = qmatmul(params, x, spec, N, M, backend="interpret")
    with dispatch.shard_scope(mesh):
        y1 = qmatmul(params, x, spec, N, M, backend="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)


@multidevice
def test_sharded_decode_gemv_parity():
    """M ≤ 8 tokens hit the weight-stationary decode kernel inside each
    shard; sharded output must match the unsharded decode kernel."""
    mesh = tp_mesh()
    params, spec, _ = _setup()
    x = jax.random.normal(jax.random.PRNGKey(9), (2, M))  # decode-sized
    y0 = qmatmul(params, x, spec, N, M, backend="interpret")
    with dispatch.shard_scope(mesh):
        y1 = qmatmul(params, x, spec, N, M, backend="interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-5, atol=2e-5)


@multidevice
def test_nondividing_out_dim_falls_back_unsharded():
    """N=100 doesn't divide the 8-way model axis: the dispatcher must take
    the unsharded path (mirroring resolve_spec's drop), not crash."""
    mesh = tp_mesh()
    params, spec, x = _setup(n=100, m=96)
    y0 = qmatmul(params, x, spec, 100, 96, backend="ref")
    with dispatch.shard_scope(mesh):
        y1 = qmatmul(params, x, spec, 100, 96, backend="ref")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-6, atol=1e-6)


@multidevice
def test_shard_scope_off_inside_scope():
    """shard_scope(None) must disable sharded dispatch (the MoE bodies rely
    on this to avoid nested shard_maps)."""
    mesh = tp_mesh()
    with dispatch.shard_scope(mesh):
        assert dispatch.shard_info() is not None
        with dispatch.shard_scope(None):
            assert dispatch.shard_info() is None
        assert dispatch.shard_info() is not None
    assert dispatch.shard_info() is None


# ---------------------------------------------------------------------------
# fused qmatmul: backward parity (psum'd dx / dA)
# ---------------------------------------------------------------------------


def _grads(params, spec, x, diff_keys, backend, mesh=None):
    def loss(t, xx):
        p = dict(params, **dict(zip(diff_keys, t)))
        return jnp.sum(qmatmul(p, xx, spec, N, M, backend=backend) ** 2)

    t0 = tuple(params[k] for k in diff_keys)
    fn = jax.jit(jax.grad(loss, argnums=(0, 1)))
    if mesh is None:
        return fn(t0, x)
    with dispatch.shard_scope(mesh):
        return fn(t0, x)


@multidevice
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_sharded_peft_backward_parity(backend):
    """dB stays row-local, dA and dx cross shards: the psum'd cotangents
    must equal the single-device custom-VJP gradients."""
    mesh = dp_tp_mesh()
    params, spec, x = _setup(mode="peft")
    (g0, dx0) = _grads(params, spec, x, ("b", "a"), backend)
    (g1, dx1) = _grads(params, spec, x, ("b", "a"), backend, mesh)
    for a_, b_ in zip(g0 + (dx0,), g1 + (dx1,)):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a_),
                                   rtol=5e-4, atol=5e-5)


@multidevice
@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_sharded_qat_backward_parity(backend):
    """QAT STE: dW/dB row-local, dA/dx psum'd — Eq. 4/5 cotangents match
    the unsharded fused backward."""
    mesh = tp_mesh()
    params, spec, x = _setup(mode="qat")
    (g0, dx0) = _grads(params, spec, x, ("w", "b", "a"), backend)
    (g1, dx1) = _grads(params, spec, x, ("w", "b", "a"), backend, mesh)
    for a_, b_ in zip(g0 + (dx0,), g1 + (dx1,)):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a_),
                                   rtol=5e-4, atol=5e-5)


@multidevice
def test_sharded_blockwise_backward_parity():
    mesh = tp_mesh()
    params, spec, x = _setup(method="blockwise")
    (g0, dx0) = _grads(params, spec, x, ("s_blk",), "interpret")
    (g1, dx1) = _grads(params, spec, x, ("s_blk",), "interpret", mesh)
    for a_, b_ in zip(g0 + (dx0,), g1 + (dx1,)):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a_),
                                   rtol=5e-4, atol=5e-5)


# ---------------------------------------------------------------------------
# end to end: train step + generate under the mesh
# ---------------------------------------------------------------------------


def _smoke_cfg(arch="llama3-8b"):
    return smoke_variant(get_config(arch)).with_(num_layers=2, d_model=64)


@multidevice
def test_sharded_train_step_matches_single_device():
    """3 PEFT steps on the 2×4 mesh vs the 1×1 mesh: same losses and same
    updated factors to fp tolerance (psum reassociation only)."""
    cfg = _smoke_cfg()
    shape = ShapeCfg("t", 32, 4, "train")
    out_1 = run_training(cfg, shape, steps=3, lr=1e-3, mesh=single_mesh(),
                         log_every=1000)
    out_8 = run_training(cfg, shape, steps=3, lr=1e-3, mesh=dp_tp_mesh(),
                         log_every=1000)
    np.testing.assert_allclose(out_8["losses"], out_1["losses"],
                               rtol=1e-4, atol=1e-5)
    # the per-step loss trajectory is the sharp check (step k's loss runs on
    # step k-1's updated factors).  Params themselves only get an O(lr·steps)
    # bound: Adam normalizes by |g|, so psum-reassociation noise on a
    # near-zero-gradient coordinate can flip its sign and move that single
    # element by up to ~2·lr per step in either run.
    for a_, b_ in zip(jax.tree.leaves(out_1["trainable"]),
                      jax.tree.leaves(out_8["trainable"])):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a_),
                                   rtol=5e-3, atol=5e-3)


@multidevice
def test_sharded_qat_train_step_runs():
    """A full QAT STE step (dW/dB local, dA psum) under the mesh learns."""
    cfg = _smoke_cfg()
    cfg = cfg.with_(quant=cfg.quant.with_(mode="qat"))
    shape = ShapeCfg("t", 32, 4, "train")
    out = run_training(cfg, shape, steps=3, lr=1e-3, mesh=dp_tp_mesh(),
                       log_every=1000)
    assert np.isfinite(out["losses"]).all()


def _generate(cfg, mesh, **kw):
    params = None  # serve_batch seeds identically from `seed`
    prompts = np.random.default_rng(7).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    return serve_batch(cfg, batch=2, prompt_len=8, gen=4, mesh=mesh,
                       seed=11, prompts=prompts, **kw)


@multidevice
def test_sharded_generate_matches_single_device():
    """4-token generate through prefill + the jitted on-device scan loop:
    the sharded run must produce the same tokens as the 1×1 mesh."""
    cfg = _smoke_cfg("qwen3-8b")
    out_1 = _generate(cfg, single_mesh())
    out_8 = _generate(cfg, dp_tp_mesh())
    assert out_1["tokens"].shape == (2, 4)
    np.testing.assert_array_equal(out_8["tokens"], out_1["tokens"])


@multidevice
def test_sharded_generate_int8_kv_cache_matches_single_device():
    """The long-context serving config: int8 KV cache under the mesh —
    quantize/dequantize per shard-resident cache block, same tokens."""
    cfg = _smoke_cfg("qwen3-8b")
    out_1 = _generate(cfg, single_mesh(), kv_cache="int8")
    out_8 = _generate(cfg, dp_tp_mesh(), kv_cache="int8")
    assert out_1["kv_cache_dtype"] == "int8"
    np.testing.assert_array_equal(out_8["tokens"], out_1["tokens"])


@multidevice
def test_sharded_generate_fused_interpret_backend():
    """The fused kernel bodies themselves (interpret mode) inside the
    sharded generation loop — the code path TPU serving runs."""
    cfg = _smoke_cfg("qwen3-8b")
    out_1 = _generate(cfg, single_mesh(), kernel_backend="interpret")
    out_8 = _generate(cfg, tp_mesh(), kernel_backend="interpret")
    np.testing.assert_array_equal(out_8["tokens"], out_1["tokens"])


@multidevice
def test_plan_meta_reports_sharding():
    from repro.launch.steps import build_plan

    cfg = _smoke_cfg()
    mesh = dp_tp_mesh()
    plan = build_plan(cfg, mesh, ShapeCfg("t", 32, 4, "train"))
    sh = plan.meta["sharding"]
    assert sh["mesh"] == {"data": 2, "model": 4}
    assert sh["model_parallel"] == 4
    assert sh["lords_factors"] == "replicated"
