"""Fault-injection plumbing + training hardening: FaultPlan determinism,
the guarded AdamW update (in-graph skip on non-finite/spiking grads), and
run_training's skip-then-rollback path under the ``train.grad_spike``
injection point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.launch.train import run_training
from repro.optim import adamw_init, adamw_update, guarded_update
from repro.robustness import NO_FAULTS, FaultPlan, FaultSpec


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------


def test_fault_plan_is_deterministic_and_replayable():
    spec = {"engine.page_alloc": {"prob": 0.3},
            "engine.step": {"at": (2, 5)}}
    a = FaultPlan(7, spec)
    b = FaultPlan(7, spec)
    seq_a = [a.fires("engine.page_alloc") for _ in range(50)]
    seq_b = [b.fires("engine.page_alloc") for _ in range(50)]
    assert seq_a == seq_b and any(seq_a) and not all(seq_a)
    # at-indices fire exactly where asked
    hits = [i for i in range(8) if a.fires("engine.step")]
    assert hits == [2, 5]
    # reset rewinds to consultation 0: identical replay
    a.reset()
    assert [a.fires("engine.page_alloc") for _ in range(50)] == seq_a
    assert a.consulted("engine.page_alloc") == 50
    assert a.fired("engine.page_alloc") == sum(seq_a)


def test_fault_plan_seed_changes_pattern():
    spec = {"p": {"prob": 0.5}}
    a = FaultPlan(1, spec)
    b = FaultPlan(2, spec)
    assert [a.fires("p") for _ in range(64)] != \
        [b.fires("p") for _ in range(64)]


def test_fault_plan_max_fires_caps_total():
    plan = FaultPlan(0, {"p": {"prob": 1.0, "max_fires": 3}})
    fires = [plan.fires("p") for _ in range(10)]
    assert sum(fires) == 3 and fires[:3] == [True] * 3
    assert plan.fired("p") == 3 and plan.consulted("p") == 10


def test_fault_plan_unknown_point_never_fires():
    plan = FaultPlan(0, {"p": {"prob": 1.0}})
    assert not plan.fires("other.point")
    assert plan.summary()["fired"] == {"p": 0}


def test_no_faults_is_inert():
    assert not NO_FAULTS.enabled
    assert not NO_FAULTS.fires("anything")
    assert NO_FAULTS.summary() == {"enabled": False}
    NO_FAULTS.reset()  # no-op, must not raise


def test_fault_spec_validates_prob():
    with pytest.raises(ValueError, match="prob"):
        FaultSpec(prob=1.5)


# ---------------------------------------------------------------------------
# guarded AdamW
# ---------------------------------------------------------------------------


def _toy_state(seed=0):
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (4, 4)),
              "b": jnp.zeros((4,))}
    return params, adamw_init(params)


def test_guarded_update_clean_grads_match_adamw_bitwise():
    params, state = _toy_state()
    grads = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p), params)
    p_ref, s_ref, g_ref = adamw_update(params, grads, state, 1e-2)
    p_new, s_new, gnorm, ok = guarded_update(params, grads, state, 1e-2,
                                             jnp.float32(np.inf))
    assert bool(ok)
    assert float(gnorm) == float(g_ref)
    for a, b in zip(jax.tree.leaves(p_new), jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_new), jax.tree.leaves(s_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("poison", ["nan", "inf", "spike"])
def test_guarded_update_skips_poisoned_grads(poison):
    params, state = _toy_state()
    val = {"nan": np.nan, "inf": np.inf, "spike": 1e9}[poison]
    grads = jax.tree.map(lambda p: jnp.full(p.shape, val), params)
    thr = jnp.float32(10.0)
    p_new, s_new, gnorm, ok = guarded_update(params, grads, state, 1e-2, thr)
    assert not bool(ok)
    for a, b in zip(jax.tree.leaves(p_new), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # optimizer moments AND the step counter stay untouched — a poisoned
    # batch must not advance bias correction either
    for a, b in zip(jax.tree.leaves(s_new), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s_new.step) == 0


# ---------------------------------------------------------------------------
# run_training: skip + rollback under train.grad_spike
# ---------------------------------------------------------------------------

def _tiny():
    cfg = smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64)
    return cfg, ShapeCfg("t", 32, 4, "train")


def test_training_skips_injected_spike_step():
    """One injected detector fire: the step is skipped (no loss recorded,
    counters tell the story) and training continues to the full budget."""
    cfg, shape = _tiny()
    faults = FaultPlan(0, {"train.grad_spike": {"at": (2,)}})
    out = run_training(cfg, shape, steps=5, lr=1e-3, log_every=1000,
                       faults=faults)
    assert out["skipped_steps"] == 1 and out["rollbacks"] == 0
    assert len(out["losses"]) == 4
    assert all(np.isfinite(out["losses"]))


def test_training_rolls_back_after_consecutive_skips(tmp_path):
    """K consecutive detector fires trigger a checkpoint rollback: the run
    restores params + optimizer + data position and finishes training."""
    cfg, shape = _tiny()
    ck = str(tmp_path / "ck")
    faults = FaultPlan(0, {"train.grad_spike": {"at": (2, 3)}})
    out = run_training(cfg, shape, steps=6, lr=1e-3, log_every=1000,
                       ckpt_dir=ck, ckpt_every=1, faults=faults,
                       rollback_after=2)
    assert out["skipped_steps"] == 2
    assert out["rollbacks"] == 1
    assert len(out["losses"]) == 4
    assert all(np.isfinite(out["losses"]))


def test_grad_guard_default_matches_unguarded_run():
    """grad_guard=True must be a bitwise no-op on a clean run — same final
    trainables as the legacy unguarded step."""
    cfg, shape = _tiny()
    out_g = run_training(cfg, shape, steps=3, lr=1e-3, log_every=1000)
    out_u = run_training(cfg, shape, steps=3, lr=1e-3, log_every=1000,
                         grad_guard=False)
    la, lb = (jax.tree.leaves(out_g["trainable"]),
              jax.tree.leaves(out_u["trainable"]))
    assert la and len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
