"""Fault-injection plumbing + training hardening: FaultPlan determinism,
the guarded AdamW update (in-graph skip on non-finite/spiking grads), and
run_training's skip-then-rollback path under the ``train.grad_spike``
injection point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.launch.train import run_training
from repro.optim import adamw_init, adamw_update, guarded_update
from repro.robustness import NO_FAULTS, FaultPlan, FaultSpec


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------


def test_fault_plan_is_deterministic_and_replayable():
    spec = {"engine.page_alloc": {"prob": 0.3},
            "engine.step": {"at": (2, 5)}}
    a = FaultPlan(7, spec)
    b = FaultPlan(7, spec)
    seq_a = [a.fires("engine.page_alloc") for _ in range(50)]
    seq_b = [b.fires("engine.page_alloc") for _ in range(50)]
    assert seq_a == seq_b and any(seq_a) and not all(seq_a)
    # at-indices fire exactly where asked
    hits = [i for i in range(8) if a.fires("engine.step")]
    assert hits == [2, 5]
    # reset rewinds to consultation 0: identical replay
    a.reset()
    assert [a.fires("engine.page_alloc") for _ in range(50)] == seq_a
    assert a.consulted("engine.page_alloc") == 50
    assert a.fired("engine.page_alloc") == sum(seq_a)


def test_fault_plan_seed_changes_pattern():
    spec = {"p": {"prob": 0.5}}
    a = FaultPlan(1, spec)
    b = FaultPlan(2, spec)
    assert [a.fires("p") for _ in range(64)] != \
        [b.fires("p") for _ in range(64)]


def test_fault_plan_max_fires_caps_total():
    plan = FaultPlan(0, {"p": {"prob": 1.0, "max_fires": 3}})
    fires = [plan.fires("p") for _ in range(10)]
    assert sum(fires) == 3 and fires[:3] == [True] * 3
    assert plan.fired("p") == 3 and plan.consulted("p") == 10


def test_fault_plan_unknown_point_never_fires():
    plan = FaultPlan(0, {"p": {"prob": 1.0}})
    assert not plan.fires("other.point")
    assert plan.summary()["fired"] == {"p": 0}


def test_no_faults_is_inert():
    assert not NO_FAULTS.enabled
    assert not NO_FAULTS.fires("anything")
    assert NO_FAULTS.summary() == {"enabled": False}
    NO_FAULTS.reset()  # no-op, must not raise


def test_fault_spec_validates_prob():
    with pytest.raises(ValueError, match="prob"):
        FaultSpec(prob=1.5)


# ---------------------------------------------------------------------------
# guarded AdamW
# ---------------------------------------------------------------------------


def _toy_state(seed=0):
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (4, 4)),
              "b": jnp.zeros((4,))}
    return params, adamw_init(params)


def test_guarded_update_clean_grads_match_adamw_bitwise():
    params, state = _toy_state()
    grads = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p), params)
    p_ref, s_ref, g_ref = adamw_update(params, grads, state, 1e-2)
    p_new, s_new, gnorm, ok = guarded_update(params, grads, state, 1e-2,
                                             jnp.float32(np.inf))
    assert bool(ok)
    assert float(gnorm) == float(g_ref)
    for a, b in zip(jax.tree.leaves(p_new), jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_new), jax.tree.leaves(s_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("poison", ["nan", "inf", "spike"])
def test_guarded_update_skips_poisoned_grads(poison):
    params, state = _toy_state()
    val = {"nan": np.nan, "inf": np.inf, "spike": 1e9}[poison]
    grads = jax.tree.map(lambda p: jnp.full(p.shape, val), params)
    thr = jnp.float32(10.0)
    p_new, s_new, gnorm, ok = guarded_update(params, grads, state, 1e-2, thr)
    assert not bool(ok)
    for a, b in zip(jax.tree.leaves(p_new), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # optimizer moments AND the step counter stay untouched — a poisoned
    # batch must not advance bias correction either
    for a, b in zip(jax.tree.leaves(s_new), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s_new.step) == 0


# ---------------------------------------------------------------------------
# run_training: skip + rollback under train.grad_spike
# ---------------------------------------------------------------------------

def _tiny():
    cfg = smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64)
    return cfg, ShapeCfg("t", 32, 4, "train")


def test_training_skips_injected_spike_step():
    """One injected detector fire: the step is skipped (no loss recorded,
    counters tell the story) and training continues to the full budget."""
    cfg, shape = _tiny()
    faults = FaultPlan(0, {"train.grad_spike": {"at": (2,)}})
    out = run_training(cfg, shape, steps=5, lr=1e-3, log_every=1000,
                       faults=faults)
    assert out["skipped_steps"] == 1 and out["rollbacks"] == 0
    assert len(out["losses"]) == 4
    assert all(np.isfinite(out["losses"]))


def test_training_rolls_back_after_consecutive_skips(tmp_path):
    """K consecutive detector fires trigger a checkpoint rollback: the run
    restores params + optimizer + data position and finishes training."""
    cfg, shape = _tiny()
    ck = str(tmp_path / "ck")
    faults = FaultPlan(0, {"train.grad_spike": {"at": (2, 3)}})
    out = run_training(cfg, shape, steps=6, lr=1e-3, log_every=1000,
                       ckpt_dir=ck, ckpt_every=1, faults=faults,
                       rollback_after=2)
    assert out["skipped_steps"] == 2
    assert out["rollbacks"] == 1
    assert len(out["losses"]) == 4
    assert all(np.isfinite(out["losses"]))


def test_grad_guard_default_matches_unguarded_run():
    """grad_guard=True must be a bitwise no-op on a clean run — same final
    trainables as the legacy unguarded step."""
    cfg, shape = _tiny()
    out_g = run_training(cfg, shape, steps=3, lr=1e-3, log_every=1000)
    out_u = run_training(cfg, shape, steps=3, lr=1e-3, log_every=1000,
                         grad_guard=False)
    la, lb = (jax.tree.leaves(out_g["trainable"]),
              jax.tree.leaves(out_u["trainable"]))
    assert la and len(la) == len(lb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# mesh-aware dist.* points: indexed RNG streams
# ---------------------------------------------------------------------------


def test_indexed_streams_independent_of_sibling_interleaving():
    """Shard i's fire schedule depends only on (seed, point, i) — never on
    how many siblings are consulted or in what order.  This is the
    cross-process-count determinism contract: an 8-process run and a
    2-process run must inject the same faults into shard 0."""
    spec = {"dist.straggler": {"prob": 0.4}}
    solo = FaultPlan(3, spec)
    seq_solo = [solo.fires("dist.straggler", index=0) for _ in range(32)]

    interleaved = FaultPlan(3, spec)
    seq_inter = []
    for _ in range(32):
        seq_inter.append(interleaved.fires("dist.straggler", index=0))
        for sib in (1, 2, 5, 7):       # siblings consult in between
            interleaved.fires("dist.straggler", index=sib)
    assert seq_inter == seq_solo
    # and the un-indexed legacy stream is yet another independent stream
    legacy = FaultPlan(3, spec)
    assert [legacy.fires("dist.straggler") for _ in range(32)] != seq_solo
    assert legacy.consulted("dist.straggler") == 32


def test_indexed_max_fires_is_per_stream():
    plan = FaultPlan(0, {"dist.device_loss": {"prob": 1.0, "max_fires": 2}})
    for i in (0, 1):
        fires = [plan.fires("dist.device_loss", index=i) for _ in range(5)]
        assert sum(fires) == 2, f"stream {i} not independently capped"
    assert plan.fired("dist.device_loss") == 4  # aggregated across streams


def test_only_index_restricts_firing_to_one_shard():
    plan = FaultPlan(0, {"dist.host_crash": {"prob": 1.0, "only_index": 2}})
    assert not plan.fires("dist.host_crash", index=0)
    assert not plan.fires("dist.host_crash", index=1)
    assert plan.fires("dist.host_crash", index=2)
    assert plan.consulted("dist.host_crash") == 3
    assert plan.fired("dist.host_crash") == 1


def test_indexed_summary_labels_streams():
    plan = FaultPlan(0, {"dist.straggler": {"prob": 1.0}})
    plan.fires("dist.straggler", index=3)
    plan.fires("dist.straggler")
    s = plan.summary()
    assert s["fired"]["dist.straggler[3]"] == 1
    assert s["fired"]["dist.straggler"] == 1


def test_dist_points_zero_cost_when_disabled():
    assert not NO_FAULTS.fires("dist.device_loss", index=5)
    assert not NO_FAULTS.enabled


# ---------------------------------------------------------------------------
# run_training: dist.* elastic recovery (single-device-runnable paths)
# ---------------------------------------------------------------------------


def test_training_collective_timeout_retries_then_completes():
    cfg, shape = _tiny()
    out = run_training(cfg, shape, steps=4, lr=1e-3, log_every=1000,
                       faults=FaultPlan(0, {"dist.collective_timeout":
                                            {"at": (1,)}}))
    assert out["collective_timeouts"] == 1
    assert out["status"] == "complete"
    assert len(out["losses"]) == 4


def test_training_collective_timeout_exhausts_retries():
    from repro.robustness import InjectedFault
    cfg, shape = _tiny()
    with pytest.raises(InjectedFault, match="collective"):
        run_training(cfg, shape, steps=4, lr=1e-3, log_every=1000,
                     collective_retries=1,
                     faults=FaultPlan(0, {"dist.collective_timeout":
                                          {"prob": 1.0}}))


def test_training_host_crash_then_resume(tmp_path):
    from repro.robustness import InjectedFault
    cfg, shape = _tiny()
    ck = str(tmp_path / "ck")
    with pytest.raises(InjectedFault, match="host crash"):
        run_training(cfg, shape, steps=6, lr=1e-3, log_every=1000,
                     ckpt_dir=ck, ckpt_every=2,
                     faults=FaultPlan(0, {"dist.host_crash": {"at": (3,)}}))
    ref = run_training(cfg, shape, steps=6, lr=1e-3, log_every=1000)
    # the crash landed past the step-2 checkpoint; resuming trains 4 more
    # steps (run_training counts steps beyond the restored position) and
    # must land on the uninterrupted 6-step trajectory
    out = run_training(cfg, shape, steps=4, lr=1e-3, log_every=1000,
                       ckpt_dir=ck, ckpt_every=100)
    assert out["status"] == "complete"
    for a, b in zip(jax.tree.leaves(ref["trainable"]),
                    jax.tree.leaves(out["trainable"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
