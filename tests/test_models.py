"""Model-zoo semantics: causality, train/decode consistency, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import QuantSpec
from repro.models import cache_init, forward_decode, forward_prefill, \
    forward_train, model_init, split_tree
from repro.models import moe as moe_mod
from repro.models.attention import chunked_causal_attention


def test_chunked_attention_is_causal(key):
    b, s, nh, nkv, hd = 2, 32, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd))
    k = jax.random.normal(ks[1], (b, s, nkv, hd))
    v = jax.random.normal(ks[2], (b, s, nkv, hd))
    out = chunked_causal_attention(q, k, v, chunk=8)
    # perturb the future: outputs at positions < t must not change
    k2 = k.at[:, 20:].set(9.9)
    v2 = v.at[:, 20:].set(-9.9)
    out2 = chunked_causal_attention(q, k2, v2, chunk=8)
    np.testing.assert_allclose(np.asarray(out[:, :20]),
                               np.asarray(out2[:, :20]), rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out[:, 21:]), np.asarray(out2[:, 21:]))


def test_chunked_attention_matches_dense_reference(key):
    b, s, nh, hd = 2, 24, 4, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd))
    k = jax.random.normal(ks[1], (b, s, nh, hd))
    v = jax.random.normal(ks[2], (b, s, nh, hd))
    out = chunked_causal_attention(q, k, v, chunk=8)
    # dense reference
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = np.tril(np.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, -1)
    want = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["llama3-8b", "minicpm3-4b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b"])
def test_prefill_decode_consistency(arch, key):
    """Greedy continuation via prefill+decode must equal a train-style
    forward over the concatenated sequence (same logits at the last pos)."""
    cfg = smoke_variant(get_config(arch)).with_(remat=False)
    params, _ = split_tree(model_init(key, cfg))
    b, s, cap = 2, 16, 24
    if cfg.input_kind == "tokens":
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        batch = {"tokens": toks}
    else:
        batch = {"embeds": jax.random.normal(key, (b, s, cfg.d_model))}
    cache, _ = split_tree(cache_init(cfg, b, cap))

    logits_pre, cache = forward_prefill(params, cfg, batch, cache)

    # reference: full forward over the same tokens, take last-position logits
    ref_cache, _ = split_tree(cache_init(cfg, b, s))
    logits_ref, _ = forward_prefill(params, cfg, batch, ref_cache)
    np.testing.assert_allclose(np.asarray(logits_pre, np.float32),
                               np.asarray(logits_ref, np.float32),
                               rtol=2e-3, atol=2e-3)

    # a decode step after prefill must be finite with correct shape
    pos = jnp.full((b,), s, jnp.int32)
    if cfg.input_kind == "tokens":
        nxt = jnp.argmax(logits_pre[:, -1, : cfg.vocab_size], -1).astype(jnp.int32)
        step = {"tokens": nxt}
    else:
        step = {"embeds": jax.random.normal(key, (b, 1, cfg.d_model))}
    logits_dec, cache2 = forward_decode(params, cfg, step, cache, pos)
    assert logits_dec.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits_dec, np.float32)).all()


def test_gqa_decode_matches_prefill_logits(key):
    """Stronger consistency: decode at position t reproduces the train-path
    logits for the same prefix (dense attention arch, no recurrent state)."""
    cfg = smoke_variant(get_config("llama3-8b")).with_(remat=False)
    params, _ = split_tree(model_init(key, cfg))
    b, s = 2, 12
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    cache, _ = split_tree(cache_init(cfg, b, s + 1))
    # prefill on s tokens, then decode token s
    logits_pre, cache = forward_prefill(
        params, cfg, {"tokens": toks[:, :s]}, cache)
    logits_dec, _ = forward_decode(
        params, cfg, {"tokens": toks[:, s]},
        cache, jnp.full((b,), s, jnp.int32))
    # reference: prefill on s+1 tokens — its last logits == decode logits
    cache2, _ = split_tree(cache_init(cfg, b, s + 1))
    logits_full, _ = forward_prefill(params, cfg, {"tokens": toks}, cache2)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_dispatch_matches_dense_reference(key):
    """With ample capacity, scatter-dispatch MoE == dense-einsum reference."""
    cfg = smoke_variant(get_config("phi3.5-moe-42b-a6.6b"))
    cfg = cfg.with_(moe=cfg.moe.__class__(
        num_experts=4, top_k=2, d_ff=32, capacity_factor=4.0))
    quant = QuantSpec(method="none", mode="frozen",
                      compute_dtype=jnp.float32)
    params_p = moe_mod.moe_init(key, cfg, quant)
    params, _ = split_tree(params_p)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32) * 0.3
    y, aux = moe_mod.moe_apply(params, x, cfg, quant)
    assert np.isfinite(np.asarray(y)).all() and float(aux) > 0

    # dense reference: route every token through its top-k experts exactly
    t = 16
    xf = x.reshape(t, cfg.d_model)
    logits = xf @ params["router"].T
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    wg, wu, wd = (params["w_gate"]["w"], params["w_up"]["w"],
                  params["w_down"]["w"])
    y_ref = np.zeros((t, cfg.d_model), np.float32)
    for ti in range(t):
        for j in range(2):
            e = int(idx[ti, j])
            h = jax.nn.silu(wg[e] @ xf[ti]) * (wu[e] @ xf[ti])
            y_ref[ti] += float(gates[ti, j]) * np.asarray(wd[e] @ h)
    np.testing.assert_allclose(np.asarray(y.reshape(t, -1)), y_ref,
                               rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_train_scan(key):
    """Step-by-step mamba decode must reproduce the chunked-scan training
    output (same recurrence, different evaluation order)."""
    from repro.models import ssm

    cfg = smoke_variant(get_config("jamba-1.5-large-398b"))
    quant = QuantSpec(method="none", mode="frozen",
                      compute_dtype=jnp.float32)
    params, _ = split_tree(ssm.mamba_init(key, cfg, quant))
    b, s = 2, 12
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.3
    y_train = ssm.mamba_train(params, x, cfg, quant)
    cache, _ = split_tree(ssm.mamba_cache_init(cfg, b))
    outs = []
    for t in range(s):
        y_t, cache = ssm.mamba_decode(params, x[:, t : t + 1], cfg, quant,
                                      cache)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_decode_matches_parallel_form(key):
    from repro.models import ssm

    cfg = smoke_variant(get_config("xlstm-1.3b"))
    quant = QuantSpec(method="none", mode="frozen",
                      compute_dtype=jnp.float32)
    params, _ = split_tree(ssm.mlstm_init(key, cfg, quant))
    b, s = 2, 10
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32) * 0.3
    y_train = ssm.mlstm_train(params, x, cfg, quant, chunk=4)
    cache, _ = split_tree(ssm.mlstm_cache_init(cfg, b))
    outs = []
    for t in range(s):
        y_t, cache = ssm.mlstm_decode(params, x[:, t : t + 1], cfg, quant,
                                      cache)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               rtol=5e-3, atol=5e-3)
