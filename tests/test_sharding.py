"""Sharding-rule resolution over the full arch zoo — pure spec level.

No multi-device runtime needed: ``make_abstract_mesh`` mirrors the 16×16 and
2×16×16 production meshes as AbstractMeshes, and ``resolve_spec`` /
``tree_pspecs`` only consult ``mesh.shape``.  Property cases run through the
optional-hypothesis shim (they skip cleanly on minimal containers)."""
import jax
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim
from jax.sharding import PartitionSpec

from repro.configs import get_config, list_configs
from repro.distributed.sharding import (
    estimate_quantized_gb,
    make_rules,
    resolve_spec,
    tree_pspecs,
)
from repro.launch.mesh import make_abstract_mesh
from repro.models import model_init, split_tree

MESHES = {
    "16x16": make_abstract_mesh(),
    "2x16x16": make_abstract_mesh(multi_pod=True),
}

ALL_ARCHS = sorted(list_configs())

# eval_shape of the *full-size* archs is pure tracing but not free (~10s for
# the biggest); cache one (values, axes) pair per arch across all mesh cases
_TREES: dict = {}


def _arch_tree(arch):
    if arch not in _TREES:
        cfg = get_config(arch)
        tree = jax.eval_shape(lambda k: model_init(k, cfg),
                              jax.random.PRNGKey(0))
        _TREES[arch] = (cfg,) + split_tree(tree)
    return _TREES[arch]


def _spec_leaves(specs):
    return jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, PartitionSpec))


def _check_spec(spec, shape, mesh):
    """A PartitionSpec is valid for (shape, mesh) iff every named axis
    exists, no mesh axis is used twice, and the sharded dims divide."""
    used = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for ax in axes:
            assert ax in mesh.shape, f"unknown mesh axis {ax!r} in {spec}"
            assert ax not in used, f"mesh axis {ax!r} reused in {spec}"
            used.append(ax)
            size *= mesh.shape[ax]
        assert dim % size == 0, f"{spec} does not divide shape {shape}"


# ---------------------------------------------------------------------------
# full arch zoo × production meshes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_weight_specs_valid(arch, mesh_name):
    """tree_shardings over every arch yields specs the production meshes
    accept: real axes, no reuse, divisibility (or a recorded drop)."""
    mesh = MESHES[mesh_name]
    cfg, values, axes = _arch_tree(arch)
    rules = make_rules(cfg, mesh, "train")
    dropped: list = []
    specs = tree_pspecs(axes, values, rules.weight_rules, mesh, dropped)
    spec_leaves = _spec_leaves(specs)
    value_leaves = jax.tree.leaves(values)
    assert len(spec_leaves) == len(value_leaves)
    for spec, val in zip(spec_leaves, value_leaves):
        _check_spec(spec, val.shape, mesh)
    # every drop is a genuine non-divisibility, not a resolver bug
    for name, dim, axes_used in dropped:
        size = 1
        for ax in axes_used:
            size *= mesh.shape[ax]
        assert dim % size != 0 or size == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_lords_factors_replicate_codes_shard(arch):
    """The paper's asymmetry on the mesh: rank-r dims never shard (B/A ride
    replicated next to their codes) and at least one packed-codes dim
    actually lands on 'model' for every arch at TP=16."""
    mesh = MESHES["16x16"]
    cfg, values, axes = _arch_tree(arch)
    rules = make_rules(cfg, mesh, "train")
    specs = tree_pspecs(axes, values, rules.weight_rules, mesh)
    rank_entries, model_hits = [], 0
    for ax_tuple, spec in zip(
            jax.tree.leaves(axes,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(i, (str, type(None))) for i in x)),
            _spec_leaves(specs)):
        padded = tuple(spec) + (None,) * len(ax_tuple)
        for name, entry in zip(ax_tuple, padded):
            if name == "lords_rank":
                rank_entries.append(entry)
            flat = (entry,) if isinstance(entry, str) else tuple(entry or ())
            if "model" in flat:
                model_hits += 1
    assert rank_entries, f"{arch}: no LoRDS factors in the tree"
    assert all(e is None for e in rank_entries), \
        f"{arch}: rank dim sharded: {rank_entries}"
    assert model_hits > 0, f"{arch}: nothing sharded over 'model' at TP=16"


def test_2d_policy_activates_for_giant_models():
    """llama3-405b exceeds the per-device budget under 1-D TP at 16-way, so
    make_rules flips to the 2-D layout (weights' other dim on 'data')."""
    mesh = MESHES["16x16"]
    cfg = get_config("llama3-405b")
    assert estimate_quantized_gb(cfg) / 16 > 8.0
    rules = make_rules(cfg, mesh, "train", budget_gb=8.0)
    assert rules.weight_rules["embed"] == "data"
    small = get_config("llama3-8b")
    rules_small = make_rules(small, mesh, "train", budget_gb=8.0)
    assert rules_small.weight_rules["embed"] is None


def test_long_context_decode_policy_shards_cache_seq():
    """batch < DP pulls the idle data axes onto the KV cache sequence dim."""
    mesh = MESHES["2x16x16"]
    cfg = get_config("llama3-8b")
    rules = make_rules(cfg, mesh, "decode", seq_shard_cache=True)
    assert rules.act_rules["cache_seq"] == ("pod", "data", "model")
    assert rules.act_rules["batch"] is None
    rules_n = make_rules(cfg, mesh, "decode", seq_shard_cache=False)
    assert rules_n.act_rules["cache_seq"] == "model"


def test_policy_summary_reports_layout():
    mesh = MESHES["16x16"]
    cfg = get_config("llama3-8b")
    s = make_rules(cfg, mesh, "train").summary()
    assert s["lords_factors"] == "replicated"
    assert "model" in s["weight_axes"]


# ---------------------------------------------------------------------------
# resolver properties (hypothesis; skipped when not installed)
# ---------------------------------------------------------------------------

_PROP_MESH = make_abstract_mesh(multi_pod=True)  # pod=2, data=16, model=16
_RULE_CHOICES = (None, "model", "data", "pod", ("data", "model"),
                 ("pod", "data"), ("pod", "data", "model"), "bogus")


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_resolve_spec_never_reuses_axis_property(seed):
    rng = np.random.default_rng(seed)
    ndim = int(rng.integers(1, 5))
    names = [f"d{i}" for i in range(ndim)]
    shape = tuple(int(rng.integers(1, 64)) * int(rng.choice((1, 2, 16)))
                  for _ in range(ndim))
    rules = {n: _RULE_CHOICES[int(rng.integers(len(_RULE_CHOICES)))]
             for n in names}
    dropped: list = []
    spec = resolve_spec(tuple(names), shape, rules, _PROP_MESH, dropped)
    used = []
    for entry in spec:
        for ax in ((entry,) if isinstance(entry, str) else tuple(entry or ())):
            assert ax in _PROP_MESH.shape
            assert ax not in used, f"axis {ax} reused in {spec}"
            used.append(ax)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_resolve_spec_drops_are_recorded_property(seed):
    """Every dim whose rule named live mesh axes but didn't divide must end
    as None in the spec AND appear in policy.dropped — never silently."""
    rng = np.random.default_rng(seed)
    ndim = int(rng.integers(1, 5))
    names = [f"d{i}" for i in range(ndim)]
    shape = tuple(int(rng.integers(1, 512)) for _ in range(ndim))
    rules = {n: _RULE_CHOICES[int(rng.integers(len(_RULE_CHOICES)))]
             for n in names}
    dropped: list = []
    spec = resolve_spec(tuple(names), shape, rules, _PROP_MESH, dropped)
    recorded = {name for name, _, _ in dropped}
    used: set = set()
    for name, dim, entry in zip(names, shape, spec):
        rule = rules.get(name)
        if rule is None or rule == "bogus":
            assert entry is None
            continue
        axes = (rule,) if isinstance(rule, str) else tuple(rule)
        live = [ax for ax in axes if ax in _PROP_MESH.shape
                and ax not in used]
        size = int(np.prod([_PROP_MESH.shape[ax] for ax in live])) \
            if live else 1
        if live and size > 1 and dim % size == 0:
            assert entry is not None
            used.update(live)
        else:
            assert entry is None
            if live:
                assert name in recorded, \
                    f"drop of {name} (dim {dim}) not recorded"


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_resolve_spec_sharded_dims_always_divide_property(seed):
    rng = np.random.default_rng(seed)
    ndim = int(rng.integers(1, 4))
    names = [f"d{i}" for i in range(ndim)]
    shape = tuple(int(rng.integers(1, 2048)) for _ in range(ndim))
    rules = {n: _RULE_CHOICES[int(rng.integers(len(_RULE_CHOICES)))]
             for n in names}
    spec = resolve_spec(tuple(names), shape, rules, _PROP_MESH)
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = int(np.prod([_PROP_MESH.shape[ax] for ax in axes]))
        assert size > 1 and dim % size == 0
