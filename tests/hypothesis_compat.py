"""Optional-hypothesis shim for the property-based test cases.

``from hypothesis_compat import given, settings, st`` gives the real
hypothesis decorators when the package is installed; otherwise stand-ins
that turn each ``@given`` case into a single skipped test (with a clear
reason) so deterministic cases in the same module still collect and run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal containers
    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Strategy stubs: only evaluated at decoration time, never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            @pytest.mark.skip(
                reason="hypothesis not installed: property-based case skipped"
            )
            def skipped():
                pass

            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped

        return deco
