"""Sensitivity-driven bit/rank allocator: budget and monotonicity contracts,
plus the mixed-precision override plumbing into ptq_stream."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import allocate, quantize
from repro.ptq_stream import ResidualMLPSource, StreamPlan, stream_quantize
from repro.ptq_stream.shards import read_shard, shard_name

BLOCK = 16
RANKS = (2, 4)
CODEBOOKS = ("nf2", "nf3", "nf4")


def _weights(seed=0):
    key = jax.random.PRNGKey(seed)
    out = {}
    for i, (n, k) in enumerate([(64, 48), (48, 64), (32, 32)]):
        out[f"m{i}"] = np.asarray(
            jax.random.normal(jax.random.fold_in(key, i), (n, k))) * 0.05
    return out


def _alloc(budget, **kw):
    return allocate.allocate(_weights(), budget, codebooks=CODEBOOKS,
                             ranks=RANKS, block_size=BLOCK, **kw)


def _min_bytes():
    return sum(min(c.bytes for c in allocate.layer_candidates(
        w, codebooks=CODEBOOKS, ranks=RANKS, block_size=BLOCK))
        for w in _weights().values())


def test_budget_respected_and_spent():
    lo = _min_bytes()
    for budget in (lo, int(lo * 1.3), int(lo * 2.5)):
        plan = _alloc(budget)
        assert plan.total_bytes <= budget
        assert plan.total_bytes == sum(
            allocate.layer_bytes(l.n, l.k, l.codebook, l.rank)
            for l in plan.layers)


def test_infeasible_budget_raises():
    with pytest.raises(ValueError, match="infeasible"):
        _alloc(_min_bytes() - 1)


def test_error_monotone_in_budget():
    """More budget can never hurt: total error is non-increasing (the
    greedy stops at the first non-fitting upgrade, so a larger budget's
    upgrade sequence strictly extends a smaller one's)."""
    lo = _min_bytes()
    budgets = [int(lo * f) for f in (1.0, 1.2, 1.5, 2.0, 3.0)]
    errors = [_alloc(b).total_error for b in budgets]
    for smaller, larger in zip(errors, errors[1:]):
        assert larger <= smaller + 1e-9


def test_generous_budget_maxes_out_and_prefers_more_bits():
    plan = _alloc(10**9)
    assert all(l.codebook == "nf4" for l in plan.layers)
    assert 2.0 <= _alloc(_min_bytes()).avg_bits() \
        <= plan.avg_bits() <= 4.0


def test_specs_emit_per_layer_quantspecs():
    from repro.core import QuantSpec

    plan = _alloc(int(_min_bytes() * 1.5))
    specs = plan.specs(QuantSpec(method="lords", block_size=BLOCK))
    assert set(specs) == set(_weights())
    for layer in plan.layers:
        assert specs[layer.name].codebook == layer.codebook
        assert specs[layer.name].rank == layer.rank


def test_col_weight_shifts_sensitivity():
    """Upweighting a layer's calibration activations must not *lower* its
    measured error (the proxy is linear in col_weight)."""
    w = _weights()["m0"]
    base = allocate.sensitivity_error(w, "nf2", 2, block_size=BLOCK)
    hot = allocate.sensitivity_error(
        w, "nf2", 2, col_weight=np.full(w.shape[1], 4.0), block_size=BLOCK)
    assert hot == pytest.approx(4.0 * base, rel=1e-5)


# ---------------------------------------------------------------------------
# override plumbing into ptq_stream
# ---------------------------------------------------------------------------


def test_stream_plan_override_lookup_and_fingerprint():
    plan = StreamPlan(block_size=BLOCK, rank=3, refine_steps=6)
    fp_uniform = plan.fingerprint()

    layers = (
        allocate.LayerAlloc("up", 64, 48, "nf3", 2,
                            allocate.layer_bytes(64, 48, "nf3", 2), 0.0),
        allocate.LayerAlloc("down", 48, 64, "nf2", 4,
                            allocate.layer_bytes(48, 64, "nf2", 4), 0.0),
    )
    mixed = plan.with_allocation(dataclasses.replace(
        allocate.AllocPlan(layers=layers, budget=0, total_bytes=0,
                           total_error=0.0)))
    assert mixed.codebook_for("up") == "nf3"
    assert mixed.rank_for("down") == 4
    # unknown matrices fall back to the uniform plan defaults
    assert mixed.codebook_for("other") == plan.codebook
    assert mixed.rank_for("other") == plan.rank
    # uniform plans keep their historical fingerprint (resume compat);
    # mixed-precision plans must never alias them
    assert plan.fingerprint() == fp_uniform
    assert mixed.fingerprint() != fp_uniform


def test_stream_quantize_honors_mixed_precision_overrides(tmp_path):
    src = ResidualMLPSource.create(
        str(tmp_path / "model"), num_blocks=1, d=48, d_ff=64,
        tokens=16, seed=0)
    plan = StreamPlan(block_size=BLOCK, rank=3, refine_steps=4,
                      overrides=(("up", "nf3", 2), ("down", "nf2", None)))
    stream_quantize(src, str(tmp_path / "out"), plan)
    shard = read_shard(str(tmp_path / "out" / shard_name(0)))
    # up: (64, 48) at nf3 -> 48 codes/row pack into 18 bytes (8c/3B)
    assert shard["up/q"].shape == (64, quantize.pack_spec("nf3")
                                   .packed_width(48))
    assert shard["up/b"].shape[1] == 2  # overridden rank
    # down: (48, 64) at nf2 -> 16 bytes/row, rank falls back to plan's 3
    assert shard["down/q"].shape == (48, quantize.pack_spec("nf2")
                                     .packed_width(64))
    assert shard["down/b"].shape[1] == 3


# ---------------------------------------------------------------------------
# allocation driven by the streamed calibration ledger (PR 10 satellite)
# ---------------------------------------------------------------------------


def _streamed_artifact(tmp_path):
    src = ResidualMLPSource.create(
        str(tmp_path / "model"), num_blocks=2, d=48, d_ff=64,
        tokens=16, seed=0)
    out = str(tmp_path / "out")
    stream_quantize(src, out, StreamPlan(block_size=BLOCK, rank=3,
                                         refine_steps=4))
    return out


def test_allocate_from_artifact_matches_explicit_col_weights(tmp_path):
    """allocate_from_artifact == allocate(col_weights=moments): the E[x^2]
    ledger of a streamed run drives sensitivity, with suffix-matched names
    and a shape gate (a layer whose fan-in disagrees with the stored
    moment falls back to plain weight-MSE)."""
    from repro.ptq_stream import allocate_from_artifact, calibration_moments

    out = _streamed_artifact(tmp_path)
    moments = calibration_moments(out)
    assert {"up", "down"} <= set(moments)
    assert moments["up"].shape == (48,) and moments["down"].shape == (64,)
    assert float(np.ptp(moments["up"])) > 0      # real data, not a constant

    key = jax.random.PRNGKey(1)
    weights = {
        "blk0/up": np.asarray(jax.random.normal(
            jax.random.fold_in(key, 0), (64, 48))) * 0.05,   # suffix match
        "blk0/down": np.asarray(jax.random.normal(
            jax.random.fold_in(key, 1), (48, 64))) * 0.05,
        "extra/up": np.asarray(jax.random.normal(
            jax.random.fold_in(key, 2), (32, 32))) * 0.05,   # fan-in 32 != 48
        "head": np.asarray(jax.random.normal(
            jax.random.fold_in(key, 3), (32, 32))) * 0.05,   # no moment
    }
    budget = sum(min(c.bytes for c in allocate.layer_candidates(
        w, codebooks=CODEBOOKS, ranks=RANKS, block_size=BLOCK))
        for w in weights.values())
    budget = int(budget * 1.5)

    got = allocate_from_artifact(weights, budget, out, codebooks=CODEBOOKS,
                                 ranks=RANKS, block_size=BLOCK)
    want = allocate.allocate(
        weights, budget,
        col_weights={"blk0/up": moments["up"], "blk0/down": moments["down"]},
        codebooks=CODEBOOKS, ranks=RANKS, block_size=BLOCK)
    assert [(l.name, l.codebook, l.rank) for l in got.layers] == \
        [(l.name, l.codebook, l.rank) for l in want.layers]
    assert got.total_error == want.total_error
    assert got.total_bytes == want.total_bytes


def test_allocate_from_artifact_without_moments_is_plain_allocate(tmp_path):
    """The documented fallback parity: an artifact with no ledger (or no
    moments) must reproduce allocate(...) exactly, bit for bit."""
    from repro.ptq_stream import allocate_from_artifact

    budget = int(_min_bytes() * 1.5)
    plain = _alloc(budget)
    got = allocate_from_artifact(_weights(), budget, str(tmp_path / "empty"),
                                 codebooks=CODEBOOKS, ranks=RANKS,
                                 block_size=BLOCK)
    assert [(l.name, l.codebook, l.rank, l.error) for l in got.layers] == \
        [(l.name, l.codebook, l.rank, l.error) for l in plain.layers]
    assert got.total_error == plain.total_error
