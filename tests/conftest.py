import os

# Multi-device opt-in (the `multidevice` marker's substrate): when the
# session is launched with REPRO_MULTIDEVICE=1 — a dedicated pytest session /
# CI job, never the default tier-1 run — force 8 host CPU devices.  This MUST
# happen before the first jax import anywhere in the process (jax locks the
# device count at backend init), which is why it lives at conftest top level.
if os.environ.get("REPRO_MULTIDEVICE") == "1":
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

# Tests run on the host CPU device(s) (the 512-device override lives ONLY
# in repro.launch.dryrun, which tests exercise via subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_CPU_EXEC", "1")  # executable bf16 dots on XLA:CPU

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_collection_modifyitems(config, items):
    """Auto-skip `multidevice` tests when the mesh isn't there: they need
    the 8-way forced host platform (make test-multidevice), not tier-1's
    single visible CPU device."""
    if not any("multidevice" in item.keywords for item in items):
        return
    if jax.device_count() >= 8:
        return
    skip = pytest.mark.skip(
        reason="needs >= 8 devices: run via REPRO_MULTIDEVICE=1 "
               "(make test-multidevice) so conftest can force them "
               "before jax initializes")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
