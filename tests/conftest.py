import os

# Tests run on the single host CPU device (the 512-device override lives ONLY
# in repro.launch.dryrun, which tests exercise via subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_CPU_EXEC", "1")  # executable bf16 dots on XLA:CPU

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
