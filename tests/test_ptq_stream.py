"""Crash-safe layer-streaming PTQ — the resume contract, asserted.

The invariant under test everywhere: whatever happens mid-run (kill at a
block boundary, kill inside a shard write, kill between shard and ledger
commit, bitrot on a published shard, an OOM spike, a preemption), a
``resume=True`` re-run finishes with an artifact **bit-identical** to an
uninterrupted run, reusing every block it can prove valid and recomputing
exactly the ones it can't.
"""
import json
import os

import numpy as np
import pytest

from repro.ptq_stream import (
    Ledger,
    MemoryBudget,
    MemoryBudgetExceeded,
    ResidualMLPSource,
    StreamPlan,
    audit_artifact,
    quantize_dense_blocks,
    read_shard,
    stream_quantize,
)
from repro.ptq_stream.shards import digest_array, shard_name, write_shard
from repro.robustness import NO_FAULTS, FaultPlan, InjectedFault

N_BLOCKS = 3


@pytest.fixture(scope="module")
def source(tmp_path_factory):
    return ResidualMLPSource.create(
        str(tmp_path_factory.mktemp("model")),
        num_blocks=N_BLOCKS, d=48, d_ff=64, tokens=16, seed=0)


@pytest.fixture(scope="module")
def plan():
    return StreamPlan(block_size=16, rank=3, refine_steps=6)


@pytest.fixture(scope="module")
def reference(source, plan, tmp_path_factory):
    """One clean streamed run: (out_dir, summary, per-block shard trees)."""
    out = str(tmp_path_factory.mktemp("ref"))
    summary = stream_quantize(source, out, plan)
    shards = [read_shard(os.path.join(out, shard_name(i)))
              for i in range(N_BLOCKS)]
    return out, summary, shards


def _assert_identical(ref_shards, out_dir):
    for i, want in enumerate(ref_shards):
        got = read_shard(os.path.join(out_dir, shard_name(i)))
        assert sorted(got) == sorted(want), f"block {i}: key set differs"
        for k in want:
            np.testing.assert_array_equal(got[k], want[k],
                                          err_msg=f"block {i} key {k}")


# ---------------------------------------------------------------------------
# clean path
# ---------------------------------------------------------------------------


def test_clean_run_completes_with_clean_audit(source, plan, reference):
    out, summary, _ = reference
    assert summary["status"] == "complete"
    assert summary["blocks_done"] == N_BLOCKS
    aud = audit_artifact(out, source, plan)
    assert aud["clean"], aud
    assert all(b["ok"] for b in aud["blocks"])


def test_streamed_equals_in_memory_bit_identical(source, plan, reference):
    """The tentpole claim: streaming one block at a time produces the same
    packed codes, factors and propagated activations as holding the whole
    dense model in memory."""
    _, summary, shards = reference
    ref, x_digest = quantize_dense_blocks(source, plan)
    for i in range(N_BLOCKS):
        assert sorted(shards[i]) == sorted(ref[i])
        for k in ref[i]:
            np.testing.assert_array_equal(shards[i][k], ref[i][k],
                                          err_msg=f"block {i} key {k}")
    assert summary["x_final_digest"] == x_digest


def test_ledger_chains_activation_digests(reference):
    out, _, _ = reference
    led = Ledger(out)
    assert led.load() and led.status == "complete"
    ents = led.entries
    assert len(ents) == N_BLOCKS
    for prev, cur in zip(ents, ents[1:]):
        assert cur["x_in"] == prev["x_out"]


def test_resume_of_complete_run_reuses_everything(source, plan, reference):
    out, _, shards = reference
    s = stream_quantize(source, out, plan, resume=True)
    assert s["status"] == "complete"
    assert s["reused"] == N_BLOCKS and s["recomputed"] == []
    _assert_identical(shards, out)


# ---------------------------------------------------------------------------
# kill + resume parity at every block boundary
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("boundary", range(N_BLOCKS))
def test_kill_at_every_boundary_resumes_bit_identical(
        source, plan, reference, tmp_path, boundary):
    _, _, shards = reference
    out = str(tmp_path / "run")
    faults = FaultPlan(boundary, {"ptq.kill_at_block": {"at": (boundary,)}})
    with pytest.raises(InjectedFault):
        stream_quantize(source, out, plan, faults=faults)
    s = stream_quantize(source, out, plan, resume=True)
    assert s["status"] == "complete"
    assert s["reused"] == boundary, "pre-kill blocks must be reused"
    assert s["recomputed"] == list(range(boundary, N_BLOCKS))
    _assert_identical(shards, out)
    assert audit_artifact(out, source, plan)["clean"]


def test_kill_mid_shard_write_leaves_no_stray_state(
        source, plan, reference, tmp_path):
    _, _, shards = reference
    out = str(tmp_path / "run")
    faults = FaultPlan(0, {"ptq.kill_mid_write": {"at": (1,)}})
    with pytest.raises(InjectedFault):
        stream_quantize(source, out, plan, faults=faults)
    assert any(".tmp" in n for n in os.listdir(out)), "kill left no temp"
    s = stream_quantize(source, out, plan, resume=True)
    assert s["stray_tmp_removed"] >= 1
    assert not any(".tmp" in n for n in os.listdir(out))
    _assert_identical(shards, out)


def test_kill_between_shard_and_ledger_commit(source, plan, reference,
                                              tmp_path):
    """A published-but-unjournaled shard is re-done — to the same bytes."""
    _, _, shards = reference
    out = str(tmp_path / "run")
    faults = FaultPlan(0, {"ptq.kill_before_commit": {"at": (1,)}})
    with pytest.raises(InjectedFault):
        stream_quantize(source, out, plan, faults=faults)
    led = Ledger(out)
    assert led.load() and len(led.entries) == 1  # block 1 never journaled
    assert os.path.exists(os.path.join(out, shard_name(1)))
    s = stream_quantize(source, out, plan, resume=True)
    assert s["recomputed"] == [1, 2]
    _assert_identical(shards, out)


# ---------------------------------------------------------------------------
# corruption detection
# ---------------------------------------------------------------------------


def test_corrupt_shard_detected_and_only_that_block_redone(
        source, plan, reference, tmp_path):
    _, _, shards = reference
    out = str(tmp_path / "run")
    faults = FaultPlan(0, {"ptq.corrupt_shard": {"at": (1,)},
                           "ptq.kill_at_block": {"at": (2,)}})
    with pytest.raises(InjectedFault):
        stream_quantize(source, out, plan, faults=faults)
    aud = audit_artifact(out, source, plan)
    assert not aud["clean"]
    assert aud["blocks"][0]["ok"] and not aud["blocks"][1]["ok"]
    s = stream_quantize(source, out, plan, resume=True)
    assert s["reused"] == 1 and s["recomputed"] == [1, 2]
    _assert_identical(shards, out)
    assert audit_artifact(out, source, plan)["clean"]


def test_hand_corrupted_ledger_falls_back_to_fresh_run(
        source, plan, reference, tmp_path):
    _, _, shards = reference
    out = str(tmp_path / "run")
    stream_quantize(source, out, plan)
    with open(os.path.join(out, "ledger.json"), "w") as f:
        f.write("{torn")
    s = stream_quantize(source, out, plan, resume=True)
    assert s["status"] == "complete"
    _assert_identical(shards, out)
    assert audit_artifact(out, source, plan)["clean"]


def test_resume_refuses_mismatched_plan(source, plan, tmp_path):
    out = str(tmp_path / "run")
    faults = FaultPlan(0, {"ptq.kill_at_block": {"at": (1,)}})
    with pytest.raises(InjectedFault):
        stream_quantize(source, out, plan, faults=faults)
    other = StreamPlan(block_size=16, rank=3, refine_steps=7)
    with pytest.raises(ValueError, match="different quantization plan"):
        stream_quantize(source, out, other, resume=True)


# ---------------------------------------------------------------------------
# transient IO + shard write protocol
# ---------------------------------------------------------------------------


def test_transient_oserror_is_retried_to_completion(source, plan, reference,
                                                    tmp_path):
    _, _, shards = reference
    out = str(tmp_path / "run")
    faults = FaultPlan(0, {"ptq.transient_oserror": {"at": (0, 2)}})
    s = stream_quantize(source, out, plan, faults=faults)
    assert s["status"] == "complete"
    assert faults.fired("ptq.transient_oserror") == 2
    _assert_identical(shards, out)


def test_write_shard_crc_matches_disk_content(tmp_path):
    tree = {"up/q": np.arange(24, dtype=np.uint8).reshape(4, 6),
            "up/b": np.linspace(-1, 1, 8, dtype=np.float32).reshape(4, 2)}
    name, crc = write_shard(str(tmp_path), 0, tree)
    got = read_shard(str(tmp_path / name))
    crc2 = 0
    for k in sorted(got):
        import zlib

        crc2 = zlib.crc32(k.encode(), crc2)
        crc2 = digest_array(got[k], crc2)
    assert crc == crc2


def test_digest_array_separates_dtype_and_shape():
    a = np.zeros(8, np.float32)
    assert digest_array(a) != digest_array(a.astype(np.int32))
    assert digest_array(a) != digest_array(a.reshape(2, 4))


# ---------------------------------------------------------------------------
# memory budget watchdog
# ---------------------------------------------------------------------------


def test_budget_watchdog_diagnostic_lists_charges():
    b = MemoryBudget(100)
    b.charge("x", 60)
    with pytest.raises(MemoryBudgetExceeded) as e:
        b.charge("y", 50)
    msg = str(e.value)
    assert "x=60" in msg and "y=50" in msg and "110 > 100" in msg


def test_budget_peak_and_release():
    b = MemoryBudget(None)
    b.charge("a", 10)
    with b.hold("t", 90):
        pass
    b.release("a")
    assert b.peak == 100 and b.live() == {}


def test_stream_under_budget_smaller_than_dense(tmp_path):
    src = ResidualMLPSource.create(str(tmp_path / "m"), num_blocks=6, d=48,
                                   d_ff=64, tokens=16, seed=1)
    plan = StreamPlan(block_size=16, rank=3, refine_steps=6,
                      memory_budget=int(src.dense_bytes() * 0.9))
    s = stream_quantize(src, str(tmp_path / "out"), plan)
    assert s["status"] == "complete"
    assert s["peak_bytes"] <= plan.memory_budget < src.dense_bytes()


def test_impossible_budget_fails_fast_with_diagnostic(source, tmp_path):
    plan = StreamPlan(block_size=16, rank=3, refine_steps=6,
                      memory_budget=1024)
    with pytest.raises(MemoryBudgetExceeded, match="live charges"):
        stream_quantize(source, str(tmp_path / "out"), plan)


def test_oom_spike_trips_watchdog_then_resumes_identical(
        source, reference, tmp_path):
    _, _, shards = reference
    plan_b = StreamPlan(block_size=16, rank=3, refine_steps=6,
                        memory_budget=1 << 20)
    out = str(tmp_path / "run")
    faults = FaultPlan(0, {"ptq.oom_spike": {"at": (5,)}})
    with pytest.raises(MemoryBudgetExceeded, match="oom_spike"):
        stream_quantize(source, out, plan_b, faults=faults)
    s = stream_quantize(source, out, plan_b, resume=True)
    assert s["status"] == "complete"
    _assert_identical(shards, out)


# ---------------------------------------------------------------------------
# preemption + pre-transforms
# ---------------------------------------------------------------------------


class _Guard:
    def __init__(self, after):
        self.n = 0
        self.after = after

    @property
    def preempted(self):
        self.n += 1
        return self.n > self.after


def test_preemption_stops_gracefully_then_resumes(source, plan, reference,
                                                  tmp_path):
    _, _, shards = reference
    out = str(tmp_path / "run")
    s = stream_quantize(source, out, plan, guard=_Guard(after=1))
    assert s["status"] == "preempted"
    assert 0 < s["blocks_done"] < N_BLOCKS
    led = Ledger(out)
    assert led.load() and led.status == "in_progress"
    s = stream_quantize(source, out, plan, resume=True)
    assert s["status"] == "complete"
    _assert_identical(shards, out)


@pytest.mark.parametrize("pre", ["smooth", "smoothrot"])
def test_pretransforms_stream_and_resume_bit_identical(source, tmp_path, pre):
    plan = StreamPlan(block_size=16, rank=3, refine_steps=6, pretransform=pre)
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    stream_quantize(source, a, plan)
    if pre == "smoothrot":  # rotation artifacts carry the basis change
        tree = read_shard(os.path.join(a, shard_name(0)))
        assert "up/c" in tree and "up/signs" in tree
    faults = FaultPlan(0, {"ptq.kill_mid_write": {"at": (1,)}})
    with pytest.raises(InjectedFault):
        stream_quantize(source, b, plan, faults=faults)
    stream_quantize(source, b, plan, resume=True)
    ref = [read_shard(os.path.join(a, shard_name(i)))
           for i in range(N_BLOCKS)]
    _assert_identical(ref, b)
    assert audit_artifact(b, source, plan)["clean"]


def test_changed_calibration_invalidates_whole_chain(plan, tmp_path):
    """Same weights, different calibration seed -> fingerprint mismatch
    (the ledger refuses silently mixing two calibration histories)."""
    a = ResidualMLPSource.create(str(tmp_path / "m"), num_blocks=2, d=48,
                                 d_ff=64, tokens=16, seed=3)
    out = str(tmp_path / "out")
    faults = FaultPlan(0, {"ptq.kill_at_block": {"at": (1,)}})
    with pytest.raises(InjectedFault):
        stream_quantize(a, out, plan, faults=faults)
    meta = json.load(open(os.path.join(str(tmp_path / "m"), "source.json")))
    meta["seed"] = 4
    json.dump(meta, open(os.path.join(str(tmp_path / "m"), "source.json"),
                         "w"))
    b = ResidualMLPSource(str(tmp_path / "m"))
    with pytest.raises(ValueError, match="different model/source"):
        stream_quantize(b, out, plan, resume=True)
