"""End-to-end behaviour tests for the system: training improves the model,
checkpoint-resume is exact, serving works, and the dry-run machinery holds
together on a subprocess with forced multi-device CPU."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.launch.train import run_training

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_peft_training_reduces_loss(key, tmp_path):
    """A reduced llama3-8b must LEARN under LoRDS-PEFT: loss decreases on the
    synthetic (structured) stream over a few dozen steps."""
    cfg = smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64)
    shape = ShapeCfg("t", 64, 8, "train")
    out = run_training(cfg, shape, steps=30, lr=3e-3, log_every=1000)
    first = float(np.mean(out["losses"][:5]))
    last = float(np.mean(out["losses"][-5:]))
    assert last < first - 0.05, f"no learning: {first:.3f} -> {last:.3f}"


def test_checkpoint_resume_is_exact(tmp_path):
    """Train 6 steps straight vs 3 + resume + 3: identical final params."""
    cfg = smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64)
    shape = ShapeCfg("t", 32, 4, "train")

    out_a = run_training(cfg, shape, steps=6, lr=1e-3, log_every=1000)

    ck = str(tmp_path / "ck")
    run_training(cfg, shape, steps=3, lr=1e-3, ckpt_dir=ck, ckpt_every=3,
                 log_every=1000)
    out_b = run_training(cfg, shape, steps=3, lr=1e-3, ckpt_dir=ck,
                         ckpt_every=100, log_every=1000)

    la = jax.tree.leaves(out_a["trainable"])
    lb = jax.tree.leaves(out_b["trainable"])
    for xa, xb in zip(la, lb):
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xb),
                                   rtol=1e-5, atol=1e-6)


def test_serve_generates(key):
    from repro.launch.serve import serve_batch

    cfg = smoke_variant(get_config("qwen3-4b"))
    out = serve_batch(cfg, batch=2, prompt_len=16, gen=4)
    assert out["tokens"].shape == (2, 4)
    assert out["tokens"].min() >= 0


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The required dry-run machinery on a real multi-device (forced) mesh —
    smallest arch, probes off, single cell; asserts compile + roofline keys."""
    code = (
        "from repro.launch.dryrun import run_cell; import json;"
        "rec = run_cell('musicgen-medium','decode_32k',multi_pod=False,"
        "verbose=False,probes=False); print(json.dumps(rec['status']));"
        "assert rec['status']=='ok';"
        "assert rec['roofline']['t_memory_s'] > 0"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"ok"' in out.stdout
