"""Substrate: optimizer, schedules, compression, data pipeline, checkpoint,
fault tolerance, sharding-rule resolution."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional-dep shim

from repro.checkpoint import Checkpointer
from repro.data import BinTokenFile, SyntheticLM, make_batch_iterator
from repro.distributed import elastic_mesh_shape
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.distributed.sharding import resolve_spec
from repro.optim import (
    adamw_init,
    adamw_update,
    cosine_warmup,
    ef_compress,
    ef_decompress,
    ef_state_init,
    linear_warmup,
)


# -- optimizer ---------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    target = jnp.asarray([1.0, 1.0, 1.0])
    st_ = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, st_, _ = adamw_update(params, grads, st_, lr=5e-2,
                                      grad_clip_norm=None)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_handles_partition_holes():
    params = {"a": jnp.ones((3,)), "b": None}
    grads = {"a": jnp.ones((3,)), "b": None}
    st_ = adamw_init(params)
    new, st2, gn = adamw_update(params, grads, st_, lr=1e-2)
    assert new["b"] is None and float(gn) > 0


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    st_ = adamw_init(params)
    huge = {"w": 1e9 * jnp.ones((4,))}
    new, _, gnorm = adamw_update(params, huge, st_, lr=1.0,
                                 grad_clip_norm=1.0)
    assert float(gnorm) > 1e8
    assert np.all(np.abs(np.asarray(new["w"])) < 10.0)


def test_schedules():
    f = cosine_warmup(1.0, 100, warmup_ratio=0.1)
    assert float(f(jnp.asarray(0))) < 0.2
    assert float(f(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(f(jnp.asarray(100))) < 0.01
    g = linear_warmup(2e-4, 100, warmup_ratio=0.0)
    assert float(g(jnp.asarray(1))) > 0


# -- error-feedback compression ----------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_ef_compression_roundtrip_accuracy(seed):
    rng = np.random.default_rng(seed)
    g = {"x": jnp.asarray(rng.standard_normal(256).astype(np.float32))}
    resid = ef_state_init(g)
    q, s, resid2 = ef_compress(g, resid)
    deq = ef_decompress(q, s)
    # int8 with per-tensor scale: error bounded by scale/2 per element
    scale = float(s["x"])
    err = np.abs(np.asarray(deq["x"] - g["x"]))
    assert err.max() <= scale * 0.5 + 1e-7
    # residual carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(resid2["x"]),
                               np.asarray(g["x"] - deq["x"]), atol=1e-6)


def test_ef_accumulated_error_does_not_drift():
    """Over many steps the error feedback keeps Σ(deq) ≈ Σ(g)."""
    rng = np.random.default_rng(0)
    resid = {"x": jnp.zeros(64)}
    total_g = np.zeros(64)
    total_d = np.zeros(64)
    for _ in range(50):
        g = {"x": jnp.asarray(rng.standard_normal(64).astype(np.float32))}
        q, s, resid = ef_compress(g, resid)
        d = ef_decompress(q, s)
        total_g += np.asarray(g["x"])
        total_d += np.asarray(d["x"])
    # unsent mass is exactly the residual (bounded), not growing
    np.testing.assert_allclose(total_d + np.asarray(resid["x"]), total_g,
                               atol=1e-4)


# -- data pipeline -------------------------------------------------------------


def test_synthetic_pipeline_deterministic_restart():
    src = SyntheticLM(vocab_size=101, seq_len=16, batch_per_shard=4, seed=3)
    it0 = make_batch_iterator(src)
    run1 = [next(it0)[1]["tokens"] for _ in range(5)]
    it = make_batch_iterator(src, start_step=3)
    s3, b3 = next(it)
    assert s3 == 3
    np.testing.assert_array_equal(b3["tokens"], run1[3])


def test_synthetic_pipeline_shards_differ():
    a = SyntheticLM(101, 16, 4, seed=3, shard_id=0, num_shards=2)
    b = SyntheticLM(101, 16, 4, seed=3, shard_id=1, num_shards=2)
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              b.batch_at(0)["tokens"])


def test_bin_token_file(tmp_path):
    path = tmp_path / "toks.bin"
    arr = (np.arange(10_000) % 97).astype(np.uint16)
    arr.tofile(path)
    src = BinTokenFile(str(path), vocab_size=97, seq_len=32,
                       batch_per_shard=2)
    b0 = src.batch_at(0)
    assert b0["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])


# -- checkpointing -------------------------------------------------------------


def _state(v):
    return {"params": {"w": jnp.full((4, 4), float(v))},
            "opt": {"mu": jnp.zeros((4, 4))}, "data_step": v}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(s, _state(s))
    assert ck.all_steps() == [20, 30]  # keep=2 pruned step 10
    restored = ck.restore(_state(0))
    assert int(np.asarray(restored["data_step"])) == 30
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 30.0)


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _state(5))
    os.makedirs(tmp_path / "step_9.tmp")  # simulated crash mid-write
    assert ck.latest_step() == 5


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(1))
    with pytest.raises(ValueError):
        ck.restore({"params": {"w": jnp.zeros((4, 4)), "extra": jnp.zeros(2)},
                    "opt": {"mu": jnp.zeros((4, 4))}, "data_step": 0})


# -- fault tolerance / elastic -------------------------------------------------


def test_straggler_monitor_flags_spike(monkeypatch):
    # deterministic: inject step durations instead of sleeping (wall-clock
    # sleeps are load-sensitive on a shared single-core container)
    mon = StragglerMonitor(alpha=0.3, z_threshold=3.0, warmup_steps=2)
    durations = [0.010, 0.011, 0.010, 0.012, 0.011, 0.010, 0.011, 0.010,
                 0.012, 0.011, 0.500, 0.011]
    clock = {"t": 0.0}
    import repro.distributed.fault_tolerance as ft

    monkeypatch.setattr(ft.time, "monotonic", lambda: clock["t"])
    for i, dt in enumerate(durations):
        mon.start_step()
        clock["t"] += dt
        mon.end_step(i)
    assert any(step == 10 for step, _, _ in mon.flags)


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(512, 16, 256) == (2, 16, 16)
    assert elastic_mesh_shape(256, 16, 256) == (16, 16)
    # losing a host: 248 -> round down to 240 = 15 x 16
    assert elastic_mesh_shape(248, 16, 256) == (15, 16)
    with pytest.raises(ValueError):
        elastic_mesh_shape(8, 16)


# -- sharding-rule resolution ---------------------------------------------------


def test_resolve_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    dropped = []
    spec = resolve_spec(("heads", "embed"), (40, 4096),
                        {"heads": "model", "embed": "data"}, FakeMesh(),
                        dropped)
    assert spec[0] is None  # 40 % 16 != 0 -> dropped
    assert spec[1] == "data"
    assert dropped and dropped[0][0] == "heads"


def test_resolve_spec_never_reuses_axis():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = resolve_spec(("vocab", "embed"), (4096, 4096),
                        {"vocab": "model", "embed": "model"}, FakeMesh())
    assert spec[0] == "model" and spec[1] is None
