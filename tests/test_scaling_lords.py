"""LoRDS scaling decomposition: parity ranks (paper Table 7), SVD init
exactness, PTQ refinement (Alg. 1), STE gradients (Eq. 4/5), PEFT partition.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantSpec,
    dequantize_weight,
    fake_quant_ste,
    init_quantized_linear,
    ptq_refine,
)
from repro.core import lut, metrics, peft, quantize, scaling


# paper Appendix A Table 7 — exact rank parity values
TABLE7 = [
    # (n, m, block, rank)
    (4096, 4096, 128, 16), (1024, 4096, 128, 6), (14336, 4096, 128, 24),
    (4096, 14336, 128, 24), (4096, 4096, 256, 8), (1024, 4096, 256, 3),
    (12288, 4096, 128, 24), (4096, 2560, 128, 12), (1024, 2560, 128, 5),
    (9728, 2560, 128, 15), (1024, 2560, 256, 2), (9728, 2560, 256, 7),
]


@pytest.mark.parametrize("n,m,bs,r", TABLE7)
def test_parity_rank_matches_paper_table7(n, m, bs, r):
    assert scaling.parity_rank(n, m, bs) == r


def test_svd_init_exact_when_rank_sufficient(key):
    """r >= rank(S_blockwise) ==> BA reproduces S exactly (Eq. 3)."""
    w = jax.random.normal(key, (64, 256)) * 0.02
    s_blk = scaling.blockwise_scales(w, 64)          # rank <= 4
    s_dense = scaling.expand_block_scales(s_blk, 64)
    b, a = scaling.svd_init(s_dense, 4)
    np.testing.assert_allclose(np.asarray(b @ a), np.asarray(s_dense),
                               rtol=1e-4, atol=1e-6)


def test_ptq_refinement_beats_blockwise(key):
    """The paper's central PTQ claim at parity budget: refined continuous
    low-rank scaling reconstructs better than rigid block-wise scaling."""
    w = jax.random.normal(key, (128, 512)) * 0.02
    qb, sb = quantize.quantize_blockwise(w, 128, "nf4")
    w_block = quantize.dequantize_blockwise(qb, sb, 128, "nf4")
    err_block = float(metrics.frobenius_error(w, w_block))

    res = ptq_refine(w, steps=150, lr=0.05, block_size=128)
    s = scaling.scale_matrix(res.b, res.a)
    codes = quantize.unpack_codes(res.q_packed, "nf4")
    w_lords = quantize.dequantize_codes(codes, s, "nf4")
    err_lords = float(metrics.frobenius_error(w, w_lords))
    assert err_lords < err_block
    # loss history is (noisily) decreasing overall
    lh = np.asarray(res.loss_history)
    assert lh[-10:].mean() < lh[:10].mean()


def test_ste_gradients_match_paper_equations(key):
    """∇_W = g (Eq. 4); ∇_S = g ⊙ (Q − W⊘S) (Eq. 5)."""
    w = jax.random.normal(key, (4, 8)) * 0.1
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (4, 8))) + 0.05
    g = jax.random.normal(jax.random.PRNGKey(8), (4, 8))

    f = lambda w_, s_: jnp.sum(fake_quant_ste("nf4", w_, s_) * g)
    gw, gs = jax.grad(f, argnums=(0, 1))(w, s)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(g), rtol=1e-6)

    codes = quantize.quantize_codes(w, s, "nf4")
    qv = jnp.take(lut.codebook("nf4"), codes.astype(jnp.int32))
    expect = np.asarray(g * (qv - w / s))
    np.testing.assert_allclose(np.asarray(gs), expect, rtol=1e-5, atol=1e-6)


def test_peft_partition_modes(key):
    w = jax.random.normal(key, (64, 128)) * 0.02
    spec = QuantSpec(method="lords", block_size=64, rank=2, mode="peft")
    params = init_quantized_linear(key, 64, 128, spec, w=w)
    t, f = peft.partition(params, spec)
    assert t["q"] is None and f["q"] is not None
    assert t["b"] is not None and t["a"] is not None
    back = peft.combine(t, f)
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]))
    # qat mode trains w too
    spec_q = spec.with_(mode="qat")
    params_q = init_quantized_linear(key, 64, 128, spec_q, w=w)
    t2, f2 = peft.partition(params_q, spec_q)
    assert t2["w"] is not None and t2["q"] is None if "q" in params_q else True


def test_peft_multiplicative_update_is_high_rank(key):
    """Fig. 3 claim: ΔW = Q ⊙ (B'A' − BA) has rank >> r."""
    n, m, r = 96, 192, 2
    w = jax.random.normal(key, (n, m)) * 0.02
    spec = QuantSpec(method="lords", block_size=64, rank=r, mode="peft")
    params = init_quantized_linear(key, n, m, spec, w=w)
    w0 = dequantize_weight(params, spec, n, m).astype(jnp.float32)
    # simulate a PEFT update on B, A
    kb, ka = jax.random.split(jax.random.PRNGKey(5))
    params2 = dict(params)
    params2["b"] = params["b"] + 0.1 * jax.random.normal(kb, params["b"].shape)
    params2["a"] = params["a"] + 0.1 * jax.random.normal(ka, params["a"].shape)
    w1 = dequantize_weight(params2, spec, n, m).astype(jnp.float32)
    delta = w1 - w0
    eff = int(metrics.effective_rank(delta, rel_tol=1e-2))
    assert eff > 4 * r, f"effective rank {eff} should far exceed r={r}"


def test_lords_dagger_extra_rank(key):
    """LoRDS† (Appendix B): r = parity + r_q."""
    spec = QuantSpec(method="lords", block_size=128, extra_rank=16)
    assert spec.lords_rank(4096, 4096) == 16 + 16


def test_channel_scale_folds_into_svd_init(key):
    """Init with channel_scale c must equal block scales of the *smoothed*
    weight divided back by c — so quantizing W against it is exactly
    quantizing W ⊙ c against its own block scales (AWQ-style smoothing at
    zero runtime cost; diagonal scaling preserves the S rank)."""
    w = jax.random.normal(key, (64, 256)) * 0.02
    c = jnp.exp(jax.random.normal(jax.random.PRNGKey(5), (256,)) * 0.5)
    b, a = scaling.lords_init_from_weight(w, 64, rank=4, channel_scale=c)
    s_fold = scaling.expand_block_scales(
        scaling.blockwise_scales(w * c[None, :], 64), 64) / c[None, :]
    np.testing.assert_allclose(np.asarray(b @ a), np.asarray(s_fold),
                               rtol=1e-4, atol=1e-6)


def test_ptq_refine_col_weight_prioritizes_heavy_columns(key):
    """Activation-weighted refinement must reduce the weighted recon error
    at least as well as unweighted refinement does."""
    w = jax.random.normal(key, (64, 128)) * 0.02
    colw = jnp.ones((128,)).at[:8].set(100.0)  # heavy leading channels

    def werr(res):
        s = scaling.scale_matrix(res.b, res.a)
        codes = quantize.unpack_codes(res.q_packed, "nf4")
        w_hat = quantize.dequantize_codes(codes, s, "nf4")
        return float(jnp.mean(((w - w_hat) ** 2) * colw[None, :]))

    plain = ptq_refine(w, "nf4", 32, rank=3, steps=40)
    weighted = ptq_refine(w, "nf4", 32, rank=3, steps=40, col_weight=colw)
    assert werr(weighted) <= werr(plain) * 1.0001
