"""Paged KV serving: page-pool attention kernels vs the gather oracles,
chunked-prefill/paged-decode model parity, the continuous-batching engine
token-for-token against the PR 2 scan loop (ragged prompts, int8 + bf16,
GQA + MLA, slot reuse, forced eviction + recompute), the jaxpr guard that
the paged int8 decode step never gathers the pool into a contiguous
temporary or dequantizes it outside a kernel launch, and sharded-vs-single
engine parity under the 8-device harness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from multidevice_compat import multidevice, single_mesh, tp_mesh
from repro.configs import get_config, smoke_variant
from repro.kernels import dispatch
from repro.kernels.dispatch import qattention
from repro.launch.engine import Engine, Request
from repro.launch.serve import serve_batch
from repro.models import (
    forward_decode,
    forward_decode_paged,
    forward_prefill,
    forward_prefill_chunk,
    model_init,
    paged_cache_init,
    split_tree,
)


def _cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-30))


def _maxerr(a, b):
    return float(np.max(np.abs(np.asarray(a, np.float32)
                               - np.asarray(b, np.float32))))


def _smoke(arch, kv):
    return smoke_variant(get_config(arch)).with_(num_layers=2,
                                                 kv_cache_dtype=kv)


def _prompts(cfg, plens, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32)
            for p in plens]


def _scan_tokens(cfg, prompt, gen, params):
    """Per-request reference: the PR 2 single-sequence scan loop."""
    out = serve_batch(cfg, batch=1, prompt_len=len(prompt), gen=gen,
                      params=params, prompts=prompt[None],
                      kernel_backend="interpret", loop="scan")
    return list(out["tokens"][0])


# ---------------------------------------------------------------------------
# paged decode kernels: fused (page-table scalar prefetch) vs gather oracle
# ---------------------------------------------------------------------------

# (batch, page_size, logical pages, physical pages, nh, nkv, hd) — positions
# off the page grid, GQA group > 1, pool larger than any one sequence
PAGED_SHAPES = [(2, 8, 5, 9, 4, 2, 16), (1, 16, 3, 7, 8, 2, 24)]


def _page_table(rng, b, np_, total):
    """Distinct physical pages per row, non-contiguous and unordered."""
    rows = [rng.choice(np.arange(1, total), size=np_, replace=False)
            for _ in range(b)]
    return jnp.asarray(np.stack(rows), jnp.int32)


@pytest.mark.parametrize("b,ps,np_,tp,nh,nkv,hd", PAGED_SHAPES)
@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_paged_decode_kernel_matches_ref(b, ps, np_, tp, nh, nkv, hd, kv):
    rng = np.random.default_rng(0)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, nh, hd))
    pt = _page_table(rng, b, np_, tp)
    pos = jnp.asarray(rng.integers(1, np_ * ps, (b,)), jnp.int32)
    sc = 1.0 / hd ** 0.5
    if kv == "int8":
        kp = jnp.asarray(rng.integers(-127, 128, (tp, ps, nkv, hd)), jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, (tp, ps, nkv, hd)), jnp.int8)
        ks = jnp.asarray(rng.uniform(0.01, 0.05, (tp, ps, nkv)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.01, 0.05, (tp, ps, nkv)), jnp.float32)
        args = (q, kp, vp, pt, pos, ks, vs)
    else:
        kp = jax.random.normal(jax.random.PRNGKey(1), (tp, ps, nkv, hd),
                               jnp.bfloat16)
        vp = jax.random.normal(jax.random.PRNGKey(2), (tp, ps, nkv, hd),
                               jnp.bfloat16)
        args = (q, kp, vp, pt, pos)
    y_ref = qattention("paged_decode", *args, logit_scale=sc, backend="ref")
    y_int = qattention("paged_decode", *args, logit_scale=sc,
                       backend="interpret")
    assert _cos(y_int, y_ref) > 0.9999
    assert _maxerr(y_int, y_ref) < 3e-5


@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_paged_mla_decode_kernel_matches_ref(kv):
    b, ps, np_, tp = 2, 8, 4, 7
    nh, lat, rope = 4, 32, 16
    rng = np.random.default_rng(1)
    q_lat = jax.random.normal(jax.random.PRNGKey(0), (b, nh, lat))
    q_rope = jax.random.normal(jax.random.PRNGKey(1), (b, nh, rope))
    krp = jax.random.normal(jax.random.PRNGKey(2), (tp, ps, rope),
                            jnp.bfloat16)
    pt = _page_table(rng, b, np_, tp)
    pos = jnp.asarray([np_ * ps - 3, 9], jnp.int32)
    sc = 1.0 / (lat + rope) ** 0.5
    if kv == "int8":
        cp = jnp.asarray(rng.integers(-127, 128, (tp, ps, lat)), jnp.int8)
        cs = jnp.asarray(rng.uniform(0.01, 0.05, (tp, ps)), jnp.float32)
        args = (q_lat, q_rope, cp, krp, pt, pos, cs)
    else:
        cp = jax.random.normal(jax.random.PRNGKey(3), (tp, ps, lat),
                               jnp.bfloat16)
        args = (q_lat, q_rope, cp, krp, pt, pos)
    y_ref = qattention("paged_mla_decode", *args, logit_scale=sc,
                       backend="ref")
    y_int = qattention("paged_mla_decode", *args, logit_scale=sc,
                       backend="interpret")
    assert _cos(y_int, y_ref) > 0.9999
    assert _maxerr(y_int, y_ref) < 3e-5


def test_chunk_prefill_kernel_matches_ref():
    """Chunk queries attend gathered-window + raw-chunk KV with absolute
    positions; fused vs oracle on a ragged (dead-row) chunk."""
    b, cs, skv, nh, nkv, hd = 2, 8, 24, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, cs, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, nkv, hd))
    # row 0: chunk positions 16..23 over a 24-token window; row 1: a short
    # final chunk (3 live tokens, rest dead) over a 19-token window
    qpos = np.full((b, cs), -1, np.int32)
    qpos[0] = np.arange(16, 24)
    qpos[1, :3] = np.arange(16, 19)
    kpos = np.full((b, skv), -1, np.int32)
    kpos[0] = np.arange(24)
    kpos[1, :19] = np.arange(19)
    qpos, kpos = jnp.asarray(qpos), jnp.asarray(kpos)
    sc = 1.0 / hd ** 0.5
    y_ref = qattention("chunk_prefill", q, k, v, qpos, kpos, logit_scale=sc,
                       backend="ref")
    y_int = qattention("chunk_prefill", q, k, v, qpos, kpos, logit_scale=sc,
                       backend="interpret")
    live = np.asarray(qpos) >= 0
    assert _cos(np.asarray(y_int)[live], np.asarray(y_ref)[live]) > 0.9999
    assert _maxerr(np.asarray(y_int)[live], np.asarray(y_ref)[live]) < 3e-5


# ---------------------------------------------------------------------------
# model layer: chunked paged prefill + paged decode vs the contiguous path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3-8b", "minicpm3-4b"])
def test_paged_forward_matches_contiguous_logits(arch):
    """Single-chunk prefill keeps in-chunk KV raw (never reads it back
    through the pool), so paged logits are bitwise equal to the contiguous
    path even with an int8 pool — then every paged decode step must match
    the contiguous decode step exactly too.  Both paths run under the
    fused backend the serving plans pin (the ref oracle prefill is a
    different implementation with its own bf16 rounding)."""
    from repro.models import cache_init

    cfg = _smoke(arch, "int8")
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    b, plen, ps, np_ = 2, 12, 8, 4
    cap = np_ * ps
    toks = jnp.asarray(np.stack(_prompts(cfg, [plen, plen])), jnp.int32)

    with dispatch.backend_scope("interpret"):
        cache, _ = split_tree(cache_init(cfg, b, cap))
        logits_c, cache = forward_prefill(params, cfg, {"tokens": toks},
                                          cache)

        pools, _ = split_tree(paged_cache_init(cfg, 2 * np_ + 1, ps))
        pt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
        pad = np.full((b, cap - plen), 0, np.int32)
        qpos = np.concatenate(
            [np.tile(np.arange(plen, dtype=np.int32), (b, 1)),
             np.full((b, cap - plen), -1, np.int32)], axis=1)
        logits_p, pools = forward_prefill_chunk(
            params, cfg,
            {"tokens": jnp.concatenate([toks, jnp.asarray(pad)], 1)},
            pools, pt, jnp.asarray(qpos), jnp.zeros((b,), jnp.int32))
        assert _maxerr(logits_p[:, 0], logits_c[:, 0]) == 0.0

        tok = jnp.argmax(logits_c[:, -1, : cfg.vocab_size],
                         -1).astype(jnp.int32)
        for step in range(3):
            pos = jnp.full((b,), plen + step, jnp.int32)
            lc, cache = forward_decode(params, cfg, {"tokens": tok}, cache,
                                       pos)
            lp, pools = forward_decode_paged(params, cfg, {"tokens": tok},
                                             pools, pt, pos)
            assert _maxerr(lp, lc) == 0.0, f"decode step {step}"
            tok = jnp.argmax(lc[:, -1, : cfg.vocab_size],
                             -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# engine end-to-end: token-for-token vs the scan serve loop
# ---------------------------------------------------------------------------

ENGINE_COMBOS = [("llama3-8b", "bf16"), ("llama3-8b", "int8"),
                 ("minicpm3-4b", "bf16"), ("minicpm3-4b", "int8")]


@pytest.mark.parametrize("arch,kv", ENGINE_COMBOS)
def test_engine_matches_scan_serve(arch, kv):
    """Three ragged requests through two slots (forces slot reuse +
    admission queueing) produce exactly the tokens the fixed-capacity scan
    loop produces per request."""
    cfg = _smoke(arch, kv)
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    prompts = _prompts(cfg, [10, 6, 13])
    gen = 5
    reqs = [Request(rid=i, tokens=p, max_new=gen, arrival=0.0)
            for i, p in enumerate(prompts)]
    eng = Engine(cfg, slots=2, total_pages=12, page_size=8, max_pages=4,
                 chunk=16, burst=4, kernel_backend="interpret",
                 params=params)
    stats = eng.run(reqs, timeout_s=600)
    assert stats["all_completed"], stats
    got = {r["rid"]: r["tokens"] for r in stats["records"]}
    for i, p in enumerate(prompts):
        assert got[i] == _scan_tokens(cfg, p, gen, params), f"rid={i}"


def test_engine_eviction_recompute_matches_scan():
    """A pool too small for the offered load forces the scheduler to evict
    the youngest sequence and recompute it from scratch later — tokens must
    still match the scan loop exactly, and the eviction path must actually
    have fired."""
    cfg = _smoke("llama3-8b", "int8")
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    prompts = _prompts(cfg, [10, 9, 12], seed=11)
    gen = 12
    reqs = [Request(rid=i, tokens=p, max_new=gen, arrival=0.02 * i)
            for i, p in enumerate(prompts)]
    eng = Engine(cfg, slots=2, total_pages=5, page_size=8, max_pages=4,
                 chunk=16, burst=4, kernel_backend="interpret",
                 params=params)
    stats = eng.run(reqs, timeout_s=600)
    assert stats["all_completed"], stats
    assert stats["evictions"] > 0, "pool was sized to force eviction"
    got = {r["rid"]: r["tokens"] for r in stats["records"]}
    for i, p in enumerate(prompts):
        assert got[i] == _scan_tokens(cfg, p, gen, params), f"rid={i}"


def test_engine_multichunk_prefill_matches_scan():
    """Prompts longer than the chunk size run multiple interleaved prefill
    chunks (later chunks re-read earlier KV through the pool); with a bf16
    pool the stored window is exact, so tokens still match the scan loop."""
    cfg = _smoke("llama3-8b", "bf16")
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    prompts = _prompts(cfg, [20, 11], seed=3)
    gen = 4
    reqs = [Request(rid=i, tokens=p, max_new=gen, arrival=0.0)
            for i, p in enumerate(prompts)]
    eng = Engine(cfg, slots=2, total_pages=12, page_size=8, max_pages=5,
                 chunk=8, burst=4, kernel_backend="interpret", params=params)
    stats = eng.run(reqs, timeout_s=600)
    assert stats["all_completed"], stats
    assert stats["chunk_steps"] >= 3        # 20-token prompt = 3 chunks of 8
    got = {r["rid"]: r["tokens"] for r in stats["records"]}
    for i, p in enumerate(prompts):
        assert got[i] == _scan_tokens(cfg, p, gen, params), f"rid={i}"


def test_engine_rejects_oversized_request():
    cfg = _smoke("llama3-8b", "int8")
    eng = Engine(cfg, slots=2, total_pages=6, page_size=8, max_pages=4,
                 chunk=16, burst=1, kernel_backend="interpret")
    big = Request(rid=0, tokens=np.zeros((40,), np.int32), max_new=8)
    with pytest.raises(ValueError, match="pages"):
        eng.run([big])


# ---------------------------------------------------------------------------
# jaxpr guard: the paged int8 decode step reads the pool in place — no
# contiguous-cache gather and no out-of-kernel pool dequant
# ---------------------------------------------------------------------------


def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue  # tile-level internals live in VMEM, not HBM
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from _walk_eqns(sub)


def _subjaxprs(val):
    if isinstance(val, jax.extend.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _subjaxprs(v)


@pytest.mark.parametrize("arch", ["llama3-8b", "minicpm3-4b"])
def test_paged_decode_step_jaxpr_no_gather_or_dequant(arch):
    """The engine's jitted paged decode step must contain (a) no tensor of
    shape (slots, max_pages*page_size, ...) — the contiguous KV window the
    gather oracle materializes from the pool — and (b) no float tensor of a
    full int8 pool's shape outside kernel launches — an out-of-kernel pool
    dequant.  The ref plan must trip (a) or the guard is vacuous."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_paged_generate_plan

    cfg = _smoke(arch, "int8")
    # slots/pages deliberately off every model dim of both smoke configs
    # (hd=16, d=64, qk=24, q_lora=32, ...): a (2, 40, ...) tensor can only
    # be a gathered contiguous KV window
    slots, ps, np_, total = 2, 8, 5, 11
    cap = np_ * ps
    mesh = make_host_mesh()

    def temporaries(backend):
        plan = build_paged_generate_plan(
            cfg, mesh, slots=slots, gen=1, total_pages=total, page_size=ps,
            max_pages=np_, kernel_backend=backend)
        pools = plan.abstract_args[2]
        pool_shapes = {tuple(l.shape[1:]) for l in jax.tree.leaves(pools)
                       if l.dtype == jnp.int8}
        jaxpr = jax.make_jaxpr(plan.step_fn)(*plan.abstract_args)
        bad = []
        for eqn in _walk_eqns(jaxpr.jaxpr):
            for v in eqn.outvars:
                aval = v.aval
                shape = tuple(getattr(aval, "shape", ()))
                if len(shape) < 3:
                    continue
                # (a) gathered contiguous window (any dtype: the int8
                # gather itself or its dequantized float twin)
                if shape[0] == slots and shape[1] == cap:
                    bad.append(("gather", eqn.primitive.name, shape,
                                str(aval.dtype)))
                # (b) full-pool dequant temporary (per stacked layer)
                if (jnp.issubdtype(aval.dtype, jnp.floating)
                        and (shape in pool_shapes
                             or shape[1:] in pool_shapes)):
                    bad.append(("dequant", eqn.primitive.name, shape,
                                str(aval.dtype)))
        return bad

    bad = temporaries("interpret")
    assert not bad, f"paged serving-path temporaries found: {bad}"

    # negative control: the gather oracle must trip the detector
    ref_bad = temporaries("ref")
    assert any(kind == "gather" for kind, *_ in ref_bad), ref_bad


# ---------------------------------------------------------------------------
# sharded engine under the 8-device harness
# ---------------------------------------------------------------------------


@multidevice
def test_engine_sharded_matches_single_device():
    """The whole engine pipeline (chunk prefill + burst decode over the
    shared pool) tensor-parallel over 8 devices produces the single-mesh
    tokens exactly."""
    cfg = _smoke("llama3-8b", "int8")
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    prompts = _prompts(cfg, [10, 6, 13], seed=5)
    gen = 5
    outs = {}
    for name, mesh in (("single", single_mesh()), ("tp", tp_mesh())):
        reqs = [Request(rid=i, tokens=p, max_new=gen, arrival=0.0)
                for i, p in enumerate(prompts)]
        eng = Engine(cfg, slots=2, total_pages=12, page_size=8, max_pages=4,
                     chunk=16, burst=4, mesh=mesh,
                     kernel_backend="interpret", params=params)
        stats = eng.run(reqs, timeout_s=600)
        assert stats["all_completed"], (name, stats)
        outs[name] = {r["rid"]: r["tokens"] for r in stats["records"]}
    assert outs["tp"] == outs["single"]


@multidevice
def test_paged_decode_kernel_sharded_matches_ref():
    """Fused paged decode under shard_map (kv heads over 'model') matches
    the unsharded gather oracle."""
    b, ps, np_, tp_, nh, nkv, hd = 2, 8, 4, 7, 16, 8, 16
    rng = np.random.default_rng(2)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, nh, hd))
    kp = jax.random.normal(jax.random.PRNGKey(1), (tp_, ps, nkv, hd),
                           jnp.bfloat16)
    vp = jax.random.normal(jax.random.PRNGKey(2), (tp_, ps, nkv, hd),
                           jnp.bfloat16)
    pt = _page_table(rng, b, np_, tp_)
    pos = jnp.asarray([np_ * ps - 1, 13], jnp.int32)
    sc = 1.0 / hd ** 0.5
    y_ref = qattention("paged_decode", q, kp, vp, pt, pos, logit_scale=sc,
                       backend="ref")
    with dispatch.shard_scope(tp_mesh()):
        y_sh = qattention("paged_decode", q, kp, vp, pt, pos,
                          logit_scale=sc, backend="interpret")
    assert _cos(y_sh, y_ref) > 0.9999
    assert _maxerr(y_sh, y_ref) < 3e-5


# ---------------------------------------------------------------------------
# hardening (PR 7): deadlines, timeout drain, retries, shedding, quarantine,
# preemption, and the page-pool invariant audit under seeded chaos
# ---------------------------------------------------------------------------

from repro.distributed.fault_tolerance import PreemptionGuard  # noqa: E402
from repro.launch.engine import TERMINAL_STATUSES  # noqa: E402
from repro.robustness import NO_FAULTS, FaultPlan  # noqa: E402


@pytest.fixture(scope="module")
def hardened():
    """One compiled engine shared by the robustness tests (they vary only
    host-side knobs — faults, budgets, guards — never compiled shapes).
    Pool: 7 usable pages, 2 slots, 5-page tables."""
    cfg = _smoke("llama3-8b", "int8")
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    eng = Engine(cfg, slots=2, total_pages=8, page_size=8, max_pages=5,
                 chunk=16, burst=4, kernel_backend="interpret",
                 params=params)
    eng.warmup()
    return cfg, params, eng


@pytest.fixture
def heng(hardened):
    cfg, params, eng = hardened
    yield cfg, params, eng
    eng.faults = NO_FAULTS
    eng.admission_budget = None
    eng.max_retries = 2
    eng._guard = None
    eng.audit_every = False


def _trace(cfg, plens, gens, gap=0.0, seed=7, deadline=None):
    prompts = _prompts(cfg, plens, seed=seed)
    return [Request(rid=i, tokens=p, max_new=g, arrival=gap * i,
                    deadline_s=deadline)
            for i, (p, g) in enumerate(zip(prompts, gens))]


def test_engine_global_timeout_returns_instead_of_raising(heng):
    """timeout_s is a drain guard: on expiry run() returns the stats dict
    with every request in a terminal 'timeout' status — never raises."""
    cfg, params, eng = heng
    stats = eng.run(_trace(cfg, [10, 6], [6, 6]), timeout_s=0.0)
    assert stats["drained"] == "timeout"
    assert len(stats["records"]) == 2
    assert all(r["status"] == "timeout" for r in stats["records"])
    assert not stats["all_completed"]
    assert stats["page_audit"]["ok"], stats["page_audit"]


def test_engine_mid_run_timeout_keeps_partial_results(heng):
    """A straggler tick pushes the run past timeout_s mid-decode: the drain
    cancels in-flight work but keeps the tokens already generated."""
    cfg, params, eng = heng
    eng.faults = FaultPlan(0, {"engine.straggler": {"at": (1,),
                                                    "delay_s": 2.0}})
    stats = eng.run(_trace(cfg, [10, 6], [16, 16]), timeout_s=0.8)
    assert stats["drained"] == "timeout"
    assert len(stats["records"]) == 2
    assert {r["status"] for r in stats["records"]} == {"timeout"}
    assert any(r["tokens"] for r in stats["records"]), stats["records"]
    assert stats["page_audit"]["ok"], stats["page_audit"]


def test_engine_deadline_cancels_inflight_request(heng):
    """A per-request deadline expires mid-decode (straggler-stretched
    tick): that request alone is cancelled with partial tokens; its
    deadline-free sibling completes token-identically to a clean run."""
    cfg, params, eng = heng
    prompts = _prompts(cfg, [10, 6], seed=5)
    clean = eng.run([Request(0, prompts[0], 10),
                     Request(1, prompts[1], 24)], timeout_s=600)
    assert clean["all_completed"]
    clean_toks = {r["rid"]: r["tokens"] for r in clean["records"]}

    eng.faults = FaultPlan(0, {"engine.straggler": {"at": (2,),
                                                    "delay_s": 1.0}})
    stats = eng.run([Request(0, prompts[0], 10),
                     Request(1, prompts[1], 24, deadline_s=0.5)],
                    timeout_s=600)
    rec = {r["rid"]: r for r in stats["records"]}
    assert rec[1]["status"] == "timeout" and rec[1]["reason"] == "deadline"
    assert stats["deadline_cancels"] >= 1
    assert rec[0]["status"] == "completed"
    assert rec[0]["tokens"] == clean_toks[0]
    assert stats["page_audit"]["ok"], stats["page_audit"]


def test_engine_admission_budget_sheds_overload(heng):
    """Arrivals beyond the admission budget are rejected immediately with
    a structured 'overload' record instead of growing the backlog."""
    cfg, params, eng = heng
    eng.admission_budget = 2
    stats = eng.run(_trace(cfg, [8] * 5, [4] * 5), timeout_s=600)
    st = stats["statuses"]
    assert st.get("rejected", 0) == 3 and stats["shed"] == 3, st
    assert st.get("completed", 0) == 2, st
    shed = [r for r in stats["records"] if r["status"] == "rejected"]
    assert all(r["reason"] == "overload" for r in shed)
    assert stats["page_audit"]["ok"], stats["page_audit"]


def test_engine_nan_quarantine_isolates_one_slot(heng):
    """NaNs injected into one slot's KV page trip the in-graph non-finite
    guard for that slot only: it fails with reason 'non_finite', the other
    slot's output stays token-for-token identical to the clean run, and
    the poisoned pages are scrubbed before reuse."""
    cfg, params, eng = heng
    reqs = _trace(cfg, [10, 6], [12, 12], seed=9)
    clean = eng.run(reqs, timeout_s=600)
    assert clean["all_completed"]
    clean_toks = {r["rid"]: r["tokens"] for r in clean["records"]}

    eng.faults = FaultPlan(3, {"engine.nan_logits": {"at": (0,)}})
    stats = eng.run(reqs, timeout_s=600)
    rec = {r["rid"]: r for r in stats["records"]}
    assert rec[0]["status"] == "failed" and rec[0]["reason"] == "non_finite"
    assert stats["quarantined"] == 1 and stats["nan_injections"] == 1
    assert rec[1]["status"] == "completed"
    assert rec[1]["tokens"] == clean_toks[1], "bystander slot corrupted"
    assert stats["page_audit"]["ok"], stats["page_audit"]
    assert not eng._poisoned, "poisoned pages must be scrubbed + reclaimed"


def test_engine_step_failure_retries_then_recovers(heng):
    """An injected step failure requeues its participants; the retry
    recomputes from scratch and the final tokens match the clean run."""
    cfg, params, eng = heng
    reqs = _trace(cfg, [10, 6], [8, 8], seed=2)
    clean = eng.run(reqs, timeout_s=600)
    assert clean["all_completed"]
    clean_toks = {r["rid"]: r["tokens"] for r in clean["records"]}

    eng.faults = FaultPlan(0, {"engine.step": {"at": (0,)}})
    stats = eng.run(reqs, timeout_s=600)
    assert stats["all_completed"], stats["statuses"]
    assert stats["step_failures"] == 1 and stats["retries"] >= 1
    got = {r["rid"]: r["tokens"] for r in stats["records"]}
    assert got == clean_toks
    assert stats["page_audit"]["ok"], stats["page_audit"]


def test_engine_step_failure_budget_exhausts_to_failed(heng):
    """A step that fails on every launch burns the per-request retry
    budget and ends in 'failed' — with every page back in the pool."""
    cfg, params, eng = heng
    eng.faults = FaultPlan(0, {"engine.step": {"prob": 1.0}})
    stats = eng.run(_trace(cfg, [8], [4]), timeout_s=600)
    (rec,) = stats["records"]
    assert rec["status"] == "failed" and "step_failure" in rec["reason"]
    assert stats["retries"] == eng.max_retries + 1
    assert stats["page_audit"]["ok"], stats["page_audit"]
    assert stats["page_audit"]["free"] == eng.total_pages - 1


def test_engine_preemption_guard_drains_gracefully(heng):
    """A pre-flagged PreemptionGuard flips the engine straight into drain:
    nothing is admitted, every waiting request gets a structured
    'rejected/preempted' record."""
    cfg, params, eng = heng
    guard = PreemptionGuard(signals=())
    guard.request()
    eng._guard = guard
    stats = eng.run(_trace(cfg, [8, 8], [4, 4]), timeout_s=600)
    assert stats["preempted"] and stats["drained"] == "preempted"
    assert all(r["status"] == "rejected" and r["reason"] == "preempted"
               for r in stats["records"])
    assert stats["page_audit"]["ok"], stats["page_audit"]


def test_engine_seeded_chaos_trace_contract(heng):
    """The PR 7 acceptance trace: an eviction-heavy seeded load under a
    FaultPlan injecting page-allocation failures, a step failure, a NaN
    burst and a mid-run preemption.  Contract: run() returns, every
    request ends in exactly one terminal status, fault-untouched requests
    are token-for-token identical to the clean run, and the page-pool
    audit is clean after every recovery path and at exit."""
    cfg, params, eng = heng
    # two concurrent 5-page requests overcommit the 7-page pool with
    # overlapping starvation windows: the clean run must already exercise
    # stall/evict/recompute
    reqs = _trace(cfg, [8, 8, 10, 8, 9], [32, 32, 12, 24, 8],
                  gap=0.02, seed=13)
    eng.audit_every = True
    clean = eng.run(reqs, timeout_s=600)
    assert clean["all_completed"], clean["statuses"]
    assert clean["evictions"] > 0, "trace was sized to force eviction"
    assert "audit_failures" not in clean, clean["audit_failures"]
    clean_toks = {r["rid"]: r["tokens"] for r in clean["records"]}

    eng.faults = FaultPlan(17, {
        "engine.page_alloc": {"prob": 0.2, "max_fires": 5},
        "engine.step": {"at": (2,)},
        "engine.nan_logits": {"at": (1,)},
        "engine.preempt": {"at": (12,)},
    })
    stats = eng.run(reqs, timeout_s=600)

    records = stats["records"]
    assert len(records) == len(reqs)
    assert sorted(r["rid"] for r in records) == list(range(len(reqs)))
    assert all(r["status"] in TERMINAL_STATUSES for r in records)
    assert sum(stats["statuses"].values()) == len(reqs)
    for r in records:
        if r["status"] == "completed":
            assert r["tokens"] == clean_toks[r["rid"]], (
                f"rid={r['rid']} diverged from the clean run")
    assert "audit_failures" not in stats, stats["audit_failures"]
    assert stats["page_audit"]["ok"], stats["page_audit"]
    fired = stats["faults"]["fired"]
    assert fired["engine.page_alloc"] + fired["engine.step"] > 0, fired


def test_engine_page_audit_detects_corruption(heng):
    """The audit helper itself must catch double-ownership — a free-list
    duplicate flips ok=False with a named issue."""
    cfg, params, eng = heng
    assert eng.audit_pages()["ok"]
    eng._free_pages.append(eng._free_pages[0])
    a = eng.audit_pages()
    assert not a["ok"] and any("duplicate" in s for s in a["issues"]), a
    eng._free_pages.pop()
    assert eng.audit_pages()["ok"]
