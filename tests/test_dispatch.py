"""Unified kernel-dispatch layer: backend parity, padding, custom VJPs.

The fused interpret backend executes the *real* Pallas kernel bodies on CPU,
so these tests cover the code that serves on TPU — including the
pad-to-tile path for non-tile-aligned shapes (the raw kernels raise on
those) and the custom-VJP gradients the peft/qat training modes rely on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, init_quantized_linear
from repro.kernels import dispatch
from repro.kernels.dispatch import qmatmul

# deliberately NOT tile-aligned: M odd/small, N/K off the 128/256/512 grid
SHAPES = [(5, 96, 160), (33, 200, 96), (1, 130, 320)]


def _lords_setup(n, m, mode="frozen", seed=0, cd=jnp.float32):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (n, m)) * 0.02
    spec = QuantSpec(method="lords", block_size=32, rank=3, mode=mode,
                     compute_dtype=cd)
    return init_quantized_linear(key, n, m, spec, w=w, use_bias=True), spec


# ---------------------------------------------------------------------------
# forward parity: fused interpret == ref oracle == legacy dense
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mtok,n,m", SHAPES)
def test_lords_fused_interpret_matches_ref_nonaligned(mtok, n, m):
    params, spec = _lords_setup(n, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (mtok, m))
    y_ref = qmatmul(params, x, spec, n, m, backend="ref")
    y_int = qmatmul(params, x, spec, n, m, backend="interpret")
    y_dense = qmatmul(params, x, spec, n, m, backend="dense")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("mtok,n,m", SHAPES)
@pytest.mark.parametrize("method", ["blockwise", "qlora"])
def test_block_fused_interpret_matches_ref_nonaligned(mtok, n, m, method):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n, m)) * 0.02
    spec = QuantSpec(method=method, block_size=32, adapter_rank=4,
                     compute_dtype=jnp.float32)
    params = init_quantized_linear(key, n, m, spec, w=w)
    x = jax.random.normal(jax.random.PRNGKey(1), (mtok, m))
    y_ref = qmatmul(params, x, spec, n, m, backend="ref")
    y_int = qmatmul(params, x, spec, n, m, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_leading_batch_dims_and_bias():
    params, spec = _lords_setup(96, 160)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 160))
    y = qmatmul(params, x, spec, 96, 160, backend="interpret")
    assert y.shape == (2, 3, 96)
    y_flat = qmatmul(params, x.reshape(6, 160), spec, 96, 160,
                     backend="interpret")
    np.testing.assert_allclose(np.asarray(y.reshape(6, 96)),
                               np.asarray(y_flat), rtol=1e-6, atol=1e-6)


def test_qat_fused_forward_matches_dense():
    params, spec = _lords_setup(96, 160, mode="qat")
    x = jax.random.normal(jax.random.PRNGKey(3), (7, 160))
    y_dense = qmatmul(params, x, spec, 96, 160, backend="dense")
    y_int = qmatmul(params, x, spec, 96, 160, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_dense),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# gradient parity: custom-VJP fused path vs dequantize-then-einsum autodiff
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_peft_gradients_match_dense_path(backend):
    n, m = 96, 160
    params, spec = _lords_setup(n, m, mode="peft")
    x = jax.random.normal(jax.random.PRNGKey(4), (5, m))

    def loss(ba, bk):
        p = dict(params, b=ba[0], a=ba[1])
        return jnp.sum(qmatmul(p, x, spec, n, m, backend=bk) ** 2)

    g_dense = jax.grad(loss)((params["b"], params["a"]), "dense")
    g_fused = jax.grad(loss)((params["b"], params["a"]), backend)
    for gd, gf in zip(g_dense, g_fused):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gd),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_qat_ste_gradients_match_dense_path(backend):
    """STE cotangents (paper Eq. 4/5) through the fused forward must equal
    autodiff through fake_quant_ste + einsum on the dense path."""
    n, m = 96, 160
    params, spec = _lords_setup(n, m, mode="qat")
    x = jax.random.normal(jax.random.PRNGKey(5), (5, m))

    def loss(t, bk):
        p = dict(params, w=t[0], b=t[1], a=t[2])
        return jnp.sum(qmatmul(p, x, spec, n, m, backend=bk) ** 2)

    t0 = (params["w"], params["b"], params["a"])
    g_dense = jax.grad(loss)(t0, "dense")
    g_fused = jax.grad(loss)(t0, backend)
    for name, gd, gf in zip("wba", g_dense, g_fused):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gd),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"grad wrt {name}")


def test_gradient_flows_to_x_through_fused_path():
    n, m = 96, 160
    params, spec = _lords_setup(n, m, mode="peft")
    x = jax.random.normal(jax.random.PRNGKey(6), (5, m))
    f = lambda xx, bk: jnp.sum(qmatmul(params, xx, spec, n, m, backend=bk))
    gx_dense = jax.grad(f)(x, "dense")
    gx_fused = jax.grad(f)(x, "interpret")
    np.testing.assert_allclose(np.asarray(gx_fused), np.asarray(gx_dense),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# dispatch plumbing: vmapped experts, backend scope, autotune table
# ---------------------------------------------------------------------------


def test_vmapped_expert_stack_matches_per_expert():
    """The MoE path vmaps qmatmul over a stacked-expert param tree."""
    spec = QuantSpec(method="lords", block_size=32, rank=2,
                     compute_dtype=jnp.float32)
    e, n, m = 3, 64, 96
    keys = jax.random.split(jax.random.PRNGKey(7), e)
    stack = jax.vmap(lambda k: init_quantized_linear(k, n, m, spec))(keys)
    xd = jax.random.normal(jax.random.PRNGKey(8), (e, 16, m))
    y = jax.vmap(
        lambda p, xe: qmatmul(p, xe, spec, n, m, backend="interpret")
    )(stack, xd)
    for i in range(e):
        yi = qmatmul(jax.tree.map(lambda v: v[i], stack), xd[i], spec, n, m,
                     backend="ref")
        np.testing.assert_allclose(np.asarray(y[i]), np.asarray(yi),
                                   rtol=3e-5, atol=3e-5)


def test_backend_scope_and_env_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.delenv("REPRO_INTERPRET_KERNELS", raising=False)
    assert dispatch.default_backend() in ("ref", "pallas")
    with dispatch.backend_scope("dense"):
        assert dispatch.default_backend() == "dense"
        with dispatch.backend_scope(None):  # None inherits the outer scope
            assert dispatch.default_backend() == "dense"
    monkeypatch.setenv("REPRO_INTERPRET_KERNELS", "1")
    assert dispatch.default_backend() == "interpret"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    assert dispatch.default_backend() == "ref"
    with pytest.raises(ValueError):
        dispatch.backend_scope("nope").__enter__()


def test_autotune_registers_and_qmatmul_consults():
    n, m = 96, 160
    params, spec = _lords_setup(n, m)
    x = jax.random.normal(jax.random.PRNGKey(9), (5, m))
    tiles, timings = dispatch.autotune_qmatmul(
        params, x, spec, n, m, backend="interpret",
        candidates=[(8, 128, 256), (8, 128, 512)], iters=1)
    assert tiles in timings and len(timings) >= 1
    # registered under compute_dtype — the dtype the fused forward traces in
    assert dispatch.lookup_tiles("lords", 5, n, m, spec.codebook,
                                 spec.compute_dtype) == tiles
    assert dispatch.tile_for("lords", 5, n, m, spec.codebook,
                             spec.compute_dtype) == tiles
    # the registered tiling must produce the same numerics
    y = qmatmul(params, x, spec, n, m, backend="interpret")
    y_ref = qmatmul(params, x, spec, n, m, backend="ref")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_autotune_key_separates_block_sizes():
    """Tiles tuned for one block size must not be handed to a same-shaped
    layer with an incompatible block size (bk 512 vs bs 96 would raise)."""
    key = jax.random.PRNGKey(14)
    n, m = 128, 192
    w = jax.random.normal(key, (n, m)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(15), (8, m))
    s64 = QuantSpec(method="blockwise", block_size=64,
                    compute_dtype=jnp.float32)
    s96 = QuantSpec(method="blockwise", block_size=96,
                    compute_dtype=jnp.float32)
    p64 = init_quantized_linear(key, n, m, s64, w=w)
    p96 = init_quantized_linear(key, n, m, s96, w=w)
    dispatch.autotune_qmatmul(p64, x, s64, n, m, backend="interpret",
                              candidates=[(8, 128, 512)], iters=1)
    y = dispatch.qmatmul(p96, x, s96, n, m, backend="interpret")
    y_ref = dispatch.qmatmul(p96, x, s96, n, m, backend="ref")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_autotune_noop_for_dense_only_specs():
    """Specs with no fused path (blockwise QAT, AWQ-smoothed) must not crash
    or register noise-tuned tiles — qmatmul ignores tiles on the dense path."""
    key = jax.random.PRNGKey(12)
    w = jax.random.normal(key, (64, 128)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(13), (4, 128))
    spec = QuantSpec(method="blockwise", block_size=32, mode="qat",
                     compute_dtype=jnp.float32)
    params = init_quantized_linear(key, 64, 128, spec, w=w)
    assert dispatch.autotune_qmatmul(
        params, x, spec, 64, 128, backend="interpret") == (None, {})
    awq_params = dict(params, awq_s=jnp.ones((128,)))
    assert dispatch.autotune_qmatmul(
        awq_params, x, spec, 64, 128, backend="interpret") == (None, {})


def test_ref_backend_equals_legacy_dense_for_all_methods():
    key = jax.random.PRNGKey(10)
    w = jax.random.normal(key, (64, 128)) * 0.02
    x = jax.random.normal(jax.random.PRNGKey(11), (4, 128))
    for method in ("lords", "blockwise", "qlora", "loftq", "qpissa", "none"):
        spec = QuantSpec(method=method, block_size=32, rank=2, adapter_rank=4,
                         compute_dtype=jnp.float32)
        params = init_quantized_linear(key, 64, 128, spec, w=w)
        y_ref = qmatmul(params, x, spec, 64, 128, backend="ref")
        y_dense = qmatmul(params, x, spec, 64, 128, backend="dense")
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_dense),
                                   rtol=3e-5, atol=3e-5, err_msg=method)
