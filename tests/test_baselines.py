"""Every baseline the paper compares against must work & behave as published."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantSpec, apply_quantized_linear, init_quantized_linear
from repro.core import baselines, metrics, quantize
from repro.data import synthetic_activations


@pytest.fixture(scope="module")
def wx(key):
    w = jax.random.normal(key, (96, 256)) * 0.02
    x = jnp.asarray(synthetic_activations(128, 256, seed=1))
    return w, x


@pytest.mark.parametrize("method", ["blockwise", "qlora", "loftq", "qpissa"])
def test_baseline_linear_forward(method, wx, key):
    w, x = wx
    spec = QuantSpec(method=method, block_size=64, adapter_rank=8,
                     loftq_iters=2)
    params = init_quantized_linear(key, 96, 256, spec, w=w)
    y = apply_quantized_linear(params, x[:4], spec, 96, 256)
    assert y.shape == (4, 96)
    assert np.isfinite(np.asarray(y)).all()


def test_qlora_starts_at_base_model(wx, key):
    """LoRA B=0 init: the adapter contributes nothing initially."""
    w, x = wx
    spec = QuantSpec(method="qlora", block_size=64, adapter_rank=8)
    params = init_quantized_linear(key, 96, 256, spec, w=w)
    y_full = apply_quantized_linear(params, x[:4], spec, 96, 256)
    spec_b = QuantSpec(method="blockwise", block_size=64)
    params_b = {"q": params["q"], "s_blk": params["s_blk"]}
    y_base = apply_quantized_linear(params_b, x[:4], spec_b, 96, 256)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_base),
                               rtol=1e-4, atol=1e-5)


def test_loftq_reduces_quant_error(wx, key):
    """LoftQ's whole point: adapter absorbs quantization residual."""
    w, x = wx
    qb, sb = quantize.quantize_blockwise(w, 64, "nf4")
    w_nf4 = quantize.dequantize_blockwise(qb, sb, 64, "nf4")
    q, s_blk, lb, la = baselines.loftq_init(w, 64, "nf4", r=8, iters=4)
    w_loftq = quantize.dequantize_blockwise(q, s_blk, 64, "nf4") + lb @ la
    ratio = float(metrics.error_reduction_ratio(w, w_loftq, w_nf4))
    assert ratio > 0.02, f"LoftQ error-reduction ratio {ratio} too small"


def test_qpissa_reduces_quant_error(wx, key):
    w, x = wx
    qb, sb = quantize.quantize_blockwise(w, 64, "nf4")
    w_nf4 = quantize.dequantize_blockwise(qb, sb, 64, "nf4")
    q, s_blk, lb, la = baselines.qpissa_init(w, 64, "nf4", r=8)
    w_q = quantize.dequantize_blockwise(q, s_blk, 64, "nf4") + lb @ la
    assert float(metrics.error_reduction_ratio(w, w_q, w_nf4)) > 0.02


def test_gptq_beats_blockwise_on_calibration_mse(wx):
    w, x = wx
    qg, sg = baselines.gptq_quantize(w, x, 64, "nf4")
    w_g = quantize.dequantize_blockwise(qg, sg, 64, "nf4")
    qb, sb = quantize.quantize_blockwise(w, 64, "nf4")
    w_b = quantize.dequantize_blockwise(qb, sb, 64, "nf4")
    y = x @ w.T
    e_g = float(jnp.mean((x @ w_g.T - y) ** 2))
    e_b = float(jnp.mean((x @ w_b.T - y) ** 2))
    assert e_g < e_b


def test_awq_protects_outlier_channels(wx):
    """With outlier-heavy activations AWQ must pick alpha > 0 and win."""
    w, x = wx
    qa, sa, sc = baselines.awq_quantize(w, x, 64, "nf4", n_grid=12)
    w_a = quantize.dequantize_blockwise(qa, sa, 64, "nf4") / sc[None, :]
    qb, sb = quantize.quantize_blockwise(w, 64, "nf4")
    w_b = quantize.dequantize_blockwise(qb, sb, 64, "nf4")
    y = x @ w.T
    e_a = float(jnp.mean((x @ w_a.T - y) ** 2))
    e_b = float(jnp.mean((x @ w_b.T - y) ** 2))
    assert e_a <= e_b * 1.0001
    assert not np.allclose(np.asarray(sc), 1.0)  # non-trivial smoothing


# ---------------------------------------------------------------------------
# SmoothRot: channel smoothing + randomized Hadamard rotation
# ---------------------------------------------------------------------------


def test_hadamard_transform_is_orthonormal_involution(key):
    v = jax.random.normal(key, (5, 64))
    t = baselines.hadamard_transform(v)
    np.testing.assert_allclose(
        np.asarray(baselines.hadamard_transform(t)), np.asarray(v),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(t), axis=-1),
        np.linalg.norm(np.asarray(v), axis=-1), rtol=1e-5)


def test_hadamard_transform_non_pow2_uses_block_groups(key):
    """m = 96 -> block-diagonal groups of 32: still an isometric involution."""
    v = jax.random.normal(key, (3, 96))
    t = baselines.hadamard_transform(v)
    assert not np.allclose(np.asarray(t), np.asarray(v))
    np.testing.assert_allclose(
        np.asarray(baselines.hadamard_transform(t)), np.asarray(v),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(t), axis=-1),
        np.linalg.norm(np.asarray(v), axis=-1), rtol=1e-5)


def test_randomized_hadamard_inverts_with_signs(key):
    signs = baselines.hadamard_signs(64, seed=3)
    assert set(np.unique(np.asarray(signs))) <= {-1.0, 1.0}
    v = jax.random.normal(key, (4, 64))
    t = baselines.hadamard_transform(v, signs)
    back = baselines.hadamard_transform(t) * signs
    np.testing.assert_allclose(np.asarray(back), np.asarray(v),
                               rtol=1e-5, atol=1e-6)


def test_smoothrot_basis_change_preserves_forward(wx):
    """Before quantization, x' W'^T == x W^T exactly (orthogonal + d² = 1)."""
    w, x = wx
    c = baselines.smooth_scales(w, x)
    signs = baselines.hadamard_signs(w.shape[1], seed=0)
    w_rot = baselines.hadamard_transform(w * c[None, :], signs)
    x_rot = baselines.hadamard_transform(x / c[None, :], signs)
    np.testing.assert_allclose(np.asarray(x_rot @ w_rot.T),
                               np.asarray(x @ w.T), rtol=1e-4, atol=1e-5)


def test_smoothrot_beats_blockwise_on_calibration_mse(wx):
    w, x = wx
    q, s_blk, c, signs = baselines.smoothrot_quantize(w, x, 64, "nf4")
    w_sr = baselines.smoothrot_dequantize(q, s_blk, c, signs, 64, "nf4")
    qb, sb = quantize.quantize_blockwise(w, 64, "nf4")
    w_b = quantize.dequantize_blockwise(qb, sb, 64, "nf4")
    y = x @ w.T
    e_sr = float(jnp.mean((x @ w_sr.T - y) ** 2))
    e_b = float(jnp.mean((x @ w_b.T - y) ** 2))
    assert e_sr < e_b
