"""Decode fast path: on-device generation loop parity, quantized-KV
numerics, decode-GEMV kernel backend parity, ragged positions, and
autotune-table persistence."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import QuantSpec, init_quantized_linear
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import qmatmul
from repro.kernels.lords_decode import lords_decode_pallas
from repro.models import attention as attn
from repro.models import split_tree


def _cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-30))


# ---------------------------------------------------------------------------
# on-device generation loop vs legacy per-token host loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_generate_scan_matches_host_loop(kv):
    """Token-for-token parity: the single jitted lax.scan generation loop
    must reproduce the eager per-token Python loop exactly (same params,
    prompts, and greedy sampling; both loops share the KV-cache format)."""
    from repro.launch.serve import serve_batch

    cfg = smoke_variant(get_config("llama3-8b")).with_(num_layers=2)
    kw = dict(batch=2, prompt_len=8, gen=6, seed=3, kv_cache=kv)
    out_host = serve_batch(cfg, loop="host", **kw)
    out_scan = serve_batch(cfg, loop="scan", **kw)
    assert out_scan["tokens"].shape == (2, 6)
    np.testing.assert_array_equal(out_scan["tokens"], out_host["tokens"])


def test_generate_temperature_sampling_shape_and_determinism():
    from repro.launch.serve import serve_batch

    cfg = smoke_variant(get_config("llama3-8b")).with_(num_layers=2)
    kw = dict(batch=2, prompt_len=8, gen=5, seed=1, temperature=0.8)
    out_a = serve_batch(cfg, **kw)
    out_b = serve_batch(cfg, **kw)
    assert out_a["tokens"].shape == (2, 5)
    # same PRNG seed => same sampled continuation
    np.testing.assert_array_equal(out_a["tokens"], out_b["tokens"])


# ---------------------------------------------------------------------------
# quantized KV cache numerics (int8 + per-head scales vs bf16 cache)
# ---------------------------------------------------------------------------


def _attn_setup(arch, kv, seed=0):
    cfg = smoke_variant(get_config(arch)).with_(kv_cache_dtype=kv)
    key = jax.random.PRNGKey(seed)
    init = attn.mla_init if cfg.attn_kind == "mla" else attn.gqa_init
    cache_init_fn = (attn.mla_cache_init if cfg.attn_kind == "mla"
                     else attn.gqa_cache_init)
    params, _ = split_tree(init(key, cfg, cfg.quant))
    cache, _ = split_tree(cache_init_fn(cfg, 2, 12))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    return cfg, params, cache, x


@pytest.mark.parametrize("arch", ["llama3-8b", "minicpm3-4b"])
def test_quantized_kv_decode_cosine(arch):
    """gqa/mla decode through the int8 cache must track the bf16-cache
    output to cosine > 0.999 (prefill fill + one decode step)."""
    outs = {}
    for kv in ("bf16", "int8"):
        cfg, params, cache, x = _attn_setup(arch, kv)
        pre = attn.mla_prefill if cfg.attn_kind == "mla" else attn.gqa_prefill
        dec = attn.mla_decode if cfg.attn_kind == "mla" else attn.gqa_decode
        positions = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None],
                                     (2, 8))
        _, cache = pre(params, x, cfg, cfg.quant, positions, cache)
        xd = x[:, :1]
        pos = jnp.full((2,), 8, jnp.int32)
        y, _ = dec(params, xd, cfg, cfg.quant, cache, pos)
        outs[kv] = np.asarray(y, np.float32)
    assert _cos(outs["bf16"], outs["int8"]) > 0.999


def test_int8_cache_structure_and_roundtrip():
    from repro.models.common import kv_dequantize, kv_quantize

    cfg = smoke_variant(get_config("llama3-8b")).with_(kv_cache_dtype="int8")
    cache, _ = split_tree(attn.gqa_cache_init(cfg, 2, 6))
    assert cache["k"].dtype == jnp.int8 and cache["v"].dtype == jnp.int8
    assert cache["k_scale"].shape == cache["k"].shape[:3]
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 4, 16))
    codes, scale = kv_quantize(x)
    back = kv_dequantize(codes, scale, dtype=jnp.float32)
    assert _cos(x, back) > 0.9999  # per-vector int8: ~0.23% RMS error


# ---------------------------------------------------------------------------
# decode GEMV kernel: backend parity on non-aligned shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mtok,n,k", [(1, 130, 320), (3, 96, 160),
                                      (8, 200, 96)])
def test_decode_kernel_dispatch_parity_nonaligned(mtok, n, k):
    """M <= 8 routes to lords_decode_pallas inside qmatmul; the padded
    interpret run must match the ref oracle on off-tile shapes."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n, k)) * 0.02
    spec = QuantSpec(method="lords", block_size=32, rank=3,
                     compute_dtype=jnp.float32)
    params = init_quantized_linear(key, n, k, spec, w=w)
    x = jax.random.normal(jax.random.PRNGKey(1), (mtok, k))
    y_ref = qmatmul(params, x, spec, n, k, backend="ref")
    y_int = qmatmul(params, x, spec, n, k, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)


def test_decode_kernel_direct_and_residual():
    from repro.core import quantize, scaling

    m, n, k = 4, 128, 256
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (n, k)) * 0.02
    b, a = scaling.lords_init_from_weight(w, 128, rank=4)
    s = scaling.scale_matrix(b, a)
    qp = quantize.pack_codes(quantize.quantize_codes(w, s, "nf4"), "nf4")
    y_ref = ref.lords_matmul_ref(x, qp, b, a, "nf4")
    y = lords_decode_pallas(x, qp, b, a, "nf4", bn=64, bk=128,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)
    res = jax.random.normal(jax.random.PRNGKey(3), (m, n))
    y_res = lords_decode_pallas(x, qp, b, a, "nf4", bn=64, bk=128,
                                interpret=True, residual=res)
    np.testing.assert_allclose(np.asarray(y_res), np.asarray(y_ref + res),
                               rtol=3e-5, atol=3e-5)
    with pytest.raises(ValueError):  # prefill-shaped M belongs elsewhere
        lords_decode_pallas(jnp.zeros((16, k)), qp, b, a, "nf4",
                            interpret=True)


# ---------------------------------------------------------------------------
# ragged per-sequence decode positions
# ---------------------------------------------------------------------------


def test_gqa_decode_ragged_positions_match_per_sequence():
    """A ragged batch (pos = [3, 6]) must equal running each sequence alone
    — the old pos[0] scatter silently wrote every row at position 3."""
    cfg, params, cache, x = _attn_setup("llama3-8b", "bf16", seed=5)
    positions = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    _, cache = attn.gqa_prefill(params, x, cfg, cfg.quant, positions, cache)
    xd = jax.random.normal(jax.random.PRNGKey(9), (2, 1, cfg.d_model),
                           jnp.float32).astype(jnp.bfloat16)
    pos = jnp.array([3, 6], jnp.int32)
    y, new_cache = attn.gqa_decode(params, xd, cfg, cfg.quant, cache, pos)
    for i in range(2):
        ci = jax.tree.map(lambda v: v[i : i + 1], cache)
        yi, ci2 = attn.gqa_decode(params, xd[i : i + 1], cfg, cfg.quant, ci,
                                  pos[i : i + 1])
        np.testing.assert_allclose(np.asarray(y[i], np.float32),
                                   np.asarray(yi[0], np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_array_equal(np.asarray(new_cache["k"][i]),
                                      np.asarray(ci2["k"][0]))


# ---------------------------------------------------------------------------
# autotune-table persistence (REPRO_AUTOTUNE_CACHE)
# ---------------------------------------------------------------------------


def test_autotune_table_persists_across_processes(tmp_path, monkeypatch):
    path = str(tmp_path / "tiles.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    n, m = 96, 160
    key = jax.random.PRNGKey(7)
    spec = QuantSpec(method="lords", block_size=32, rank=3,
                     compute_dtype=jnp.float32)
    params = init_quantized_linear(key, n, m, spec,
                                   w=jax.random.normal(key, (n, m)) * 0.02)
    x = jax.random.normal(jax.random.PRNGKey(8), (5, m))
    tiles, _ = dispatch.autotune_qmatmul(
        params, x, spec, n, m, backend="interpret",
        candidates=[(8, 128, 256)], iters=1)
    assert tiles == (8, 128, 256) and os.path.exists(path)
    akey = dispatch.autotune_key("lords", 5, n, m, spec.codebook,
                                 spec.compute_dtype)
    # simulate a fresh process: drop the entry, reload from disk
    dispatch._AUTOTUNE.pop(akey)
    assert dispatch.lookup_tiles("lords", 5, n, m, spec.codebook,
                                 spec.compute_dtype) is None
    assert dispatch.load_autotune_table() >= 1
    assert dispatch.lookup_tiles("lords", 5, n, m, spec.codebook,
                                 spec.compute_dtype) == tiles
    dispatch._AUTOTUNE.pop(akey, None)  # don't leak tuned tiles to others


def test_autotune_load_ignores_corrupt_cache_with_warning(tmp_path,
                                                          monkeypatch):
    path = tmp_path / "bad.json"
    path.write_text("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert dispatch.load_autotune_table() == 0


def test_autotune_load_skips_malformed_entries_with_warning(tmp_path):
    path = tmp_path / "mixed.json"
    good = {"key": ["lords", 5, 96, 160, "nf4", "float32", None],
            "tiles": [8, 128, 256]}
    path.write_text(json.dumps({"version": 1, "entries": [
        good,
        {"key": ["x"], "tiles": [8, 128]},        # wrong tile arity
        {"key": ["y"], "tiles": ["a", "b", "c"]},  # non-int tiles
        {"tiles": [1, 2, 3]},                      # missing key
    ]}))
    with pytest.warns(RuntimeWarning, match="3 malformed"):
        assert dispatch.load_autotune_table(str(path)) == 1
    akey = tuple(good["key"])
    assert dispatch._AUTOTUNE.get(akey) == (8, 128, 256)
    dispatch._AUTOTUNE.pop(akey, None)  # don't leak to other tests


def test_autotune_save_then_load_roundtrip_atomic(tmp_path):
    """save_autotune_table publishes via tmp+rename: the target is either
    absent or a complete, loadable table."""
    akey = ("lords", 5, 64, 96, "nf4", "float32", None)
    dispatch._AUTOTUNE[akey] = (8, 64, 128)
    try:
        path = str(tmp_path / "tiles.json")
        assert dispatch.save_autotune_table(path) == path
        assert not [p for p in os.listdir(tmp_path) if ".tmp" in p]
        dispatch._AUTOTUNE.pop(akey)
        assert dispatch.load_autotune_table(path) >= 1
        assert dispatch._AUTOTUNE[akey] == (8, 64, 128)
    finally:
        dispatch._AUTOTUNE.pop(akey, None)
