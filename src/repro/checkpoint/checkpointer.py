"""Fault-tolerant checkpointing with elastic-reshard restore.

Design (multi-host-aware, CPU-testable):
  * atomic: write to ``step_<N>.tmp/``, fsync, rename to ``step_<N>/`` and
    update ``MANIFEST.json`` last — a crash mid-write never corrupts the
    latest checkpoint; restore always reads the manifest.
  * content: params / optimizer state / data-pipeline step / RNG key, stored
    as raw ``.npy`` per leaf + a msgpack-free JSON tree spec (no pickle).
  * sharded save: a leaf that lives sharded on a mesh (e.g. packed int
    codes row-sharded over 'model' while the LoRDS B/A factors replicate)
    is written as one ``.npy`` *per distinct shard* — no host-side
    all-gather — and the step's ``spec.json`` manifest records each leaf's
    global shape, the shard index windows, and the ``PartitionSpec`` it was
    saved under.  Each host writes only the shards it owns
    (``process_index`` prefix); in this single-process container that
    degenerates to one writer, but the layout and addressing logic are the
    multi-host ones.
  * elastic restore: checkpoints store *logical* shapes; ``restore`` accepts
    any target sharding (a different mesh / chip count) and lets
    jax.device_put reshard — scale-up/scale-down restarts.  Restoring a
    sharded save without target shardings reassembles full arrays.
  * retention: keep the newest ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

from repro.distributed.fault_tolerance import retry_on_transient
from repro.robustness import NO_FAULTS, InjectedFault

__all__ = ["Checkpointer"]

_SEP = "__"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
        if hasattr(tree, "_fields"):  # NamedTuple marker
            out[f"{prefix}{_SEP}namedtuple"] = type(tree).__name__
    elif tree is None:
        out[prefix.rstrip(_SEP) + f"{_SEP}none"] = True
    else:
        out[prefix.rstrip(_SEP)] = tree
    return out


def _shard_entries(leaf):
    """Distinct (index-window, host array) pairs for a sharded jax.Array.

    Shards replicated across mesh axes repeat the same index window on
    several devices — only the first copy is written.  Windows come back as
    ``[[start, stop], ...]`` per dim (JSON-friendly).
    """
    seen, out = set(), []
    shape = leaf.shape
    for sh in leaf.addressable_shards:
        idx = tuple(
            (0 if s.start is None else int(s.start),
             dim if s.stop is None else int(s.stop))
            for s, dim in zip(sh.index, shape))
        if idx in seen:
            continue
        seen.add(idx)
        out.append(([list(w) for w in idx], np.asarray(sh.data)))
    return out


def _np_dtype(name: str) -> np.dtype:
    """np.dtype from its saved string name, including the ml_dtypes extras
    (bfloat16 & friends) numpy itself cannot look up by name."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _is_sharded(leaf) -> bool:
    return (isinstance(leaf, jax.Array)
            and len(leaf.sharding.device_set) > 1
            and not leaf.is_fully_replicated)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3,
                 io_retries: int = 2, io_backoff: float = 0.05,
                 io_jitter: float = 0.0, faults=NO_FAULTS):
        self.dir = directory
        self.keep = keep
        self.io_retries = io_retries
        self.io_backoff = io_backoff
        # decorrelated-jitter fraction for retry sleeps: many hosts saving
        # shards to one filesystem must not retry in lockstep
        self.io_jitter = io_jitter
        # chaos hook: ``ckpt.save_crash`` is consulted once per leaf write,
        # so tests can kill a save at any point mid-step and assert the
        # previous checkpoint stays restorable (atomicity contract).
        self.faults = faults
        os.makedirs(directory, exist_ok=True)

    def _io(self, fn):
        """Every file write/read goes through bounded retry-with-backoff:
        on networked filesystems (the real deployment target) transient
        ``OSError``s are routine and must not kill a training run holding
        hours of optimizer state.  Permanent failures still raise after
        ``io_retries`` attempts."""
        return retry_on_transient(fn, retries=self.io_retries,
                                  backoff=self.io_backoff,
                                  exceptions=(OSError,),
                                  jitter=self.io_jitter)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: dict):
        """state: an arbitrary pytree dict (params/opt/data_step/rng...)."""
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        leaves, treedef = jax.tree_util.tree_flatten(state)
        proc = jax.process_index()
        entries = []
        for i, leaf in enumerate(leaves):
            if self.faults.fires("ckpt.save_crash"):
                raise InjectedFault(
                    f"killed mid checkpoint save (step {step}, leaf {i})")
            if _is_sharded(leaf):
                files, indices = [], []
                for j, (idx, data) in enumerate(_shard_entries(leaf)):
                    name = f"leaf_{i:05d}_p{proc}_s{j}.npy"
                    self._io(lambda: np.save(os.path.join(tmp, name), data))
                    files.append(name)
                    indices.append(idx)
                entries.append({
                    "files": files,
                    "indices": indices,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "pspec": str(leaf.sharding.spec),
                })
            else:
                name = f"leaf_{i:05d}_p{proc}.npy"
                host = np.asarray(jax.device_get(leaf))
                self._io(lambda: np.save(os.path.join(tmp, name), host))
                entries.append({"files": [name], "indices": None,
                                "dtype": str(host.dtype)})
        spec = {
            "version": 2,
            "treedef": str(treedef),
            "leaves": entries,
            "step": step,
            "num_leaves": len(entries),
        }
        def write_spec():
            with open(os.path.join(tmp, "spec.json"), "w") as f:
                json.dump(spec, f)

        self._io(write_spec)
        self._io(lambda: os.replace(tmp, final))  # atomic on POSIX
        self._write_manifest(step)
        self._gc()

    def _write_manifest(self, step: int):
        man = os.path.join(self.dir, "MANIFEST.json")
        tmp = man + ".tmp"
        steps = sorted(set(self.all_steps() + [step]))

        def write_man():
            with open(tmp, "w") as f:
                json.dump({"steps": steps, "latest": max(steps)}, f)
            os.replace(tmp, man)

        self._io(write_man)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        man = os.path.join(self.dir, "MANIFEST.json")
        live = set(self.all_steps())
        if os.path.exists(man):
            try:
                with open(man) as f:
                    data = json.load(f)
                # the manifest may reference a GC'd step after keep-pruning
                cands = [s for s in data.get("steps", []) if s in live]
            except (ValueError, OSError, AttributeError):
                # torn/corrupt manifest: the step dirs themselves are the
                # source of truth (each was atomically renamed into place)
                cands = sorted(live)
            return max(cands) if cands else None
        steps = sorted(live)
        return steps[-1] if steps else None

    def _load_leaf(self, path: str, entry: dict) -> np.ndarray:
        # np.load round-trips the ml_dtypes extras (bfloat16, ...) as raw
        # void records; the manifest dtype views them back bit-exactly
        want = _np_dtype(entry["dtype"]) if entry.get("dtype") else None
        if entry.get("indices") is None:
            arr = self._io(
                lambda: np.load(os.path.join(path, entry["files"][0])))
            if want is not None and arr.dtype != want:
                arr = arr.view(want)
            return arr
        out = np.empty(tuple(entry["shape"]), dtype=want)
        for name, idx in zip(entry["files"], entry["indices"]):
            window = tuple(slice(a, b) for a, b in idx)
            shard = self._io(lambda: np.load(os.path.join(path, name)))
            out[window] = shard.view(want) if shard.dtype != want else shard
        return out

    def restore(self, example_state: dict, step: int | None = None,
                shardings=None) -> dict | None:
        """Restore into the structure of ``example_state``.

        ``shardings``: optional matching tree of jax.sharding.Sharding — the
        elastic-reshard path (device_put onto a *different* mesh than the one
        that saved, or straight back onto the saving layout for bit-exact
        sharded resume).  Returns None when no checkpoint exists.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "spec.json")) as f:
            spec = json.load(f)
        leaves, treedef = jax.tree_util.tree_flatten(example_state)
        if len(leaves) != spec["num_leaves"]:
            raise ValueError(
                f"checkpoint has {spec['num_leaves']} leaves; target structure "
                f"has {len(leaves)} — incompatible state")
        if spec.get("version", 1) >= 2:
            loaded = [self._load_leaf(path, e) for e in spec["leaves"]]
        else:  # v1 layout: one whole-array file per leaf
            loaded = [np.load(os.path.join(path, n)) for n in spec["names"]]
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
            loaded = [jax.device_put(l, s)
                      for l, s in zip(loaded, shard_leaves)]
        restored = jax.tree_util.tree_unflatten(treedef, loaded)
        return restored

    def saved_pspecs(self, step: int | None = None) -> list | None:
        """The PartitionSpec strings recorded at save time (one per leaf;
        None for unsharded leaves) — the manifest trail that lets operators
        audit how a checkpoint was laid out without loading it."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "spec.json")) as f:
            spec = json.load(f)
        if spec.get("version", 1) < 2:
            return [None] * spec["num_leaves"]
        return [e.get("pspec") for e in spec["leaves"]]
