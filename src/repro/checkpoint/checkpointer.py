"""Fault-tolerant checkpointing with elastic-reshard restore.

Design (multi-host-aware, CPU-testable):
  * atomic: write to ``step_<N>.tmp/``, fsync, rename to ``step_<N>/`` and
    update ``MANIFEST.json`` last — a crash mid-write never corrupts the
    latest checkpoint; restore always reads the manifest.
  * content: params / optimizer state / data-pipeline step / RNG key, stored
    as raw ``.npy`` per leaf + a msgpack-free JSON tree spec (no pickle).
  * sharded save: each host writes only the leaf-shards it owns
    (``process_index`` prefix); restore concatenates lazily.  In this
    single-process container that degenerates to one writer, but the layout
    and addressing logic are the multi-host ones.
  * elastic restore: checkpoints store *logical* shapes; ``restore`` accepts
    any target sharding (a different mesh / chip count) and lets jax.device_put
    reshard — scale-up/scale-down restarts.
  * retention: keep the newest ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["Checkpointer"]

_SEP = "__"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}{_SEP}"))
        if hasattr(tree, "_fields"):  # NamedTuple marker
            out[f"{prefix}{_SEP}namedtuple"] = type(tree).__name__
    elif tree is None:
        out[prefix.rstrip(_SEP) + f"{_SEP}none"] = True
    else:
        out[prefix.rstrip(_SEP)] = tree
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: dict):
        """state: an arbitrary pytree dict (params/opt/data_step/rng...)."""
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        leaves, treedef = jax.tree_util.tree_flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        names = []
        for i, leaf in enumerate(host_leaves):
            name = f"leaf_{i:05d}_p{jax.process_index()}.npy"
            np.save(os.path.join(tmp, name), leaf)
            names.append(name)
        spec = {
            "treedef": str(treedef),
            "names": names,
            "step": step,
            "num_leaves": len(names),
        }
        with open(os.path.join(tmp, "spec.json"), "w") as f:
            json.dump(spec, f)
        os.replace(tmp, final)  # atomic on POSIX
        self._write_manifest(step)
        self._gc()

    def _write_manifest(self, step: int):
        man = os.path.join(self.dir, "MANIFEST.json")
        tmp = man + ".tmp"
        steps = sorted(set(self.all_steps() + [step]))
        with open(tmp, "w") as f:
            json.dump({"steps": steps, "latest": max(steps)}, f)
        os.replace(tmp, man)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        man = os.path.join(self.dir, "MANIFEST.json")
        if os.path.exists(man):
            with open(man) as f:
                data = json.load(f)
            # the manifest may reference a GC'd step after keep-pruning
            live = set(self.all_steps())
            cands = [s for s in data.get("steps", []) if s in live]
            return max(cands) if cands else None
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, example_state: dict, step: int | None = None,
                shardings=None) -> dict | None:
        """Restore into the structure of ``example_state``.

        ``shardings``: optional matching tree of jax.sharding.Sharding — the
        elastic-reshard path (device_put onto a *different* mesh than the one
        that saved).  Returns None when no checkpoint exists.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "spec.json")) as f:
            spec = json.load(f)
        leaves, treedef = jax.tree_util.tree_flatten(example_state)
        if len(leaves) != spec["num_leaves"]:
            raise ValueError(
                f"checkpoint has {spec['num_leaves']} leaves; target structure "
                f"has {len(leaves)} — incompatible state")
        loaded = [np.load(os.path.join(path, n)) for n in spec["names"]]
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
            loaded = [jax.device_put(l, s)
                      for l, s in zip(loaded, shard_leaves)]
        restored = jax.tree_util.tree_unflatten(treedef, loaded)
        return restored
