"""repro.checkpoint — atomic, elastic-reshard checkpointing."""
from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
