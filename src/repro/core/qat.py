"""Quantization-aware training: fake quantization with straight-through grads.

Paper §3.3:  ``Ŵ = ROUND(W ⊘ (BA)) ⊙ (BA)`` with STE gradients

    ∇_W L ≈ ∂L/∂Ŵ                      (Eq. 4)
    ∇_S L ≈ ∂L/∂Ŵ ⊙ (Q − W ⊘ S)       (Eq. 5), S = BA

``ste_cotangents`` is the single source of the Eq. 4/5 rule: the
``fake_quant_ste`` custom_vjp (dense path — chain rule through ``S = B @ A``
left to autodiff by computing S outside the boundary), the fused-backward
ref oracle (:func:`repro.kernels.ref.lords_grads_ref`), and the Pallas grad
kernel (:mod:`repro.kernels.lords_grad`, which applies the same terms
tile-by-tile) all implement it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import lut
from repro.core.quantize import quantize_codes
from repro.core.scaling import SCALE_EPS

__all__ = ["fake_quant_ste", "ste_cotangents"]


def ste_cotangents(dw_hat, resid):
    """Paper Eq. 4/5 from the weight-space cotangent ``∂L/∂Ŵ``.

    Returns ``(∇W, ∇S) = (∂L/∂Ŵ, ∂L/∂Ŵ ⊙ (Q − W⊘S))`` — ``resid`` is the
    fake-quant residual Q − W ⊘ S.  Callers apply their own clamp mask /
    dtype casts; keeping the rule here means the dense STE path, the ref
    backward oracle, and the fused grad kernel can never drift apart.
    """
    return dw_hat, dw_hat * resid


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def fake_quant_ste(codebook_name: str, w: jnp.ndarray, s: jnp.ndarray):
    """Differentiable fake quantization: returns ROUND(w ⊘ s) ⊙ s."""
    q, _ = _round_terms(codebook_name, w, s)
    return (q * s).astype(w.dtype)


def _round_terms(codebook_name, w, s):
    safe = jnp.where(jnp.abs(s) < SCALE_EPS, SCALE_EPS, s)
    codes = quantize_codes(w, s, codebook_name)
    levels = lut.codebook(codebook_name).astype(jnp.float32)
    q = jnp.take(levels, codes.astype(jnp.int32), axis=0).astype(s.dtype)
    resid = q - (w / safe).astype(s.dtype)  # Q - W ⊘ S, for Eq. 5
    return q, resid


def _fwd(codebook_name, w, s):
    q, resid = _round_terms(codebook_name, w, s)
    protos = (jnp.zeros((), w.dtype), jnp.zeros((), s.dtype))
    return (q * s).astype(w.dtype), (resid, protos)


def _bwd(codebook_name, residuals, g):
    resid, (w_proto, s_proto) = residuals
    dw, ds = ste_cotangents(g, resid)
    return dw.astype(w_proto.dtype), ds.astype(s_proto.dtype)


fake_quant_ste.defvjp(_fwd, _bwd)
