"""Quantization-aware training: fake quantization with straight-through grads.

Paper §3.3:  ``Ŵ = ROUND(W ⊘ (BA)) ⊙ (BA)`` with STE gradients

    ∇_W L ≈ ∂L/∂Ŵ                      (Eq. 4)
    ∇_S L ≈ ∂L/∂Ŵ ⊙ (Q − W ⊘ S)       (Eq. 5), S = BA

The custom_vjp below exposes exactly these two cotangents; the chain rule
through ``S = B @ A`` (∇_B = ∇_S Aᵀ, ∇_A = Bᵀ ∇_S) is left to JAX autodiff by
computing S outside the custom_vjp boundary.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import lut
from repro.core.quantize import quantize_codes
from repro.core.scaling import SCALE_EPS

__all__ = ["fake_quant_ste"]


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def fake_quant_ste(codebook_name: str, w: jnp.ndarray, s: jnp.ndarray):
    """Differentiable fake quantization: returns ROUND(w ⊘ s) ⊙ s."""
    q, _ = _round_terms(codebook_name, w, s)
    return (q * s).astype(w.dtype)


def _round_terms(codebook_name, w, s):
    safe = jnp.where(jnp.abs(s) < SCALE_EPS, SCALE_EPS, s)
    codes = quantize_codes(w, s, codebook_name)
    levels = lut.codebook(codebook_name).astype(jnp.float32)
    q = jnp.take(levels, codes.astype(jnp.int32), axis=0).astype(s.dtype)
    resid = q - (w / safe).astype(s.dtype)  # Q - W ⊘ S, for Eq. 5
    return q, resid


def _fwd(codebook_name, w, s):
    q, resid = _round_terms(codebook_name, w, s)
    protos = (jnp.zeros((), w.dtype), jnp.zeros((), s.dtype))
    return (q * s).astype(w.dtype), (resid, protos)


def _bwd(codebook_name, residuals, g):
    resid, (w_proto, s_proto) = residuals
    dw = g.astype(w_proto.dtype)            # Eq. 4 (STE identity)
    ds = (g * resid).astype(s_proto.dtype)  # Eq. 5
    return dw, ds


fake_quant_ste.defvjp(_fwd, _bwd)
