"""Baselines the paper compares against, implemented in JAX.

* block-wise NF4/INT4 (bitsandbytes semantics)          — Tables 1, 4
* QLoRA: block-wise quant + additive LoRA adapter        — Table 5
* LoftQ: alternating residual-SVD adapter initialization — Tables 1, 3, 5, 8
* QPiSSA: principal-components-to-adapter initialization — Tables 8, 9
* GPTQ: Hessian-based column-wise quantization           — Table 1
* AWQ: activation-aware per-channel scale search         — Table 1
* SmoothRot: channel-wise smoothing + Hadamard rotation  — outlier front end

GPTQ/AWQ/SmoothRot consume calibration activations (`repro.data.calibration`).

SmoothRot (Czakó et al., 2025) composes two quantization-friendliness
transforms on the input dimension: SmoothQuant-style per-channel scales
``c_j = E|x_j|^α / max_i|w_ij|^{1-α}`` migrate activation outliers into the
weight, then a (sign-randomized) normalized Hadamard rotation spreads the
remaining per-channel energy across all channels.  Both are exactly
invertible, so ``smoothrot_dequantize`` returns Ŵ in the *original* basis
and callers need no activation-side changes.  The channel-scale half also
folds into the LoRDS S = BA init for free (``repro.core.scaling
.lords_init_from_weight(channel_scale=...)``) since S is element-wise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import scaling
from repro.core.quantize import (
    dequantize_blockwise,
    dequantize_codes,
    pack_codes,
    quantize_blockwise,
    quantize_codes,
    unpack_codes,
)

__all__ = [
    "init_baseline_linear",
    "dequantize_baseline_weight",
    "baseline_block_operands",
    "loftq_init",
    "qpissa_init",
    "gptq_quantize",
    "awq_quantize",
    "hadamard_transform",
    "smooth_scales",
    "smoothrot_quantize",
    "smoothrot_dequantize",
]


# ---------------------------------------------------------------------------
# init / dequant dispatch used by repro.core.lords
# ---------------------------------------------------------------------------


def init_baseline_linear(key, n, m, spec, w):
    params: dict[str, jnp.ndarray] = {}
    if spec.method == "blockwise":
        if spec.mode == "qat":
            params["w"] = w
            params["s_blk"] = scaling.blockwise_scales(w, spec.block_size)
        else:
            q, s_blk = quantize_blockwise(w, spec.block_size, spec.codebook)
            params["q"], params["s_blk"] = q, s_blk
        return params

    if spec.method == "qlora":
        q, s_blk = quantize_blockwise(w, spec.block_size, spec.codebook)
        params["q"], params["s_blk"] = q, s_blk
        r = spec.adapter_rank
        # LoRA init: A ~ kaiming-uniform, B = 0  (Hu et al., 2022)
        bound = 1.0 / jnp.sqrt(m)
        params["lora_a"] = jax.random.uniform(
            key, (r, m), jnp.float32, -bound, bound
        )
        params["lora_b"] = jnp.zeros((n, r), jnp.float32)
        return params

    if spec.method == "loftq":
        q, s_blk, lb, la = loftq_init(
            w, spec.block_size, spec.codebook, spec.adapter_rank, spec.loftq_iters
        )
        params.update(q=q, s_blk=s_blk, lora_b=lb, lora_a=la)
        return params

    if spec.method == "qpissa":
        q, s_blk, lb, la = qpissa_init(
            w, spec.block_size, spec.codebook, spec.adapter_rank
        )
        params.update(q=q, s_blk=s_blk, lora_b=lb, lora_a=la)
        return params

    raise ValueError(f"unknown baseline method {spec.method!r}")


def dequantize_baseline_weight(params, spec, n, m):
    """Dequantize the *frozen/base* weight (adapter handled by the caller)."""
    if spec.method == "blockwise" and spec.mode == "qat":
        from repro.core.qat import fake_quant_ste

        bs = params["w"].shape[-1] // params["s_blk"].shape[-1]
        s = scaling.expand_block_scales(params["s_blk"], bs)
        return fake_quant_ste(spec.codebook, params["w"], s).astype(
            spec.compute_dtype
        )
    w_hat = dequantize_blockwise(
        params["q"], params["s_blk"], spec.block_size, spec.codebook,
        dtype=spec.compute_dtype,
    )
    if "awq_s" in params:  # AWQ: un-fold the per-input-channel smoothing
        w_hat = w_hat / params["awq_s"][None, :].astype(spec.compute_dtype)
    return w_hat


def baseline_block_operands(params, m):
    """Fused-kernel operands for the frozen block-quantized base weight.

    Returns ``(q_packed, s_blk, effective_block_size)``.  The block size is
    recovered from the stored scale columns rather than ``spec.block_size``
    so the ``eff_block`` clamp (rows shorter than the nominal block) is
    honored.  Only valid when the base is frozen and un-smoothed — callers
    (repro.kernels.dispatch) must keep AWQ/QAT variants on the dense path.
    """
    return params["q"], params["s_blk"], m // params["s_blk"].shape[-1]


# ---------------------------------------------------------------------------
# LoftQ (Li et al., 2023) & QPiSSA (Meng et al., 2024)
# ---------------------------------------------------------------------------


def _svd_lowrank(x, r):
    u, s, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
    root = jnp.sqrt(s[:r])
    return u[:, :r] * root[None, :], root[:, None] * vt[:r, :]


def loftq_init(w, block_size, codebook, r, iters=5):
    """Alternate Q = quant(W − BA); (B, A) = SVD_r(W − dequant(Q))."""
    w = w.astype(jnp.float32)
    lb = jnp.zeros((w.shape[0], r), jnp.float32)
    la = jnp.zeros((r, w.shape[1]), jnp.float32)
    q = s_blk = None
    for _ in range(max(iters, 1)):
        resid = w - lb @ la
        q, s_blk = quantize_blockwise(resid, block_size, codebook)
        d = dequantize_blockwise(q, s_blk, block_size, codebook)
        lb, la = _svd_lowrank(w - d, r)
    return q, s_blk, lb, la


def qpissa_init(w, block_size, codebook, r):
    """Principal singular directions → adapter; residual → quantized base."""
    w = w.astype(jnp.float32)
    lb, la = _svd_lowrank(w, r)
    resid = w - lb @ la
    q, s_blk = quantize_blockwise(resid, block_size, codebook)
    return q, s_blk, lb, la


# ---------------------------------------------------------------------------
# GPTQ (Frantar et al., 2022) — column-wise with error compensation
# ---------------------------------------------------------------------------


def gptq_quantize(
    w: jnp.ndarray,
    x_calib: jnp.ndarray,
    block_size: int,
    codebook: str,
    damp: float = 0.01,
):
    """GPTQ for one linear.  ``w`` (n, m); ``x_calib`` (T, m) activations.

    Classic formulation: H = 2 X Xᵀ (here Xᵀ X over tokens), Cholesky of
    H⁻¹; quantize columns left→right, propagating the weighted error to the
    not-yet-quantized columns.  Block scales are computed up front from W
    (standard practice: scales from the original weights).
    """
    n, m = w.shape
    w = w.astype(jnp.float32)
    h = 2.0 * (x_calib.astype(jnp.float32).T @ x_calib.astype(jnp.float32))
    h = h + damp * jnp.mean(jnp.diag(h)) * jnp.eye(m, dtype=jnp.float32)
    # Hinv via Cholesky: GPTQ uses U = chol(H^-1, upper); U_jj scales the err.
    hinv = jnp.linalg.inv(h)
    u = jnp.linalg.cholesky(hinv, upper=True)

    s_blk = scaling.blockwise_scales(w, block_size)
    s = scaling.expand_block_scales(s_blk, block_size)

    def body(j, carry):
        wc, codes = carry
        col = wc[:, j]
        sj = s[:, j]
        cj = quantize_codes(col, sj, codebook)
        qj = dequantize_codes(cj, sj, codebook)
        err = (col - qj) / u[j, j]
        # propagate to remaining columns (mask keeps it jit-shaped)
        row = u[j, :]
        mask = (jnp.arange(m) > j).astype(jnp.float32)
        wc = wc - jnp.outer(err, row * mask)
        codes = codes.at[:, j].set(cj)
        return wc, codes

    codes0 = jnp.zeros((n, m), jnp.uint8)
    _, codes = jax.lax.fori_loop(0, m, body, (w, codes0))
    return pack_codes(codes, codebook), s_blk


# ---------------------------------------------------------------------------
# AWQ (Lin et al., 2024) — activation-aware per-channel scale search
# ---------------------------------------------------------------------------


def awq_quantize(
    w: jnp.ndarray,
    x_calib: jnp.ndarray,
    block_size: int,
    codebook: str,
    n_grid: int = 20,
):
    """Grid-search s_j = E|x_j|^α protecting salient channels (α ∈ [0, 1))."""
    w = w.astype(jnp.float32)
    act_mag = jnp.mean(jnp.abs(x_calib.astype(jnp.float32)), axis=0)  # (m,)
    act_mag = jnp.maximum(act_mag, 1e-8)
    y_ref = x_calib @ w.T

    def loss_for(alpha):
        sc = act_mag**alpha
        sc = sc / jnp.sqrt(jnp.max(sc) * jnp.min(sc))  # normalize center
        q, s_blk = quantize_blockwise(w * sc[None, :], block_size, codebook)
        w_hat = (
            dequantize_blockwise(q, s_blk, block_size, codebook) / sc[None, :]
        )
        err = jnp.mean((x_calib @ w_hat.T - y_ref) ** 2)
        return err, (q, s_blk, sc)

    best = None
    for i in range(n_grid):
        alpha = i / n_grid
        err, payload = loss_for(alpha)
        if best is None or float(err) < best[0]:
            best = (float(err), payload)
    q, s_blk, sc = best[1]
    return q, s_blk, sc


# ---------------------------------------------------------------------------
# SmoothRot (Czakó et al., 2025) — channel smoothing + Hadamard rotation
# ---------------------------------------------------------------------------


def _hadamard_group(m: int) -> int:
    """Largest power of two dividing m — the block-diagonal FWHT group."""
    g = m & (-m)
    return max(g, 1)


def hadamard_transform(v: jnp.ndarray, signs: jnp.ndarray | None = None
                       ) -> jnp.ndarray:
    """Normalized fast Walsh–Hadamard transform along the last axis.

    Block-diagonal over contiguous groups of size ``g`` = the largest power
    of two dividing the axis length, so it applies to any dimension (g = 1
    degenerates to identity).  With the normalization 1/sqrt(g) the
    transform is a symmetric involution: ``fwht(fwht(x)) == x``.

    ``signs`` (m,) of ±1 pre-multiplies the input (the randomized-Hadamard
    ``D·H`` construction); the inverse of ``t(x) = fwht(x ⊙ d)`` is
    ``t⁻¹(y) = fwht(y) ⊙ d``.
    """
    v = jnp.asarray(v)
    m = v.shape[-1]
    if signs is not None:
        v = v * jnp.asarray(signs, v.dtype)
    g = _hadamard_group(m)
    if g == 1:
        return v
    lead = v.shape[:-1]
    r = v.reshape(*lead, m // g, g)
    h = 1
    while h < g:
        r = r.reshape(*lead, m // g, g // (2 * h), 2, h)
        a, b = r[..., 0, :], r[..., 1, :]
        r = jnp.stack([a + b, a - b], axis=-2)
        h *= 2
    r = r.reshape(*lead, m) / jnp.sqrt(jnp.asarray(g, v.dtype))
    return r


def hadamard_signs(m: int, seed: int) -> jnp.ndarray:
    """Deterministic ±1 diagonal for the randomized Hadamard (f32)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2, m) * 2 - 1, jnp.float32)


def smooth_scales(w: jnp.ndarray, x_calib: jnp.ndarray,
                  alpha: float = 0.5) -> jnp.ndarray:
    """SmoothQuant migration scales c_j = E|x_j|^α / max_i|w_ij|^{1-α}.

    Applied as W ⊙ c (and x ⊘ c): channels with large activations get their
    weight columns boosted so the *weight* quantizer sees the outlier
    energy, where block scales can absorb it.
    """
    act = jnp.maximum(
        jnp.mean(jnp.abs(x_calib.astype(jnp.float32)), axis=0), 1e-6)
    wmax = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0), 1e-6)
    c = act**alpha / wmax ** (1.0 - alpha)
    return jnp.maximum(c / jnp.sqrt(jnp.max(c) * jnp.min(c)), 1e-6)


def smoothrot_quantize(
    w: jnp.ndarray,
    x_calib: jnp.ndarray,
    block_size: int,
    codebook: str,
    alpha: float = 0.5,
    seed: int = 0,
):
    """Quantize W in the smoothed+rotated basis; returns (q, s_blk, c, signs).

    W' = fwht((W ⊙ c) ⊙ d) row-wise; y = x Wᵀ is preserved exactly under
    x' = fwht((x ⊘ c) ⊙ d) since fwht is symmetric-orthogonal and d² = 1.
    """
    w = w.astype(jnp.float32)
    c = smooth_scales(w, x_calib, alpha)
    signs = hadamard_signs(w.shape[1], seed)
    w_rot = hadamard_transform(w * c[None, :], signs)
    q, s_blk = quantize_blockwise(w_rot, block_size, codebook)
    return q, s_blk, c, signs


def smoothrot_dequantize(q, s_blk, c, signs, block_size, codebook):
    """Ŵ back in the original basis: fwht(Ŵ') ⊙ d ⊘ c per row."""
    w_rot = dequantize_blockwise(q, s_blk, block_size, codebook)
    return hadamard_transform(w_rot) * signs[None, :] / c[None, :]
