"""Baselines the paper compares against, implemented in JAX.

* block-wise NF4/INT4 (bitsandbytes semantics)          — Tables 1, 4
* QLoRA: block-wise quant + additive LoRA adapter        — Table 5
* LoftQ: alternating residual-SVD adapter initialization — Tables 1, 3, 5, 8
* QPiSSA: principal-components-to-adapter initialization — Tables 8, 9
* GPTQ: Hessian-based column-wise quantization           — Table 1
* AWQ: activation-aware per-channel scale search         — Table 1

GPTQ/AWQ consume calibration activations (`repro.data.calibration`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import scaling
from repro.core.quantize import (
    dequantize_blockwise,
    dequantize_codes,
    pack_codes,
    quantize_blockwise,
    quantize_codes,
    unpack_codes,
)

__all__ = [
    "init_baseline_linear",
    "dequantize_baseline_weight",
    "baseline_block_operands",
    "loftq_init",
    "qpissa_init",
    "gptq_quantize",
    "awq_quantize",
]


# ---------------------------------------------------------------------------
# init / dequant dispatch used by repro.core.lords
# ---------------------------------------------------------------------------


def init_baseline_linear(key, n, m, spec, w):
    params: dict[str, jnp.ndarray] = {}
    if spec.method == "blockwise":
        if spec.mode == "qat":
            params["w"] = w
            params["s_blk"] = scaling.blockwise_scales(w, spec.block_size)
        else:
            q, s_blk = quantize_blockwise(w, spec.block_size, spec.codebook)
            params["q"], params["s_blk"] = q, s_blk
        return params

    if spec.method == "qlora":
        q, s_blk = quantize_blockwise(w, spec.block_size, spec.codebook)
        params["q"], params["s_blk"] = q, s_blk
        r = spec.adapter_rank
        # LoRA init: A ~ kaiming-uniform, B = 0  (Hu et al., 2022)
        bound = 1.0 / jnp.sqrt(m)
        params["lora_a"] = jax.random.uniform(
            key, (r, m), jnp.float32, -bound, bound
        )
        params["lora_b"] = jnp.zeros((n, r), jnp.float32)
        return params

    if spec.method == "loftq":
        q, s_blk, lb, la = loftq_init(
            w, spec.block_size, spec.codebook, spec.adapter_rank, spec.loftq_iters
        )
        params.update(q=q, s_blk=s_blk, lora_b=lb, lora_a=la)
        return params

    if spec.method == "qpissa":
        q, s_blk, lb, la = qpissa_init(
            w, spec.block_size, spec.codebook, spec.adapter_rank
        )
        params.update(q=q, s_blk=s_blk, lora_b=lb, lora_a=la)
        return params

    raise ValueError(f"unknown baseline method {spec.method!r}")


def dequantize_baseline_weight(params, spec, n, m):
    """Dequantize the *frozen/base* weight (adapter handled by the caller)."""
    if spec.method == "blockwise" and spec.mode == "qat":
        from repro.core.qat import fake_quant_ste

        bs = params["w"].shape[-1] // params["s_blk"].shape[-1]
        s = scaling.expand_block_scales(params["s_blk"], bs)
        return fake_quant_ste(spec.codebook, params["w"], s).astype(
            spec.compute_dtype
        )
    w_hat = dequantize_blockwise(
        params["q"], params["s_blk"], spec.block_size, spec.codebook,
        dtype=spec.compute_dtype,
    )
    if "awq_s" in params:  # AWQ: un-fold the per-input-channel smoothing
        w_hat = w_hat / params["awq_s"][None, :].astype(spec.compute_dtype)
    return w_hat


def baseline_block_operands(params, m):
    """Fused-kernel operands for the frozen block-quantized base weight.

    Returns ``(q_packed, s_blk, effective_block_size)``.  The block size is
    recovered from the stored scale columns rather than ``spec.block_size``
    so the ``eff_block`` clamp (rows shorter than the nominal block) is
    honored.  Only valid when the base is frozen and un-smoothed — callers
    (repro.kernels.dispatch) must keep AWQ/QAT variants on the dense path.
    """
    return params["q"], params["s_blk"], m // params["s_blk"].shape[-1]


# ---------------------------------------------------------------------------
# LoftQ (Li et al., 2023) & QPiSSA (Meng et al., 2024)
# ---------------------------------------------------------------------------


def _svd_lowrank(x, r):
    u, s, vt = jnp.linalg.svd(x.astype(jnp.float32), full_matrices=False)
    root = jnp.sqrt(s[:r])
    return u[:, :r] * root[None, :], root[:, None] * vt[:r, :]


def loftq_init(w, block_size, codebook, r, iters=5):
    """Alternate Q = quant(W − BA); (B, A) = SVD_r(W − dequant(Q))."""
    w = w.astype(jnp.float32)
    lb = jnp.zeros((w.shape[0], r), jnp.float32)
    la = jnp.zeros((r, w.shape[1]), jnp.float32)
    q = s_blk = None
    for _ in range(max(iters, 1)):
        resid = w - lb @ la
        q, s_blk = quantize_blockwise(resid, block_size, codebook)
        d = dequantize_blockwise(q, s_blk, block_size, codebook)
        lb, la = _svd_lowrank(w - d, r)
    return q, s_blk, lb, la


def qpissa_init(w, block_size, codebook, r):
    """Principal singular directions → adapter; residual → quantized base."""
    w = w.astype(jnp.float32)
    lb, la = _svd_lowrank(w, r)
    resid = w - lb @ la
    q, s_blk = quantize_blockwise(resid, block_size, codebook)
    return q, s_blk, lb, la


# ---------------------------------------------------------------------------
# GPTQ (Frantar et al., 2022) — column-wise with error compensation
# ---------------------------------------------------------------------------


def gptq_quantize(
    w: jnp.ndarray,
    x_calib: jnp.ndarray,
    block_size: int,
    codebook: str,
    damp: float = 0.01,
):
    """GPTQ for one linear.  ``w`` (n, m); ``x_calib`` (T, m) activations.

    Classic formulation: H = 2 X Xᵀ (here Xᵀ X over tokens), Cholesky of
    H⁻¹; quantize columns left→right, propagating the weighted error to the
    not-yet-quantized columns.  Block scales are computed up front from W
    (standard practice: scales from the original weights).
    """
    n, m = w.shape
    w = w.astype(jnp.float32)
    h = 2.0 * (x_calib.astype(jnp.float32).T @ x_calib.astype(jnp.float32))
    h = h + damp * jnp.mean(jnp.diag(h)) * jnp.eye(m, dtype=jnp.float32)
    # Hinv via Cholesky: GPTQ uses U = chol(H^-1, upper); U_jj scales the err.
    hinv = jnp.linalg.inv(h)
    u = jnp.linalg.cholesky(hinv, upper=True)

    s_blk = scaling.blockwise_scales(w, block_size)
    s = scaling.expand_block_scales(s_blk, block_size)

    def body(j, carry):
        wc, codes = carry
        col = wc[:, j]
        sj = s[:, j]
        cj = quantize_codes(col, sj, codebook)
        qj = dequantize_codes(cj, sj, codebook)
        err = (col - qj) / u[j, j]
        # propagate to remaining columns (mask keeps it jit-shaped)
        row = u[j, :]
        mask = (jnp.arange(m) > j).astype(jnp.float32)
        wc = wc - jnp.outer(err, row * mask)
        codes = codes.at[:, j].set(cj)
        return wc, codes

    codes0 = jnp.zeros((n, m), jnp.uint8)
    _, codes = jax.lax.fori_loop(0, m, body, (w, codes0))
    return pack_codes(codes, codebook), s_blk


# ---------------------------------------------------------------------------
# AWQ (Lin et al., 2024) — activation-aware per-channel scale search
# ---------------------------------------------------------------------------


def awq_quantize(
    w: jnp.ndarray,
    x_calib: jnp.ndarray,
    block_size: int,
    codebook: str,
    n_grid: int = 20,
):
    """Grid-search s_j = E|x_j|^α protecting salient channels (α ∈ [0, 1))."""
    w = w.astype(jnp.float32)
    act_mag = jnp.mean(jnp.abs(x_calib.astype(jnp.float32)), axis=0)  # (m,)
    act_mag = jnp.maximum(act_mag, 1e-8)
    y_ref = x_calib @ w.T

    def loss_for(alpha):
        sc = act_mag**alpha
        sc = sc / jnp.sqrt(jnp.max(sc) * jnp.min(sc))  # normalize center
        q, s_blk = quantize_blockwise(w * sc[None, :], block_size, codebook)
        w_hat = (
            dequantize_blockwise(q, s_blk, block_size, codebook) / sc[None, :]
        )
        err = jnp.mean((x_calib @ w_hat.T - y_ref) ** 2)
        return err, (q, s_blk, sc)

    best = None
    for i in range(n_grid):
        alpha = i / n_grid
        err, payload = loss_for(alpha)
        if best is None or float(err) < best[0]:
            best = (float(err), payload)
    q, s_blk, sc = best[1]
    return q, s_blk, sc
