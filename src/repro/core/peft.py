"""Trainable/frozen parameter partitioning for the three lifecycle modes.

PEFT (paper §3.4): only the scaling matrices (B, A) train — the multiplicative
update ΔW = Q ⊙ (B'A' − BA).  QAT: everything trains (W via STE).  The
partition is structural (by leaf path), so the optimizer/train-step never see
frozen uint8 codes.

``partition(params, quant)`` -> (trainable, frozen) trees with ``None`` holes;
``combine(trainable, frozen)`` re-assembles.  Holes keep tree structure
identical, so pytree transforms (grads, optimizer states) map 1:1.

``scale_grads`` is the single source of the multiplicative-PEFT chain rule
through ``S = B·A``: the dense backward, the ref backward oracle
(:func:`repro.kernels.ref.lords_grads_ref`), and the fused Pallas grad
kernel (:mod:`repro.kernels.lords_grad`, which applies the same
contractions tile-by-tile) all implement it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lords import QuantSpec

__all__ = ["partition", "combine", "trainable_leaf", "scale_grads"]


def scale_grads(ds, b, a):
    """Chain rule of the low-rank scale ``S = B·A`` (paper §3.4).

    ``ds`` is the scale-space cotangent ∂L/∂S (N, K), clamp mask already
    applied.  Returns ``(∇B, ∇A) = (∂L/∂S · Aᵀ, Bᵀ · ∂L/∂S)`` in f32 —
    callers cast to storage dtypes.
    """
    ds = ds.astype(jnp.float32)
    db = ds @ a.astype(jnp.float32).T
    da = b.astype(jnp.float32).T @ ds
    return db, da

# keys that belong to quantized-linear leaves
_QUANT_KEYS = {"q", "b", "a", "s_blk", "w", "lora_b", "lora_a", "bias", "awq_s"}
# never trainable regardless of mode
_ALWAYS_FROZEN = {"q", "awq_s"}


def trainable_leaf(path: tuple, quant: QuantSpec) -> bool:
    """Decide trainability of a leaf from its tree path + the quant spec."""
    key = None
    for p in reversed(path):
        name = getattr(p, "key", None) or getattr(p, "name", None)
        if name is not None:
            key = str(name)
            break
    if key is None:
        return quant.mode != "frozen"
    if key in _ALWAYS_FROZEN:
        return False
    mode, method = quant.mode, quant.method
    if mode == "frozen":
        return False
    if mode == "qat":
        return True  # everything: W (STE), B/A, norms, router, embeds
    # mode == "peft"
    if method == "lords":
        return key in ("b", "a")
    if method in ("qlora", "loftq", "qpissa"):
        return key in ("lora_b", "lora_a")
    if method == "none":
        return True
    if method == "blockwise":
        return key == "s_blk"  # PEQA-style: tune scales only
    return False


def partition(params, quant: QuantSpec):
    """-> (trainable, frozen); same structure, None holes in each."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    t_leaves, f_leaves = [], []
    for path, leaf in flat:
        if trainable_leaf(path, quant):
            t_leaves.append(leaf)
            f_leaves.append(None)
        else:
            t_leaves.append(None)
            f_leaves.append(leaf)
    trainable = jax.tree_util.tree_unflatten(treedef, t_leaves)
    frozen = jax.tree_util.tree_unflatten(treedef, f_leaves)
    return trainable, frozen


def combine(trainable, frozen):
    return jax.tree.map(
        lambda t, f: t if t is not None else f,
        trainable, frozen,
        is_leaf=lambda x: x is None,
    )
