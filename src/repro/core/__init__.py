"""repro.core — LoRDS: Low-Rank Decomposed Scaling (the paper's contribution).

Public surface:
  QuantSpec, init_quantized_linear, apply_quantized_linear  (module API)
  ptq_refine                                                 (Algorithm 1)
  fake_quant_ste                                             (QAT STE)
  lut / scaling / quantize / baselines / metrics             (submodules)
"""
from repro.core.lords import (  # noqa: F401
    QuantSpec,
    apply_quantized_linear,
    dequantize_weight,
    init_quantized_linear,
    linear_param_specs,
    trainable_keys,
)
from repro.core.ptq import PTQResult, ptq_refine  # noqa: F401
from repro.core.qat import fake_quant_ste  # noqa: F401
