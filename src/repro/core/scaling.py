"""Scaling-matrix construction: block-wise scales and the LoRDS S = B·A init.

Conventions (paper §3.1):
  * weight ``W ∈ R^{n×m}`` (out_features × in_features),
  * blocks are contiguous runs of ``block_size`` elements along the *rows*
    (the in-features axis), matching bitsandbytes / QLoRA flattening,
  * the global scaling matrix ``S ∈ R^{n×m}`` repeats each block scale:
    ``S = s ⊗ 1_{1×B}`` with ``s ∈ R^{n×(m/B)}`` → ``rank(S) ≤ m/B``.

The LoRDS initialization (paper Eq. 3) truncates the SVD of S:
  ``S ≈ (U_r Σ_r^{1/2})(Σ_r^{1/2} V_rᵀ) = B·A``
with the parameter-parity rank ``r = ⌊ n·m / (B·(n+m)) ⌋`` (Appendix A).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "parity_rank",
    "blockwise_scales",
    "eff_block",
    "expand_block_scales",
    "svd_init",
    "lords_init_from_weight",
    "scale_matrix",
    "clamp_scale",
    "SCALE_EPS",
]

# Scales must stay away from zero: the quantization step divides by S.
SCALE_EPS = 1e-8


def clamp_scale(s: jnp.ndarray, eps: float = SCALE_EPS) -> jnp.ndarray:
    """|S| >= eps, sign-preserving — THE clamp rule, shared by every Pallas
    kernel body, the ref oracles, and :func:`scale_matrix`.  The backward
    mask is its boundary (``|S| >= eps``); keeping both rules in one module
    is what guarantees forward/backward consistency."""
    sign = jnp.where(s >= 0, 1.0, -1.0).astype(s.dtype)
    return jnp.where(jnp.abs(s) < eps, sign * eps, s)


def parity_rank(n: int, m: int, block_size: int, extra_rank: int = 0) -> int:
    """r = floor(n*m / (B*(n+m))) (+ r_q for the parameter-aligned LoRDS†)."""
    r = (n * m) // (block_size * (n + m)) + extra_rank
    return max(int(r), 1)


def eff_block(m: int, block_size: int) -> int:
    """Effective block size: clamped to the row length (tiny matrices)."""
    return min(block_size, m)


def blockwise_scales(w: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Symmetric absmax block scales, shape (n, m // block_size).

    Each scale maps its block onto [-1, 1] so codebook levels (normalized to
    [-1, 1]) dequantize as ``level * scale``.
    """
    n, m = w.shape
    block_size = eff_block(m, block_size)
    if m % block_size:
        raise ValueError(f"in-features {m} not divisible by block {block_size}")
    blocks = w.reshape(n, m // block_size, block_size)
    return jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1), SCALE_EPS)


def expand_block_scales(s: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """(n, m/B) block scales -> dense (n, m) piecewise-constant S."""
    return jnp.repeat(s, block_size, axis=1)


def svd_init(s_dense: jnp.ndarray, rank: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Truncated-SVD factorization S ≈ B·A with balanced sqrt(Σ) split."""
    u, sig, vt = jnp.linalg.svd(s_dense, full_matrices=False)
    r = min(rank, sig.shape[0])
    root = jnp.sqrt(sig[:r])
    b = u[:, :r] * root[None, :]
    a = root[:, None] * vt[:r, :]
    return b, a


def lords_init_from_weight(
    w: jnp.ndarray,
    block_size: int,
    rank: int | None = None,
    extra_rank: int = 0,
    channel_scale: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full LoRDS init: block scales -> dense S -> truncated SVD -> (B, A).

    ``channel_scale`` (m,): SmoothQuant-style per-input-channel smoothing
    scales c_j, folded into the init — block scales are computed on the
    smoothed weight W ⊙ c and the dense S is divided back by c, so
    quantizing W against this S is exactly quantizing W ⊙ c against its own
    block scales.  Because S is element-wise the smoothing is free: no
    runtime transform, no extra stored tensors, and refinement can move off
    the smoothed manifold if the data prefers.
    """
    n, m = w.shape
    if rank is None:
        rank = parity_rank(n, m, block_size, extra_rank)
    block_size = eff_block(m, block_size)
    if channel_scale is not None:
        c = jnp.maximum(jnp.abs(channel_scale.astype(w.dtype)), SCALE_EPS)
        s = expand_block_scales(
            blockwise_scales(w * c[None, :], block_size), block_size)
        s = s / c[None, :]
    else:
        s = expand_block_scales(blockwise_scales(w, block_size), block_size)
    return svd_init(s, rank)


def scale_matrix(b: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """S = B·A, clamped away from zero (sign-preserving)."""
    return clamp_scale(b @ a)
