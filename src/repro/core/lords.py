"""LoRDS quantized linear layers — the paper's core contribution as a module.

A quantized linear is a pytree of arrays plus a :class:`QuantSpec`.  Three
lifecycle modes share one parameterization (paper §3):

  * ``frozen`` — inference: packed codes Q + (B, A); Ŵ = Q ⊙ (B·A).
  * ``peft``   — same storage; B, A are *trainable* (multiplicative PEFT,
    ΔW = Q ⊙ (B'A' − BA)); Q stays frozen. Fully differentiable, no STE.
  * ``qat``    — master weights W kept; forward uses STE fake-quant
    Ŵ = ROUND(W ⊘ BA) ⊙ (BA); W, B, A all trainable.

Param-tree layout (keys present depend on mode/method):

    {"q": uint8 packed codes (n, m/pack),
     "b": (n, r), "a": (r, m),                  # lords
     "s_blk": (n, m/B),                          # blockwise baseline
     "w": (n, m),                                # qat master / fp
     "lora_b": (n, r_q), "lora_a": (r_q, m),     # qlora/loftq baselines
     "bias": (n,)}                               # optional

Logical sharding axes for every key are produced alongside the params so the
distributed layer can pjit any quantized model without introspection.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lut, scaling
from repro.core.qat import fake_quant_ste
from repro.core.quantize import (
    dequantize_codes,
    pack_codes,
    packed_dim,
    quantize_codes,
    unpack_codes,
)

__all__ = ["QuantSpec", "init_quantized_linear", "apply_quantized_linear",
           "dequantize_weight", "linear_param_specs", "trainable_keys"]


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How to quantize (and adapt) one linear layer / a whole model."""

    method: str = "lords"  # lords | blockwise | qlora | loftq | qpissa | none
    codebook: str = "nf4"
    block_size: int = 128  # equivalent block size (sets LoRDS parity rank)
    rank: int | None = None  # explicit LoRDS rank override
    extra_rank: int = 0  # +r_q for the parameter-aligned LoRDS†
    mode: str = "frozen"  # frozen | peft | qat
    adapter_rank: int = 32  # additive-adapter rank for qlora/loftq/qpissa
    compute_dtype: Any = jnp.bfloat16
    scale_dtype: Any = jnp.float32
    ba_compute_dtype: Any = jnp.float32  # S=B·A product precision (perf knob)
    loftq_iters: int = 5

    def with_(self, **kw) -> "QuantSpec":
        return dataclasses.replace(self, **kw)

    def lords_rank(self, n: int, m: int) -> int:
        if self.rank is not None:
            return self.rank + self.extra_rank
        return scaling.parity_rank(n, m, self.block_size, self.extra_rank)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _init_weight(key, n, m, dtype):
    """LeCun-normal init used when no pretrained weight is supplied."""
    std = 1.0 / jnp.sqrt(m)
    return (jax.random.normal(key, (n, m), jnp.float32) * std).astype(dtype)


def init_quantized_linear(
    key: jax.Array,
    n: int,
    m: int,
    spec: QuantSpec,
    w: jnp.ndarray | None = None,
    use_bias: bool = False,
) -> dict:
    """Build the param tree for one (n out × m in) quantized linear.

    If ``w`` is None a fresh weight is drawn first (from-scratch QAT / tests).
    For ``method='lords'`` this performs the paper's SVD initialization; the
    iterative PTQ refinement lives in :mod:`repro.core.ptq`.
    """
    if w is None:
        key, sub = jax.random.split(key)
        w = _init_weight(sub, n, m, jnp.float32)
    w = w.astype(jnp.float32)
    params: dict[str, jnp.ndarray] = {}
    method, mode = spec.method, spec.mode

    if method == "none":
        params["w"] = w.astype(spec.compute_dtype)
    elif method == "lords":
        b, a = scaling.lords_init_from_weight(
            w, spec.block_size, rank=spec.rank, extra_rank=spec.extra_rank
        )
        s = scaling.scale_matrix(b, a)
        params["b"] = b.astype(spec.scale_dtype)
        params["a"] = a.astype(spec.scale_dtype)
        if mode == "qat":
            params["w"] = w
        else:
            codes = quantize_codes(w, s, spec.codebook)
            params["q"] = pack_codes(codes, spec.codebook)
    elif method in ("blockwise", "qlora", "loftq", "qpissa"):
        from repro.core import baselines  # cycle-free: baselines imports us not

        params = baselines.init_baseline_linear(key, n, m, spec, w)
    else:
        raise ValueError(f"unknown quant method {method!r}")

    if use_bias:
        params["bias"] = jnp.zeros((n,), spec.compute_dtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def dequantize_weight(params: dict, spec: QuantSpec, n: int, m: int) -> jnp.ndarray:
    """Materialize Ŵ (compute dtype). Used by the pure-JAX (non-Pallas) path."""
    method, mode = spec.method, spec.mode
    if method == "none":
        return params["w"].astype(spec.compute_dtype)
    if method == "lords":
        s = scaling.scale_matrix(
            params["b"].astype(spec.ba_compute_dtype),
            params["a"].astype(spec.ba_compute_dtype),
        )
        if mode == "qat":
            return fake_quant_ste(spec.codebook, params["w"], s).astype(
                spec.compute_dtype
            )
        codes = unpack_codes(params["q"], spec.codebook)
        return dequantize_codes(codes, s, spec.codebook, dtype=spec.compute_dtype)
    from repro.core import baselines

    return baselines.dequantize_baseline_weight(params, spec, n, m)


def apply_quantized_linear(
    params: dict, x: jnp.ndarray, spec: QuantSpec, n: int, m: int
) -> jnp.ndarray:
    """y = x @ Ŵᵀ (+ additive adapter for qlora-family baselines).

    Routed through :mod:`repro.kernels.dispatch`: fused Pallas kernels on
    TPU / in interpret mode, pure-jnp oracles elsewhere — Ŵ is only
    materialized on the explicit ``dense`` fallback backend.
    """
    from repro.kernels.dispatch import qmatmul  # lazy: kernels import core

    return qmatmul(params, x, spec, n, m)


# ---------------------------------------------------------------------------
# Logical sharding axes (consumed by repro.distributed.sharding)
# ---------------------------------------------------------------------------


def linear_param_specs(
    spec: QuantSpec, out_axis: str, in_axis: str, use_bias: bool = False
) -> dict:
    """Logical axis names, mirroring the param tree of this linear.

    ``out_axis`` / ``in_axis`` are logical names like 'mlp' / 'embed'.  The
    packed-codes axis shares the in_axis name: packing divides the dim by a
    constant, and the rule resolver checks divisibility on the *actual* dim.
    """
    method, mode = spec.method, spec.mode
    axes: dict[str, tuple] = {}
    if method == "none":
        axes["w"] = (out_axis, in_axis)
    elif method == "lords":
        axes["b"] = (out_axis, "lords_rank")
        axes["a"] = ("lords_rank", in_axis)
        if mode == "qat":
            axes["w"] = (out_axis, in_axis)
        else:
            axes["q"] = (out_axis, in_axis)
    elif method == "blockwise":
        if mode == "qat":
            axes["w"] = (out_axis, in_axis)
        else:
            axes["q"] = (out_axis, in_axis)
        axes["s_blk"] = (out_axis, in_axis)
    elif method in ("qlora", "loftq", "qpissa"):
        axes["q"] = (out_axis, in_axis)
        axes["s_blk"] = (out_axis, in_axis)
        axes["lora_b"] = (out_axis, "lords_rank")
        axes["lora_a"] = ("lords_rank", in_axis)
    if use_bias:
        axes["bias"] = (out_axis,)
    return axes


def trainable_keys(spec: QuantSpec) -> tuple[str, ...]:
    """Which param-tree keys receive gradients in the given mode/method."""
    if spec.mode == "frozen":
        return ()
    if spec.method == "lords":
        return ("b", "a", "w", "bias") if spec.mode == "qat" else ("b", "a", "bias")
    if spec.method in ("qlora", "loftq", "qpissa"):
        return ("lora_b", "lora_a", "bias")
    if spec.method == "none":
        return ("w", "bias")
    if spec.method == "blockwise":
        return ("s_blk", "w", "bias") if spec.mode == "qat" else ()
    return ()
