"""Quantization codebooks (look-up tables).

Every codebook is a sorted 1-D float32 array of discrete levels normalized to
[-1, 1].  Symmetric absmax scaling maps a weight block onto this range, so
``dequant = codebook[idx] * scale``.

NF4 follows QLoRA (Dettmers et al., 2023): quantiles of N(0,1) renormalized to
[-1, 1], with an exact zero.  NF2/NF3 are the natural 2-/3-bit analogues used
by the paper's mixed-precision low-bit configurations (Table 3: "3-bit" =
NF4 for the first 50% of layers, NF2 for the rest, etc.).
"""
from __future__ import annotations

import functools
import math

import jax.numpy as jnp
import numpy as np
from repro.core._norminv import ppf

__all__ = [
    "codebook",
    "codebook_bits",
    "CODEBOOKS",
    "midpoints",
    "mixed_precision_schedule",
    "realized_bits",
]


def _normal_quantile_levels(bits: int) -> np.ndarray:
    """NFk levels a la QLoRA: asymmetric quantile grid with an exact zero."""
    n = 2**bits
    # QLoRA/bitsandbytes construction: 2**(k-1)+1 non-negative quantiles
    # (including an exact 0) and 2**(k-1)-1 negative ones; the offset trick
    # avoids the infinite tails.  Matches the canonical NF4 table
    # [-1, -0.6962, ..., 0, 0.0796, ..., 0.7230, 1].
    offset = 0.5 * (1 / 32 + 1 / 30)
    pos = ppf(np.linspace(0.5, 1 - offset, n // 2 + 1))  # [0 ... max]
    neg = ppf(np.linspace(offset, 0.5, n // 2)[:-1])  # [min ... ) negative
    levels = np.concatenate([neg, pos])
    levels = levels / np.abs(levels).max()
    levels = np.sort(levels)
    # force an exact zero on the level closest to zero (QLoRA property)
    levels[np.argmin(np.abs(levels))] = 0.0
    return levels.astype(np.float32)


def _int_levels(bits: int) -> np.ndarray:
    """Symmetric INTk grid normalized to [-1, 1] (no exact -2^(k-1) asym)."""
    qmax = 2 ** (bits - 1) - 1
    return (np.arange(-qmax, qmax + 1) / qmax).astype(np.float32)


def _fp4_levels() -> np.ndarray:
    """FP4 (e2m1) value set, normalized to [-1, 1]."""
    vals = np.array(
        [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32
    )
    # e2m1 has ±0 sharing a value -> 15 distinct levels
    levels = np.sort(np.concatenate([-vals[1:], vals]))
    return (levels / np.abs(levels).max()).astype(np.float32)


@functools.lru_cache(maxsize=None)
def _build(name: str) -> np.ndarray:
    name = name.lower()
    if name == "nf4":
        return _normal_quantile_levels(4)
    if name == "nf3":
        return _normal_quantile_levels(3)
    if name == "nf2":
        # 2-bit normal-float: {-1, -1/3-ish, 0, +something} from quantiles
        return _normal_quantile_levels(2)
    if name == "int8":
        return _int_levels(8)
    if name == "int4":
        return _int_levels(4)
    if name == "int2":
        return _int_levels(2)
    if name == "fp4":
        return _fp4_levels()
    raise ValueError(f"unknown codebook {name!r}")


# name -> storage bits (packing density); NB int4 grid has 15 levels but
# still packs in 4 bits.
_BITS = {
    "nf4": 4,
    "nf3": 3,
    "nf2": 2,
    "int8": 8,
    "int4": 4,
    "int2": 2,
    "fp4": 4,
}
CODEBOOKS = tuple(_BITS)


def codebook(name: str) -> jnp.ndarray:
    """Sorted float32 levels in [-1, 1] for codebook ``name``."""
    return jnp.asarray(_build(name))


def codebook_bits(name: str) -> int:
    try:
        return _BITS[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown codebook {name!r}; available: {', '.join(CODEBOOKS)}"
        ) from None


def midpoints(name: str) -> jnp.ndarray:
    """Decision boundaries between adjacent levels (len = n_levels - 1)."""
    levels = _build(name)
    return jnp.asarray((levels[1:] + levels[:-1]) / 2)


def mixed_precision_schedule(
    num_layers: int, avg_bits: float, hi: str = "nf4", lo: str = "nf2"
) -> list[str]:
    """Paper Table 3 mixed-precision schedule.

    "3/2.5/2.25-bit configurations denote mixed-precision quantization, using
    NF4 for the first 50%/25%/12.5% of layers and NF2 for the remainder."
    Generalized: the fraction of hi-precision layers is chosen so the average
    bit width equals ``avg_bits`` given hi/lo bit widths.
    """
    b_hi, b_lo = codebook_bits(hi), codebook_bits(lo)
    if not (b_lo <= avg_bits <= b_hi):
        raise ValueError(f"avg_bits {avg_bits} outside [{b_lo}, {b_hi}]")
    frac_hi = (avg_bits - b_lo) / (b_hi - b_lo)
    # pick n_hi minimizing |realized − requested| average bits: plain
    # round(frac·n) can silently drift (e.g. 2.25-bit over 7 layers) and
    # rounds half-to-even, biasing small layer counts
    exact = frac_hi * num_layers
    n_hi = min(
        (int(math.floor(exact)), int(math.ceil(exact))),
        key=lambda c: (abs((c * b_hi + (num_layers - c) * b_lo) / num_layers
                           - avg_bits), c),
    )
    return [hi] * n_hi + [lo] * (num_layers - n_hi)


def realized_bits(schedule: list[str]) -> float:
    """Average storage bits/weight a mixed-precision schedule actually
    realizes (what ``bench_lowbit`` reports next to the requested width)."""
    if not schedule:
        return 0.0
    return sum(codebook_bits(c) for c in schedule) / len(schedule)
