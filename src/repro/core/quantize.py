"""Quantize / dequantize primitives shared by block-wise and LoRDS paths.

Storage format
--------------
Codes are indices into a codebook (``repro.core.lut``).  On disk / in HBM we
pack them along the last axis in groups of ``PackSpec.group_codes`` codes per
``PackSpec.group_bytes`` bytes, little-endian within the group (code 0 in the
lowest bits of byte 0):

  * 8-bit codebooks (int8):          1 code  per byte   (1c/1B)
  * 4-bit codebooks (nf4/int4/fp4):  2 codes per byte   (2c/1B, low nibble
    first — unchanged from the historical nibble layout)
  * 3-bit codebooks (nf3):           8 codes per 3 bytes (8c/3B, cross-byte:
    the 8 codes form one 24-bit little-endian integer)
  * 2-bit codebooks (nf2/int2):      4 codes per byte   (4c/1B)

For ``group_bytes == 1`` widths this is byte-identical to the historical
layout; 3-bit is the only cross-byte group.  All functions are jit-friendly
and differentiable where meaningful.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import lut
from repro.core.scaling import SCALE_EPS

__all__ = [
    "PackSpec",
    "pack_spec",
    "nearest_code",
    "quantize_codes",
    "dequantize_codes",
    "pack_codes",
    "unpack_codes",
    "packed_dim",
    "codes_per_byte",
    "fake_quant",
    "quantize_blockwise",
    "dequantize_blockwise",
]


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Bit-packing group layout: ``group_codes`` codes per ``group_bytes``
    bytes, little-endian (code i occupies bits [bits*i, bits*(i+1)) of the
    group's ``8 * group_bytes``-bit integer)."""

    bits: int
    group_codes: int
    group_bytes: int

    def packed_width(self, m: int) -> int:
        """Packed byte count for a logical last-axis width of ``m`` codes."""
        if m % self.group_codes:
            raise ValueError(
                f"last dim {m} not divisible by pack group {self.group_codes}"
                f" ({self.bits}-bit)")
        return m // self.group_codes * self.group_bytes

    def logical_width(self, mp: int) -> int:
        """Logical code count for a packed last-axis width of ``mp`` bytes."""
        if mp % self.group_bytes:
            raise ValueError(
                f"packed dim {mp} not divisible by group bytes "
                f"{self.group_bytes} ({self.bits}-bit)")
        return mp // self.group_bytes * self.group_codes


# bits -> (group_codes, group_bytes).  group_bytes==1 entries are
# byte-identical to the historical single-byte layout.
_PACK_SPECS = {
    8: PackSpec(8, 1, 1),
    4: PackSpec(4, 2, 1),
    3: PackSpec(3, 8, 3),
    2: PackSpec(2, 4, 1),
}


def pack_spec(codebook_name: str) -> PackSpec:
    """The storage :class:`PackSpec` for a codebook — the single source of
    the bits->pack-layout map."""
    bits = lut.codebook_bits(codebook_name)
    spec = _PACK_SPECS.get(bits)
    if spec is None:
        raise ValueError(
            f"no pack layout for {bits}-bit codebook {codebook_name!r}; "
            f"supported bit widths: {sorted(_PACK_SPECS)}")
    return spec


def nearest_code(x: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Index of the nearest codebook level for each element of ``x``.

    Implemented with ``searchsorted`` over the level midpoints — exact
    nearest-neighbour for a sorted 1-D codebook, O(log L) per element.
    """
    mids = lut.midpoints(codebook_name).astype(x.dtype)
    return jnp.searchsorted(mids, x, side="left").astype(jnp.uint8)


def quantize_codes(
    w: jnp.ndarray, s: jnp.ndarray, codebook_name: str
) -> jnp.ndarray:
    """Paper Alg. 1 quantization step: Q_ij = argmin_v (S_ij * v - W_ij)^2.

    For s != 0 this equals nearest-level rounding of w/s (the s^2 factor does
    not change the argmin); for s < 0 the division flips the ordering, which
    nearest-neighbour on w/s handles automatically.
    """
    safe = jnp.where(jnp.abs(s) < SCALE_EPS, SCALE_EPS, s)
    ratio = (w / safe).astype(jnp.float32)
    return nearest_code(ratio, codebook_name)


def dequantize_codes(
    codes: jnp.ndarray, s: jnp.ndarray, codebook_name: str, dtype=None
) -> jnp.ndarray:
    """W_hat = codebook[codes] * S."""
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    out = vals * s
    return out.astype(dtype) if dtype is not None else out


def codes_per_byte(codebook_name: str) -> int:
    """Whole codes per uint8 for single-byte pack groups.

    Only defined when the pack group is one byte wide; 3-bit codes straddle
    byte boundaries (8 codes / 3 bytes) and must go through :func:`pack_spec`
    ``packed_width`` / ``logical_width`` instead.
    """
    spec = pack_spec(codebook_name)
    if spec.group_bytes != 1:
        raise ValueError(
            f"{spec.bits}-bit codebook {codebook_name!r} packs "
            f"{spec.group_codes} codes across {spec.group_bytes} bytes — "
            "there is no whole codes-per-byte factor; use pack_spec()")
    return spec.group_codes


def packed_dim(m: int, codebook_name: str) -> int:
    """Packed byte count of a logical last-axis width ``m``."""
    return pack_spec(codebook_name).packed_width(m)


def pack_codes(codes: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Pack uint8 code indices along the last axis into uint8 bytes.

    Each group of ``group_codes`` codes is assembled into one little-endian
    integer (code i at bits ``[bits*i, bits*(i+1))``) and emitted as
    ``group_bytes`` little-endian bytes.  For single-byte groups this reduces
    to the historical low-nibble-first layout.
    """
    ps = pack_spec(codebook_name)
    if ps.group_codes == 1:
        return codes.astype(jnp.uint8)
    *lead, m = codes.shape
    grp = codes.reshape(*lead, ps.packed_width(m) // ps.group_bytes,
                        ps.group_codes).astype(jnp.uint32)
    shifts = jnp.arange(ps.group_codes, dtype=jnp.uint32) * ps.bits
    word = jnp.sum(grp << shifts, axis=-1)  # <= 24 bits, fits uint32
    byte_shifts = jnp.arange(ps.group_bytes, dtype=jnp.uint32) * 8
    packed = (word[..., None] >> byte_shifts) & jnp.uint32(0xFF)
    return packed.reshape(*lead, -1).astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`; returns uint8 code indices."""
    ps = pack_spec(codebook_name)
    if ps.group_codes == 1:
        return packed.astype(jnp.uint8)
    *lead, mp = packed.shape
    grp = packed.reshape(*lead, ps.logical_width(mp) // ps.group_codes,
                         ps.group_bytes).astype(jnp.uint32)
    byte_shifts = jnp.arange(ps.group_bytes, dtype=jnp.uint32) * 8
    word = jnp.sum(grp << byte_shifts, axis=-1)
    shifts = jnp.arange(ps.group_codes, dtype=jnp.uint32) * ps.bits
    mask = jnp.uint32(2**ps.bits - 1)
    codes = (word[..., None] >> shifts) & mask
    return codes.reshape(*lead, -1).astype(jnp.uint8)


def fake_quant(w: jnp.ndarray, s: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Non-differentiable fake quantization (see qat.py for the STE version)."""
    codes = quantize_codes(w, s, codebook_name)
    return dequantize_codes(codes, s, codebook_name, dtype=w.dtype)


# ---------------------------------------------------------------------------
# Block-wise convenience wrappers (the NF4/INT4 baseline format)
# ---------------------------------------------------------------------------


def quantize_blockwise(
    w: jnp.ndarray, block_size: int, codebook_name: str
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Standard block-wise quantization -> (packed codes, block scales)."""
    from repro.core.scaling import blockwise_scales, eff_block, expand_block_scales

    block_size = eff_block(w.shape[1], block_size)
    s_blk = blockwise_scales(w, block_size)
    s = expand_block_scales(s_blk, block_size)
    codes = quantize_codes(w, s, codebook_name)
    return pack_codes(codes, codebook_name), s_blk


def dequantize_blockwise(
    packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str,
    dtype=jnp.float32,
) -> jnp.ndarray:
    from repro.core.scaling import expand_block_scales

    codes = unpack_codes(packed, codebook_name)
    block_size = codes.shape[-1] // s_blk.shape[-1]
    s = expand_block_scales(s_blk, block_size).astype(dtype)
    return dequantize_codes(codes, s, codebook_name, dtype=dtype)
