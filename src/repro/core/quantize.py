"""Quantize / dequantize primitives shared by block-wise and LoRDS paths.

Storage format
--------------
Codes are indices into a codebook (``repro.core.lut``).  On disk / in HBM we
pack them along the last axis:

  * 4-bit codebooks (nf4/int4/fp4): 2 codes per uint8  (low nibble first)
  * 2-bit codebooks (nf2/int2):     4 codes per uint8
  * 3-bit / 8-bit:                  1 code  per uint8  (3-bit is only used in
    mixed-precision schedules where layers are individually nf4 or nf2; an
    nf3 codebook is available but stored unpacked)

All functions are jit-friendly and differentiable where meaningful.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut
from repro.core.scaling import SCALE_EPS

__all__ = [
    "nearest_code",
    "quantize_codes",
    "dequantize_codes",
    "pack_codes",
    "unpack_codes",
    "packed_dim",
    "codes_per_byte",
    "fake_quant",
    "quantize_blockwise",
    "dequantize_blockwise",
]


def nearest_code(x: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Index of the nearest codebook level for each element of ``x``.

    Implemented with ``searchsorted`` over the level midpoints — exact
    nearest-neighbour for a sorted 1-D codebook, O(log L) per element.
    """
    mids = lut.midpoints(codebook_name).astype(x.dtype)
    return jnp.searchsorted(mids, x, side="left").astype(jnp.uint8)


def quantize_codes(
    w: jnp.ndarray, s: jnp.ndarray, codebook_name: str
) -> jnp.ndarray:
    """Paper Alg. 1 quantization step: Q_ij = argmin_v (S_ij * v - W_ij)^2.

    For s != 0 this equals nearest-level rounding of w/s (the s^2 factor does
    not change the argmin); for s < 0 the division flips the ordering, which
    nearest-neighbour on w/s handles automatically.
    """
    safe = jnp.where(jnp.abs(s) < SCALE_EPS, SCALE_EPS, s)
    ratio = (w / safe).astype(jnp.float32)
    return nearest_code(ratio, codebook_name)


def dequantize_codes(
    codes: jnp.ndarray, s: jnp.ndarray, codebook_name: str, dtype=None
) -> jnp.ndarray:
    """W_hat = codebook[codes] * S."""
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    out = vals * s
    return out.astype(dtype) if dtype is not None else out


def codes_per_byte(codebook_name: str) -> int:
    """Pack factor per uint8 — the single source of the bits->pack map."""
    bits = lut.codebook_bits(codebook_name)
    return {8: 1, 4: 2, 3: 1, 2: 4}[bits]


def packed_dim(m: int, codebook_name: str) -> int:
    cpb = codes_per_byte(codebook_name)
    if m % cpb:
        raise ValueError(f"last dim {m} not divisible by pack factor {cpb}")
    return m // cpb


def pack_codes(codes: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Pack uint8 code indices along the last axis into uint8 bytes."""
    cpb = codes_per_byte(codebook_name)
    if cpb == 1:
        return codes.astype(jnp.uint8)
    bits = 8 // cpb
    *lead, m = codes.shape
    if m % cpb:
        raise ValueError(f"last dim {m} not divisible by pack factor {cpb}")
    grp = codes.reshape(*lead, m // cpb, cpb).astype(jnp.uint32)
    shifts = jnp.arange(cpb, dtype=jnp.uint32) * bits  # low nibble first
    packed = jnp.sum(grp << shifts[None, :], axis=-1)
    return packed.astype(jnp.uint8)


def unpack_codes(packed: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`; returns uint8 code indices."""
    cpb = codes_per_byte(codebook_name)
    if cpb == 1:
        return packed.astype(jnp.uint8)
    bits = 8 // cpb
    mask = jnp.uint8(2**bits - 1)
    *lead, mp = packed.shape
    shifts = jnp.arange(cpb, dtype=jnp.uint32) * bits
    grp = (packed[..., None].astype(jnp.uint32) >> shifts) & mask
    return grp.reshape(*lead, mp * cpb).astype(jnp.uint8)


def fake_quant(w: jnp.ndarray, s: jnp.ndarray, codebook_name: str) -> jnp.ndarray:
    """Non-differentiable fake quantization (see qat.py for the STE version)."""
    codes = quantize_codes(w, s, codebook_name)
    return dequantize_codes(codes, s, codebook_name, dtype=w.dtype)


# ---------------------------------------------------------------------------
# Block-wise convenience wrappers (the NF4/INT4 baseline format)
# ---------------------------------------------------------------------------


def quantize_blockwise(
    w: jnp.ndarray, block_size: int, codebook_name: str
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Standard block-wise quantization -> (packed codes, block scales)."""
    from repro.core.scaling import blockwise_scales, eff_block, expand_block_scales

    block_size = eff_block(w.shape[1], block_size)
    s_blk = blockwise_scales(w, block_size)
    s = expand_block_scales(s_blk, block_size)
    codes = quantize_codes(w, s, codebook_name)
    return pack_codes(codes, codebook_name), s_blk


def dequantize_blockwise(
    packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str,
    dtype=jnp.float32,
) -> jnp.ndarray:
    from repro.core.scaling import expand_block_scales

    codes = unpack_codes(packed, codebook_name)
    block_size = codes.shape[-1] // s_blk.shape[-1]
    s = expand_block_scales(s_blk, block_size).astype(dtype)
    return dequantize_codes(codes, s, codebook_name, dtype=dtype)
