"""Inverse standard-normal CDF (quantile function) without scipy.

Peter Acklam's rational approximation (relative error < 1.15e-9), refined by
one Halley step using an erf-based CDF — plenty for building NFk codebooks.
"""
from __future__ import annotations

import math

import numpy as np

_A = (
    -3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
    1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00,
)
_B = (
    -5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
    6.680131188771972e01, -1.328068155288572e01,
)
_C = (
    -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
    -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00,
)
_D = (
    7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
    3.754408661907416e00,
)

_P_LOW = 0.02425
_P_HIGH = 1 - _P_LOW


def _acklam(p: float) -> float:
    if p <= 0.0:
        return -math.inf
    if p >= 1.0:
        return math.inf
    if p < _P_LOW:
        q = math.sqrt(-2 * math.log(p))
        return (
            ((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]
        ) / ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1)
    if p <= _P_HIGH:
        q = p - 0.5
        r = q * q
        return (
            (((((_A[0] * r + _A[1]) * r + _A[2]) * r + _A[3]) * r + _A[4]) * r + _A[5])
            * q
            / (((((_B[0] * r + _B[1]) * r + _B[2]) * r + _B[3]) * r + _B[4]) * r + 1)
        )
    q = math.sqrt(-2 * math.log(1 - p))
    return -(
        ((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4]) * q + _C[5]
    ) / ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1)


def _refine(x: float, p: float) -> float:
    if not math.isfinite(x):
        return x
    # one Halley step: e = CDF(x) - p, u = e / pdf(x)
    e = 0.5 * math.erfc(-x / math.sqrt(2)) - p
    u = e * math.sqrt(2 * math.pi) * math.exp(x * x / 2)
    return x - u / (1 + x * u / 2)


def ppf(p):
    """Vectorized inverse normal CDF."""
    arr = np.asarray(p, dtype=np.float64)
    out = np.array([_refine(_acklam(float(v)), float(v)) for v in arr.ravel()])
    return out.reshape(arr.shape)
