"""Sensitivity-driven per-layer bit/rank allocation (ROADMAP "sub-4-bit
frontier": adaptive rank-and-bitwidth under a global bytes budget).

The paper's mixed-precision schedules (Table 3) assign codebooks by layer
*position*; this module assigns them by measured *sensitivity*.  For every
layer and every (codebook, rank) candidate we score a diagonal-Fisher proxy
of the loss damage quantization does:

    err(layer, cb, r) = Σ_j  E[x_j²] · Σ_i (Ŵ_ij − W_ij)²

i.e. the output-MSE of the quantized linear under the calibration activation
second moments (``col_weight = E[x²]``, the same statistic ``ptq_stream``
already accumulates; without calibration it degrades to plain weight MSE).
Ŵ uses the standard LoRDS init (block scales → truncated-SVD S = B·A →
nearest-level codes) — cheap and deterministic, no refinement loop — so a
full llama-scale sweep is a few seconds of eval work.

Allocation is a greedy marginal-utility knapsack:

  1. every layer starts at its smallest candidate (fewest bytes),
  2. repeatedly apply the single upgrade with the best Δerror/Δbytes ratio
     anywhere in the model,
  3. stop when the best upgrade no longer fits the remaining budget.

Stopping at the first non-fitting upgrade (instead of skipping to a cheaper
one) makes the upgrade sequence for a larger budget a strict extension of
the sequence for a smaller one — total error is provably non-increasing in
the budget, which the unit tests pin down.

The result maps straight onto the rest of the stack: ``AllocPlan.specs()``
emits per-layer :class:`repro.core.lords.QuantSpec` (which ``dispatch``
already keys tiles and autotune entries on), and ``ptq_stream.StreamPlan``
accepts the same per-matrix overrides (fingerprinted, so mixed-precision
artifacts never alias uniform ones).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import lut, quantize
from repro.core.scaling import lords_init_from_weight, scale_matrix

__all__ = [
    "Candidate",
    "LayerAlloc",
    "AllocPlan",
    "layer_bytes",
    "sensitivity_error",
    "layer_candidates",
    "allocate",
]

DEFAULT_CODEBOOKS = ("nf2", "nf3", "nf4")
DEFAULT_RANKS = (4, 8, 16)


def layer_bytes(n: int, k: int, codebook: str, rank: int,
                scale_bytes: int = 4) -> int:
    """Stored bytes of one LoRDS linear: packed codes + the (B, A) factors."""
    ps = quantize.pack_spec(codebook)
    return n * ps.packed_width(k) + rank * (n + k) * scale_bytes


@dataclasses.dataclass(frozen=True)
class Candidate:
    codebook: str
    rank: int
    bytes: int
    error: float


@dataclasses.dataclass(frozen=True)
class LayerAlloc:
    name: str
    n: int
    k: int
    codebook: str
    rank: int
    bytes: int
    error: float


@dataclasses.dataclass(frozen=True)
class AllocPlan:
    layers: tuple[LayerAlloc, ...]
    budget: int
    total_bytes: int
    total_error: float

    def avg_bits(self) -> float:
        """Realized average storage bits/weight across the allocated layers
        (codes only — the low-rank factors are reported via bytes)."""
        weights = sum(l.n * l.k for l in self.layers)
        if not weights:
            return 0.0
        return sum(lut.codebook_bits(l.codebook) * l.n * l.k
                   for l in self.layers) / weights

    def by_name(self) -> dict[str, LayerAlloc]:
        return {l.name: l for l in self.layers}

    def specs(self, base=None) -> dict:
        """Per-layer QuantSpecs dispatch/serving configs consume directly."""
        from repro.core.lords import QuantSpec

        base = base or QuantSpec(method="lords")
        return {l.name: base.with_(codebook=l.codebook, rank=l.rank)
                for l in self.layers}


def sensitivity_error(
    w: jnp.ndarray,
    codebook: str,
    rank: int,
    col_weight: jnp.ndarray | None = None,
    block_size: int = 128,
) -> float:
    """Activation-weighted quantization error of one layer at (codebook,
    rank) — the diagonal-Fisher/∆loss proxy (see module docstring)."""
    b, a = lords_init_from_weight(w, block_size, rank=rank)
    s = scale_matrix(b, a)
    codes = quantize.quantize_codes(w, s, codebook)
    w_hat = quantize.dequantize_codes(codes, s, codebook, dtype=jnp.float32)
    sq = (w_hat - w.astype(jnp.float32)) ** 2
    if col_weight is not None:
        sq = sq * col_weight.astype(jnp.float32)[None, :]
    return float(jnp.sum(sq))


def layer_candidates(
    w: jnp.ndarray,
    col_weight: jnp.ndarray | None = None,
    *,
    codebooks=DEFAULT_CODEBOOKS,
    ranks=DEFAULT_RANKS,
    block_size: int = 128,
    scale_bytes: int = 4,
) -> list[Candidate]:
    """Pareto-pruned (bytes ↑, error ↓) candidate ladder for one layer.

    Dominated points (more bytes, no less error) are dropped, so walking the
    returned list left→right is exactly the layer's upgrade ladder.
    """
    n, k = w.shape
    cands = []
    for cb in codebooks:
        for r in ranks:
            r_eff = min(r, min(n, k))
            cands.append(Candidate(
                codebook=cb,
                rank=r_eff,
                bytes=layer_bytes(n, k, cb, r_eff, scale_bytes),
                error=sensitivity_error(w, cb, r_eff, col_weight,
                                        block_size),
            ))
    cands.sort(key=lambda c: (c.bytes, c.error))
    ladder: list[Candidate] = []
    for c in cands:
        if not ladder:
            ladder.append(c)
        elif c.error < ladder[-1].error and c.bytes > ladder[-1].bytes:
            ladder.append(c)
    return ladder


def allocate(
    weights: dict[str, jnp.ndarray],
    budget_bytes: int,
    *,
    col_weights: dict[str, jnp.ndarray] | None = None,
    codebooks=DEFAULT_CODEBOOKS,
    ranks=DEFAULT_RANKS,
    block_size: int = 128,
    scale_bytes: int = 4,
) -> AllocPlan:
    """Greedy best-Δerror/Δbytes allocation under a global bytes budget.

    Raises ``ValueError`` when even the all-minimum assignment exceeds the
    budget (the budget is infeasible, not merely tight).
    """
    col_weights = col_weights or {}
    names = list(weights)
    ladders = {
        name: layer_candidates(
            weights[name], col_weights.get(name),
            codebooks=codebooks, ranks=ranks,
            block_size=block_size, scale_bytes=scale_bytes)
        for name in names
    }
    level = {name: 0 for name in names}
    spent = sum(ladders[n][0].bytes for n in names)
    if spent > budget_bytes:
        raise ValueError(
            f"budget {budget_bytes} B infeasible: minimum assignment needs "
            f"{spent} B across {len(names)} layers")
    while True:
        best = None  # (ratio, name)
        for name in names:
            i = level[name]
            if i + 1 >= len(ladders[name]):
                continue
            cur, nxt = ladders[name][i], ladders[name][i + 1]
            dbytes = nxt.bytes - cur.bytes
            ratio = (cur.error - nxt.error) / dbytes
            if best is None or ratio > best[0]:
                best = (ratio, name)
        if best is None:
            break
        name = best[1]
        cur = ladders[name][level[name]]
        nxt = ladders[name][level[name] + 1]
        if spent + (nxt.bytes - cur.bytes) > budget_bytes:
            # stop at the first non-fitting upgrade: keeps the upgrade
            # sequence budget-monotone (see module docstring)
            break
        spent += nxt.bytes - cur.bytes
        level[name] += 1
    layers = []
    for name in names:
        c = ladders[name][level[name]]
        n, k = weights[name].shape
        layers.append(LayerAlloc(
            name=name, n=n, k=k, codebook=c.codebook, rank=c.rank,
            bytes=c.bytes, error=c.error))
    return AllocPlan(
        layers=tuple(layers),
        budget=budget_bytes,
        total_bytes=sum(l.bytes for l in layers),
        total_error=sum(l.error for l in layers),
    )
