"""Quantization-quality metrics used throughout the paper's tables.

* quantization error      = ‖W − Ŵ‖_*  (nuclear norm of the residual; §4.1)
* error reduction ratio   = 1 − ‖W − Ŵ‖_* / ‖W − nf4(W)‖_*  (Appendix B)
* effective rank of ΔW    — Fig. 3 / Appendix C (PEFT expressivity)
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "nuclear_norm",
    "quant_error",
    "error_reduction_ratio",
    "singular_values",
    "effective_rank",
    "frobenius_error",
]


def singular_values(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.svd(x.astype(jnp.float32), compute_uv=False)


def nuclear_norm(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(singular_values(x))


def quant_error(w: jnp.ndarray, w_hat: jnp.ndarray) -> jnp.ndarray:
    """‖W − Ŵ‖_* — the paper's QuantError (Table 2)."""
    return nuclear_norm(w.astype(jnp.float32) - w_hat.astype(jnp.float32))


def frobenius_error(w: jnp.ndarray, w_hat: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.norm(w.astype(jnp.float32) - w_hat.astype(jnp.float32))


def error_reduction_ratio(
    w: jnp.ndarray, w_hat: jnp.ndarray, w_hat_ref: jnp.ndarray
) -> jnp.ndarray:
    """1 − ‖W−Ŵ‖_*/‖W−Ŵ_ref‖_* ; ref is block-wise NF4 in the paper."""
    return 1.0 - quant_error(w, w_hat) / quant_error(w, w_hat_ref)


def effective_rank(x: jnp.ndarray, rel_tol: float = 1e-3) -> jnp.ndarray:
    """# singular values above rel_tol × σ_max — ΔW rank analysis (Fig. 3)."""
    s = singular_values(x)
    return jnp.sum(s > rel_tol * s[0])
