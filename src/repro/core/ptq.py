"""LoRDS PTQ — Algorithm 1: iterative refinement of the scaling manifold.

    min_{B,A,Q}  ‖ W − (B·A) ⊙ Q ‖_F²

alternating (per step t):
  1. Quantization step:  Q ← argmin_v (S·v − W)²  with S = BA fixed
     (= nearest codebook level of W ⊘ S, exactly — the S² factor cancels),
  2. Adaptation step:    one AdamW update of (B, A) on the MSE with Q fixed.

The whole loop is one ``lax.scan`` → jit-compiles once and runs fast; the
paper reports < 30 min for an 8B model on one A100 with T = 500, lr = 0.05.

Calibration hooks (used by the layer-streaming pipeline, repro.ptq_stream):
  * ``col_weight`` — per-input-channel weights (typically E[x_j²] from
    captured activations, a diagonal-Hessian proxy): the adaptation step
    minimizes the *activation-weighted* MSE, pushing (B, A) capacity toward
    the channels that matter for the layer's output.  The quantization step
    is untouched — per-element positive weights never change an
    element-wise argmin — so Q stays the exact nearest-level solution.
  * ``channel_scale`` — SmoothQuant/SmoothRot-style per-input-channel
    smoothing scales c_j, *folded into the S = BA init* instead of being a
    runtime transform: because S is element-wise, quantizing W against
    S₀ = blockscales(W ⊙ c) ⊘ c is identical to quantizing the smoothed
    weight W ⊙ c against its own block scales — the smoothing costs nothing
    at inference and the refinement is free to move away from it.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lut, scaling
from repro.core.quantize import pack_codes, quantize_codes

__all__ = ["ptq_refine", "PTQResult"]


class PTQResult(NamedTuple):
    b: jnp.ndarray
    a: jnp.ndarray
    q_packed: jnp.ndarray
    loss_history: jnp.ndarray  # (T,) recon MSE per step


class _AdamState(NamedTuple):
    mu_b: jnp.ndarray
    nu_b: jnp.ndarray
    mu_a: jnp.ndarray
    nu_a: jnp.ndarray


def _adam_update(g, mu, nu, step, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    mu = b1 * mu + (1 - b1) * g
    nu = b2 * nu + (1 - b2) * g * g
    mu_hat = mu / (1 - b1**step)
    nu_hat = nu / (1 - b2**step)
    upd = lr * (mu_hat / (jnp.sqrt(nu_hat) + eps))
    return upd, mu, nu


@partial(jax.jit, static_argnames=("codebook_name", "steps", "block_size",
                                   "rank", "extra_rank"))
def ptq_refine(
    w: jnp.ndarray,
    codebook_name: str = "nf4",
    block_size: int = 128,
    rank: int | None = None,
    extra_rank: int = 0,
    steps: int = 500,
    lr: float = 0.05,
    weight_decay: float = 0.0,
    col_weight: jnp.ndarray | None = None,
    channel_scale: jnp.ndarray | None = None,
) -> PTQResult:
    """Run Algorithm 1 on one weight matrix; returns refined (B, A, Q).

    ``col_weight`` (m,): activation-weighted adaptation (see module doc).
    ``channel_scale`` (m,): smoothing scales folded into the S init.
    """
    w = w.astype(jnp.float32)
    b0, a0 = scaling.lords_init_from_weight(
        w, block_size, rank=rank, extra_rank=extra_rank,
        channel_scale=channel_scale,
    )
    levels = lut.codebook(codebook_name)
    colw = (None if col_weight is None
            else col_weight.astype(jnp.float32)[None, :])

    def recon_loss(ba, qv):
        b, a = ba
        s = scaling.scale_matrix(b, a)
        err = (w - s * qv) ** 2
        if colw is not None:
            err = err * colw
        return jnp.mean(err)

    def step_fn(carry, t):
        b, a, st = carry
        # -- quantization step (Q fixed-point values, straight lookup) --
        s = scaling.scale_matrix(b, a)
        codes = quantize_codes(w, s, codebook_name)
        qv = jnp.take(levels, codes.astype(jnp.int32), axis=0)
        # -- adaptation step: one AdamW update of (B, A) --
        loss, (gb, ga) = jax.value_and_grad(recon_loss)((b, a), qv)
        ub, mu_b, nu_b = _adam_update(gb, st.mu_b, st.nu_b, t + 1, lr)
        ua, mu_a, nu_a = _adam_update(ga, st.mu_a, st.nu_a, t + 1, lr)
        b = b * (1 - lr * weight_decay) - ub
        a = a * (1 - lr * weight_decay) - ua
        return (b, a, _AdamState(mu_b, nu_b, mu_a, nu_a)), loss

    st0 = _AdamState(
        jnp.zeros_like(b0), jnp.zeros_like(b0),
        jnp.zeros_like(a0), jnp.zeros_like(a0),
    )
    (b, a, _), losses = jax.lax.scan(
        step_fn, (b0, a0, st0), jnp.arange(steps, dtype=jnp.float32)
    )
    # final quantization with the refined manifold
    s = scaling.scale_matrix(b, a)
    codes = quantize_codes(w, s, codebook_name)
    return PTQResult(b, a, pack_codes(codes, codebook_name), losses)
