"""LoRDS PTQ — Algorithm 1: iterative refinement of the scaling manifold.

    min_{B,A,Q}  ‖ W − (B·A) ⊙ Q ‖_F²

alternating (per step t):
  1. Quantization step:  Q ← argmin_v (S·v − W)²  with S = BA fixed
     (= nearest codebook level of W ⊘ S, exactly — the S² factor cancels),
  2. Adaptation step:    one AdamW update of (B, A) on the MSE with Q fixed.

The whole loop is one ``lax.scan`` → jit-compiles once and runs fast; the
paper reports < 30 min for an 8B model on one A100 with T = 500, lr = 0.05.

Calibration hooks (used by the layer-streaming pipeline, repro.ptq_stream):
  * ``col_weight`` — per-input-channel weights (typically E[x_j²] from
    captured activations, a diagonal-Hessian proxy): the adaptation step
    minimizes the *activation-weighted* MSE, pushing (B, A) capacity toward
    the channels that matter for the layer's output.  The quantization step
    is untouched — per-element positive weights never change an
    element-wise argmin — so Q stays the exact nearest-level solution.
  * ``channel_scale`` — SmoothQuant/SmoothRot-style per-input-channel
    smoothing scales c_j, *folded into the S = BA init* instead of being a
    runtime transform: because S is element-wise, quantizing W against
    S₀ = blockscales(W ⊙ c) ⊘ c is identical to quantizing the smoothed
    weight W ⊙ c against its own block scales — the smoothing costs nothing
    at inference and the refinement is free to move away from it.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lut, scaling
from repro.core.quantize import pack_codes, quantize_codes

__all__ = ["ptq_refine", "ptq_refine_chunked", "virtual_shards", "PTQResult"]


class PTQResult(NamedTuple):
    b: jnp.ndarray
    a: jnp.ndarray
    q_packed: jnp.ndarray
    loss_history: jnp.ndarray  # (T,) recon MSE per step


class _AdamState(NamedTuple):
    mu_b: jnp.ndarray
    nu_b: jnp.ndarray
    mu_a: jnp.ndarray
    nu_a: jnp.ndarray


def _adam_update(g, mu, nu, step, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    mu = b1 * mu + (1 - b1) * g
    nu = b2 * nu + (1 - b2) * g * g
    mu_hat = mu / (1 - b1**step)
    nu_hat = nu / (1 - b2**step)
    upd = lr * (mu_hat / (jnp.sqrt(nu_hat) + eps))
    return upd, mu, nu


@partial(jax.jit, static_argnames=("codebook_name", "steps", "block_size",
                                   "rank", "extra_rank"))
def ptq_refine(
    w: jnp.ndarray,
    codebook_name: str = "nf4",
    block_size: int = 128,
    rank: int | None = None,
    extra_rank: int = 0,
    steps: int = 500,
    lr: float = 0.05,
    weight_decay: float = 0.0,
    col_weight: jnp.ndarray | None = None,
    channel_scale: jnp.ndarray | None = None,
) -> PTQResult:
    """Run Algorithm 1 on one weight matrix; returns refined (B, A, Q).

    ``col_weight`` (m,): activation-weighted adaptation (see module doc).
    ``channel_scale`` (m,): smoothing scales folded into the S init.
    """
    w = w.astype(jnp.float32)
    b0, a0 = scaling.lords_init_from_weight(
        w, block_size, rank=rank, extra_rank=extra_rank,
        channel_scale=channel_scale,
    )
    levels = lut.codebook(codebook_name)
    colw = (None if col_weight is None
            else col_weight.astype(jnp.float32)[None, :])

    def recon_loss(ba, qv):
        b, a = ba
        s = scaling.scale_matrix(b, a)
        err = (w - s * qv) ** 2
        if colw is not None:
            err = err * colw
        return jnp.mean(err)

    def step_fn(carry, t):
        b, a, st = carry
        # -- quantization step (Q fixed-point values, straight lookup) --
        s = scaling.scale_matrix(b, a)
        codes = quantize_codes(w, s, codebook_name)
        qv = jnp.take(levels, codes.astype(jnp.int32), axis=0)
        # -- adaptation step: one AdamW update of (B, A) --
        loss, (gb, ga) = jax.value_and_grad(recon_loss)((b, a), qv)
        ub, mu_b, nu_b = _adam_update(gb, st.mu_b, st.nu_b, t + 1, lr)
        ua, mu_a, nu_a = _adam_update(ga, st.mu_a, st.nu_a, t + 1, lr)
        b = b * (1 - lr * weight_decay) - ub
        a = a * (1 - lr * weight_decay) - ua
        return (b, a, _AdamState(mu_b, nu_b, mu_a, nu_a)), loss

    st0 = _AdamState(
        jnp.zeros_like(b0), jnp.zeros_like(b0),
        jnp.zeros_like(a0), jnp.zeros_like(a0),
    )
    (b, a, _), losses = jax.lax.scan(
        step_fn, (b0, a0, st0), jnp.arange(steps, dtype=jnp.float32)
    )
    # final quantization with the refined manifold
    s = scaling.scale_matrix(b, a)
    codes = quantize_codes(w, s, codebook_name)
    return PTQResult(b, a, pack_codes(codes, codebook_name), losses)


def virtual_shards(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= ``want`` (>= 1).

    The chunked refine folds partial sums over a *fixed* virtual-shard count
    so the arithmetic is independent of how many physical devices run it;
    the count must divide the row dimension exactly."""
    ns = max(1, min(int(want), int(dim)))
    while dim % ns:
        ns -= 1
    return ns


@partial(jax.jit, static_argnames=("codebook_name", "steps", "block_size",
                                   "rank", "extra_rank", "nshard"))
def ptq_refine_chunked(
    w: jnp.ndarray,
    codebook_name: str = "nf4",
    block_size: int = 128,
    rank: int | None = None,
    extra_rank: int = 0,
    steps: int = 500,
    lr: float = 0.05,
    weight_decay: float = 0.0,
    col_weight: jnp.ndarray | None = None,
    channel_scale: jnp.ndarray | None = None,
    nshard: int = 1,
) -> PTQResult:
    """Algorithm 1 with *canonical chunked arithmetic*: bit-identical on any
    device count.

    The rows of ``w`` are split into ``nshard`` fixed virtual shards
    (``nshard`` must divide ``n`` — see :func:`virtual_shards`).  Everything
    row-local (quantization step, ∂loss/∂B, B's Adam state) is computed
    per-chunk under ``vmap``; the only cross-chunk quantities — the loss and
    ∂loss/∂A, whose reduction order is what normally changes with sharding —
    are combined by an explicitly *ordered left fold* over chunk partials.
    A mesh only changes where chunks live (`device_put` of the chunk axis),
    never the arithmetic, so a single-host run and an 8-device run of the
    same ``nshard`` produce byte-identical (B, A, Q).  ``nshard`` is part of
    the numerical program and is fingerprinted by callers (StreamPlan).
    """
    w = w.astype(jnp.float32)
    n, m = w.shape
    if n % nshard:
        raise ValueError(f"nshard {nshard} does not divide rows {n}")
    b0, a0 = scaling.lords_init_from_weight(
        w, block_size, rank=rank, extra_rank=extra_rank,
        channel_scale=channel_scale,
    )
    levels = lut.codebook(codebook_name)
    colw = (None if col_weight is None
            else col_weight.astype(jnp.float32)[None, :])
    wc = w.reshape(nshard, n // nshard, m)
    bc0 = b0.reshape(nshard, n // nshard, -1)
    denom = jnp.float32(n * m)

    def fold(parts):
        # ordered left fold over the chunk axis — THE canonical reduction
        acc = parts[0]
        for i in range(1, nshard):
            acc = acc + parts[i]
        return acc

    def chunk_grads(wc_i, bc_i, a, qv_i):
        def local_loss(ba):
            bb, aa = ba
            s = scaling.scale_matrix(bb, aa)
            err = (wc_i - s * qv_i) ** 2
            if colw is not None:
                err = err * colw
            return jnp.sum(err)
        return jax.value_and_grad(local_loss)((bc_i, a))

    def step_fn(carry, t):
        bc, a, st = carry
        # -- quantization step: row-local, runs per chunk --
        s = jax.vmap(scaling.scale_matrix, in_axes=(0, None))(bc, a)
        codes = quantize_codes(wc, s, codebook_name)
        qv = jnp.take(levels, codes.astype(jnp.int32), axis=0)
        # -- adaptation step: per-chunk partials, ordered cross-chunk fold --
        losses_c, (gbs, gas) = jax.vmap(
            chunk_grads, in_axes=(0, 0, None, 0))(wc, bc, a, qv)
        loss = fold(losses_c) / denom
        gb = gbs / denom              # row-local: stays chunked
        ga = fold(gas) / denom        # cross-chunk: ordered fold
        ub, mu_b, nu_b = _adam_update(gb, st.mu_b, st.nu_b, t + 1, lr)
        ua, mu_a, nu_a = _adam_update(ga, st.mu_a, st.nu_a, t + 1, lr)
        bc = bc * (1 - lr * weight_decay) - ub
        a = a * (1 - lr * weight_decay) - ua
        return (bc, a, _AdamState(mu_b, nu_b, mu_a, nu_a)), loss

    st0 = _AdamState(
        jnp.zeros_like(bc0), jnp.zeros_like(bc0),
        jnp.zeros_like(a0), jnp.zeros_like(a0),
    )
    (bc, a, _), losses = jax.lax.scan(
        step_fn, (bc0, a0, st0), jnp.arange(steps, dtype=jnp.float32)
    )
    # final quantization with the refined manifold (row-local per chunk)
    s = jax.vmap(scaling.scale_matrix, in_axes=(0, None))(bc, a)
    codes = quantize_codes(wc, s, codebook_name).reshape(n, m)
    b = bc.reshape(n, -1)
    return PTQResult(b, a, pack_codes(codes, codebook_name), losses)
