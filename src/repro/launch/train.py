"""End-to-end training driver (PEFT / QAT / full) with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --steps 300 \
        --d-model 256 --layers 4 ...   # reduced dims for CPU runs

Production path: real mesh via ``make_production_mesh``, checkpoint/restore
via ``repro.checkpoint``, preemption-safe, straggler-monitored, deterministic
restartable data pipeline.  On this CPU container it runs reduced configs end
to end (examples/finetune_peft.py drives a ~100M-param model this way).

XLA flags for real TPU runs (latency-hiding overlap of the collectives the
dry-run surfaces) are in ``TPU_PERF_FLAGS`` — applied when backend == tpu.
"""
from __future__ import annotations

import argparse
import os
import time

TPU_PERF_FLAGS = (
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_tpu_data_parallel_opt_different_sized_ops=true"
)

import jax

if jax.default_backend() == "cpu":
    os.environ.setdefault("REPRO_CPU_EXEC", "1")
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, ShapeCfg, get_config, smoke_variant
from repro.core import peft
from repro.data import SyntheticLM, make_batch_iterator
from repro.distributed.desync import desync_spread, replica_digests
from repro.distributed.fault_tolerance import PreemptionGuard, StragglerMonitor
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import build_plan
from repro.models import model_init, split_tree
from repro.optim import adamw_init


def run_training(cfg, shape_cfg, *, steps: int, lr: float = 1e-4,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 mesh=None, seed: int = 0, log_every: int = 10,
                 num_microbatches: int | None = None,
                 kernel_backend: str | None = None,
                 faults=None, grad_guard: bool = True,
                 rollback_after: int = 3, spike_factor: float = 10.0,
                 spike_warmup: int = 10, desync_every: int = 0,
                 max_mesh_rebuilds: int = 4, collective_retries: int = 2,
                 io_retries: int = 2, io_backoff: float = 0.05,
                 io_jitter: float = 0.0) -> dict:
    """Train ``cfg`` for ``steps``; returns final metrics + loss history.

    ``kernel_backend`` pins the quantized-matmul dispatch backend for the
    whole step — forward *and* backward: on the fused backends
    (pallas/interpret) QAT and PEFT steps run the fused custom-VJP kernels
    end to end and never materialize Ŵ (None = ambient default).

    ``mesh`` may be a real data×tensor-parallel mesh: the step then runs
    sharded (codes + B rows over 'model', dB/dA psum-reduced by the fused
    VJPs), checkpoints save per-shard, and restore resharding onto the
    plan's NamedShardings keeps resume bit-exact.

    **Hardening** (``grad_guard=True``): every update runs through
    :func:`repro.optim.guarded_update` behind a per-step spike threshold —
    ``spike_factor`` × an EMA of accepted grad norms (disabled for the
    first ``spike_warmup`` accepted steps).  A non-finite or spiking
    gradient *skips* the update in-graph (params + optimizer state
    untouched, counted in ``skipped_steps``); after ``rollback_after``
    consecutive skips the loop restores the latest checkpoint — optimizer
    state and data position included — and resumes from there
    (``rollbacks``).  ``faults`` (a :class:`repro.robustness.FaultPlan`)
    can force the detector via the ``train.grad_spike`` point: on a fire
    the threshold drops to -1 so that step is guaranteed to skip —
    deterministic detector-path coverage without needing a batch that
    organically produces NaNs.  Threaded as a traced scalar, so the guard
    never recompiles.

    **Elastic recovery** (the ``dist.*`` fault points, all zero-cost under
    ``NO_FAULTS``): on ``dist.device_loss`` the loop rebuilds a smaller
    host mesh (data axis halves first — weight shards must still fit, per
    ``elastic_mesh_shape``), re-jits the step plan, and reshards state onto
    it — from the latest checkpoint when one exists (elastic restore +
    data-iterator reseek, counted in ``resharded_restores``), else by
    ``device_put`` of the live state.  ``dist.collective_timeout`` retries
    the step launch (bounded by ``collective_retries``);
    ``dist.host_crash`` raises :class:`InjectedFault` with no graceful
    save — the crash drill resumes via a fresh ``run_training`` on the same
    ``ckpt_dir``.  ``desync_every`` > 0 enables the cross-replica state
    digest (:mod:`repro.distributed.desync`) every N completed steps: any
    spread quarantines the run and rolls back to the latest checkpoint
    (no checkpoint → status ``quarantined``, run stops).  Recovery
    counters (``mesh_rebuilds``, ``lost_devices``, ``resharded_restores``,
    ``desyncs_detected``, ``desync_rollbacks``, ``collective_timeouts``)
    come back in the results dict.
    """
    from repro.robustness import NO_FAULTS, InjectedFault
    faults = faults or NO_FAULTS
    mesh = mesh or make_host_mesh()

    def _build(m):
        plan = build_plan(cfg, m, shape_cfg, lr=lr,
                          num_microbatches=num_microbatches,
                          kernel_backend=kernel_backend,
                          grad_guard=grad_guard)
        step_jit = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                           out_shardings=plan.out_shardings,
                           donate_argnums=plan.donate_argnums)
        ckpt_sh = {"trainable": plan.in_shardings[0],
                   "opt": plan.in_shardings[2],
                   "data_step": NamedSharding(m, PartitionSpec())}
        return plan, step_jit, ckpt_sh

    plan, step_jit, ckpt_sh = _build(mesh)
    print(f"[train] plan {plan.name} mode={plan.meta['mode']} "
          f"kernels={plan.meta['kernel_backend']} "
          f"mesh={plan.meta['sharding']['mesh']}")

    key = jax.random.PRNGKey(seed)
    values, _ = split_tree(model_init(key, cfg))
    trainable, frozen = peft.partition(values, cfg.quant)
    opt = adamw_init(trainable)

    ckpt = (Checkpointer(ckpt_dir, io_retries=io_retries,
                         io_backoff=io_backoff, io_jitter=io_jitter)
            if ckpt_dir else None)
    start_step = 0
    if ckpt is not None:
        # restore straight onto the plan's shardings: on a multi-device mesh
        # the per-shard .npy files land back on their devices (bit-exact
        # resume); on the 1×1 host mesh this degenerates to device_put
        restored = ckpt.restore({"trainable": trainable, "opt": opt,
                                 "data_step": 0}, shardings=ckpt_sh)
        if restored is not None:
            trainable, opt = restored["trainable"], restored["opt"]
            start_step = int(restored["data_step"])
            print(f"[train] resumed from step {start_step}")

    source = SyntheticLM(cfg.vocab_size, shape_cfg.seq_len,
                         shape_cfg.global_batch, seed=seed)
    it = make_batch_iterator(source, start_step)

    guard = PreemptionGuard()
    mon = StragglerMonitor()
    losses = []
    gnorm_ema = None
    accepted = 0
    consecutive_skips = 0
    skipped_steps = 0
    rollbacks = 0
    done = 0
    status = "complete"
    mesh_rebuilds = 0
    lost_devices = 0
    resharded_restores = 0
    desyncs_detected = 0
    desync_rollbacks = 0
    collective_timeouts = 0
    straggler_injected: list[tuple[int, int]] = []
    dist_on = faults.enabled  # skip every dist.* consult under NO_FAULTS

    def _restore_latest(reason: str):
        """Elastic restore of the latest checkpoint onto the *current*
        plan's shardings + data-iterator reseek; returns True on success."""
        nonlocal trainable, opt, it, gnorm_ema, accepted, consecutive_skips
        if ckpt is None or ckpt.latest_step() is None:
            return False
        restored = ckpt.restore(
            {"trainable": trainable, "opt": opt, "data_step": 0},
            shardings=ckpt_sh)
        trainable, opt = restored["trainable"], restored["opt"]
        it = make_batch_iterator(source, int(restored["data_step"]))
        gnorm_ema, accepted, consecutive_skips = None, 0, 0
        print(f"[train] {reason} — restored step "
              f"{int(restored['data_step'])}", flush=True)
        return True

    rebuild = False
    while done < steps and status == "complete":
        if rebuild:
            # device loss: shrink the mesh (data axis first — the model
            # axis is sized so weight shards fit) and reshard onto it.
            shape = dict(mesh.shape)
            data, model = shape.get("data", 1), shape.get("model", 1)
            if data > 1:
                new_data, new_model = max(1, data // 2), model
            else:
                new_data, new_model = data, max(1, model // 2)
            lost_devices += data * model - new_data * new_model
            mesh = make_host_mesh(data=new_data, model=new_model)
            plan, step_jit, ckpt_sh = _build(mesh)
            mesh_rebuilds += 1
            print(f"[train] device loss — rebuilt mesh "
                  f"{data}x{model} -> {new_data}x{new_model}", flush=True)
            if _restore_latest("elastic restore"):
                resharded_restores += 1
            else:
                # no checkpoint yet: reshard the live state onto the new
                # mesh (elastic device_put — bytes unchanged)
                trainable = jax.device_put(trainable, plan.in_shardings[0])
                frozen = jax.device_put(frozen, plan.in_shardings[1])
                opt = jax.device_put(opt, plan.in_shardings[2])
            rebuild = False
        n_data = dict(mesh.shape).get("data", 1)
        with mesh:
            while done < steps:
                if dist_on and faults.fires("dist.device_loss") \
                        and mesh.devices.size > 1 \
                        and mesh_rebuilds < max_mesh_rebuilds:
                    rebuild = True
                    break
                if dist_on and faults.fires("dist.host_crash"):
                    # whole-process crash: no graceful save — the driver
                    # restarts run_training on the same ckpt_dir
                    raise InjectedFault(
                        f"injected host crash at step count {done}")
                step, batch = next(it)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                mon.start_step()
                if dist_on:
                    for s in range(n_data):  # per-shard straggler streams
                        if faults.fires("dist.straggler", index=s):
                            straggler_injected.append((step, s))
                if grad_guard:
                    if faults.fires("train.grad_spike"):
                        thr = -1.0          # detector fires unconditionally
                    elif gnorm_ema is None or accepted < spike_warmup:
                        thr = float("inf")  # no baseline yet
                    else:
                        thr = spike_factor * gnorm_ema
                    args = (trainable, frozen, opt, batch, jnp.float32(thr))
                else:
                    args = (trainable, frozen, opt, batch)
                attempts = 0
                while dist_on and faults.fires("dist.collective_timeout"):
                    collective_timeouts += 1
                    attempts += 1
                    if attempts > collective_retries:
                        raise InjectedFault(
                            "collective timeout persisted past "
                            f"{collective_retries} retries (step {step})")
                trainable, opt, metrics = step_jit(*args)
                loss = float(metrics["loss"])
                skipped = bool(
                    float(metrics.get("update_skipped", 0.0)) > 0.5)
                mon.end_step(step)
                done += 1
                if skipped:
                    skipped_steps += 1
                    consecutive_skips += 1
                    print(f"[train] step {step:5d} SKIPPED "
                          f"(grad_norm {float(metrics['grad_norm']):.3g} "
                          f"> threshold {thr:.3g})", flush=True)
                    if consecutive_skips >= rollback_after:
                        if _restore_latest(
                                f"{rollback_after} consecutive skips"):
                            rollbacks += 1
                    continue
                consecutive_skips = 0
                gn = float(metrics["grad_norm"])
                if np.isfinite(gn):
                    gnorm_ema = gn if gnorm_ema is None \
                        else 0.9 * gnorm_ema + 0.1 * gn
                    accepted += 1
                losses.append(loss)
                if step % log_every == 0:
                    print(f"[train] step {step:5d} loss {loss:.4f}",
                          flush=True)
                if ckpt is not None and (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1,
                              {"trainable": trainable, "opt": opt,
                               "data_step": step + 1})
                if desync_every > 0 and done % desync_every == 0:
                    digests = replica_digests((trainable, opt), n_data,
                                              faults=faults, step=step)
                    if desync_spread(digests) > 0.0:
                        desyncs_detected += 1
                        if _restore_latest("replica desync detected"):
                            desync_rollbacks += 1
                        else:
                            status = "quarantined"
                            print("[train] desync with no checkpoint — "
                                  "quarantining run", flush=True)
                            break
                if guard.preempted:
                    print("[train] preemption signal — checkpoint & "
                          "clean exit")
                    if ckpt is not None:
                        ckpt.save(step + 1,
                                  {"trainable": trainable, "opt": opt,
                                   "data_step": step + 1})
                    status = "preempted"
                    break
    return {"losses": losses, "trainable": trainable, "frozen": frozen,
            "straggler_flags": mon.flags, "skipped_steps": skipped_steps,
            "rollbacks": rollbacks, "status": status,
            "mesh_rebuilds": mesh_rebuilds, "lost_devices": lost_devices,
            "resharded_restores": resharded_restores,
            "desyncs_detected": desyncs_detected,
            "desync_rollbacks": desync_rollbacks,
            "collective_timeouts": collective_timeouts,
            "straggler_injected": straggler_injected,
            "final_mesh": dict(mesh.shape)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU)")
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mode", default=None, choices=["peft", "qat"],
                    help="override cfg.quant.mode for this run")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "interpret", "ref", "dense"],
                    help="pin the fused-kernel dispatch backend (fwd + bwd)")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="host mesh shape, e.g. 2x4 (needs that many visible "
                         "devices; on CPU force them via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--desync-every", type=int, default=0,
                    help="cross-replica state-digest cadence in steps "
                         "(0 = off)")
    ap.add_argument("--io-retries", type=int, default=2,
                    help="checkpoint IO retry attempts")
    ap.add_argument("--io-backoff", type=float, default=0.05,
                    help="checkpoint IO retry backoff base (s)")
    ap.add_argument("--io-jitter", type=float, default=0.0,
                    help="decorrelated-jitter fraction for IO retries "
                         "(0 = deterministic exponential)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
        shape = ShapeCfg("smoke", args.seq_len or 128,
                         args.global_batch or 8, "train")
    else:
        shape = SHAPES[args.shape]
        if args.seq_len or args.global_batch:
            shape = ShapeCfg(shape.name, args.seq_len or shape.seq_len,
                             args.global_batch or shape.global_batch, "train")
    if args.mode:
        cfg = cfg.with_(quant=cfg.quant.with_(mode=args.mode))
    mesh = None
    if args.mesh:
        data, model = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_host_mesh(data=data, model=model)
    t0 = time.time()
    out = run_training(cfg, shape, steps=args.steps, lr=args.lr,
                       ckpt_dir=args.ckpt_dir, mesh=mesh,
                       kernel_backend=args.kernel_backend,
                       desync_every=args.desync_every,
                       io_retries=args.io_retries,
                       io_backoff=args.io_backoff,
                       io_jitter=args.io_jitter)
    dt = time.time() - t0
    print(f"[train] done: {len(out['losses'])} steps in {dt:.1f}s; "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
