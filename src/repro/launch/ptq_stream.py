"""Streaming-PTQ launcher — quantize, resume, audit, self-check.

Runs the crash-safe layer-streaming pipeline (``repro.ptq_stream``) over a
disk-backed synthetic source (stand-in for a real checkpoint reader: dense
weights exist one block at a time).  Modes:

  default      quantize ``--model-dir`` into ``--out`` under ``--budget-mb``
  --resume     continue a killed/preempted run from its ledger (validates
               every prior block's checksum + activation digest first)
  --audit      read-only ledger/checksum/digest-chain audit of ``--out``
  --selfcheck  in-process crash/resume differential: kill the pipeline at
               a block boundary, mid-shard-write, and after a shard but
               before its ledger commit; corrupt a published shard; then
               resume each and assert the artifact is **bit-identical** to
               an uninterrupted run (exit 1 on any mismatch)

Fault flags (``--kill-at``, ``--kill-mid-write``, ``--corrupt-shard``)
inject a single deterministic fault for CI-style kill/resume drills:

  python -m repro.launch.ptq_stream --out /tmp/a --kill-at 1   # dies
  python -m repro.launch.ptq_stream --out /tmp/a --resume      # finishes
  python -m repro.launch.ptq_stream --out /tmp/a --audit       # clean
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.ptq_stream import (
    MemoryBudgetExceeded,
    ResidualMLPSource,
    StreamPlan,
    audit_artifact,
    read_shard,
    stream_quantize,
)
from repro.ptq_stream.shards import shard_name
from repro.robustness import NO_FAULTS, FaultPlan, InjectedFault


def _ensure_source(args) -> ResidualMLPSource:
    model_dir = args.model_dir or os.path.join(args.out, "model")
    if os.path.exists(os.path.join(model_dir, "source.json")):
        return ResidualMLPSource(model_dir)
    return ResidualMLPSource.create(
        model_dir, num_blocks=args.blocks, d=args.d, d_ff=args.dff,
        tokens=args.tokens, seed=args.model_seed)


def _plan(args) -> StreamPlan:
    budget = (None if args.budget_mb is None
              else int(args.budget_mb * 1024 * 1024))
    return StreamPlan(
        codebook=args.codebook, block_size=args.block_size, rank=args.rank,
        extra_rank=args.extra_rank, refine_steps=args.steps, lr=args.lr,
        seed=args.seed, pretransform=args.pretransform,
        smooth_alpha=args.smooth_alpha, act_weighted=not args.no_act_weighted,
        memory_budget=budget, calib_shards=args.calib_shards,
        io_retries=args.io_retries, io_backoff=args.io_backoff,
        io_jitter=args.io_jitter)


def _mesh(args):
    """``--mesh DxM`` → a data×model host mesh (needs that many visible
    devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8).
    The mesh is pure placement: artifacts stay byte-identical with or
    without it."""
    if args.mesh is None:
        return None
    from repro.launch.mesh import make_host_mesh
    try:
        data, model = (int(v) for v in args.mesh.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh wants DxM (e.g. 2x4), got {args.mesh!r}")
    return make_host_mesh(data=data, model=model)


def _faults(args):
    spec = {}
    if args.kill_at is not None:
        spec["ptq.kill_at_block"] = {"at": (args.kill_at,)}
    if args.kill_mid_write is not None:
        spec["ptq.kill_mid_write"] = {"at": (args.kill_mid_write,)}
    if args.corrupt_shard is not None:
        spec["ptq.corrupt_shard"] = {"at": (args.corrupt_shard,)}
    return FaultPlan(args.fault_seed, spec) if spec else NO_FAULTS


def _artifact_equal(dir_a: str, dir_b: str, num_blocks: int) -> bool:
    for i in range(num_blocks):
        a = read_shard(os.path.join(dir_a, shard_name(i)))
        b = read_shard(os.path.join(dir_b, shard_name(i)))
        if sorted(a) != sorted(b):
            return False
        for k in a:
            if not np.array_equal(a[k], b[k]):
                return False
    return True


def selfcheck(args) -> int:
    """Crash/resume differential at every fault class; 0 iff bit-identical."""
    src = _ensure_source(args)
    plan = _plan(args)
    ref_dir = os.path.join(args.out, "ref")
    s = stream_quantize(src, ref_dir, plan)
    print(f"[selfcheck] reference run: {s['status']} "
          f"peak={s['peak_bytes']} dense={src.dense_bytes()}")
    mid = src.num_blocks // 2
    scenarios = [
        ("kill_at_block", {"ptq.kill_at_block": {"at": (mid,)}}),
        ("kill_mid_write", {"ptq.kill_mid_write": {"at": (mid,)}}),
        ("kill_before_commit", {"ptq.kill_before_commit": {"at": (mid,)}}),
        ("corrupt_then_kill", {"ptq.corrupt_shard": {"at": (mid,)},
                               "ptq.kill_at_block": {"at": (mid + 1,)}}),
    ]
    failures = 0
    for name, spec in scenarios:
        out = os.path.join(args.out, name)
        try:
            stream_quantize(src, out, plan,
                            faults=FaultPlan(args.fault_seed, spec))
            print(f"[selfcheck] {name}: FAIL — injected fault never fired")
            failures += 1
            continue
        except InjectedFault:
            pass
        s = stream_quantize(src, out, plan, resume=True)
        aud = audit_artifact(out, src, plan)
        same = _artifact_equal(ref_dir, out, src.num_blocks)
        ok = s["status"] == "complete" and aud["clean"] and same
        print(f"[selfcheck] {name}: {'ok' if ok else 'FAIL'} "
              f"(resume reused={s['reused']} redone={s['recomputed']} "
              f"audit={aud['clean']} bit_identical={same})")
        failures += 0 if ok else 1
    print(f"[selfcheck] {'PASS' if not failures else 'FAIL'} "
          f"({len(scenarios) - failures}/{len(scenarios)} scenarios)")
    return 1 if failures else 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="ptq_stream_out")
    ap.add_argument("--model-dir", default=None,
                    help="dense source dir (default: <out>/model; a "
                         "synthetic source is generated if absent)")
    ap.add_argument("--blocks", type=int, default=6)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--dff", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--model-seed", type=int, default=0)
    ap.add_argument("--codebook", default="nf4")
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--rank", type=int, default=None)
    ap.add_argument("--extra-rank", type=int, default=0)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pretransform", default="none",
                    choices=["none", "smooth", "smoothrot"])
    ap.add_argument("--smooth-alpha", type=float, default=0.5)
    ap.add_argument("--no-act-weighted", action="store_true")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="hard memory budget; the watchdog fails fast "
                         "with a per-charge diagnostic when exceeded")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--audit", action="store_true")
    ap.add_argument("--selfcheck", action="store_true")
    ap.add_argument("--kill-at", type=int, default=None, metavar="N",
                    help="inject ptq.kill_at_block at consultation N")
    ap.add_argument("--kill-mid-write", type=int, default=None, metavar="N")
    ap.add_argument("--corrupt-shard", type=int, default=None, metavar="N")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard calibration data-parallel over a data x "
                         "model host mesh (placement only — bytes are "
                         "mesh-invariant)")
    ap.add_argument("--calib-shards", type=int, default=8,
                    help="virtual-shard count of the canonical chunked "
                         "calibration math (part of the fingerprint)")
    ap.add_argument("--io-retries", type=int, default=2)
    ap.add_argument("--io-backoff", type=float, default=0.02)
    ap.add_argument("--io-jitter", type=float, default=0.0,
                    help="decorrelated-jitter fraction for IO retry "
                         "backoff (0 = pure exponential)")
    args = ap.parse_args(argv)

    if args.selfcheck:
        sys.exit(selfcheck(args))

    src = _ensure_source(args)
    plan = _plan(args)
    if args.audit:
        aud = audit_artifact(args.out, src, plan)
        print(json.dumps(aud, indent=1))
        sys.exit(0 if aud["clean"] else 1)
    try:
        s = stream_quantize(src, args.out, plan, resume=args.resume,
                            faults=_faults(args), mesh=_mesh(args))
    except InjectedFault as e:
        print(f"[ptq-stream] injected fault fired: {e}")
        sys.exit(17)  # distinct code so drivers can tell kill from crash
    except MemoryBudgetExceeded as e:
        print(f"[ptq-stream] {e}")
        sys.exit(2)
    print(f"[ptq-stream] {s['status']}: {s['blocks_done']}/{s['num_blocks']} "
          f"blocks (reused {s['reused']}, redone {len(s['recomputed'])}) "
          f"peak {s['peak_bytes'] / 1e6:.2f} MB "
          f"vs dense {src.dense_bytes() / 1e6:.2f} MB "
          f"in {s['wall_s']:.1f}s -> {args.out}")


if __name__ == "__main__":
    main()
