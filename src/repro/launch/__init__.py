"""repro.launch — mesh, step builders, dry-run, train/serve drivers."""
