"""pjit-able train / serve steps + the machinery to build their shardings.

``build_plan(cfg, mesh, shape_cfg, ...)`` produces a StepPlan holding
  * abstract state (ShapeDtypeStructs — nothing allocated),
  * matching NamedSharding trees (in/out),
  * the step callable (closed over cfg + activation rules),
ready for ``jax.jit(...).lower(...).compile()`` (dry-run) or real execution.

Modes:
  * train: LoRDS-PEFT by default (trainable = B/A; frozen packed Q) — the
    paper's regime and the only one that fits 1T params on 512 v5e chips;
    ``cfg.quant.mode='qat'`` switches to full STE fake-quant training.
  * prefill: full-sequence forward, fills KV/SSM caches, returns last logits.
  * decode: one token with caches (the serve_step for decode shapes).

``build_generate_plan`` wraps the decode step in an on-device
``jax.lax.scan`` over the whole generation budget: one jit, one dispatch,
donated cache — decode cost becomes kernel-bound instead of paying a host
round-trip per token (the decode fast path the paper's §4.4 speedup needs).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import peft
from repro.distributed.sharding import make_rules, tree_shardings
from repro.kernels import dispatch
from repro.models import (
    activation_rules,
    cache_init,
    forward_decode,
    forward_decode_paged,
    forward_prefill,
    forward_prefill_chunk,
    forward_train,
    model_init,
    paged_cache_init,
    split_tree,
)
from repro.optim import adamw_init, adamw_update, guarded_update

__all__ = ["StepPlan", "build_plan", "build_generate_plan", "sample_token",
           "sample_token_guarded", "NONFINITE_TOKEN",
           "build_prefill_chunk_plan", "build_paged_generate_plan"]


def _meta_backend(kernel_backend: str | None) -> str:
    """Honest meta label: an explicit backend is pinned into the step via
    backend_scope; None re-resolves at trace time, so report it as auto."""
    return kernel_backend or f"auto:{dispatch.default_backend()}"


def _meta_attention(kernel_backend: str | None) -> str:
    """Which attention body the step traces: the fused flash kernels
    (qattention routes prefill + quantized-KV decode through Pallas) or the
    portable einsum oracle."""
    return ("fused" if dispatch.fused_backend_active(kernel_backend)
            else "einsum-ref")


def _meta_sharding(mesh, rules) -> dict:
    """Layout record for the plan: mesh shape, model parallelism (the degree
    the fused qmatmuls shard over inside the step's shard_scope), and the
    policy summary (codes-shard / factors-replicate + dropped rules)."""
    return dict(rules.summary(),
                mesh={k: int(v) for k, v in dict(mesh.shape).items()},
                model_parallel=int(dict(mesh.shape).get("model", 1)))


@dataclasses.dataclass
class StepPlan:
    name: str
    step_fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    rules: Any          # ShardingPolicy
    donate_argnums: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)


def _abstract_init(cfg, batch_example=None):
    key = jax.random.PRNGKey(0)
    ptree = jax.eval_shape(lambda k: model_init(k, cfg), key)
    return ptree


def _batch_specs(cfg, shape_cfg, mesh, rules, *, decode=False):
    b = shape_cfg.global_batch
    s = shape_cfg.seq_len
    batch_rule = rules.act_rules.get("batch")
    axes = tuple(a for a in ((batch_rule,) if isinstance(batch_rule, str)
                             else (batch_rule or ())) if a in mesh.shape)
    bsize = 1
    for a in axes:
        bsize *= mesh.shape[a]
    bspec = (axes if len(axes) > 1 else (axes[0] if axes else None)) \
        if (axes and b % max(bsize, 1) == 0) else None

    def sd(shape, dtype, spec):
        return (jax.ShapeDtypeStruct(shape, dtype),
                NamedSharding(mesh, PartitionSpec(*spec)))

    if decode:
        if cfg.input_kind == "tokens":
            tok, tok_sh = sd((b,), jnp.int32, (bspec,))
            batch = {"tokens": tok}
            bsh = {"tokens": tok_sh}
        else:
            e, e_sh = sd((b, 1, cfg.d_model), jnp.bfloat16, (bspec, None, None))
            batch = {"embeds": e}
            bsh = {"embeds": e_sh}
        pos, pos_sh = sd((b,), jnp.int32, (bspec,))
        return batch, bsh, pos, pos_sh

    if cfg.input_kind == "tokens":
        tok, tok_sh = sd((b, s), jnp.int32, (bspec, None))
        lab, lab_sh = sd((b, s), jnp.int32, (bspec, None))
        return {"tokens": tok, "labels": lab}, {"tokens": tok_sh, "labels": lab_sh}
    e, e_sh = sd((b, s, cfg.d_model), jnp.bfloat16, (bspec, None, None))
    lab, lab_sh = sd((b, s), jnp.int32, (bspec, None))
    return {"embeds": e, "labels": lab}, {"embeds": e_sh, "labels": lab_sh}


def _pick_microbatches(global_batch: int, dp: int, seq: int,
                       target_tokens: int = 8192) -> int:
    """Smallest divisor of the per-DP-shard batch that caps live tokens/device
    at ~target_tokens per microbatch (bounds the remat carry footprint)."""
    b_local = max(global_batch // max(dp, 1), 1)
    want = -(-b_local * seq // target_tokens)
    for n in range(1, b_local + 1):
        if b_local % n == 0 and n >= want:
            return n
    return b_local


def _plan_state(cfg, mesh, shape_cfg, kind, *, budget_gb, force_2d,
                seq_parallel=False):
    """Shared plan setup: sharding rules + abstract weights and their
    shardings (one code path for train / prefill / decode / generate, so
    the scan generation loop can never drift from the host-loop decode
    shardings it is parity-tested against)."""
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    seq_shard = (kind == "decode" and shape_cfg.global_batch < dp)
    rules = make_rules(cfg, mesh, kind, budget_gb=budget_gb,
                       force_2d=force_2d, seq_shard_cache=seq_shard,
                       seq_parallel=seq_parallel)
    dropped: list = []
    values, axes = split_tree(_abstract_init(cfg))
    shard_tree = tree_shardings(axes, values, rules.weight_rules, mesh, dropped)
    rules.dropped.extend(dropped)
    return rules, values, shard_tree


def _cache_state(cfg, mesh, shape_cfg, rules):
    """Abstract decode cache + its shardings (serving kinds only)."""
    cache_ptree = jax.eval_shape(
        lambda: cache_init(cfg, shape_cfg.global_batch, shape_cfg.seq_len))
    cache_vals, cache_axes = split_tree(cache_ptree)
    cache_sh = tree_shardings(cache_axes, cache_vals, rules.act_rules, mesh,
                              rules.dropped)
    return cache_vals, cache_sh


def build_plan(cfg, mesh, shape_cfg, *, lr: float = 1e-4,
               force_2d: bool | None = None, budget_gb: float = 8.0,
               num_microbatches: int | None = None,
               target_micro_tokens: int = 8192,
               seq_parallel: bool = False,
               kernel_backend: str | None = None,
               grad_guard: bool = False) -> StepPlan:
    """``kernel_backend`` pins the quantized-matmul dispatch backend for
    everything traced inside the produced step (None = ambient default:
    fused Pallas on TPU, interpret/ref per env flags elsewhere).

    ``grad_guard`` (train kind only) appends a scalar ``max_gnorm``
    argument to the step and routes the update through
    :func:`repro.optim.guarded_update`: a non-finite or
    above-threshold grad norm applies a *zero* update in-graph (params,
    moments and the step counter all keep their old values) and reports
    ``update_skipped`` in the metrics — the train loop's spike detector
    feeds the threshold and decides on checkpoint rollback."""
    kind = shape_cfg.kind
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    rules, values, shard_tree = _plan_state(
        cfg, mesh, shape_cfg, kind, budget_gb=budget_gb, force_2d=force_2d,
        seq_parallel=seq_parallel)

    if kind == "train":
        t_vals, f_vals = peft.partition(values, cfg.quant)
        t_sh, f_sh = peft.partition(shard_tree, cfg.quant)
        opt = jax.eval_shape(adamw_init, t_vals)
        rep = NamedSharding(mesh, PartitionSpec())
        opt_sh = type(opt)(mu=t_sh, nu=t_sh, step=rep)
        tgt = min(target_micro_tokens, cfg.micro_tokens)
        n_micro = (num_microbatches if num_microbatches is not None else
                   _pick_microbatches(shape_cfg.global_batch, dp,
                                      shape_cfg.seq_len, tgt))

        def train_step(trainable, frozen, opt_state, batch, max_gnorm=None):
            with activation_rules(rules.act_rules), \
                    dispatch.backend_scope(kernel_backend), \
                    dispatch.shard_scope(mesh):
                def loss_fn(t, mb):
                    params = peft.combine(t, frozen)
                    loss, metrics = forward_train(params, cfg, mb)
                    return loss, metrics

                if n_micro == 1:
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(trainable, batch)
                else:
                    # gradient accumulation over microbatches (memory: remat
                    # carries scale with the microbatch, not the global batch)
                    from repro.models.common import shard as shard_act

                    def split(x):
                        x = x.reshape(n_micro, x.shape[0] // n_micro,
                                      *x.shape[1:])
                        return shard_act(x, None, "batch")
                    micro = jax.tree.map(split, batch)
                    g0 = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), trainable)

                    def mb_body(carry, mb):
                        g_acc, loss_acc = carry
                        (loss, _), grads = jax.value_and_grad(
                            loss_fn, has_aux=True)(trainable, mb)
                        g_acc = jax.tree.map(
                            lambda a, g: a + g.astype(jnp.float32),
                            g_acc, grads)
                        return (g_acc, loss_acc + loss), None

                    (grads, loss_sum), _ = jax.lax.scan(
                        mb_body, (g0, jnp.zeros((), jnp.float32)), micro)
                    grads = jax.tree.map(lambda g: g / n_micro, grads)
                    loss = loss_sum / n_micro
                    metrics = {"loss": loss}
                if grad_guard:
                    new_t, new_opt, gnorm, ok = guarded_update(
                        trainable, grads, opt_state, lr, max_gnorm)
                else:
                    new_t, new_opt, gnorm = adamw_update(
                        trainable, grads, opt_state, lr)
                    ok = None
            metrics = dict(metrics, grad_norm=gnorm)
            if ok is not None:
                metrics["update_skipped"] = 1.0 - ok.astype(jnp.float32)
            return new_t, new_opt, metrics

        batch, batch_sh = _batch_specs(cfg, shape_cfg, mesh, rules)
        args = (t_vals, f_vals, opt, batch)
        shardings = (t_sh, f_sh, opt_sh, batch_sh)
        if grad_guard:
            args += (jax.ShapeDtypeStruct((), jnp.float32),)
            shardings += (NamedSharding(mesh, PartitionSpec()),)
        return StepPlan(
            name=f"train:{cfg.name}:{shape_cfg.name}",
            step_fn=train_step,
            abstract_args=args,
            in_shardings=shardings,
            out_shardings=(t_sh, opt_sh, None),
            rules=rules,
            donate_argnums=(0, 2),
            meta={"mode": cfg.quant.mode, "kind": kind,
                  "num_microbatches": n_micro, "grad_guard": grad_guard,
                  "kernel_backend": _meta_backend(kernel_backend),
                  "sharding": _meta_sharding(mesh, rules)},
        )

    # ---- serving ----
    cache_vals, cache_sh = _cache_state(cfg, mesh, shape_cfg, rules)

    if kind == "prefill":
        batch, batch_sh = _batch_specs(cfg, shape_cfg, mesh, rules)
        batch.pop("labels"), batch_sh.pop("labels")

        def prefill_step(params, batch, cache):
            # optional "positions" (b, s) rides in the batch dict: ragged
            # prompt lengths mask their padding out of the window (see
            # forward_prefill); absent = the aligned arange as before
            with activation_rules(rules.act_rules), \
                    dispatch.backend_scope(kernel_backend), \
                    dispatch.shard_scope(mesh):
                logits, new_cache = forward_prefill(
                    params, cfg, batch, cache, batch.get("positions"))
            return logits, new_cache

        return StepPlan(
            name=f"prefill:{cfg.name}:{shape_cfg.name}",
            step_fn=prefill_step,
            abstract_args=(values, batch, cache_vals),
            in_shardings=(shard_tree, batch_sh, cache_sh),
            out_shardings=(None, cache_sh),
            rules=rules,
            donate_argnums=(2,),
            meta={"kind": kind,
                  "kernel_backend": _meta_backend(kernel_backend),
                  "attention": _meta_attention(kernel_backend),
                  "sharding": _meta_sharding(mesh, rules)},
        )

    # decode
    batch, batch_sh, pos, pos_sh = _batch_specs(
        cfg, shape_cfg, mesh, rules, decode=True)

    def decode_step(params, batch, cache, pos):
        with activation_rules(rules.act_rules), \
                dispatch.backend_scope(kernel_backend), \
                dispatch.shard_scope(mesh):
            logits, new_cache = forward_decode(params, cfg, batch, cache, pos)
        return logits, new_cache

    return StepPlan(
        name=f"decode:{cfg.name}:{shape_cfg.name}",
        step_fn=decode_step,
        abstract_args=(values, batch, cache_vals, pos),
        in_shardings=(shard_tree, batch_sh, cache_sh, pos_sh),
        out_shardings=(None, cache_sh),
        rules=rules,
        donate_argnums=(2,),
        meta={"kind": kind,
              "kernel_backend": _meta_backend(kernel_backend),
              "attention": _meta_attention(kernel_backend),
              "sharding": _meta_sharding(mesh, rules)},
    )


# ---------------------------------------------------------------------------
# On-device generation loop (single jit over the whole decode budget)
# ---------------------------------------------------------------------------


def sample_token(logits, key, temperature: float):
    """Greedy (temperature <= 0) or temperature sampling over (b, V) logits."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


NONFINITE_TOKEN = -1


def sample_token_guarded(logits, key, temperature: float):
    """:func:`sample_token` plus the serving non-finite guard: rows whose
    logits contain a NaN/Inf emit :data:`NONFINITE_TOKEN` (-1) instead of a
    garbage sample.  The engine treats -1 as a per-slot poison marker and
    quarantines only that slot — the rest of the batch keeps decoding.  On
    finite logits this is bitwise ``sample_token`` (the ``where`` is an
    identity), so clean-run parity is untouched."""
    tok = sample_token(logits, key, temperature)
    ok = jnp.all(jnp.isfinite(logits.astype(jnp.float32)), axis=-1)
    return jnp.where(ok, tok, jnp.int32(NONFINITE_TOKEN))


def build_generate_plan(cfg, mesh, shape_cfg, *, gen: int,
                        temperature: float = 0.0,
                        force_2d: bool | None = None, budget_gb: float = 8.0,
                        kernel_backend: str | None = None) -> StepPlan:
    """A StepPlan whose step runs ``gen`` decode steps as one on-device
    ``lax.scan`` — the caller dispatches once, the cache never leaves the
    device, and per-token cost is the decode kernels, not Python.

    step_fn(params, tok0, cache, pos0, key, embeds0) -> (tokens (b, gen),
    cache).  ``tok0`` (b,) seeds the loop (usually argmax of the prefill
    logits); ``pos0`` (b,) may be ragged per sequence.  ``embeds0`` is the
    fixed per-step input for ``input_kind='embeddings'`` archs (frontends
    are stubbed) and None for token models.  Donate the cache (argnums 2)
    when jitting.
    """
    rules, values, shard_tree = _plan_state(
        cfg, mesh, shape_cfg, "decode", budget_gb=budget_gb,
        force_2d=force_2d)
    cache_vals, cache_sh = _cache_state(cfg, mesh, shape_cfg, rules)
    batch, batch_sh, pos, pos_sh = _batch_specs(
        cfg, shape_cfg, mesh, rules, decode=True)
    b = shape_cfg.global_batch
    tok0 = jax.ShapeDtypeStruct((b,), jnp.int32)
    key_arg = jax.ShapeDtypeStruct((2,), jnp.uint32)
    embeds0 = batch.get("embeds")

    def generate_step(params, tok0, cache, pos0, key, embeds0=None):
        with activation_rules(rules.act_rules), \
                dispatch.backend_scope(kernel_backend), \
                dispatch.shard_scope(mesh):
            def body(carry, _):
                tok, cache, pos, key = carry
                if cfg.input_kind == "tokens":
                    step_in = {"tokens": tok}
                else:
                    step_in = {"embeds": embeds0}
                logits, cache = forward_decode(params, cfg, step_in, cache,
                                               pos)
                key, sub = jax.random.split(key)
                nxt = sample_token(logits[:, -1, : cfg.vocab_size], sub,
                                   temperature)
                return (nxt, cache, pos + 1, key), nxt

            (_, cache, _, _), toks = jax.lax.scan(
                body, (tok0, cache, pos0, key), None, length=gen)
        return jnp.moveaxis(toks, 0, 1), cache  # (b, gen)

    return StepPlan(
        name=f"generate:{cfg.name}:{shape_cfg.name}:g{gen}",
        step_fn=generate_step,
        abstract_args=(values, tok0, cache_vals, pos, key_arg, embeds0),
        in_shardings=(shard_tree, pos_sh, cache_sh, pos_sh, None,
                      batch_sh.get("embeds")),
        out_shardings=(None, cache_sh),
        rules=rules,
        donate_argnums=(2,),
        meta={"kind": "generate", "gen": gen, "temperature": temperature,
              "kernel_backend": _meta_backend(kernel_backend),
              "attention": _meta_attention(kernel_backend),
              "sharding": _meta_sharding(mesh, rules)},
    )


# ---------------------------------------------------------------------------
# Paged serving steps (continuous-batching engine; launch/engine.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _PagedShape:
    """Minimal ShapeCfg stand-in for the paged plans (they key off explicit
    slots/pages arguments, not a named benchmark shape)."""
    seq_len: int
    global_batch: int
    kind: str
    name: str = "paged"


def _pool_state(cfg, mesh, rules, total_pages, page_size):
    """Abstract page pools + shardings (pages replicate over data, kv heads
    keep their model rule — see gqa_paged_cache_init)."""
    pools_ptree = jax.eval_shape(
        lambda: paged_cache_init(cfg, total_pages, page_size))
    vals, axes = split_tree(pools_ptree)
    sh = tree_shardings(axes, vals, rules.act_rules, mesh, rules.dropped)
    return vals, sh


def build_prefill_chunk_plan(cfg, mesh, *, slots: int, chunk: int,
                             total_pages: int, page_size: int,
                             max_pages: int, temperature: float = 0.0,
                             force_2d: bool | None = None,
                             budget_gb: float = 8.0,
                             kernel_backend: str | None = None) -> StepPlan:
    """One fixed-shape chunk of paged prefill over the whole slot batch.

    step_fn(params, tokens (slots, chunk), pools, pt (slots, max_pages),
    qpos (slots, chunk), pos0 (slots,), key) -> (tok1 (slots,), pools).
    Dead slots (qpos all -1, pt row all zeros) write only the dummy page
    and produce garbage tok1 the scheduler ignores; ``tok1`` is each row's
    token sampled from its last live logits — the first generated token for
    slots whose prompt ends in this chunk.  Donate pools (argnums 2)."""
    if chunk % page_size:
        raise ValueError(f"chunk {chunk} must be a multiple of the page "
                         f"size {page_size}")
    rules, values, shard_tree = _plan_state(
        cfg, mesh, _PagedShape(chunk, slots, "prefill"), "prefill",
        budget_gb=budget_gb, force_2d=force_2d)
    pool_vals, pool_sh = _pool_state(cfg, mesh, rules, total_pages,
                                     page_size)
    b = slots
    toks = jax.ShapeDtypeStruct((b, chunk), jnp.int32)
    pt = jax.ShapeDtypeStruct((b, max_pages), jnp.int32)
    qpos = jax.ShapeDtypeStruct((b, chunk), jnp.int32)
    pos0 = jax.ShapeDtypeStruct((b,), jnp.int32)
    key_arg = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def chunk_step(params, tokens, pools, pt, qpos, pos0, key):
        with activation_rules(rules.act_rules), \
                dispatch.backend_scope(kernel_backend), \
                dispatch.shard_scope(mesh):
            logits, pools = forward_prefill_chunk(
                params, cfg, {"tokens": tokens}, pools, pt, qpos, pos0)
            tok1 = sample_token_guarded(logits[:, -1, : cfg.vocab_size], key,
                                        temperature)
        return tok1, pools

    return StepPlan(
        name=f"chunk_prefill:{cfg.name}:b{slots}c{chunk}",
        step_fn=chunk_step,
        abstract_args=(values, toks, pool_vals, pt, qpos, pos0, key_arg),
        in_shardings=(shard_tree, None, pool_sh, None, None, None, None),
        out_shardings=(None, pool_sh),
        rules=rules,
        donate_argnums=(2,),
        meta={"kind": "chunk_prefill", "chunk": chunk,
              "page_size": page_size, "total_pages": total_pages,
              "kernel_backend": _meta_backend(kernel_backend),
              "attention": _meta_attention(kernel_backend),
              "sharding": _meta_sharding(mesh, rules)},
    )


def build_paged_generate_plan(cfg, mesh, *, slots: int, gen: int,
                              total_pages: int, page_size: int,
                              max_pages: int, temperature: float = 0.0,
                              force_2d: bool | None = None,
                              budget_gb: float = 8.0,
                              kernel_backend: str | None = None) -> StepPlan:
    """``gen`` paged decode steps as one on-device scan (the paged
    analogue of :func:`build_generate_plan`; gen=1 is the single decode
    step the engine interleaves with prefill chunks).

    step_fn(params, tok0 (slots,), pools, pt (slots, max_pages),
    pos0 (slots,), key) -> (tokens (slots, gen), pools).  The page table is
    fixed across the burst — the scheduler pre-allocates every page the
    burst can write, so mid-burst writes never land on an unmapped page
    (unmapped entries point at the dummy page 0, whose reads are masked).
    Dead slots run with pt row 0 / pos 0 and their tokens are ignored.
    Donate pools (argnums 2)."""
    rules, values, shard_tree = _plan_state(
        cfg, mesh, _PagedShape(max_pages * page_size, slots, "decode"),
        "decode", budget_gb=budget_gb, force_2d=force_2d)
    pool_vals, pool_sh = _pool_state(cfg, mesh, rules, total_pages,
                                     page_size)
    b = slots
    tok0 = jax.ShapeDtypeStruct((b,), jnp.int32)
    pt = jax.ShapeDtypeStruct((b, max_pages), jnp.int32)
    pos0 = jax.ShapeDtypeStruct((b,), jnp.int32)
    key_arg = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def generate_step(params, tok0, pools, pt, pos0, key):
        with activation_rules(rules.act_rules), \
                dispatch.backend_scope(kernel_backend), \
                dispatch.shard_scope(mesh):
            def body(carry, _):
                tok, pools, pos, key = carry
                logits, pools = forward_decode_paged(
                    params, cfg, {"tokens": tok}, pools, pt, pos)
                key, sub = jax.random.split(key)
                nxt = sample_token_guarded(logits[:, -1, : cfg.vocab_size],
                                           sub, temperature)
                # a quarantined (-1) row keeps scanning on token 0 so its
                # embedding lookup stays in range; the emitted -1 persists
                # (its KV history is poisoned, logits stay non-finite) and
                # the engine truncates at the first marker
                return (jnp.maximum(nxt, 0), pools, pos + 1, key), nxt

            (_, pools, _, _), toks = jax.lax.scan(
                body, (tok0, pools, pos0, key), None, length=gen)
        return jnp.moveaxis(toks, 0, 1), pools  # (slots, gen)

    return StepPlan(
        name=f"paged_generate:{cfg.name}:b{slots}g{gen}",
        step_fn=generate_step,
        abstract_args=(values, tok0, pool_vals, pt, pos0, key_arg),
        in_shardings=(shard_tree, None, pool_sh, None, None, None),
        out_shardings=(None, pool_sh),
        rules=rules,
        donate_argnums=(2,),
        meta={"kind": "paged_generate", "gen": gen,
              "page_size": page_size, "total_pages": total_pages,
              "temperature": temperature,
              "kernel_backend": _meta_backend(kernel_backend),
              "attention": _meta_attention(kernel_backend),
              "sharding": _meta_sharding(mesh, rules)},
    )
