"""Serving driver: batched prefill + decode with a quantized (LoRDS) model.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Request flow: a batch of prompts is prefilled once (cache build), then
decoded step by step with greedy sampling.  The model runs fully quantized
(packed Q + B·A scales) — the zero-overhead inference the paper claims,
since the PEFT-adapted scales live inside the dequant path.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

if jax.default_backend() == "cpu":
    os.environ.setdefault("REPRO_CPU_EXEC", "1")
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_plan
from repro.models import cache_init, model_init, split_tree


def serve_batch(cfg, *, batch: int, prompt_len: int, gen: int,
                mesh=None, seed: int = 0, params=None, prompts=None,
                kernel_backend: str | None = None) -> dict:
    """``kernel_backend`` selects the quantized-matmul path (pallas /
    interpret / ref / dense); None = platform default via the dispatch
    layer — fused Pallas kernels on TPU, oracles elsewhere."""
    mesh = mesh or make_host_mesh()
    capacity = prompt_len + gen
    prefill_shape = ShapeCfg("serve_prefill", capacity, batch, "prefill")
    decode_shape = ShapeCfg("serve_decode", capacity, batch, "decode")

    key = jax.random.PRNGKey(seed)
    if params is None:
        params, _ = split_tree(model_init(key, cfg))
    cache, _ = split_tree(cache_init(cfg, batch, capacity))

    pre_plan = build_plan(cfg, mesh, prefill_shape,
                          kernel_backend=kernel_backend)
    dec_plan = build_plan(cfg, mesh, decode_shape,
                          kernel_backend=kernel_backend)

    if prompts is None:
        prompts = np.random.default_rng(seed).integers(
            0, cfg.vocab_size, (batch, capacity)).astype(np.int32)
    else:
        pad = np.zeros((batch, capacity - prompts.shape[1]), np.int32)
        prompts = np.concatenate([prompts, pad], axis=1).astype(np.int32)

    with mesh:
        prefill = jax.jit(pre_plan.step_fn, donate_argnums=(2,))
        decode = jax.jit(dec_plan.step_fn, donate_argnums=(2,))

        t0 = time.time()
        if cfg.input_kind == "tokens":
            batch_in = {"tokens": jnp.asarray(prompts)}
        else:
            batch_in = {"embeds": jax.random.normal(
                key, (batch, capacity, cfg.d_model), jnp.bfloat16)}
        logits, cache = prefill(params, batch_in, cache)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1).astype(
            jnp.int32)
        generated = [np.asarray(tok)]
        t0 = time.time()
        for i in range(gen - 1):
            pos = jnp.full((batch,), prompt_len + i, jnp.int32)
            if cfg.input_kind == "tokens":
                step_in = {"tokens": tok}
            else:
                step_in = {"embeds": jax.random.normal(
                    key, (batch, 1, cfg.d_model), jnp.bfloat16)}
            logits, cache = decode(params, step_in, cache, pos)
            tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1).astype(
                jnp.int32)
            generated.append(np.asarray(tok))
        jax.block_until_ready(logits)
        t_decode = time.time() - t0

    toks = np.stack(generated, axis=1)
    return {
        "tokens": toks,
        "prefill_tok_s": batch * prompt_len / max(t_prefill, 1e-9),
        "decode_tok_s": batch * max(gen - 1, 1) / max(t_decode, 1e-9),
        "kernel_backend": pre_plan.meta["kernel_backend"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "interpret", "ref", "dense"],
                    help="quantized-matmul dispatch backend "
                         "(default: fused pallas on TPU, ref elsewhere)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    out = serve_batch(cfg, batch=args.batch, prompt_len=args.prompt_len,
                      gen=args.gen, kernel_backend=args.kernel_backend)
    print(f"[serve] backend={out['kernel_backend']} "
          f"prefill {out['prefill_tok_s']:.1f} tok/s, "
          f"decode {out['decode_tok_s']:.1f} tok/s")
    print("[serve] sample tokens:", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
