"""Serving driver: batched prefill + on-device decode with a quantized
(LoRDS) model.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Request flow: a batch of prompts is prefilled once (cache build), then the
whole generation budget runs as a *single jitted on-device loop*
(``jax.lax.scan`` over decode steps, donated cache) — one host dispatch for
all generated tokens, so decode cost is the fused kernels, not Python
round-trips.  The model runs fully quantized (packed Q + B·A scales), the
M<=8 matmuls hit the weight-stationary decode GEMV kernel, and with
``--kv-cache int8`` the KV cache is stored as per-head int8 + f32 scales
(~2x less cache HBM traffic per token at capacity).

``loop='host'`` keeps the legacy per-token Python loop as the parity
oracle: token-for-token identical output is asserted in the test suite.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

if jax.default_backend() == "cpu":
    os.environ.setdefault("REPRO_CPU_EXEC", "1")
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_generate_plan, build_plan, sample_token
from repro.models import cache_init, model_init, split_tree


def serve_batch(cfg, *, batch: int, prompt_len: int, gen: int,
                mesh=None, seed: int = 0, params=None, prompts=None,
                kernel_backend: str | None = None, loop: str = "scan",
                temperature: float = 0.0,
                kv_cache: str | None = None) -> dict:
    """``kernel_backend`` selects the quantized-matmul path (pallas /
    interpret / ref / dense); None = platform default via the dispatch
    layer.  ``loop`` picks the decode driver: 'scan' (default — single
    jitted on-device generation loop) or 'host' (legacy per-token Python
    loop, the parity oracle).  ``kv_cache`` overrides
    ``cfg.kv_cache_dtype`` ('bf16' | 'int8').  A multi-device ``mesh`` runs
    the whole pipeline sharded: params and KV cache are placed onto the
    plan's NamedShardings, and the fused qmatmuls execute tensor-parallel
    over the mesh's 'model' axis inside the jitted steps.  (For repeated
    min-timed decode measurements use
    ``benchmarks.bench_serve.paired_decode_tok_s``, which interleaves both
    KV formats' compiled loops.)"""
    if loop not in ("scan", "host"):
        raise ValueError(f"unknown decode loop {loop!r}")
    if kv_cache is not None:
        cfg = cfg.with_(kv_cache_dtype=kv_cache)
    if loop == "host" and temperature > 0.0:
        raise ValueError("temperature sampling needs the on-device loop")
    mesh = mesh or make_host_mesh()
    capacity = prompt_len + gen
    prefill_shape = ShapeCfg("serve_prefill", capacity, batch, "prefill")
    decode_shape = ShapeCfg("serve_decode", capacity, batch, "decode")

    key = jax.random.PRNGKey(seed)
    if params is None:
        params, _ = split_tree(model_init(key, cfg))
    cache, _ = split_tree(cache_init(cfg, batch, capacity))

    pre_plan = build_plan(cfg, mesh, prefill_shape,
                          kernel_backend=kernel_backend)
    if np.prod(tuple(mesh.shape.values())) > 1:
        # commit params/cache to the plan layout up front (codes + B rows
        # sharded over 'model', factors replicated, cache per act rules) so
        # prefill/decode jits run sharded instead of resharding per call
        params = jax.device_put(params, pre_plan.in_shardings[0])
        cache = jax.device_put(cache, pre_plan.in_shardings[2])

    if prompts is None:
        prompts = np.random.default_rng(seed).integers(
            0, cfg.vocab_size, (batch, capacity)).astype(np.int32)
    else:
        pad = np.zeros((batch, capacity - prompts.shape[1]), np.int32)
        prompts = np.concatenate([prompts, pad], axis=1).astype(np.int32)

    with mesh:
        prefill = jax.jit(pre_plan.step_fn, donate_argnums=(2,))

        t0 = time.time()
        # dead-padding prefill: only the first prompt_len columns are live
        # (-1 positions mask the rest out of attention and the last-token
        # logits come from column prompt_len-1, not the padded window end)
        positions = jnp.arange(capacity, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(
            jnp.where(positions < prompt_len, positions, -1),
            (batch, capacity))
        if cfg.input_kind == "tokens":
            batch_in = {"tokens": jnp.asarray(prompts),
                        "positions": positions}
            step_embeds = None
        else:
            batch_in = {"embeds": jax.random.normal(
                key, (batch, capacity, cfg.d_model), jnp.bfloat16),
                "positions": positions}
            # the per-step frontend is stubbed: every decode step feeds the
            # same embedding (matching the legacy loop, which reused `key`)
            step_embeds = jax.random.normal(
                key, (batch, 1, cfg.d_model), jnp.bfloat16)
        logits, cache = prefill(params, batch_in, cache)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        # first generated token: sampled under the same policy as the loop
        # (greedy at temperature 0) so position 0 isn't frozen to argmax
        key0, gen_key = jax.random.split(jax.random.PRNGKey(seed + 1))
        tok = sample_token(logits[:, -1, : cfg.vocab_size], key0, temperature)

        if loop == "scan":
            if gen > 1:
                gen_plan = build_generate_plan(
                    cfg, mesh, decode_shape, gen=gen - 1,
                    temperature=temperature, kernel_backend=kernel_backend)
                pos0 = jnp.full((batch,), prompt_len, jnp.int32)
                # AOT-compile outside the timed region (lower() neither
                # executes nor consumes the donated cache), so decode_tok_s
                # measures the on-device loop, not tracing + compilation
                generate = jax.jit(
                    gen_plan.step_fn, donate_argnums=(2,)
                ).lower(params, tok, cache, pos0, gen_key,
                        step_embeds).compile()
                t0 = time.time()
                toks, cache = generate(params, tok, cache, pos0, gen_key,
                                       step_embeds)
                jax.block_until_ready(toks)
                t_decode = time.time() - t0
                toks = np.concatenate(
                    [np.asarray(tok)[:, None], np.asarray(toks)], axis=1)
            else:
                toks = np.asarray(tok)[:, None]
                t_decode = 0.0
        else:  # legacy per-token host loop (parity oracle)
            dec_plan = build_plan(cfg, mesh, decode_shape,
                                  kernel_backend=kernel_backend)
            decode = jax.jit(dec_plan.step_fn, donate_argnums=(2,))
            generated = [np.asarray(tok)]
            t0 = time.time()
            for i in range(gen - 1):
                pos = jnp.full((batch,), prompt_len + i, jnp.int32)
                if cfg.input_kind == "tokens":
                    step_in = {"tokens": tok}
                else:
                    step_in = {"embeds": step_embeds}
                logits, cache = decode(params, step_in, cache, pos)
                tok = jnp.argmax(
                    logits[:, -1, : cfg.vocab_size], axis=-1).astype(jnp.int32)
                generated.append(np.asarray(tok))
            jax.block_until_ready(tok)
            t_decode = time.time() - t0
            toks = np.stack(generated, axis=1)

    return {
        "tokens": toks,
        "prefill_tok_s": batch * prompt_len / max(t_prefill, 1e-9),
        "prefill_ms": t_prefill * 1e3,
        "decode_tok_s": (batch * (gen - 1) / max(t_decode, 1e-9)
                         if gen > 1 else 0.0),
        "decode_ms": t_decode * 1e3,
        "decode_loop": loop,
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "kernel_backend": pre_plan.meta["kernel_backend"],
        "attention": pre_plan.meta["attention"],
    }


def serve_engine(cfg, *, n_requests: int = 8, mesh=None, seed: int = 0,
                 slots: int = 4, total_pages: int = 48, page_size: int = 8,
                 max_pages: int = 12, chunk: int = 16, burst: int = 4,
                 kernel_backend: str | None = None,
                 deadline_s: float | None = None,
                 admission_budget: int | None = None,
                 faults=None, timeout_s: float = 300.0) -> dict:
    """Drive the continuous-batching :class:`repro.launch.engine.Engine`
    over a seeded synthetic ragged trace (the CLI's ``--engine N`` mode).

    ``deadline_s`` attaches a per-request latency budget, and
    ``admission_budget`` bounds the queue (overload shedding); ``faults``
    takes a :class:`repro.robustness.FaultPlan` for chaos runs.  Returns
    ``Engine.run``'s stats dict — every request ends in exactly one
    terminal status even under injected faults.
    """
    from repro.launch.engine import Engine, Request

    rng = np.random.default_rng(seed)
    cap_tokens = min(max_pages, total_pages - 1) * page_size
    reqs = []
    t = 0.0
    for rid in range(n_requests):
        plen = int(rng.integers(4, max(chunk, 8) + 1))
        gen = int(rng.integers(4, max(cap_tokens - chunk, 8) + 1))
        gen = min(gen, cap_tokens - (-(-plen // chunk) * chunk) + 1, 24)
        prompt = rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32)
        reqs.append(Request(rid, prompt, max(gen, 1), arrival=t,
                            deadline_s=deadline_s))
        t += float(rng.exponential(0.01))
    eng = Engine(cfg, slots=slots, total_pages=total_pages,
                 page_size=page_size, max_pages=max_pages, chunk=chunk,
                 burst=burst, mesh=mesh, kernel_backend=kernel_backend,
                 params=None, seed=seed, faults=faults,
                 admission_budget=admission_budget)
    return eng.run(reqs, timeout_s=timeout_s)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--engine", type=int, default=None, metavar="N",
                    help="serve N synthetic ragged requests through the "
                         "continuous-batching paged engine instead of one "
                         "fixed batch")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline for --engine mode")
    ap.add_argument("--admission-budget", type=int, default=None,
                    help="max queued requests before shedding (--engine)")
    ap.add_argument("--loop", default="scan", choices=["scan", "host"],
                    help="decode driver: single jitted on-device scan "
                         "(default) or the legacy per-token host loop")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 = temperature sampling (scan loop)")
    ap.add_argument("--kv-cache", default=None, choices=["bf16", "int8"],
                    help="KV-cache storage (default: cfg.kv_cache_dtype)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "interpret", "ref", "dense"],
                    help="quantized-matmul dispatch backend "
                         "(default: fused pallas on TPU, ref elsewhere)")
    ap.add_argument("--codebook", default=None,
                    choices=["nf4", "nf3", "nf2", "int8", "int4", "fp4"],
                    help="override the weight codebook (nf3 = the true "
                         "3-bit serving config: 8 codes packed into 3 "
                         "bytes, unpacked in-kernel)")
    ap.add_argument("--scale-dtype", default=None, choices=["f32", "bf16"],
                    help="storage dtype of the LoRDS B/A factors (default: "
                         "config; sub-4-bit codebooks default to bf16 so "
                         "total storage stays under 0.5 bytes/weight)")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="host mesh shape, e.g. 2x4 (needs that many visible "
                         "devices; on CPU force them via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if args.codebook or args.scale_dtype:
        from repro.core import lut

        q = cfg.quant
        if args.codebook:
            q = q.with_(codebook=args.codebook)
        if args.scale_dtype:
            q = q.with_(scale_dtype={"f32": jnp.float32,
                                     "bf16": jnp.bfloat16}[args.scale_dtype])
        elif lut.codebook_bits(q.codebook) < 4:
            # sub-4-bit point of the storage Pareto: bf16 factors keep the
            # B/A overhead below the packing win (nf3 ≈ 0.39 bytes/weight
            # incl. scales vs 0.41 with f32 factors)
            q = q.with_(scale_dtype=jnp.bfloat16)
        cfg = cfg.with_(quant=q)
    mesh = None
    if args.mesh:
        data, model = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_host_mesh(data=data, model=model)
    if args.engine is not None:
        stats = serve_engine(cfg, n_requests=args.engine, mesh=mesh,
                             kernel_backend=args.kernel_backend,
                             deadline_s=args.deadline_s,
                             admission_budget=args.admission_budget)
        print(f"[serve] engine: {stats['statuses']} "
              f"goodput {stats['goodput_tok_s']:.1f} tok/s "
              f"p50 {stats['latency_p50_s'] * 1e3:.0f}ms "
              f"p99 {stats['latency_p99_s'] * 1e3:.0f}ms "
              f"evictions {stats['evictions']} shed {stats['shed']} "
              f"page_audit_ok {stats['page_audit']['ok']}")
        return
    out = serve_batch(cfg, batch=args.batch, prompt_len=args.prompt_len,
                      gen=args.gen, mesh=mesh,
                      kernel_backend=args.kernel_backend,
                      loop=args.loop, temperature=args.temperature,
                      kv_cache=args.kv_cache)
    print(f"[serve] backend={out['kernel_backend']} loop={out['decode_loop']} "
          f"kv={out['kv_cache_dtype']} attention={out['attention']} "
          f"prefill {out['prefill_tok_s']:.1f} tok/s, "
          f"decode {out['decode_tok_s']:.1f} tok/s")
    print("[serve] sample tokens:", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
