"""Continuous-batching serving engine over the block-paged KV cache.

The fixed-capacity :func:`repro.launch.serve.serve_batch` allocates
``prompt_len + gen`` cache rows per sequence and runs one batch to
completion — ragged real traffic wastes cache memory on short requests and
stalls everyone behind the longest prompt.  This engine serves a *stream*:

  * **Page pool** — every layer's KV lives in a global pool of fixed-size
    pages (``models.paged_cache_init``); a request holds only the pages its
    tokens actually fill, via a per-slot page table.  Page 0 is a dummy:
    unmapped table entries point at it, so dead slots/rows write there and
    never corrupt live state.
  * **Scheduler** — FIFO admission while free pages last; decode pages are
    allocated on demand, and when the pool runs dry the *youngest* admitted
    request is evicted (pages freed, request requeued at the front for
    recompute) so the oldest always completes — no livelock.
  * **Chunked prefill** — prompts prefill ``chunk`` tokens per tick
    (``steps.build_prefill_chunk_plan``), interleaved with decode steps, so
    a long prompt never stalls the decode batch.
  * **Fixed-shape steps** — every tick reuses two jitted step functions
    (chunk prefill + paged decode burst) with constant shapes: slot
    activity is encoded in the *data* (dead rows: positions -1, page-table
    rows 0), never in the shapes, so the engine never recompiles no matter
    the arrival pattern.  Pools are donated through every step.

Decode semantics match ``serve_batch`` token for token: token 1 is sampled
from the prefill logits at the prompt's last live row, decode step k runs
at position ``prompt_len + k - 1``.  The parity tests pin the engine to the
PR 2 ``loop='scan'`` path bitwise under greedy sampling.

**Failure semantics** (PR 7): every request ends in exactly one terminal
status — ``completed`` / ``timeout`` / ``rejected`` / ``failed`` — and
``Engine.run`` *returns* its stats dict under every fault the hardening
layer covers instead of raising away completed work:

  * **Deadlines.**  ``Request.deadline_s`` (relative to arrival) cancels a
    late request wherever it is — queued or mid-decode — reclaiming its
    pages and recording ``status='timeout', reason='deadline'`` with the
    tokens it did produce.  The global ``timeout_s`` is a *drain guard*:
    on expiry the engine stops admitting, cancels in-flight work with
    partial results, marks unserved requests ``timeout``, and returns.
  * **Retry + requeue.**  A step-compute failure requeues its participants
    for recompute with a per-request retry budget (``max_retries``);
    exhausted budgets end in ``failed``.  Injected failures
    (:class:`repro.robustness.InjectedFault`, raised *before* the launch)
    are request-scoped — bystander slots keep their KV; an organic
    mid-launch failure cannot trust the donated pools, so the pool is
    rebuilt and every active sequence recomputes.
  * **Overload shedding.**  ``admission_budget`` bounds the admission
    queue; arrivals beyond it are rejected immediately
    (``status='rejected', reason='overload'``) instead of growing an
    unbounded backlog.
  * **Non-finite quarantine.**  The paged steps sample through
    ``sample_token_guarded``: a slot whose logits go NaN/Inf emits the
    ``NONFINITE_TOKEN`` marker, and the engine quarantines *that slot
    only* (``failed/non_finite``, pages scrubbed then reclaimed) while the
    rest of the batch keeps decoding.
  * **Graceful drain.**  A ``PreemptionGuard`` (or the ``engine.preempt``
    fault point) flips the engine into drain: waiting requests are
    rejected with ``reason='preempted'``, in-flight requests run to
    completion, and the stats report ``preempted=True``.

**Elastic execution** (PR 10): the engine also survives *infrastructure*
faults, injected through the mesh-aware ``dist.*`` points:

  * **Device loss** (``dist.device_loss``) triggers an elastic mesh
    rebuild: the mesh shrinks (data axis halves first), the step plans and
    jits are rebuilt on the survivors, params reshard onto the new layout,
    the page pool is rebuilt, and every in-flight request is requeued for
    recompute without being charged a retry — bounded by
    ``max_mesh_rebuilds``.
  * **Collective timeouts** (``dist.collective_timeout``) surface as
    injected step failures riding the retry + requeue path, counted
    separately in ``stats['collective_timeouts']``.
  * **Straggler watchdog**: per-shard ``dist.straggler`` injection streams
    (one RNG per shard index) pair with an EMA z-score over tick wall time;
    flagged ticks land in ``stats['straggler_flags']`` with the slow shard
    indices.

Every recovery action is counted in ``Engine.stats`` (``evictions``,
``retries``, ``step_failures``, ``quarantined``, ``shed``,
``deadline_cancels``, ``mesh_rebuilds``, ``lost_devices``,
``resharded_restores``, ``collective_timeouts``) and
:meth:`Engine.audit_pages` checks the page-pool invariant
(``free + held == total_pages - 1``, no page in two places) after each
recovery when faults are active and always at exit.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault_tolerance import StragglerMonitor
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (
    NONFINITE_TOKEN,
    build_paged_generate_plan,
    build_prefill_chunk_plan,
)
from repro.models import model_init, paged_cache_init, split_tree
from repro.robustness import NO_FAULTS, InjectedFault

__all__ = ["Request", "Engine", "TERMINAL_STATUSES"]

TERMINAL_STATUSES = ("completed", "timeout", "rejected", "failed")


@dataclasses.dataclass
class Request:
    """One generation request: ``tokens`` is the prompt (1-D int array),
    ``max_new`` the generation budget, ``arrival`` the trace-relative
    arrival time in seconds (0 = available immediately), ``deadline_s`` an
    optional per-request latency budget relative to arrival (None = no
    deadline) — expiry cancels the request wherever it is and records a
    ``timeout`` status with whatever tokens it produced."""
    rid: int
    tokens: np.ndarray
    max_new: int
    arrival: float = 0.0
    deadline_s: float | None = None


_FREE, _PREFILL, _DECODE = "free", "prefill", "decode"


@dataclasses.dataclass
class _Slot:
    state: str = _FREE
    req: Request | None = None
    pages: list = dataclasses.field(default_factory=list)
    chunk_done: int = 0       # prompt tokens already prefilled
    tok: int = 0              # last generated token (next decode input)
    pos: int = 0              # next decode write position
    out: list = dataclasses.field(default_factory=list)
    admit_seq: int = -1       # admission order (eviction picks the max)
    admit_t: float = 0.0
    first_tok_t: float | None = None


class Engine:
    """Continuous-batching engine; see the module docstring.

    Geometry: ``slots`` concurrent sequences, a pool of ``total_pages``
    pages of ``page_size`` tokens (page 0 reserved), per-slot page tables
    of ``max_pages`` entries (the per-request capacity ceiling), prompts
    prefilled ``chunk`` tokens at a time (``chunk % page_size == 0``).
    ``burst`` decode steps run as one on-device scan when no prefill or
    arrival is waiting (1 while interleaving, so prompts never stall).

    Robustness knobs: ``faults`` (a :class:`repro.robustness.FaultPlan`;
    default :data:`NO_FAULTS` — zero cost), ``admission_budget`` (max
    queued requests before shedding; None = unbounded),``max_retries``
    (per-request step-failure budget), ``preemption_guard`` (a
    :class:`repro.distributed.fault_tolerance.PreemptionGuard` polled each
    tick for graceful drain).
    """

    def __init__(self, cfg, *, slots: int, total_pages: int, page_size: int,
                 max_pages: int, chunk: int, burst: int = 8, mesh=None,
                 kernel_backend: str | None = None,
                 temperature: float = 0.0, seed: int = 0, params=None,
                 faults=None, admission_budget: int | None = None,
                 max_retries: int = 2, preemption_guard=None,
                 max_mesh_rebuilds: int = 4):
        if cfg.input_kind != "tokens":
            raise ValueError("the paged engine serves token models")
        if chunk % page_size:
            raise ValueError(f"chunk {chunk} % page_size {page_size}")
        if total_pages < 2:
            raise ValueError("need at least one real page beyond the dummy")
        self.cfg = cfg
        self.slots = slots
        self.total_pages = total_pages
        self.page_size = page_size
        self.max_pages = max_pages
        self.chunk = chunk
        self.burst = max(int(burst), 1)
        self.temperature = temperature
        self.mesh = mesh or make_host_mesh()
        self.faults = faults or NO_FAULTS
        self.admission_budget = admission_budget
        self.max_retries = max_retries
        self.max_mesh_rebuilds = max_mesh_rebuilds
        self.audit_every = False   # force post-recovery audits sans faults
        self._guard = preemption_guard

        self._step_kw = dict(slots=slots, total_pages=total_pages,
                             page_size=page_size, max_pages=max_pages,
                             temperature=temperature,
                             kernel_backend=kernel_backend)
        self._build_plans()

        if params is None:
            params, _ = split_tree(model_init(jax.random.PRNGKey(seed), cfg))
        pools, _ = split_tree(
            paged_cache_init(cfg, total_pages, page_size))
        if self._multi:
            params = jax.device_put(params, self.chunk_plan.in_shardings[0])
            pools = jax.device_put(pools, self.chunk_plan.in_shardings[2])
        self.params = params
        self.pools = pools
        self._key = jax.random.PRNGKey(seed + 1)

        self._slots = [_Slot() for _ in range(slots)]
        self._free_pages = list(range(1, total_pages))  # page 0 = dummy
        self._admit_seq = 0
        self._warm = False
        self._poisoned: set = set()     # pages holding injected NaNs
        self._records: list = []
        self._recorded: set = set()
        self._retries: dict = {}
        self._drain_reason: str | None = None
        self.stats: dict = {}

    def _build_plans(self):
        """(Re)build the three fixed-shape step plans and their jits on
        ``self.mesh`` — at construction and again after an elastic mesh
        rebuild (device loss shrinks the mesh; the plans' shardings and
        compiled steps must follow it)."""
        self.chunk_plan = build_prefill_chunk_plan(
            self.cfg, self.mesh, chunk=self.chunk, **self._step_kw)
        self.decode_plan = build_paged_generate_plan(
            self.cfg, self.mesh, gen=1, **self._step_kw)
        self.burst_plan = (build_paged_generate_plan(
            self.cfg, self.mesh, gen=self.burst, **self._step_kw)
            if self.burst > 1 else self.decode_plan)
        self._multi = int(np.prod(tuple(self.mesh.shape.values()))) > 1
        self._chunk_step = jax.jit(self.chunk_plan.step_fn,
                                   donate_argnums=(2,))
        self._decode_step = jax.jit(self.decode_plan.step_fn,
                                    donate_argnums=(2,))
        self._burst_step = (jax.jit(self.burst_plan.step_fn,
                                    donate_argnums=(2,))
                            if self.burst > 1 else self._decode_step)
        self._warm = False

    def warmup(self):
        """Compile and steady-state every step function before serving:
        two calls each, because the first call sees uncommitted input
        buffers and the second (donated, committed) hits a separate jit
        cache entry — without this the second compile lands inside the
        first timed run.  All-dead inputs (positions -1, page tables 0)
        only ever write the dummy page, so the pools stay semantically
        empty."""
        if self._warm:
            return
        z_tok = jnp.zeros((self.slots, self.chunk), jnp.int32)
        z_qpos = jnp.full((self.slots, self.chunk), -1, jnp.int32)
        z_pos = jnp.zeros((self.slots,), jnp.int32)
        z_pt = jnp.zeros((self.slots, self.max_pages), jnp.int32)
        z_t = jnp.zeros((self.slots,), jnp.int32)
        for _ in range(2):
            tok1, self.pools = self._chunk_step(
                self.params, z_tok, self.pools, z_pt, z_qpos, z_pos,
                self._split_key())
            toks, self.pools = self._decode_step(
                self.params, z_t, self.pools, z_pt, z_pos,
                self._split_key())
            if self._burst_step is not self._decode_step:
                toks, self.pools = self._burst_step(
                    self.params, z_t, self.pools, z_pt, z_pos,
                    self._split_key())
            jax.block_until_ready(toks)
        self._warm = True

    # ---- page accounting ------------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        """Pages a request holds at peak: prompt chunks round up to the
        chunk grid, and decode writes through plen + max_new - 2."""
        plen = len(req.tokens)
        hi = max(-(-plen // self.chunk) * self.chunk,
                 plen + req.max_new - 1)
        return -(-hi // self.page_size)

    def _validate(self, req: Request):
        need = self._pages_needed(req)
        cap = min(self.max_pages, self.total_pages - 1)
        if need > cap:
            raise ValueError(
                f"request {req.rid} needs {need} pages "
                f"(prompt {len(req.tokens)} + gen {req.max_new}, page size "
                f"{self.page_size}) but the ceiling is {cap} "
                f"(max_pages={self.max_pages}, pool={self.total_pages})")
        if not req.max_new:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")

    def _free_slot_pages(self, slot: _Slot):
        """Return a slot's pages to the free pool, scrubbing any that hold
        injected NaNs first (a reclaimed page must never leak non-finite
        state into its next owner)."""
        doomed = [p for p in slot.pages if p in self._poisoned]
        if doomed:
            idx = jnp.asarray(doomed, jnp.int32)
            self.pools = jax.tree.map(lambda l: l.at[:, idx].set(0),
                                      self.pools)
            self._poisoned.difference_update(doomed)
        self._free_pages.extend(slot.pages)

    def _release(self, slot: _Slot):
        self._free_slot_pages(slot)
        self._reset(slot)

    def _evict_youngest(self, queue: deque) -> bool:
        """Free the youngest admitted slot and requeue its request at the
        front (recompute-on-readmit).  Returns False if nothing is active."""
        active = [s for s in self._slots if s.state != _FREE]
        if not active:
            return False
        victim = max(active, key=lambda s: s.admit_seq)
        req = victim.req
        self._release(victim)
        queue.appendleft(req)
        self.stats["evictions"] += 1
        self._post_recovery_audit("eviction")
        return True

    def _try_page(self, slot: _Slot, logical: int) -> bool:
        """Grow slot's page list through logical index ``logical`` from the
        free pool; False (no allocation rollback needed — partial growth is
        still valid) if the pool runs dry.  The ``engine.page_alloc`` fault
        point makes an allocation fail as if the pool were empty."""
        while len(slot.pages) <= logical:
            if not self._free_pages or self.faults.fires("engine.page_alloc"):
                return False
            slot.pages.append(self._free_pages.pop())
        return True

    def _claim(self, slots_, need_fn, queue: deque, can_wait: bool):
        """Partition a phase's slots into those whose pages are available
        this tick.  A starved slot *stalls* — skips the tick and keeps its
        pages; the pool refills as siblings complete, so stalling is almost
        always cheaper than eviction-recompute.  Eviction is the last
        resort: only when no slot in the phase can move and there is no
        other progress to wait on (``can_wait``) does the scheduler evict
        the youngest admitted request to break the deadlock."""
        ready, stalled = [], []
        for s in slots_:
            (ready if self._try_page(s, need_fn(s)) else stalled).append(s)
        while not ready and stalled and not can_wait:
            if not self._evict_youngest(queue):
                break
            # the victim may have been anywhere, including `stalled`
            stalled = [s for s in stalled if s.req is not None]
            retry, stalled = stalled, []
            for s in retry:
                (ready if self._try_page(s, need_fn(s))
                 else stalled).append(s)
        return [s for s in ready if s.req is not None]

    def _reset(self, slot: _Slot):
        slot.state = _FREE
        slot.req = None
        slot.pages = []
        slot.chunk_done = 0
        slot.tok = 0
        slot.pos = 0
        slot.out = []
        slot.admit_seq = -1
        slot.first_tok_t = None

    def _split_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    # ---- fault handling / accounting ------------------------------------

    def audit_pages(self) -> dict:
        """Page-pool invariant check: every page except the dummy is in
        exactly one place (the free list or one slot's table) and nothing
        is duplicated.  Cheap host-side bookkeeping — safe to run after
        every recovery action."""
        held = [p for s in self._slots for p in s.pages]
        free = list(self._free_pages)
        issues = []
        if len(held) != len(set(held)):
            issues.append("page held by two slots")
        if len(free) != len(set(free)):
            issues.append("free-list duplicate")
        if set(held) & set(free):
            issues.append("page both free and held")
        if 0 in held or 0 in free:
            issues.append("dummy page 0 circulating")
        if len(set(held)) + len(set(free)) != self.total_pages - 1:
            issues.append(
                f"leak: held {len(set(held))} + free {len(set(free))} "
                f"!= {self.total_pages - 1}")
        return {"ok": not issues, "free": len(free), "held": len(held),
                "total_pages": self.total_pages, "issues": issues}

    def _post_recovery_audit(self, label: str):
        if not (self.faults.enabled or self.audit_every):
            return
        a = self.audit_pages()
        if not a["ok"]:
            self.stats.setdefault("audit_failures", []).append(
                dict(a, after=label))

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _record(self, req: Request, status: str, *, reason=None,
                tokens=(), slot: _Slot | None = None):
        """Append a request's single terminal record (idempotent per rid)."""
        if req.rid in self._recorded:
            return
        self._recorded.add(req.rid)
        t = self._now()
        self._records.append({
            "rid": req.rid,
            "arrival": req.arrival,
            "status": status,
            "reason": reason,
            "admitted": slot.admit_t if slot is not None else None,
            "first_token": slot.first_tok_t if slot is not None else None,
            "finished": t,
            "latency": t - req.arrival,
            "prompt_len": int(len(req.tokens)),
            "tokens": list(tokens),
        })

    def _finish(self, slot: _Slot):
        self._record(slot.req, "completed", tokens=slot.out, slot=slot)
        self._release(slot)

    def _quarantine(self, slot: _Slot):
        """Non-finite logits in this slot only: record the failure with the
        tokens generated before the poison, scrub + reclaim its pages (its
        own KV writes are suspect too), and keep every other slot going."""
        self._poisoned.update(slot.pages)
        self._record(slot.req, "failed", reason="non_finite",
                     tokens=slot.out, slot=slot)
        self._release(slot)
        self.stats["quarantined"] += 1
        self._post_recovery_audit("quarantine")

    def _reinit_pools(self):
        """Rebuild the page pool from scratch (organic step failure: the
        donated pools' state is unknown)."""
        pools, _ = split_tree(
            paged_cache_init(self.cfg, self.total_pages, self.page_size))
        if self._multi:
            pools = jax.device_put(pools, self.chunk_plan.in_shardings[2])
        self.pools = pools
        self._free_pages = list(range(1, self.total_pages))
        self._poisoned = set()

    def _elastic_rebuild(self, queue: deque) -> bool:
        """Elastic recovery from a (injected) device loss: shrink the mesh
        — the data axis halves first, the model axis only once data
        parallelism is exhausted — rebuild the step plans and their jits on
        the surviving devices, reshard the live params onto the new layout
        (an elastic restore: same bytes, new placement), rebuild the page
        pool, and requeue every in-flight request for recompute *without*
        charging its retry budget — the hardware failed, not the request.
        Returns False when the mesh is already a single device (nothing
        left to lose)."""
        shape = dict(self.mesh.shape)
        data = int(shape.get("data", 1))
        model = int(shape.get("model", 1))
        old = data * model
        if old <= 1:
            return False
        if data > 1:
            data //= 2
        else:
            model //= 2
        self.stats["lost_devices"] += old - data * model
        self.mesh = make_host_mesh(data=data, model=model)
        self._build_plans()
        self.params = jax.device_put(
            self.params, self.chunk_plan.in_shardings[0]) if self._multi \
            else jax.device_put(self.params, self.mesh.devices.flat[0])
        self.stats["resharded_restores"] += 1
        # every active sequence's KV lived (in part) on the lost devices:
        # requeue oldest-frontmost for recompute, then rebuild the pool on
        # the new mesh
        active = [s for s in self._slots if s.state != _FREE]
        for s in sorted(active, key=lambda s: s.admit_seq, reverse=True):
            req = s.req
            self._reset(s)
            queue.appendleft(req)
        self._reinit_pools()
        self.stats["mesh_rebuilds"] += 1
        self.warmup()
        self._post_recovery_audit("mesh_rebuild")
        return True

    def _step_failure(self, participants, queue: deque, *, injected: bool,
                      phase: str):
        """Recover from a failed step launch.  Participants are charged a
        retry (``failed`` once the budget is gone) and requeued at the
        front for recompute.  Injected faults fire *before* the launch, so
        bystander slots keep their pages and KV; an organic failure cannot
        trust the donated pool state, so the pool is rebuilt and every
        active sequence recomputes."""
        self.stats["step_failures"] += 1
        affected = (list(participants) if injected
                    else [s for s in self._slots if s.state != _FREE])
        charged = {id(s) for s in participants}
        # appendleft in reverse admission order keeps the oldest frontmost
        for s in sorted(affected, key=lambda s: s.admit_seq, reverse=True):
            req = s.req
            if id(s) in charged:
                n = self._retries[req.rid] = self._retries.get(req.rid, 0) + 1
                self.stats["retries"] += 1
                if n > self.max_retries:
                    self._record(req, "failed",
                                 reason=f"{phase}_step_failure",
                                 tokens=s.out, slot=s)
                    if injected:
                        self._free_slot_pages(s)
                    self._reset(s)
                    continue
            if injected:
                self._free_slot_pages(s)
            self._reset(s)
            queue.appendleft(req)
        if not injected:
            self._reinit_pools()
        self._post_recovery_audit(f"{phase}_step_failure")

    def _enforce_deadlines(self, queue: deque):
        """Cancel deadline-expired requests wherever they are: queued ones
        are recorded unserved; in-flight ones free their pages and keep the
        tokens they produced."""
        expired = [r for r in queue
                   if r.deadline_s is not None
                   and self._now() - r.arrival > r.deadline_s]
        for r in expired:
            queue.remove(r)
            self._record(r, "timeout", reason="deadline")
            self.stats["deadline_cancels"] += 1
        for s in self._slots:
            if s.state == _FREE or s.req.deadline_s is None:
                continue
            if self._now() - s.req.arrival > s.req.deadline_s:
                self._record(s.req, "timeout", reason="deadline",
                             tokens=s.out, slot=s)
                self._release(s)
                self.stats["deadline_cancels"] += 1
                self._post_recovery_audit("deadline_cancel")

    def _drain_all(self, pending: deque, queue: deque, reason: str):
        """Global-timeout drain: cancel in-flight work keeping partial
        output, mark everything still waiting unserved.  Nothing raises —
        the caller returns the stats dict with all completed records."""
        for s in self._slots:
            if s.state != _FREE:
                self._record(s.req, "timeout", reason=reason,
                             tokens=s.out, slot=s)
                self._release(s)
        while queue:
            self._record(queue.popleft(), "timeout", reason="unserved")
        while pending:
            self._record(pending.popleft(), "timeout", reason="unserved")
        self._post_recovery_audit("drain")

    # ---- run loop -------------------------------------------------------

    def run(self, requests, *, timeout_s: float = 300.0) -> dict:
        """Replay ``requests`` (any order; sorted by arrival) to completion
        or controlled degradation.

        Returns a stats dict: one terminal record per request (status in
        ``completed | timeout | rejected | failed``), goodput (completed
        generated tokens / wall second), latency percentiles over completed
        requests, per-phase prefill/decode milliseconds, recovery counters
        and the exit page-pool audit.  ``timeout_s`` is a drain guard, not
        an exception: on expiry the engine stops admitting, keeps partial
        results, and returns.
        """
        for r in requests:
            self._validate(r)
        self.warmup()
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        queue: deque = deque()
        self._records = []
        self._recorded = set()
        self._retries = {}
        self._poisoned = set()
        self._drain_reason = None
        self.stats = {"evictions": 0, "chunk_steps": 0, "decode_steps": 0,
                      "prefill_ms": 0.0, "decode_ms": 0.0,
                      "step_failures": 0, "retries": 0, "quarantined": 0,
                      "shed": 0, "deadline_cancels": 0, "nan_injections": 0,
                      "preempted": False, "mesh_rebuilds": 0,
                      "lost_devices": 0, "resharded_restores": 0,
                      "collective_timeouts": 0, "straggler_flags": []}
        t0 = time.perf_counter()
        self._t0 = t0
        now = self._now
        tick = 0
        mon = StragglerMonitor(warmup_steps=5)
        n_shards = int(np.prod(tuple(self.mesh.shape.values())))

        while pending or queue or any(s.state != _FREE for s in self._slots):
            if now() > timeout_s:
                self._drain_reason = "timeout"
                self._drain_all(pending, queue, "global_timeout")
                break
            # fast-forward: nothing is runnable and the next arrival lands
            # beyond the drain guard — declare the timeout now instead of
            # sleeping into it
            if (not queue and pending
                    and all(s.state == _FREE for s in self._slots)
                    and pending[0].arrival > timeout_s):
                self._drain_reason = "timeout"
                self._drain_all(pending, queue, "global_timeout")
                break

            if self._drain_reason is None and (
                    (self._guard is not None and self._guard.preempted)
                    or self.faults.fires("engine.preempt")):
                # graceful drain: reject everything waiting (structured,
                # immediate), let in-flight slots run to completion
                self._drain_reason = "preempted"
                self.stats["preempted"] = True
                while queue:
                    self._record(queue.popleft(), "rejected",
                                 reason="preempted")
                while pending:
                    self._record(pending.popleft(), "rejected",
                                 reason="preempted")

            if (self.faults.enabled
                    and self.stats["mesh_rebuilds"] < self.max_mesh_rebuilds
                    and self.faults.fires("dist.device_loss")):
                self._elastic_rebuild(queue)
                n_shards = int(np.prod(tuple(self.mesh.shape.values())))

            self.faults.fires("engine.straggler")   # sleeps when it fires
            # straggler watchdog: per-shard injection streams (one RNG per
            # shard index — deterministic across process counts) plus an
            # EMA z-score over tick wall time that flags organic slowness
            tick += 1
            mon.start_step()
            slow_shards = []
            if self.faults.enabled:
                for sidx in range(n_shards):
                    if self.faults.fires("dist.straggler", index=sidx):
                        slow_shards.append(sidx)  # fires() slept in-line

            while pending and pending[0].arrival <= now():
                r = pending.popleft()
                if (self.admission_budget is not None
                        and len(queue) >= self.admission_budget):
                    self._record(r, "rejected", reason="overload")
                    self.stats["shed"] += 1
                else:
                    queue.append(r)

            self._enforce_deadlines(queue)

            # admission: FIFO while a slot is free and the pool can cover
            # the whole prompt (gating on full prompt pages, not just the
            # first chunk, keeps overcommit — and eviction thrash — down;
            # pages past the first chunk are still allocated lazily)
            for slot in self._slots:
                if not queue or slot.state != _FREE:
                    continue
                req = queue[0]
                if len(self._free_pages) < -(-len(req.tokens)
                                             // self.page_size):
                    break
                first = -(-min(len(req.tokens), self.chunk)
                          // self.page_size)
                queue.popleft()
                slot.state = _PREFILL
                slot.req = req
                slot.pages = [self._free_pages.pop() for _ in range(first)]
                slot.admit_seq = self._admit_seq
                self._admit_seq += 1
                slot.admit_t = now()

            prefilling = [s for s in self._slots if s.state == _PREFILL]
            if prefilling:
                self._run_chunk(prefilling, queue)

            decoding = [s for s in self._slots if s.state == _DECODE]
            if decoding:
                # burst only when nothing competes for the device: no
                # prefill in flight, and no admissible work waiting (a
                # non-empty queue with every slot busy can't be admitted,
                # so it doesn't force single-stepping)
                can_admit = any(s.state == _FREE for s in self._slots)
                waiting = bool(queue) or (
                    pending and pending[0].arrival <= now() + 1e-3)
                quiet = not prefilling and not (can_admit and waiting)
                n = self.burst if quiet else 1
                n = min(n, max(len(s.req.tokens) + s.req.max_new - s.pos - 1
                               for s in decoding))
                self._run_decode(decoding, max(n, 1), queue)

            if (prefilling or decoding) and (
                    mon.end_step(tick) or slow_shards):
                flagged = mon.flags[-1] if mon.flags else None
                self.stats["straggler_flags"].append({
                    "tick": tick, "shards": slow_shards,
                    "injected": bool(slow_shards),
                    "dt_s": flagged[1] if flagged else None,
                    "zscore": flagged[2] if flagged else None})

            if not prefilling and not decoding and not queue and pending:
                time.sleep(min(max(pending[0].arrival - now(), 0.0), 0.05))

        wall = now()
        records = self._records
        completed = [r for r in records if r["status"] == "completed"]
        lat = sorted(r["latency"] for r in completed)

        def pct(p):
            return lat[min(int(p * len(lat)), len(lat) - 1)] if lat else 0.0

        statuses: dict = {}
        for r in records:
            statuses[r["status"]] = statuses.get(r["status"], 0) + 1
        gen_tokens = sum(len(r["tokens"]) for r in completed)
        self.stats.update({
            "requests": len(records),
            "completed": len(completed),
            "statuses": statuses,
            "all_completed": len(completed) == len(requests),
            "drained": self._drain_reason,
            "wall_s": wall,
            "goodput_tok_s": gen_tokens / max(wall, 1e-9),
            "generated_tokens": gen_tokens,
            "latency_p50_s": pct(0.50),
            "latency_p99_s": pct(0.99),
            "records": records,
            "page_audit": self.audit_pages(),
            "faults": self.faults.summary(),
        })
        return dict(self.stats)

    # ---- phase steps ----------------------------------------------------

    def _run_chunk(self, prefilling, queue):
        cs = self.chunk

        def pages_for_chunk(s):
            # pages ahead of this chunk are allocated lazily so a long
            # prompt doesn't hold its whole footprint from tick 0
            return (min(s.chunk_done + cs, len(s.req.tokens)) - 1) \
                // self.page_size

        prefilling = self._claim(
            prefilling, pages_for_chunk, queue,
            can_wait=any(s.state == _DECODE for s in self._slots))
        if not prefilling:
            return
        tokens = np.zeros((self.slots, cs), np.int32)
        qpos = np.full((self.slots, cs), -1, np.int32)
        pos0 = np.zeros((self.slots,), np.int32)
        live = {id(s) for s in prefilling}
        for s in prefilling:
            i = self._slots.index(s)
            seg = np.asarray(s.req.tokens[s.chunk_done: s.chunk_done + cs],
                             np.int32)
            tokens[i, : len(seg)] = seg
            qpos[i, : len(seg)] = s.chunk_done + np.arange(len(seg))
            pos0[i] = s.chunk_done
        pt = np.zeros((self.slots, self.max_pages), np.int32)
        for i, s in enumerate(self._slots):
            if id(s) in live:
                pt[i, : len(s.pages)] = s.pages
        t0 = time.perf_counter()
        try:
            if self.faults.fires("dist.collective_timeout"):
                self.stats["collective_timeouts"] += 1
                raise InjectedFault("injected collective timeout (prefill)")
            if self.faults.fires("engine.step"):
                raise InjectedFault("injected chunk-step failure")
            tok1, self.pools = self._chunk_step(
                self.params, jnp.asarray(tokens), self.pools,
                jnp.asarray(pt), jnp.asarray(qpos), jnp.asarray(pos0),
                self._split_key())
        except InjectedFault:
            self._step_failure(prefilling, queue, injected=True,
                               phase="prefill")
            return
        except Exception:
            self._step_failure(prefilling, queue, injected=False,
                               phase="prefill")
            return
        tok1 = np.asarray(tok1)
        self.stats["prefill_ms"] += (time.perf_counter() - t0) * 1e3
        self.stats["chunk_steps"] += 1
        for s in prefilling:
            i = self._slots.index(s)
            s.chunk_done += cs
            if s.chunk_done < len(s.req.tokens):
                continue
            if int(tok1[i]) == NONFINITE_TOKEN:
                self._quarantine(s)
                continue
            s.state = _DECODE
            s.tok = int(tok1[i])
            s.pos = len(s.req.tokens)
            s.out = [s.tok]
            s.first_tok_t = time.perf_counter() - self._t0
            if len(s.out) >= s.req.max_new:
                self._finish(s)

    def _poison_page(self, page: int):
        """Inject NaNs into one physical page across every float pool leaf
        (bf16 KV directly; int8 pools through their f32 scales) — the real
        in-graph non-finite guard then trips on the next read."""
        def f(leaf):
            if jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf.at[:, page].set(float("nan"))
            return leaf

        self.pools = jax.tree.map(f, self.pools)
        self._poisoned.add(int(page))
        self.stats["nan_injections"] += 1

    def _run_decode(self, decoding, n, queue):
        def pages_for_burst(s):
            # decode writes positions pos .. pos+n-1, capped at the
            # request's true last write (plen + max_new - 2); overrun
            # steps past that land in the dummy page
            return min((s.pos + n - 1) // self.page_size,
                       (len(s.req.tokens) + s.req.max_new - 2)
                       // self.page_size)

        decoding = self._claim(decoding, pages_for_burst, queue,
                               can_wait=False)
        if not decoding:
            return
        if self.faults.fires("engine.nan_logits"):
            victim = min(decoding, key=lambda s: s.admit_seq)
            if victim.pages:
                self._poison_page(victim.pages[0])
        tok = np.zeros((self.slots,), np.int32)
        pos = np.zeros((self.slots,), np.int32)
        live = {id(s) for s in decoding}
        for s in decoding:
            i = self._slots.index(s)
            tok[i] = s.tok
            pos[i] = s.pos
        pt = np.zeros((self.slots, self.max_pages), np.int32)
        for i, s in enumerate(self._slots):
            if id(s) in live:
                pt[i, : len(s.pages)] = s.pages
        step = self._burst_step if n == self.burst and self.burst > 1 \
            else self._decode_step
        if n not in (1, self.burst):
            step = self._decode_step
            n = 1
        t0 = time.perf_counter()
        try:
            if self.faults.fires("dist.collective_timeout"):
                self.stats["collective_timeouts"] += 1
                raise InjectedFault("injected collective timeout (decode)")
            if self.faults.fires("engine.step"):
                raise InjectedFault("injected decode-step failure")
            toks, self.pools = step(
                self.params, jnp.asarray(tok), self.pools, jnp.asarray(pt),
                jnp.asarray(pos), self._split_key())
        except InjectedFault:
            self._step_failure(decoding, queue, injected=True,
                               phase="decode")
            return
        except Exception:
            self._step_failure(decoding, queue, injected=False,
                               phase="decode")
            return
        toks = np.asarray(toks)
        self.stats["decode_ms"] += (time.perf_counter() - t0) * 1e3
        self.stats["decode_steps"] += n
        for s in decoding:
            i = self._slots.index(s)
            poisoned = False
            for j in range(toks.shape[1]):
                if len(s.out) >= s.req.max_new:
                    break
                t = int(toks[i, j])
                if t == NONFINITE_TOKEN:
                    poisoned = True
                    break
                s.out.append(t)
                s.tok = t
                s.pos += 1
            if poisoned:
                self._quarantine(s)
            elif len(s.out) >= s.req.max_new:
                self._finish(s)
