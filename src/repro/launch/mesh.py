"""Production meshes.

Defined as functions (importing this module never touches jax device state).

Single pod: 16×16 = 256 chips ('data', 'model').
Multi-pod:  2×16×16 = 512 chips ('pod', 'data', 'model') — the 'pod' axis is
the slow (DCN/inter-pod ICI) axis; batch shards over ('pod','data').
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the local device (CPU tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
