"""Production meshes.

Defined as functions (importing this module never touches jax device state).

Single pod: 16×16 = 256 chips ('data', 'model').
Multi-pod:  2×16×16 = 512 chips ('pod', 'data', 'model') — the 'pod' axis is
the slow (DCN/inter-pod ICI) axis; batch shards over ('pod','data').

``make_host_mesh`` builds a mesh over the *local* host devices — by default
the degenerate 1×1 CPU mesh, but with ``data``/``model`` arguments it forms
a real data×tensor-parallel mesh over forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), which is how the
multi-device test harness proves the sharded fused pipeline on CPU.

``make_abstract_mesh`` mirrors the production shapes as a
``jax.sharding.AbstractMesh`` — enough for every spec-level operation
(``make_rules`` / ``resolve_spec`` / ``tree_shardings``) without 256 devices,
so sharding policies for the full arch zoo are testable anywhere.
"""
from __future__ import annotations

import jax
from jax.sharding import AbstractMesh

__all__ = ["make_production_mesh", "make_host_mesh", "make_abstract_mesh"]

_POD_SHAPE = (2, 16, 16)
_POD_AXES = ("pod", "data", "model")
_SINGLE_SHAPE = (16, 16)
_SINGLE_AXES = ("data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = _POD_SHAPE if multi_pod else _SINGLE_SHAPE
    axes = _POD_AXES if multi_pod else _SINGLE_AXES
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Mesh over local devices: 1×1 by default (CPU tests / examples).

    ``data``/``model`` > 1 require that many visible devices — on CPU that
    means forcing them before the first jax import, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a (2, 4)
    data×tensor-parallel mesh (what ``make test-multidevice`` does).
    """
    return jax.make_mesh((data, model), ("data", "model"))


def make_abstract_mesh(*, multi_pod: bool = False) -> AbstractMesh:
    """AbstractMesh twin of :func:`make_production_mesh` (no devices)."""
    shape = _POD_SHAPE if multi_pod else _SINGLE_SHAPE
    axes = _POD_AXES if multi_pod else _SINGLE_AXES
    return AbstractMesh(tuple(zip(axes, shape)))
