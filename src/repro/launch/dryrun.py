import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:
  * ``jax.jit(step).lower(**abstract).compile()`` must succeed on the 16×16
    single-pod mesh AND the 2×16×16 multi-pod mesh for every cell,
  * ``compiled.memory_analysis()`` -> bytes/device (fits-in-HBM evidence),
  * ``compiled.cost_analysis()``  -> FLOPs & HBM bytes (roofline numerator),
  * HLO text -> collective bytes (roofline collective term).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out f.json]

NOTE: the device-count env var above MUST precede any jax import (jax locks
the device count at first init) — hence the unconventional module layout.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    Roofline,
    collective_bytes,
    cpu_upcast_bytes,
    model_flops,
)
from repro.launch.steps import build_plan

# long_500k only runs for sub-quadratic archs (see DESIGN.md §Arch-applicability)
LONG_CTX_ARCHS = {"xlstm-1.3b", "jamba-1.5-large-398b"}

# archs over the single-device HBM budget at 1-D TP -> 2-D weight sharding
HBM_BUDGET_GB = 8.0


def cells(archs=None, shapes=None):
    archs = archs or list_configs()
    shapes = shapes or list(SHAPES)
    for a in archs:
        for s in shapes:
            if s == "long_500k" and a not in LONG_CTX_ARCHS:
                continue
            yield a, s


def _compile_plan(cfg, mesh, shape_cfg, force_2d, plan_tweaks=None):
    plan = build_plan(cfg, mesh, shape_cfg, budget_gb=HBM_BUDGET_GB,
                      force_2d=force_2d, **(plan_tweaks or {}))
    with mesh:
        jitted = jax.jit(
            plan.step_fn,
            in_shardings=plan.in_shardings,
            out_shardings=plan.out_shardings,
            donate_argnums=plan.donate_argnums,
        )
        lowered = jitted.lower(*plan.abstract_args)
        compiled = lowered.compile()
    return plan, compiled


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    # remove CPU-emitter bf16->f32 operand upcasts (absent on the TPU target)
    bytes_tpu = max(raw_bytes - cpu_upcast_bytes(hlo), raw_bytes * 0.1)
    return (float(cost.get("flops", 0.0)), bytes_tpu,
            float(coll["total"]), coll)


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             force_2d: bool | None = None, verbose: bool = True,
             plan_tweaks: dict | None = None, probes: bool = True,
             cfg_mutate=None) -> dict:
    """Compile the full scanned program (memory/sharding proof) plus two
    unrolled probe programs (1 and 2 periods) whose linear extrapolation
    gives true per-step FLOPs/bytes/collective-bytes — XLA's cost analysis
    counts while-loop bodies once, so the scanned program alone undercounts.
    """
    from repro.distributed.sharding import estimate_quantized_gb

    cfg = get_config(arch)
    if cfg_mutate is not None:
        cfg = cfg_mutate(cfg)
    shape_cfg = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    model_par = mesh.shape.get("model", 1)
    if force_2d is None:
        force_2d = estimate_quantized_gb(cfg) / model_par > HBM_BUDGET_GB

    t0 = time.time()
    plan, compiled = _compile_plan(cfg, mesh, shape_cfg, force_2d, plan_tweaks)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    f_scan, b_scan, c_scan, coll = _cost_of(compiled)

    # ---- unrolled probes -> extrapolated true per-step costs ----
    if probes and cfg.num_periods > 1:
        p = cfg.period
        # probes run single-microbatch so per-step cost extrapolation is exact
        # (they are compiled, never executed — probe memory doesn't matter)
        ptweaks = dict(plan_tweaks or {}, num_microbatches=1)
        cfg1 = cfg.with_(num_layers=p, scan_layers=False)
        cfg2 = cfg.with_(num_layers=2 * p, scan_layers=False)
        _, comp1 = _compile_plan(cfg1, mesh, shape_cfg, force_2d, ptweaks)
        f1, b1, c1, _ = _cost_of(comp1)
        _, comp2 = _compile_plan(cfg2, mesh, shape_cfg, force_2d, ptweaks)
        f2, b2, c2, _ = _cost_of(comp2)
        k = cfg.num_periods - 1
        flops, bytes_hbm, coll_b = (f1 + (f2 - f1) * k,
                                    b1 + (b2 - b1) * k,
                                    c1 + (c2 - c1) * k)
        probe_info = {"probe1": [f1, b1, c1], "probe2": [f2, b2, c2]}
    else:
        flops, bytes_hbm, coll_b = f_scan, b_scan, c_scan
        probe_info = {"scan_only": [f_scan, b_scan, c_scan]}

    tokens = (shape_cfg.global_batch * shape_cfg.seq_len
              if shape_cfg.kind != "decode" else shape_cfg.global_batch)
    values = plan.abstract_args[0] if shape_cfg.kind != "train" else None
    if shape_cfg.kind == "train":
        from repro.core import peft

        values = peft.combine(plan.abstract_args[0], plan.abstract_args[1])
    mf_total = model_flops(values, cfg, tokens, shape_cfg.kind == "train")

    rl = Roofline(
        flops=flops,
        bytes_hbm=bytes_hbm,
        bytes_collective=coll_b,
        model_flops_per_dev=mf_total / n_dev,
        n_devices=n_dev,
    )

    mem_dict = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_dict[attr] = int(v)

    rec = {
        "arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape_cfg.kind, "mode": cfg.quant.mode,
        "status": "ok", "force_2d": bool(force_2d),
        "compile_s": round(t_compile, 1),
        "memory": mem_dict,
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "probe_info": probe_info,
        "sharding_fallbacks": plan.rules.dropped[:20],
        "roofline": rl.to_dict(),
    }
    if verbose:
        arg_gb = mem_dict.get("argument_size_in_bytes", 0) / 1e9
        tmp_gb = mem_dict.get("temp_size_in_bytes", 0) / 1e9
        print(f"[ok] {arch:24s} {shape:12s} mesh={rec['mesh']:8s} "
              f"args={arg_gb:7.2f}GB temp={tmp_gb:7.2f}GB "
              f"t_c={rl.t_compute:.3e}s t_m={rl.t_memory:.3e}s "
              f"t_coll={rl.t_collective:.3e}s bound={rl.bottleneck:10s} "
              f"frac={rl.model_fraction:.3f} (compile {t_compile:.0f}s)",
              flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force-2d", action="store_true", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.all:
        todo = list(cells())
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []
    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            try:
                records.append(run_cell(arch, shape, multi_pod=mp,
                                        force_2d=args.force_2d,
                                        probes=not mp))
            except Exception as e:  # noqa: BLE001 — report, keep going
                failures += 1
                traceback.print_exc()
                records.append({"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "status": f"FAIL: {type(e).__name__}: {e}"})
                print(f"[FAIL] {arch} {shape} multi_pod={mp}: {e}",
                      flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
