"""Roofline-term derivation from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW   (serial lower bound)

HLO FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed out of the (per-device SPMD) HLO text — the sum of output-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (convention documented in EXPERIMENTS.md).

MODEL_FLOPS uses the 6·N·D (train) / 2·N·D (forward) convention with N =
matmul parameters (packed codes expanded to logical element counts; MoE
counted active-only), so MODEL_FLOPS/HLO_FLOPs exposes remat & attention &
dispatch overheads.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e per chip
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # B/s
ICI_BW = 50e9           # B/s per link (serial lower bound; no multi-link model)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1, "s4": 0.5,
    "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> float:
    """Sum of bytes over every 'dtype[dims]' shape literal in ``text``."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _executed_lines(hlo_text: str):
    """Lines of computations XLA executes per-op (skip fusion interiors).

    cost_analysis models a fusion's traffic as its operands+outputs, so ops
    *inside* %fused_computation bodies must not be double-counted by our
    text-level passes.
    """
    in_fusion = False
    depth = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not in_fusion and ls.startswith("%fused_") and ls.endswith("{"):
            in_fusion = True
            depth = 1
            continue
        if in_fusion:
            depth += ls.count("{") - ls.count("}")
            if depth <= 0:
                in_fusion = False
            continue
        yield ls


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind output bytes summed over the per-device program."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for ls in _executed_lines(hlo_text):
        if "=" not in ls:
            continue
        lhs, rhs = ls.split("=", 1)
        rhs_s = rhs.strip()
        # op name appears as e.g. 'bf16[128,4096] all-reduce(' — after shape
        m = re.match(r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)",
                     rhs_s)
        if not m:
            continue
        op = m.group(1)
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                # bytes = output shape(s) on the lhs-declared shape in rhs
                shape_txt = rhs_s.split(op)[0]
                out[kind] += _shape_bytes(shape_txt)
                counts[kind] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


_UPCAST_RE = re.compile(
    r"=\s*f32\[([0-9,]*)\][^ ]*\s+convert\(\s*(?:[a-z0-9_.%-]+\s+)?bf16\[")


def cpu_upcast_bytes(hlo_text: str) -> float:
    """Bytes attributable to bf16->f32 operand upcasts the CPU emitter
    inserts before dots (TPU MXUs consume bf16 natively — these converts do
    not exist in the TPU program).  Counted as read(bf16) + write(f32) = 6
    bytes/element, top-level ops only.
    """
    total = 0.0
    for ls in _executed_lines(hlo_text):
        m = _UPCAST_RE.search(ls)
        if not m:
            continue
        n = 1
        for d in m.group(1).split(","):
            if d:
                n *= int(d)
        if n * 4 >= 1 << 20:  # ignore small converts
            total += 6.0 * n
    return total


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_hbm: float
    bytes_collective: float
    model_flops_per_dev: float
    n_devices: int

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self):
        return self.bytes_collective / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def model_fraction(self):
        """useful-model-FLOPs time / bound time (upper bound on MFU)."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops_per_dev / PEAK_FLOPS) / self.t_bound

    @property
    def flops_ratio(self):
        return self.model_flops_per_dev / self.flops if self.flops else 0.0

    def to_dict(self):
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.bytes_hbm,
            "collective_bytes_per_dev": self.bytes_collective,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_per_dev": self.model_flops_per_dev,
            "model_flops_ratio": self.flops_ratio,
            "model_fraction_of_roofline": self.model_fraction,
        }


def model_flops(values, cfg, tokens: int, training: bool) -> float:
    """6·N·D (train) or 2·N·D (forward) with MoE active-only counting."""
    import jax

    from repro.core.quantize import pack_spec

    ps = pack_spec(cfg.quant.codebook)
    flat = jax.tree_util.tree_flatten_with_path(values)[0]
    n_active = 0.0
    moe_frac = (cfg.moe.top_k / cfg.moe.num_experts) if cfg.moe else 1.0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        name = keys[-1] if keys else ""
        if name == "q":
            # logical weight count from the packed byte count
            n = leaf.size // ps.group_bytes * ps.group_codes
        elif name in ("w", "head", "router", "dt_proj", "lora_a", "lora_b", "r"):
            n = leaf.size
        else:
            continue
        # stacked expert FFNs: (layers, E, out, in) or (E, out, in)
        is_expert = cfg.moe is not None and any(
            k in ("w_gate", "w_up", "w_down") for k in keys) and "mlp" in keys
        n_active += n * (moe_frac if is_expert else 1.0)
    factor = 6.0 if training else 2.0
    return factor * n_active * tokens
