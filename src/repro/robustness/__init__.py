"""repro.robustness — deterministic fault injection + hardening helpers."""
from repro.robustness.faults import (  # noqa: F401
    NO_FAULTS,
    FaultPlan,
    FaultSpec,
    InjectedFault,
)
