"""Seeded, deterministic fault injection for the serving engine and the
train loop.

A :class:`FaultPlan` is a named set of injection points the hardened code
paths *consult* (``plan.fires("engine.page_alloc")``) at well-defined
moments; the plan decides — deterministically, from its seed and the
consultation index — whether the fault fires this time.  The consuming code
then exercises its real recovery path (stall/evict, retry/requeue,
quarantine, drain, skip/rollback) exactly as it would for an organic fault,
so chaos tests pin failure *semantics*, not mocks.

Design rules:
  * **Deterministic.**  Each point gets its own ``np.random.default_rng``
    seeded from ``(seed, crc32(point))`` plus a consultation counter.  The
    same seed + spec + consultation order always fires the same faults —
    a chaos trace is replayable bit-for-bit.
  * **Zero-cost when disabled.**  Hardened code holds :data:`NO_FAULTS`
    (whose ``fires`` is a constant ``False``) unless a plan is supplied;
    there is no per-step dict lookup or RNG draw in clean runs.
  * **Bounded.**  ``max_fires`` caps a point's total fires so probabilistic
    faults cannot livelock a bounded-retry loop.

Engine injection points (consulted by ``repro.launch.engine.Engine``):
  * ``engine.page_alloc`` — one per page-pool pop; firing makes the
    allocation fail as if the pool were dry (slot stalls / eviction).
  * ``engine.step``      — one per jitted step launch; firing raises
    :class:`InjectedFault` *before* the launch (request-scoped failure:
    participants are retried/requeued, the pool state stays valid).
  * ``engine.nan_logits``— one per decode launch; firing poisons the first
    KV page of the oldest decoding slot with NaNs, so the *real* in-graph
    non-finite guard trips and the engine quarantines that slot only.
  * ``engine.straggler`` — one per scheduler tick; firing sleeps
    ``delay_s`` (artificial straggler step — deadline/timeout pressure).
  * ``engine.preempt``   — one per scheduler tick; firing flips the engine
    into graceful drain (stop admitting, finish in-flight work).

Train injection points (consulted by ``repro.launch.train.run_training``):
  * ``train.grad_spike`` — one per step; firing forces the grad-spike
    detector's threshold below any real norm, so the in-graph guard skips
    the update (and K consecutive fires exercise checkpoint rollback).

Streaming-PTQ injection points (consulted by ``repro.ptq_stream``):
  * ``ptq.kill_at_block``     — one per freshly-processed block; firing
    raises :class:`InjectedFault` at the block boundary, before any work.
  * ``ptq.kill_mid_write``    — one per shard write; firing kills between
    the temp-file write and the atomic publish (temp is stray, no shard).
  * ``ptq.kill_before_commit``— one per block commit; firing kills after
    the shard is published but before its ledger entry lands.
  * ``ptq.corrupt_shard``     — one per shard write; firing flips a byte
    of the *published* shard (bitrot the resume audit must catch).
  * ``ptq.transient_oserror`` — one per shard-write attempt; firing raises
    ``OSError`` inside the retried write fn (``retry_on_transient`` path).
  * ``ptq.oom_spike``         — one per budget charge; firing adds a
    phantom allocation of the full limit, tripping the memory watchdog.

Checkpoint injection points (consulted by ``repro.checkpoint``):
  * ``ckpt.save_crash``       — one per leaf written during a save; firing
    raises :class:`InjectedFault` mid-save, leaving a stray ``.tmp`` step
    dir that ``latest_step``/``restore`` must ignore.

Mesh injection points (consulted by the elastic layers in
``repro.launch.train`` / ``repro.launch.engine`` / ``repro.ptq_stream``):
  * ``dist.device_loss``       — one per step/tick; firing simulates a host
    dropping out of the mesh: the consumer rebuilds a smaller mesh
    (``make_host_mesh``), elastically reshards its state onto it
    (checkpointer v2 restore / ``device_put``), and continues.
  * ``dist.host_crash``        — one per step; firing raises
    :class:`InjectedFault` (whole-process crash drill — the outer driver
    restarts and resumes from the latest checkpoint/ledger).
  * ``dist.collective_timeout``— one per collective step launch; firing
    raises :class:`InjectedFault` *before* the launch, exercising the
    bounded retry path without corrupting device state.
  * ``dist.replica_desync``    — one per desync-digest interval; firing
    perturbs one replica's digest so the *real* compare-quarantine-rollback
    path runs (silent divergence cannot be created under single-controller
    SPMD, so — like ``train.grad_spike`` — the detector input is forced
    and the recovery path is exercised for real).
  * ``dist.straggler``         — one per (tick, shard); firing sleeps
    ``delay_s`` so the straggler watchdog flags that shard.

Mesh points are consulted with an explicit *shard/process index*
(``plan.fires("dist.straggler", index=3)``): every (point, index) pair owns
an independent RNG stream keyed ``[seed, crc32(point), index]`` and its own
consultation counter, so a multi-process replay is bit-identical no matter
how many processes consult concurrently — shard 3's fault schedule never
depends on how many siblings exist (the acceptance contract for
deterministic mesh chaos across process counts).  ``FaultSpec.only_index``
restricts a point to one shard (e.g. "host 1 dies", "shard 3 straggles").
"""
from __future__ import annotations

import dataclasses
import time
import zlib

import numpy as np

__all__ = ["FaultSpec", "FaultPlan", "InjectedFault", "NO_FAULTS"]


class InjectedFault(RuntimeError):
    """Raised by hardened code when a ``*.step``-style point fires; kept a
    distinct type so recovery code can tell an injected failure (state
    known-good: raised before the launch) from an organic one."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """When one injection point fires.

    ``at``: consultation indices (0-based) that fire deterministically.
    ``prob``: per-consultation fire probability (seeded RNG).
    ``max_fires``: cap on total fires (None = unbounded).  For indexed
    (mesh) points the cap is **per stream** — a global cap would make one
    shard's schedule depend on sibling interleaving and break cross-
    process-count determinism.
    ``delay_s``: sleep this long on fire (straggler-style points).
    ``only_index``: restrict an indexed point to one shard/process
    (e.g. "host 1 dies"); consultations with any other index never fire.
    """
    prob: float = 0.0
    at: tuple = ()
    max_fires: int | None = None
    delay_s: float = 0.0
    only_index: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "at", tuple(self.at))
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob {self.prob} outside [0, 1]")


def _point_rng(seed: int, point: str,
               index: int | None = None) -> np.random.Generator:
    # crc32, not hash(): stable across processes (PYTHONHASHSEED)
    key = [seed, zlib.crc32(point.encode())]
    if index is not None:
        # index + 1, never a bare 0: SeedSequence zero-pads its entropy
        # list, so [seed, crc, 0] would be the *same* stream as the
        # un-indexed [seed, crc] — shard 0 must not mirror the legacy point
        key.append(int(index) + 1)
    return np.random.default_rng(key)


class FaultPlan:
    """Seeded fault plan: ``spec`` maps point name -> FaultSpec (or the
    kwargs dict for one).  Replayable: same seed + spec + consultation
    order => same fires."""

    enabled = True

    def __init__(self, seed: int, spec: dict):
        self.seed = int(seed)
        self.spec: dict[str, FaultSpec] = {
            k: (v if isinstance(v, FaultSpec) else FaultSpec(**v))
            for k, v in spec.items()}
        # Streams are keyed (point, index); index None is the classic
        # un-indexed stream and keeps the exact pre-existing RNG keying.
        # Indexed streams materialize lazily on first consultation.
        self._rngs: dict[tuple, np.random.Generator] = {}
        self._consults: dict[tuple, int] = {}
        self._fired: dict[tuple, int] = {}
        for k in self.spec:
            self._stream(k, None)

    def _stream(self, point: str, index: int | None) -> tuple:
        key = (point, index)
        if key not in self._rngs:
            self._rngs[key] = _point_rng(self.seed, point, index)
            self._consults[key] = 0
            self._fired[key] = 0
        return key

    def fires(self, point: str, index: int | None = None) -> bool:
        """Consult ``point``; True iff the fault fires this consultation.

        ``index`` names the consulting shard/process for mesh points: each
        (point, index) pair is an independent deterministic stream, so the
        schedule seen by shard *i* does not depend on how many other shards
        consult, or in what order.
        """
        s = self.spec.get(point)
        if s is None:
            return False
        key = self._stream(point, index)
        i = self._consults[key]
        self._consults[key] = i + 1
        if s.only_index is not None and index != s.only_index:
            return False
        hit = i in s.at
        if not hit and s.prob > 0.0:
            hit = self._rngs[key].random() < s.prob
        if not hit:
            return False
        if s.max_fires is not None and self._fired[key] >= s.max_fires:
            return False
        self._fired[key] += 1
        if s.delay_s > 0.0:
            time.sleep(s.delay_s)
        return True

    def fired(self, point: str, index: int | None = ...) -> int:
        if index is not ...:
            return self._fired.get((point, index), 0)
        return sum(n for (p, _), n in self._fired.items() if p == point)

    def consulted(self, point: str, index: int | None = ...) -> int:
        if index is not ...:
            return self._consults.get((point, index), 0)
        return sum(n for (p, _), n in self._consults.items() if p == point)

    def reset(self):
        """Rewind every point to consultation 0 (fresh replay)."""
        self._rngs = {}
        self._consults = {}
        self._fired = {}
        for k in self.spec:
            self._stream(k, None)

    def summary(self) -> dict:
        def _label(key):
            point, index = key
            return point if index is None else f"{point}[{index}]"
        return {"enabled": True, "seed": self.seed,
                "consults": {_label(k): v for k, v in self._consults.items()},
                "fired": {_label(k): v for k, v in self._fired.items()}}


class _NoFaults:
    """Null plan: the zero-cost default every hardened path holds."""

    enabled = False

    def fires(self, point: str, index: int | None = None) -> bool:
        return False

    def fired(self, point: str, index: int | None = ...) -> int:
        return 0

    def consulted(self, point: str, index: int | None = ...) -> int:
        return 0

    def reset(self):
        pass

    def summary(self) -> dict:
        return {"enabled": False}


NO_FAULTS = _NoFaults()
