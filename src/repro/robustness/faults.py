"""Seeded, deterministic fault injection for the serving engine and the
train loop.

A :class:`FaultPlan` is a named set of injection points the hardened code
paths *consult* (``plan.fires("engine.page_alloc")``) at well-defined
moments; the plan decides — deterministically, from its seed and the
consultation index — whether the fault fires this time.  The consuming code
then exercises its real recovery path (stall/evict, retry/requeue,
quarantine, drain, skip/rollback) exactly as it would for an organic fault,
so chaos tests pin failure *semantics*, not mocks.

Design rules:
  * **Deterministic.**  Each point gets its own ``np.random.default_rng``
    seeded from ``(seed, crc32(point))`` plus a consultation counter.  The
    same seed + spec + consultation order always fires the same faults —
    a chaos trace is replayable bit-for-bit.
  * **Zero-cost when disabled.**  Hardened code holds :data:`NO_FAULTS`
    (whose ``fires`` is a constant ``False``) unless a plan is supplied;
    there is no per-step dict lookup or RNG draw in clean runs.
  * **Bounded.**  ``max_fires`` caps a point's total fires so probabilistic
    faults cannot livelock a bounded-retry loop.

Engine injection points (consulted by ``repro.launch.engine.Engine``):
  * ``engine.page_alloc`` — one per page-pool pop; firing makes the
    allocation fail as if the pool were dry (slot stalls / eviction).
  * ``engine.step``      — one per jitted step launch; firing raises
    :class:`InjectedFault` *before* the launch (request-scoped failure:
    participants are retried/requeued, the pool state stays valid).
  * ``engine.nan_logits``— one per decode launch; firing poisons the first
    KV page of the oldest decoding slot with NaNs, so the *real* in-graph
    non-finite guard trips and the engine quarantines that slot only.
  * ``engine.straggler`` — one per scheduler tick; firing sleeps
    ``delay_s`` (artificial straggler step — deadline/timeout pressure).
  * ``engine.preempt``   — one per scheduler tick; firing flips the engine
    into graceful drain (stop admitting, finish in-flight work).

Train injection points (consulted by ``repro.launch.train.run_training``):
  * ``train.grad_spike`` — one per step; firing forces the grad-spike
    detector's threshold below any real norm, so the in-graph guard skips
    the update (and K consecutive fires exercise checkpoint rollback).

Streaming-PTQ injection points (consulted by ``repro.ptq_stream``):
  * ``ptq.kill_at_block``     — one per freshly-processed block; firing
    raises :class:`InjectedFault` at the block boundary, before any work.
  * ``ptq.kill_mid_write``    — one per shard write; firing kills between
    the temp-file write and the atomic publish (temp is stray, no shard).
  * ``ptq.kill_before_commit``— one per block commit; firing kills after
    the shard is published but before its ledger entry lands.
  * ``ptq.corrupt_shard``     — one per shard write; firing flips a byte
    of the *published* shard (bitrot the resume audit must catch).
  * ``ptq.transient_oserror`` — one per shard-write attempt; firing raises
    ``OSError`` inside the retried write fn (``retry_on_transient`` path).
  * ``ptq.oom_spike``         — one per budget charge; firing adds a
    phantom allocation of the full limit, tripping the memory watchdog.

Checkpoint injection points (consulted by ``repro.checkpoint``):
  * ``ckpt.save_crash``       — one per leaf written during a save; firing
    raises :class:`InjectedFault` mid-save, leaving a stray ``.tmp`` step
    dir that ``latest_step``/``restore`` must ignore.
"""
from __future__ import annotations

import dataclasses
import time
import zlib

import numpy as np

__all__ = ["FaultSpec", "FaultPlan", "InjectedFault", "NO_FAULTS"]


class InjectedFault(RuntimeError):
    """Raised by hardened code when a ``*.step``-style point fires; kept a
    distinct type so recovery code can tell an injected failure (state
    known-good: raised before the launch) from an organic one."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """When one injection point fires.

    ``at``: consultation indices (0-based) that fire deterministically.
    ``prob``: per-consultation fire probability (seeded RNG).
    ``max_fires``: cap on total fires (None = unbounded).
    ``delay_s``: sleep this long on fire (straggler-style points).
    """
    prob: float = 0.0
    at: tuple = ()
    max_fires: int | None = None
    delay_s: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "at", tuple(self.at))
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob {self.prob} outside [0, 1]")


def _point_rng(seed: int, point: str) -> np.random.Generator:
    # crc32, not hash(): stable across processes (PYTHONHASHSEED)
    return np.random.default_rng([seed, zlib.crc32(point.encode())])


class FaultPlan:
    """Seeded fault plan: ``spec`` maps point name -> FaultSpec (or the
    kwargs dict for one).  Replayable: same seed + spec + consultation
    order => same fires."""

    enabled = True

    def __init__(self, seed: int, spec: dict):
        self.seed = int(seed)
        self.spec: dict[str, FaultSpec] = {
            k: (v if isinstance(v, FaultSpec) else FaultSpec(**v))
            for k, v in spec.items()}
        self._rngs = {k: _point_rng(self.seed, k) for k in self.spec}
        self._consults: dict[str, int] = {k: 0 for k in self.spec}
        self._fired: dict[str, int] = {k: 0 for k in self.spec}

    def fires(self, point: str) -> bool:
        """Consult ``point``; True iff the fault fires this consultation."""
        s = self.spec.get(point)
        if s is None:
            return False
        i = self._consults[point]
        self._consults[point] = i + 1
        hit = i in s.at
        if not hit and s.prob > 0.0:
            hit = self._rngs[point].random() < s.prob
        if not hit:
            return False
        if s.max_fires is not None and self._fired[point] >= s.max_fires:
            return False
        self._fired[point] += 1
        if s.delay_s > 0.0:
            time.sleep(s.delay_s)
        return True

    def fired(self, point: str) -> int:
        return self._fired.get(point, 0)

    def consulted(self, point: str) -> int:
        return self._consults.get(point, 0)

    def reset(self):
        """Rewind every point to consultation 0 (fresh replay)."""
        self._rngs = {k: _point_rng(self.seed, k) for k in self.spec}
        self._consults = {k: 0 for k in self.spec}
        self._fired = {k: 0 for k in self.spec}

    def summary(self) -> dict:
        return {"enabled": True, "seed": self.seed,
                "consults": dict(self._consults),
                "fired": dict(self._fired)}


class _NoFaults:
    """Null plan: the zero-cost default every hardened path holds."""

    enabled = False

    def fires(self, point: str) -> bool:
        return False

    def fired(self, point: str) -> int:
        return 0

    def consulted(self, point: str) -> int:
        return 0

    def reset(self):
        pass

    def summary(self) -> dict:
        return {"enabled": False}


NO_FAULTS = _NoFaults()
