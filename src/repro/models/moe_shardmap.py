"""Explicit expert-parallel MoE dispatch via shard_map + all_to_all.

The §Perf fix for collective-bound MoE training: the portable pjit path lets
GSPMD partition a global scatter/gather over (tokens × experts), and at
kimi-k2 scale the partitioner falls back to replication (~40 TB/step/device
of collective traffic in the baseline dry-run).  This path makes the
communication pattern explicit and minimal:

  per device: route -> local slot assignment -> (E_pad, C_loc, d) buffer
  all_to_all over the EP axes: each device receives its experts' tokens
  local (quantized) expert FFN
  inverse all_to_all -> local gate-weighted combine

Requirements: experts (padded to ``pad_experts_to``) divisible by the EP
axis product; tokens stay within their batch shard (no cross-DP traffic).
Collective bytes/device/layer = 2 × t_loc·k·cf·d·2B — the theoretical
minimum for capacity-based EP dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models.common import current_rules  # ambient rules (mesh + axes)

__all__ = ["moe_apply_shard_map"]


def _ep_axes(mesh, e_pad):
    """Largest mesh-axis tuple (from fastest axes) that divides e_pad."""
    for axes in (("pod", "data", "model"), ("data", "model"), ("model",)):
        if all(a in mesh.shape for a in axes):
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if e_pad % size == 0:
                return axes, size
    return (), 1


def _batch_axes(mesh, rules, b):
    rule = rules.get("batch") or ()
    if isinstance(rule, str):
        rule = (rule,)
    axes = tuple(a for a in rule if a in mesh.shape)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if axes and b % size == 0 and size > 1:
        return axes, size
    return (), 1


def moe_apply_shard_map(params, x, cfg, quant):
    from repro.models.moe import (
        _expert_ffn,
        _n_experts_padded,
        _ranks_within_expert,
        _route,
    )

    mo, d = cfg.moe, cfg.d_model
    e, k = mo.num_experts, mo.top_k
    e_pad = _n_experts_padded(mo)
    b, s, _ = x.shape

    rules = current_rules() or {}
    mesh = rules.get("__mesh__")
    if mesh is None:  # no mesh (unit tests) -> portable path
        from repro.models.moe import _moe_apply_pjit

        return _moe_apply_pjit(params, x, cfg, quant)

    ep_axes, n_ep = _ep_axes(mesh, e_pad)
    b_axes, n_dp = _batch_axes(mesh, rules, b)
    if n_ep == 1:
        from repro.models.moe import _moe_apply_pjit

        return _moe_apply_pjit(params, x, cfg, quant)

    t_loc = (b // n_dp) * s
    cap = int(mo.capacity_factor * t_loc * k / e + 0.5)
    cap = max(8, -(-cap // 8) * 8)

    x_spec = P(b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None),
               None, None)
    w_spec = jax.tree.map(lambda _: P(ep_axes if len(ep_axes) > 1 else
                                      ep_axes[0]), params)
    w_spec["router"] = P()  # replicated

    # EP axes the batch is NOT sharded over hold replicated copies of x —
    # each such rank dispatches a distinct token slice (else every model-rank
    # would dispatch the same tokens: 16x duplicate all-to-all traffic and
    # 16x oversized expert buffers, the refuted first version of this path)
    rep_axes = tuple(a for a in ep_axes if a not in b_axes)
    n_rep = 1
    for a in rep_axes:
        n_rep *= mesh.shape[a]

    def body(x_loc, wr, wgate, wup, wdown):
        lp = {"router": wr, "w_gate": wgate, "w_up": wup, "w_down": wdown}
        bl, sl, _ = x_loc.shape
        tl_full = bl * sl
        xfull = x_loc.reshape(tl_full, d)
        if rep_axes and tl_full % n_rep == 0:
            ridx = jax.lax.axis_index(rep_axes)
            tl = tl_full // n_rep
            xf = jax.lax.dynamic_slice_in_dim(xfull, ridx * tl, tl, axis=0)
        else:
            ridx, tl, xf = None, tl_full, xfull

        gates, idx, aux = _route(lp, xf, mo)
        cap_l = max(8, -(-int(mo.capacity_factor * tl * k / e + 0.5) // 8) * 8)

        flat_e = idx.reshape(-1)
        ranks = _ranks_within_expert(flat_e, e, tl * k)
        keep = ranks < cap_l
        dest = jnp.where(keep, flat_e * cap_l + ranks, e_pad * cap_l)

        src = jnp.repeat(xf, k, axis=0)
        buf = jnp.zeros((e_pad * cap_l + 1, d), x_loc.dtype).at[dest].set(src)
        send = buf[: e_pad * cap_l].reshape(e_pad, cap_l, d)

        # EP all-to-all: experts split across devices, capacities concatenate
        recv = jax.lax.all_to_all(send, ep_axes, split_axis=0, concat_axis=1,
                                  tiled=True)  # (e_pad/n_ep, n_ep*cap_l, d)

        # already inside this shard_map: the expert matmuls are local by
        # construction, so fused dispatch must not open a nested shard_map
        from repro.kernels import dispatch

        with dispatch.shard_scope(None):
            y_loc = _expert_ffn(recv, lp, mo, d, quant)

        back = jax.lax.all_to_all(y_loc, ep_axes, split_axis=1, concat_axis=0,
                                  tiled=True)  # (e_pad, cap_l, d)
        ybuf = jnp.concatenate(
            [back.reshape(e_pad * cap_l, d),
             jnp.zeros((1, d), back.dtype)], axis=0)
        per_assign = ybuf[dest] * gates.reshape(-1)[:, None].astype(
            back.dtype)
        y = jnp.sum(per_assign.reshape(tl, k, d), axis=1)
        if ridx is not None:  # reassemble the token slices
            y = jax.lax.all_gather(y, rep_axes, axis=0, tiled=True)
        # aux is a mean over local tokens; average across DP shards
        aux = jax.lax.pmean(aux, b_axes + rep_axes) if (b_axes or rep_axes) \
            else aux
        return y.reshape(bl, sl, d).astype(x_loc.dtype), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, w_spec["router"], w_spec["w_gate"],
                  w_spec["w_up"], w_spec["w_down"]),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y, aux
