"""Recurrent mixers: Mamba (Jamba's SSM layer) and xLSTM (mLSTM / sLSTM).

Training paths are parallel where the math allows:
  * Mamba — chunked associative scan over the discretized diagonal SSM
    (chunk length cfg.mamba.chunk bounds the (chunk, d_inner, d_state)
    working set; the inter-chunk recurrence is a cheap sequential scan),
  * mLSTM — stabilized parallel (quadratic) form, q-chunked exactly like
    chunked attention; decay matrix from cumulative log-forget-gates,
  * sLSTM — inherently sequential (recurrent R matrices): lax.scan over time.

Decode paths are O(1)-state single-step recurrences; their states are the
`long_500k` story — no KV growth.

All projections are quantized linears (LoRDS applies to every matmul weight;
convs / gates / A_log stay fp — they are vectors or tiny).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (
    P,
    dense_init,
    f32_einsum,
    qlinear_apply,
    qlinear_init,
    shard,
)

__all__ = [
    "mamba_init", "mamba_train", "mamba_decode", "mamba_cache_init",
    "mlstm_init", "mlstm_train", "mlstm_decode", "mlstm_cache_init",
    "slstm_init", "slstm_train", "slstm_decode", "slstm_cache_init",
]


# ---------------------------------------------------------------------------
# Mamba (selective SSM, Gu & Dao 2023), as used by Jamba
# ---------------------------------------------------------------------------


def _mamba_dims(cfg):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return mc, d_in, dt_rank


def mamba_init(key, cfg, quant):
    mc, d_in, dt_rank = _mamba_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (d_in, mc.d_state)))
    return {
        "in_proj": qlinear_init(ks[0], 2 * d_in, d, quant, "mamba_in", "embed"),
        "conv_w": dense_init(ks[1], (mc.d_conv, d_in), (None, "mamba_in"),
                             dtype=jnp.float32, scale=0.5),
        "conv_b": P(jnp.zeros((d_in,), jnp.float32), ("mamba_in",)),
        "x_proj": qlinear_init(ks[2], dt_rank + 2 * mc.d_state, d_in, quant,
                               "dt_rank", "mamba_in"),
        "dt_proj": dense_init(ks[3], (d_in, dt_rank), ("mamba_in", "dt_rank"),
                              dtype=jnp.float32),
        "dt_bias": P(jnp.log(jnp.exp(
            jax.random.uniform(ks[4], (d_in,), jnp.float32, 1e-3, 0.1)) - 1.0
        ), ("mamba_in",)),
        "a_log": P(a_init, ("mamba_in", "state")),
        "d_skip": P(jnp.ones((d_in,), jnp.float32), ("mamba_in",)),
        "out_proj": qlinear_init(ks[5], d, d_in, quant, "embed", "mamba_in"),
    }


def _ssm_scan_chunked(a_bar, bx, h0, chunk):
    """h_t = a_t * h_{t-1} + bx_t over time axis 1.

    a_bar, bx: (b, s, d_in, n); h0: (b, d_in, n).  Returns (h_all, h_last).
    """
    b, s, d_in, n = a_bar.shape
    chunk = min(chunk, s)
    if s % chunk:
        import math
        chunk = math.gcd(chunk, s) or s
    nc = s // chunk
    a_c = a_bar.reshape(b, nc, chunk, d_in, n)
    bx_c = bx.reshape(b, nc, chunk, d_in, n)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_body(h, inp):
        ac, bc = inp  # (b, chunk, d_in, n)
        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = b_cum + a_cum * h[:, None]
        return h_all[:, -1], h_all

    h_last, h_stack = jax.lax.scan(
        chunk_body, h0, (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(bx_c, 1, 0))
    )
    h_all = jnp.moveaxis(h_stack, 0, 1).reshape(b, s, d_in, n)
    return h_all, h_last


def _causal_conv(u, w, bias, state=None):
    """u (b,s,d_in); w (k,d_in); left-pad causal depthwise conv."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)  # (b, k-1, d_in)
    ext = jnp.concatenate([pad, u], axis=1)
    out = sum(
        ext[:, i : i + u.shape[1], :] * w[i][None, None, :].astype(u.dtype)
        for i in range(k)
    )
    new_state = ext[:, -(k - 1):, :] if k > 1 else pad
    return out + bias[None, None, :].astype(u.dtype), new_state


def mamba_train(params, x, cfg, quant, positions=None):
    mc, d_in, dt_rank = _mamba_dims(cfg)
    d = cfg.d_model
    b, s, _ = x.shape
    zu = qlinear_apply(params["in_proj"], x, quant, 2 * d_in, d)
    z, u = jnp.split(zu, 2, axis=-1)
    u, _ = _causal_conv(u, params["conv_w"], params["conv_b"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    u = shard(u, "batch", "seq", "mamba_act")

    proj = qlinear_apply(params["x_proj"], u, quant, dt_rank + 2 * mc.d_state,
                         d_in)
    dt_r = proj[..., :dt_rank]
    b_t = proj[..., dt_rank : dt_rank + mc.d_state].astype(jnp.float32)
    c_t = proj[..., dt_rank + mc.d_state :].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,dr->bsd", dt_r.astype(jnp.float32),
                   params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"][None, None]
    )  # (b,s,d_in)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (d_in, n)
    da = jnp.exp(dt[..., None] * a[None, None])  # (b,s,d_in,n)
    dbu = (dt * u.astype(jnp.float32))[..., None] * b_t[:, :, None, :]
    h0 = jnp.zeros((b, d_in, mc.d_state), jnp.float32)
    h_all, _ = _ssm_scan_chunked(da, dbu, h0, mc.chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all, c_t)
    y = y + params["d_skip"][None, None] * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return qlinear_apply(params["out_proj"], y, quant, d, d_in)


def mamba_cache_init(cfg, batch, dtype=jnp.float32):
    mc, d_in, _ = _mamba_dims(cfg)
    return {
        "h": P(jnp.zeros((batch, d_in, mc.d_state), jnp.float32),
               ("batch", "mamba_act", "state")),
        "conv": P(jnp.zeros((batch, mc.d_conv - 1, d_in), dtype),
                  ("batch", None, "mamba_act")),
    }


def mamba_decode(params, x, cfg, quant, cache, pos=None):
    mc, d_in, dt_rank = _mamba_dims(cfg)
    d = cfg.d_model
    b = x.shape[0]
    zu = qlinear_apply(params["in_proj"], x, quant, 2 * d_in, d)  # (b,1,2di)
    z, u = jnp.split(zu, 2, axis=-1)
    u, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"],
                                 state=cache["conv"])
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    proj = qlinear_apply(params["x_proj"], u, quant, dt_rank + 2 * mc.d_state,
                         d_in)
    dt_r = proj[..., :dt_rank]
    b_t = proj[..., dt_rank : dt_rank + mc.d_state].astype(jnp.float32)
    c_t = proj[..., dt_rank + mc.d_state :].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,dr->bsd", dt_r.astype(jnp.float32),
                   params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"][None, None]
    )[:, 0]  # (b,d_in)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[..., None] * a[None])  # (b,d_in,n)
    dbu = (dt * u[:, 0].astype(jnp.float32))[..., None] * b_t[:, 0, None, :]
    h = da * cache["h"] + dbu
    y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])
    y = y + params["d_skip"][None] * u[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None].astype(x.dtype)
    out = qlinear_apply(params["out_proj"], y, quant, d, d_in)
    return out, {"h": h, "conv": conv_state.astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM; Beck et al. 2024) — matrix memory, parallel training form
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg):
    xc = cfg.xlstm
    d_in = int(xc.proj_factor * cfg.d_model)
    nh = cfg.num_heads
    dh = d_in // nh
    return xc, d_in, nh, dh


def mlstm_init(key, cfg, quant):
    xc, d_in, nh, dh = _mlstm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "up_proj": qlinear_init(ks[0], 2 * d_in, d, quant, "mlstm_in", "embed"),
        "conv_w": dense_init(ks[1], (xc.conv_k, d_in), (None, "mlstm_in"),
                             dtype=jnp.float32, scale=0.5),
        "conv_b": P(jnp.zeros((d_in,), jnp.float32), ("mlstm_in",)),
        "wq": qlinear_init(ks[2], d_in, d_in, quant, "mlstm_in", "mlstm_in"),
        "wk": qlinear_init(ks[3], d_in, d_in, quant, "mlstm_in", "mlstm_in"),
        "wv": qlinear_init(ks[4], d_in, d_in, quant, "mlstm_in", "mlstm_in"),
        "w_i": dense_init(ks[5], (nh, d_in), ("heads", "mlstm_in"),
                          dtype=jnp.float32),
        "b_i": P(jnp.zeros((nh,), jnp.float32), ("heads",)),
        "w_f": dense_init(ks[6], (nh, d_in), ("heads", "mlstm_in"),
                          dtype=jnp.float32),
        "b_f": P(3.0 * jnp.ones((nh,), jnp.float32), ("heads",)),
        "down_proj": qlinear_init(ks[7], d, d_in, quant, "embed", "mlstm_in"),
    }


def _mlstm_gates(params, xc_feats):
    """xc_feats (b,s,d_in) -> log input gate, log forget gate (b,s,nh)."""
    i_pre = jnp.einsum("bsd,hd->bsh", xc_feats.astype(jnp.float32),
                       params["w_i"]) + params["b_i"]
    f_pre = jnp.einsum("bsd,hd->bsh", xc_feats.astype(jnp.float32),
                       params["w_f"]) + params["b_f"]
    logf = jax.nn.log_sigmoid(f_pre)
    return i_pre, logf


def mlstm_train(params, x, cfg, quant, positions=None, chunk=512):
    xc, d_in, nh, dh = _mlstm_dims(cfg)
    d = cfg.d_model
    b, s, _ = x.shape
    xz = qlinear_apply(params["up_proj"], x, quant, 2 * d_in, d)
    xm, z = jnp.split(xz, 2, axis=-1)
    xconv, _ = _causal_conv(xm, params["conv_w"], params["conv_b"])
    xconv = jax.nn.silu(xconv.astype(jnp.float32)).astype(x.dtype)

    q = qlinear_apply(params["wq"], xconv, quant, d_in, d_in)
    k = qlinear_apply(params["wk"], xconv, quant, d_in, d_in)
    v = qlinear_apply(params["wv"], xm, quant, d_in, d_in)
    q = q.reshape(b, s, nh, dh)
    k = k.reshape(b, s, nh, dh) / jnp.sqrt(dh)
    v = v.reshape(b, s, nh, dh)

    i_pre, logf = _mlstm_gates(params, xconv)  # (b,s,nh)
    bcum = jnp.cumsum(logf, axis=1)  # (b,s,nh)

    chunk = min(chunk, s)
    if s % chunk:
        import math
        chunk = math.gcd(chunk, s) or s
    nc = s // chunk
    qg = jnp.moveaxis(q.reshape(b, nc, chunk, nh, dh), 1, 0)
    # decay weights: log w_ij = bcum_i - bcum_j + i_j   (j <= i)
    kv_logw = i_pre - bcum  # (b,s,nh): the j-dependent part
    kpos = jnp.arange(s, dtype=jnp.int32)

    def body(carry, inp):
        qc, ci = inp
        qpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        bq = jax.lax.dynamic_slice_in_dim(bcum, ci * chunk, chunk, axis=1)
        logw = bq[:, :, None, :] + kv_logw[:, None, :, :]  # (b,cq,s,nh)
        mask = (qpos[:, None] >= kpos[None, :])[None, :, :, None]
        logw = jnp.where(mask, logw, -jnp.inf)
        m = jnp.max(logw, axis=2, keepdims=True)  # (b,cq,1,nh)
        m = jnp.maximum(m, -60.0)
        wmat = jnp.exp(logw - m)  # (b,cq,s,nh)
        scores = f32_einsum("bchd,bshd->bchs", qc, k)
        sw = scores * wmat.transpose(0, 1, 3, 2)  # (b, cq, nh, s)
        denom = jnp.maximum(
            jnp.abs(jnp.sum(sw, axis=-1)), jnp.exp(-m[:, :, 0, :])
        )  # (b,cq,nh)
        out = jnp.einsum("bchs,bshd->bchd", sw, v.astype(jnp.float32))
        out = out / denom[..., None]
        return carry, out

    _, outs = jax.lax.scan(body, None,
                           (qg, jnp.arange(nc, dtype=jnp.int32)))
    h = jnp.moveaxis(outs, 0, 1).reshape(b, s, d_in)
    h = (h * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return qlinear_apply(params["down_proj"], h, quant, d, d_in)


def mlstm_cache_init(cfg, batch, dtype=jnp.float32):
    xc, d_in, nh, dh = _mlstm_dims(cfg)
    return {
        "c": P(jnp.zeros((batch, nh, dh, dh), jnp.float32),
               ("batch", "heads", None, None)),
        "n": P(jnp.zeros((batch, nh, dh), jnp.float32),
               ("batch", "heads", None)),
        "m": P(jnp.full((batch, nh), -1e30, jnp.float32), ("batch", "heads")),
        "conv": P(jnp.zeros((batch, xc.conv_k - 1, d_in), dtype),
                  ("batch", None, "mlstm_in")),
    }


def mlstm_decode(params, x, cfg, quant, cache, pos=None):
    xc, d_in, nh, dh = _mlstm_dims(cfg)
    d = cfg.d_model
    b = x.shape[0]
    xz = qlinear_apply(params["up_proj"], x, quant, 2 * d_in, d)
    xm, z = jnp.split(xz, 2, axis=-1)
    xconv, conv_state = _causal_conv(xm, params["conv_w"], params["conv_b"],
                                     state=cache["conv"])
    xconv = jax.nn.silu(xconv.astype(jnp.float32)).astype(x.dtype)
    q = qlinear_apply(params["wq"], xconv, quant, d_in, d_in).reshape(b, nh, dh)
    k = qlinear_apply(params["wk"], xconv, quant, d_in, d_in).reshape(b, nh, dh)
    k = k / jnp.sqrt(dh)
    v = qlinear_apply(params["wv"], xm, quant, d_in, d_in).reshape(b, nh, dh)

    i_pre, logf = _mlstm_gates(params, xconv)  # (b,1,nh)
    i_pre, logf = i_pre[:, 0], logf[:, 0]  # (b,nh)
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    decay = jnp.exp(logf + cache["m"] - m_new)[..., None]
    inp = jnp.exp(i_pre - m_new)[..., None]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    c_new = decay[..., None] * cache["c"] + (inp[..., None]
                                             * kf[..., :, None] * vf[..., None, :])
    n_new = decay * cache["n"] + inp * kf
    num = jnp.einsum("bhij,bhi->bhj", c_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n_new, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, 1, d_in)
    h = (h * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = qlinear_apply(params["down_proj"], h, quant, d, d_in)
    return out, {"c": c_new, "n": n_new, "m": m_new,
                 "conv": conv_state.astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory variant; sequential)
# ---------------------------------------------------------------------------


def slstm_init(key, cfg, quant):
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    ks = jax.random.split(key, 6)
    p = {}
    for i, gate in enumerate(("z", "i", "f", "o")):
        p[f"w_{gate}"] = qlinear_init(ks[i], d, d, quant, "slstm_in", "embed")
    p["r"] = dense_init(ks[4], (nh, dh, dh), ("heads", None, None),
                        dtype=jnp.float32, scale=1.0 / jnp.sqrt(dh))
    p["b_z"] = P(jnp.zeros((d,), jnp.float32), ("slstm_in",))
    p["b_i"] = P(jnp.zeros((d,), jnp.float32), ("slstm_in",))
    p["b_f"] = P(3.0 * jnp.ones((d,), jnp.float32), ("slstm_in",))
    p["b_o"] = P(jnp.zeros((d,), jnp.float32), ("slstm_in",))
    return p


def _slstm_step(params, xz, xi, xf, xo, state, nh, dh):
    """One recurrence step; x* are pre-projected gate inputs (b, d)."""
    h, c, n, m = state
    b = h.shape[0]
    hh = h.reshape(b, nh, dh)
    rz = jnp.einsum("bhi,hij->bhj", hh, params["r"]).reshape(b, nh * dh)
    z = jnp.tanh(xz + rz + params["b_z"])
    i_pre = xi + rz + params["b_i"]
    f_pre = xf + rz + params["b_f"]
    o = jax.nn.sigmoid(xo + rz + params["b_o"])
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    c_new = jnp.exp(logf + m - m_new) * c + jnp.exp(i_pre - m_new) * z
    n_new = jnp.exp(logf + m - m_new) * n + jnp.exp(i_pre - m_new)
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_train(params, x, cfg, quant, positions=None):
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    b, s, _ = x.shape
    xz = qlinear_apply(params["w_z"], x, quant, d, d).astype(jnp.float32)
    xi = qlinear_apply(params["w_i"], x, quant, d, d).astype(jnp.float32)
    xf = qlinear_apply(params["w_f"], x, quant, d, d).astype(jnp.float32)
    xo = qlinear_apply(params["w_o"], x, quant, d, d).astype(jnp.float32)

    def body(state, t_in):
        tz, ti, tf, to = t_in
        h, c, n, m = _slstm_step(params, tz, ti, tf, to, state, nh, dh)
        return (h, c, n, m), h

    zero = jnp.zeros((b, d), jnp.float32)
    init = (zero, zero, zero, jnp.full((b, d), -1e30, jnp.float32))
    _, hs = jax.lax.scan(
        body, init,
        (jnp.moveaxis(xz, 1, 0), jnp.moveaxis(xi, 1, 0),
         jnp.moveaxis(xf, 1, 0), jnp.moveaxis(xo, 1, 0)),
    )
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)


def slstm_cache_init(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    zero = jnp.zeros((batch, d), jnp.float32)
    return {
        "h": P(zero, ("batch", "slstm_in")),
        "c": P(zero, ("batch", "slstm_in")),
        "n": P(zero, ("batch", "slstm_in")),
        "m": P(jnp.full((batch, d), -1e30, jnp.float32), ("batch", "slstm_in")),
    }


def slstm_decode(params, x, cfg, quant, cache, pos=None):
    d = cfg.d_model
    nh = cfg.num_heads
    dh = d // nh
    xz = qlinear_apply(params["w_z"], x, quant, d, d)[:, 0].astype(jnp.float32)
    xi = qlinear_apply(params["w_i"], x, quant, d, d)[:, 0].astype(jnp.float32)
    xf = qlinear_apply(params["w_f"], x, quant, d, d)[:, 0].astype(jnp.float32)
    xo = qlinear_apply(params["w_o"], x, quant, d, d)[:, 0].astype(jnp.float32)
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_step(params, xz, xi, xf, xo, state, nh, dh)
    return h[:, None].astype(x.dtype), {"h": h, "c": c, "n": n, "m": m}
