"""Mixture-of-Experts layer with expert parallelism.

Dispatch is sort-free scatter-based (O(T·k·d) data movement, no (T×E×C)
one-hot einsum whose FLOPs would be quadratic in tokens):

  1. router top-k over experts (f32),
  2. per-assignment slot index = rank of the token within its expert queue
     (computed with an argsort over the T·k expert ids),
  3. scatter into the (E, C, d) dispatch buffer (capacity-dropped, like
     GShard/Switch; capacity_factor controls drop rate),
  4. per-expert quantized FFN (LoRDS/baseline weights, stacked per expert),
  5. gather back + gate-weighted combine.

Expert weights carry the 'expert' logical axis; the dispatch buffer is
sharding-constrained to the expert axis so GSPMD materializes the
all-to-all on the expert-parallel mesh axis.  Router aux (load-balance) loss
is returned to the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lords
from repro.models.common import P, dense_init, shard

__all__ = ["moe_init", "moe_apply", "dense_mlp_init", "dense_mlp_apply"]


# ---------------------------------------------------------------------------
# dense (SwiGLU) MLP — also the per-expert FFN body
# ---------------------------------------------------------------------------


def dense_mlp_init(key, d, d_ff, quant):
    ks = jax.random.split(key, 3)
    from repro.models.common import qlinear_init

    return {
        "w_gate": qlinear_init(ks[0], d_ff, d, quant, "mlp", "embed"),
        "w_up": qlinear_init(ks[1], d_ff, d, quant, "mlp", "embed"),
        "w_down": qlinear_init(ks[2], d, d_ff, quant, "embed", "mlp"),
    }


def dense_mlp_apply(params, x, d, d_ff, quant):
    from repro.models.common import qlinear_apply

    g = qlinear_apply(params["w_gate"], x, quant, d_ff, d)
    u = qlinear_apply(params["w_up"], x, quant, d_ff, d)
    h = jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
    h = shard(h.astype(x.dtype), "batch", "seq", "mlp_act")
    return qlinear_apply(params["w_down"], h, quant, d, d_ff)


# ---------------------------------------------------------------------------
# expert-stacked quantized linears (vmapped core init over the expert axis)
# ---------------------------------------------------------------------------


def _qlinear_stack_init(key, e, n, m, quant):
    """Stack of e quantized (n×m) linears; leaves get a leading 'expert' axis.

    vmapped over the expert axis — a Python loop here costs minutes of trace
    time at kimi-k2 scale (384 experts × 61 layers × 3 matrices).
    """
    keys = jax.random.split(key, e)
    init_one = lambda k: lords.init_quantized_linear(k, n, m, quant)
    stacked = jax.vmap(init_one)(keys)
    axes = lords.linear_param_specs(quant, "moe_out", "moe_in")
    return {
        k: P(v, ("expert",) + axes[k]) for k, v in stacked.items()
    }


def _qlinear_stack_apply(ptree, xd, quant, n, m, e_here):
    """Batched per-expert quantized matmul: (E, C, m) -> (E, C, n).

    vmaps the kernel-dispatch entry point over the expert axis, so each
    expert's fused dequant-matmul runs as one batched kernel invocation —
    the (E, n, m) dequantized weight stack is never materialized.

    Tensor-parallel dispatch is pinned off here: expert weights shard over
    the *expert* axis (EP), not row-wise over 'model', so the per-expert
    matmuls must stay local (and a shard_map under this vmap would be
    ill-formed anyway).
    """
    from repro.kernels import dispatch
    from repro.kernels.dispatch import qmatmul

    sliced = jax.tree.map(lambda v: v[:e_here], ptree)
    with dispatch.shard_scope(None):
        return jax.vmap(lambda p, xe: qmatmul(p, xe, quant, n, m))(sliced, xd)


def _n_experts_padded(mo):
    return max(mo.pad_experts_to or 0, mo.num_experts)


def moe_init(key, cfg, quant):
    mo, d = cfg.moe, cfg.d_model
    e_pad = _n_experts_padded(mo)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (mo.num_experts, d), ("expert", "embed"),
                             dtype=jnp.float32),
        "w_gate": _qlinear_stack_init(ks[1], e_pad, mo.d_ff, d, quant),
        "w_up": _qlinear_stack_init(ks[2], e_pad, mo.d_ff, d, quant),
        "w_down": _qlinear_stack_init(ks[3], e_pad, d, mo.d_ff, quant),
    }


def _route(params, xf, mo):
    """Shared router: returns (gates (t,k), idx (t,k), aux scalar)."""
    e, k = mo.num_experts, mo.top_k
    logits = jnp.einsum(
        "td,ed->te", xf.astype(jnp.float32),
        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)
    return gates, idx, aux


def _ranks_within_expert(flat_e, e_total, tk):
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e_total), side="left")
    rank_sorted = jnp.arange(tk) - seg_start[sorted_e]
    return jnp.zeros((tk,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))


def _expert_ffn(xd, params, mo, d, quant):
    """SwiGLU over (E_local, C, d) with stacked (possibly padded) experts."""
    e_here = xd.shape[0]
    g = _qlinear_stack_apply(params["w_gate"], xd, quant, mo.d_ff, d, e_here)
    u = _qlinear_stack_apply(params["w_up"], xd, quant, mo.d_ff, d, e_here)
    h = (jax.nn.silu(g.astype(jnp.float32))
         * u.astype(jnp.float32)).astype(xd.dtype)
    return _qlinear_stack_apply(params["w_down"], h, quant, d, mo.d_ff, e_here)


def moe_apply(params, x, cfg, quant):
    """x (b,s,d) -> (y (b,s,d), aux_loss scalar)."""
    if cfg.moe.dispatch == "shard_map":
        from repro.models.moe_shardmap import moe_apply_shard_map

        return moe_apply_shard_map(params, x, cfg, quant)
    return _moe_apply_pjit(params, x, cfg, quant)


def _moe_apply_pjit(params, x, cfg, quant):
    mo, d = cfg.moe, cfg.d_model
    e, k = mo.num_experts, mo.top_k
    e_pad = _n_experts_padded(mo)
    b, s, _ = x.shape
    t = b * s
    xf = x.reshape(t, d)

    gates, idx, aux = _route(params, xf, mo)

    # ---- slot assignment: rank of each (token, j) within its expert ----
    flat_e = idx.reshape(-1)  # (t*k,)
    ranks = _ranks_within_expert(flat_e, e, t * k)

    cap = int(mo.capacity_factor * t * k / e + 0.5)
    cap = max(8, -(-cap // 8) * 8)  # round up to a multiple of 8
    keep = ranks < cap
    dest = jnp.where(keep, flat_e * cap + ranks, e_pad * cap)  # drops -> pad

    # ---- dispatch (scatter) ----
    src = jnp.repeat(xf, k, axis=0)  # (t*k, d) token rows per assignment
    src = shard(src, "tokens", None)
    buf = jnp.zeros((e_pad * cap + 1, d), x.dtype).at[dest].set(src)
    xd = buf[: e_pad * cap].reshape(e_pad, cap, d)
    xd = shard(xd, "expert", "capacity", None)

    yd = _expert_ffn(xd, params, mo, d, quant)
    yd = shard(yd, "expert", "capacity", None)

    # ---- combine (gather) ----
    ybuf = jnp.concatenate([yd.reshape(e_pad * cap, d),
                            jnp.zeros((1, d), yd.dtype)], axis=0)
    per_assign = ybuf[dest]  # (t*k, d); dropped slots hit the zero pad row
    per_assign = shard(per_assign, "tokens", None)
    per_assign = per_assign * gates.reshape(-1)[:, None].astype(per_assign.dtype)
    y = jnp.sum(per_assign.reshape(t, k, d), axis=1)
    return y.reshape(b, s, d).astype(x.dtype), aux
