"""repro.models — the architecture zoo (all linears quantized via repro.core)."""
from repro.models.common import P, activation_rules, shard, split_tree  # noqa: F401
from repro.models.model import (  # noqa: F401
    cache_init,
    forward_decode,
    forward_decode_paged,
    forward_prefill,
    forward_prefill_chunk,
    forward_train,
    model_init,
    paged_cache_init,
)
