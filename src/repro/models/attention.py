"""Attention mixers: GQA/MQA (chunked-causal) and MLA (latent KV compression).

Pure-JAX implementations built for three regimes:
  * train/prefill — q-chunked causal attention (flash-style memory profile:
    the (seq × seq) score matrix never materializes; peak extra memory is
    (batch, heads, chunk, seq) per layer, rematerialized in backward),
  * decode — single-token query against a fixed-capacity KV cache,
  * MLA decode uses the *absorbed* latent form: the cache stores the
    compressed c_kv + shared RoPE key only (kv_lora + rope floats per token
    instead of 2·nh·hd) — the paper-native cache-compression win.

All linear projections (fused QKV/O, MLA down/up) go through the unified
kernel-dispatch layer (:func:`repro.kernels.dispatch.qmatmul`), so LoRDS /
any baseline runs its fused dequant-matmul on TPU and its oracle elsewhere.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import fused_backend_active, qattention, qmatmul
from repro.models.common import (
    P,
    apply_rope,
    f32_einsum,
    kv_dequantize,
    kv_quantize,
    qlinear_init,
    rmsnorm,
    rmsnorm_init,
    shard,
)

__all__ = [
    "gqa_init", "gqa_train", "gqa_decode",
    "mla_init", "mla_train", "mla_decode",
    "gqa_cache_init", "mla_cache_init",
    "gqa_paged_cache_init", "mla_paged_cache_init",
    "gqa_decode_paged", "mla_decode_paged",
    "gqa_prefill_chunk", "mla_prefill_chunk",
]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared chunked causal core
# ---------------------------------------------------------------------------


def chunked_causal_attention(q, k, v, *, chunk=512, logit_scale=None,
                             positions=None):
    """q (b,s,nh,hd), k/v (b,s,nkv,hd) -> (b,s,nh,hd); causal.

    On the fused backends (pallas/interpret) this routes through
    ``dispatch.qattention("prefill", ...)`` — the streaming-softmax flash
    kernel that reads the *unexpanded* GQA KV heads and never materializes
    a score matrix.  The chunked einsum body below is the portable path
    and the fused kernel's parity oracle.

    ``positions`` (b, s) int32 drives the causal mask (ragged / shifted
    sequences mask per batch row; -1 marks dead padding rows); None means
    the standard aligned arange.

    Ref-path notes: GQA keys/values are expanded to the full head count
    *before* the score einsum — a (nkv, g) reshape of a TP-sharded head
    dim is not representable in GSPMD and silently replicates the
    (b,h,chunk,s) score tensors, while the expansion keeps everything
    head-sharded (the flash kernel avoids the expansion natively via its
    KV index map).  The chunk body is rematerialized: backward keeps only
    (q-chunk, out).
    """
    b, s, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    scale = logit_scale if logit_scale is not None else 1.0 / math.sqrt(hd)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if fused_backend_active():
        out = qattention("prefill", q, k, v, positions,
                         logit_scale=float(scale))
        return out.astype(q.dtype)

    chunk = min(chunk, s)
    if s % chunk:  # odd smoke-test lengths: fall back to a divisor
        chunk = math.gcd(chunk, s) or s
    nc = s // chunk

    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = shard(k, "batch", "seq", "heads", "head_dim")
        v = shard(v, "batch", "seq", "heads", "head_dim")
    kpos = positions  # (b, s)

    def body(carry, inputs):
        qc, ci = inputs  # (b, chunk, nh, hd), scalar chunk index
        qpos = jax.lax.dynamic_slice_in_dim(positions, ci * chunk, chunk,
                                            axis=1)          # (b, chunk)
        scores = f32_einsum(
            "bcnh,bsnh->bncs", qc * jnp.asarray(scale, qc.dtype), k)
        mask = (kpos[:, None, :] <= qpos[:, :, None]) \
            & (kpos[:, None, :] >= 0)                        # (b, chunk, s)
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = f32_einsum("bncs,bsnh->bcnh", probs, v)
        return carry, out.astype(q.dtype)

    qc_stack = jnp.moveaxis(q.reshape(b, nc, chunk, nh, hd), 1, 0)
    _, outs = jax.lax.scan(
        jax.checkpoint(body),
        None, (qc_stack, jnp.arange(nc, dtype=jnp.int32))
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, nh, v.shape[-1])
    return out.astype(q.dtype)


def _scatter_token(cache_arr, new, pos):
    """Write per-sequence entries ``new`` (b, 1, ...) into ``cache_arr``
    (b, S, ...) at per-sequence positions ``pos`` (b,) int32.

    Ragged-safe: each batch row scatters at its own position (the old code
    used pos[0] for the whole batch, silently corrupting ragged batches).
    """
    return jax.vmap(
        lambda c, u, p: jax.lax.dynamic_update_slice(
            c, u, (p,) + (0,) * (c.ndim - 1))
    )(cache_arr, new, pos)


def decode_attention(q, k_cache, v_cache, pos, *, logit_scale=None,
                     k_scale=None, v_scale=None):
    """q (b,1,nh,hd) vs cache (b,S,nkv,hd); positions<=pos are live.

    With ``k_scale``/``v_scale`` (b,S,nkv) the caches hold per-head int8
    codes.  On the fused backends the whole read side routes through
    ``dispatch.qattention("decode", ...)`` — the cache streams through the
    flash-decode kernel once, *as stored*, with the per-(token, head)
    scales folded into the in-kernel dot products: int8 KV pays int8
    bandwidth (the full roofline number bench_serve reports).  The einsum
    body below is the portable path / parity oracle; it dequantizes the
    entire cache up front, which is why int8 used to *lose* to bf16 here.
    """
    b, _, nh, hd = q.shape
    nkv = k_cache.shape[2]
    g = nh // nkv
    cap = k_cache.shape[1]
    scale = logit_scale if logit_scale is not None else 1.0 / math.sqrt(hd)
    if fused_backend_active():
        out = qattention("decode", q[:, 0], k_cache, v_cache, pos,
                         k_scale, v_scale, logit_scale=float(scale))
        return out[:, None].astype(q.dtype)  # (b, 1, nh, hd_v)
    if k_scale is not None:
        k_cache = kv_dequantize(k_cache, k_scale, dtype=q.dtype)
    if v_scale is not None:
        v_cache = kv_dequantize(v_cache, v_scale, dtype=q.dtype)
    qg = q.reshape(b, nkv, g, hd)
    scores = f32_einsum(
        "bngh,bsnh->bngs", qg * jnp.asarray(scale, qg.dtype), k_cache)
    live = jnp.arange(cap, dtype=jnp.int32)[None, :] <= pos[:, None]  # (b,S)
    scores = jnp.where(live[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = f32_einsum("bngs,bsnh->bngh", probs, v_cache)
    return out.reshape(b, 1, nh, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg, quant):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": qlinear_init(ks[0], nh * hd, d, quant, "qkv_out", "embed"),
        "wk": qlinear_init(ks[1], nkv * hd, d, quant, "kv_out", "embed"),
        "wv": qlinear_init(ks[2], nkv * hd, d, quant, "kv_out", "embed"),
        "wo": qlinear_init(ks[3], d, nh * hd, quant, "embed", "qkv_out"),
    }


def _gqa_qkv(params, x, cfg, quant, positions):
    b, s, d = x.shape
    hd, nh, nkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = qmatmul(params["wq"], x, quant, nh * hd, d).reshape(b, s, nh, hd)
    k = qmatmul(params["wk"], x, quant, nkv * hd, d).reshape(b, s, nkv, hd)
    v = qmatmul(params["wv"], x, quant, nkv * hd, d).reshape(b, s, nkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def gqa_train(params, x, cfg, quant, positions, chunk=512):
    b, s, d = x.shape
    nh, hd = cfg.num_heads, cfg.resolved_head_dim
    q, k, v = _gqa_qkv(params, x, cfg, quant, positions)
    out = chunked_causal_attention(q, k, v, chunk=chunk,
                                   positions=positions)
    out = out.reshape(b, s, nh * hd)
    return qmatmul(params["wo"], out, quant, d, nh * hd)


def gqa_cache_init(cfg, batch, capacity, dtype=jnp.bfloat16):
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    shape = (batch, capacity, nkv, hd)
    axes = ("batch", "cache_seq", "kv_heads", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        s_axes = ("batch", "cache_seq", "kv_heads")
        return {
            "k": P(jnp.zeros(shape, jnp.int8), axes),
            "v": P(jnp.zeros(shape, jnp.int8), axes),
            "k_scale": P(jnp.zeros(shape[:3], jnp.float32), s_axes),
            "v_scale": P(jnp.zeros(shape[:3], jnp.float32), s_axes),
        }
    return {"k": P(jnp.zeros(shape, dtype), axes),
            "v": P(jnp.zeros(shape, dtype), axes)}


def _kv_store(cache, name, new, pos=None):
    """Store ``new`` (b, s, nkv, hd) into cache slot ``name``, quantizing to
    the cache's storage format.  pos None = prefill (write at 0); pos (b,)
    = decode (ragged per-sequence scatter)."""
    quantized = f"{name}_scale" in cache
    if quantized:
        codes, scale = kv_quantize(new)
        if pos is None:
            out = {
                name: jax.lax.dynamic_update_slice(
                    cache[name], codes, (0,) * cache[name].ndim),
                f"{name}_scale": jax.lax.dynamic_update_slice(
                    cache[f"{name}_scale"], scale,
                    (0,) * cache[f"{name}_scale"].ndim),
            }
        else:
            out = {
                name: _scatter_token(cache[name], codes, pos),
                f"{name}_scale": _scatter_token(
                    cache[f"{name}_scale"], scale, pos),
            }
    elif pos is None:
        out = {name: jax.lax.dynamic_update_slice(
            cache[name], new.astype(cache[name].dtype),
            (0,) * cache[name].ndim)}
    else:
        out = {name: _scatter_token(
            cache[name], new.astype(cache[name].dtype), pos)}
    return out


def gqa_prefill(params, x, cfg, quant, positions, cache, chunk=512):
    """Train-style forward that also fills the cache (capacity == seq)."""
    b, s, d = x.shape
    nh, hd = cfg.num_heads, cfg.resolved_head_dim
    q, k, v = _gqa_qkv(params, x, cfg, quant, positions)
    out = chunked_causal_attention(q, k, v, chunk=chunk,
                                   positions=positions)
    out = out.reshape(b, s, nh * hd)
    new_cache = {**_kv_store(cache, "k", k), **_kv_store(cache, "v", v)}
    return qmatmul(params["wo"], out, quant, d, nh * hd), new_cache


def gqa_decode(params, x, cfg, quant, cache, pos):
    """x (b,1,d); pos (b,) current position; cache dict of (b,S,nkv,hd).

    Positions may be ragged (one per sequence): the new KV scatters at each
    sequence's own slot and the attention mask is already per-sequence.
    """
    b, _, d = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = qmatmul(params["wq"], x, quant, nh * hd, d).reshape(b, 1, nh, hd)
    k = qmatmul(params["wk"], x, quant, nkv * hd, d).reshape(b, 1, nkv, hd)
    v = qmatmul(params["wv"], x, quant, nkv * hd, d).reshape(b, 1, nkv, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    new_cache = {**_kv_store(cache, "k", k, pos),
                 **_kv_store(cache, "v", v, pos)}
    new_cache = {
        kk: shard(vv, "batch", "cache_seq", "kv_heads", "head_dim"
                  ) if vv.ndim == 4
        else shard(vv, "batch", "cache_seq", "kv_heads")
        for kk, vv in new_cache.items()
    }
    out = decode_attention(q, new_cache["k"], new_cache["v"], pos,
                           k_scale=new_cache.get("k_scale"),
                           v_scale=new_cache.get("v_scale"))
    out = out.reshape(b, 1, nh * hd)
    y = qmatmul(params["wo"], out, quant, d, nh * hd)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-style multi-head latent attention; minicpm3)
# ---------------------------------------------------------------------------


def mla_init(key, cfg, quant):
    m, d, nh = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "q_down": qlinear_init(ks[0], m.q_lora_rank, d, quant, "q_lora", "embed"),
        "q_up": qlinear_init(ks[1], nh * qk, m.q_lora_rank, quant, "qkv_out", "q_lora"),
        "kv_down": qlinear_init(
            ks[2], m.kv_lora_rank + m.qk_rope_dim, d, quant, "kv_lora", "embed"),
        "k_up": qlinear_init(
            ks[3], nh * m.qk_nope_dim, m.kv_lora_rank, quant, "qkv_out", "kv_lora"),
        "v_up": qlinear_init(
            ks[4], nh * m.v_head_dim, m.kv_lora_rank, quant, "qkv_out", "kv_lora"),
        "wo": qlinear_init(ks[5], d, nh * m.v_head_dim, quant, "embed", "qkv_out"),
        "q_norm": rmsnorm_init(m.q_lora_rank, "q_lora"),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, "kv_lora"),
    }


def _mla_q(params, x, cfg, quant, positions):
    m, d, nh = cfg.mla, cfg.d_model, cfg.num_heads
    b, s, _ = x.shape
    qk = m.qk_nope_dim + m.qk_rope_dim
    ql = qmatmul(params["q_down"], x, quant, m.q_lora_rank, d)
    ql = rmsnorm(params["q_norm"], ql, cfg.norm_eps)
    q = qmatmul(params["q_up"], ql, quant, nh * qk, m.q_lora_rank)
    q = q.reshape(b, s, nh, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(params, x, cfg, quant, positions):
    m, d = cfg.mla, cfg.d_model
    ckv = qmatmul(
        params["kv_down"], x, quant, m.kv_lora_rank + m.qk_rope_dim, d)
    c, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c = rmsnorm(params["kv_norm"], c, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, k_rope  # (b,s,kv_lora), (b,s,rope)


def mla_train(params, x, cfg, quant, positions, chunk=512):
    m, d, nh = cfg.mla, cfg.d_model, cfg.num_heads
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(params, x, cfg, quant, positions)
    c, k_rope = _mla_latents(params, x, cfg, quant, positions)
    k_nope = qmatmul(
        params["k_up"], c, quant, nh * m.qk_nope_dim, m.kv_lora_rank
    ).reshape(b, s, nh, m.qk_nope_dim)
    v = qmatmul(
        params["v_up"], c, quant, nh * m.v_head_dim, m.kv_lora_rank
    ).reshape(b, s, nh, m.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, nh, m.qk_rope_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    out = chunked_causal_attention(q, k, v, chunk=chunk, logit_scale=scale,
                                   positions=positions)
    out = out.reshape(b, s, nh * m.v_head_dim)
    return qmatmul(params["wo"], out, quant, d, nh * m.v_head_dim)


def mla_cache_init(cfg, batch, capacity, dtype=jnp.bfloat16):
    m = cfg.mla
    cache = {
        "c": P(jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
               ("batch", "cache_seq", "kv_lora")),
        "k_rope": P(jnp.zeros((batch, capacity, m.qk_rope_dim), dtype),
                    ("batch", "cache_seq", "rope_dim")),
    }
    if cfg.kv_cache_dtype == "int8":
        # quantize the compressed latent (the bulk of the MLA cache);
        # k_rope is qk_rope_dim floats/token — not worth a scale per row
        cache["c"] = P(
            jnp.zeros((batch, capacity, m.kv_lora_rank), jnp.int8),
            ("batch", "cache_seq", "kv_lora"))
        cache["c_scale"] = P(jnp.zeros((batch, capacity), jnp.float32),
                             ("batch", "cache_seq"))
    return cache


def mla_prefill(params, x, cfg, quant, positions, cache, chunk=512):
    y = mla_train(params, x, cfg, quant, positions, chunk=chunk)
    c, k_rope = _mla_latents(params, x, cfg, quant, positions)
    new_cache = {**_kv_store(cache, "c", c),
                 **_kv_store(cache, "k_rope", k_rope)}
    return y, new_cache


def mla_decode(params, x, cfg, quant, cache, pos):
    """Absorbed-latent decode: cache is (c, k_rope) only; pos may be ragged.

    With an int8 latent cache the dequant happens right here at the two
    latent einsums; as in :func:`decode_attention`, the footprint saving is
    structural while the traffic saving depends on the dequant fusing into
    the einsum reads (fused-kernel target: int8 codes + one f32 scale per
    token).
    """
    m, d, nh = cfg.mla, cfg.d_model, cfg.num_heads
    b = x.shape[0]
    q_nope, q_rope = _mla_q(params, x, cfg, quant, pos[:, None])
    c_new, k_rope_new = _mla_latents(params, x, cfg, quant, pos[:, None])
    new_cache = {**_kv_store(cache, "c", c_new, pos),
                 **_kv_store(cache, "k_rope", k_rope_new, pos)}
    r_cache = new_cache["k_rope"]
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)

    # absorb k_up into q:  q_lat (b,1,nh,kv_lora)
    w_kup = _dequant(params["k_up"], cfg, quant, nh * m.qk_nope_dim, m.kv_lora_rank)
    w_kup = w_kup.reshape(nh, m.qk_nope_dim, m.kv_lora_rank)
    q_lat = f32_einsum("bthn,hnl->bthl", q_nope, w_kup.astype(q_nope.dtype))

    if fused_backend_active():
        # fused path: the (possibly int8) latent cache streams through the
        # flash-decode kernel once, as stored — no full-cache dequant temp
        lat = qattention(
            "mla_decode", q_lat[:, 0], q_rope[:, 0], new_cache["c"],
            r_cache, pos, new_cache.get("c_scale"),
            logit_scale=scale)[:, None]
    else:
        if "c_scale" in new_cache:
            c_cache = kv_dequantize(new_cache["c"], new_cache["c_scale"],
                                    dtype=r_cache.dtype)
        else:
            c_cache = new_cache["c"]
        cap = c_cache.shape[1]
        scores = f32_einsum("bthl,bsl->bhts", q_lat.astype(c_cache.dtype),
                            c_cache)
        scores += f32_einsum("bthr,bsr->bhts", q_rope.astype(r_cache.dtype),
                             r_cache)
        scores *= scale
        live = jnp.arange(cap, dtype=jnp.int32)[None, :] <= pos[:, None]
        scores = jnp.where(live[:, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(c_cache.dtype)
        lat = f32_einsum("bhts,bsl->bthl", probs, c_cache)
    w_vup = _dequant(params["v_up"], cfg, quant, nh * m.v_head_dim, m.kv_lora_rank)
    w_vup = w_vup.reshape(nh, m.v_head_dim, m.kv_lora_rank)
    out = f32_einsum("bthl,hvl->bthv", lat.astype(w_vup.dtype), w_vup)
    out = out.reshape(b, 1, nh * m.v_head_dim).astype(x.dtype)
    y = qmatmul(params["wo"], out, quant, d, nh * m.v_head_dim)
    return y, new_cache


def _dequant(ptree, cfg, quant, n, mdim):
    from repro.core import dequantize_weight

    return dequantize_weight(ptree, quant, n, mdim)


# ---------------------------------------------------------------------------
# block-paged KV (continuous-batching serving)
# ---------------------------------------------------------------------------
#
# The paged cache replaces the per-sequence (b, S, ...) cache with a global
# pool (P, ps, ...) of fixed-size pages plus a per-sequence page table
# (b, np) int32: logical page pi of slot b lives at physical page pt[b, pi].
# Page 0 is reserved as a dummy/scratch page — the engine points every
# unallocated (or inactive-slot) table entry at it, so fixed-shape decode
# steps can always run the full batch: dead slots scatter into page 0 and
# their reads are masked.  Decode reads go through
# qattention("paged_decode"/"paged_mla_decode") — the page table rides into
# the Pallas index maps, the int8 pool streams once as stored (the ref
# backend gathers; that's the jaxpr-guard negative control, not the serving
# path).  Writes are scatters into the flattened pool — never a gather.


def _paged_scatter_token(pool_arr, new, pt, pos):
    """Scatter per-sequence entries ``new`` (b, 1, ...) into the pool
    (P, ps, ...) at each slot's current position through the page table."""
    P_, ps = pool_arr.shape[:2]
    flat = pool_arr.reshape((P_ * ps,) + pool_arr.shape[2:])
    page = jnp.take_along_axis(pt, (pos // ps)[:, None], axis=1)[:, 0]
    idx = page * ps + pos % ps                                     # (b,)
    flat = flat.at[idx].set(new[:, 0].astype(pool_arr.dtype))
    return flat.reshape(pool_arr.shape)


def _paged_scatter_chunk(pool_arr, new, pt, pos0):
    """Write a prefill chunk ``new`` (b, cs, ...) as whole pages.

    Requires cs % ps == 0 and pos0 % ps == 0 (the engine aligns its chunk
    size to the page size), so the chunk covers cs/ps full pages per slot
    and the write is a page-granular scatter.  Rows past a slot's prompt
    carry garbage (dead qpos) — they land in pages that decode either masks
    (beyond pos) or overwrites token-by-token as pos advances."""
    b, cs = new.shape[:2]
    ps = pool_arr.shape[1]
    npg = cs // ps
    tiles = new.reshape((b * npg, ps) + new.shape[2:])
    lp = pos0[:, None] // ps + jnp.arange(npg, dtype=pt.dtype)[None]
    phys = jnp.take_along_axis(pt, lp, axis=1).reshape(-1)     # (b*npg,)
    return pool_arr.at[phys].set(tiles.astype(pool_arr.dtype))


def _paged_store(pool, name, new, pt, pos=None, pos0=None):
    """Paged analogue of :func:`_kv_store`: quantize ``new`` to the pool's
    storage format and scatter it through the page table.  Exactly one of
    ``pos`` (b,) (single-token decode write) / ``pos0`` (b,) (page-aligned
    chunk write) must be given."""
    scatter = (functools.partial(_paged_scatter_token, pt=pt, pos=pos)
               if pos is not None
               else functools.partial(_paged_scatter_chunk, pt=pt,
                                      pos0=pos0))
    if f"{name}_scale" in pool:
        codes, scale = kv_quantize(new)
        return {name: scatter(pool[name], codes),
                f"{name}_scale": scatter(pool[f"{name}_scale"], scale)}
    return {name: scatter(pool[name], new)}


def _paged_window(pool, name, pt, dtype):
    """Gather + dequantize the full logical window (b, np*ps, ...) of slot
    ``name`` — the *prefix* read of chunked prefill (a chunk's queries
    attend to everything earlier sequences of chunks wrote).  Decode never
    calls this: its reads go through the paged kernels."""
    arr = pool[name]
    P_, ps = arr.shape[:2]
    b = pt.shape[0]
    flat = arr.reshape((P_ * ps,) + arr.shape[2:])
    idx = (pt[:, :, None] * ps
           + jnp.arange(ps, dtype=pt.dtype)[None, None]).reshape(b, -1)
    win = jnp.take(flat, idx, axis=0)                   # (b, np*ps, ...)
    if f"{name}_scale" in pool:
        sarr = pool[f"{name}_scale"]
        swin = jnp.take(sarr.reshape((P_ * ps,) + sarr.shape[2:]), idx,
                        axis=0)
        return kv_dequantize(win, swin, dtype=dtype)
    return win.astype(dtype)


def gqa_paged_cache_init(cfg, total_pages, page_size, dtype=jnp.bfloat16):
    """Global page pool: (P, ps, nkv, hd) [+ scale pools (P, ps, nkv)].

    Pages never shard over data (every slot shares the pool); the kv_heads
    dim keeps the same model-axis rule as the contiguous cache."""
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    shape = (total_pages, page_size, nkv, hd)
    axes = ("kv_pages", "page_slot", "kv_heads", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        s_axes = ("kv_pages", "page_slot", "kv_heads")
        return {
            "k": P(jnp.zeros(shape, jnp.int8), axes),
            "v": P(jnp.zeros(shape, jnp.int8), axes),
            "k_scale": P(jnp.zeros(shape[:3], jnp.float32), s_axes),
            "v_scale": P(jnp.zeros(shape[:3], jnp.float32), s_axes),
        }
    return {"k": P(jnp.zeros(shape, dtype), axes),
            "v": P(jnp.zeros(shape, dtype), axes)}


def mla_paged_cache_init(cfg, total_pages, page_size, dtype=jnp.bfloat16):
    """MLA latent page pool: c (P, ps, kv_lora) + k_rope (P, ps, rope)."""
    m = cfg.mla
    pool = {
        "c": P(jnp.zeros((total_pages, page_size, m.kv_lora_rank), dtype),
               ("kv_pages", "page_slot", "kv_lora")),
        "k_rope": P(jnp.zeros((total_pages, page_size, m.qk_rope_dim),
                              dtype),
                    ("kv_pages", "page_slot", "rope_dim")),
    }
    if cfg.kv_cache_dtype == "int8":
        pool["c"] = P(
            jnp.zeros((total_pages, page_size, m.kv_lora_rank), jnp.int8),
            ("kv_pages", "page_slot", "kv_lora"))
        pool["c_scale"] = P(jnp.zeros((total_pages, page_size), jnp.float32),
                            ("kv_pages", "page_slot"))
    return pool


def gqa_decode_paged(params, x, cfg, quant, pool, pt, pos):
    """One paged decode step: x (b,1,d); pt (b,np); pos (b,) int32.

    Identical math to :func:`gqa_decode` — the new token's KV scatters into
    its slot's current page and attention reads the pool through the page
    table (the paged kinds route to the gather oracle off the fused
    backends, so every backend works; only the fused path is gather-free).
    """
    b, _, d = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = qmatmul(params["wq"], x, quant, nh * hd, d).reshape(b, 1, nh, hd)
    k = qmatmul(params["wk"], x, quant, nkv * hd, d).reshape(b, 1, nkv, hd)
    v = qmatmul(params["wv"], x, quant, nkv * hd, d).reshape(b, 1, nkv, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    new_pool = {**_paged_store(pool, "k", k, pt, pos=pos),
                **_paged_store(pool, "v", v, pt, pos=pos)}
    new_pool = {
        kk: shard(vv, "kv_pages", "page_slot", "kv_heads", "head_dim"
                  ) if vv.ndim == 4
        else shard(vv, "kv_pages", "page_slot", "kv_heads")
        for kk, vv in new_pool.items()
    }
    scale = 1.0 / math.sqrt(hd)
    out = qattention("paged_decode", q[:, 0], new_pool["k"], new_pool["v"],
                     pt, pos, new_pool.get("k_scale"),
                     new_pool.get("v_scale"), logit_scale=scale)
    out = out[:, None].astype(x.dtype).reshape(b, 1, nh * hd)
    y = qmatmul(params["wo"], out, quant, d, nh * hd)
    return y, new_pool


def mla_decode_paged(params, x, cfg, quant, pool, pt, pos):
    """Paged absorbed-latent MLA decode (see :func:`mla_decode`)."""
    m, d, nh = cfg.mla, cfg.d_model, cfg.num_heads
    b = x.shape[0]
    q_nope, q_rope = _mla_q(params, x, cfg, quant, pos[:, None])
    c_new, k_rope_new = _mla_latents(params, x, cfg, quant, pos[:, None])
    new_pool = {**_paged_store(pool, "c", c_new, pt, pos=pos),
                **_paged_store(pool, "k_rope", k_rope_new, pt, pos=pos)}
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    w_kup = _dequant(params["k_up"], cfg, quant, nh * m.qk_nope_dim,
                     m.kv_lora_rank)
    w_kup = w_kup.reshape(nh, m.qk_nope_dim, m.kv_lora_rank)
    q_lat = f32_einsum("bthn,hnl->bthl", q_nope, w_kup.astype(q_nope.dtype))
    lat = qattention(
        "paged_mla_decode", q_lat[:, 0], q_rope[:, 0], new_pool["c"],
        new_pool["k_rope"], pt, pos, new_pool.get("c_scale"),
        logit_scale=scale)[:, None]
    w_vup = _dequant(params["v_up"], cfg, quant, nh * m.v_head_dim,
                     m.kv_lora_rank)
    w_vup = w_vup.reshape(nh, m.v_head_dim, m.kv_lora_rank)
    out = f32_einsum("bthl,hvl->bthv", lat.astype(w_vup.dtype), w_vup)
    out = out.reshape(b, 1, nh * m.v_head_dim).astype(x.dtype)
    y = qmatmul(params["wo"], out, quant, d, nh * m.v_head_dim)
    return y, new_pool


def gqa_prefill_chunk(params, x, cfg, quant, qpos, pos0, pool, pt):
    """One chunk of paged prefill: x (b, cs, d) at positions ``qpos``
    (b, cs; -1 = dead row), chunk start ``pos0`` (b,) page-aligned.

    The chunk's KV is written into its slot's pages, then the chunk queries
    attend over [gathered prefix window (< pos0) ++ raw in-chunk KV] via
    qattention("chunk_prefill").  Keeping the in-chunk KV *raw* (not read
    back from the pool) makes a single-chunk prefill bit-identical to the
    contiguous prefill even with an int8 pool — the chunk never sees its
    own quantization error, exactly like the contiguous path."""
    b, cs, d = x.shape
    nh, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = _gqa_qkv(params, x, cfg, quant, qpos)
    new_pool = {**_paged_store(pool, "k", k, pt, pos0=pos0),
                **_paged_store(pool, "v", v, pt, pos0=pos0)}
    cap = pt.shape[1] * pool["k"].shape[1]
    kw = _paged_window(new_pool, "k", pt, k.dtype)
    vw = _paged_window(new_pool, "v", pt, v.dtype)
    prefix_pos = jnp.arange(cap, dtype=jnp.int32)[None]
    prefix_pos = jnp.where(prefix_pos < pos0[:, None], prefix_pos, -1)
    kcat = jnp.concatenate([kw, k], axis=1)
    vcat = jnp.concatenate([vw, v], axis=1)
    kpos = jnp.concatenate([prefix_pos, qpos], axis=1)
    scale = 1.0 / math.sqrt(hd)
    out = qattention("chunk_prefill", q, kcat, vcat, qpos, kpos,
                     logit_scale=scale)
    out = out.astype(x.dtype).reshape(b, cs, nh * hd)
    return qmatmul(params["wo"], out, quant, d, nh * hd), new_pool


def mla_prefill_chunk(params, x, cfg, quant, qpos, pos0, pool, pt):
    """Chunked paged MLA prefill: latents for the chunk are written to the
    pool; attention runs in the *train* (non-absorbed) form over
    [gathered prefix latents ++ raw chunk latents], up-projected to k/v."""
    m, d, nh = cfg.mla, cfg.d_model, cfg.num_heads
    b, cs, _ = x.shape
    q_nope, q_rope = _mla_q(params, x, cfg, quant, qpos)
    c, k_rope = _mla_latents(params, x, cfg, quant, qpos)
    new_pool = {**_paged_store(pool, "c", c, pt, pos0=pos0),
                **_paged_store(pool, "k_rope", k_rope, pt, pos0=pos0)}
    cap = pt.shape[1] * pool["c"].shape[1]
    cw = _paged_window(new_pool, "c", pt, c.dtype)
    rw = _paged_window(new_pool, "k_rope", pt, k_rope.dtype)
    ccat = jnp.concatenate([cw, c], axis=1)            # (b, cap+cs, L)
    rcat = jnp.concatenate([rw, k_rope], axis=1)       # (b, cap+cs, R)
    W = cap + cs
    k_nope = qmatmul(
        params["k_up"], ccat, quant, nh * m.qk_nope_dim, m.kv_lora_rank
    ).reshape(b, W, nh, m.qk_nope_dim)
    vcat = qmatmul(
        params["v_up"], ccat, quant, nh * m.v_head_dim, m.kv_lora_rank
    ).reshape(b, W, nh, m.v_head_dim)
    kcat = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(rcat[:, :, None], (b, W, nh, m.qk_rope_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    prefix_pos = jnp.arange(cap, dtype=jnp.int32)[None]
    prefix_pos = jnp.where(prefix_pos < pos0[:, None], prefix_pos, -1)
    kpos = jnp.concatenate([prefix_pos, qpos], axis=1)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    out = qattention("chunk_prefill", q, kcat, vcat, qpos, kpos,
                     logit_scale=scale)
    out = out.astype(x.dtype).reshape(b, cs, nh * m.v_head_dim)
    return qmatmul(params["wo"], out, quant, d, nh * m.v_head_dim), new_pool
