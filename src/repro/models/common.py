"""Shared model plumbing: param leaves with logical axes, norms, RoPE,
activation-sharding constraints.

Params are plain nested dicts whose leaves are :class:`P` — an array (or
ShapeDtypeStruct under ``jax.eval_shape``) tagged with *logical axis names*.
``split_tree`` separates values from axes so the distributed layer can build
PartitionSpecs without introspecting module code.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "P",
    "f32_einsum",
    "split_tree",
    "tree_axes",
    "qlinear_init",
    "qlinear_apply",
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "rope_freqs",
    "apply_rope",
    "shard",
    "activation_rules",
    "current_rules",
    "stack_periods",
    "kv_quantize",
    "kv_dequantize",
]


def f32_einsum(subscripts, *args):
    """einsum with f32 accumulation.

    TPU path (default): bf16 operands + preferred_element_type=f32 — native
    MXU mixed precision, no operand upcasts in HBM.
    CPU-execution path (REPRO_CPU_EXEC=1, set by tests/drivers/benchmarks):
    upcast operands — XLA:CPU cannot *execute* BF16×BF16→F32 dots.  The
    dry-run compiles on CPU but never executes, so it keeps the TPU form.
    """
    import os

    if os.environ.get("REPRO_CPU_EXEC") == "1":
        args = tuple(a.astype(jnp.float32) for a in args)
        return jnp.einsum(subscripts, *args)
    return jnp.einsum(subscripts, *args,
                      preferred_element_type=jnp.float32)


class P(NamedTuple):
    """A parameter leaf: array + logical axis names (one per dim)."""

    value: Any
    axes: tuple

    # make jax.tree happy if leaves leak through untyped paths
    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"P(shape={shape}, axes={self.axes})"


jax.tree_util.register_pytree_node(
    P, lambda p: ((p.value,), p.axes), lambda axes, v: P(v[0], axes)
)


def _is_p(x):
    return isinstance(x, P)


def split_tree(tree):
    """tree-of-P -> (tree of arrays, tree of axis tuples)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_p)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_p)
    return values, axes


def tree_axes(tree):
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_p)


def stack_periods(period_trees: list):
    """Stack per-period param trees along a new leading 'layers' axis."""
    def stack(*leaves):
        vals = [l.value for l in leaves]
        return P(jnp.stack(vals, axis=0), ("layers",) + leaves[0].axes)

    return jax.tree.map(stack, *period_trees, is_leaf=_is_p)


# ---------------------------------------------------------------------------
# Quantized + dense linears as P-trees
# ---------------------------------------------------------------------------


def qlinear_init(key, n, m, quant_spec, out_axis, in_axis, w=None,
                 use_bias=False):
    """Quantized linear (repro.core) wrapped in P leaves with logical axes."""
    from repro.core import init_quantized_linear, linear_param_specs

    params = init_quantized_linear(key, n, m, quant_spec, w=w,
                                   use_bias=use_bias)
    axes = linear_param_specs(quant_spec, out_axis, in_axis, use_bias=use_bias)
    return {k: P(v, axes[k]) for k, v in params.items()}


def qlinear_apply(params, x, quant_spec, n, m):
    """Quantized matmul through the unified kernel-dispatch layer."""
    from repro.kernels.dispatch import qmatmul

    return qmatmul(params, x, quant_spec, n, m)


def dense_init(key, shape, axes, dtype=jnp.bfloat16, scale=None):
    """Unquantized dense weight (router, embeddings, conv, gates...)."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(shape[-1])
    w = jax.random.normal(key, shape, jnp.float32) * scale
    return P(w.astype(dtype), axes)


# ---------------------------------------------------------------------------
# Quantized KV-cache storage (per-vector symmetric int8)
# ---------------------------------------------------------------------------

_KV_EPS = 1e-8  # all-zero vectors (cache padding) quantize to scale eps


def kv_quantize(x, axis: int = -1):
    """Symmetric int8 over ``axis``: returns (codes int8, scales f32).

    The scale tensor drops ``axis`` (one f32 per quantized vector — for a
    (b, s, nkv, hd) cache with axis=-1 that is per-token-per-head, the
    'per-head scales' layout the decode roofline wants: hd int8 + 4 bytes
    instead of hd bf16 per head-token).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, _KV_EPS) / 127.0
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return codes, jnp.squeeze(scale, axis=axis)


def kv_dequantize(codes, scale, axis: int = -1, dtype=jnp.bfloat16):
    """Inverse of :func:`kv_quantize` (codes ⊙ broadcast scales)."""
    return (codes.astype(jnp.float32)
            * jnp.expand_dims(scale, axis)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / RoPE
# ---------------------------------------------------------------------------


def rmsnorm_init(d, axis="embed"):
    return P(jnp.ones((d,), jnp.float32), (axis,))


def rmsnorm(g, x, eps=1e-5):
    import os

    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    if os.environ.get("REPRO_BF16_ELEMWISE") == "1":
        # perf mode: variance in f32, application in the compute dtype —
        # halves the (b,s,d)-sized elementwise traffic of every norm
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * g.astype(x.dtype)
    return (g * xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def rope_freqs(head_dim, theta=10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))
    return inv  # (head_dim/2,)


def apply_rope(x, positions, theta=10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    import os

    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., s, hd/2)
    dt = x.dtype if os.environ.get("REPRO_BF16_ELEMWISE") == "1" else jnp.float32
    cos = jnp.cos(ang)[..., None, :].astype(dt)
    sin = jnp.sin(ang)[..., None, :].astype(dt)
    x1, x2 = jnp.split(x.astype(dt), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activation sharding constraints (rules are ambient, set by the launcher)
# ---------------------------------------------------------------------------

_TLS = threading.local()


@contextlib.contextmanager
def activation_rules(rules: dict | None):
    """Context manager installing logical->mesh rules for ``shard``."""
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield
    finally:
        _TLS.rules = prev


def current_rules() -> dict | None:
    """The ambient activation rules (installed by the launcher's step fn),
    or None outside any :func:`activation_rules` scope.  The mesh rides
    along under the ``"__mesh__"`` key — the supported way for model code
    (e.g. the shard_map MoE) to reach the active mesh."""
    return getattr(_TLS, "rules", None)


def shard(x, *axes):
    """with_sharding_constraint by logical axis names; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    mesh = rules.get("__mesh__")
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec

    spec, used = [], set()
    for dim, name in zip(x.shape, axes):
        mesh_axes = rules.get(name)
        if mesh_axes is None:
            spec.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        size = 1
        ok = []
        for ax in mesh_axes:
            if ax in used or ax not in mesh.shape:
                continue
            size *= mesh.shape[ax]
            ok.append(ax)
        if ok and size > 1 and dim % size == 0:
            spec.append(tuple(ok) if len(ok) > 1 else ok[0])
            used.update(ok)
        else:
            spec.append(None)
    sharding = NamedSharding(mesh, PartitionSpec(*spec))
    return jax.lax.with_sharding_constraint(x, sharding)
