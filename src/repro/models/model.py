"""Full language model: embed → (scanned periods of heterogeneous blocks) → head.

Layer-stack layout
------------------
``cfg.layer_pattern`` (+ MoE interleave) defines a *period* of heterogeneous
blocks (e.g. Jamba: 1×attn + 7×mamba, MoE every 2nd layer).  Layers are
initialized per period and stacked along a leading 'layers' axis, then the
forward is one ``lax.scan`` over periods with the period body unrolled —
heterogeneous architectures keep O(period) HLO size instead of O(num_layers).

Modes
-----
  * ``forward_train(params, batch)``  -> (loss, metrics); chunked vocab loss
  * ``forward_prefill(params, tokens, cache)`` -> (last-token logits, cache)
  * ``forward_decode(params, token, cache, pos)`` -> (logits, cache)

Every linear is a quantized linear (cfg.quant) — LoRDS PEFT/QAT/frozen or any
baseline.  VLM/audio archs (`input_kind='embeddings'`) take pre-computed
frontend embeddings (the frontend itself is stubbed per assignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.common import (
    P,
    dense_init,
    f32_einsum,
    rmsnorm,
    rmsnorm_init,
    shard,
    stack_periods,
)

__all__ = [
    "model_init", "cache_init", "forward_train", "forward_prefill",
    "forward_decode",
    "paged_cache_init", "forward_decode_paged", "forward_prefill_chunk",
]


# ---------------------------------------------------------------------------
# per-block init/apply
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": lambda key, cfg, quant: (
        attn.mla_init(key, cfg, quant) if cfg.attn_kind == "mla"
        else attn.gqa_init(key, cfg, quant)),
    "mamba": ssm.mamba_init,
    "mlstm": ssm.mlstm_init,
    "slstm": ssm.slstm_init,
}


def _block_init(key, cfg, mixer_kind, mlp_kind):
    k1, k2 = jax.random.split(key)
    blk = {
        "ln1": rmsnorm_init(cfg.d_model),
        "mixer": _MIXER_INIT[mixer_kind](k1, cfg, cfg.quant),
    }
    if mlp_kind == "dense":
        blk["ln2"] = rmsnorm_init(cfg.d_model)
        blk["mlp"] = moe_mod.dense_mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.quant)
    elif mlp_kind == "moe":
        blk["ln2"] = rmsnorm_init(cfg.d_model)
        blk["mlp"] = moe_mod.moe_init(k2, cfg, cfg.quant)
    return blk


def _mixer_train(blk, h, cfg, mixer_kind, positions):
    q = cfg.quant
    if mixer_kind == "attn":
        if cfg.attn_kind == "mla":
            return attn.mla_train(blk, h, cfg, q, positions)
        return attn.gqa_train(blk, h, cfg, q, positions)
    if mixer_kind == "mamba":
        return ssm.mamba_train(blk, h, cfg, q)
    if mixer_kind == "mlstm":
        return ssm.mlstm_train(blk, h, cfg, q)
    return ssm.slstm_train(blk, h, cfg, q)


def _block_train(blk, x, cfg, kind, positions):
    mixer_kind, mlp_kind = kind
    h = rmsnorm(blk["ln1"], x, cfg.norm_eps)
    x = x + _mixer_train(blk["mixer"], h, cfg, mixer_kind, positions)
    aux = jnp.zeros((), jnp.float32)
    if mlp_kind == "dense":
        h = rmsnorm(blk["ln2"], x, cfg.norm_eps)
        x = x + moe_mod.dense_mlp_apply(blk["mlp"], h, cfg.d_model, cfg.d_ff,
                                        cfg.quant)
    elif mlp_kind == "moe":
        h = rmsnorm(blk["ln2"], x, cfg.norm_eps)
        y, aux = moe_mod.moe_apply(blk["mlp"], h, cfg, cfg.quant)
        x = x + y
    x = shard(x, "batch", "seq", None)
    return x, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

_MIXER_CACHE = {
    "mamba": lambda cfg, b, cap: ssm.mamba_cache_init(cfg, b),
    "mlstm": lambda cfg, b, cap: ssm.mlstm_cache_init(cfg, b),
    "slstm": lambda cfg, b, cap: ssm.slstm_cache_init(cfg, b),
}


def _block_cache(cfg, mixer_kind, batch, capacity):
    if mixer_kind == "attn":
        if cfg.attn_kind == "mla":
            return attn.mla_cache_init(cfg, batch, capacity)
        return attn.gqa_cache_init(cfg, batch, capacity)
    return _MIXER_CACHE[mixer_kind](cfg, batch, capacity)


def cache_init(cfg, batch, capacity):
    """Stacked (num_periods-leading) P-tree of per-layer decode caches."""
    period_caches = []
    kinds = cfg.layer_kinds()
    for _ in range(cfg.num_periods):
        period_caches.append({
            f"blk{i}": _block_cache(cfg, kinds[i][0], batch, capacity)
            for i in range(cfg.period)
        })
    return stack_periods(period_caches)


def paged_cache_init(cfg, total_pages, page_size):
    """Stacked per-layer page pools (the paged analogue of `cache_init`).

    Paged serving needs every mixer to be a page-table reader, so it is
    attention-only: recurrent mixers (mamba/xlstm) keep O(1) state that
    the fixed-capacity path already serves without a cache window."""
    kinds = cfg.layer_kinds()
    if any(k[0] != "attn" for k in kinds):
        raise ValueError(
            "paged serving requires an attention-only layer stack; "
            f"got mixers {sorted({k[0] for k in kinds})}")
    pool_init = (attn.mla_paged_cache_init if cfg.attn_kind == "mla"
                 else attn.gqa_paged_cache_init)
    periods = []
    for _ in range(cfg.num_periods):
        periods.append({
            f"blk{i}": pool_init(cfg, total_pages, page_size)
            for i in range(cfg.period)
        })
    return stack_periods(periods)


def _mlp_residual(blk, x, cfg, mlp_kind):
    """Shared post-mixer MLP residual (inference paths discard moe aux)."""
    q = cfg.quant
    if mlp_kind == "dense":
        h = rmsnorm(blk["ln2"], x, cfg.norm_eps)
        x = x + moe_mod.dense_mlp_apply(blk["mlp"], h, cfg.d_model, cfg.d_ff,
                                        q)
    elif mlp_kind == "moe":
        h = rmsnorm(blk["ln2"], x, cfg.norm_eps)
        y, _ = moe_mod.moe_apply(blk["mlp"], h, cfg, q)
        x = x + y
    return x


def _block_decode(blk, x, cfg, kind, cache, pos):
    mixer_kind, mlp_kind = kind
    q = cfg.quant
    h = rmsnorm(blk["ln1"], x, cfg.norm_eps)
    if mixer_kind == "attn":
        if cfg.attn_kind == "mla":
            y, cache = attn.mla_decode(blk["mixer"], h, cfg, q, cache, pos)
        else:
            y, cache = attn.gqa_decode(blk["mixer"], h, cfg, q, cache, pos)
    elif mixer_kind == "mamba":
        y, cache = ssm.mamba_decode(blk["mixer"], h, cfg, q, cache, pos)
    elif mixer_kind == "mlstm":
        y, cache = ssm.mlstm_decode(blk["mixer"], h, cfg, q, cache, pos)
    else:
        y, cache = ssm.slstm_decode(blk["mixer"], h, cfg, q, cache, pos)
    x = x + y
    return _mlp_residual(blk, x, cfg, mlp_kind), cache


def _block_prefill(blk, x, cfg, kind, cache, positions):
    mixer_kind, mlp_kind = kind
    q = cfg.quant
    h = rmsnorm(blk["ln1"], x, cfg.norm_eps)
    if mixer_kind == "attn":
        if cfg.attn_kind == "mla":
            y, cache = attn.mla_prefill(blk["mixer"], h, cfg, q, positions, cache)
        else:
            y, cache = attn.gqa_prefill(blk["mixer"], h, cfg, q, positions, cache)
    else:
        # recurrent mixers: run the train path, then rebuild the final state
        # by a single decode step is wasteful; instead run train path and keep
        # zero states (prefill for SSM archs is exercised via train path).
        y = _mixer_train(blk["mixer"], h, cfg, mixer_kind, positions)
    x = x + y
    return _mlp_residual(blk, x, cfg, mlp_kind), cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def model_init(key, cfg):
    keys = jax.random.split(key, cfg.num_periods + 3)
    kinds = cfg.layer_kinds()
    periods = []
    for p in range(cfg.num_periods):
        pkeys = jax.random.split(keys[p], cfg.period)
        periods.append({
            f"blk{i}": _block_init(pkeys[i], cfg, *kinds[i])
            for i in range(cfg.period)
        })
    params = {"layers": stack_periods(periods),
              "final_norm": rmsnorm_init(cfg.d_model)}
    if cfg.input_kind == "tokens":
        params["embed"] = dense_init(
            keys[-1], (cfg.padded_vocab, cfg.d_model),
            ("embed_vocab", "embed"), dtype=jnp.bfloat16, scale=0.02)
    if not cfg.tie_embeddings or cfg.input_kind != "tokens":
        params["head"] = dense_init(
            keys[-2], (cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
            dtype=jnp.bfloat16, scale=0.02)
    return params


def _embed_in(params, cfg, batch):
    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["embeds"].astype(jnp.bfloat16)
    return shard(x, "batch", "seq", None)


def _head_matrix(params, cfg):
    return params["head"] if "head" in params else params["embed"]


def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def _index_period(tree, i):
    return jax.tree.map(lambda v: v[i], tree)


def _scan_train(params, cfg, x, positions):
    kinds = cfg.layer_kinds()

    def period_body(carry, layer_params):
        x, aux = carry
        for i in range(cfg.period):
            x, a = _block_train(layer_params[f"blk{i}"], x, cfg, kinds[i],
                                positions)
            aux = aux + a
        return (x, aux), None

    body = period_body
    if cfg.remat:
        body = jax.checkpoint(period_body, policy=_remat_policy(cfg))
    carry0 = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, carry0, params["layers"])
    else:  # unrolled (cost-analysis probes; XLA counts loop bodies once)
        carry = carry0
        for p in range(cfg.num_periods):
            carry, _ = body(carry, _index_period(params["layers"], p))
        x, aux = carry
    return x, aux


def forward_train(params, cfg, batch):
    """batch: tokens/embeds (b,s[,d]) + labels (b,s) (-1 = masked).

    Returns (loss, metrics dict).
    """
    labels = batch["labels"]
    b, s = labels.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed_in(params, cfg, batch)
    x, aux = _scan_train(params, cfg, x, positions)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)

    head = _head_matrix(params, cfg)  # (Vp, d)
    vocab = cfg.padded_vocab

    # chunked vocab loss: never materialize (b, s, V) f32 logits at once
    chunk = min(512, s)
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, cfg.d_model), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def chunk_loss(carry, inp):
        xi, li = inp  # (b, chunk, d), (b, chunk)
        logits = f32_einsum("bcd,vd->bcv", xi.astype(head.dtype), head)
        logits = shard(logits, "batch", "seq", "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        nll = (logz - gold) * mask
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    if cfg.remat:  # recompute per-chunk logits in backward: peak loss memory
        chunk_loss = jax.checkpoint(chunk_loss)  # is one vocab chunk
    (tot, cnt), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc))
    loss = tot / jnp.maximum(cnt, 1.0)
    if cfg.moe is not None:
        loss = loss + 0.01 * aux
    return loss, {"loss": loss, "aux_loss": aux, "tokens": cnt}


def forward_prefill(params, cfg, batch, cache, positions=None):
    """Full-sequence forward filling caches; returns (last logits, cache).

    ``positions`` (b, s) int32 makes the window ragged: -1 rows are dead
    padding (masked out of attention), and the returned logits come from
    each row's *last live* token instead of column s-1 — so a batch of
    mixed-length prompts prefills in one fixed-shape call without the
    padding leaking into the numerics.  None = the aligned arange (every
    row fully live, logits from the last column, as before)."""
    if cfg.input_kind == "tokens":
        b, s = batch["tokens"].shape
    else:
        b, s, _ = batch["embeds"].shape
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = _embed_in(params, cfg, batch)
    kinds = cfg.layer_kinds()

    def period_body(x, inp):
        layer_params, layer_cache = inp
        new_cache = {}
        for i in range(cfg.period):
            x, new_cache[f"blk{i}"] = _block_prefill(
                layer_params[f"blk{i}"], x, cfg, kinds[i],
                layer_cache[f"blk{i}"], positions)
        return x, new_cache

    body = period_body
    if cfg.remat:
        body = jax.checkpoint(period_body, policy=_remat_policy(cfg))
    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        outs = []
        for p in range(cfg.num_periods):
            x, nc = body(x, (_index_period(params["layers"], p),
                             _index_period(cache, p)))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outs)
    last = jnp.argmax(positions, axis=1)                   # (b,) last live
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)  # (b, 1, d)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = _head_matrix(params, cfg)
    logits = f32_einsum("btd,vd->btv", x.astype(head.dtype), head)
    return logits, new_cache


def forward_decode(params, cfg, batch, cache, pos):
    """One decode step.  batch: token (b,) or embed (b,1,d); pos (b,) int32."""
    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"][:, None], axis=0)
    else:
        x = batch["embeds"].astype(jnp.bfloat16)
    kinds = cfg.layer_kinds()

    def period_body(x, inp):
        layer_params, layer_cache = inp
        new_cache = {}
        for i in range(cfg.period):
            x, new_cache[f"blk{i}"] = _block_decode(
                layer_params[f"blk{i}"], x, cfg, kinds[i],
                layer_cache[f"blk{i}"], pos)
        return x, new_cache

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(period_body, x, (params["layers"], cache))
    else:
        outs = []
        for p in range(cfg.num_periods):
            x, nc = period_body(x, (_index_period(params["layers"], p),
                                    _index_period(cache, p)))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = _head_matrix(params, cfg)
    logits = f32_einsum("btd,vd->btv", x.astype(head.dtype), head)
    return logits, new_cache


def forward_decode_paged(params, cfg, batch, pools, pt, pos):
    """One decode step against the page pools.  batch: token (b,) or embed
    (b,1,d); pt (b, np) page table; pos (b,) int32 current positions."""
    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"][:, None], axis=0)
    else:
        x = batch["embeds"].astype(jnp.bfloat16)
    dec = (attn.mla_decode_paged if cfg.attn_kind == "mla"
           else attn.gqa_decode_paged)
    q = cfg.quant
    kinds = cfg.layer_kinds()

    def period_body(x, inp):
        layer_params, layer_pools = inp
        new_pools = {}
        for i in range(cfg.period):
            blk = layer_params[f"blk{i}"]
            h = rmsnorm(blk["ln1"], x, cfg.norm_eps)
            y, new_pools[f"blk{i}"] = dec(blk["mixer"], h, cfg, q,
                                          layer_pools[f"blk{i}"], pt, pos)
            x = x + y
            x = _mlp_residual(blk, x, cfg, kinds[i][1])
        return x, new_pools

    if cfg.scan_layers:
        x, new_pools = jax.lax.scan(period_body, x, (params["layers"],
                                                     pools))
    else:
        outs = []
        for p in range(cfg.num_periods):
            x, np_ = period_body(x, (_index_period(params["layers"], p),
                                     _index_period(pools, p)))
            outs.append(np_)
        new_pools = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = _head_matrix(params, cfg)
    logits = f32_einsum("btd,vd->btv", x.astype(head.dtype), head)
    return logits, new_pools


def forward_prefill_chunk(params, cfg, batch, pools, pt, qpos, pos0):
    """One chunk of paged prefill.  batch: tokens (b, cs); qpos (b, cs)
    in-chunk positions (-1 = dead row); pos0 (b,) page-aligned chunk start.

    Returns (last-live-row logits (b, 1, V), new pools).  The logits are
    each row's argmax(qpos) column — only meaningful for slots whose final
    prompt token is in this chunk (the scheduler samples token 1 from them
    then, and ignores them for slots still mid-prompt)."""
    if cfg.input_kind == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["embeds"].astype(jnp.bfloat16)
    x = shard(x, "batch", "seq", None)
    pre = (attn.mla_prefill_chunk if cfg.attn_kind == "mla"
           else attn.gqa_prefill_chunk)
    q = cfg.quant
    kinds = cfg.layer_kinds()

    def period_body(x, inp):
        layer_params, layer_pools = inp
        new_pools = {}
        for i in range(cfg.period):
            blk = layer_params[f"blk{i}"]
            h = rmsnorm(blk["ln1"], x, cfg.norm_eps)
            y, new_pools[f"blk{i}"] = pre(blk["mixer"], h, cfg, q, qpos,
                                          pos0, layer_pools[f"blk{i}"], pt)
            x = x + y
            x = _mlp_residual(blk, x, cfg, kinds[i][1])
        return x, new_pools

    if cfg.scan_layers:
        x, new_pools = jax.lax.scan(period_body, x, (params["layers"],
                                                     pools))
    else:
        outs = []
        for p in range(cfg.num_periods):
            x, np_ = period_body(x, (_index_period(params["layers"], p),
                                     _index_period(pools, p)))
            outs.append(np_)
        new_pools = jax.tree.map(lambda *ls: jnp.stack(ls, 0), *outs)
    last = jnp.argmax(qpos, axis=1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = _head_matrix(params, cfg)
    logits = f32_einsum("btd,vd->btv", x.astype(head.dtype), head)
    return logits, new_pools