"""Deterministic, restartable, shardable token pipeline.

Two sources behind one iterator interface:
  * ``SyntheticLM`` — seeded Zipf-ish token stream; batch content is a pure
    function of (seed, step, shard), so restart-after-preemption reproduces
    the exact stream with no cursor state beyond the step counter.
  * ``BinTokenFile`` — memory-mapped uint16/uint32 token file (the offline
    equivalent of a tokenized corpus shard), strided by (step, shard).

Sharding: each host/process takes ``shard_id`` of ``num_shards``; the global
batch is the concatenation over shards, matching a batch-sharded pjit input.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM", "BinTokenFile", "make_batch_iterator"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_per_shard: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    zipf_a: float = 1.2

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_id]))
        # Zipf-like marginal over the vocab; sequences get local structure by
        # mixing a shifted copy (so models have something learnable).
        z = rng.zipf(self.zipf_a, size=(self.batch_per_shard, self.seq_len + 1))
        toks = (z - 1) % self.vocab_size
        flip = rng.random((self.batch_per_shard, self.seq_len + 1)) < 0.35
        shifted = np.roll(toks, 1, axis=1)
        toks = np.where(flip, shifted, toks).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


@dataclasses.dataclass
class BinTokenFile:
    path: str
    vocab_size: int
    seq_len: int
    batch_per_shard: int
    shard_id: int = 0
    num_shards: int = 1
    dtype: str = "uint16"

    def __post_init__(self):
        self._mm = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_seq = (len(self._mm) - 1) // self.seq_len

    def batch_at(self, step: int) -> dict:
        idx0 = (step * self.num_shards + self.shard_id) * self.batch_per_shard
        rows = []
        for i in range(self.batch_per_shard):
            s = ((idx0 + i) % self._n_seq) * self.seq_len
            rows.append(np.asarray(self._mm[s : s + self.seq_len + 1]))
        arr = np.stack(rows).astype(np.int32) % self.vocab_size
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:].copy()}


def make_batch_iterator(source, start_step: int = 0):
    """Iterator of (step, batch); resumes exactly from ``start_step``."""
    step = start_step
    while True:
        yield step, source.batch_at(step)
        step += 1
