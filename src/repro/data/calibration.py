"""Calibration activations for PTQ (GPTQ / AWQ / LoRDS refinement eval).

Real deployments feed a few hundred sequences through the fp model and tap
per-layer inputs; offline we synthesize activations with the statistics that
matter for the algorithms under test:

  * heavy-tailed per-channel magnitudes (LLM activations have stable outlier
    channels — the phenomenon AWQ exploits),
  * token-correlated rows (GPTQ's Hessian needs realistic covariance).
"""
from __future__ import annotations

import numpy as np

__all__ = ["synthetic_activations"]


def synthetic_activations(
    n_tokens: int,
    dim: int,
    seed: int = 0,
    outlier_frac: float = 0.02,
    outlier_gain: float = 20.0,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((n_tokens, dim)).astype(np.float32)
    # low-rank token correlation
    r = max(4, dim // 64)
    mix = rng.standard_normal((r, dim)).astype(np.float32) / np.sqrt(r)
    coef = rng.standard_normal((n_tokens, r)).astype(np.float32)
    x = 0.7 * base + 0.7 * coef @ mix
    # persistent outlier channels
    n_out = max(1, int(dim * outlier_frac))
    idx = rng.choice(dim, n_out, replace=False)
    x[:, idx] *= outlier_gain
    return x
