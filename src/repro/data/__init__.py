"""repro.data — restartable token pipeline + PTQ calibration."""
from repro.data.calibration import synthetic_activations  # noqa: F401
from repro.data.pipeline import (  # noqa: F401
    BinTokenFile,
    SyntheticLM,
    make_batch_iterator,
)
