"""Fused flash-style causal prefill attention Pallas kernel.

Computes  O = softmax(mask(Q·Kᵀ · scale)) · V  per (batch, head) without
ever materializing the (s, S) score matrix: the KV sequence is streamed in
``bkv``-sized tiles with the classic online-softmax recurrence (running max
``m``, running exp-sum ``l``, unnormalized accumulator ``acc`` — flash-2
style: the 1/l normalization happens once, on the last KV tile).  This is
the prefill analogue of the lords_matmul family — the portable einsum path
in :func:`repro.models.attention.chunked_causal_attention` stays as the
ref oracle, but peaks at a (b, nh, chunk, S) f32 temporary the kernel
never creates.

Layout / tiling — all operands are indexed in the model's native
(batch, seq, heads, head_dim) layout (no host-side transpose copies):
  grid = (b, nh, s/bq, S/bkv), KV innermost (the online-softmax reduction)
    q tile    (1, bq, 1, hd)    — constant over the KV axis (VMEM-resident
                                  per Q tile)
    k/v tile  (1, bkv, 1, hd)   — head-indexed ``h // group`` so GQA heads
                                  read their shared KV head straight from
                                  the unexpanded (b, S, nkv, hd) arrays:
                                  the head-group broadcast costs zero HBM
                                  traffic (the portable path jnp.repeats
                                  K/V to the full head count first)
    qpos tile (1, bq, 1) int32  — per-token positions, so ragged /
    kpos tile (1, 1, bkv) int32   shifted sequences mask correctly; -1
                                  marks dead (padding) rows
    m/l scratch (bq, 128) f32   — lane-replicated running max / exp-sum
    acc scratch (bq, hd)  f32   — unnormalized output accumulator

Masking uses the finite ``ATTN_NEG_INF`` (-1e30), and the per-tile p is
zeroed through the liveness mask itself: a fully-masked tile contributes
exactly nothing (no exp(0) junk to correct), fully-dead padding rows keep
l = 0 and are zeroed by the final where(l == 0) guard, and no -inf - -inf
NaNs can arise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import ATTN_NEG_INF

__all__ = ["attn_prefill_pallas"]

_STAT_LANES = 128  # lane width of the m/l scratch tiles


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, nk):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, ATTN_NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32) * scale           # (bq, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                   # (bkv, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                        # (bq, bkv)
    qpos = qpos_ref[0]                                       # (bq, 1)
    kpos = kpos_ref[0]                                       # (1, bkv)
    live = (kpos <= qpos) & (kpos >= 0)                      # (bq, bkv)
    s = jnp.where(live, s, ATTN_NEG_INF)

    m_prev = m_ref[:, :1]                                    # (bq, 1)
    l_prev = l_ref[:, :1]
    m_curr = jnp.max(s, axis=1, keepdims=True)               # (bq, 1)
    m_next = jnp.maximum(m_prev, m_curr)
    alpha = jnp.exp(m_prev - m_next)                         # (bq, 1)
    # liveness-zeroed weights: a fully-masked tile (all s == NEG_INF ==
    # m_next) would otherwise yield p = exp(0) = 1 junk, leaving dead
    # rows with l = S instead of 0
    p = jnp.exp(s - m_next) * live.astype(jnp.float32)       # (bq, bkv)
    l_next = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = jnp.broadcast_to(m_next, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_next, l_ref.shape)

    v = v_ref[0, :, 0].astype(jnp.float32)                   # (bkv, hd)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _store():
        l = l_ref[:, :1]
        inv = jnp.where(l == 0.0, 0.0, 1.0 / l)              # dead rows -> 0
        o_ref[0, :, 0] = acc_ref[...] * inv


@functools.partial(
    jax.jit, static_argnames=("logit_scale", "bq", "bkv", "interpret"))
def attn_prefill_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    qpos: jnp.ndarray,
    kpos: jnp.ndarray,
    *,
    logit_scale: float,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q (b, s, nh, hd) · k/v (b, S, nkv, hd) → (b, s, nh, hd_v) f32.

    Operands stay in the model's native layout; the index maps do the
    per-head tiling.  ``qpos`` (b, s) / ``kpos`` (b, S) int32 positions
    drive the causal mask (-1 = dead row, output zeroed).  s/S must divide
    bq/bkv — the dispatch layer pads and sets padded positions to -1.
    """
    b, s, nh, hd = q.shape
    cap, nkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    group = nh // nkv
    bq = min(bq, s)
    bkv = min(bkv, cap)
    if s % bq or cap % bkv:
        raise ValueError(
            f"seq lengths (s={s}, S={cap}) not divisible by tiles "
            f"({bq},{bkv})")
    nk = cap // bkv
    grid = (b, nh, s // bq, nk)

    kern = functools.partial(_kernel, scale=float(logit_scale), nk=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            # GQA broadcast in the index map: head hi reads KV head hi//g
            pl.BlockSpec((1, bkv, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
            pl.BlockSpec((1, bkv, 1, hdv),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
            pl.BlockSpec((1, bq, 1), lambda bi, hi, qi, ki: (bi, qi, 0)),
            pl.BlockSpec((1, 1, bkv), lambda bi, hi, qi, ki: (bi, 0, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hdv),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, nh, hdv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, _STAT_LANES), jnp.float32),
            pltpu.VMEM((bq, _STAT_LANES), jnp.float32),
            pltpu.VMEM((bq, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, qpos.reshape(b, s, 1), kpos.reshape(b, 1, cap))
