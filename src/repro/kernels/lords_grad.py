"""Fused LoRDS gradient-reduction Pallas kernels (training backward).

Given upstream gradient ``g[M, N]`` and activations ``x[M, K]``, the LoRDS
parameter gradients all factor through the weight-space cotangent

    ∂L/∂Ŵ = gᵀ·x                                    (N, K)

which the dense backward used to materialize in f32 alongside a second
dequantized Ŵ.  These kernels instead accumulate ∂L/∂Ŵ *tile by tile* in a
VMEM scratch (never HBM) and collapse it straight into the small outputs:

  frozen / peft (multiplicative PEFT, paper §3.4):
      ∂S = ∂L/∂Ŵ ⊙ lut[Q] ⊙ 1[|S| ≥ eps]            clamp mask in-kernel
      dB = ∂S·Aᵀ   (N, r)      dA = Bᵀ·∂S   (r, K)

  qat (STE, paper Eq. 4/5):
      dW = ∂L/∂Ŵ                                     Eq. 4 (identity)
      ∂S = ∂L/∂Ŵ ⊙ (lut[Q] − W ⊘ S) ⊙ 1[|S| ≥ eps]  Eq. 5
      dB / dA as above

Tiling:  grid = (N/bn, K/bk, M/bm), M innermost (the ∂L/∂Ŵ reduction).
Per (j, k) tile the scratch ``acc`` (bn, bk) f32 accumulates gᵀ·x over the
M axis; at the last M step the tile is dequant-masked and contracted on the
MXU into the rank-space outputs.  The q/bT/a (and W for qat) tiles have
M-independent index maps, so Pallas fetches each exactly once per (j, k) —
codes stream from HBM once per call.

Outputs (f32, padded shapes — callers slice):
  dbT     (r, N)             B-gradient, transposed so the rank dim sits in
                             sublanes; resident in VMEM for a whole j row
                             (its index map is constant across k and m)
  da_part (N/bn, r, K)       per-N-tile partial A-gradients — summed over
                             axis 0 by the caller (a (N/bn)·r·K f32 array,
                             ~r/bn of one weight matrix: negligible)
  dW      (N, K) [qat only]  the master-weight gradient itself (a parameter
                             gradient the optimizer owns — not a temporary)

``block_grad_pallas`` is the block-wise analogue: ∂s_blk = per-block sums of
∂L/∂Ŵ ⊙ lut[Q], with the same scratch-accumulation structure (no clamp mask
— block scales are absmax-initialized away from zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lut as lut_mod
from repro.core import quantize as quantize_mod
from repro.core.scaling import clamp_scale
from repro.kernels.lords_matmul import _lut_select, _unpack_tile

__all__ = ["lords_grad_pallas", "block_grad_pallas"]


def _body(x_ref, g_ref, q_ref, bt_ref, a_ref, lut_ref, w_ref, dbt_ref,
          dap_ref, dw_ref, acc_ref, *, ps, n_levels, eps):
    k, m = pl.program_id(1), pl.program_id(2)
    nm = pl.num_programs(2)

    @pl.when(m == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(k == 0, m == 0))
    def _zero_dbt():  # dbT tile is resident across the whole (k, m) sweep
        dbt_ref[...] = jnp.zeros_like(dbt_ref)

    acc_ref[...] += jax.lax.dot_general(
        g_ref[...], x_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # ∂L/∂Ŵ (bn, bk)

    @pl.when(m == nm - 1)
    def _reduce():
        codes = _unpack_tile(q_ref[...], ps)
        vals = _lut_select(codes, lut_ref, n_levels)           # (bn, bk) f32
        s_raw = jax.lax.dot_general(
            bt_ref[...], a_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        mask = (jnp.abs(s_raw) >= eps).astype(jnp.float32)
        dw_hat = acc_ref[...]
        if w_ref is None:                                      # frozen / peft
            ds = dw_hat * vals * mask
        else:                                                  # qat STE
            s = clamp_scale(s_raw, eps)
            resid = vals - w_ref[...].astype(jnp.float32) / s  # Q − W ⊘ S
            ds = dw_hat * resid * mask                         # Eq. 5
            dw_ref[...] = dw_hat                               # Eq. 4
        # rank-space contractions: dBᵀ = A·∂Sᵀ, dA-partial = Bᵀ·∂S
        dbt_ref[...] += jax.lax.dot_general(
            a_ref[...], ds, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # (r, bn)
        dap_ref[...] = jax.lax.dot_general(
            bt_ref[...], ds, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[None]                                                # (1, r, bk)


def _kernel_frozen(x_ref, g_ref, q_ref, bt_ref, a_ref, lut_ref, dbt_ref,
                   dap_ref, acc_ref, *, ps, n_levels, eps):
    _body(x_ref, g_ref, q_ref, bt_ref, a_ref, lut_ref, None, dbt_ref,
          dap_ref, None, acc_ref, ps=ps, n_levels=n_levels, eps=eps)


def _kernel_qat(x_ref, g_ref, q_ref, bt_ref, a_ref, lut_ref, w_ref, dbt_ref,
                dap_ref, dw_ref, acc_ref, *, ps, n_levels, eps):
    _body(x_ref, g_ref, q_ref, bt_ref, a_ref, lut_ref, w_ref, dbt_ref,
          dap_ref, dw_ref, acc_ref, ps=ps, n_levels=n_levels, eps=eps)


@functools.partial(
    jax.jit,
    static_argnames=("codebook_name", "bm", "bn", "bk", "interpret"),
)
def lords_grad_pallas(
    x: jnp.ndarray,
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    *,
    w: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
):
    """See module docstring.  Returns ``(dbT (r,N), da_part (N/bn,r,K))``
    plus ``dW (N,K)`` when the qat master weight ``w`` is given."""
    from repro.core.scaling import SCALE_EPS

    m, kdim = x.shape
    n, r = b.shape
    ps = quantize_mod.pack_spec(codebook_name)
    levels = lut_mod.codebook(codebook_name)
    n_levels = levels.shape[0]

    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, kdim)
    if m % bm or n % bn or kdim % bk or bk % ps.group_codes:
        raise ValueError(
            f"shape ({m},{n},{kdim}) not divisible by blocks ({bm},{bn},{bk})"
        )
    grid = (n // bn, kdim // bk, m // bm)  # M innermost: the ∂L/∂Ŵ reduction

    bt = b.T  # (r, N)
    lut_arr = levels.reshape(1, -1).astype(jnp.float32)
    qat = w is not None
    kern = functools.partial(
        _kernel_qat if qat else _kernel_frozen,
        ps=ps, n_levels=n_levels, eps=SCALE_EPS,
    )
    in_specs = [
        pl.BlockSpec((bm, bk), lambda j, k, m: (m, k)),        # x
        pl.BlockSpec((bm, bn), lambda j, k, m: (m, j)),        # g
        pl.BlockSpec((bn, ps.packed_width(bk)), lambda j, k, m: (j, k)),  # q
        pl.BlockSpec((r, bn), lambda j, k, m: (0, j)),         # bT
        pl.BlockSpec((r, bk), lambda j, k, m: (0, k)),         # a
        pl.BlockSpec((1, n_levels), lambda j, k, m: (0, 0)),   # lut
    ]
    inputs = [x, g, q_packed, bt, a, lut_arr]
    out_specs = [
        pl.BlockSpec((r, bn), lambda j, k, m: (0, j)),         # dbT
        pl.BlockSpec((1, r, bk), lambda j, k, m: (j, 0, k)),   # da_part
    ]
    out_shape = [
        jax.ShapeDtypeStruct((r, n), jnp.float32),
        jax.ShapeDtypeStruct((n // bn, r, kdim), jnp.float32),
    ]
    if qat:
        in_specs.append(pl.BlockSpec((bn, bk), lambda j, k, m: (j, k)))  # w
        inputs.append(w)
        out_specs.append(pl.BlockSpec((bn, bk), lambda j, k, m: (j, k)))
        out_shape.append(jax.ShapeDtypeStruct((n, kdim), jnp.float32))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
        interpret=interpret,
    )(*inputs)


# ---------------------------------------------------------------------------
# Block-wise baseline:  ∂s_blk = per-block sums of (gᵀ·x) ⊙ lut[Q]
# ---------------------------------------------------------------------------


def _block_body(x_ref, g_ref, q_ref, lut_ref, o_ref, acc_ref, *, ps,
                n_levels, group, blocks_per_tile):
    k, m = pl.program_id(1), pl.program_id(2)
    nm = pl.num_programs(2)

    @pl.when(m == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jnp.logical_and(k % group == 0, m == 0))
    def _zero_out():  # out tile is resident for `group` consecutive k steps
        o_ref[...] = jnp.zeros_like(o_ref)

    acc_ref[...] += jax.lax.dot_general(
        g_ref[...], x_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(m == nm - 1)
    def _reduce():
        codes = _unpack_tile(q_ref[...], ps)
        vals = _lut_select(codes, lut_ref, n_levels)
        ds = acc_ref[...] * vals                               # (bn, bk)
        bn, bk = ds.shape
        o_ref[...] += ds.reshape(bn, blocks_per_tile,
                                 bk // blocks_per_tile).sum(-1)


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "codebook_name", "bm", "bn", "bk",
                     "interpret"),
)
def block_grad_pallas(
    x: jnp.ndarray,
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """∂s_blk (N, K/block_size) for the block-wise dequant matmul."""
    m, kdim = x.shape
    n = q_packed.shape[0]
    ps = quantize_mod.pack_spec(codebook_name)
    levels = lut_mod.codebook(codebook_name)
    n_levels = levels.shape[0]

    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    if m % bm or n % bn or kdim % bk or bk % ps.group_codes:
        raise ValueError(
            f"shape ({m},{n},{kdim}) not divisible by blocks ({bm},{bn},{bk})"
        )
    if not (bk % block_size == 0 or block_size % bk == 0):
        raise ValueError(f"bk {bk} incompatible with block_size {block_size}")
    grid = (n // bn, kdim // bk, m // bm)

    if bk >= block_size:
        # each k tile owns bk/block_size whole blocks
        s_cols, group, blocks_per_tile = bk // block_size, 1, bk // block_size
        s_index = lambda j, k, m: (j, k)
    else:
        # one block spans `group` consecutive k tiles: the (bn, 1) output
        # column stays resident and accumulates across them
        group = block_size // bk
        s_cols, blocks_per_tile = 1, 1
        s_index = lambda j, k, m: (j, k // group)

    lut_arr = levels.reshape(1, -1).astype(jnp.float32)
    kern = functools.partial(_block_body, ps=ps, n_levels=n_levels,
                             group=group, blocks_per_tile=blocks_per_tile)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda j, k, m: (m, k)),
            pl.BlockSpec((bm, bn), lambda j, k, m: (m, j)),
            pl.BlockSpec((bn, ps.packed_width(bk)), lambda j, k, m: (j, k)),
            pl.BlockSpec((1, n_levels), lambda j, k, m: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, s_cols), s_index),
        out_shape=jax.ShapeDtypeStruct((n, kdim // block_size), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bk), jnp.float32)],
        interpret=interpret,
    )(x, g, q_packed, lut_arr)
