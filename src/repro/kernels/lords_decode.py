"""Decode-specialized fused LoRDS GEMV kernel (M ≤ 8).

Computes  y[M, N] = x[M, K] @ Ŵᵀ,   Ŵ[N, K] = lut[Q] ⊙ (B·A)

for decode-shaped workloads: a handful of tokens (one per in-flight
sequence, M ≤ 8 = one f32 sublane tile) against the full weight matrix.
This is the regime the paper's §4.4 serving claim lives in — per-token cost
is the time to *stream the weights once*, so the kernel is organized around
that invariant rather than around MXU occupancy like the prefill kernel
(:mod:`repro.kernels.lords_matmul`):

  * weight-stationary grid (N/bn, K/bk) with K innermost: every q (packed
    codes) and bT tile is fetched from HBM exactly once per call — the
    memory-roofline minimum (the prefill kernel re-streams weights once per
    M-tile; with M ≤ 8 there is exactly one M-tile, so nothing is
    re-fetched here either, but this kernel also drops the M grid axis and
    its index arithmetic),
  * the K loop is double-buffered by the Pallas grid pipeline: while tile k
    is in the MXU, the DMAs for the q tiles of k+1 are already in flight
    (two VMEM buffers per streamed operand — Pallas' automatic
    revolving-buffer pipelining over the innermost grid axis),
  * x (≤ 8 × K) and a (r × K) are held VMEM-resident for the whole call
    (constant index map; the kernel slices the live bk columns with
    ``pl.ds``) — a K-streamed BlockSpec for them would re-fetch both once
    per N-tile sweep, quietly adding up to ~(32 + 4r)/bn of the packed-q
    bytes in redundant traffic,
  * the M dimension is padded to the 8-row f32 sublane tile inside the
    wrapper, so callers can pass any M ≤ 8 without host-side padding,
  * optional out-of-kernel residual fusion: ``residual`` is added to the
    sliced result outside the kernel (XLA fuses the add into the epilogue;
    keeping it out of the kernel keeps the accumulator tile pure f32 and
    the kernel shape-agnostic about what the caller chains after it).

Per tile:  S = bTᵀ·a  (rank-r contraction), W = lut[q] ⊙ S, acc += x·Wᵀ —
identical math to the prefill kernel, so the pure-jnp oracle
(:func:`repro.kernels.ref.lords_matmul_ref`) is the parity reference for
both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lut_mod
from repro.core import quantize as quantize_mod
from repro.core.scaling import clamp_scale
from repro.kernels.lords_matmul import _lut_select, _unpack_tile

__all__ = ["lords_decode_pallas", "DECODE_M_MAX"]

DECODE_M_MAX = 8  # one f32 sublane tile: the M-bucket this kernel serves


def _kernel(x_ref, q_ref, bt_ref, a_ref, lut_ref, o_ref, *, ps, n_levels,
            eps, bk):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ks = pl.multiple_of(k * bk, bk)  # live K columns of the resident x/a
    codes = _unpack_tile(q_ref[...], ps)                      # (bn, bk)
    vals = _lut_select(codes, lut_ref, n_levels)              # (bn, bk) f32
    s = jax.lax.dot_general(
        bt_ref[...], a_ref[:, pl.ds(ks, bk)], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # (bn, bk)
    s = clamp_scale(s, eps)
    w = (vals * s).astype(x_ref.dtype)                        # (bn, bk)
    o_ref[...] += jax.lax.dot_general(
        x_ref[:, pl.ds(ks, bk)], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # (8, bn)


@functools.partial(
    jax.jit,
    static_argnames=("codebook_name", "bn", "bk", "interpret"),
)
def lords_decode_pallas(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    *,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
    residual: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """See module docstring.  x (M≤8, K) · dequant(q, b, a)ᵀ (+ residual)."""
    from repro.core.scaling import SCALE_EPS

    m, kdim = x.shape
    n, r = b.shape
    if m > DECODE_M_MAX:
        raise ValueError(
            f"decode kernel serves M <= {DECODE_M_MAX}, got M={m}; "
            "use lords_matmul_pallas for prefill-shaped inputs"
        )
    ps = quantize_mod.pack_spec(codebook_name)
    levels = lut_mod.codebook(codebook_name)
    n_levels = levels.shape[0]

    bn = min(bn, n)
    bk = min(bk, kdim)
    if n % bn or kdim % bk or bk % ps.group_codes:
        raise ValueError(
            f"shape (N={n}, K={kdim}) not divisible by blocks ({bn},{bk})"
        )
    if m < DECODE_M_MAX:  # pad M to the f32 sublane tile; sliced off below
        x = jnp.pad(x, ((0, DECODE_M_MAX - m), (0, 0)))
    grid = (n // bn, kdim // bk)  # K innermost: weights stream exactly once

    bt = b.T  # (r, N)
    lut_arr = levels.reshape(1, -1).astype(jnp.float32)
    kern = functools.partial(
        _kernel, ps=ps, n_levels=n_levels, eps=SCALE_EPS, bk=bk
    )
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # x and a: constant index map = fetched once, VMEM-resident
            pl.BlockSpec((DECODE_M_MAX, kdim), lambda j, k: (0, 0)),
            pl.BlockSpec((bn, ps.packed_width(bk)), lambda j, k: (j, k)),
            pl.BlockSpec((r, bn), lambda j, k: (0, j)),
            pl.BlockSpec((r, kdim), lambda j, k: (0, 0)),
            pl.BlockSpec((1, n_levels), lambda j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((DECODE_M_MAX, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((DECODE_M_MAX, n), jnp.float32),
        interpret=interpret,
    )(x, q_packed, bt, a, lut_arr)
    y = y[:m]
    if residual is not None:
        y = y + residual.astype(y.dtype)
    return y
