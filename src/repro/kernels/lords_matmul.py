"""Fused LoRDS dequant-matmul Pallas TPU kernel.

Computes  y[M, N] = x[M, K] @ Ŵᵀ,   Ŵ[N, K] = lut[Q] ⊙ (B·A)

with Q stored packed (2×4-bit / 4×2-bit codes per uint8, or 8×3-bit codes
per 3 bytes) in HBM.  This is
the TPU analogue of the paper's Triton kernel (§4.4): the low-rank scale
product rides along with each weight tile, so dequantization adds no extra
HBM traffic beyond the packed codes themselves — the entire reason LoRDS
serving matches block-wise NF4 speed while QLoRA pays for an extra adapter
GEMM.

Tiling (all VMEM):
  grid = (M/bm, N/bn, K/bk), K innermost for accumulation
    x tile   (bm, bk)            input activations
    q tile   (bn, packed(bk)) uint8 packed codes (bk·bits/8 bytes)
    bT tile  (r, bn)             scale factor B, transposed so the tiny rank
    a tile   (r, bk)             dim sits in sublanes (lane dim stays 128-al.)
    lut      (1, L) f32          codebook levels
    out tile (bm, bn) f32        accumulated across the K grid axis

Per tile:  S = bTᵀ·a  (r-contraction, r ≤ 32), W = lut[q]⊙S, acc += x·Wᵀ.
The MXU sees two matmuls: the tiny (bn×r)×(r×bk) scale product and the main
(bm×bk)×(bk×bn) GEMM — dequant itself is pure VPU elementwise work.

Weight-stationary layout note: with grid order (i, j, k) the q/bT/a tiles are
re-fetched for every i; for decode (M small → one i) this is optimal
(weights stream exactly once — the memory-roofline minimum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lut_mod
from repro.core import quantize as quantize_mod
from repro.core.scaling import clamp_scale

__all__ = ["lords_matmul_pallas"]


def _unpack_tile(q, ps: quantize_mod.PackSpec):
    """(bn, bkp) uint8 -> (bn, logical(bkp)) int32 codes, little-endian.

    Cross-byte groups (3-bit: 8 codes / 3 bytes) first assemble each group's
    bytes into one int32 word, then shift/mask out the codes — pure VPU
    bit work feeding the one-hot×LUT MXU gather, no dense unpack in HBM.
    """
    if ps.group_codes == 1:
        return q.astype(jnp.int32)
    bn, bkp = q.shape
    word = q.astype(jnp.int32)
    if ps.group_bytes > 1:
        grp = word.reshape(bn, bkp // ps.group_bytes, ps.group_bytes)
        word = grp[:, :, 0]
        for j in range(1, ps.group_bytes):
            word |= grp[:, :, j] << (8 * j)
    mask = (1 << ps.bits) - 1
    parts = [(word >> (ps.bits * i)) & mask for i in range(ps.group_codes)]
    stacked = jnp.stack(parts, axis=-1)  # (bn, groups, group_codes)
    return stacked.reshape(bn, ps.logical_width(bkp))


# One-hot tensors above this LUT width would dwarf the codes tile in VMEM
# (L× the f32 tile) — int8's 256-level table stays on the select chain.
_ONE_HOT_MAX_LEVELS = 32
# Column slab for the one-hot: bounds the live (bn, slab, L) f32 intermediate
# to ~2 MiB at bn=256/L=16 regardless of bk, so default prefill tiles
# (bn 256 × bk 512, which would be an 8 MiB one-hot in one shot) still fit
# VMEM next to the double-buffered operand tiles and the accumulator.
_ONE_HOT_SLAB = 128


def _lut_select(codes, lut_ref, n_levels: int):
    """LUT gather as one-hot × lut matmul: the L-way gather becomes
    (bn, slab, L) · (L,) contractions the MXU executes, instead of the O(L)
    compare-select chain the VPU had to walk per element.  The K dimension
    is processed in lane slabs so the one-hot intermediate stays a bounded
    VMEM transient.  Wide tables (int8: L=256) keep the chain — their
    one-hot would be L× the tile.  No dynamic gather either way
    (Mosaic-friendly)."""
    if n_levels > _ONE_HOT_MAX_LEVELS:
        out = jnp.zeros(codes.shape, jnp.float32)
        for l in range(n_levels):
            out = jnp.where(codes == l, lut_ref[0, l], out)
        return out

    def slab_vals(slab):
        iota = jax.lax.broadcasted_iota(
            jnp.int32, (*slab.shape, n_levels), slab.ndim)
        one_hot = (slab[..., None] == iota).astype(jnp.float32)
        out = jax.lax.dot_general(
            one_hot, lut_ref[...],  # lut (1, L): contract L, drop the 1
            (((slab.ndim,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return out[..., 0]

    kdim = codes.shape[-1]
    if kdim <= _ONE_HOT_SLAB:
        return slab_vals(codes)
    # non-multiple K tiles get a short trailing slab — the bound must hold
    # for every bk the kernels accept, not just the 128-multiple defaults
    slabs = [slab_vals(codes[..., i : i + _ONE_HOT_SLAB])
             for i in range(0, kdim, _ONE_HOT_SLAB)]
    return jnp.concatenate(slabs, axis=-1)


def _kernel(x_ref, q_ref, bt_ref, a_ref, lut_ref, o_ref, *, ps, n_levels,
            eps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_tile(q_ref[...], ps)                      # (bn, bk)
    vals = _lut_select(codes, lut_ref, n_levels)              # (bn, bk) f32
    # low-rank scale tile: S = Bᵀᵀ·A  -> (bn, bk), r-contraction on the MXU
    s = jax.lax.dot_general(
        bt_ref[...], a_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = clamp_scale(s, eps)
    w = (vals * s).astype(x_ref.dtype)                        # (bn, bk)
    acc = jax.lax.dot_general(
        x_ref[...], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # (bm, bn)
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("codebook_name", "bm", "bn", "bk", "interpret"),
)
def lords_matmul_pallas(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """See module docstring.  x (M,K) · dequant(q (N,K/pack), b (N,r), a (r,K))ᵀ."""
    from repro.core.scaling import SCALE_EPS

    m, kdim = x.shape
    n, r = b.shape
    ps = quantize_mod.pack_spec(codebook_name)
    levels = lut_mod.codebook(codebook_name)
    n_levels = levels.shape[0]

    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, kdim)
    if m % bm or n % bn or kdim % bk or bk % ps.group_codes:
        raise ValueError(
            f"shape ({m},{n},{kdim}) not divisible by blocks ({bm},{bn},{bk})"
        )
    grid = (m // bm, n // bn, kdim // bk)

    bt = b.T  # (r, N): keep the tiny rank dim out of the lane dimension
    lut_arr = levels.reshape(1, -1).astype(jnp.float32)

    kern = functools.partial(
        _kernel, ps=ps, n_levels=n_levels, eps=SCALE_EPS
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, ps.packed_width(bk)), lambda i, j, k: (j, k)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((r, bk), lambda i, j, k: (0, k)),
            pl.BlockSpec((1, n_levels), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, q_packed, bt, a, lut_arr)
