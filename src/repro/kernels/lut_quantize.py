"""Pallas TPU kernel for the LoRDS quantization step (Alg. 1, step 2.1).

    codes[i, j] = argmin_{v ∈ L} (S_ij · v − W_ij)²,   S = B·A
                = nearest-level( W_ij / S_ij )          (S² factors out)

emitted *packed* (2×4-bit / 4×2-bit per uint8).  Used inside the PTQ
refinement loop and the QAT fake-quant forward, where it fuses the S = B·A
product, the division, the midpoint compare tree and the nibble packing into
one VMEM pass over W.

Tiling: grid = (N/bn, K/bk); W tile (bn, bk); bT (r, bn); a (r, bk);
midpoints (1, L-1); out tile (bn, bk/pack) uint8.

The nearest-level search is a static compare tree over the L−1 midpoints
(code = Σ_l [ratio > mid_l]) — branch-free, VPU-only, no dynamic gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lut_mod
from repro.core import quantize as quantize_mod
from repro.core.scaling import clamp_scale

__all__ = ["lut_quantize_pallas"]


def _kernel(w_ref, bt_ref, a_ref, mids_ref, o_ref, *, pack, n_mids, eps):
    s = jax.lax.dot_general(
        bt_ref[...], a_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = clamp_scale(s, eps)
    ratio = w_ref[...].astype(jnp.float32) / s
    codes = jnp.zeros(ratio.shape, jnp.int32)
    for l in range(n_mids):
        codes += (ratio > mids_ref[0, l]).astype(jnp.int32)
    if pack == 1:
        o_ref[...] = codes.astype(jnp.uint8)
        return
    bits = 8 // pack
    bn, bk = codes.shape
    grp = codes.reshape(bn, bk // pack, pack)
    packed = jnp.zeros((bn, bk // pack), jnp.int32)
    for i in range(pack):
        packed |= grp[:, :, i] << (bits * i)
    o_ref[...] = packed.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("codebook_name", "bn", "bk", "interpret")
)
def lut_quantize_pallas(
    w: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    *,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    from repro.core.scaling import SCALE_EPS

    n, kdim = w.shape
    _, r = b.shape
    pack = quantize_mod.codes_per_byte(codebook_name)
    mids = lut_mod.midpoints(codebook_name).reshape(1, -1).astype(jnp.float32)
    n_mids = mids.shape[1]

    bn = min(bn, n)
    bk = min(bk, kdim)
    if n % bn or kdim % bk or bk % pack:
        raise ValueError(f"({n},{kdim}) not divisible by ({bn},{bk})")
    grid = (n // bn, kdim // bk)

    kern = functools.partial(_kernel, pack=pack, n_mids=n_mids, eps=SCALE_EPS)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, k: (i, k)),
            pl.BlockSpec((r, bn), lambda i, k: (0, i)),
            pl.BlockSpec((r, bk), lambda i, k: (0, k)),
            pl.BlockSpec((1, n_mids), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk // pack), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct(
            (n, kdim // pack), jnp.uint8
        ),
        interpret=interpret,
    )(w, b.T, a, mids)
