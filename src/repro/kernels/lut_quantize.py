"""Pallas TPU kernel for the LoRDS quantization step (Alg. 1, step 2.1).

    codes[i, j] = argmin_{v ∈ L} (S_ij · v − W_ij)²,   S = B·A
                = nearest-level( W_ij / S_ij )          (S² factors out)

emitted *packed* (2×4-bit / 4×2-bit per uint8, 8×3-bit per 3 bytes).  Used
inside the PTQ refinement loop and the QAT fake-quant forward, where it fuses
the S = B·A product, the division, the midpoint compare tree and the bit
packing into one VMEM pass over W.

Tiling: grid = (N/bn, K/bk); W tile (bn, bk); bT (r, bn); a (r, bk);
midpoints (1, L-1); out tile (bn, packed(bk)) uint8.

Non-tile-divisible (n, kdim) are zero-padded up to the tile grid (mirroring
``dispatch.qmatmul``) and the output sliced back; the trailing partial pack
group, if kdim is not a multiple of ``group_codes``, keeps its deterministic
padded codes (callers that slice by logical width never read them).

The nearest-level search is a static compare tree over the L−1 midpoints
(code = Σ_l [ratio > mid_l]) — branch-free, VPU-only, no dynamic gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lut_mod
from repro.core import quantize as quantize_mod
from repro.core.scaling import clamp_scale

__all__ = ["lut_quantize_pallas"]


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _kernel(w_ref, bt_ref, a_ref, mids_ref, o_ref, *, ps, n_mids, eps):
    s = jax.lax.dot_general(
        bt_ref[...], a_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = clamp_scale(s, eps)
    ratio = w_ref[...].astype(jnp.float32) / s
    codes = jnp.zeros(ratio.shape, jnp.int32)
    for l in range(n_mids):
        codes += (ratio > mids_ref[0, l]).astype(jnp.int32)
    if ps.group_codes == 1:
        o_ref[...] = codes.astype(jnp.uint8)
        return
    bn, bk = codes.shape
    grp = codes.reshape(bn, bk // ps.group_codes, ps.group_codes)
    word = jnp.zeros((bn, bk // ps.group_codes), jnp.int32)
    for i in range(ps.group_codes):
        word |= grp[:, :, i] << (ps.bits * i)
    if ps.group_bytes == 1:
        o_ref[...] = word.astype(jnp.uint8)
        return
    parts = [(word >> (8 * j)) & 0xFF for j in range(ps.group_bytes)]
    stacked = jnp.stack(parts, axis=-1)  # (bn, groups, group_bytes)
    o_ref[...] = stacked.reshape(bn, -1).astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("codebook_name", "bn", "bk", "interpret")
)
def lut_quantize_pallas(
    w: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    *,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    from repro.core.scaling import SCALE_EPS

    n, kdim = w.shape
    _, r = b.shape
    ps = quantize_mod.pack_spec(codebook_name)
    mids = lut_mod.midpoints(codebook_name).reshape(1, -1).astype(jnp.float32)
    n_mids = mids.shape[1]

    bn = min(bn, n)
    # bk % group_codes must hold on the (possibly padded) tile so every tile
    # packs whole groups
    bk = _round_up(min(bk, kdim), ps.group_codes)
    np_ = _round_up(n, bn)
    kp = _round_up(kdim, bk)
    if (np_, kp) != (n, kdim):
        w = jnp.pad(w, ((0, np_ - n), (0, kp - kdim)))
        b = jnp.pad(b, ((0, np_ - n), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, kp - kdim)))
    grid = (np_ // bn, kp // bk)

    kern = functools.partial(_kernel, ps=ps, n_mids=n_mids, eps=SCALE_EPS)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, k: (i, k)),
            pl.BlockSpec((r, bn), lambda i, k: (0, i)),
            pl.BlockSpec((r, bk), lambda i, k: (0, k)),
            pl.BlockSpec((1, n_mids), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (bn, ps.packed_width(bk)), lambda i, k: (i, k)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (np_, ps.packed_width(kp)), jnp.uint8
        ),
        interpret=interpret,
    )(w, b.T, a, mids)
    return out[:n, : ps.packed_width(_round_up(kdim, ps.group_codes))]
