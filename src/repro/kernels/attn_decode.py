"""Fused quantized-KV decode attention Pallas kernels (GQA + MLA).

The decode regime mirrors :mod:`repro.kernels.lords_decode`: a handful of
query rows (the g = nh/nkv head-group per KV head for GQA, all nh heads for
MLA) against the full KV cache, so per-token cost is the time to *stream
the cache once*.  Both kernels walk the cache sequence axis innermost with
the flash-2 online-softmax recurrence and read the cache tiles **as
stored**: an int8 cache is DMA'd at int8 width and the per-(token, head)
scales are folded into the score / output dot-products in VMEM —

    score(g, j) = logit_scale · (q · codes_j) · k_scale_j
    out(g)     += (p ⊙ v_scale) · codes_v

— so dequantization adds one VPU multiply per tile instead of a full-cache
bf16 temporary in HBM (the reason the portable einsum path made int8 KV
*slower* than bf16 despite its ~2x bytes/token advantage).  A bf16 cache
runs the same kernels with the scale operands absent.

Layouts — the caches are indexed **in their stored layouts** via the
BlockSpec index maps (a host-side transpose would force XLA to copy the
entire cache every decode step, tripling the traffic the kernels exist to
minimize):
  GQA:  q (b, nkv, g8, hd) · k/v (b, S, nkv, hd) [+ scales (b, S, nkv)],
        grid (b, nkv, S/bs) — one head-group per grid cell, q VMEM-resident,
        KV tiles (1, bs, 1, hd) sliced straight from the cache arrays
  MLA:  q_lat (b, nh8, L) / q_rope (b, nh8, R) against the absorbed cache
        c (b, S, L) [+ c_scale (b, S)] and k_rope (b, S, R),
        grid (b, S/bs) — output *is* the weighted latent (b, nh8, L)

``kmask`` (b, S) f32 is the additive liveness mask (0 live / -1e30 dead):
positions beyond each sequence's ``pos`` and cache padding never
contribute, with the same finite-NEG_INF / alpha-correction NaN hygiene as
:mod:`repro.kernels.attn_prefill`.

Paged variants — the continuous-batching engine stores KV in a global pool
of fixed-size pages (page == kv tile) with a per-sequence page table
``pt`` (b, np) int32.  ``pt`` rides in as a *scalar-prefetch* operand
(:class:`pltpu.PrefetchScalarGridSpec`), so the BlockSpec index maps
dereference it directly —

    k_pool tile for (seq b, logical page pi) = k_pool[pt[b, pi]]

— and the pool tiles DMA straight from their stored (possibly int8)
layout, exactly like the contiguous kernels: no gather into a contiguous
per-sequence temp, no out-of-kernel dequant.  The kernel bodies are the
*same functions* as the contiguous path; only the index maps change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import ATTN_NEG_INF

__all__ = ["attn_decode_gqa_pallas", "attn_decode_mla_pallas",
           "attn_decode_gqa_paged_pallas", "attn_decode_mla_paged_pallas",
           "DECODE_ROWS"]

DECODE_ROWS = 8     # sublane multiple query rows are padded to
_STAT_LANES = 128


def _online_update(s, v, m_ref, l_ref, acc_ref):
    """Shared flash-2 step: fold the (rows, bs) score tile ``s`` and value
    tile ``v`` into the running (m, l, acc) statistics."""
    m_prev = m_ref[:, :1]
    l_prev = l_ref[:, :1]
    m_curr = jnp.max(s, axis=1, keepdims=True)
    m_next = jnp.maximum(m_prev, m_curr)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.exp(s - m_next)
    l_next = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = jnp.broadcast_to(m_next, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_next, l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return p


def _gqa_kernel(q_ref, k_ref, v_ref, mask_ref, *rest, scale, nk, quantized):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, ATTN_NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale              # (g8, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)                   # (bs, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                        # (g8, bs)
    if quantized:
        s = s * ks_ref[0].reshape(1, -1)                     # (bs, 1) scales
    s = s + mask_ref[...]                                    # (1, bs) additive
    v = v_ref[0, :, 0].astype(jnp.float32)                   # (bs, hdv)
    if quantized:
        v = v * vs_ref[0]                                    # (bs, 1)
    _online_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(ki == nk - 1)
    def _store():
        l = l_ref[:, :1]
        inv = jnp.where(l == 0.0, 1.0, 1.0 / l)
        o_ref[0, 0] = acc_ref[...] * inv


@functools.partial(
    jax.jit, static_argnames=("logit_scale", "bs", "interpret"))
def attn_decode_gqa_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    kmask: jnp.ndarray,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    *,
    logit_scale: float,
    bs: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q (b, nkv, g8, hd) vs cache k/v (b, S, nkv, hd) → (b, nkv, g8, hd_v).

    ``kmask`` (b, S) f32 additive liveness; ``k_scale``/``v_scale``
    (b, S, nkv) dequantize int8 caches in-kernel (pass both or neither).
    The cache operands keep the storage layout — the index maps slice
    per-head tiles, so no transposed copy of the cache ever exists.
    g8 must be a multiple of 8 and S of ``bs`` — the dispatch layer pads.
    """
    b, nkv, g8, hd = q.shape
    cap = k.shape[1]
    hdv = v.shape[-1]
    quantized = k_scale is not None
    if quantized != (v_scale is not None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    bs = min(bs, cap)
    if cap % bs or g8 % DECODE_ROWS:
        raise ValueError(
            f"cache length {cap} % tile {bs} or rows {g8} % {DECODE_ROWS}")
    nk = cap // bs
    grid = (b, nkv, nk)

    in_specs = [
        pl.BlockSpec((1, 1, g8, hd), lambda bi, hi, ki: (bi, hi, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), lambda bi, hi, ki: (bi, ki, hi, 0)),
        pl.BlockSpec((1, bs, 1, hdv), lambda bi, hi, ki: (bi, ki, hi, 0)),
        pl.BlockSpec((1, bs), lambda bi, hi, ki: (bi, ki)),
    ]
    args = [q, k, v, kmask]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bs, 1), lambda bi, hi, ki: (bi, ki, hi)),
            pl.BlockSpec((1, bs, 1), lambda bi, hi, ki: (bi, ki, hi)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    kern = functools.partial(
        _gqa_kernel, scale=float(logit_scale), nk=nk, quantized=quantized)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g8, hdv),
                               lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nkv, g8, hdv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((g8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((g8, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


def _mla_kernel(ql_ref, qr_ref, c_ref, kr_ref, mask_ref, *rest, scale, nk,
                quantized):
    if quantized:
        cs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, ATTN_NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ql = ql_ref[0].astype(jnp.float32)                       # (nh8, L)
    qr = qr_ref[0].astype(jnp.float32)                       # (nh8, R)
    c = c_ref[0].astype(jnp.float32)                         # (bs, L)
    kr = kr_ref[0].astype(jnp.float32)                       # (bs, R)
    s_lat = jax.lax.dot_general(
        ql, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                        # (nh8, bs)
    if quantized:
        s_lat = s_lat * cs_ref[...]                          # (1, bs) scales
    s = s_lat + jax.lax.dot_general(
        qr, kr, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s * scale + mask_ref[...]
    if quantized:
        c = c * cs_ref[...].reshape(-1, 1)
    _online_update(s, c, m_ref, l_ref, acc_ref)

    @pl.when(ki == nk - 1)
    def _store():
        l = l_ref[:, :1]
        inv = jnp.where(l == 0.0, 1.0, 1.0 / l)
        o_ref[0] = acc_ref[...] * inv


@functools.partial(
    jax.jit, static_argnames=("logit_scale", "bs", "interpret"))
def attn_decode_mla_pallas(
    q_lat: jnp.ndarray,
    q_rope: jnp.ndarray,
    c: jnp.ndarray,
    k_rope: jnp.ndarray,
    kmask: jnp.ndarray,
    c_scale: jnp.ndarray | None = None,
    *,
    logit_scale: float,
    bs: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Absorbed-latent MLA decode: q_lat (b, nh8, L) / q_rope (b, nh8, R)
    vs c (b, S, L) + k_rope (b, S, R) → weighted latent (b, nh8, L) f32.

    ``c_scale`` (b, S) dequantizes an int8 latent cache in-kernel.
    """
    b, nh8, lat = q_lat.shape
    cap = c.shape[1]
    rope = q_rope.shape[-1]
    quantized = c_scale is not None
    bs = min(bs, cap)
    if cap % bs or nh8 % DECODE_ROWS:
        raise ValueError(
            f"cache length {cap} % tile {bs} or rows {nh8} % {DECODE_ROWS}")
    nk = cap // bs
    grid = (b, nk)

    in_specs = [
        pl.BlockSpec((1, nh8, lat), lambda bi, ki: (bi, 0, 0)),
        pl.BlockSpec((1, nh8, rope), lambda bi, ki: (bi, 0, 0)),
        pl.BlockSpec((1, bs, lat), lambda bi, ki: (bi, ki, 0)),
        pl.BlockSpec((1, bs, rope), lambda bi, ki: (bi, ki, 0)),
        pl.BlockSpec((1, bs), lambda bi, ki: (bi, ki)),
    ]
    args = [q_lat, q_rope, c, k_rope, kmask]
    if quantized:
        in_specs.append(pl.BlockSpec((1, bs), lambda bi, ki: (bi, ki)))
        args.append(c_scale.astype(jnp.float32))

    kern = functools.partial(
        _mla_kernel, scale=float(logit_scale), nk=nk, quantized=quantized)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, nh8, lat), lambda bi, ki: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh8, lat), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((nh8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((nh8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((nh8, lat), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Block-paged variants: KV tiles indexed through the page map
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("logit_scale", "interpret"))
def attn_decode_gqa_paged_pallas(
    pt: jnp.ndarray,
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    kmask: jnp.ndarray,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    *,
    logit_scale: float,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged GQA decode: q (b, nkv, g8, hd) vs a page pool.

    ``pt`` (b, np) int32 maps logical page ``pi`` of sequence ``b`` to its
    physical page in ``k_pool``/``v_pool`` (P, ps, nkv, hd) [+ scale pools
    (P, ps, nkv)].  ``kmask`` (b, np*ps) masks the logical window (dead
    beyond ``pos``, so dummy/unallocated pages never contribute).  The kv
    tile size *is* the page size; grid (b, nkv, np) with ``pt`` consulted
    inside the index maps (scalar prefetch) — the pool is read once, as
    stored, with scales folded in-kernel.  Returns (b, nkv, g8, hd_v) f32.
    """
    b, nkv, g8, hd = q.shape
    ps = k_pool.shape[1]
    hdv = v_pool.shape[-1]
    npages = pt.shape[1]
    quantized = k_scale is not None
    if quantized != (v_scale is not None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    if ps % 8 or g8 % DECODE_ROWS:
        raise ValueError(
            f"page size {ps} % 8 or rows {g8} % {DECODE_ROWS}")
    if kmask.shape != (b, npages * ps):
        raise ValueError(
            f"kmask {kmask.shape} != (b, np*ps) = {(b, npages * ps)}")
    grid = (b, nkv, npages)

    in_specs = [
        pl.BlockSpec((1, 1, g8, hd), lambda bi, hi, ki, pt_ref: (bi, hi, 0, 0)),
        pl.BlockSpec((1, ps, 1, hd),
                     lambda bi, hi, ki, pt_ref: (pt_ref[bi, ki], 0, hi, 0)),
        pl.BlockSpec((1, ps, 1, hdv),
                     lambda bi, hi, ki, pt_ref: (pt_ref[bi, ki], 0, hi, 0)),
        pl.BlockSpec((1, ps), lambda bi, hi, ki, pt_ref: (bi, ki)),
    ]
    args = [q, k_pool, v_pool, kmask]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, ps, 1),
                         lambda bi, hi, ki, pt_ref: (pt_ref[bi, ki], 0, hi)),
            pl.BlockSpec((1, ps, 1),
                         lambda bi, hi, ki, pt_ref: (pt_ref[bi, ki], 0, hi)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    body = functools.partial(
        _gqa_kernel, scale=float(logit_scale), nk=npages,
        quantized=quantized)

    def kern(pt_ref, *refs):  # scalar-prefetch operand arrives first
        del pt_ref
        body(*refs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g8, hdv),
                               lambda bi, hi, ki, pt_ref: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((g8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((g8, hdv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g8, hdv), jnp.float32),
        interpret=interpret,
    )(pt, *args)


@functools.partial(jax.jit, static_argnames=("logit_scale", "interpret"))
def attn_decode_mla_paged_pallas(
    pt: jnp.ndarray,
    q_lat: jnp.ndarray,
    q_rope: jnp.ndarray,
    c_pool: jnp.ndarray,
    k_rope_pool: jnp.ndarray,
    kmask: jnp.ndarray,
    c_scale: jnp.ndarray | None = None,
    *,
    logit_scale: float,
    interpret: bool = False,
) -> jnp.ndarray:
    """Paged absorbed-latent MLA decode: q_lat (b, nh8, L) / q_rope
    (b, nh8, R) vs c_pool (P, ps, L) + k_rope_pool (P, ps, R) [+ c_scale
    pool (P, ps)] through ``pt`` (b, np); kmask (b, np*ps).  Same kernel
    body as the contiguous MLA decode; returns the weighted latent
    (b, nh8, L) f32."""
    b, nh8, lat = q_lat.shape
    ps = c_pool.shape[1]
    rope = q_rope.shape[-1]
    npages = pt.shape[1]
    quantized = c_scale is not None
    if ps % 8 or nh8 % DECODE_ROWS:
        raise ValueError(
            f"page size {ps} % 8 or rows {nh8} % {DECODE_ROWS}")
    if kmask.shape != (b, npages * ps):
        raise ValueError(
            f"kmask {kmask.shape} != (b, np*ps) = {(b, npages * ps)}")
    grid = (b, npages)

    in_specs = [
        pl.BlockSpec((1, nh8, lat), lambda bi, ki, pt_ref: (bi, 0, 0)),
        pl.BlockSpec((1, nh8, rope), lambda bi, ki, pt_ref: (bi, 0, 0)),
        pl.BlockSpec((1, ps, lat),
                     lambda bi, ki, pt_ref: (pt_ref[bi, ki], 0, 0)),
        pl.BlockSpec((1, ps, rope),
                     lambda bi, ki, pt_ref: (pt_ref[bi, ki], 0, 0)),
        pl.BlockSpec((1, ps), lambda bi, ki, pt_ref: (bi, ki)),
    ]
    args = [q_lat, q_rope, c_pool, k_rope_pool, kmask]
    if quantized:
        in_specs.append(
            pl.BlockSpec((1, ps), lambda bi, ki, pt_ref: (pt_ref[bi, ki], 0)))
        args.append(c_scale.astype(jnp.float32))

    body = functools.partial(
        _mla_kernel, scale=float(logit_scale), nk=npages,
        quantized=quantized)

    def kern(pt_ref, *refs):
        del pt_ref
        body(*refs)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, nh8, lat),
                               lambda bi, ki, pt_ref: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nh8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((nh8, _STAT_LANES), jnp.float32),
            pltpu.VMEM((nh8, lat), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nh8, lat), jnp.float32),
        interpret=interpret,
    )(pt, *args)
