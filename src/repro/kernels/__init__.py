# Fused dequant-matmul kernels — forward (lords_matmul, lords_decode,
# block_matmul, lut_quantize) and backward (lords_matmul_t: transposed
# dequant-matmul for dx; lords_grad: tiled grad reductions for dB/dA/dW) —
# plus the fused attention family (attn_prefill: streaming-softmax flash
# causal prefill; attn_decode: quantized-KV GQA/MLA flash decode), their
# pure-jnp oracles (ref), thin platform wrappers (ops), and the
# QuantSpec-aware dispatch layer every quantized linear and hot attention
# routes through (dispatch.qmatmul / dispatch.qattention).  Import dispatch
# lazily from repro.core to keep the kernels<->core dependency
# one-directional at import time.
