# Fused dequant-matmul kernels (lords_matmul, block_matmul, lut_quantize),
# their pure-jnp oracles (ref), thin platform wrappers (ops), and the
# QuantSpec-aware dispatch layer every quantized linear routes through
# (dispatch.qmatmul).  Import dispatch lazily from repro.core to keep the
# kernels<->core dependency one-directional at import time.
