# Fused dequant-matmul kernels — forward (lords_matmul, lords_decode,
# block_matmul, lut_quantize) and backward (lords_matmul_t: transposed
# dequant-matmul for dx; lords_grad: tiled grad reductions for dB/dA/dW) —
# their pure-jnp oracles (ref), thin platform wrappers (ops), and the
# QuantSpec-aware dispatch layer every quantized linear routes through
# (dispatch.qmatmul).  Import dispatch lazily from repro.core to keep the
# kernels<->core dependency one-directional at import time.
