"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (tests assert_allclose kernels against
them) *and* the CPU/dry-run execution path: ``ops.py`` dispatches here on
non-TPU platforms, so the multi-pod dry-run lowers these exact graphs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import lut
from repro.core.quantize import unpack_codes
from repro.core.scaling import SCALE_EPS, expand_block_scales

__all__ = ["lords_matmul_ref", "lut_quantize_ref", "block_matmul_ref"]


def _dequant_lords(q_packed, b, a, codebook_name, dtype):
    codes = unpack_codes(q_packed, codebook_name)
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    s = b.astype(jnp.float32) @ a.astype(jnp.float32)
    sign = jnp.where(s >= 0, 1.0, -1.0)
    s = jnp.where(jnp.abs(s) < SCALE_EPS, sign * SCALE_EPS, s)
    return (vals * s).astype(dtype)


def lords_matmul_ref(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """y = x @ (lut[Q] ⊙ (B·A))ᵀ.   x: (M, K); q: (N, K/pack); y: (M, N)."""
    w_hat = _dequant_lords(q_packed, b, a, codebook_name, x.dtype)
    return jnp.dot(x, w_hat.T, preferred_element_type=out_dtype).astype(out_dtype)


def lut_quantize_ref(
    w: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
) -> jnp.ndarray:
    """Packed nearest-level codes of W ⊘ (B·A) (Alg. 1 quantization step)."""
    from repro.core.quantize import pack_codes, quantize_codes

    s = b.astype(jnp.float32) @ a.astype(jnp.float32)
    codes = quantize_codes(w, s, codebook_name)
    return pack_codes(codes, codebook_name)


def block_matmul_ref(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """Block-wise (bitsandbytes-style) dequant matmul baseline."""
    codes = unpack_codes(q_packed, codebook_name)
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    s = expand_block_scales(s_blk, block_size)
    w_hat = (vals * s).astype(x.dtype)
    return jnp.dot(x, w_hat.T, preferred_element_type=out_dtype).astype(out_dtype)
