"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (tests assert_allclose kernels against
them) *and* the CPU/dry-run execution path: ``ops.py`` dispatches here on
non-TPU platforms, so the multi-pod dry-run lowers these exact graphs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import lut
from repro.core.quantize import unpack_codes
from repro.core.scaling import SCALE_EPS, clamp_scale, expand_block_scales

__all__ = [
    "lords_matmul_ref",
    "lut_quantize_ref",
    "block_matmul_ref",
    "lords_matmul_t_ref",
    "lords_grads_ref",
    "block_matmul_t_ref",
    "block_grads_ref",
]


def _lords_terms(q_packed, b, a, codebook_name):
    """Shared dequant terms: (lut[Q], clamped S, clamp mask) — the one place
    the backward family dequantizes, forward or ref."""
    codes = unpack_codes(q_packed, codebook_name)
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    s_raw = b.astype(jnp.float32) @ a.astype(jnp.float32)
    mask = (jnp.abs(s_raw) >= SCALE_EPS).astype(jnp.float32)
    return vals, clamp_scale(s_raw), mask


def _dequant_lords(q_packed, b, a, codebook_name, dtype):
    vals, s, _ = _lords_terms(q_packed, b, a, codebook_name)
    return (vals * s).astype(dtype)


def lords_matmul_ref(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """y = x @ (lut[Q] ⊙ (B·A))ᵀ.   x: (M, K); q: (N, K/pack); y: (M, N)."""
    w_hat = _dequant_lords(q_packed, b, a, codebook_name, x.dtype)
    return jnp.dot(x, w_hat.T, preferred_element_type=out_dtype).astype(out_dtype)


def lut_quantize_ref(
    w: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
) -> jnp.ndarray:
    """Packed nearest-level codes of W ⊘ (B·A) (Alg. 1 quantization step)."""
    from repro.core.quantize import pack_codes, quantize_codes

    s = b.astype(jnp.float32) @ a.astype(jnp.float32)
    codes = quantize_codes(w, s, codebook_name)
    return pack_codes(codes, codebook_name)


def lords_matmul_t_ref(
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
) -> jnp.ndarray:
    """dx = g @ (lut[Q] ⊙ (B·A)).   g: (M, N); q: (N, K/pack); dx: (M, K)."""
    w_hat = _dequant_lords(q_packed, b, a, codebook_name, jnp.float32)
    return g.astype(jnp.float32) @ w_hat


def lords_grads_ref(
    g: jnp.ndarray,
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    w: jnp.ndarray | None = None,
    want_dx: bool = True,
):
    """Dense-math oracle for the fused LoRDS backward family (one dequant).

    Returns ``(dx, dB, dA)`` for frozen/peft, plus ``dW`` when the qat
    master weight ``w`` is given — the parity reference for
    :mod:`repro.kernels.lords_matmul_t` + :mod:`repro.kernels.lords_grad`,
    and the execution path of the ``ref``/``dense`` backward.  The STE rule
    (Eq. 4/5) and the S = B·A chain rule are the shared helpers in
    :mod:`repro.core.qat` / :mod:`repro.core.peft`.  ``want_dx=False``
    drops the dx term (and its GEMM) for callers that only need the
    parameter gradients eagerly.
    """
    from repro.core.peft import scale_grads
    from repro.core.qat import ste_cotangents

    vals, s, mask = _lords_terms(q_packed, b, a, codebook_name)
    g32 = g.astype(jnp.float32)
    head = (g32 @ (vals * s),) if want_dx else ()
    dw_hat = g32.T @ x.astype(jnp.float32)                 # ∂L/∂Ŵ  (N, K)
    if w is None:                                          # frozen / peft
        ds = dw_hat * vals * mask
        return (*head, *scale_grads(ds, b, a))
    resid = vals - w.astype(jnp.float32) / s               # Q − W ⊘ S
    dw, ds = ste_cotangents(dw_hat, resid)
    db, da = scale_grads(ds * mask, b, a)
    return (*head, db, da, dw)


def _block_terms(q_packed, s_blk, block_size, codebook_name):
    """Shared block-wise dequant terms: (lut[Q], expanded S)."""
    codes = unpack_codes(q_packed, codebook_name)
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    s = expand_block_scales(s_blk.astype(jnp.float32), block_size)
    return vals, s


def block_matmul_t_ref(
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
) -> jnp.ndarray:
    """dx = g @ (lut[Q] ⊙ repeat(s_blk)).   g: (M, N); dx: (M, K)."""
    vals, s = _block_terms(q_packed, s_blk, block_size, codebook_name)
    return g.astype(jnp.float32) @ (vals * s)


def block_grads_ref(
    g: jnp.ndarray,
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
):
    """(dx, ∂s_blk) oracle for the block-wise backward (one dequant)."""
    vals, s = _block_terms(q_packed, s_blk, block_size, codebook_name)
    g32 = g.astype(jnp.float32)
    dx = g32 @ (vals * s)
    ds_full = (g32.T @ x.astype(jnp.float32)) * vals
    n, nblk = s_blk.shape
    ds_blk = ds_full.reshape(n, nblk, block_size).sum(-1)
    return dx, ds_blk


def block_matmul_ref(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """Block-wise (bitsandbytes-style) dequant matmul baseline."""
    vals, s = _block_terms(q_packed, s_blk, block_size, codebook_name)
    w_hat = (vals * s).astype(x.dtype)
    return jnp.dot(x, w_hat.T, preferred_element_type=out_dtype).astype(out_dtype)
