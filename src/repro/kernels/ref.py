"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (tests assert_allclose kernels against
them) *and* the CPU/dry-run execution path: ``ops.py`` dispatches here on
non-TPU platforms, so the multi-pod dry-run lowers these exact graphs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut
from repro.core.quantize import unpack_codes
from repro.core.scaling import SCALE_EPS, clamp_scale, expand_block_scales

__all__ = [
    "lords_matmul_ref",
    "lut_quantize_ref",
    "block_matmul_ref",
    "lords_matmul_t_ref",
    "lords_grads_ref",
    "block_matmul_t_ref",
    "block_grads_ref",
    "attn_prefill_ref",
    "attn_chunk_prefill_ref",
    "attn_decode_ref",
    "attn_mla_decode_ref",
    "attn_decode_paged_ref",
    "attn_mla_decode_paged_ref",
    "ATTN_NEG_INF",
]

ATTN_NEG_INF = -1e30  # finite mask value: exp(m - m) stays NaN-free


def _lords_terms(q_packed, b, a, codebook_name):
    """Shared dequant terms: (lut[Q], clamped S, clamp mask) — the one place
    the backward family dequantizes, forward or ref."""
    codes = unpack_codes(q_packed, codebook_name)
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    s_raw = b.astype(jnp.float32) @ a.astype(jnp.float32)
    mask = (jnp.abs(s_raw) >= SCALE_EPS).astype(jnp.float32)
    return vals, clamp_scale(s_raw), mask


def _dequant_lords(q_packed, b, a, codebook_name, dtype):
    vals, s, _ = _lords_terms(q_packed, b, a, codebook_name)
    return (vals * s).astype(dtype)


def lords_matmul_ref(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """y = x @ (lut[Q] ⊙ (B·A))ᵀ.   x: (M, K); q: (N, K/pack); y: (M, N)."""
    w_hat = _dequant_lords(q_packed, b, a, codebook_name, x.dtype)
    return jnp.dot(x, w_hat.T, preferred_element_type=out_dtype).astype(out_dtype)


def lut_quantize_ref(
    w: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
) -> jnp.ndarray:
    """Packed nearest-level codes of W ⊘ (B·A) (Alg. 1 quantization step)."""
    from repro.core.quantize import pack_codes, quantize_codes

    s = b.astype(jnp.float32) @ a.astype(jnp.float32)
    codes = quantize_codes(w, s, codebook_name)
    return pack_codes(codes, codebook_name)


def lords_matmul_t_ref(
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
) -> jnp.ndarray:
    """dx = g @ (lut[Q] ⊙ (B·A)).   g: (M, N); q: (N, K/pack); dx: (M, K)."""
    w_hat = _dequant_lords(q_packed, b, a, codebook_name, jnp.float32)
    return g.astype(jnp.float32) @ w_hat


def lords_grads_ref(
    g: jnp.ndarray,
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    w: jnp.ndarray | None = None,
    want_dx: bool = True,
):
    """Dense-math oracle for the fused LoRDS backward family (one dequant).

    Returns ``(dx, dB, dA)`` for frozen/peft, plus ``dW`` when the qat
    master weight ``w`` is given — the parity reference for
    :mod:`repro.kernels.lords_matmul_t` + :mod:`repro.kernels.lords_grad`,
    and the execution path of the ``ref``/``dense`` backward.  The STE rule
    (Eq. 4/5) and the S = B·A chain rule are the shared helpers in
    :mod:`repro.core.qat` / :mod:`repro.core.peft`.  ``want_dx=False``
    drops the dx term (and its GEMM) for callers that only need the
    parameter gradients eagerly.
    """
    from repro.core.peft import scale_grads
    from repro.core.qat import ste_cotangents

    vals, s, mask = _lords_terms(q_packed, b, a, codebook_name)
    g32 = g.astype(jnp.float32)
    head = (g32 @ (vals * s),) if want_dx else ()
    dw_hat = g32.T @ x.astype(jnp.float32)                 # ∂L/∂Ŵ  (N, K)
    if w is None:                                          # frozen / peft
        ds = dw_hat * vals * mask
        return (*head, *scale_grads(ds, b, a))
    resid = vals - w.astype(jnp.float32) / s               # Q − W ⊘ S
    dw, ds = ste_cotangents(dw_hat, resid)
    db, da = scale_grads(ds * mask, b, a)
    return (*head, db, da, dw)


def _block_terms(q_packed, s_blk, block_size, codebook_name):
    """Shared block-wise dequant terms: (lut[Q], expanded S)."""
    codes = unpack_codes(q_packed, codebook_name)
    levels = lut.codebook(codebook_name)
    vals = jnp.take(levels, codes.astype(jnp.int32), axis=0)
    s = expand_block_scales(s_blk.astype(jnp.float32), block_size)
    return vals, s


def block_matmul_t_ref(
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
) -> jnp.ndarray:
    """dx = g @ (lut[Q] ⊙ repeat(s_blk)).   g: (M, N); dx: (M, K)."""
    vals, s = _block_terms(q_packed, s_blk, block_size, codebook_name)
    return g.astype(jnp.float32) @ (vals * s)


def block_grads_ref(
    g: jnp.ndarray,
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
):
    """(dx, ∂s_blk) oracle for the block-wise backward (one dequant)."""
    vals, s = _block_terms(q_packed, s_blk, block_size, codebook_name)
    g32 = g.astype(jnp.float32)
    dx = g32 @ (vals * s)
    ds_full = (g32.T @ x.astype(jnp.float32)) * vals
    n, nblk = s_blk.shape
    ds_blk = ds_full.reshape(n, nblk, block_size).sum(-1)
    return dx, ds_blk


def attn_prefill_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    positions: jnp.ndarray,
    logit_scale: float,
) -> jnp.ndarray:
    """Materializing causal-attention oracle for the flash-prefill kernel.

    q (b, s, nh, hd) · k/v (b, s, nkv, hd) unexpanded-GQA; ``positions``
    (b, s) int32 gives every token's position (-1 = dead padding row).  A
    query attends to keys with ``kpos <= qpos`` and ``kpos >= 0`` — the same
    ragged mask the kernel applies per tile.  Returns (b, s, nh, hd_v) f32.
    """
    b, s, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qf = q.astype(jnp.float32) * jnp.float32(logit_scale)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, s, nkv, g, hd)
    scores = jnp.einsum("bqngh,bknh->bngqk", qg, kf)
    live = (positions[:, None, :] <= positions[:, :, None]) \
        & (positions[:, None, :] >= 0)                       # (b, q, k)
    scores = jnp.where(live[:, None, None], scores, ATTN_NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqk,bknh->bqngh", probs, vf)
    return out.reshape(b, s, nh, vf.shape[-1])


def attn_decode_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    pos: jnp.ndarray,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    logit_scale: float | None = None,
) -> jnp.ndarray:
    """Materializing GQA decode oracle for the fused quantized-KV kernel.

    q (b, nh, hd) vs cache k/v (b, S, nkv, hd); cache slots ``<= pos`` (b,)
    are live.  With ``k_scale``/``v_scale`` (b, S, nkv) the caches hold int8
    codes and the oracle dequantizes them up front — exactly the full-cache
    bf16 temporary the fused kernel exists to avoid.  Returns (b, nh, hd_v)
    f32.
    """
    b, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    cap = k.shape[1]
    if logit_scale is None:
        logit_scale = 1.0 / float(hd) ** 0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale[..., None].astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale[..., None].astype(jnp.float32)
    qg = q.astype(jnp.float32).reshape(b, nkv, g, hd) * jnp.float32(logit_scale)
    scores = jnp.einsum("bngh,bsnh->bngs", qg, kf)
    live = jnp.arange(cap, dtype=jnp.int32)[None, :] <= pos[:, None]
    scores = jnp.where(live[:, None, None], scores, ATTN_NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngs,bsnh->bngh", probs, vf)
    return out.reshape(b, nh, vf.shape[-1])


def attn_mla_decode_ref(
    q_lat: jnp.ndarray,
    q_rope: jnp.ndarray,
    c: jnp.ndarray,
    k_rope: jnp.ndarray,
    pos: jnp.ndarray,
    c_scale: jnp.ndarray | None = None,
    logit_scale: float = 1.0,
) -> jnp.ndarray:
    """Materializing MLA absorbed-latent decode oracle.

    q_lat (b, nh, L) scores against the latent cache c (b, S, L) and
    q_rope (b, nh, R) against the shared RoPE key cache k_rope (b, S, R);
    the attention output *is* the probability-weighted latent (b, nh, L) —
    the v_up absorption stays outside.  ``c_scale`` (b, S) dequantizes an
    int8 latent cache up front (the temporary the fused kernel avoids).
    """
    cap = c.shape[1]
    cf = c.astype(jnp.float32)
    if c_scale is not None:
        cf = cf * c_scale[..., None].astype(jnp.float32)
    scores = jnp.einsum("bhl,bsl->bhs", q_lat.astype(jnp.float32), cf)
    scores = scores + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                                 k_rope.astype(jnp.float32))
    scores = scores * jnp.float32(logit_scale)
    live = jnp.arange(cap, dtype=jnp.int32)[None, :] <= pos[:, None]
    scores = jnp.where(live[:, None], scores, ATTN_NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bsl->bhl", probs, cf)


def attn_chunk_prefill_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    qpos: jnp.ndarray,
    kpos: jnp.ndarray,
    logit_scale: float,
) -> jnp.ndarray:
    """Two-positions variant of :func:`attn_prefill_ref` for chunked
    prefill: q (b, s, nh, hd) at ``qpos`` (b, s) attends keys (b, S, nkv,
    hd) at ``kpos`` (b, S) — q and key lengths may differ (prefix window +
    current chunk).  Mask: ``kpos <= qpos``, both non-negative."""
    b, s, nh, hd = q.shape
    nkv = k.shape[2]
    g = nh // nkv
    qf = q.astype(jnp.float32) * jnp.float32(logit_scale)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, s, nkv, g, hd)
    scores = jnp.einsum("bqngh,bknh->bngqk", qg, kf)
    live = (kpos[:, None, :] <= qpos[:, :, None]) \
        & (kpos[:, None, :] >= 0) & (qpos[:, :, None] >= 0)   # (b, q, k)
    scores = jnp.where(live[:, None, None], scores, ATTN_NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqk,bknh->bqngh", probs, vf)
    return out.reshape(b, s, nh, vf.shape[-1])


def _gather_pool(pool: jnp.ndarray, pt: jnp.ndarray) -> jnp.ndarray:
    """(P, ps, ...) pool → contiguous (b, np*ps, ...) per-sequence window —
    the exact gather temp the paged kernels exist to avoid (this oracle is
    the negative control for the no-gather jaxpr guard)."""
    b, npages = pt.shape
    ps = pool.shape[1]
    flat = pool.reshape((pool.shape[0] * ps,) + pool.shape[2:])
    idx = (pt[:, :, None] * ps
           + jnp.arange(ps, dtype=pt.dtype)[None, None, :]).reshape(b, -1)
    return jnp.take(flat, idx, axis=0)


def attn_decode_paged_ref(
    pt: jnp.ndarray,
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    pos: jnp.ndarray,
    k_scale: jnp.ndarray | None = None,
    v_scale: jnp.ndarray | None = None,
    logit_scale: float | None = None,
) -> jnp.ndarray:
    """Paged GQA decode oracle: gather each sequence's pages into the
    contiguous (b, np*ps, nkv, hd) cache the fused paged kernel never
    materializes, then defer to :func:`attn_decode_ref`."""
    k = _gather_pool(k_pool, pt)
    v = _gather_pool(v_pool, pt)
    ks = None if k_scale is None else _gather_pool(k_scale, pt)
    vs = None if v_scale is None else _gather_pool(v_scale, pt)
    return attn_decode_ref(q, k, v, pos, ks, vs, logit_scale)


def attn_mla_decode_paged_ref(
    pt: jnp.ndarray,
    q_lat: jnp.ndarray,
    q_rope: jnp.ndarray,
    c_pool: jnp.ndarray,
    k_rope_pool: jnp.ndarray,
    pos: jnp.ndarray,
    c_scale: jnp.ndarray | None = None,
    logit_scale: float = 1.0,
) -> jnp.ndarray:
    """Paged MLA decode oracle (gather + :func:`attn_mla_decode_ref`)."""
    c = _gather_pool(c_pool, pt)
    kr = _gather_pool(k_rope_pool, pt)
    cs = None if c_scale is None else _gather_pool(c_scale, pt)
    return attn_mla_decode_ref(q_lat, q_rope, c, kr, pos, cs, logit_scale)


def block_matmul_ref(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """Block-wise (bitsandbytes-style) dequant matmul baseline."""
    vals, s = _block_terms(q_packed, s_blk, block_size, codebook_name)
    w_hat = (vals * s).astype(x.dtype)
    return jnp.dot(x, w_hat.T, preferred_element_type=out_dtype).astype(out_dtype)
