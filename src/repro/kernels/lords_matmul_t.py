"""Fused *transposed* LoRDS dequant-matmul Pallas kernels (training backward).

Computes  dx[M, K] = g[M, N] @ Ŵ,   Ŵ[N, K] = lut[Q] ⊙ (B·A)

directly from the packed codes — the activation-gradient half of the LoRDS
backward pass.  Together with :mod:`repro.kernels.lords_grad` this is what
lets QAT/PEFT training never materialize Ŵ: the forward streams Q once
(:mod:`repro.kernels.lords_matmul`), the backward streams it twice (here for
dx, there for the parameter gradients), and no (N, K) f32 dequantized
temporary ever exists in HBM.

Tiling (all VMEM):
  grid = (M/bm, K/bk, N/bn), N innermost for accumulation
    g tile   (bm, bn)            output-side gradient
    q tile   (bn, bk/pack) uint8 packed codes — streamed once per M-tile
    bT tile  (r, bn)             scale factor B, transposed (rank in sublanes)
    a tile   (r, bk)             constant index across the N loop → fetched
                                 once per K-tile and VMEM-resident after that
    lut      (1, L) f32          codebook levels
    out tile (bm, bk) f32        accumulated across the N grid axis

Per tile:  S = bTᵀ·a (rank-r MXU contraction), W = lut[q] ⊙ S (the same
one-hot × lut MXU gather as the forward kernels), acc += g·W — note W is
used *untransposed* here: the (bn, bk) dequant tile is exactly the operand
layout ``g @ Ŵ`` wants, so transposition costs nothing.  The innermost
(reduction) grid axis is double-buffered by the Pallas pipeline exactly as
in :mod:`repro.kernels.lords_decode`: the q DMAs for tile n+1 are in flight
while tile n is in the MXU.

``block_matmul_t_pallas`` is the block-wise analogue (piecewise-constant
scales instead of S = B·A) used by the blockwise/qlora-family backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lut_mod
from repro.core import quantize as quantize_mod
from repro.core.scaling import clamp_scale
from repro.kernels.lords_matmul import _lut_select, _unpack_tile

__all__ = ["lords_matmul_t_pallas", "block_matmul_t_pallas"]


def _kernel(g_ref, q_ref, bt_ref, a_ref, lut_ref, o_ref, *, ps, n_levels,
            eps):
    nn = pl.program_id(2)

    @pl.when(nn == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_tile(q_ref[...], ps)                      # (bn, bk)
    vals = _lut_select(codes, lut_ref, n_levels)              # (bn, bk) f32
    s = jax.lax.dot_general(
        bt_ref[...], a_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # (bn, bk)
    s = clamp_scale(s, eps)
    w = (vals * s).astype(g_ref.dtype)                        # (bn, bk)
    o_ref[...] += jax.lax.dot_general(
        g_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # (bm, bk)


@functools.partial(
    jax.jit,
    static_argnames=("codebook_name", "bm", "bn", "bk", "interpret"),
)
def lords_matmul_t_pallas(
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    b: jnp.ndarray,
    a: jnp.ndarray,
    codebook_name: str = "nf4",
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """See module docstring.  g (M,N) · dequant(q (N,K/pack), b (N,r), a (r,K))."""
    from repro.core.scaling import SCALE_EPS

    m, n = g.shape
    _, r = b.shape
    kdim = a.shape[1]
    ps = quantize_mod.pack_spec(codebook_name)
    levels = lut_mod.codebook(codebook_name)
    n_levels = levels.shape[0]

    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, kdim)
    if m % bm or n % bn or kdim % bk or bk % ps.group_codes:
        raise ValueError(
            f"shape ({m},{n},{kdim}) not divisible by blocks ({bm},{bn},{bk})"
        )
    grid = (m // bm, kdim // bk, n // bn)  # N innermost: the reduction axis

    bt = b.T  # (r, N)
    lut_arr = levels.reshape(1, -1).astype(jnp.float32)
    kern = functools.partial(
        _kernel, ps=ps, n_levels=n_levels, eps=SCALE_EPS
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, k, nn: (i, nn)),
            pl.BlockSpec((bn, ps.packed_width(bk)), lambda i, k, nn: (nn, k)),
            pl.BlockSpec((r, bn), lambda i, k, nn: (0, nn)),
            pl.BlockSpec((r, bk), lambda i, k, nn: (0, k)),
            pl.BlockSpec((1, n_levels), lambda i, k, nn: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, k, nn: (i, k)),
        out_shape=jax.ShapeDtypeStruct((m, kdim), jnp.float32),
        interpret=interpret,
    )(g, q_packed, bt, a, lut_arr)


# ---------------------------------------------------------------------------
# Block-wise transposed baseline:  dx = g @ (lut[Q] ⊙ repeat(s_blk))
# ---------------------------------------------------------------------------


def _block_kernel(g_ref, q_ref, s_ref, lut_ref, o_ref, *, ps, n_levels,
                  reps):
    nn = pl.program_id(2)

    @pl.when(nn == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_tile(q_ref[...], ps)
    vals = _lut_select(codes, lut_ref, n_levels)
    s = s_ref[...]  # (bn, bk // block_size) or (bn, 1)
    bn, nblk = s.shape
    # nblk * reps == bk in both layouts (whole blocks per tile, or one
    # block column spanning `block_size // bk` consecutive tiles)
    s_full = jnp.broadcast_to(s[:, :, None], (bn, nblk, reps)).reshape(
        bn, nblk * reps
    )
    w = (vals * s_full).astype(g_ref.dtype)
    o_ref[...] += jax.lax.dot_general(
        g_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "codebook_name", "bm", "bn", "bk",
                     "interpret"),
)
def block_matmul_t_pallas(
    g: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    m, n = g.shape
    ps = quantize_mod.pack_spec(codebook_name)
    kdim = ps.logical_width(q_packed.shape[1])
    levels = lut_mod.codebook(codebook_name)
    n_levels = levels.shape[0]

    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    if m % bm or n % bn or kdim % bk or bk % ps.group_codes:
        raise ValueError(f"({m},{n},{kdim}) not divisible by ({bm},{bn},{bk})")
    if not (bk % block_size == 0 or block_size % bk == 0):
        raise ValueError(f"bk {bk} incompatible with block_size {block_size}")
    grid = (m // bm, kdim // bk, n // bn)

    if bk >= block_size:
        s_cols, reps = bk // block_size, block_size
        s_index = lambda i, k, nn: (nn, k)
    else:
        s_cols, reps = 1, bk
        s_index = lambda i, k, nn: (nn, k // (block_size // bk))

    lut_arr = levels.reshape(1, -1).astype(jnp.float32)
    kern = functools.partial(_block_kernel, ps=ps, n_levels=n_levels,
                             reps=reps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, k, nn: (i, nn)),
            pl.BlockSpec((bn, ps.packed_width(bk)), lambda i, k, nn: (nn, k)),
            pl.BlockSpec((bn, s_cols), s_index),
            pl.BlockSpec((1, n_levels), lambda i, k, nn: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, k, nn: (i, k)),
        out_shape=jax.ShapeDtypeStruct((m, kdim), jnp.float32),
        interpret=interpret,
    )(g, q_packed, s_blk.astype(jnp.float32), lut_arr)
