"""Block-wise (bitsandbytes-style) dequant-matmul Pallas kernel — baseline.

Same contract as :mod:`repro.kernels.lords_matmul` but with piecewise-constant
block scales instead of the low-rank S = B·A.  Exists so the Fig.-2 style
kernel comparison (bnb-NF4 vs QLoRA vs LoRDS) is apples-to-apples on TPU.
Shares ``_lut_select`` with the lords kernels, so the LUT gather here is the
same one-hot × lut MXU matmul (select-chain only for wide int8 tables).

y[M,N] = x[M,K] @ (lut[Q] ⊙ repeat(s_blk))ᵀ
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lut_mod
from repro.core import quantize as quantize_mod
from repro.kernels.lords_matmul import _lut_select, _unpack_tile

__all__ = ["block_matmul_pallas"]


def _kernel(x_ref, q_ref, s_ref, lut_ref, o_ref, *, ps, n_levels, reps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_tile(q_ref[...], ps)
    vals = _lut_select(codes, lut_ref, n_levels)
    s = s_ref[...]  # (bn, bk // block_size) or (bn, 1)
    bn, nblk = s.shape
    s_full = jnp.broadcast_to(s[:, :, None], (bn, nblk, reps)).reshape(
        bn, nblk * reps
    )
    if s_full.shape[1] != vals.shape[1]:  # block spans multiple k tiles
        s_full = jnp.broadcast_to(s, vals.shape)
    w = (vals * s_full).astype(x_ref.dtype)
    o_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "codebook_name", "bm", "bn", "bk",
                     "interpret"),
)
def block_matmul_pallas(
    x: jnp.ndarray,
    q_packed: jnp.ndarray,
    s_blk: jnp.ndarray,
    block_size: int,
    codebook_name: str = "nf4",
    *,
    bm: int = 128,
    bn: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    m, kdim = x.shape
    n = q_packed.shape[0]
    ps = quantize_mod.pack_spec(codebook_name)
    levels = lut_mod.codebook(codebook_name)
    n_levels = levels.shape[0]

    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    if m % bm or n % bn or kdim % bk or bk % ps.group_codes:
        raise ValueError(f"({m},{n},{kdim}) not divisible by ({bm},{bn},{bk})")
    if not (bk % block_size == 0 or block_size % bk == 0):
        raise ValueError(f"bk {bk} incompatible with block_size {block_size}")
    grid = (m // bm, n // bn, kdim // bk)

    if bk >= block_size:
        s_cols, reps = bk // block_size, block_size
        s_index = lambda i, j, k: (j, k)
    else:
        s_cols, reps = 1, bk
        s_index = lambda i, j, k: (j, k // (block_size // bk))

    lut_arr = levels.reshape(1, -1).astype(jnp.float32)
    kern = functools.partial(_kernel, ps=ps, n_levels=n_levels, reps=reps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, ps.packed_width(bk)), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, s_cols), s_index),
            pl.BlockSpec((1, n_levels), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, q_packed, s_blk.astype(jnp.float32), lut_arr)
