"""Public jit'd wrappers for the kernels with platform dispatch.

On TPU the Pallas kernels run natively; everywhere else (CPU tests, the
512-device dry-run) the pure-jnp oracles from :mod:`repro.kernels.ref` are
used — numerically identical contract, so tests written against `ops` hold on
both paths.  ``use_pallas`` can force either path (tests pass
``use_pallas=True, interpret=True`` to execute the real kernel body on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.block_matmul import block_matmul_pallas
from repro.kernels.lords_grad import lords_grad_pallas
from repro.kernels.lords_matmul import lords_matmul_pallas
from repro.kernels.lords_matmul_t import lords_matmul_t_pallas
from repro.kernels.lut_quantize import lut_quantize_pallas

__all__ = ["lords_matmul", "lut_quantize", "block_matmul", "lords_matmul_t",
           "lords_grad", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto(use_pallas):
    return on_tpu() if use_pallas is None else use_pallas


def lords_matmul(
    x, q_packed, b, a, codebook_name="nf4", *,
    use_pallas=None, interpret=False, **blocks,
):
    """y = x @ (lut[Q] ⊙ (B·A))ᵀ — fused on TPU, oracle elsewhere."""
    if _auto(use_pallas):
        return lords_matmul_pallas(
            x, q_packed, b, a, codebook_name, interpret=interpret, **blocks
        )
    return ref.lords_matmul_ref(x, q_packed, b, a, codebook_name)


def lut_quantize(
    w, b, a, codebook_name="nf4", *, use_pallas=None, interpret=False, **blocks
):
    """Packed nearest-level codes of W ⊘ (B·A)."""
    if _auto(use_pallas):
        return lut_quantize_pallas(
            w, b, a, codebook_name, interpret=interpret, **blocks
        )
    return ref.lut_quantize_ref(w, b, a, codebook_name)


def block_matmul(
    x, q_packed, s_blk, block_size, codebook_name="nf4", *,
    use_pallas=None, interpret=False, **blocks,
):
    """Block-wise baseline dequant-matmul."""
    if _auto(use_pallas):
        return block_matmul_pallas(
            x, q_packed, s_blk, block_size, codebook_name,
            interpret=interpret, **blocks,
        )
    return ref.block_matmul_ref(x, q_packed, s_blk, block_size, codebook_name)


def lords_matmul_t(
    g, q_packed, b, a, codebook_name="nf4", *,
    use_pallas=None, interpret=False, **blocks,
):
    """dx = g @ (lut[Q] ⊙ (B·A)) — the training-backward transposed matmul."""
    if _auto(use_pallas):
        return lords_matmul_t_pallas(
            g, q_packed, b, a, codebook_name, interpret=interpret, **blocks
        )
    return ref.lords_matmul_t_ref(g, q_packed, b, a, codebook_name)


def lords_grad(
    x, g, q_packed, b, a, codebook_name="nf4", *,
    w=None, use_pallas=None, interpret=False, **blocks,
):
    """Rank-space parameter gradients (dB, dA[, dW]) of a LoRDS matmul.

    The fused path returns the kernel layout ``(dbT (r,N), da_part
    (N/bn,r,K)[, dW])``; this wrapper normalizes both paths to
    ``(dB (N,r), dA (r,K)[, dW])`` so callers are layout-agnostic.
    """
    if _auto(use_pallas):
        out = lords_grad_pallas(
            x, g, q_packed, b, a, codebook_name, w=w,
            interpret=interpret, **blocks,
        )
        db, da = out[0].T, out[1].sum(axis=0)
        return (db, da, out[2]) if w is not None else (db, da)
    return ref.lords_grads_ref(g, x, q_packed, b, a, codebook_name, w=w,
                               want_dx=False)
