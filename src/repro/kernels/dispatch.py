"""Unified, differentiable kernel dispatch for every quantized matmul.

``qmatmul(params, x, spec, n, m)`` is the single entry point all quantized
linears go through (core/lords, models/*, launch/serve, benchmarks).  It
replaces the old "always materialize Ŵ, then einsum" forward with a
QuantSpec-aware dispatch over four backends:

  * ``pallas``    — fused Pallas TPU kernels (``lords_matmul``,
                    ``block_matmul``, ``lut_quantize``): the low-rank scale
                    product S = B·A rides along with each weight tile, so Ŵ
                    never exists in HBM (paper §4.4 serving claim).
  * ``interpret`` — the same kernel bodies under the Pallas interpreter, so
                    CPU CI executes the real fused code paths.
  * ``ref``       — the pure-jnp oracles from :mod:`repro.kernels.ref`
                    (default off-TPU: numerically identical contract).
  * ``dense``     — the legacy dequantize-then-einsum path, kept as the
                    universal fallback (blockwise QAT, AWQ-smoothed weights,
                    any method/mode combination the fused kernels don't cover).

Selection: explicit ``backend=`` argument > :func:`backend_scope` context >
``REPRO_KERNEL_BACKEND`` env > ``REPRO_INTERPRET_KERNELS=1`` env (tests/CI) >
platform default (pallas on TPU, ref elsewhere).

Padding: the raw Pallas kernels require tile-divisible (M, N, K) and raise
otherwise.  The dispatcher instead zero-pads every operand up to the active
tile multiples and slices the result — K-padding is exact because x is
zero-padded along K, and padded N rows / M columns are sliced off.  Padded
scale entries hit the kernels' |S| >= eps clamp, never a divide-by-zero.

Differentiability: fused lords forwards carry ``jax.custom_vjp``s —
``peft`` mode backpropagates to (B, A) through the multiplicative scale
(the clamp-masked ∂S rule autodiff would produce on the dense path), and
``qat`` mode implements the paper's STE cotangents (Eq. 4/5: ∇W = ∂L/∂Ŵ,
∇S = ∂L/∂Ŵ ⊙ (Q − W⊘S)).  On the fused backends the *backward* is fused
too: dx runs the transposed dequant-matmul kernel
(:mod:`repro.kernels.lords_matmul_t`) and the parameter gradients the
tiled grad-reduction kernel (:mod:`repro.kernels.lords_grad`), so neither
the forward nor the backward ever materializes an (N, K) f32 Ŵ (or ∂S)
temporary — training costs packed-weight bandwidth, not dense bandwidth.
On ``ref``/``dense`` backends the backward runs the single dense-math
oracle :func:`repro.kernels.ref.lords_grads_ref` (one dequant, shared
Eq. 4/5 / chain-rule helpers from ``core.qat`` / ``core.peft``).
Backward tile choices use the *transposed* autotune keys (``lords_t`` /
``blockwise_t``, tuned by ``autotune_qmatmul_bwd``); the ``tiles=``
argument only pins the forward.

Decode fast path: fused lords forwards with M ≤ 8 flattened tokens route to
the weight-stationary GEMV kernel (:mod:`repro.kernels.lords_decode`) —
weights stream exactly once per call, the memory-roofline minimum for
autoregressive decoding.  The routing is by trace-time shape, so a jitted
serve step picks the decode kernel automatically.

Sharded execution: inside a ``shard_scope(mesh)`` the fused lords /
blockwise paths run data+tensor-parallel over the mesh via ``shard_map``:
the packed codes (and the row dim of B / the QAT master W / the block
scales) shard over 'model' while the rank-r A factor stays replicated —
the codes-shard / factors-replicate layout the sharding rules in
:mod:`repro.distributed.sharding` assign to every quantized linear — and
the flattened token dim shards over the remaining (data/pod) mesh axes
when it divides them.  The custom VJPs stay fused per shard and
psum-reduce exactly the cross-shard cotangents (dx over 'model', dB/dW/
ds_blk over the data axes, dA over both), so a data+tensor-parallel
QAT/PEFT step never materializes Ŵ either.  Layers whose out-dim does not
divide the model axis fall back to the unsharded path (mirroring
``resolve_spec``'s divisibility drops), as does the ``dense`` backend
(GSPMD partitions its einsum directly).

Autotuning: per-(method, M-bucket, N, K, codebook, dtype) tile choices live
in a small in-process table.  ``autotune_qmatmul`` times candidate tilings
through the public entry point and registers the winner; subsequent
``qmatmul`` traces consult the table (lookups happen at trace time).  Set
``REPRO_AUTOTUNE_CACHE=/path/to/table.json`` to persist the table across
processes: it is loaded on import and saved after every successful
``autotune_qmatmul``, so benchmark-found tiles survive into serving runs.
"""
from __future__ import annotations

import contextlib
import functools
import json
import math
import os
import threading
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.kernels import ref
from repro.kernels.attn_decode import (
    DECODE_ROWS,
    attn_decode_gqa_paged_pallas,
    attn_decode_gqa_pallas,
    attn_decode_mla_paged_pallas,
    attn_decode_mla_pallas,
)
from repro.kernels.attn_prefill import attn_prefill_pallas
from repro.kernels.block_matmul import block_matmul_pallas
from repro.kernels.lords_decode import DECODE_M_MAX, lords_decode_pallas
from repro.kernels.lords_grad import block_grad_pallas, lords_grad_pallas
from repro.kernels.lords_matmul import lords_matmul_pallas
from repro.kernels.lords_matmul_t import (
    block_matmul_t_pallas,
    lords_matmul_t_pallas,
)
from repro.kernels.lut_quantize import lut_quantize_pallas

__all__ = [
    "BACKENDS",
    "qmatmul",
    "qattention",
    "default_backend",
    "fused_backend_active",
    "backend_scope",
    "shard_scope",
    "shard_info",
    "tile_for",
    "attn_tile_for",
    "lookup_tiles",
    "register_tiles",
    "autotune_qmatmul",
    "autotune_qmatmul_bwd",
    "autotune_qattention",
    "autotune_table",
    "load_autotune_table",
    "save_autotune_table",
]

BACKENDS = ("pallas", "interpret", "ref", "dense")
_FUSED = ("pallas", "interpret")

_TLS = threading.local()


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------


def default_backend() -> str:
    """Resolve the active backend (see module docstring for precedence)."""
    scoped = getattr(_TLS, "backend", None)
    forced = scoped or os.environ.get("REPRO_KERNEL_BACKEND")
    if forced:
        if forced not in BACKENDS:
            raise ValueError(
                f"unknown kernel backend {forced!r}; expected one of {BACKENDS}"
            )
        return forced
    if os.environ.get("REPRO_INTERPRET_KERNELS") == "1":
        return "interpret"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@contextlib.contextmanager
def backend_scope(backend: str | None):
    """Pin the dispatch backend for everything traced inside the scope.

    ``None`` leaves the ambient selection untouched (so launchers can thread
    an optional CLI flag straight through).
    """
    if backend is not None and backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}"
        )
    prev = getattr(_TLS, "backend", None)
    _TLS.backend = backend if backend is not None else prev
    try:
        yield
    finally:
        _TLS.backend = prev


def _resolve(backend: str | None) -> str:
    return backend if backend is not None else default_backend()


def fused_backend_active(backend: str | None = None) -> bool:
    """Whether the resolved backend runs the fused Pallas kernel bodies —
    the single routing predicate model code and plan metadata share, so a
    backend added to ``_FUSED`` can never leave them disagreeing."""
    return _resolve(backend) in _FUSED


# ---------------------------------------------------------------------------
# Tensor-parallel scope (shard_map over the mesh's model axis)
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def shard_scope(mesh, axis: str = "model"):
    """Run fused qmatmuls traced inside tensor-parallel over ``mesh``.

    Packed codes / B rows / the QAT master W shard over ``axis``; A stays
    replicated; the custom VJPs psum dx and dA across ``axis``.  Unlike
    :func:`backend_scope`, ``mesh=None`` (or a mesh where ``axis`` has size
    1) explicitly *disables* sharded dispatch inside the scope — the form
    the MoE shard_map bodies use to stop fused matmuls from opening a
    nested shard_map.
    """
    prev = getattr(_TLS, "shard", None)
    active = mesh is not None and dict(mesh.shape).get(axis, 1) > 1
    _TLS.shard = (mesh, axis) if active else None
    try:
        yield
    finally:
        _TLS.shard = prev


def shard_info() -> tuple | None:
    """The active (mesh, model-axis) pair, or None outside any shard_scope."""
    return getattr(_TLS, "shard", None)


def _tp_shard(backend: str, n: int) -> tuple | None:
    """Resolve the tensor-parallel route for an (N, K) quantized linear.

    Returns (mesh, axis) when a shard scope is active, the backend has a
    fused/ref per-shard body, and N divides the model-axis size; None means
    take the unsharded path (the same divisibility fallback resolve_spec
    applies to the weight tree, so compute and layout always agree).
    """
    sh = shard_info()
    if sh is None or backend == "dense":
        return None
    mesh, axis = sh
    if n % dict(mesh.shape)[axis]:
        return None
    return sh


# ---------------------------------------------------------------------------
# Tile selection + autotune table
# ---------------------------------------------------------------------------

# (method, M-bucket, N, K, codebook, dtype-name, block_size) -> (bm, bn, bk)
_AUTOTUNE: dict[tuple, tuple[int, int, int]] = {}


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _spec_of(codebook_name: str):
    from repro.core.quantize import pack_spec

    return pack_spec(codebook_name)


def _m_bucket(m: int) -> int:
    """Power-of-two token bucket so decode (M=1..8) and prefill share keys."""
    return 1 << max(3, (max(m, 1) - 1).bit_length())


def autotune_key(method: str, m: int, n: int, k: int, codebook: str,
                 dtype, block_size: int | None = None) -> tuple:
    # block_size is part of the key: same-(N, K) layers with different
    # effective block sizes need bk-compatible tilings (bk % bs or bs % bk)
    return (method, _m_bucket(m), n, k, codebook, jnp.dtype(dtype).name,
            block_size)


def lookup_tiles(method, m, n, k, codebook, dtype, block_size=None):
    return _AUTOTUNE.get(
        autotune_key(method, m, n, k, codebook, dtype, block_size))


def register_tiles(method, m, n, k, codebook, dtype,
                   tiles: tuple[int, int, int],
                   block_size: int | None = None) -> None:
    key = autotune_key(method, m, n, k, codebook, dtype, block_size)
    _AUTOTUNE[key] = tuple(tiles)


def autotune_table() -> dict:
    """Read-only snapshot of the autotune table (for benchmarks/reports)."""
    return dict(_AUTOTUNE)


# ---------------------------------------------------------------------------
# Autotune persistence (REPRO_AUTOTUNE_CACHE=<json path>)
# ---------------------------------------------------------------------------

_AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"


def _autotune_cache_path(path: str | None = None) -> str | None:
    return path or os.environ.get(_AUTOTUNE_CACHE_ENV) or None


def save_autotune_table(path: str | None = None) -> str | None:
    """Write the in-process table to JSON (``path`` or the env default).

    Returns the path written, or None when no destination is configured —
    callers can treat persistence as strictly optional.
    """
    path = _autotune_cache_path(path)
    if not path:
        return None
    # merge-then-write narrows (not closes) the lost-update window between
    # concurrent shards sharing one cache file: a shard that replaces the
    # file between this load and our rename below still loses its entries.
    # Best-effort is fine for a tuning cache — a dropped entry only costs
    # a re-autotune; correctness never depends on the file.
    load_autotune_table(path)
    entries = [{"key": list(k), "tiles": list(v)}
               for k, v in sorted(_AUTOTUNE.items(), key=str)]
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic rename: readers never see a torn file
    return path


def load_autotune_table(path: str | None = None, *,
                        overwrite: bool = False) -> int:
    """Merge a persisted table into the process (in-process entries win
    unless ``overwrite``).  Missing/corrupt files are *tolerated* — a stale
    or bit-rotted cache must never break serving — but corruption is
    surfaced with a warning so operators know tiles fell back to the
    heuristic.  Returns the number of entries merged.
    """
    path = _autotune_cache_path(path)
    if not path or not os.path.exists(path):
        return 0
    try:
        with open(path) as f:
            data = json.load(f)
        entries = data["entries"]
    except (OSError, ValueError, KeyError, TypeError) as e:
        warnings.warn(
            f"autotune cache {path!r} is unreadable ({e!r}); ignoring it — "
            "kernels fall back to heuristic tiles until re-autotuned",
            RuntimeWarning, stacklevel=2)
        return 0
    n, bad = 0, 0
    for e in entries:
        try:
            key = tuple(e["key"])
            tiles = tuple(int(t) for t in e["tiles"])
        except (KeyError, TypeError, ValueError):
            bad += 1
            continue
        if len(tiles) != 3:
            bad += 1
            continue
        if overwrite or key not in _AUTOTUNE:
            _AUTOTUNE[key] = tiles
            n += 1
    if bad:
        warnings.warn(
            f"autotune cache {path!r}: skipped {bad} malformed "
            f"entr{'y' if bad == 1 else 'ies'} (kept {n})",
            RuntimeWarning, stacklevel=2)
    return n


load_autotune_table()  # import-time: benchmark-found tiles from prior runs


def tile_for(method: str, m: int, n: int, k: int, codebook: str, dtype,
             block_size: int | None = None) -> tuple[int, int, int]:
    """Tile choice: autotune-table hit, else a lane-aligned heuristic.

    Defaults follow the kernel docstrings (bm 128 / bn 256 / bk 512), shrunk
    to the (padded) problem: bm to a sublane multiple, bn/bk to lane
    multiples, bk additionally to a pack multiple and — for blockwise — to a
    block_size-compatible value (bk % bs == 0 or bs % bk == 0).
    """
    hit = lookup_tiles(method, m, n, k, codebook, dtype, block_size)
    if hit is not None:
        return hit
    ps = _spec_of(codebook)
    bm = min(128, _round_up(m, 8))
    bn = min(256, _round_up(n, 128))
    if ps.group_bytes == 1:
        # historical unit: bk a multiple of 128·codes-per-byte so the packed
        # q tile width stays lane-aligned
        bk = min(512, _round_up(k, 128 * ps.group_codes))
    else:
        # cross-byte groups (3-bit): prefer the smallest bk whose packed
        # width is lane-aligned (1024 → 384 bytes = 3 lanes); for small K
        # fall back to lane-aligned *logical* tiles with whole pack groups
        # rather than padding K up to 1024
        unit = ps.group_codes * (128 // math.gcd(ps.group_bytes, 128))
        bk = min(max(512, unit),
                 _round_up(k, math.lcm(ps.group_codes, 128)))
    if block_size is not None:
        if bk >= block_size:
            bk = max(block_size, (bk // block_size) * block_size)
        elif block_size % bk:
            bk = math.gcd(bk, block_size) or block_size
        if bk % ps.group_codes:  # exotic block sizes: keep whole groups
            bk = _round_up(bk, ps.group_codes)
    return bm, bn, bk


def _pad2(arr, rows, cols):
    pr, pc = rows - arr.shape[0], cols - arr.shape[1]
    if pr == 0 and pc == 0:
        return arr
    return jnp.pad(arr, ((0, pr), (0, pc)))


# ---------------------------------------------------------------------------
# Fused lords forward (frozen / peft): y = x @ (lut[Q] ⊙ (B·A))ᵀ
# ---------------------------------------------------------------------------


def _lords_forward(x2d, q_packed, b, a, codebook, backend, tiles):
    if backend == "ref":
        return ref.lords_matmul_ref(x2d, q_packed, b, a, codebook)
    m, k = x2d.shape
    n = q_packed.shape[0]
    ps = _spec_of(codebook)
    bm, bn, bk = tiles or tile_for("lords", m, n, k, codebook, x2d.dtype)
    interp = backend == "interpret"
    if m <= DECODE_M_MAX:
        # decode fast path: weight-stationary GEMV kernel, M padded to the
        # sublane tile inside the kernel (bm from the tile table is moot)
        np_, kp = _round_up(n, bn), _round_up(k, bk)
        y = lords_decode_pallas(
            _pad2(x2d, m, kp),
            _pad2(q_packed, np_, ps.packed_width(kp)),
            _pad2(b, np_, b.shape[1]),
            _pad2(a, a.shape[0], kp),
            codebook,
            bn=bn, bk=bk,
            interpret=interp,
        )
        return y[:, :n]
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    y = lords_matmul_pallas(
        _pad2(x2d, mp, kp),
        _pad2(q_packed, np_, ps.packed_width(kp)),
        _pad2(b, np_, b.shape[1]),
        _pad2(a, a.shape[0], kp),
        codebook,
        bm=bm, bn=bn, bk=bk,
        interpret=interp,
    )
    return y[:m, :n]


def _lords_grads(g, x2d, q_packed, b, a, w, codebook, backend):
    """Fused backward family: dx = g·Ŵ via the transposed kernel, rank-space
    dB/dA (and the QAT dW/∂S STE terms) via the tiled grad-reduction kernel
    — no (N, K) f32 dequantized temporary on fused backends.  Returns
    ``(dx, db, da)`` in f32 (+ ``dw`` when the qat master ``w`` is given).
    """
    if backend not in _FUSED:
        return ref.lords_grads_ref(g, x2d, q_packed, b, a, codebook, w=w)
    m, k = x2d.shape
    n = q_packed.shape[0]
    ps = _spec_of(codebook)
    # the `transposed` autotune key: one tile triple drives both bwd kernels
    bm, bn, bk = tile_for("lords_t", m, n, k, codebook, jnp.float32)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    interp = backend == "interpret"
    g32 = _pad2(g.astype(jnp.float32), mp, np_)
    x32 = _pad2(x2d.astype(jnp.float32), mp, kp)
    qp = _pad2(q_packed, np_, ps.packed_width(kp))
    bp = _pad2(b.astype(jnp.float32), np_, b.shape[1])
    ap = _pad2(a.astype(jnp.float32), a.shape[0], kp)
    dx = lords_matmul_t_pallas(
        g32, qp, bp, ap, codebook, bm=bm, bn=bn, bk=bk, interpret=interp,
    )[:m, :k]
    wp = None if w is None else _pad2(w.astype(jnp.float32), np_, kp)
    out = lords_grad_pallas(
        x32, g32, qp, bp, ap, codebook, w=wp,
        bm=bm, bn=bn, bk=bk, interpret=interp,
    )
    db = out[0][:, :n].T                       # dbT (r, Np) -> dB (N, r)
    da = out[1].sum(axis=0)[:, :k]             # Σ_j da_part -> dA (r, K)
    if w is None:
        return dx, db, da
    return dx, db, da, out[2][:n, :k]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _lords_qmatmul(x2d, q_packed, b, a, codebook, backend, tiles):
    return _lords_forward(x2d, q_packed, b, a, codebook, backend, tiles)


def _lords_fwd(x2d, q_packed, b, a, codebook, backend, tiles):
    y = _lords_forward(x2d, q_packed, b, a, codebook, backend, tiles)
    return y, (x2d, q_packed, b, a)


def _lords_bwd(codebook, backend, tiles, res, g):
    x2d, q_packed, b, a = res
    dx, db, da = _lords_grads(g, x2d, q_packed, b, a, None, codebook, backend)
    dq = np.zeros(q_packed.shape, jax.dtypes.float0)   # int codes: no grad
    return (dx.astype(x2d.dtype), dq, db.astype(b.dtype), da.astype(a.dtype))


_lords_qmatmul.defvjp(_lords_fwd, _lords_bwd)


# ---------------------------------------------------------------------------
# Fused lords QAT: y = x @ (ROUND(W ⊘ BA) ⊙ BA)ᵀ with STE cotangents
# ---------------------------------------------------------------------------


def _lords_qat_forward(x2d, w, b, a, codebook, backend, tiles):
    """Returns (y, q_packed).  Fused backends run the lut_quantize kernel and
    feed its packed codes straight into the fused matmul — Ŵ never exists."""
    if backend == "ref":
        q_packed = ref.lut_quantize_ref(w, b, a, codebook)
        return ref.lords_matmul_ref(x2d, q_packed, b, a, codebook), q_packed
    m, k = x2d.shape
    n = w.shape[0]
    ps = _spec_of(codebook)
    bm, bn, bk = tiles or tile_for("lords", m, n, k, codebook, x2d.dtype)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    interp = backend == "interpret"
    bp = _pad2(b, np_, b.shape[1])
    ap = _pad2(a, a.shape[0], kp)
    qp = lut_quantize_pallas(
        _pad2(w, np_, kp), bp, ap, codebook, bn=bn, bk=bk, interpret=interp
    )
    y = lords_matmul_pallas(
        _pad2(x2d, mp, kp), qp, bp, ap, codebook,
        bm=bm, bn=bn, bk=bk, interpret=interp,
    )
    # slice codes back to the logical K, rounded up to whole pack groups —
    # trailing codes past k (if any) decode under zero-padded activations
    return y[:m, :n], qp[:n, : ps.packed_width(_round_up(k, ps.group_codes))]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _lords_qat_qmatmul(x2d, w, b, a, codebook, backend, tiles):
    y, _ = _lords_qat_forward(x2d, w, b, a, codebook, backend, tiles)
    return y


def _lords_qat_fwd(x2d, w, b, a, codebook, backend, tiles):
    y, q_packed = _lords_qat_forward(x2d, w, b, a, codebook, backend, tiles)
    return y, (x2d, w, b, a, q_packed)


def _lords_qat_bwd(codebook, backend, tiles, res, g):
    # the packed codes saved by the forward feed the backward kernels
    # directly — no second quantization or dequantization pass
    x2d, w, b, a, q_packed = res
    dx, db, da, dw = _lords_grads(g, x2d, q_packed, b, a, w, codebook,
                                  backend)
    return (dx.astype(x2d.dtype), dw.astype(w.dtype),
            db.astype(b.dtype), da.astype(a.dtype))


_lords_qat_qmatmul.defvjp(_lords_qat_fwd, _lords_qat_bwd)


# ---------------------------------------------------------------------------
# Fused block-wise baseline: y = x @ (lut[Q] ⊙ repeat(s_blk))ᵀ
# ---------------------------------------------------------------------------


def _block_padded(q_packed, s_blk, m, n, k, block_size, bm, bn, bk, ps):
    """Shared fwd/bwd block-operand padding: K rounds to lcm(bk, block_size)
    so tiles and blocks stay commensurate, padded scales are 1.0 (never the
    eps clamp), padded rows/cols contribute zeros.  One helper so the
    forward and its VJP can never pad differently."""
    kmult = bk * block_size // math.gcd(bk, block_size)  # lcm: tiles + blocks
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, kmult)
    qp = _pad2(q_packed, np_, ps.packed_width(kp))
    s_pad = jnp.pad(
        s_blk,
        ((0, np_ - n), (0, kp // block_size - s_blk.shape[1])),
        constant_values=1.0,
    )
    return qp, s_pad, mp, np_, kp


def _block_forward(x2d, q_packed, s_blk, block_size, codebook, backend, tiles):
    if backend == "ref":
        return ref.block_matmul_ref(x2d, q_packed, s_blk, block_size, codebook)
    m, k = x2d.shape
    n = q_packed.shape[0]
    ps = _spec_of(codebook)
    bm, bn, bk = tiles or tile_for(
        "blockwise", m, n, k, codebook, x2d.dtype, block_size=block_size)
    qp, s_pad, mp, np_, kp = _block_padded(
        q_packed, s_blk, m, n, k, block_size, bm, bn, bk, ps)
    y = block_matmul_pallas(
        _pad2(x2d, mp, kp),
        qp,
        s_pad,
        block_size,
        codebook,
        bm=bm, bn=bn, bk=bk,
        interpret=(backend == "interpret"),
    )
    return y[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _block_qmatmul(x2d, q_packed, s_blk, block_size, codebook, backend, tiles):
    return _block_forward(x2d, q_packed, s_blk, block_size, codebook, backend,
                          tiles)


def _block_fwd(x2d, q_packed, s_blk, block_size, codebook, backend, tiles):
    y = _block_forward(x2d, q_packed, s_blk, block_size, codebook, backend,
                       tiles)
    return y, (x2d, q_packed, s_blk)


def _block_grads(g, x2d, q_packed, s_blk, block_size, codebook, backend):
    """Fused block-wise backward: transposed dequant-matmul for dx + tiled
    per-block ∂s reduction — the blockwise mirror of :func:`_lords_grads`."""
    if backend not in _FUSED:
        return ref.block_grads_ref(g, x2d, q_packed, s_blk, block_size,
                                   codebook)
    m, k = x2d.shape
    n = q_packed.shape[0]
    ps = _spec_of(codebook)
    bm, bn, bk = tile_for("blockwise_t", m, n, k, codebook, jnp.float32,
                          block_size=block_size)
    qp, s_pad, mp, np_, kp = _block_padded(
        q_packed, s_blk.astype(jnp.float32), m, n, k, block_size,
        bm, bn, bk, ps)
    interp = backend == "interpret"
    g32 = _pad2(g.astype(jnp.float32), mp, np_)
    x32 = _pad2(x2d.astype(jnp.float32), mp, kp)
    dx = block_matmul_t_pallas(
        g32, qp, s_pad, block_size, codebook,
        bm=bm, bn=bn, bk=bk, interpret=interp,
    )[:m, :k]
    ds_blk = block_grad_pallas(
        x32, g32, qp, block_size, codebook,
        bm=bm, bn=bn, bk=bk, interpret=interp,
    )[:n, : s_blk.shape[1]]
    return dx, ds_blk


def _block_bwd(block_size, codebook, backend, tiles, res, g):
    x2d, q_packed, s_blk = res
    dx, ds_blk = _block_grads(g, x2d, q_packed, s_blk, block_size, codebook,
                              backend)
    dq = np.zeros(q_packed.shape, jax.dtypes.float0)
    return dx.astype(x2d.dtype), dq, ds_blk.astype(s_blk.dtype)


_block_qmatmul.defvjp(_block_fwd, _block_bwd)


# ---------------------------------------------------------------------------
# Sharded fused paths: shard_map over the mesh, psum'd cotangents
# ---------------------------------------------------------------------------
#
# Layout per (N, K) linear with model parallelism p and data parallelism d
# (the product of the remaining mesh axes, used when the flattened token
# count divides it):
#   codes Q (N/p, K/pack) · B (N/p, r) · W (N/p, K) · s_blk (N/p, K/bs)
#   row-shard over 'model'; A (r, K) replicates; x (M/d, K) shards its
#   token dim over the data axes; y comes out (M/d, N/p).  Each device
#   runs the *same* fused kernel bodies as the unsharded path on its
#   (token-slice × row-slice) block — Ŵ never exists anywhere.
# Backward psums follow from the layout: dx is token-local but partial
#   over the row shards (psum 'model'); dB / dW / ds_blk are row-local but
#   partial over the token shards (psum data axes); dA is partial over
#   both (psum all).  When M doesn't divide d, x replicates and the
#   data-axis psums drop out.
# The custom VJPs sit *outside* shard_map (explicit psums instead of
# relying on transpose-of-manual replication rules, which custom_vjp
# bodies cannot declare).


def _dp_axes(mesh, axis, m_tokens: int) -> tuple:
    """Mesh axes the token dim shards over: every non-model axis, kept only
    when the flattened token count divides their product (else replicate,
    matching resolve_spec's divisibility behavior for activations)."""
    shape = dict(mesh.shape)
    axes = tuple(a for a, size in shape.items() if a != axis and size > 1)
    size = 1
    for a in axes:
        size *= shape[a]
    if not axes or m_tokens % size:
        return ()
    return axes


def _psum(v, axes):
    return jax.lax.psum(v, axes) if axes else v


def _tp_specs(axis, batch: tuple):
    bspec = batch if len(batch) > 1 else (batch[0] if batch else None)
    xs = PartitionSpec(bspec, None)     # x / dx: tokens over the data axes
    row = PartitionSpec(axis, None)     # codes / B / W / s_blk rows
    rep = PartitionSpec()               # A
    out = PartitionSpec(bspec, axis)    # y / g
    return xs, row, rep, out


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _shlords_qmatmul(x2d, q_packed, b, a, codebook, backend, mesh, axis,
                     tiles):
    xs, row, rep, out = _tp_specs(axis, _dp_axes(mesh, axis, x2d.shape[0]))
    return shard_map(
        lambda xl, ql, bl, al: _lords_forward(
            xl, ql, bl, al, codebook, backend, tiles),
        mesh=mesh, in_specs=(xs, row, row, rep), out_specs=out,
        check_rep=False,
    )(x2d, q_packed, b, a)


def _shlords_fwd(x2d, q_packed, b, a, codebook, backend, mesh, axis, tiles):
    y = _shlords_qmatmul(x2d, q_packed, b, a, codebook, backend, mesh, axis,
                         tiles)
    return y, (x2d, q_packed, b, a)


def _shlords_bwd(codebook, backend, mesh, axis, tiles, res, g):
    x2d, q_packed, b, a = res
    dp = _dp_axes(mesh, axis, x2d.shape[0])
    xs, row, rep, out = _tp_specs(axis, dp)

    def body(gl, xl, ql, bl, al):
        dx, db, da = _lords_grads(gl, xl, ql, bl, al, None, codebook, backend)
        return jax.lax.psum(dx, axis), _psum(db, dp), _psum(da, dp + (axis,))

    dx, db, da = shard_map(
        body, mesh=mesh, in_specs=(out, xs, row, row, rep),
        out_specs=(xs, row, rep), check_rep=False,
    )(g, x2d, q_packed, b, a)
    dq = np.zeros(q_packed.shape, jax.dtypes.float0)
    return (dx.astype(x2d.dtype), dq, db.astype(b.dtype), da.astype(a.dtype))


_shlords_qmatmul.defvjp(_shlords_fwd, _shlords_bwd)


def _shlords_qat_forward(x2d, w, b, a, codebook, backend, mesh, axis, tiles):
    """Shared primal/fwd body: returns (y, row-sharded packed codes)."""
    xs, row, rep, out = _tp_specs(axis, _dp_axes(mesh, axis, x2d.shape[0]))
    return shard_map(
        lambda xl, wl, bl, al: _lords_qat_forward(
            xl, wl, bl, al, codebook, backend, tiles),
        mesh=mesh, in_specs=(xs, row, row, rep), out_specs=(out, row),
        check_rep=False,
    )(x2d, w, b, a)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _shlords_qat_qmatmul(x2d, w, b, a, codebook, backend, mesh, axis, tiles):
    y, _ = _shlords_qat_forward(x2d, w, b, a, codebook, backend, mesh, axis,
                                tiles)
    return y


def _shlords_qat_fwd(x2d, w, b, a, codebook, backend, mesh, axis, tiles):
    y, q_packed = _shlords_qat_forward(x2d, w, b, a, codebook, backend, mesh,
                                       axis, tiles)
    # the row-sharded packed codes ride to the backward exactly as saved —
    # each shard re-reads its own codes, no re-quantization pass
    return y, (x2d, w, b, a, q_packed)


def _shlords_qat_bwd(codebook, backend, mesh, axis, tiles, res, g):
    x2d, w, b, a, q_packed = res
    dp = _dp_axes(mesh, axis, x2d.shape[0])
    xs, row, rep, out = _tp_specs(axis, dp)

    def body(gl, xl, ql, bl, al, wl):
        dx, db, da, dw = _lords_grads(gl, xl, ql, bl, al, wl, codebook,
                                      backend)
        return (jax.lax.psum(dx, axis), _psum(db, dp),
                _psum(da, dp + (axis,)), _psum(dw, dp))

    dx, db, da, dw = shard_map(
        body, mesh=mesh, in_specs=(out, xs, row, row, rep, row),
        out_specs=(xs, row, rep, row), check_rep=False,
    )(g, x2d, q_packed, b, a, w)
    return (dx.astype(x2d.dtype), dw.astype(w.dtype),
            db.astype(b.dtype), da.astype(a.dtype))


_shlords_qat_qmatmul.defvjp(_shlords_qat_fwd, _shlords_qat_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _shblock_qmatmul(x2d, q_packed, s_blk, block_size, codebook, backend,
                     mesh, axis, tiles):
    xs, row, rep, out = _tp_specs(axis, _dp_axes(mesh, axis, x2d.shape[0]))
    return shard_map(
        lambda xl, ql, sl: _block_forward(
            xl, ql, sl, block_size, codebook, backend, tiles),
        mesh=mesh, in_specs=(xs, row, row), out_specs=out,
        check_rep=False,
    )(x2d, q_packed, s_blk)


def _shblock_fwd(x2d, q_packed, s_blk, block_size, codebook, backend, mesh,
                 axis, tiles):
    y = _shblock_qmatmul(x2d, q_packed, s_blk, block_size, codebook, backend,
                         mesh, axis, tiles)
    return y, (x2d, q_packed, s_blk)


def _shblock_bwd(block_size, codebook, backend, mesh, axis, tiles, res, g):
    x2d, q_packed, s_blk = res
    dp = _dp_axes(mesh, axis, x2d.shape[0])
    xs, row, rep, out = _tp_specs(axis, dp)

    def body(gl, xl, ql, sl):
        dx, ds = _block_grads(gl, xl, ql, sl, block_size, codebook, backend)
        return jax.lax.psum(dx, axis), _psum(ds, dp)

    dx, ds_blk = shard_map(
        body, mesh=mesh, in_specs=(out, xs, row, row),
        out_specs=(xs, row), check_rep=False,
    )(g, x2d, q_packed, s_blk)
    dq = np.zeros(q_packed.shape, jax.dtypes.float0)
    return dx.astype(x2d.dtype), dq, ds_blk.astype(s_blk.dtype)


_shblock_qmatmul.defvjp(_shblock_fwd, _shblock_bwd)


# ---------------------------------------------------------------------------
# Dense fallback — the legacy materialize-Ŵ path
# ---------------------------------------------------------------------------


def _dense_base(params, x2d, spec, n, m):
    from repro.core.lords import dequantize_weight

    w_hat = dequantize_weight(params, spec, n, m)
    return jnp.einsum("tk,nk->tn", x2d.astype(spec.compute_dtype), w_hat)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def _fused_supported(params: dict, spec) -> bool:
    method, mode = spec.method, spec.mode
    if "awq_s" in params:  # per-channel smoothing must be un-folded densely
        return False
    if method == "lords":
        return True
    if method == "blockwise":
        return mode != "qat"  # blockwise QAT trains s_blk through STE: dense
    if method in ("qlora", "loftq", "qpissa"):
        return True  # frozen block-quantized base + additive adapter
    return False


def _block_operands(params: dict, m: int):
    from repro.core.baselines import baseline_block_operands

    return baseline_block_operands(params, m)


def qmatmul(params: dict, x: jnp.ndarray, spec, n: int, m: int, *,
            backend: str | None = None,
            tiles: tuple[int, int, int] | None = None) -> jnp.ndarray:
    """y = x @ Ŵᵀ (+ additive adapter + bias) for any QuantSpec.

    ``x`` may carry arbitrary leading batch dims over the in-features axis
    ``m``; the result replaces that axis with ``n``.  Backend selection,
    padding, and differentiability are described in the module docstring.
    """
    backend = _resolve(backend)
    method, mode = spec.method, spec.mode
    cd = spec.compute_dtype
    lead = x.shape[:-1]
    x2d = x.reshape(-1, m)

    if backend == "dense" or not _fused_supported(params, spec):
        # also the 'none' method: a plain einsum on the unquantized weight
        # (GSPMD partitions it directly — no shard_map route needed)
        y2d = _dense_base(params, x2d, spec, n, m)
    elif method == "lords":
        xc = x2d.astype(cd)
        b = params["b"].astype(spec.ba_compute_dtype)
        a = params["a"].astype(spec.ba_compute_dtype)
        tp = _tp_shard(backend, n)
        if mode == "qat":
            if tp is not None:
                y2d = _shlords_qat_qmatmul(
                    xc, params["w"], b, a, spec.codebook, backend, *tp,
                    tiles)
            else:
                y2d = _lords_qat_qmatmul(
                    xc, params["w"], b, a, spec.codebook, backend, tiles)
        else:
            if tp is not None:
                y2d = _shlords_qmatmul(
                    xc, params["q"], b, a, spec.codebook, backend, *tp,
                    tiles)
            else:
                y2d = _lords_qmatmul(
                    xc, params["q"], b, a, spec.codebook, backend, tiles)
        y2d = y2d.astype(cd)
    else:  # blockwise base (also the qlora/loftq/qpissa frozen base)
        q_packed, s_blk, bs = _block_operands(params, m)
        tp = _tp_shard(backend, n)
        if tp is not None:
            y2d = _shblock_qmatmul(
                x2d.astype(cd), q_packed, s_blk, bs, spec.codebook,
                backend, *tp, tiles)
        else:
            y2d = _block_qmatmul(
                x2d.astype(cd), q_packed, s_blk, bs, spec.codebook,
                backend, tiles)
        y2d = y2d.astype(cd)

    if method in ("qlora", "loftq", "qpissa") and "lora_a" in params:
        # unmergeable additive adapter: y += x @ Aᵀ Bᵀ (the extra GEMM the
        # paper's Fig. 2 measures against LoRDS)
        xa = jnp.einsum("tk,rk->tr", x2d.astype(cd),
                        params["lora_a"].astype(cd))
        y2d = y2d + jnp.einsum("tr,nr->tn", xa, params["lora_b"].astype(cd))
    if "bias" in params:
        y2d = y2d + params["bias"].astype(y2d.dtype)
    return y2d.reshape(*lead, n)


# ---------------------------------------------------------------------------
# Fused attention dispatch (flash prefill + quantized-KV decode)
# ---------------------------------------------------------------------------
#
# ``qattention(kind, ...)`` is the attention analogue of :func:`qmatmul`:
# one entry point per hot attention shape, with the same backend precedence
# (explicit > backend_scope > env > platform), pad-to-tile, shard_scope
# integration, and autotuned tiles persisted through REPRO_AUTOTUNE_CACHE.
#
#   kind="prefill"     flash-style causal prefill (attn_prefill_pallas):
#                      q (b,s,nh,hd) · k/v (b,s,nkv,hd) unexpanded-GQA,
#                      ragged `positions` (b,s) mask, never materializes
#                      the (chunk, S) score matrix.  Differentiable: the
#                      custom VJP recomputes through the ref oracle (same
#                      peak memory as the rematerialized einsum path QAT /
#                      PEFT training already pays).
#   kind="decode"      fused GQA decode (attn_decode_gqa_pallas): the int8
#                      cache streams once at int8 width, per-(token, head)
#                      scales fold into the score/output dots in VMEM.
#   kind="mla_decode"  fused absorbed-latent MLA decode
#                      (attn_decode_mla_pallas): int8 latent + per-token
#                      scale, output is the weighted latent.
#   kind="chunk_prefill"
#                      the prefill kernel with *separate* q / key positions
#                      (q length != key length): chunk queries against the
#                      gathered prefix window + the raw in-flight chunk —
#                      the chunked-prefill step of the continuous-batching
#                      engine.  Serving-only: no VJP.
#   kind="paged_decode" / "paged_mla_decode"
#                      block-paged variants of the decode kinds: the KV
#                      lives in a global page pool (P, ps, ...) and a
#                      per-sequence page table (b, np) rides into the
#                      Pallas index maps as a scalar-prefetch operand — the
#                      int8 pool streams once, as stored, no gather into a
#                      contiguous temp (the ref oracles *do* gather; that
#                      gather is the jaxpr-guard negative control).
#
# Sharding: attention is head-local and batch-local, so inside a
# shard_scope the fused kernels run under shard_map with heads on the
# 'model' axis and the batch on the data axes — psum-free in both
# directions.  Head counts that don't divide the model axis fall back to
# the unsharded call (GSPMD handles the ref path directly).

_ATTN_CODEBOOK = "attn"     # codebook slot of attention autotune keys
_ATTN_KINDS = ("prefill", "chunk_prefill", "decode", "mla_decode",
               "paged_decode", "paged_mla_decode")
_ATTN_METHOD = {"prefill": "attn_prefill", "chunk_prefill": "attn_chunk",
                "decode": "attn_gqa", "mla_decode": "attn_mla",
                "paged_decode": "attn_gqa_paged",
                "paged_mla_decode": "attn_mla_paged"}


def attn_tile_for(kind: str, seq: int, heads: int, depth: int, kv_dtype,
                  default: tuple[int, int]) -> tuple[int, int]:
    """(row-tile, kv-tile) for an attention launch: autotune-table hit under
    the shared key machinery (method ``attn_*``, codebook ``"attn"``, dtype
    = the *cache* dtype so int8 and bf16 caches tune independently), else
    ``default``.  Triples in the table carry a trailing 1 (the bk slot is
    meaningless for attention)."""
    hit = lookup_tiles(_ATTN_METHOD[kind], seq, heads, depth,
                       _ATTN_CODEBOOK, kv_dtype)
    if hit is not None:
        return hit[0], hit[1]
    return default


def _attn_shard(backend: str, nh: int, nkv: int) -> tuple | None:
    """Shard route for a head-local attention call: active scope + fused
    backend + both head counts divide the model axis."""
    sh = shard_info()
    if sh is None or backend not in _FUSED:
        return None
    mesh, axis = sh
    tp = dict(mesh.shape)[axis]
    if nh % tp or nkv % tp:
        return None
    return sh


def _decode_kmask(pos, cap: int):
    """(b, S) additive liveness mask: 0 where the cache slot is live
    (index <= pos, covering padded slots too since pos < S), NEG_INF else."""
    live = jnp.arange(cap, dtype=jnp.int32)[None, :] <= pos[:, None]
    return jnp.where(live, 0.0, ref.ATTN_NEG_INF).astype(jnp.float32)


def _pad_axis(arr, axis: int, to: int, value=0):
    pad = to - arr.shape[axis]
    if pad == 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return jnp.pad(arr, widths, constant_values=value)


# ---- prefill ----


def _attn_prefill_run(q, k, v, positions, logit_scale, backend, tiles):
    """Pad-to-tile + flash kernel, all in the model's native layouts.
    q (b,s,nh,hd), k/v (b,s,nkv,hd), positions (b,s) → (b,s,nh,hdv) f32."""
    b, s, nh, hd = q.shape
    nkv = k.shape[2]
    bq, bkv = tiles or attn_tile_for(
        "prefill", s, nh, hd, k.dtype, (128, 128))
    bq, bkv = min(bq, _round_up(s, 8)), min(bkv, _round_up(s, 8))
    sq, skv = _round_up(s, bq), _round_up(s, bkv)
    qt = _pad_axis(q, 1, sq)
    kt = _pad_axis(k, 1, skv)
    vt = _pad_axis(v, 1, skv)
    qpos = _pad_axis(positions, 1, sq, value=-1)
    kpos = _pad_axis(positions, 1, skv, value=-1)
    y = attn_prefill_pallas(
        qt, kt, vt, qpos, kpos, logit_scale=float(logit_scale),
        bq=bq, bkv=bkv, interpret=(backend == "interpret"))
    return y[:, :s]


def _attn_prefill_fused(q, k, v, positions, logit_scale, backend, tiles):
    tp = _attn_shard(backend, q.shape[2], k.shape[2])
    if tp is None:
        return _attn_prefill_run(q, k, v, positions, logit_scale, backend,
                                 tiles)
    mesh, axis = tp
    dp = _dp_axes(mesh, axis, q.shape[0])
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    hspec = PartitionSpec(bspec, None, axis, None)
    pspec = PartitionSpec(bspec, None)
    return shard_map(
        lambda ql, kl, vl, pl_: _attn_prefill_run(
            ql, kl, vl, pl_, logit_scale, backend, tiles),
        mesh=mesh, in_specs=(hspec, hspec, hspec, pspec), out_specs=hspec,
        check_rep=False,
    )(q, k, v, positions)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _attn_prefill_qdisp(q, k, v, positions, logit_scale, backend, tiles):
    return _attn_prefill_fused(q, k, v, positions, logit_scale, backend,
                               tiles)


def _attn_prefill_fwd(q, k, v, positions, logit_scale, backend, tiles):
    y = _attn_prefill_fused(q, k, v, positions, logit_scale, backend, tiles)
    return y, (q, k, v, positions)


def _attn_prefill_bwd(logit_scale, backend, tiles, res, g):
    # backward recomputes through the materializing oracle — attention
    # training cost matches the rematerialized einsum path; the fused
    # kernel is the *serving* fast path (decode never differentiates)
    q, k, v, positions = res
    _, vjp = jax.vjp(
        lambda qq, kk, vv: ref.attn_prefill_ref(qq, kk, vv, positions,
                                                float(logit_scale)),
        q, k, v)
    dq, dk, dv = vjp(g.astype(jnp.float32))
    dpos = np.zeros(positions.shape, jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            dpos)


_attn_prefill_qdisp.defvjp(_attn_prefill_fwd, _attn_prefill_bwd)


# ---- chunked prefill (q length != key length) ----


def _attn_chunk_run(q, k, v, qpos, kpos, logit_scale, backend, tiles):
    """q (b,s,nh,hd) at qpos (b,s) vs k/v (b,S,nkv,hd) at kpos (b,S) →
    (b,s,nh,hdv) f32.  Same kernel as prefill — the flash kernel already
    takes separate query/key position arrays; only the padding differs
    (q and kv lengths round up to their tiles independently)."""
    b, s, nh, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    bq, bkv = tiles or attn_tile_for(
        "chunk_prefill", skv, nh, hd, k.dtype, (128, 128))
    bq = min(bq, _round_up(s, 8))
    bkv = min(bkv, _round_up(skv, 8))
    sq, sk = _round_up(s, bq), _round_up(skv, bkv)
    qt = _pad_axis(q, 1, sq)
    kt = _pad_axis(k, 1, sk)
    vt = _pad_axis(v, 1, sk)
    qp = _pad_axis(qpos, 1, sq, value=-1)
    kp = _pad_axis(kpos, 1, sk, value=-1)
    y = attn_prefill_pallas(
        qt, kt, vt, qp, kp, logit_scale=float(logit_scale),
        bq=bq, bkv=bkv, interpret=(backend == "interpret"))
    return y[:, :s]


def _attn_chunk_fused(q, k, v, qpos, kpos, logit_scale, backend, tiles):
    tp = _attn_shard(backend, q.shape[2], k.shape[2])
    if tp is None:
        return _attn_chunk_run(q, k, v, qpos, kpos, logit_scale, backend,
                               tiles)
    mesh, axis = tp
    dp = _dp_axes(mesh, axis, q.shape[0])
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    hspec = PartitionSpec(bspec, None, axis, None)
    pspec = PartitionSpec(bspec, None)
    return shard_map(
        lambda ql, kl, vl, qpl, kpl: _attn_chunk_run(
            ql, kl, vl, qpl, kpl, logit_scale, backend, tiles),
        mesh=mesh, in_specs=(hspec, hspec, hspec, pspec, pspec),
        out_specs=hspec, check_rep=False,
    )(q, k, v, qpos, kpos)


# ---- GQA decode ----


def _attn_decode_run(q, k, v, pos, k_scale, v_scale, logit_scale, backend,
                     tiles):
    """q (b,nh,hd) vs cache (b,S,nkv,hd) [+ scales (b,S,nkv)] →
    (b,nh,hdv) f32.  The cache operands go to the kernel in their stored
    layout (the index maps slice per-head tiles) — a transpose here would
    make XLA copy the whole cache every decode step."""
    b, nh, hd = q.shape
    cap, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    _, bs = tiles or attn_tile_for(
        "decode", cap, nh, hd, k.dtype, (DECODE_ROWS, 128))
    bs = min(bs, _round_up(cap, 8))
    capp = _round_up(cap, bs)
    g8 = _round_up(g, DECODE_ROWS)
    qg = _pad_axis(q.reshape(b, nkv, g, hd), 2, g8)
    kt = _pad_axis(k, 1, capp)
    vt = _pad_axis(v, 1, capp)
    kst = vst = None
    if k_scale is not None:
        kst = _pad_axis(k_scale, 1, capp)
        vst = _pad_axis(v_scale, 1, capp)
    y = attn_decode_gqa_pallas(
        qg, kt, vt, _decode_kmask(pos, capp), kst, vst,
        logit_scale=float(logit_scale), bs=bs,
        interpret=(backend == "interpret"))
    return y[:, :, :g].reshape(b, nh, v.shape[-1])


def _attn_decode_fused(q, k, v, pos, k_scale, v_scale, logit_scale, backend,
                       tiles):
    tp = _attn_shard(backend, q.shape[1], k.shape[2])
    if tp is None:
        return _attn_decode_run(q, k, v, pos, k_scale, v_scale, logit_scale,
                                backend, tiles)
    mesh, axis = tp
    dp = _dp_axes(mesh, axis, q.shape[0])
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    qspec = PartitionSpec(bspec, axis, None)
    cspec = PartitionSpec(bspec, None, axis, None)
    sspec = PartitionSpec(bspec, None, axis)
    pspec = PartitionSpec(bspec)

    def body(ql, kl, vl, posl, ksl, vsl):
        return _attn_decode_run(ql, kl, vl, posl, ksl, vsl, logit_scale,
                                backend, tiles)

    if k_scale is None:
        return shard_map(
            lambda ql, kl, vl, posl: body(ql, kl, vl, posl, None, None),
            mesh=mesh, in_specs=(qspec, cspec, cspec, pspec),
            out_specs=qspec, check_rep=False,
        )(q, k, v, pos)
    return shard_map(
        body, mesh=mesh,
        in_specs=(qspec, cspec, cspec, pspec, sspec, sspec),
        out_specs=qspec, check_rep=False,
    )(q, k, v, pos, k_scale, v_scale)


# ---- MLA decode ----


def _attn_mla_run(q_lat, q_rope, c, k_rope, pos, c_scale, logit_scale,
                  backend, tiles):
    """q_lat (b,nh,L) / q_rope (b,nh,R) vs c (b,S,L) + k_rope (b,S,R)
    [+ c_scale (b,S)] → weighted latent (b,nh,L) f32."""
    b, nh, lat = q_lat.shape
    cap = c.shape[1]
    _, bs = tiles or attn_tile_for(
        "mla_decode", cap, nh, lat, c.dtype, (DECODE_ROWS, 128))
    bs = min(bs, _round_up(cap, 8))
    capp = _round_up(cap, bs)
    nh8 = _round_up(nh, DECODE_ROWS)
    qlp = _pad_axis(q_lat, 1, nh8)
    qrp = _pad_axis(q_rope, 1, nh8)
    cp = _pad_axis(c, 1, capp)
    krp = _pad_axis(k_rope, 1, capp)
    csp = None if c_scale is None else _pad_axis(c_scale, 1, capp)
    y = attn_decode_mla_pallas(
        qlp, qrp, cp, krp, _decode_kmask(pos, capp), csp,
        logit_scale=float(logit_scale), bs=bs,
        interpret=(backend == "interpret"))
    return y[:, :nh]


def _attn_mla_fused(q_lat, q_rope, c, k_rope, pos, c_scale, logit_scale,
                    backend, tiles):
    tp = _attn_shard(backend, q_lat.shape[1], q_lat.shape[1])
    if tp is None:
        return _attn_mla_run(q_lat, q_rope, c, k_rope, pos, c_scale,
                             logit_scale, backend, tiles)
    mesh, axis = tp
    dp = _dp_axes(mesh, axis, q_lat.shape[0])
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    qspec = PartitionSpec(bspec, axis, None)    # heads shard
    cspec = PartitionSpec(bspec, None, None)    # latent cache replicates
    sspec = PartitionSpec(bspec, None)
    pspec = PartitionSpec(bspec)

    def body(qll, qrl, cl, krl, posl, csl):
        return _attn_mla_run(qll, qrl, cl, krl, posl, csl, logit_scale,
                             backend, tiles)

    if c_scale is None:
        return shard_map(
            lambda qll, qrl, cl, krl, posl: body(qll, qrl, cl, krl, posl,
                                                 None),
            mesh=mesh, in_specs=(qspec, qspec, cspec, cspec, pspec),
            out_specs=qspec, check_rep=False,
        )(q_lat, q_rope, c, k_rope, pos)
    return shard_map(
        body, mesh=mesh,
        in_specs=(qspec, qspec, cspec, cspec, pspec, sspec),
        out_specs=qspec, check_rep=False,
    )(q_lat, q_rope, c, k_rope, pos, c_scale)


# ---- paged GQA decode ----


def _attn_paged_run(q, k_pool, v_pool, pt, pos, k_scale, v_scale,
                    logit_scale, backend):
    """q (b,nh,hd) vs page pools (P,ps,nkv,hd) [+ scale pools (P,ps,nkv)]
    through the page table pt (b,np) → (b,nh,hdv) f32.  The kv tile is the
    page — no tile padding of the pool, and no gather: pt rides into the
    kernel's index maps."""
    b, nh, hd = q.shape
    ps, nkv = k_pool.shape[1], k_pool.shape[2]
    g = nh // nkv
    g8 = _round_up(g, DECODE_ROWS)
    qg = _pad_axis(q.reshape(b, nkv, g, hd), 2, g8)
    cap = pt.shape[1] * ps
    y = attn_decode_gqa_paged_pallas(
        pt, qg, k_pool, v_pool, _decode_kmask(pos, cap), k_scale, v_scale,
        logit_scale=float(logit_scale), interpret=(backend == "interpret"))
    return y[:, :, :g].reshape(b, nh, v_pool.shape[-1])


def _attn_paged_fused(q, k_pool, v_pool, pt, pos, k_scale, v_scale,
                      logit_scale, backend):
    tp = _attn_shard(backend, q.shape[1], k_pool.shape[2])
    if tp is None:
        return _attn_paged_run(q, k_pool, v_pool, pt, pos, k_scale, v_scale,
                               logit_scale, backend)
    mesh, axis = tp
    dp = _dp_axes(mesh, axis, q.shape[0])
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    qspec = PartitionSpec(bspec, axis, None)
    # the pool is global (slots share it): kv heads shard on the model
    # axis exactly like the contiguous cache, pages replicate over data
    poolspec = PartitionSpec(None, None, axis, None)
    spoolspec = PartitionSpec(None, None, axis)
    ptspec = PartitionSpec(bspec, None)
    pspec = PartitionSpec(bspec)

    def body(ql, kl, vl, ptl, posl, ksl, vsl):
        return _attn_paged_run(ql, kl, vl, ptl, posl, ksl, vsl, logit_scale,
                               backend)

    if k_scale is None:
        return shard_map(
            lambda ql, kl, vl, ptl, posl: body(ql, kl, vl, ptl, posl, None,
                                               None),
            mesh=mesh, in_specs=(qspec, poolspec, poolspec, ptspec, pspec),
            out_specs=qspec, check_rep=False,
        )(q, k_pool, v_pool, pt, pos)
    return shard_map(
        body, mesh=mesh,
        in_specs=(qspec, poolspec, poolspec, ptspec, pspec, spoolspec,
                  spoolspec),
        out_specs=qspec, check_rep=False,
    )(q, k_pool, v_pool, pt, pos, k_scale, v_scale)


# ---- paged MLA decode ----


def _attn_mla_paged_run(q_lat, q_rope, c_pool, k_rope_pool, pt, pos,
                        c_scale, logit_scale, backend):
    """q_lat (b,nh,L) / q_rope (b,nh,R) vs c_pool (P,ps,L) +
    k_rope_pool (P,ps,R) [+ c_scale pool (P,ps)] through pt (b,np) →
    weighted latent (b,nh,L) f32."""
    b, nh, _ = q_lat.shape
    ps = c_pool.shape[1]
    nh8 = _round_up(nh, DECODE_ROWS)
    qlp = _pad_axis(q_lat, 1, nh8)
    qrp = _pad_axis(q_rope, 1, nh8)
    cap = pt.shape[1] * ps
    y = attn_decode_mla_paged_pallas(
        pt, qlp, qrp, c_pool, k_rope_pool, _decode_kmask(pos, cap), c_scale,
        logit_scale=float(logit_scale), interpret=(backend == "interpret"))
    return y[:, :nh]


def _attn_mla_paged_fused(q_lat, q_rope, c_pool, k_rope_pool, pt, pos,
                          c_scale, logit_scale, backend):
    tp = _attn_shard(backend, q_lat.shape[1], q_lat.shape[1])
    if tp is None:
        return _attn_mla_paged_run(q_lat, q_rope, c_pool, k_rope_pool, pt,
                                   pos, c_scale, logit_scale, backend)
    mesh, axis = tp
    dp = _dp_axes(mesh, axis, q_lat.shape[0])
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    qspec = PartitionSpec(bspec, axis, None)    # heads shard
    poolspec = PartitionSpec(None, None, None)  # latent pool replicates
    spoolspec = PartitionSpec(None, None)
    ptspec = PartitionSpec(bspec, None)
    pspec = PartitionSpec(bspec)

    def body(qll, qrl, cl, krl, ptl, posl, csl):
        return _attn_mla_paged_run(qll, qrl, cl, krl, ptl, posl, csl,
                                   logit_scale, backend)

    if c_scale is None:
        return shard_map(
            lambda qll, qrl, cl, krl, ptl, posl: body(qll, qrl, cl, krl,
                                                      ptl, posl, None),
            mesh=mesh,
            in_specs=(qspec, qspec, poolspec, poolspec, ptspec, pspec),
            out_specs=qspec, check_rep=False,
        )(q_lat, q_rope, c_pool, k_rope_pool, pt, pos)
    return shard_map(
        body, mesh=mesh,
        in_specs=(qspec, qspec, poolspec, poolspec, ptspec, pspec,
                  spoolspec),
        out_specs=qspec, check_rep=False,
    )(q_lat, q_rope, c_pool, k_rope_pool, pt, pos, c_scale)


# ---- public entry point ----


def qattention(kind: str, *args, logit_scale: float,
               backend: str | None = None,
               tiles: tuple[int, int] | None = None) -> jnp.ndarray:
    """Unified fused-attention entry point (see the section comment).

    kind="prefill":       qattention("prefill", q, k, v, positions, ...)
    kind="chunk_prefill": qattention("chunk_prefill", q, k, v, qpos,
                                     kpos, ...)
    kind="decode":        qattention("decode", q, k, v, pos,
                                     k_scale=None, v_scale=None, ...)
    kind="mla_decode":    qattention("mla_decode", q_lat, q_rope, c,
                                     k_rope, pos, c_scale=None, ...)
    kind="paged_decode":  qattention("paged_decode", q, k_pool, v_pool,
                                     pt, pos, k_scale=None,
                                     v_scale=None, ...)
    kind="paged_mla_decode":
                          qattention("paged_mla_decode", q_lat, q_rope,
                                     c_pool, k_rope_pool, pt, pos,
                                     c_scale=None, ...)

    Fused backends (pallas/interpret) run the Pallas kernels with
    pad-to-tile and optional shard_map; ``ref``/``dense`` run the
    materializing oracles from :mod:`repro.kernels.ref` — numerically the
    same contract, and the parity reference the tests pin the kernels to.
    Results are f32; callers cast.
    """
    if kind not in _ATTN_KINDS:
        raise ValueError(f"unknown attention kind {kind!r}; "
                         f"expected one of {_ATTN_KINDS}")
    backend = _resolve(backend)
    if kind == "prefill":
        q, k, v, positions = args
        if backend in _FUSED:
            return _attn_prefill_qdisp(q, k, v, positions,
                                       float(logit_scale), backend, tiles)
        return ref.attn_prefill_ref(q, k, v, positions, float(logit_scale))
    if kind == "chunk_prefill":
        q, k, v, qpos, kpos = args
        if backend in _FUSED:
            return _attn_chunk_fused(q, k, v, qpos, kpos,
                                     float(logit_scale), backend, tiles)
        return ref.attn_chunk_prefill_ref(q, k, v, qpos, kpos,
                                          float(logit_scale))
    if kind == "paged_decode":
        q, k_pool, v_pool, pt, pos = args[:5]
        k_scale = args[5] if len(args) > 5 else None
        v_scale = args[6] if len(args) > 6 else None
        if backend in _FUSED:
            return _attn_paged_fused(q, k_pool, v_pool, pt, pos, k_scale,
                                     v_scale, float(logit_scale), backend)
        return ref.attn_decode_paged_ref(pt, q, k_pool, v_pool, pos,
                                         k_scale, v_scale,
                                         float(logit_scale))
    if kind == "paged_mla_decode":
        q_lat, q_rope, c_pool, k_rope_pool, pt, pos = args[:6]
        c_scale = args[6] if len(args) > 6 else None
        if backend in _FUSED:
            return _attn_mla_paged_fused(q_lat, q_rope, c_pool, k_rope_pool,
                                         pt, pos, c_scale,
                                         float(logit_scale), backend)
        return ref.attn_mla_decode_paged_ref(pt, q_lat, q_rope, c_pool,
                                             k_rope_pool, pos, c_scale,
                                             float(logit_scale))
    if kind == "decode":
        q, k, v, pos = args[:4]
        k_scale = args[4] if len(args) > 4 else None
        v_scale = args[5] if len(args) > 5 else None
        if backend in _FUSED:
            return _attn_decode_fused(q, k, v, pos, k_scale, v_scale,
                                      float(logit_scale), backend, tiles)
        return ref.attn_decode_ref(q, k, v, pos, k_scale, v_scale,
                                   float(logit_scale))
    q_lat, q_rope, c, k_rope, pos = args[:5]
    c_scale = args[5] if len(args) > 5 else None
    if backend in _FUSED:
        return _attn_mla_fused(q_lat, q_rope, c, k_rope, pos, c_scale,
                               float(logit_scale), backend, tiles)
    return ref.attn_mla_decode_ref(q_lat, q_rope, c, k_rope, pos, c_scale,
                                   float(logit_scale))


_ATTN_CANDIDATES = {
    "prefill": ((128, 128), (128, 256), (256, 128), (64, 128), (128, 512)),
    "chunk_prefill": ((128, 128), (128, 256), (64, 128), (64, 256),
                      (128, 512)),
    "decode": ((DECODE_ROWS, 128), (DECODE_ROWS, 256), (DECODE_ROWS, 512)),
    "mla_decode": ((DECODE_ROWS, 128), (DECODE_ROWS, 256),
                   (DECODE_ROWS, 512)),
    # paged decode has no tile freedom (the kv tile IS the page size); a
    # single sentinel candidate still times + registers the autotune key so
    # paged launches are attributable in the persisted table
    "paged_decode": ((DECODE_ROWS, 0),),
    "paged_mla_decode": ((DECODE_ROWS, 0),),
}


def autotune_qattention(kind: str, *args, logit_scale: float,
                        backend: str | None = None, candidates=None,
                        iters: int = 3):
    """Time candidate (row-tile, kv-tile) pairs through :func:`qattention`
    and register the winner under the attention autotune key (persisted via
    ``REPRO_AUTOTUNE_CACHE`` like every other entry).  Returns
    ``(best, {tiles: seconds})``; ``(None, {})`` off the fused backends.
    """
    backend = _resolve(backend)
    if backend not in _FUSED:
        return None, {}
    if kind == "prefill":
        q, k = args[0], args[1]
        seq, heads, depth, kv_dtype = q.shape[1], q.shape[2], q.shape[3], \
            k.dtype
    elif kind == "chunk_prefill":
        q, k = args[0], args[1]
        seq, heads, depth, kv_dtype = k.shape[1], q.shape[2], q.shape[3], \
            k.dtype
    elif kind == "decode":
        q, k = args[0], args[1]
        seq, heads, depth, kv_dtype = k.shape[1], q.shape[1], q.shape[2], \
            k.dtype
    elif kind == "paged_decode":
        q, k_pool, pt = args[0], args[1], args[3]
        seq = pt.shape[1] * k_pool.shape[1]
        heads, depth, kv_dtype = q.shape[1], q.shape[2], k_pool.dtype
    elif kind == "paged_mla_decode":
        q_lat, c_pool, pt = args[0], args[2], args[4]
        seq = pt.shape[1] * c_pool.shape[1]
        heads, depth, kv_dtype = q_lat.shape[1], q_lat.shape[2], c_pool.dtype
    else:
        q_lat, c = args[0], args[2]
        seq, heads, depth, kv_dtype = c.shape[1], q_lat.shape[1], \
            q_lat.shape[2], c.dtype
    timings: dict[tuple, float] = {}
    for cand in candidates or _ATTN_CANDIDATES[kind]:
        fn = jax.jit(lambda *a, c=tuple(cand): qattention(
            kind, *a, logit_scale=logit_scale, backend=backend, tiles=c))
        try:
            fn(*args).block_until_ready()
        except (ValueError, jax.errors.JaxRuntimeError):
            continue
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(*args).block_until_ready()
        timings[tuple(cand)] = (time.perf_counter() - t0) / iters
    if not timings:
        return None, {}
    best = min(timings, key=timings.get)
    register_tiles(_ATTN_METHOD[kind], seq, heads, depth, _ATTN_CODEBOOK,
                   kv_dtype, (best[0], best[1], 1))
    save_autotune_table()
    return best, timings


# ---------------------------------------------------------------------------
# Autotuner (consulted by benchmarks/bench_kernels.py)
# ---------------------------------------------------------------------------

_DEFAULT_CANDIDATES = (
    (128, 256, 512), (128, 128, 512), (128, 256, 256),
    (64, 128, 256), (32, 128, 512), (8, 128, 256),
)


def autotune_qmatmul(params, x, spec, n, m, *, backend=None,
                     candidates=None, iters: int = 3):
    """Time candidate tilings through :func:`qmatmul`, register the winner.

    Returns ``(best_tiles, {tiles: seconds})``.  On the ``ref``/``dense``
    backends there is nothing to tune — returns ``(None, {})``.  Lookups are
    trace-time: autotune before jitting the consumer of the table.
    """
    backend = _resolve(backend)
    if backend not in _FUSED or not _fused_supported(params, spec):
        return None, {}  # nothing fused to tune (dense/ref path ignores tiles)
    method = "blockwise" if spec.method != "lords" else "lords"
    kdim = x.shape[-1]
    bs = None
    if method == "blockwise":
        bs = _block_operands(params, m)[2]
    timings: dict[tuple, float] = {}
    mdim = int(np.prod(x.shape[:-1]))
    # fused forwards run (and look tiles up) in compute dtype, not x.dtype
    key_dtype = jnp.dtype(spec.compute_dtype)
    for cand in candidates or _DEFAULT_CANDIDATES:
        bm, bn, bk = cand
        if bs is not None and bk % bs and bs % bk:
            continue
        fn = jax.jit(lambda xx, c=cand: qmatmul(
            params, xx, spec, n, m, backend=backend, tiles=c))
        try:
            fn(x).block_until_ready()  # compile + warm
        except (ValueError, jax.errors.JaxRuntimeError):
            # tiling rejected by the kernel's shape checks (ValueError) or by
            # the Mosaic/XLA compiler-runtime on device: skip this candidate
            continue
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(x).block_until_ready()
        timings[cand] = (time.perf_counter() - t0) / iters
    if not timings:
        return None, {}
    best = min(timings, key=timings.get)
    register_tiles(method, mdim, n, kdim, spec.codebook, key_dtype, best,
                   block_size=bs)
    save_autotune_table()  # no-op unless REPRO_AUTOTUNE_CACHE is set
    return best, timings


def _diff_keys(spec) -> tuple[str, ...]:
    """Param keys that receive gradients through the fused VJPs."""
    if spec.method == "lords":
        return ("w", "b", "a") if spec.mode == "qat" else ("b", "a")
    return ("s_blk",)


def autotune_qmatmul_bwd(params, x, spec, n, m, *, backend=None,
                         candidates=None, iters: int = 3):
    """Tune the fused *backward* kernels (transposed matmul + grad
    reduction) by timing ``jax.grad`` through :func:`qmatmul` with each
    candidate registered under the transposed key (``lords_t`` /
    ``blockwise_t``), then register the winner.  Entries persist through
    the same ``REPRO_AUTOTUNE_CACHE`` file as forward tiles.

    Returns ``(best_tiles, {tiles: seconds})``; ``(None, {})`` when the
    spec has no fused path or the backend isn't fused.
    """
    backend = _resolve(backend)
    if backend not in _FUSED or not _fused_supported(params, spec):
        return None, {}
    method = "lords_t" if spec.method == "lords" else "blockwise_t"
    kdim = x.shape[-1]
    mdim = int(np.prod(x.shape[:-1]))
    bs = None
    if method == "blockwise_t":
        bs = _block_operands(params, m)[2]
    keys = _diff_keys(spec)
    operands = tuple(params[kk] for kk in keys)
    key_dtype = jnp.float32  # backward kernels always accumulate in f32
    # candidates are staged into the live table; remember any pre-existing
    # entry (cache-loaded or previously tuned) so total failure restores it
    prev = lookup_tiles(method, mdim, n, kdim, spec.codebook, key_dtype, bs)

    def loss(t, xx):
        p = dict(params, **dict(zip(keys, t)))
        return jnp.sum(qmatmul(p, xx, spec, n, m, backend=backend) ** 2)

    timings: dict[tuple, float] = {}
    for cand in candidates or _DEFAULT_CANDIDATES:
        bm, bn, bk = cand
        if bs is not None and bk % bs and bs % bk:
            continue
        # the bwd consults the table at trace time: stage the candidate,
        # trace, and drop it again if the kernels reject the tiling
        register_tiles(method, mdim, n, kdim, spec.codebook, key_dtype, cand,
                       block_size=bs)
        fn = jax.jit(jax.grad(loss, argnums=(0, 1)))
        try:
            jax.block_until_ready(fn(operands, x))
        except (ValueError, jax.errors.JaxRuntimeError):
            _AUTOTUNE.pop(
                autotune_key(method, mdim, n, kdim, spec.codebook, key_dtype,
                             bs), None)
            continue
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(operands, x))
        timings[cand] = (time.perf_counter() - t0) / iters
    if not timings:
        if prev is not None:
            register_tiles(method, mdim, n, kdim, spec.codebook, key_dtype,
                           prev, block_size=bs)
        return None, {}
    best = min(timings, key=timings.get)
    register_tiles(method, mdim, n, kdim, spec.codebook, key_dtype, best,
                   block_size=bs)
    save_autotune_table()
    return best, timings
