"""LR schedules (paper: cosine+linear-warmup for QAT, linear for PEFT)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_warmup", "linear_warmup", "constant"]


def constant(peak_lr: float):
    return lambda step: jnp.asarray(peak_lr, jnp.float32)


def linear_warmup(peak_lr: float, total_steps: int, warmup_ratio: float = 0.0):
    warm = max(int(total_steps * warmup_ratio), 1)

    def fn(step):
        step = step.astype(jnp.float32)
        wu = jnp.minimum(step / warm, 1.0)
        decay = jnp.maximum(0.0, 1.0 - jnp.maximum(step - warm, 0.0)
                            / max(total_steps - warm, 1))
        return peak_lr * wu * decay

    return fn


def cosine_warmup(peak_lr: float, total_steps: int, warmup_ratio: float = 0.3,
                  final_frac: float = 0.0):
    """Paper's QAT recipe: cosine schedule with linear warmup (ratio 0.3)."""
    warm = max(int(total_steps * warmup_ratio), 1)

    def fn(step):
        step = step.astype(jnp.float32)
        wu = jnp.minimum(step / warm, 1.0)
        prog = jnp.clip((step - warm) / max(total_steps - warm, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * wu * cos

    return fn
