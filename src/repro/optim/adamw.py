"""AdamW (decoupled weight decay) over pytrees — handles None holes from
``repro.core.peft.partition`` (holes are empty subtrees; maps skip them).

State layout mirrors the param tree: {mu, nu, step}.  All moments f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "guarded_update"]


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    step: jnp.ndarray


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip_norm: float | None = 1.0,
):
    """-> (new_params, new_state).  ``lr`` may be a scalar or traced value."""
    step = state.step + 1

    if grad_clip_norm is not None:
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)
        ) + 1e-12)
        scale = jnp.minimum(1.0, grad_clip_norm / gnorm)
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gnorm = jnp.zeros(())

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * g
        nu_n = b2 * nu + (1 - b2) * g * g
        mu_hat = mu_n / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu_n / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + eps)
        p32 = p.astype(jnp.float32)
        p_n = p32 - lr * (delta + weight_decay * p32)
        return p_n.astype(p.dtype), mu_n, nu_n

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    # out is a tree of 3-tuples; unzip
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_mu, new_nu, step), gnorm


def guarded_update(params, grads, state: AdamWState, lr, max_gnorm,
                   **adamw_kwargs):
    """:func:`adamw_update` behind a non-finite / spike guard.

    -> (new_params, new_state, gnorm, applied).  When the (pre-clip) grad
    norm is non-finite or exceeds ``max_gnorm`` the step is *skipped*
    in-graph: params, both moments and the step counter all keep their old
    values exactly (``jnp.where`` on every leaf), so one poisoned batch can
    never write NaNs into the optimizer state.  ``applied`` is a scalar
    bool the host loop uses for consecutive-skip counting and checkpoint
    rollback.  With finite grads under the threshold the output is bitwise
    ``adamw_update``.
    """
    new_params, new_state, gnorm = adamw_update(params, grads, state, lr,
                                                **adamw_kwargs)
    ok = jnp.isfinite(gnorm) & (gnorm <= max_gnorm)

    def pick(new, old):
        return jnp.where(ok, new, old)

    new_params = jax.tree.map(pick, new_params, params)
    new_state = AdamWState(
        mu=jax.tree.map(pick, new_state.mu, state.mu),
        nu=jax.tree.map(pick, new_state.nu, state.nu),
        step=jnp.where(ok, new_state.step, state.step),
    )
    return new_params, new_state, gnorm, ok
