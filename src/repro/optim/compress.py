"""int8 error-feedback gradient compression for cross-pod all-reduce.

Beyond-paper distributed optimization: with LoRDS-PEFT the DP gradient
payload is only (B, A) — already ~1-3% of a full model — and this shrinks the
cross-pod (slowest-link) traffic another 4× by all-reducing int8-quantized
gradients with per-tensor scales and local error feedback (residual carried
to the next step, so compression noise doesn't bias the optimizer:
Seide et al. 2014 / Karimireddy et al. 2019 semantics).

Usage inside a pjit step (SPMD-visible compression):
    g_q, scale, new_resid = compress(g + resid)
    g_sync = psum(g_q * scale) / n      # int8 payload crosses the pod axis
Here we expose the quantize/dequantize halves; the collective itself is
whatever GSPMD inserts for the sharded->replicated transition of the packed
tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_compress", "ef_decompress", "ef_state_init"]


def ef_state_init(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _q_one(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads, resid):
    """-> (int8 tree, scale tree, new residual tree)."""
    acc = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, resid)
    qs = jax.tree.map(_q_one, acc, is_leaf=lambda x: hasattr(x, "shape"))
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si, q, s)
    new_resid = jax.tree.map(lambda a, d: a - d, acc, deq)
    return q, s, new_resid


def ef_decompress(q, s):
    return jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si, q, s)
