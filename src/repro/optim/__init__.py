"""repro.optim — AdamW, schedules, gradient accumulation & compression."""
from repro.optim.adamw import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    guarded_update,
)
from repro.optim.compress import (  # noqa: F401
    ef_compress,
    ef_decompress,
    ef_state_init,
)
from repro.optim.schedule import constant, cosine_warmup, linear_warmup  # noqa: F401
