"""Cross-replica desync detection for long sharded runs.

Silent replica divergence (bit-flips, non-deterministic kernels, a host
running stale code) is invisible to the loss curve until the run is ruined.
The guard here is a cheap periodic *state digest*: a single-scalar reduction
over the full (trainable, optimizer) pytree, computed in-graph so the sum is
psum'd across whatever mesh the state is sharded over.  Every replica must
agree on it bit-for-bit; any spread means the replicas have silently
diverged and the run is quarantined and rolled back to the last checkpoint.

Under this repo's single-controller SPMD harness (8 forced host devices) a
*real* divergence cannot occur — XLA computes one program — so, exactly like
``train.grad_spike``, the ``dist.replica_desync`` fault point forces the
detector's *input* (one replica's reported digest is perturbed) and the
detection → quarantine → rollback machinery runs for real.  On a true
multi-controller deployment the per-process digest report is the same code
path; only the transport differs.

Digest cost: two fused reductions per leaf, launched every ``digest_every``
steps — amortized noise next to a train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["tree_digest", "replica_digests", "desync_spread", "DesyncError"]


class DesyncError(RuntimeError):
    """Raised (or recorded) when replica digests disagree."""


@jax.jit
def tree_digest(tree) -> jax.Array:
    """Single-scalar f32 digest of a pytree, sensitive to sign and
    magnitude drift: sum of |x| plus sum of x² per leaf, folded in
    deterministic leaf order.  Runs in-graph: on a sharded tree XLA emits
    the cross-device reduction (the psum), so the scalar is the *global*
    state digest every replica must agree on."""
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(tree):
        x = jnp.asarray(leaf).astype(jnp.float32)
        total = total + jnp.sum(jnp.abs(x)) + jnp.sum(x * x)
    return total


def replica_digests(tree, n_replicas: int, *, faults=None,
                    step: int = 0) -> np.ndarray:
    """Per-replica digest vector ``(n_replicas,)``.

    The global digest is computed once (it is identical on every replica
    under SPMD by construction); each replica's *report* starts as that
    value.  When the ``dist.replica_desync`` point fires for replica *i*
    (indexed stream — deterministic per shard), replica *i*'s report is
    perturbed by a seeded relative bump, simulating the diverged host whose
    state no longer matches the fleet.
    """
    g = float(np.asarray(tree_digest(tree)))
    out = np.full((n_replicas,), g, dtype=np.float64)
    if faults is not None and faults.enabled:
        for i in range(n_replicas):
            if faults.fires("dist.replica_desync", index=i):
                # relative perturbation: survives any digest magnitude
                out[i] = g * (1.0 + 1e-3) + 1e-3
    return out


def desync_spread(digests: np.ndarray) -> float:
    """Max-min spread of the replica digest vector (0.0 == all agree)."""
    d = np.asarray(digests, dtype=np.float64)
    if d.size == 0:
        return 0.0
    return float(d.max() - d.min())
