"""Fault tolerance & straggler mitigation for long multi-pod runs.

Pieces (all host-side, framework-agnostic):
  * PreemptionGuard — SIGTERM/SIGINT handler that flips a flag the train loop
    polls; the loop checkpoints + exits cleanly inside the grace window.
  * StragglerMonitor — per-step wall-time EMA + z-score flagging; on real
    multi-host deployments each host reports its step time and the controller
    flags hosts whose EMA drifts k-sigma from the fleet median (hook provided;
    in this single-host container it monitors local step-time spikes).
  * retry_on_transient — bounded-retry wrapper for collective/IO ops that
    fail transiently on large fleets.
  * ElasticPlan — given a checkpoint's mesh and the surviving device count,
    pick the new (data, model) mesh that keeps per-device memory bounded —
    the decision logic for scale-down restarts.
"""
from __future__ import annotations

import math
import signal
import time

__all__ = ["PreemptionGuard", "StragglerMonitor", "retry_on_transient",
           "elastic_mesh_shape"]


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except (ValueError, OSError):  # non-main thread / platform
                pass

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self):
        """Programmatic trigger — same effect as receiving SIGTERM.  Lets
        orchestrators (and chaos tests) start a graceful drain without
        delivering a real signal."""
        self._requested = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerMonitor:
    """EMA step-time tracker with z-score anomaly flags."""

    def __init__(self, alpha: float = 0.05, z_threshold: float = 4.0,
                 warmup_steps: int = 10):
        self.alpha = alpha
        self.z = z_threshold
        self.warmup = warmup_steps
        self.mean = None
        self.var = 0.0
        self.n = 0
        self.flags: list[tuple[int, float, float]] = []
        self._t0 = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> bool:
        """Returns True if this step is flagged as a straggler event."""
        dt = time.monotonic() - self._t0
        self.n += 1
        if self.mean is None:
            self.mean, self.var = dt, 0.0
            return False
        # test against the PRE-update statistics: folding the sample into the
        # EMA first would let a large spike mask itself
        sigma = math.sqrt(self.var) + 1e-9
        zscore = (dt - self.mean) / sigma
        flagged = self.n > self.warmup and zscore > self.z
        if flagged:
            self.flags.append((step, dt, zscore))
        else:
            # only non-outlier samples update the baseline statistics
            delta = dt - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var
                                           + self.alpha * delta * delta)
        return flagged


def retry_on_transient(fn, retries: int = 3, backoff: float = 0.5,
                       exceptions=(OSError, RuntimeError),
                       jitter: float = 0.0, rng=None,
                       backoff_cap: float = 30.0):
    """Call fn() with bounded retries + exponential backoff.

    ``jitter`` > 0 switches to *decorrelated jitter* (AWS-style): each sleep
    is drawn uniformly from ``[backoff, prev_sleep * 3]``, capped at
    ``backoff_cap``, scaled so ``jitter=1.0`` is fully decorrelated and
    smaller values interpolate toward the deterministic schedule.  Sharded
    writers hitting the same filesystem stamp retry at the same instant
    under pure exponential backoff; jitter spreads the herd.  Pass a seeded
    ``rng`` (``np.random.Generator``-like, needs ``.uniform``) for
    reproducible chaos runs; default draws a fresh one per call.
    """
    if jitter > 0.0 and rng is None:
        import numpy as np
        rng = np.random.default_rng()
    prev = backoff
    for attempt in range(retries + 1):
        try:
            return fn()
        except exceptions:
            if attempt == retries:
                raise
            base = backoff * (2**attempt)
            if jitter > 0.0:
                decorr = min(backoff_cap, rng.uniform(backoff, prev * 3))
                sleep = (1.0 - jitter) * base + jitter * decorr
                prev = max(decorr, backoff)
            else:
                sleep = base
            time.sleep(min(sleep, backoff_cap))


def elastic_mesh_shape(n_devices: int, model_parallel: int = 16,
                       pod_size: int = 256) -> tuple:
    """Mesh shape for a (possibly degraded) device count.

    Keeps the model axis fixed (weight shards must still fit) and absorbs
    device loss into the data(+pod) axes.  Raises if n_devices can't form a
    rectangle — callers then drop to the next lower multiple.
    """
    if n_devices % model_parallel:
        n_devices -= n_devices % model_parallel
    data = n_devices // model_parallel
    if data <= 0:
        raise ValueError("not enough devices for one model shard")
    if n_devices > pod_size and data % (n_devices // pod_size) == 0:
        pods = n_devices // pod_size
        return (pods, data // pods, model_parallel)
    return (data, model_parallel)
