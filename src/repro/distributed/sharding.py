"""Logical-axis sharding: rules, resolution, per-arch policies.

MaxText-style two-namespace design:
  * weight rules  — applied to the P-tree axis names from model init,
  * activation rules — applied by `repro.models.common.shard` constraints.

``resolve_spec`` enforces divisibility per dimension (a rule that doesn't
divide the actual dim is dropped with a record, which is how 40/24/14-head
archs stay compilable at TP=16) and never reuses a mesh axis twice in one
PartitionSpec.

Policies (chosen per arch × shape by ``make_rules``):
  * 1D: weights on 'model' (TP); batch on ('pod','data') — default.
  * 2D: giant models additionally shard the weights' other dim over 'data'
    (GSPMD turns that into FSDP-style gather / 2-D TP) — picked automatically
    when the quantized bytes/device under 1D exceed ``budget_gb``.
  * long-context decode: batch < data-parallelism ⇒ the KV-cache sequence dim
    shards over ('pod','data') instead of batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = ["ShardingPolicy", "make_rules", "resolve_spec", "tree_pspecs",
           "tree_shardings", "estimate_quantized_gb", "row_shard"]


def row_shard(arr, mesh):
    """Place an array with its leading axis sharded over *every* axis of
    ``mesh`` (data-parallel rows), replicating when the mesh is absent,
    trivial, or the dim does not divide.

    Used by the sharded streaming-PTQ path: placement only — the chunked
    arithmetic is fixed by the plan's virtual-shard count, so replicating
    (the fallback here) changes wall-clock, never bytes.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(arr)
    if mesh is None:
        return x
    total = int(np.prod(list(dict(mesh.shape).values())))
    if total <= 1 or x.ndim == 0 or x.shape[0] % total:
        return x
    spec = PartitionSpec(tuple(mesh.axis_names))
    return jax.device_put(x, NamedSharding(mesh, spec))


@dataclasses.dataclass
class ShardingPolicy:
    weight_rules: dict
    act_rules: dict
    dropped: list  # [(axes, dim, rule)] divisibility fallbacks (for the log)

    def summary(self) -> dict:
        """Compact layout record (StepPlan.meta / checkpoint manifests):
        which mesh axes carry weights, whether the LoRDS factors replicate
        (the codes-shard / factors-replicate invariant), whether the fused
        attention kernels can run head-sharded under shard_map (the
        head-local, psum-free qattention route needs the heads act rule on
        'model'), and how many rules were dropped to divisibility."""
        used = sorted({ax for rule in self.weight_rules.values() if rule
                       for ax in ((rule,) if isinstance(rule, str)
                                  else tuple(rule))})
        return {
            "weight_axes": used,
            "lords_factors": ("replicated"
                              if self.weight_rules.get("lords_rank") is None
                              else "sharded"),
            "attention_heads": ("model-sharded"
                                if self.act_rules.get("heads") == "model"
                                else "replicated"),
            "dropped": len(self.dropped),
        }


# logical axis names used across the model zoo
_WEIGHT_AXES_1D = {
    # dim -> mesh axis (None = replicate)
    "embed": None, "vocab": "model", "embed_vocab": None,
    "mlp": "model",
    "qkv_out": "model", "kv_out": "model",
    "q_lora": None, "kv_lora": None,
    "expert": "model", "moe_out": None, "moe_in": None,
    "mamba_in": "model", "dt_rank": None, "state": None,
    "mlstm_in": "model", "slstm_in": "model",
    "heads": None, "lords_rank": None, "layers": None,
}

# 2D variant: contract/other weight dims also shard over 'data'
_WEIGHT_AXES_2D = dict(
    _WEIGHT_AXES_1D,
    embed="data",          # second weight dim of attn/mlp matrices
    moe_in="data",         # per-expert FFN d_model dim (kimi-k2 2-D ETP)
    embed_vocab=None,
)

_ACT_AXES = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data"),
    "seq": None,
    "heads": "model", "kv_heads": "model", "head_dim": None,
    "mlp_act": "model", "mamba_act": "model",
    "vocab": "model",
    "expert": "model", "capacity": None,
    "cache_seq": None,
    # paged KV pool: pages are global (shared across slots) — never sharded
    # over data; the kv_heads dim keeps its model rule like the contiguous
    # cache it replaces
    "kv_pages": None, "page_slot": None,
    "kv_lora": None, "rope_dim": None, "state": None,
    "mlstm_in": "model", "slstm_in": "model",
}


def estimate_quantized_gb(cfg, pack: int = 2) -> float:
    """Rough quantized-model footprint (GB): params/pack + bf16 embeds."""
    d = cfg.d_model
    per_layer = 0
    for mixer, mlp in cfg.layer_kinds():
        if mixer == "attn":
            if cfg.attn_kind == "mla":
                m = cfg.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                per_layer += (d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
                              + d * (m.kv_lora_rank + m.qk_rope_dim)
                              + m.kv_lora_rank * cfg.num_heads
                              * (m.qk_nope_dim + m.v_head_dim)
                              + cfg.num_heads * m.v_head_dim * d)
            else:
                hd = cfg.resolved_head_dim
                per_layer += (d * cfg.num_heads * hd
                              + 2 * d * cfg.num_kv_heads * hd
                              + cfg.num_heads * hd * d)
        elif mixer == "mamba":
            din = cfg.mamba.expand * d
            dtr = cfg.mamba.dt_rank or -(-d // 16)
            per_layer += d * 2 * din + din * (dtr + 2 * cfg.mamba.d_state) \
                + dtr * din + din * d
        elif mixer in ("mlstm", "slstm"):
            din = int(cfg.xlstm.proj_factor * d) if cfg.xlstm else d
            per_layer += (2 * d * din + 3 * din * din + din * d
                          if mixer == "mlstm" else 4 * d * d)
        if mlp == "dense":
            per_layer += 3 * d * cfg.d_ff
        elif mlp == "moe":
            per_layer += cfg.moe.num_experts * 3 * d * cfg.moe.d_ff
    reps = cfg.num_layers / cfg.period
    q_bytes = reps * per_layer / pack
    embed_bytes = cfg.padded_vocab * d * 2 * (1 if cfg.tie_embeddings else 2)
    return float(q_bytes + embed_bytes) / 1e9


def make_rules(cfg, mesh: Mesh, shape_kind: str = "train",
               budget_gb: float = 8.0, force_2d: bool | None = None,
               seq_shard_cache: bool | None = None,
               seq_parallel: bool = False) -> ShardingPolicy:
    """Build weight+activation rules for (arch, mesh, shape kind)."""
    model_par = mesh.shape.get("model", 1)
    data_par = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)

    per_dev_1d = estimate_quantized_gb(cfg) / max(model_par, 1)
    use_2d = force_2d if force_2d is not None else per_dev_1d > budget_gb
    wrules = dict(_WEIGHT_AXES_2D if use_2d else _WEIGHT_AXES_1D)
    arules = dict(_ACT_AXES)

    # divisibility-driven head fallbacks: resolve_spec would drop these
    # anyway, but dropping them here keeps weights & activations consistent
    if cfg.moe is not None and cfg.moe.dispatch == "shard_map":
        # EP over every available axis (experts padded to divide); weights
        # must enter the program already laid out the way the shard_map body
        # splits them, or GSPMD would reshard per layer
        wrules["expert"] = ("pod", "data", "model")
        arules["expert"] = ("pod", "data", "model")
    if seq_parallel:
        # Megatron-style sequence parallelism: inter-layer activations shard
        # their sequence dim on 'model' (GSPMD turns the TP all-reduce into
        # reduce-scatter + all-gather and the remat carries shrink 16x)
        arules["seq"] = "model"
    if cfg.num_heads % model_par:
        arules["heads"] = None
        wrules["qkv_out"] = None if not use_2d else wrules["qkv_out"]
    if cfg.num_kv_heads % model_par:
        arules["kv_heads"] = None
        wrules["kv_out"] = None if not use_2d else wrules["kv_out"]

    if shape_kind in ("decode", "prefill"):
        # KV caches: kv_heads < TP everywhere at TP=16, so the cache shards
        # its sequence dim over 'model' (softmax/psum over the sharded dim is
        # GSPMD-native).  Long-context decode (batch < DP) additionally pulls
        # the idle ('pod','data') axes onto the sequence dim.
        if seq_shard_cache:
            arules["cache_seq"] = ("pod", "data", "model")
            arules["batch"] = None
            arules["tokens"] = None
        else:
            arules["cache_seq"] = "model"
    arules["__mesh__"] = mesh
    return ShardingPolicy(wrules, arules, [])


def resolve_spec(axes: tuple, shape: tuple, rules: dict, mesh: Mesh,
                 dropped: list | None = None) -> PartitionSpec:
    """Logical axes tuple + actual shape -> PartitionSpec (with fallbacks)."""
    spec, used = [], set()
    for dim, name in zip(shape, axes):
        rule = rules.get(name) if name is not None else None
        if rule is None:
            spec.append(None)
            continue
        mesh_axes = (rule,) if isinstance(rule, str) else tuple(rule)
        ok, size = [], 1
        for ax in mesh_axes:
            if ax in used or ax not in mesh.shape:
                continue
            ok.append(ax)
            size *= mesh.shape[ax]
        if ok and size > 1 and dim % size == 0:
            spec.append(tuple(ok) if len(ok) > 1 else ok[0])
            used.update(ok)
        else:
            if ok and dropped is not None:
                dropped.append((name, dim, tuple(ok)))
            spec.append(None)
    return PartitionSpec(*spec)


_AXES_LEAF = lambda x: isinstance(x, tuple) and all(  # noqa: E731
    isinstance(i, (str, type(None))) for i in x)


def tree_pspecs(axes_tree, value_tree, rules: dict, mesh,
                dropped: list | None = None):
    """Resolve a PartitionSpec tree matching ``value_tree`` from its logical
    axes tree.  Pure spec-level: ``mesh`` only needs a ``.shape`` mapping, so
    an ``AbstractMesh`` of the production shape works without any devices
    (how the sharding test-suite validates the full arch zoo on one CPU)."""
    import jax

    def one(axes, val):
        shape = val.shape if hasattr(val, "shape") else ()
        return resolve_spec(tuple(axes), tuple(shape), rules, mesh, dropped)

    return jax.tree.map(one, axes_tree, value_tree, is_leaf=_AXES_LEAF)


def tree_shardings(axes_tree, value_tree, rules: dict, mesh: Mesh,
                   dropped: list | None = None):
    """Build a NamedSharding tree matching value_tree from its axes tree."""
    import jax

    specs = tree_pspecs(axes_tree, value_tree, rules, mesh, dropped)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))
