"""repro.distributed — sharding rules, fault tolerance, elastic restarts."""
from repro.distributed.desync import (  # noqa: F401
    DesyncError,
    desync_spread,
    replica_digests,
    tree_digest,
)
from repro.distributed.fault_tolerance import (  # noqa: F401
    PreemptionGuard,
    StragglerMonitor,
    elastic_mesh_shape,
    retry_on_transient,
)
from repro.distributed.sharding import (  # noqa: F401
    ShardingPolicy,
    estimate_quantized_gb,
    make_rules,
    resolve_spec,
    row_shard,
    tree_shardings,
)
