"""repro.ptq_stream — crash-safe layer-streaming PTQ.

Quantizes a model one transformer block at a time under a hard memory
budget, with every block's artifact atomic, checksummed and journaled so a
killed run resumes bit-identically instead of restarting.
"""
from repro.ptq_stream.ledger import Ledger  # noqa: F401
from repro.ptq_stream.shards import (  # noqa: F401
    digest_array,
    read_shard,
    shard_digest,
    write_shard,
)
from repro.ptq_stream.source import ResidualMLPSource  # noqa: F401
from repro.ptq_stream.stream import (  # noqa: F401
    MemoryBudget,
    MemoryBudgetExceeded,
    StreamPlan,
    allocate_from_artifact,
    audit_artifact,
    calibration_moments,
    quantize_dense_blocks,
    stream_quantize,
)
