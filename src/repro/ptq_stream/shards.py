"""Atomic, checksummed shard IO for the streaming PTQ pipeline.

A *shard* is one block's quantized artifact: a flat ``{name: array}`` dict
(npz container, keys like ``up/q``, ``up/b``, ``down/a`` …).  The write
protocol is crash-safe end to end:

  1. serialize into ``<shard>.tmp.<pid>`` (transient ``OSError`` retried via
     :func:`repro.distributed.fault_tolerance.retry_on_transient`),
  2. **verify-on-write**: re-read the temp file from disk and digest its
     *content* — a torn or bit-flipped write is caught before publication,
  3. ``os.replace`` onto the final name (atomic on POSIX) — readers only
     ever see complete shards.

Digests are CRC32 over array bytes + dtype + shape per sorted key, not over
the zip container, so they are stable across archive metadata (timestamps)
and directly comparable between a fresh write and a years-old file.

Fault-injection points consulted here (see ``repro.robustness.faults``):
``ptq.transient_oserror`` (inside the retried write fn), ``ptq.kill_mid_write``
(between temp write and publish), ``ptq.corrupt_shard`` (flips a byte of the
*published* file — simulated bitrot the resume audit must catch).
"""
from __future__ import annotations

import os
import zlib

import numpy as np

from repro.distributed.fault_tolerance import retry_on_transient
from repro.robustness import NO_FAULTS, InjectedFault

__all__ = ["digest_array", "shard_digest", "write_shard", "read_shard",
           "shard_name"]


def shard_name(block: int) -> str:
    return f"block_{block:05d}.npz"


def digest_array(x, crc: int = 0) -> int:
    """CRC32 of one array's content (+dtype/shape so views can't collide)."""
    a = np.ascontiguousarray(np.asarray(x))
    crc = zlib.crc32(str(a.dtype).encode(), crc)
    crc = zlib.crc32(str(a.shape).encode(), crc)
    return zlib.crc32(a.tobytes(), crc)


def _digest_tree(tree: dict) -> int:
    crc = 0
    for k in sorted(tree):
        crc = zlib.crc32(k.encode(), crc)
        crc = digest_array(tree[k], crc)
    return crc


def read_shard(path: str) -> dict:
    """Load a shard back to {name: np.ndarray}; raises on a corrupt file."""
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def shard_digest(path: str) -> int:
    """Content digest of an on-disk shard (raises if unreadable)."""
    return _digest_tree(read_shard(path))


def write_shard(directory: str, block: int, tree: dict, *,
                faults=NO_FAULTS, io_retries: int = 2,
                io_backoff: float = 0.02,
                io_jitter: float = 0.0) -> tuple[str, int]:
    """Atomically publish one block's shard; returns (filename, crc32).

    The returned digest comes from re-reading the written bytes, never from
    the in-memory arrays — what's recorded in the ledger is what the disk
    actually holds.  ``io_jitter`` > 0 decorrelates the retry backoff
    (sharded writers hammering one filesystem shouldn't retry in lockstep);
    it changes sleep timing only, never bytes.
    """
    os.makedirs(directory, exist_ok=True)
    name = shard_name(block)
    final = os.path.join(directory, name)
    tmp = final + f".tmp.{os.getpid()}"
    host = {k: np.asarray(v) for k, v in tree.items()}

    def _retry(fn):
        return retry_on_transient(fn, retries=io_retries, backoff=io_backoff,
                                  exceptions=(OSError,), jitter=io_jitter)

    def _write():
        if faults.fires("ptq.transient_oserror"):
            raise OSError("injected transient shard-write failure")
        with open(tmp, "wb") as f:
            np.savez(f, **host)
            f.flush()
            os.fsync(f.fileno())

    _retry(_write)

    if faults.fires("ptq.kill_mid_write"):
        # temp written, final never published: a resume must re-do the block
        raise InjectedFault(f"killed mid shard write (block {block})")

    # verify-on-write: digest the bytes that actually landed on disk
    def _verify():
        got = _digest_tree(read_shard(tmp))
        want = _digest_tree(host)
        if got != want:
            raise OSError(
                f"shard verify-on-write mismatch for block {block}: "
                f"disk crc {got:#010x} != memory crc {want:#010x}")
        return got

    crc = _retry(_verify)
    _retry(lambda: os.replace(tmp, final))

    if faults.fires("ptq.corrupt_shard"):
        _flip_byte(final)
    return name, crc


def _flip_byte(path: str, offset: int | None = None):
    """Bit-rot simulator: XOR one byte of the published file in place.

    Defaults to the middle of the file — inside the (uncompressed) array
    data, so the *content* digest changes; flipping zip trailer metadata
    would be invisible to a content-level checksum."""
    size = os.path.getsize(path)
    pos = size // 2 if offset is None else offset
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))
