"""Journaled progress ledger for the streaming PTQ pipeline.

``ledger.json`` is the single source of truth for how far a run got: one
entry per completed block, carrying everything resume needs to *prove* the
on-disk artifact is still the one this run produced —

  * ``shard`` / ``crc32``   — the shard file name and its content digest
    (over array bytes + dtypes + shapes, not the zip container, so the
    digest is stable across archive-metadata differences),
  * ``x_in`` / ``x_out``    — digests of the block's calibration input and
    output activations: consecutive entries must chain
    (``entries[i].x_in == entries[i-1].x_out``), pinning the whole
    propagation history, not just per-block artifacts,
  * ``seed``                — the derived per-block RNG seed (drives the
    randomized-Hadamard signs when the pre-transform is on).

Every mutation rewrites the whole file via write-temp + ``os.replace`` —
readers never see a torn ledger; a crash between a shard landing and its
ledger commit simply re-does that block (deterministically, to identical
bytes).  The plan fingerprint is recorded up front and resume refuses to
continue a ledger written under different quantization settings.
"""
from __future__ import annotations

import json
import os

from repro.distributed.fault_tolerance import retry_on_transient

__all__ = ["Ledger"]

_FILE = "ledger.json"


class Ledger:
    def __init__(self, directory: str, io_retries: int = 2,
                 io_backoff: float = 0.02):
        self.dir = directory
        self.path = os.path.join(directory, _FILE)
        self.io_retries = io_retries
        self.io_backoff = io_backoff
        self._data = {"version": 1, "plan": None, "source": None,
                      "status": "empty", "entries": []}

    # -- IO -----------------------------------------------------------------

    def _io(self, fn):
        return retry_on_transient(fn, retries=self.io_retries,
                                  backoff=self.io_backoff,
                                  exceptions=(OSError,))

    def _commit(self):
        os.makedirs(self.dir, exist_ok=True)
        tmp = self.path + f".tmp.{os.getpid()}"

        def write():
            with open(tmp, "w") as f:
                json.dump(self._data, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)

        self._io(write)

    def load(self) -> bool:
        """Read ledger.json; returns False when absent/unreadable (fresh)."""
        if not os.path.exists(self.path):
            return False
        try:
            with open(self.path) as f:
                data = json.load(f)
            assert isinstance(data["entries"], list)
        except (OSError, ValueError, KeyError, AssertionError):
            return False
        self._data = data
        return True

    # -- lifecycle ----------------------------------------------------------

    def start(self, plan_fp: dict, source_fp: dict):
        """Begin a fresh run (drops any previous entries) and commit."""
        self._data = {"version": 1, "plan": plan_fp, "source": source_fp,
                      "status": "in_progress", "entries": []}
        self._commit()

    def check_fingerprint(self, plan_fp: dict, source_fp: dict):
        """Resume guard: refuse to continue under different settings."""
        if self._data.get("plan") != plan_fp:
            raise ValueError(
                "ledger was written under a different quantization plan: "
                f"ledger={self._data.get('plan')} vs run={plan_fp}")
        if self._data.get("source") != source_fp:
            raise ValueError(
                "ledger was written for a different model/source: "
                f"ledger={self._data.get('source')} vs run={source_fp}")

    @property
    def entries(self) -> list[dict]:
        return self._data["entries"]

    @property
    def status(self) -> str:
        return self._data.get("status", "empty")

    def entry(self, block: int) -> dict | None:
        ents = self._data["entries"]
        return ents[block] if block < len(ents) else None

    def append(self, entry: dict):
        if entry["block"] != len(self._data["entries"]):
            raise ValueError(
                f"ledger append out of order: got block {entry['block']}, "
                f"expected {len(self._data['entries'])}")
        self._data["entries"].append(entry)
        self._commit()

    def replace(self, block: int, entry: dict):
        """Overwrite one entry in place (a re-done block on resume)."""
        self._data["entries"][block] = entry
        self._commit()

    def complete(self):
        self._data["status"] = "complete"
        self._commit()

    def mark_in_progress(self):
        self._data["status"] = "in_progress"
        self._commit()

    def cleanup_stray_tmp(self) -> int:
        """Remove leftover ``*.tmp*`` files from a killed writer."""
        n = 0
        if not os.path.isdir(self.dir):
            return 0
        for name in os.listdir(self.dir):
            if ".tmp" in name:
                try:
                    os.remove(os.path.join(self.dir, name))
                    n += 1
                except OSError:
                    pass
        return n
