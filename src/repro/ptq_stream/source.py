"""Dense-block sources: where the streaming pipeline gets one block at a time.

The pipeline never sees a whole model — it talks to a *source* exposing:

  * ``num_blocks``                    — sequential transformer-block count,
  * ``calibration_inputs()``          — the (T, d) activations entering
    block 0 (the Catcher output: in a real deployment the embedding+norm
    front end runs over the calibration set once and is freed),
  * ``load_block(i)``                 — materialize block *i*'s dense
    weights as ``{name: (n, m) array}`` — the only point dense weights
    exist, and the watchdog charges them against the memory budget,
  * ``calib_inputs(weights, x, *, chunks=1, mesh=None)`` — per-matrix
    calibration activations for one block given its weights and the block
    input (the in-block Catcher: each linear is calibrated against what
    actually feeds it),
  * ``block_apply(weights, x, *, chunks=1, mesh=None)`` — the block forward
    used to propagate calibration activations to the next block (called
    with the *quantized* weights, GPTQ-style, so later blocks calibrate
    against the error the earlier ones actually emit).  ``chunks`` fixes
    the virtual-shard count of the canonical chunked math (bytes depend on
    it, never on ``mesh``); ``mesh`` optionally places the token chunks
    data-parallel,
  * ``fingerprint()``                 — identity recorded in the ledger.

:class:`ResidualMLPSource` is the reference implementation: a chain of
pre-norm-free residual MLP blocks (``x + gelu(x Upᵀ) Downᵀ``) whose dense
weights live in per-block ``.npz`` files on disk, so process memory holds at
most one dense block — the layout a 100B+ checkpoint-streaming adapter
plugs into.
"""
from __future__ import annotations

import json
import os
import zlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ptq import virtual_shards
from repro.data.calibration import synthetic_activations
from repro.distributed.sharding import row_shard

__all__ = ["ResidualMLPSource"]

_META = "source.json"


@partial(jax.jit, static_argnames=("chunks",))
def _mlp_forward_chunked(up, down, x, chunks: int):
    """Canonical chunked residual-MLP forward: ``h = gelu(x Upᵀ)``,
    ``y = x + h Downᵀ``, with the token axis split into ``chunks`` fixed
    virtual shards.  Every chunk's math is token-local (the matmuls reduce
    over the *feature* axis, which is never split), so the program — and
    its bytes — are identical whether the chunk axis lives on one device
    or eight: a mesh is pure placement.
    """
    t, d = x.shape
    xc = x.reshape(chunks, t // chunks, d)
    h = jax.nn.gelu(jnp.einsum("ctd,fd->ctf", xc, up))
    y = xc + jnp.einsum("ctf,df->ctd", h, down)
    return h.reshape(t, -1), y.reshape(t, d)


def _dense_name(i: int) -> str:
    return f"dense_{i:05d}.npz"


class ResidualMLPSource:
    """Disk-backed chain of residual MLP blocks (see module docstring)."""

    def __init__(self, directory: str):
        self.dir = directory
        with open(os.path.join(directory, _META)) as f:
            meta = json.load(f)
        self.d = int(meta["d"])
        self.d_ff = int(meta["d_ff"])
        self.num_blocks = int(meta["num_blocks"])
        self.tokens = int(meta["tokens"])
        self.seed = int(meta["seed"])

    # -- construction -------------------------------------------------------

    @staticmethod
    def create(directory: str, *, num_blocks: int, d: int, d_ff: int,
               tokens: int = 64, seed: int = 0) -> "ResidualMLPSource":
        """Generate + persist a deterministic dense model (one npz/block)."""
        os.makedirs(directory, exist_ok=True)
        rng = np.random.default_rng(seed)
        for i in range(num_blocks):
            up = (rng.standard_normal((d_ff, d)) * 0.2).astype(np.float32)
            down = (rng.standard_normal((d, d_ff)) * 0.2).astype(np.float32)
            # persistent outlier input channels — what SmoothRot-style
            # pre-transforms (and block scales) actually fight
            n_out = max(1, d // 16)
            idx = rng.choice(d, n_out, replace=False)
            up[:, idx] *= 8.0
            np.savez(os.path.join(directory, _dense_name(i)),
                     up=up, down=down)
        meta = {"kind": "residual_mlp", "d": d, "d_ff": d_ff,
                "num_blocks": num_blocks, "tokens": tokens, "seed": seed}
        with open(os.path.join(directory, _META), "w") as f:
            json.dump(meta, f)
        return ResidualMLPSource(directory)

    # -- the source protocol ------------------------------------------------

    def fingerprint(self) -> dict:
        return {"kind": "residual_mlp", "d": self.d, "d_ff": self.d_ff,
                "num_blocks": self.num_blocks, "tokens": self.tokens,
                "seed": self.seed}

    def calibration_inputs(self) -> np.ndarray:
        x = synthetic_activations(self.tokens, self.d, seed=self.seed)
        return (0.1 * x).astype(np.float32)  # keep the residual stream sane

    def load_block(self, i: int) -> dict:
        with np.load(os.path.join(self.dir, _dense_name(i))) as z:
            return {k: z[k] for k in z.files}

    def _forward(self, weights: dict, x: np.ndarray, chunks: int, mesh):
        ns = virtual_shards(x.shape[0], chunks)
        xj = row_shard(np.asarray(x, np.float32), mesh)
        h, y = _mlp_forward_chunked(
            jnp.asarray(weights["up"], jnp.float32),
            jnp.asarray(weights["down"], jnp.float32), xj, ns)
        return h, y

    def calib_inputs(self, weights: dict, x: np.ndarray, *,
                     chunks: int = 1, mesh=None) -> dict:
        h, _ = self._forward(weights, x, chunks, mesh)
        return {"up": np.asarray(x, np.float32),
                "down": np.asarray(h, np.float32)}

    def block_apply(self, weights: dict, x: np.ndarray, *,
                    chunks: int = 1, mesh=None) -> np.ndarray:
        _, y = self._forward(weights, x, chunks, mesh)
        return np.asarray(y, np.float32)

    # -- accounting ---------------------------------------------------------

    def block_bytes(self, i: int | None = None) -> int:
        """Dense bytes of one block (shape-derived, nothing materialized)."""
        return 2 * self.d * self.d_ff * 4

    def dense_bytes(self) -> int:
        """Total dense model bytes — what in-memory PTQ would have to hold."""
        return sum(self.block_bytes(i) for i in range(self.num_blocks))

    def content_seed(self, block: int) -> int:
        """Stable per-block seed derived from (source seed, block index)."""
        return zlib.crc32(f"{self.seed}/{block}".encode())
