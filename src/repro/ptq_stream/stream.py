"""Layer-streaming PTQ: sequential, crash-safe, memory-bounded.

``stream_quantize(source, out_dir, plan)`` processes one transformer block
at a time — materialize the dense block, capture per-matrix calibration
activations, LoRDS-refine S = BA against them (``core.ptq.ptq_refine``
with the activation-weighted loss), publish the packed codes as an atomic
checksummed shard, journal the block in the ledger, propagate the
calibration activations through the *quantized* block (GPTQ-style), and
free.  Dense weights for at most one block ever exist, enforced — not
assumed — by a :class:`MemoryBudget` watchdog that fails fast with a
per-charge diagnostic instead of silently swapping.

Crash-safety contract (asserted by tests/test_ptq_stream.py and the
``ptq-stream-smoke`` CI job):

  * a run killed at any block boundary, mid-shard-write, or between a
    shard landing and its ledger commit, resumes (``resume=True``) to an
    artifact **bit-identical** to an uninterrupted run;
  * resume trusts nothing: every prior block's shard is re-digested
    against the ledger CRC, and the activation chain is re-propagated and
    checked digest-by-digest — any mismatch (corrupt shard, changed
    calibration set) re-does exactly the invalid block and then keeps
    re-validating, so one flipped bit costs one block, not the run;
  * :class:`~repro.distributed.fault_tolerance.PreemptionGuard` flips a
    graceful stop at the next block boundary (status ``preempted``; the
    ledger stays resumable);
  * transient ``OSError`` during shard IO is retried
    (``retry_on_transient``), bounded.

Fault-injection points (``repro.robustness.FaultPlan``): ``ptq.kill_at_block``,
``ptq.kill_mid_write``, ``ptq.kill_before_commit``, ``ptq.corrupt_shard``,
``ptq.transient_oserror``, ``ptq.oom_spike``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core.ptq import ptq_refine_chunked, virtual_shards
from repro.core.baselines import (
    hadamard_signs,
    hadamard_transform,
    smooth_scales,
)
from repro.core.quantize import dequantize_codes, unpack_codes
from repro.core.scaling import scale_matrix
from repro.distributed.sharding import row_shard
from repro.kernels import dispatch
from repro.ptq_stream.ledger import Ledger
from repro.ptq_stream.shards import (
    digest_array,
    read_shard,
    shard_digest,
    write_shard,
)
from repro.robustness import NO_FAULTS, InjectedFault

__all__ = ["StreamPlan", "MemoryBudget", "MemoryBudgetExceeded",
           "stream_quantize", "quantize_dense_blocks", "audit_artifact",
           "calibration_moments", "allocate_from_artifact"]


# ---------------------------------------------------------------------------
# plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Everything that determines the quantized artifact's bytes.

    ``memory_budget`` / ``refine_overhead`` are *execution* knobs — they
    gate whether a run is allowed to proceed, never what it computes — so
    they are excluded from the ledger fingerprint: resuming under a
    different budget is legal and still bit-identical.
    """

    codebook: str = "nf4"
    block_size: int = 32
    rank: int | None = None
    extra_rank: int = 0
    # per-matrix mixed-precision overrides from the sensitivity allocator
    # (core.allocate): ((matrix_name, codebook, rank), ...).  Overrides
    # determine the artifact bytes, so they are fingerprinted.
    overrides: tuple = ()
    refine_steps: int = 40
    lr: float = 0.05
    seed: int = 0
    pretransform: str = "none"      # none | smooth | smoothrot
    smooth_alpha: float = 0.5
    act_weighted: bool = True       # col_weight = E[x_j^2] in refinement
    # Fixed virtual-shard count for the canonical chunked arithmetic
    # (calibration matmuls, E[x²] folds, ptq_refine_chunked).  Part of the
    # numerical program — fingerprinted — so a run is bit-identical on any
    # physical device count: a mesh changes where chunks live, never what
    # is computed.  Per-dim counts clamp to the largest divisor
    # (core.ptq.virtual_shards).
    calib_shards: int = 8
    memory_budget: int | None = None  # bytes; None = unenforced
    refine_overhead: int = 6        # transient f32 copies charged per refine
    # shard/ledger IO retry policy (execution knobs, not fingerprinted)
    io_retries: int = 2
    io_backoff: float = 0.02
    io_jitter: float = 0.0          # 0 = deterministic exponential backoff

    def __post_init__(self):
        if self.pretransform not in ("none", "smooth", "smoothrot"):
            raise ValueError(f"unknown pretransform {self.pretransform!r}")
        object.__setattr__(
            self, "overrides",
            tuple((str(n), str(cb), None if r is None else int(r))
                  for n, cb, r in self.overrides))

    def codebook_for(self, name: str) -> str:
        for n, cb, _ in self.overrides:
            if n == name:
                return cb
        return self.codebook

    def rank_for(self, name: str):
        for n, _, r in self.overrides:
            if n == name:
                # a None rank in an override means "codebook only": the
                # matrix keeps the plan-wide rank policy
                return self.rank if r is None else r
        return self.rank

    def with_allocation(self, alloc) -> "StreamPlan":
        """Fold a :class:`repro.core.allocate.AllocPlan` into per-matrix
        overrides (keyed by the allocator's layer names)."""
        ov = tuple((l.name, l.codebook, l.rank) for l in alloc.layers)
        return dataclasses.replace(self, overrides=ov)

    def fingerprint(self) -> dict:
        fp = {"codebook": self.codebook, "block_size": self.block_size,
              "rank": self.rank, "extra_rank": self.extra_rank,
              "refine_steps": self.refine_steps, "lr": self.lr,
              "seed": self.seed, "pretransform": self.pretransform,
              "smooth_alpha": self.smooth_alpha,
              "act_weighted": self.act_weighted,
              "calib_shards": self.calib_shards}
        if self.overrides:  # absent for uniform plans: fingerprint-stable
            fp["overrides"] = [list(o) for o in self.overrides]
        return fp


def _block_seed(plan_seed: int, block: int) -> int:
    return zlib.crc32(f"{plan_seed}/{block}".encode())


def _mat_seed(plan_seed: int, block: int, name: str) -> int:
    return zlib.crc32(f"{plan_seed}/{block}/{name}".encode())


# ---------------------------------------------------------------------------
# memory-budget watchdog
# ---------------------------------------------------------------------------


class MemoryBudgetExceeded(RuntimeError):
    """The streaming invariant broke: fail fast, never swap silently."""


class MemoryBudget:
    """Explicit byte accounting for everything the pipeline materializes.

    Every dense block, activation capture, and refine temporary is charged
    under a name; exceeding ``limit`` raises :class:`MemoryBudgetExceeded`
    whose message lists the live charges — the diagnostic names exactly
    which allocation broke the streaming invariant.  ``ptq.oom_spike``
    injects a phantom allocation of the full limit so chaos tests exercise
    the failure path deterministically.
    """

    def __init__(self, limit: int | None, faults=NO_FAULTS):
        self.limit = limit
        self.faults = faults
        self._live: dict[str, int] = {}
        self.peak = 0

    def charge(self, name: str, nbytes: int):
        self._live[name] = self._live.get(name, 0) + int(nbytes)
        total = sum(self._live.values())
        self.peak = max(self.peak, total)
        phantom = 0
        if self.limit is not None and self.faults.fires("ptq.oom_spike"):
            phantom = self.limit
            self._live["injected/oom_spike"] = phantom
        if self.limit is not None and total + phantom > self.limit:
            diag = ", ".join(f"{k}={v}" for k, v in sorted(
                self._live.items(), key=lambda kv: -kv[1]))
            self._live.pop("injected/oom_spike", None)
            raise MemoryBudgetExceeded(
                f"memory budget exceeded: {total + phantom} > "
                f"{self.limit} bytes while charging {name!r} "
                f"(+{nbytes}); live charges: {diag}")

    def release(self, name: str):
        self._live.pop(name, None)

    def release_prefix(self, prefix: str):
        for k in [k for k in self._live if k.startswith(prefix)]:
            del self._live[k]

    @contextlib.contextmanager
    def hold(self, name: str, nbytes: int):
        self.charge(name, nbytes)
        try:
            yield
        finally:
            self.release(name)

    def live(self) -> dict:
        return dict(self._live)


# ---------------------------------------------------------------------------
# per-matrix / per-block quantization (shared by streamed + in-memory paths)
# ---------------------------------------------------------------------------


def _col_weight(xm: jnp.ndarray, chunks: int = 1) -> jnp.ndarray:
    """E[x_j²] + eps with *canonical chunked* token reduction: the token
    axis is split into ``chunks`` fixed virtual shards whose partial sums
    fold in shard order, so the bytes never depend on physical sharding."""
    x = jnp.asarray(xm, jnp.float32)
    t = x.shape[0]
    ns = virtual_shards(t, chunks)
    parts = jnp.sum(x.reshape(ns, t // ns, -1) ** 2, axis=1)
    acc = parts[0]
    for i in range(1, ns):
        acc = acc + parts[i]
    return acc / jnp.float32(t) + 1e-6


def _quantize_matrix(w, xm, plan: StreamPlan, seed: int,
                     name: str = "", mesh=None) -> dict:
    """One matrix through Alg. 1 under the plan's pre-transform; returns the
    flat artifact arrays ({q, b, a[, c, signs], xsq}).

    The refine runs :func:`ptq_refine_chunked` over ``plan.calib_shards``
    virtual row shards; when ``mesh`` is given the rows (chunk axis) are
    placed data-parallel across it — placement only, identical bytes.
    ``xsq`` is the original-basis E[x_j²] moment, stored for the
    sensitivity allocator (core.allocate) to consume later.
    """
    w = jnp.asarray(w, jnp.float32)
    cs = plan.calib_shards
    xsq = _col_weight(xm, cs)
    kw = dict(codebook_name=plan.codebook_for(name),
              block_size=plan.block_size,
              rank=plan.rank_for(name), extra_rank=plan.extra_rank,
              steps=plan.refine_steps, lr=plan.lr,
              nshard=virtual_shards(w.shape[0], cs))
    w_in = row_shard(w, mesh)
    if plan.pretransform == "smoothrot":
        c = smooth_scales(w, xm, plan.smooth_alpha)
        signs = hadamard_signs(w.shape[1], seed)
        w_work = hadamard_transform(w * c[None, :], signs)
        x_work = hadamard_transform(
            jnp.asarray(xm, jnp.float32) / c[None, :], signs)
        colw = _col_weight(x_work, cs) if plan.act_weighted else None
        res = ptq_refine_chunked(row_shard(w_work, mesh),
                                 col_weight=colw, **kw)
        return {"q": res.q_packed, "b": res.b, "a": res.a,
                "c": c, "signs": signs, "xsq": xsq}
    colw = _col_weight(xm, cs) if plan.act_weighted else None
    if plan.pretransform == "smooth":
        c = smooth_scales(w, xm, plan.smooth_alpha)
        res = ptq_refine_chunked(w_in, col_weight=colw, channel_scale=c,
                                 **kw)
    else:
        res = ptq_refine_chunked(w_in, col_weight=colw, **kw)
    return {"q": res.q_packed, "b": res.b, "a": res.a, "xsq": xsq}


def _dequant_matrix(mats: dict, plan: StreamPlan,
                    name: str = "") -> np.ndarray:
    """Ŵ in the original basis from one matrix's artifact arrays."""
    cb = plan.codebook_for(name)
    codes = unpack_codes(jnp.asarray(mats["q"]), cb)
    s = scale_matrix(jnp.asarray(mats["b"]), jnp.asarray(mats["a"]))
    w_hat = dequantize_codes(codes, s, cb)
    if "c" in mats:  # smoothrot: rotate back, un-smooth
        signs = jnp.asarray(mats["signs"], jnp.float32)
        c = jnp.asarray(mats["c"], jnp.float32)
        w_hat = hadamard_transform(w_hat) * signs[None, :] / c[None, :]
    return np.asarray(w_hat, np.float32)


def _quantize_block(weights: dict, calib: dict, plan: StreamPlan,
                    block: int, budget: MemoryBudget | None = None,
                    mesh=None) -> tuple[dict, dict]:
    """Quantize every matrix of one block; returns (flat shard tree, Ŵ)."""
    flat, w_hat = {}, {}
    for name in sorted(weights):
        w = np.asarray(weights[name], np.float32)
        ctx = (budget.hold(f"block{block}/refine",
                           plan.refine_overhead * w.nbytes)
               if budget is not None else contextlib.nullcontext())
        with ctx:
            mats = _quantize_matrix(w, calib[name], plan,
                                    _mat_seed(plan.seed, block, name),
                                    name=name, mesh=mesh)
        for k, v in mats.items():
            flat[f"{name}/{k}"] = np.asarray(v)
        w_hat[name] = _dequant_matrix(mats, plan, name=name)
        if budget is not None:
            budget.charge(f"block{block}/artifact",
                          sum(v.nbytes for v in mats.values()))
            budget.charge(f"block{block}/dequant", w_hat[name].nbytes)
    return flat, w_hat


def _unflatten(tree: dict) -> dict:
    """{'up/q': ...} -> {'up': {'q': ...}} (shard layout -> per-matrix)."""
    out: dict[str, dict] = {}
    for k, v in tree.items():
        name, key = k.rsplit("/", 1)
        out.setdefault(name, {})[key] = v
    return out


# ---------------------------------------------------------------------------
# streaming pipeline
# ---------------------------------------------------------------------------


def _try_reuse(out_dir: str, entry: dict, plan: StreamPlan, source, x,
               budget: MemoryBudget, mesh=None):
    """Validate one ledger entry against disk + the activation chain.

    Returns (ok, x_out, reason).  On ok the block's work is skipped and the
    propagated activations come from the *stored* shard — the same bytes a
    fresh run would have produced (verify-on-write proved it)."""
    path = os.path.join(out_dir, entry["shard"])
    try:
        crc = shard_digest(path)
    except Exception:
        return False, None, "shard missing/unreadable"
    if crc != entry["crc32"]:
        return False, None, "shard checksum mismatch"
    if digest_array(x) != entry["x_in"]:
        return False, None, "input-activation digest mismatch"
    mats = _unflatten(read_shard(path))
    i = entry["block"]
    w_hat = {}
    for name, m in mats.items():
        w_hat[name] = _dequant_matrix(m, plan, name=name)
        budget.charge(f"block{i}/dequant", w_hat[name].nbytes)
    x_out = source.block_apply(w_hat, x, chunks=plan.calib_shards,
                               mesh=mesh)
    budget.release_prefix(f"block{i}/")
    if digest_array(x_out) != entry["x_out"]:
        return False, None, "output-activation digest mismatch"
    return True, x_out, None


def stream_quantize(source, out_dir: str, plan: StreamPlan, *,
                    resume: bool = False, faults=None, guard=None,
                    mesh=None) -> dict:
    """Run (or resume) the streaming pipeline; returns a summary dict.

    ``faults``: a :class:`repro.robustness.FaultPlan` consulted at the
    ``ptq.*`` points.  ``guard``: anything with a ``preempted`` property
    (:class:`PreemptionGuard`) — checked at block boundaries.

    ``mesh``: optional ``jax.sharding.Mesh`` — the calibration matmuls and
    the ``ptq_refine_chunked`` inner loop run data-parallel over it (rows /
    tokens placed across every mesh axis, ``dispatch.shard_scope``
    active).  The mesh is an *execution* knob: the plan's fixed
    ``calib_shards`` virtual-shard arithmetic makes the artifact bytes
    identical on any device count, so a sharded run killed at a block
    boundary may resume on a smaller mesh (or a single host) and still
    converge to the bit-identical artifact.
    """
    faults = faults or NO_FAULTS
    t_start = time.monotonic()
    ledger = Ledger(out_dir, io_retries=plan.io_retries,
                    io_backoff=plan.io_backoff)
    budget = MemoryBudget(plan.memory_budget, faults)
    plan_fp, source_fp = plan.fingerprint(), source.fingerprint()

    if resume and ledger.load():
        if ledger.entries:
            ledger.check_fingerprint(plan_fp, source_fp)
        ledger.mark_in_progress()
    else:
        ledger.start(plan_fp, source_fp)
    stray = ledger.cleanup_stray_tmp()

    x = np.asarray(source.calibration_inputs(), np.float32)
    budget.charge("calib/x", x.nbytes)

    reused, recomputed = 0, []
    n = source.num_blocks
    scope = (dispatch.shard_scope(mesh) if mesh is not None
             else contextlib.nullcontext())
    with scope:
        for i in range(n):
            entry = ledger.entry(i)
            if entry is not None:
                ok, x_out, _reason = _try_reuse(out_dir, entry, plan,
                                                source, x, budget, mesh=mesh)
                if ok:
                    x = x_out
                    reused += 1
                    continue
                # invalid entry: fall through and re-do exactly this block —
                # deterministic recompute restores the original bytes, so
                # later entries stay reusable via the digest chain.
            if guard is not None and guard.preempted:
                return {"status": "preempted", "blocks_done": i,
                        "num_blocks": n, "reused": reused,
                        "recomputed": recomputed, "stray_tmp_removed": stray,
                        "peak_bytes": budget.peak,
                        "wall_s": time.monotonic() - t_start}
            if faults.fires("ptq.kill_at_block"):
                raise InjectedFault(f"killed at block boundary {i}")

            t0 = time.monotonic()
            weights = source.load_block(i)
            budget.charge(f"block{i}/dense",
                          sum(np.asarray(v).nbytes
                              for v in weights.values()))
            calib = source.calib_inputs(weights, x,
                                        chunks=plan.calib_shards, mesh=mesh)
            budget.charge(f"block{i}/calib",
                          sum(np.asarray(v).nbytes for v in calib.values()))

            flat, w_hat = _quantize_block(weights, calib, plan, i, budget,
                                          mesh=mesh)
            shard, crc = write_shard(out_dir, i, flat, faults=faults,
                                     io_retries=plan.io_retries,
                                     io_backoff=plan.io_backoff,
                                     io_jitter=plan.io_jitter)
            x_out = source.block_apply(w_hat, x, chunks=plan.calib_shards,
                                       mesh=mesh)
            new_entry = {"block": i, "status": "done", "shard": shard,
                         "crc32": crc, "x_in": digest_array(x),
                         "x_out": digest_array(x_out),
                         "seed": _block_seed(plan.seed, i),
                         "wall_s": round(time.monotonic() - t0, 4)}
            if faults.fires("ptq.kill_before_commit"):
                # shard published but never journaled: resume re-does the
                # block
                raise InjectedFault(
                    f"killed before ledger commit (block {i})")
            if entry is None:
                ledger.append(new_entry)
            else:
                ledger.replace(i, new_entry)
            recomputed.append(i)
            budget.release_prefix(f"block{i}/")
            budget.release("calib/x")
            budget.charge("calib/x", x_out.nbytes)
            x = x_out

    ledger.complete()
    return {"status": "complete", "blocks_done": n, "num_blocks": n,
            "reused": reused, "recomputed": recomputed,
            "stray_tmp_removed": stray, "peak_bytes": budget.peak,
            "x_final_digest": digest_array(x),
            "wall_s": time.monotonic() - t_start}


# ---------------------------------------------------------------------------
# in-memory reference path (the one-shot core.ptq equivalent)
# ---------------------------------------------------------------------------


def quantize_dense_blocks(source, plan: StreamPlan) -> tuple[list[dict], int]:
    """One-shot in-memory PTQ: all dense blocks held at once, same per-matrix
    math as the streamed path (shared ``_quantize_block``).  Returns
    (per-block flat artifact trees, final activation digest) — the oracle
    the streamed artifact must match bit for bit."""
    blocks = [source.load_block(i) for i in range(source.num_blocks)]
    x = np.asarray(source.calibration_inputs(), np.float32)
    out = []
    for i, weights in enumerate(blocks):
        calib = source.calib_inputs(weights, x, chunks=plan.calib_shards)
        flat, w_hat = _quantize_block(weights, calib, plan, i)
        out.append({k: np.asarray(v) for k, v in flat.items()})
        x = source.block_apply(w_hat, x, chunks=plan.calib_shards)
    return out, digest_array(x)


# ---------------------------------------------------------------------------
# audit
# ---------------------------------------------------------------------------


def audit_artifact(out_dir: str, source, plan: StreamPlan) -> dict:
    """Read-only ledger/checksum audit of a streamed artifact.

    Re-digests every shard against its ledger CRC and re-propagates the
    calibration activations through the stored quantized blocks, checking
    the digest chain end to end.  Returns ``{"clean": bool, "blocks":
    [{block, ok, reason}, ...], "status": ledger status}``.
    """
    ledger = Ledger(out_dir)
    if not ledger.load():
        return {"clean": False, "status": "missing",
                "blocks": [], "reason": "no readable ledger"}
    report = {"status": ledger.status, "blocks": []}
    try:
        ledger.check_fingerprint(plan.fingerprint(), source.fingerprint())
    except ValueError as e:
        return {**report, "clean": False, "reason": str(e)}
    budget = MemoryBudget(None)
    x = np.asarray(source.calibration_inputs(), np.float32)
    clean = ledger.status == "complete"
    for i in range(source.num_blocks):
        entry = ledger.entry(i)
        if entry is None:
            report["blocks"].append(
                {"block": i, "ok": False, "reason": "missing ledger entry"})
            clean = False
            break
        ok, x_out, reason = _try_reuse(out_dir, entry, plan, source, x,
                                       budget)
        report["blocks"].append({"block": i, "ok": ok, "reason": reason})
        if not ok:
            clean = False
            break
        x = x_out
    report["clean"] = clean
    return report


# ---------------------------------------------------------------------------
# calibration moments -> sensitivity allocator
# ---------------------------------------------------------------------------


def calibration_moments(out_dir: str) -> dict:
    """Per-matrix E[x_j²] moments stored by a streamed run.

    Reads the ``xsq`` arrays out of every journaled shard and averages them
    per matrix name across blocks — the override system (StreamPlan /
    ``core.allocate``) keys layers by matrix name, so the result plugs
    straight into ``allocate(..., col_weights=calibration_moments(dir))``.
    Returns ``{}`` when no ledger/shards exist (or none carry moments):
    callers then fall back to plain weight-MSE sensitivity
    (``col_weight=None`` — the documented fallback parity).
    """
    ledger = Ledger(out_dir)
    if not ledger.load():
        return {}
    sums: dict[str, np.ndarray] = {}
    counts: dict[str, int] = {}
    for entry in ledger.entries:
        path = os.path.join(out_dir, entry["shard"])
        try:
            tree = read_shard(path)
        except Exception:
            continue
        for k, v in tree.items():
            if k.endswith("/xsq"):
                name = k[:-len("/xsq")]
                arr = np.asarray(v, np.float64)
                if name in sums and sums[name].shape == arr.shape:
                    sums[name] = sums[name] + arr
                    counts[name] += 1
                elif name not in sums:
                    sums[name] = arr
                    counts[name] = 1
    return {name: (sums[name] / counts[name]).astype(np.float32)
            for name in sums}


def allocate_from_artifact(weights: dict, budget_bytes: int, out_dir: str,
                           **kw):
    """Sensitivity allocation driven by a streamed run's calibration ledger.

    Feeds :func:`calibration_moments` (the E[x_j²] each matrix was actually
    calibrated against) into ``core.allocate`` as per-layer ``col_weights``.
    Layer names match moments exactly or by their ``.../<matrix>`` suffix
    (streamed moments are per matrix *kind*, shared across blocks).  A layer
    with no usable moment — missing, or shaped for a different fan-in —
    falls back to plain weight-MSE sensitivity (``col_weight=None``), so an
    artifact with no moments reproduces ``allocate(...)`` exactly.
    """
    from repro.core.allocate import allocate

    moments = calibration_moments(out_dir)
    col = {}
    for name, w in weights.items():
        m = moments.get(name)
        if m is None:
            m = moments.get(name.rsplit("/", 1)[-1])
        if m is not None and m.shape == (w.shape[1],):
            col[name] = m
    return allocate(weights, budget_bytes, col_weights=col, **kw)
