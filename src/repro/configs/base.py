"""Config schema for every architecture + the four benchmark input shapes."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp

from repro.core.lords import QuantSpec

__all__ = [
    "MoECfg", "MLACfg", "MambaCfg", "XLSTMCfg", "ModelConfig", "ShapeCfg",
    "SHAPES", "register", "get_config", "list_configs",
]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32
    every: int = 1                 # MoE layer every `every` layers (jamba: 2)
    # expert-parallel dispatch implementation:
    #   pjit      — scatter/gather + GSPMD-inferred collectives (portable)
    #   shard_map — explicit local dispatch + all_to_all over the EP axes
    #               (the §Perf fix for collective-bound MoE training)
    dispatch: str = "pjit"
    pad_experts_to: int | None = None  # pad so EP divides the device count


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None     # default ceil(d_model / 16)
    chunk: int = 128               # chunked associative scan length


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    proj_factor: float = 2.0
    conv_k: int = 4
    slstm_every: int = 8           # sLSTM block every N layers (rest mLSTM)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | ssm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense-MLP hidden (0 => none, e.g. xLSTM)
    vocab_size: int
    head_dim: int | None = None    # default d_model // num_heads
    attn_kind: str = "gqa"         # gqa | mla
    mla: MLACfg | None = None
    moe: MoECfg | None = None
    mamba: MambaCfg | None = None
    xlstm: XLSTMCfg | None = None
    # per-layer mixer pattern, tiled over num_layers.
    #   e.g. jamba: ('attn','mamba','mamba','mamba',...)  period 8
    #        xlstm: ('mlstm',)*7 + ('slstm',)
    layer_pattern: tuple = ("attn",)
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    input_kind: str = "tokens"     # tokens | embeddings (vlm/audio stubs)
    quant: QuantSpec = QuantSpec(method="lords", codebook="nf4",
                                 block_size=128, mode="peft")
    # decode KV-cache storage: 'bf16' (dense) or 'int8' (per-head symmetric
    # int8 + f32 scales — ~2x less cache HBM traffic per decoded token)
    kv_cache_dtype: str = "bf16"
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots (checkpoint dot outputs)
    vocab_pad_multiple: int = 2048
    micro_tokens: int = 8192       # per-device live tokens per microbatch
    notes: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return int(math.ceil(self.vocab_size / m) * m)

    @property
    def pattern(self) -> tuple:
        """Full per-layer mixer pattern of length num_layers (tiled)."""
        p = self.layer_pattern
        reps = math.ceil(self.num_layers / len(p))
        return (p * reps)[: self.num_layers]

    @property
    def period(self) -> int:
        """Scan period: LCM of mixer pattern and MoE interleave."""
        p = len(self.layer_pattern)
        if self.moe is not None and self.moe.every > 1:
            p = math.lcm(p, self.moe.every)
        if self.num_layers % p:
            # fall back to unrolled if the pattern doesn't tile evenly
            return self.num_layers
        return p

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    def layer_kinds(self, period_idx: int = 0) -> list[tuple[str, str]]:
        """[(mixer_kind, mlp_kind)] for one scan period."""
        out = []
        for i in range(self.period):
            layer = period_idx * self.period + i
            mixer = self.pattern[i % len(self.pattern)]
            if self.moe is not None and layer % self.moe.every == (self.moe.every - 1 if self.moe.every > 1 else 0):
                mlp = "moe"
            elif self.d_ff > 0:
                mlp = "dense"
            else:
                mlp = "none"
            out.append((mixer, mlp))
        return out

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, Any] = {}


def _norm(name: str) -> str:
    return name.lower().replace("-", "").replace("_", "").replace(".", "")


def register(fn):
    """Decorator: configs/archs.py registers a zero-arg builder."""
    _REGISTRY[_norm(fn.__name__.removesuffix("_cfg"))] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers submodule registration)

    key = _norm(name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[key]()


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(cfg().name for cfg in _REGISTRY.values())
