"""All architecture configs: 10 assigned + the paper's own 3 models.

Exact dimensions from the assignment table; sources cited inline.  Each
builder also has a ``smoke()`` reduced variant (same family, tiny dims) used
by per-arch CPU smoke tests.
"""
from __future__ import annotations

from repro.configs.base import (
    MLACfg,
    MambaCfg,
    ModelConfig,
    MoECfg,
    XLSTMCfg,
    register,
)

# ---------------------------------------------------------------------------
# assigned pool
# ---------------------------------------------------------------------------


@register
def minicpm3_4b_cfg() -> ModelConfig:
    # [hf:openbmb/MiniCPM3-4B] dense with MLA; 62L d=2560 40H d_ff=6400 v=73448
    return ModelConfig(
        name="minicpm3-4b", family="dense", num_layers=62, d_model=2560,
        num_heads=40, num_kv_heads=40, d_ff=6400, vocab_size=73448,
        attn_kind="mla",
        mla=MLACfg(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                   qk_rope_dim=32, v_head_dim=64),
        head_dim=96, rope_theta=10000.0,
        notes="MLA dims follow MiniCPM3-4B HF config.",
    )


@register
def minitron_4b_cfg() -> ModelConfig:
    # [arXiv:2407.14679] pruned nemotron; 32L d=3072 24H kv=8 ff=9216 v=256000
    return ModelConfig(
        name="minitron-4b", family="dense", num_layers=32, d_model=3072,
        num_heads=24, num_kv_heads=8, d_ff=9216, vocab_size=256000,
        rope_theta=10000.0,
    )


@register
def llama3_405b_cfg() -> ModelConfig:
    # [arXiv:2407.21783] 126L d=16384 128H kv=8 ff=53248 v=128256
    return ModelConfig(
        name="llama3-405b", family="dense", num_layers=126, d_model=16384,
        num_heads=128, num_kv_heads=8, d_ff=53248, vocab_size=128256,
        head_dim=128, rope_theta=500000.0,
    )


@register
def granite_20b_cfg() -> ModelConfig:
    # [arXiv:2405.04324] code model, MQA; 52L d=6144 48H kv=1 ff=24576 v=49152
    return ModelConfig(
        name="granite-20b", family="dense", num_layers=52, d_model=6144,
        num_heads=48, num_kv_heads=1, d_ff=24576, vocab_size=49152,
        rope_theta=10000.0,
    )


@register
def phi35_moe_42b_a6_6b_cfg() -> ModelConfig:
    # [hf:microsoft/Phi-3.5-MoE-instruct] 32L d=4096 32H kv=8, 16e top-2
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32,
        d_model=4096, num_heads=32, num_kv_heads=8, d_ff=0, vocab_size=32064,
        moe=MoECfg(num_experts=16, top_k=2, d_ff=6400),
        rope_theta=10000.0, micro_tokens=2048,
    )


@register
def kimi_k2_1t_a32b_cfg() -> ModelConfig:
    # [arXiv:2501.kimi2 per assignment] 61L d=7168 64H kv=8, 384e top-8
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", num_layers=61, d_model=7168,
        num_heads=64, num_kv_heads=8, d_ff=0, vocab_size=163840,
        moe=MoECfg(num_experts=384, top_k=8, d_ff=2048),
        head_dim=112, rope_theta=50000.0, micro_tokens=2048,
        notes="per-assignment GQA kv=8 (not MLA); head_dim=7168/64=112.",
    )


@register
def internvl2_1b_cfg() -> ModelConfig:
    # [arXiv:2404.16821] InternViT frontend (STUB) + InternLM2 backbone
    return ModelConfig(
        name="internvl2-1b", family="vlm", num_layers=24, d_model=896,
        num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151655,
        input_kind="embeddings", rope_theta=10000.0,
        notes="vision frontend stubbed: input_specs() supplies patch embeds.",
    )


@register
def xlstm_1_3b_cfg() -> ModelConfig:
    # [arXiv:2405.04517] 48L d=2048, 4 heads; mLSTM:sLSTM = 7:1; no dense FFN
    return ModelConfig(
        name="xlstm-1.3b", family="ssm", num_layers=48, d_model=2048,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
        layer_pattern=("mlstm",) * 7 + ("slstm",),
        xlstm=XLSTMCfg(proj_factor=2.0, conv_k=4, slstm_every=8),
    )


@register
def musicgen_medium_cfg() -> ModelConfig:
    # [arXiv:2306.05284] decoder-only over EnCodec tokens (frontend STUB)
    return ModelConfig(
        name="musicgen-medium", family="audio", num_layers=48, d_model=1536,
        num_heads=24, num_kv_heads=24, d_ff=6144, vocab_size=2048,
        input_kind="embeddings", rope_theta=10000.0,
        vocab_pad_multiple=256,
        notes="EnCodec frame embeddings supplied by input_specs(); RoPE "
              "stands in for MusicGen's learned positions (noted deviation).",
    )


@register
def jamba_1_5_large_398b_cfg() -> ModelConfig:
    # [arXiv:2403.19887] 72L d=8192 64H kv=8; attn:mamba 1:7; MoE 16e top-2
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid", num_layers=72,
        d_model=8192, num_heads=64, num_kv_heads=8, d_ff=24576,
        vocab_size=65536,
        layer_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        moe=MoECfg(num_experts=16, top_k=2, d_ff=24576, every=2),
        mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
        rope_theta=10000.0, micro_tokens=2048,
        notes="MoE every 2nd layer (d_ff shared with dense layers); attn at "
              "layer 4 of each 8-layer period, per Jamba block spec.",
    )


# ---------------------------------------------------------------------------
# the paper's own models (Tables 1-6)
# ---------------------------------------------------------------------------


@register
def llama3_8b_cfg() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
        rope_theta=500000.0,
    )


@register
def qwen3_8b_cfg() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b", family="dense", num_layers=36, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=12288, vocab_size=151936,
        head_dim=128, rope_theta=1000000.0,
    )


@register
def qwen3_4b_cfg() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", family="dense", num_layers=36, d_model=2560,
        num_heads=32, num_kv_heads=8, d_ff=9728, vocab_size=151936,
        head_dim=128, rope_theta=1000000.0,
    )


# ---------------------------------------------------------------------------
# reduced smoke variants (same family, tiny dims) for CPU tests
# ---------------------------------------------------------------------------


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Shrink any config to CPU-smoke size, preserving its family structure."""
    kw = dict(
        num_layers=max(2, min(cfg.period, 8)) if cfg.period > 1 else 2,
        d_model=64, num_heads=4, num_kv_heads=min(4, cfg.num_kv_heads),
        d_ff=128 if cfg.d_ff else 0, vocab_size=256, head_dim=16,
        vocab_pad_multiple=64,
    )
    if cfg.period > 1:
        kw["num_layers"] = cfg.period  # one full heterogeneous period
    if cfg.attn_kind == "mla":
        kw["mla"] = MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                           qk_rope_dim=8, v_head_dim=16)
    if cfg.moe is not None:
        kw["moe"] = MoECfg(num_experts=4, top_k=min(2, cfg.moe.top_k),
                           d_ff=64, every=cfg.moe.every)
    if cfg.mamba is not None:
        kw["mamba"] = MambaCfg(d_state=8, d_conv=4, expand=2, chunk=16)
    if cfg.xlstm is not None:
        kw["xlstm"] = XLSTMCfg(proj_factor=2.0, conv_k=4,
                               slstm_every=cfg.xlstm.slstm_every)
    # smaller quant blocks so tiny matrices still have >1 block
    kw["quant"] = cfg.quant.with_(block_size=32, rank=2)
    return cfg.with_(**kw)
