"""repro.configs — architecture registry + benchmark shapes.

``get_config('<arch-id>')`` returns the exact assigned config; arch ids use
dashes (underscores accepted).  ``smoke_variant(cfg)`` shrinks any config for
CPU tests while preserving family structure.
"""
from repro.configs import archs  # noqa: F401  (registers all builders)
from repro.configs.archs import smoke_variant  # noqa: F401
from repro.configs.base import (  # noqa: F401
    SHAPES,
    MLACfg,
    MambaCfg,
    ModelConfig,
    MoECfg,
    ShapeCfg,
    XLSTMCfg,
    get_config,
    list_configs,
)
