"""Table 2 analogue — impact of iterative refinement (Alg. 1).

QuantError (nuclear norm of residual) before vs after refinement at two
(equivalent) block sizes; paper claim: refinement strictly reduces error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import MODULE_SHAPES, realistic_weight
from repro.core import metrics, ptq_refine, quantize
from repro.core.scaling import scale_matrix


def _err(w, res):
    s = scale_matrix(res.b, res.a)
    codes = quantize.unpack_codes(res.q_packed, "nf4")
    w_hat = quantize.dequantize_codes(codes, s, "nf4")
    return float(metrics.quant_error(w, w_hat))


def run(report):
    key = jax.random.PRNGKey(1)
    for block in (32, 64):
        tot0 = tot1 = 0.0
        for mod, (n, m) in list(MODULE_SHAPES.items())[:4]:
            key, sub = jax.random.split(key)
            w = realistic_weight(sub, n // 2, m // 2)
            res0 = ptq_refine(w, "nf4", block, steps=0)
            res1 = ptq_refine(w, "nf4", block, steps=300, lr=0.05)
            e0, e1 = _err(w, res0), _err(w, res1)
            tot0, tot1 = tot0 + e0, tot1 + e1
        report(f"refine_t2/block{block}/init", 0.0, f"quant_error={tot0:.2f}")
        report(f"refine_t2/block{block}/refined", 0.0,
               f"quant_error={tot1:.2f} (delta={100*(tot0-tot1)/tot0:.1f}%)")
        assert tot1 < tot0, "refinement must reduce QuantError"
