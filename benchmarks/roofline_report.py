"""Render dryrun_results.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report dryrun_results.json
"""
from __future__ import annotations

import json
import sys


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_gb(x):
    return f"{x/1e9:.2f}"


def render(records, mesh="16x16"):
    rows = []
    hdr = ("| arch | shape | kind | fits (arg+tmp GB) | t_compute | t_memory "
           "| t_collective | bound | MODEL/HLO flops | roofline frac |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in records:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | - | FAIL | | | | "
                        f"{r['status'][:40]} | | |")
            continue
        rl = r["roofline"]
        mem = r.get("memory", {})
        argt = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0))
        fits = "✓" if argt < 16e9 else "✗"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_gb(mem.get('argument_size_in_bytes', 0))}+"
            f"{fmt_gb(mem.get('temp_size_in_bytes', 0))} {fits} | "
            f"{fmt_s(rl['t_compute_s'])} | {fmt_s(rl['t_memory_s'])} | "
            f"{fmt_s(rl['t_collective_s'])} | {rl['bottleneck']} | "
            f"{rl['model_flops_ratio']:.2f} | "
            f"{rl['model_fraction_of_roofline']:.3f} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        records = json.load(f)
    ok = sum(1 for r in records if r.get("status") == "ok")
    print(f"## records: {len(records)} ({ok} ok)\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"### mesh {mesh}\n")
        print(render(records, mesh))
        print()


if __name__ == "__main__":
    main()
