"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Select subsets with
``python -m benchmarks.run [table ...]`` (default: all); unknown table
names fail fast before any benchmark runs, and ``--list`` prints the
table registry and exits.

  ptq          Table 1  — PTQ method comparison (4-bit)
  refine       Table 2  — iterative-refinement impact
  lowbit       Table 3/9 — ultra-low-bit mixed precision
  qat          Table 4  — INT4-QAT vs LoRDS-QAT
  peft         Table 5  — QLoRA / LoftQ / LoRDS fine-tuning
  rank         Fig. 3   — ΔW singular spectrum
  kernels      Fig. 2/Table 6 — kernel cost comparison
  error_ratio  Table 8  — per-module error reduction (incl. LoRDS†)
  serve        §4.4     — decode fast path (prefill ms, decode tok/s,
                          bytes/token roofline) -> BENCH_serve.json
  train        §3.3/3.4 — training fast path (fused vs dequant backward:
                          step ms, tokens/s, bwd bytes) -> BENCH_train.json
  attn         §4.4     — attention fast path (fused flash kernels vs the
                          einsum oracle: prefill ms, decode tok/s, cache
                          bytes/token bf16 vs int8) -> BENCH_attn.json
  chaos        §4.4     — graceful degradation under injected faults
                          (clean-vs-chaos differential trace replay,
                          terminal statuses, failure isolation, page-pool
                          audit) -> BENCH_serve.json ("chaos" section)
  ptq_stream   §4.1     — crash-safe layer-streaming PTQ (kill/resume
                          parity at every block boundary, bitrot + OOM
                          watchdog drills, forced-8-device sharded
                          kill/resume/mesh-shrink) -> BENCH_ptq_stream.json
  dist_chaos   §4.4     — elastic distributed recovery drills under a
                          forced 8-device mesh (device-loss resharding,
                          desync rollback, host-crash resume, engine
                          elastic rebuild, sharded-PTQ crash + mesh
                          shrink; every invariant self-asserted)
                          -> BENCH_dist_chaos.json
"""
from __future__ import annotations

import sys
import time

TABLES = ["ptq", "refine", "lowbit", "qat", "peft", "rank", "kernels",
          "error_ratio", "serve", "train", "attn", "chaos", "ptq_stream",
          "dist_chaos"]


def main() -> None:
    argv = sys.argv[1:]
    if "--list" in argv or "-l" in argv:
        for t in TABLES:
            print(t)
        return
    want = argv or TABLES
    unknown = [t for t in want if t not in TABLES]
    if unknown:
        raise SystemExit(
            f"unknown table(s): {', '.join(unknown)} — pick from: "
            f"{', '.join(TABLES)} (or --list)")
    rows = []

    def report(name: str, us_per_call: float, derived: str):
        row = f"{name},{us_per_call:.1f},{derived}"
        rows.append(row)
        print(row, flush=True)

    print("name,us_per_call,derived")
    for table in want:
        mod = __import__(f"benchmarks.bench_{table}", fromlist=["run"])
        t0 = time.time()
        mod.run(report)
        print(f"# bench_{table} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
