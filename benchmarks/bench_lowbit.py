"""Tables 3 & 9 analogue — ultra-low-bit mixed precision (NF4 front / NF2 back).

Error-reduction ratio vs NF4-block baseline for LoftQ / QPiSSA / LoRDS at
4 / 3 / 2.5 / 2.25 / 2 bits.  Paper claim: LoRDS's advantage *grows* as bits
shrink (~3× the adapter baselines at 2-bit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import MODULE_SHAPES, realistic_weight
from repro.core import baselines, lut, metrics, ptq_refine, quantize
from repro.core.scaling import scale_matrix

BLOCK = 64
# a "layer" here is one matrix; mixed precision assigns nf4/nf2 across the
# module list in the paper's front-fraction pattern
BITS = {"4": 4.0, "3": 3.0, "2.5": 2.5, "2.25": 2.25, "2": 2.0}


def run(report):
    key = jax.random.PRNGKey(2)
    mats = []
    for mod, (n, m) in MODULE_SHAPES.items():
        key, sub = jax.random.split(key)
        mats.append((mod, realistic_weight(sub, n // 2, m // 2)))

    results = {}
    for bname, bits in BITS.items():
        sched = lut.mixed_precision_schedule(len(mats), bits)
        r_lords, r_loftq, r_qpissa = [], [], []
        for (mod, w), cb in zip(mats, sched):
            qb, sb = quantize.quantize_blockwise(w, BLOCK, cb)
            w_nf = quantize.dequantize_blockwise(qb, sb, BLOCK, cb)

            res = ptq_refine(w, cb, BLOCK, steps=250, lr=0.05)
            s = scale_matrix(res.b, res.a)
            codes = quantize.unpack_codes(res.q_packed, cb)
            w_lords = quantize.dequantize_codes(codes, s, cb)
            r_lords.append(float(metrics.error_reduction_ratio(
                w, w_lords, w_nf)))

            ql, sl, lb, la = baselines.loftq_init(w, BLOCK, cb, r=8, iters=3)
            w_l = quantize.dequantize_blockwise(ql, sl, BLOCK, cb) + lb @ la
            r_loftq.append(float(metrics.error_reduction_ratio(w, w_l, w_nf)))

            qp, sp, pb, pa = baselines.qpissa_init(w, BLOCK, cb, r=8)
            w_p = quantize.dequantize_blockwise(qp, sp, BLOCK, cb) + pb @ pa
            r_qpissa.append(float(metrics.error_reduction_ratio(w, w_p, w_nf)))

        avg = lambda xs: sum(xs) / len(xs)
        results[bname] = (avg(r_lords), avg(r_loftq), avg(r_qpissa))
        report(f"lowbit_t3/{bname}bit/lords", 0.0,
               f"err_reduction={avg(r_lords):.4f}")
        report(f"lowbit_t3/{bname}bit/loftq", 0.0,
               f"err_reduction={avg(r_loftq):.4f}")
        report(f"lowbit_t3/{bname}bit/qpissa", 0.0,
               f"err_reduction={avg(r_qpissa):.4f}")

    # paper ordering checks: LoRDS leads at low bits, advantage grows
    assert results["2"][0] > results["2"][1], "LoRDS must beat LoftQ at 2bit"
    gap4 = results["4"][0] - results["4"][1]
    gap2 = results["2"][0] - results["2"][1]
    report("lowbit_t3/gap_growth", 0.0,
           f"lords_minus_loftq@4bit={gap4:.4f} @2bit={gap2:.4f}")
