"""Tables 3 & 9 analogue + the sub-4-bit storage Pareto frontier.

Section 1 (paper Table 3/9): error-reduction ratio vs the NF4-block baseline
for LoftQ / QPiSSA / LoRDS at 4 / 3 / 2.5 / 2.25 / 2 average bits, with the
mixed-precision schedule's *realized* average bits in every row label (the
requested width can be unrealizable over a finite layer count).  Paper
claim: LoRDS's advantage *grows* as bits shrink (~3x the adapter baselines
at 2-bit).

Section 2 (sub-4-bit frontier): accuracy-vs-bytes/token sweep over storage
configs — blockwise NF4, uniform LoRDS at nf4/nf3/nf2 (true cross-byte
packing: 8 nf3 codes in 3 bytes), the paper's mixed nf4/nf2 schedules, and
the sensitivity-driven per-layer allocator at the uniform-nf3 budget.
Bytes/token = stored bytes (decode streams every weight byte once per
token).  Self-asserting:

  * uniform 3-bit stores strictly fewer bytes/token than uniform 4-bit
    (regression guard on the nf3 byte-per-code packing bug), and
  * LoRDS still leads LoftQ at 2-bit.

Writes ``BENCH_lowbit.json``.  Standalone (``--smoke`` = reduced sweep for
CI):

    PYTHONPATH=src python -m benchmarks.bench_lowbit [--smoke]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import MODULE_SHAPES, realistic_weight
from repro.core import allocate, baselines, lut, metrics, ptq_refine, quantize
from repro.core.scaling import scale_matrix

BLOCK = 64
RANK = 8
# a "layer" here is one matrix; mixed precision assigns nf4/nf2 across the
# module list in the paper's front-fraction pattern
BITS = {"4": 4.0, "3": 3.0, "2.5": 2.5, "2.25": 2.25, "2": 2.0}


def _lords_dequant(w, cb, steps, rank=RANK):
    res = ptq_refine(w, cb, BLOCK, rank=rank, steps=steps, lr=0.05)
    s = scale_matrix(res.b, res.a)
    codes = quantize.unpack_codes(res.q_packed, cb)
    return quantize.dequantize_codes(codes, s, cb)


def _rel_err(w, w_hat) -> float:
    return float(metrics.quant_error(w, w_hat))


def _blockwise_bytes(n: int, k: int, cb: str) -> int:
    ps = quantize.pack_spec(cb)
    return n * ps.packed_width(k) + n * (k // BLOCK) * 4  # codes + f32 scales


def _table3(mats, steps, report):
    results = {}
    for bname, bits in BITS.items():
        sched = lut.mixed_precision_schedule(len(mats), bits)
        label = f"{bname}bit(real={lut.realized_bits(sched):.2f})"
        r_lords, r_loftq, r_qpissa = [], [], []
        for (mod, w), cb in zip(mats, sched):
            qb, sb = quantize.quantize_blockwise(w, BLOCK, cb)
            w_nf = quantize.dequantize_blockwise(qb, sb, BLOCK, cb)

            w_lords = _lords_dequant(w, cb, steps)
            r_lords.append(float(metrics.error_reduction_ratio(
                w, w_lords, w_nf)))

            ql, sl, lb, la = baselines.loftq_init(w, BLOCK, cb, r=8, iters=3)
            w_l = quantize.dequantize_blockwise(ql, sl, BLOCK, cb) + lb @ la
            r_loftq.append(float(metrics.error_reduction_ratio(w, w_l, w_nf)))

            qp, sp, pb, pa = baselines.qpissa_init(w, BLOCK, cb, r=8)
            w_p = quantize.dequantize_blockwise(qp, sp, BLOCK, cb) + pb @ pa
            r_qpissa.append(float(metrics.error_reduction_ratio(w, w_p, w_nf)))

        avg = lambda xs: sum(xs) / len(xs)
        results[bname] = (avg(r_lords), avg(r_loftq), avg(r_qpissa))
        report(f"lowbit_t3/{label}/lords", 0.0,
               f"err_reduction={avg(r_lords):.4f}")
        report(f"lowbit_t3/{label}/loftq", 0.0,
               f"err_reduction={avg(r_loftq):.4f}")
        report(f"lowbit_t3/{label}/qpissa", 0.0,
               f"err_reduction={avg(r_qpissa):.4f}")

    # paper ordering checks: LoRDS leads at low bits, advantage grows
    assert results["2"][0] > results["2"][1], "LoRDS must beat LoftQ at 2bit"
    gap4 = results["4"][0] - results["4"][1]
    gap2 = results["2"][0] - results["2"][1]
    report("lowbit_t3/gap_growth", 0.0,
           f"lords_minus_loftq@4bit={gap4:.4f} @2bit={gap2:.4f}")
    return {k: {"lords": v[0], "loftq": v[1], "qpissa": v[2]}
            for k, v in results.items()}


def _pareto(mats, steps, report):
    """Accuracy-vs-bytes/token sweep (decode streams every stored weight
    byte once per generated token)."""
    n_weights = sum(w.size for _, w in mats)
    rows = []

    def add(config, byts, rel_err):
        rows.append({
            "config": config,
            "bytes_per_token": int(byts),
            "bytes_per_weight": byts / n_weights,
            "rel_err": rel_err,
        })
        report(f"lowbit_pareto/{config}", 0.0,
               f"bytes/tok={byts} B/weight={byts / n_weights:.4f} "
               f"rel_err={rel_err:.4f}")

    # blockwise NF4 — the 4-bit baseline serving format
    errs, byts = [], 0
    for _, w in mats:
        qb, sb = quantize.quantize_blockwise(w, BLOCK, "nf4")
        errs.append(_rel_err(
            w, quantize.dequantize_blockwise(qb, sb, BLOCK, "nf4")))
        byts += _blockwise_bytes(*w.shape, "nf4")
    add("blockwise-nf4", byts, sum(errs) / len(errs))

    # uniform LoRDS at each codebook (true sub-byte packing for nf3/nf2);
    # quality is the error-reduction ratio vs the *same-codebook* blockwise
    # baseline — the paper's per-width quality metric, which lets storage
    # points at different widths be compared at "matched quality"
    uniform = {}
    for cb in ("nf4", "nf3", "nf2"):
        errs, reds, byts = [], [], 0
        for _, w in mats:
            w_hat = _lords_dequant(w, cb, steps)
            qb, sb = quantize.quantize_blockwise(w, BLOCK, cb)
            w_nf = quantize.dequantize_blockwise(qb, sb, BLOCK, cb)
            errs.append(_rel_err(w, w_hat))
            reds.append(float(metrics.error_reduction_ratio(w, w_hat, w_nf)))
            byts += allocate.layer_bytes(*w.shape, cb, RANK)
        uniform[cb] = {"bytes": byts, "err": sum(errs) / len(errs),
                       "err_reduction": sum(reds) / len(reds)}
        add(f"lords-{cb}", byts, sum(errs) / len(errs))

    # mixed nf4/nf2 schedules (paper Table 3 storage points)
    for bname in ("3", "2.5"):
        sched = lut.mixed_precision_schedule(len(mats), BITS[bname])
        errs, byts = [], 0
        for (mod, w), cb in zip(mats, sched):
            errs.append(_rel_err(w, _lords_dequant(w, cb, steps)))
            byts += allocate.layer_bytes(*w.shape, cb, RANK)
        add(f"lords-mixed{bname}(real={lut.realized_bits(sched):.2f})",
            byts, sum(errs) / len(errs))

    # sensitivity-driven allocator at the uniform-nf3 budget: per-layer
    # (codebook, rank) chosen by measured damage, same global bytes
    weights = {mod: w for mod, w in mats}
    plan = allocate.allocate(weights, uniform["nf3"]["bytes"],
                             ranks=(4, RANK, 16), block_size=BLOCK)
    errs = []
    for layer in plan.layers:
        errs.append(_rel_err(
            weights[layer.name],
            _lords_dequant(weights[layer.name], layer.codebook, steps,
                           rank=layer.rank)))
    add(f"lords-alloc(avg={plan.avg_bits():.2f}b)", plan.total_bytes,
        sum(errs) / len(errs))

    # the fixed packing bug: nf3 used to store 1 byte/code, i.e. *more*
    # than nf4's half byte — true 3-bit storage must undercut 4-bit ...
    assert uniform["nf3"]["bytes"] < uniform["nf4"]["bytes"], \
        "3-bit config must store fewer bytes/token than 4-bit"
    # ... at matched quality: the per-width error-reduction ratio may not
    # regress as bits shrink (paper: LoRDS's edge *grows* at low bits)
    assert (uniform["nf3"]["err_reduction"]
            >= uniform["nf4"]["err_reduction"] - 1e-3), \
        "3-bit err_reduction must match 4-bit's"
    assert plan.total_bytes <= uniform["nf3"]["bytes"], \
        "allocator must respect its budget"
    return rows


def _model_roofline(report):
    """Model-scale storage roofline (shape math only, no weights): true
    bytes/weight incl. scales for the llama3-8b serving configs."""
    from benchmarks.bench_serve import weight_stream_bytes
    from repro.configs import get_config

    base = get_config("llama3-8b")
    out = {}
    for cb in ("nf4", "nf3", "nf2"):
        q = base.quant.with_(codebook=cb)
        if lut.codebook_bits(cb) < 4:
            # the sub-4-bit serving configs store B/A in bf16 (what
            # `serve --codebook nf3` defaults to) — factor overhead halves
            q = q.with_(scale_dtype=jnp.bfloat16)
        wb = weight_stream_bytes(base.with_(quant=q))
        out[cb] = wb
        report(f"lowbit_roofline/llama3-8b/{cb}", 0.0,
               f"packed={wb['packed']} bytes/weight="
               f"{wb['bytes_per_weight']:.4f}")
    assert out["nf3"]["q_codes"] * 8 == out["nf3"]["q_weights"] * 3, \
        "nf3 codes must be exactly 3 bits/weight on disk"
    assert out["nf3"]["bytes_per_weight"] <= 0.40, \
        "nf3 serving config must be <= 0.40 bytes/weight incl. scales"
    assert out["nf3"]["packed"] < out["nf4"]["packed"], \
        "nf3 must stream fewer weight bytes/token than nf4"
    return {cb: {k: v for k, v in wb.items()} for cb, wb in out.items()}


def run(report, *, smoke: bool = False, json_path: str = "BENCH_lowbit.json"):
    key = jax.random.PRNGKey(2)
    mats = []
    shapes = dict(MODULE_SHAPES)
    if smoke:
        shapes = {k: shapes[k] for k in ("Q", "K", "Gate", "Down")}
    steps = 40 if smoke else 250
    for mod, (n, m) in shapes.items():
        key, sub = jax.random.split(key)
        mats.append((mod, realistic_weight(sub, n // 2, m // 2)))

    table3 = _table3(mats, steps, report)
    pareto = _pareto(mats, steps, report)
    roofline = _model_roofline(report)

    with open(json_path, "w") as f:
        json.dump({"smoke": smoke, "refine_steps": steps,
                   "table3": table3, "pareto": pareto,
                   "roofline_llama3_8b": roofline}, f, indent=2)
    report("lowbit/json", 0.0, f"wrote {json_path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep (fewer modules / refine steps)")
    ap.add_argument("--json", default="BENCH_lowbit.json")
    args = ap.parse_args(argv)

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(report, smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
