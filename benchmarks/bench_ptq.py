"""Table 1 analogue — PTQ method comparison at 4-bit, parity budgets.

Per real-module-shaped matrix (llama3-8b modules / 4): quant-error-reduction
ratio vs plain block-wise NF4 for GPTQ / AWQ / SmoothRot / LoftQ /
LoRDS(init) / LoRDS(refined), plus tiny-LM eval-loss after whole-model PTQ.
Expected ordering (paper): LoRDS(refined) best at equal float budget.

Also checks the layer-streaming pipeline against the in-memory path
(identical packed codes, block by block) and records the streaming peak
footprint vs the dense model — persisted to ``BENCH_ptq.json``.
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    MODULE_SHAPES,
    eval_loss,
    quantize_model_weights,
    realistic_weight,
    timer,
    tiny_lm,
    train_tiny,
)
from repro.core import QuantSpec, baselines, metrics, ptq_refine, quantize
from repro.core.scaling import scale_matrix
from repro.data import synthetic_activations

BLOCK = 64


def _dequant_lords(res):
    s = scale_matrix(res.b, res.a)
    codes = quantize.unpack_codes(res.q_packed, "nf4")
    return quantize.dequantize_codes(codes, s, "nf4")


def run(report):
    key = jax.random.PRNGKey(0)
    ratios = {m: [] for m in ("gptq", "awq", "smoothrot", "loftq",
                              "lords_init", "lords_refined")}
    for mod, (n, m) in MODULE_SHAPES.items():
        key, sub = jax.random.split(key)
        w = realistic_weight(sub, n, m)
        x = jnp.asarray(synthetic_activations(256, m, seed=hash(mod) % 997))

        qb, sb = quantize.quantize_blockwise(w, BLOCK, "nf4")
        w_nf4 = quantize.dequantize_blockwise(qb, sb, BLOCK, "nf4")

        outs = {}
        qg, sg = baselines.gptq_quantize(w, x, BLOCK, "nf4")
        outs["gptq"] = quantize.dequantize_blockwise(qg, sg, BLOCK, "nf4")
        qa, sa, sc = baselines.awq_quantize(w, x, BLOCK, "nf4", n_grid=10)
        outs["awq"] = quantize.dequantize_blockwise(qa, sa, BLOCK,
                                                    "nf4") / sc[None, :]
        qs, ss, c, signs = baselines.smoothrot_quantize(w, x, BLOCK, "nf4")
        outs["smoothrot"] = baselines.smoothrot_dequantize(
            qs, ss, c, signs, BLOCK, "nf4")
        ql, sl, lb, la = baselines.loftq_init(w, BLOCK, "nf4", r=8, iters=3)
        outs["loftq"] = quantize.dequantize_blockwise(ql, sl, BLOCK,
                                                      "nf4") + lb @ la
        res0 = ptq_refine(w, "nf4", BLOCK, steps=0)
        outs["lords_init"] = _dequant_lords(res0)
        res = ptq_refine(w, "nf4", BLOCK, steps=250, lr=0.05)
        outs["lords_refined"] = _dequant_lords(res)

        y_ref = x @ w.T
        mse_nf4 = float(jnp.mean((x @ w_nf4.T - y_ref) ** 2))
        for name, w_hat in outs.items():
            r = float(metrics.error_reduction_ratio(w, w_hat, w_nf4))
            # GPTQ/AWQ optimize calibration-output MSE, not weight error —
            # report both metrics (the paper's PPL tracks the output metric)
            mse = float(jnp.mean((x @ w_hat.T - y_ref) ** 2))
            ratios[name].append(r)
            report(f"ptq_t1/{mod}/{name}", 0.0,
                   f"err_reduction={r:.4f} out_mse_vs_nf4={mse/mse_nf4:.3f}")

    for name, rs in ratios.items():
        report(f"ptq_t1/avg/{name}", 0.0,
               f"err_reduction_avg={sum(rs)/len(rs):.4f}")

    # whole-model PTQ -> eval loss (PPL direction)
    fp_quant = QuantSpec(method="none", mode="qat")
    cfg_fp = tiny_lm(fp_quant)
    with timer() as t:
        params_fp, _ = train_tiny(cfg_fp, steps=150, lr=2e-3)
    base = eval_loss(params_fp, cfg_fp)
    report("ptq_t1/model/fp", t.dt * 1e6, f"eval_loss={base:.4f}")

    # use NF2 so quantization damage (and LoRDS recovery) is visible on a
    # tiny underfit model — at NF4 the noise floor hides any difference
    for name, q in [
        ("nf2", QuantSpec(method="blockwise", codebook="nf2", block_size=32,
                          mode="frozen")),
        ("lords_nf2", QuantSpec(method="lords", codebook="nf2", block_size=32,
                                rank=4, mode="frozen")),
    ]:
        refine = 150 if name.startswith("lords") else 0
        params_q = quantize_model_weights(params_fp, cfg_fp, q, refine=refine)
        cfg_q = cfg_fp.with_(quant=q)
        l = eval_loss(params_q, cfg_q)
        report(f"ptq_t1/model/{name}", 0.0, f"eval_loss={l:.4f}")

    # streamed vs in-memory PTQ: identical packed codes + peak footprint
    streaming = _streaming_equivalence(report)

    out = {"err_reduction": {k: [float(v) for v in vs]
                             for k, vs in ratios.items()},
           "streaming": streaming}
    with open("BENCH_ptq.json", "w") as f:
        json.dump(out, f, indent=1)
    report("ptq_t1/json", 0.0, "wrote BENCH_ptq.json")


def _streaming_equivalence(report) -> dict:
    """Layer-streaming pipeline vs the in-memory path: the packed artifact
    must be bit-identical and the streaming peak must undercut the dense
    model footprint.  Returns the record persisted to BENCH_ptq.json."""
    from repro.ptq_stream import (ResidualMLPSource, StreamPlan,
                                  quantize_dense_blocks, read_shard,
                                  stream_quantize)
    from repro.ptq_stream.shards import shard_name

    with tempfile.TemporaryDirectory() as root:
        src = ResidualMLPSource.create(
            os.path.join(root, "model"), num_blocks=6, d=96, d_ff=192,
            tokens=48, seed=0)
        plan = StreamPlan(block_size=32, rank=4, refine_steps=10,
                          memory_budget=int(src.dense_bytes() * 0.95))
        out = os.path.join(root, "stream")
        with timer() as t:
            s = stream_quantize(src, out, plan)
        ref, x_digest = quantize_dense_blocks(src, plan)
        identical = s["status"] == "complete"
        for i, want in enumerate(ref):
            got = read_shard(os.path.join(out, shard_name(i)))
            identical &= sorted(got) == sorted(want) and all(
                np.array_equal(got[k], want[k]) for k in want)
        identical &= s["x_final_digest"] == x_digest
        rec = {"bit_identical": bool(identical),
               "peak_bytes": s["peak_bytes"],
               "dense_bytes": src.dense_bytes(),
               "budget_bytes": plan.memory_budget,
               "wall_s": t.dt}
    assert rec["bit_identical"], "streamed artifact diverged from in-memory"
    assert rec["peak_bytes"] <= rec["budget_bytes"], rec
    report("ptq_t1/streaming", rec["wall_s"] * 1e6,
           f"bit_identical={rec['bit_identical']} "
           f"peak_bytes={rec['peak_bytes']} dense_bytes={rec['dense_bytes']}")
    return rec
