"""Training benchmark — fused-backward fast path (QAT / PEFT).

Measures full train steps (fused fwd **and** bwd through the kernel-dispatch
custom VJPs) against the legacy dequantize-then-einsum backward, and derives
the analytic backward roofline — HBM bytes the backward moves per step for
the packed (fused) path vs the dense path that materializes Ŵ — then writes
``BENCH_train.json``:

    PYTHONPATH=src python -m benchmarks.bench_train [--arch llama3-8b]
        [--seq-len 16] [--batch 2] [--steps 2] [--backend interpret]

Also runnable via ``python -m benchmarks.run train`` or ``make bench-train``.
CPU step times are plumbing (CI smoke), not speed — the roofline section is
the hardware-independent content.  As a side effect the representative-layer
backward autotune populates the transposed (``lords_t``) tile-table entries,
persisted when ``REPRO_AUTOTUNE_CACHE`` is set.
"""
from __future__ import annotations

import argparse
import json
import time

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)
import jax
import jax.numpy as jnp

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.core import peft
from repro.core.quantize import pack_spec
from repro.data import SyntheticLM, make_batch_iterator
from repro.kernels import dispatch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_plan
from repro.models import model_init, split_tree
from repro.optim import adamw_init

_BM = 128  # M-tile the analytic roofline assumes (kernel default)


def _lords_linears(cfg) -> list[tuple[int, int, int]]:
    """(n, k, r) of every LoRDS linear in the model, from abstract shapes."""
    ptree = jax.eval_shape(
        lambda k: model_init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))
    values, _ = split_tree(ptree)
    leaves = jax.tree_util.tree_flatten_with_path(values)[0]
    by_parent: dict[tuple, dict] = {}
    for path, leaf in leaves:
        name = str(getattr(path[-1], "key", "")) if path else ""
        if name in ("b", "a"):
            by_parent.setdefault(tuple(str(p) for p in path[:-1]), {})[name] = (
                leaf.shape)
    out = []
    for shapes in by_parent.values():
        if "b" in shapes and "a" in shapes:
            # leading dims are layer-stack / expert-stack replicas
            bsh, ash = shapes["b"], shapes["a"]
            reps = 1
            for d in bsh[:-2]:
                reps *= d
            out.extend([(bsh[-2], ash[-1], bsh[-1])] * reps)
    return out


def backward_bytes(cfg, tokens: int) -> dict:
    """Analytic per-step backward HBM weight-side traffic (bytes).

    fused: the transposed-matmul kernel streams packed codes + (B, A) once
    per ``_BM``-token M-tile; the grad-reduction kernel streams them once
    total (its M axis is the innermost reduction).  QAT additionally reads
    the f32 master W (for the Eq. 5 residual) and writes dW — parameter
    traffic that exists on every path.

    dense: dequantizes once, then materializes the (N, K) f32 temporaries
    the old backward built — lut[Q] values, Ŵ, and ∂S — each written and
    read back once (6·4·N·K bytes of pure temporary traffic on top of the
    packed reads).  ``peak_temp_bytes`` is the largest concurrently-live
    (N, K) f32 temporary footprint: Ŵ + ∂S for dense, the (N/bn)·r·K
    partial-dA accumulator for fused (~r/bn of one weight matrix).
    """
    ps = pack_spec(cfg.quant.codebook)
    mtiles = -(-tokens // _BM)
    mode = cfg.quant.mode
    fused = dense = fused_peak = dense_peak = 0
    for n, k, r in _lords_linears(cfg):
        q_b = n * ps.packed_width(k)  # true packed bytes per row
        ba_b = 4 * (n * r + r * k)
        w_b = 4 * n * k
        fused += (mtiles + 1) * (q_b + ba_b)
        dense += q_b + ba_b + 6 * w_b
        fused_peak = max(fused_peak, 4 * (-(-n // 256)) * r * k)
        dense_peak = max(dense_peak, 2 * w_b)
        if mode == "qat":
            fused += 2 * w_b          # master-W read + dW write (param grad)
            dense += 4 * w_b          # same + the resid (N,K) temporary
    return {"fused": fused, "dense": dense,
            "fused_peak_temp": fused_peak, "dense_peak_temp": dense_peak}


def _time_train_steps(cfg, shape, backend: str, steps: int) -> dict:
    """Jit one train step via build_plan and time it (post-compile)."""
    mesh = make_host_mesh()
    plan = build_plan(cfg, mesh, shape, kernel_backend=backend)
    key = jax.random.PRNGKey(0)
    values, _ = split_tree(model_init(key, cfg))
    trainable, frozen = peft.partition(values, cfg.quant)
    opt = adamw_init(trainable)
    source = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                         seed=0)
    it = make_batch_iterator(source, 0)
    with mesh:
        step_jit = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                           out_shardings=plan.out_shardings,
                           donate_argnums=plan.donate_argnums)
        _, batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        trainable, opt, metrics = step_jit(trainable, frozen, opt, batch)
        jax.block_until_ready(metrics["loss"])  # compile + warm
        t0 = time.perf_counter()
        for _ in range(steps):
            _, batch = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            trainable, opt, metrics = step_jit(trainable, frozen, opt, batch)
            jax.block_until_ready(metrics["loss"])
        dt = (time.perf_counter() - t0) / steps
    tokens = shape.global_batch * shape.seq_len
    return {"step_ms": round(dt * 1e3, 3),
            "tokens_per_s": round(tokens / dt, 3),
            "loss": round(float(metrics["loss"]), 4)}


def _autotune_transposed(cfg, backend: str) -> tuple | None:
    """Populate (and persist, via REPRO_AUTOTUNE_CACHE) a representative
    transposed-kernel tile entry through the backward autotuner, timed on
    the same fused backend the benchmark runs.  Autotune keys carry no
    platform dimension, so interpreter-timed entries are placeholders that
    exercise the persistence wiring (what CI asserts) — don't point a TPU
    run's cache file at one produced on CPU; re-running this benchmark
    with ``--backend pallas`` on the TPU overwrites them with real
    timings."""
    n, k, _ = max(_lords_linears(cfg), key=lambda s: s[0] * s[1])
    key = jax.random.PRNGKey(0)
    from repro.core import init_quantized_linear

    spec = cfg.quant.with_(mode="peft", compute_dtype=jnp.float32)
    params = init_quantized_linear(key, n, k, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, k))
    candidates = ([(8, 128, 256), (8, 128, 512)] if backend == "interpret"
                  else None)  # pallas: the full default candidate set
    best, _ = dispatch.autotune_qmatmul_bwd(
        params, x, spec, n, k, backend=backend,
        candidates=candidates, iters=1 if backend == "interpret" else 3)
    return best


def bench(arch: str = "llama3-8b", *, smoke: bool = True, seq_len: int = 16,
          batch: int = 2, steps: int = 2,
          backend: str | None = None) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    shape = ShapeCfg("bench", seq_len, batch, "train")
    tokens = batch * seq_len
    fused_backend = backend or "interpret"
    runs: dict = {}
    roofline: dict = {}
    for mode in ("peft", "qat"):
        mcfg = cfg.with_(quant=cfg.quant.with_(mode=mode))
        runs[mode] = {
            "fused": _time_train_steps(mcfg, shape, fused_backend, steps),
            "dequant": _time_train_steps(mcfg, shape, "dense", steps),
        }
        roofline[mode] = backward_bytes(mcfg, tokens)
    best = _autotune_transposed(cfg, fused_backend)
    return {
        "arch": cfg.name, "smoke": smoke, "seq_len": seq_len, "batch": batch,
        "steps": steps, "fused_backend": fused_backend,
        "bwd_weight_bytes": roofline, "runs": runs,
        "autotuned_transposed_tiles": list(best) if best else None,
    }


def run(report):
    """benchmarks.run entry point: smoke-scale train + BENCH_train.json."""
    rec = bench()
    for mode, r in rec["runs"].items():
        for kind, t in r.items():
            report(f"train/step/{mode}_{kind}", t["step_ms"] * 1e3,
                   f"step_ms={t['step_ms']} tokens_per_s={t['tokens_per_s']}")
        rl = rec["bwd_weight_bytes"][mode]
        report(f"train/bwd_bytes/{mode}", float(rl["fused"]),
               f"dense={rl['dense']} ratio={rl['dense'] / rl['fused']:.2f}")
    with open("BENCH_train.json", "w") as f:
        json.dump(rec, f, indent=1)
    report("train/json", 0.0, "wrote BENCH_train.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--steps", type=int, default=2)
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "interpret"],
                    help="fused backend to time against the dense baseline")
    ap.add_argument("--out", default="BENCH_train.json")
    args = ap.parse_args(argv)
    rec = bench(args.arch, smoke=not args.full, seq_len=args.seq_len,
                batch=args.batch, steps=args.steps, backend=args.backend)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec["runs"], indent=1))
    for mode, rl in rec["bwd_weight_bytes"].items():
        print(f"[bench_train] {mode}: bwd bytes fused={rl['fused']} "
              f"dense={rl['dense']} ({rl['dense'] / rl['fused']:.2f}x); "
              f"peak temp fused={rl['fused_peak_temp']} "
              f"dense={rl['dense_peak_temp']} -> {args.out}")


if __name__ == "__main__":
    main()
