"""Table 8 analogue — per-module error-reduction ratio incl. LoRDS†.

LoRDS at parity budget vs LoftQ/QPiSSA (which carry +rank-16 adapters), and
the parameter-aligned LoRDS† (r = parity + r_q) that matches their budget.
Paper claim: LoRDS beats adapters even WITHOUT alignment; LoRDS† roughly
doubles the margin.
"""
from __future__ import annotations

import jax

from benchmarks.common import MODULE_SHAPES, realistic_weight
from repro.core import baselines, metrics, ptq_refine, quantize
from repro.core.scaling import scale_matrix

BLOCK = 64
RQ = 8


def _lords_ratio(w, w_nf4, extra_rank=0):
    res = ptq_refine(w, "nf4", BLOCK, steps=250, lr=0.05,
                     extra_rank=extra_rank)
    s = scale_matrix(res.b, res.a)
    codes = quantize.unpack_codes(res.q_packed, "nf4")
    w_hat = quantize.dequantize_codes(codes, s, "nf4")
    return float(metrics.error_reduction_ratio(w, w_hat, w_nf4))


def run(report):
    key = jax.random.PRNGKey(5)
    sums = dict(loftq=0.0, qpissa=0.0, lords=0.0, lords_dagger=0.0)
    for mod, (n, m) in MODULE_SHAPES.items():
        key, sub = jax.random.split(key)
        w = realistic_weight(sub, n // 2, m // 2)
        qb, sb = quantize.quantize_blockwise(w, BLOCK, "nf4")
        w_nf4 = quantize.dequantize_blockwise(qb, sb, BLOCK, "nf4")

        ql, sl, lb, la = baselines.loftq_init(w, BLOCK, "nf4", RQ, iters=3)
        r_loftq = float(metrics.error_reduction_ratio(
            w, quantize.dequantize_blockwise(ql, sl, BLOCK, "nf4") + lb @ la,
            w_nf4))
        qp, sp, pb, pa = baselines.qpissa_init(w, BLOCK, "nf4", RQ)
        r_qpissa = float(metrics.error_reduction_ratio(
            w, quantize.dequantize_blockwise(qp, sp, BLOCK, "nf4") + pb @ pa,
            w_nf4))
        r_lords = _lords_ratio(w, w_nf4)
        r_dag = _lords_ratio(w, w_nf4, extra_rank=RQ)

        for k, v in (("loftq", r_loftq), ("qpissa", r_qpissa),
                     ("lords", r_lords), ("lords_dagger", r_dag)):
            sums[k] += v
        report(f"err_t8/{mod}", 0.0,
               f"loftq={r_loftq:.3f} qpissa={r_qpissa:.3f} "
               f"lords={r_lords:.3f} lords+={r_dag:.3f}")
    n_mod = len(MODULE_SHAPES)
    report("err_t8/avg", 0.0,
           " ".join(f"{k}={v / n_mod:.4f}" for k, v in sums.items()))
    assert sums["lords_dagger"] > sums["lords"], "LoRDS† must add margin"
