"""Shared benchmark infrastructure.

The paper's quality tables use 4–8B checkpoints + WikiText/PTB; this
container is CPU-only and offline, so every benchmark runs the same
*algorithms* at laptop scale and checks the paper's *orderings*:

  * realistic weight matrices: gaussian base + per-row/column scale structure
    + persistent outlier channels (what block-wise scaling actually fights),
  * tiny LMs trained on the deterministic synthetic stream for PPL-direction
    claims (eval loss == log-PPL on the held-out stream).
"""
from __future__ import annotations

import os
import time

os.environ.setdefault("REPRO_CPU_EXEC", "1")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig, ShapeCfg, get_config, smoke_variant
from repro.core import QuantSpec, peft
from repro.data import SyntheticLM
from repro.models import forward_train, model_init, split_tree

__all__ = ["realistic_weight", "tiny_lm", "train_tiny", "eval_loss",
           "quantize_model_weights", "timer", "MODULE_SHAPES"]

# llama3-8b module shapes scaled 1/4 (aspect ratios preserved) — Table 8 rows
MODULE_SHAPES = {
    "Q": (1024, 1024), "K": (256, 1024), "V": (256, 1024), "O": (1024, 1024),
    "Gate": (3584, 1024), "Up": (3584, 1024), "Down": (1024, 3584),
}


def realistic_weight(key, n, m, outlier_frac=0.01, outlier_gain=8.0,
                     row_scale_spread=1.0):
    """LLM-like weight: gaussian + log-normal row scales + outlier columns."""
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.normal(k1, (n, m)) * 0.02
    row_scale = jnp.exp(row_scale_spread
                        * jax.random.normal(k2, (n, 1)) * 0.4)
    w = base * row_scale
    n_out = max(1, int(m * outlier_frac))
    idx = jax.random.choice(k3, m, (n_out,), replace=False)
    w = w.at[:, idx].multiply(outlier_gain)
    return w


def tiny_lm(quant: QuantSpec, layers=2, d=128, heads=4, d_ff=256,
            vocab=512) -> ModelConfig:
    return get_config("llama3-8b").with_(
        name="tiny-lm", num_layers=layers, d_model=d, num_heads=heads,
        num_kv_heads=heads, d_ff=d_ff, vocab_size=vocab,
        vocab_pad_multiple=64, head_dim=d // heads, quant=quant, remat=False)


def _batches(cfg, shape, seed, n):
    src = SyntheticLM(cfg.vocab_size, shape.seq_len, shape.global_batch,
                      seed=seed)
    return [src.batch_at(i) for i in range(n)]


def train_tiny(cfg, steps=200, lr=2e-3, seed=0, seq=64, batch=8,
               params=None, schedule=None):
    """Train (or fine-tune) a tiny LM; returns (params, loss_history)."""
    from repro.optim import adamw_init, adamw_update

    shape = ShapeCfg("bench", seq, batch, "train")
    key = jax.random.PRNGKey(seed)
    if params is None:
        params, _ = split_tree(model_init(key, cfg))
    trainable, frozen = peft.partition(params, cfg.quant)
    opt = adamw_init(trainable)

    @jax.jit
    def step(trainable, opt, batch):
        def loss_fn(t):
            return forward_train(peft.combine(t, frozen), cfg, batch)[0]

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        new_t, new_opt, _ = adamw_update(trainable, grads, opt, lr)
        return new_t, new_opt, loss

    losses = []
    src = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        trainable, opt, loss = step(trainable, opt, b)
        losses.append(float(loss))
    return peft.combine(trainable, frozen), losses


def eval_loss(params, cfg, seed=10_000, n_batches=8, seq=64, batch=8):
    shape = ShapeCfg("eval", seq, batch, "train")
    src = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)

    @jax.jit
    def one(params, b):
        return forward_train(params, cfg, b)[0]

    tot = 0.0
    for i in range(n_batches):
        b = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        tot += float(one(params, b))
    return tot / n_batches


def quantize_model_weights(params_fp, cfg_fp, quant: QuantSpec, refine=0,
                           lr=0.05):
    """Re-quantize a trained fp tiny-LM's linears under ``quant``.

    Walks the param tree, replacing each {'w': ...} linear with the target
    format (blockwise / lords / adapters), optionally running Alg.-1
    refinement per matrix.  Returns params for cfg_fp.with_(quant=quant).
    """
    from repro.core import init_quantized_linear, ptq_refine
    from repro.core.quantize import pack_codes, quantize_codes
    from repro.core.scaling import scale_matrix

    key = jax.random.PRNGKey(0)

    def convert_one(w):
        n, m = w.shape
        if quant.method == "lords" and refine:
            res = ptq_refine(w, quant.codebook, quant.block_size,
                             rank=quant.rank, extra_rank=quant.extra_rank,
                             steps=refine, lr=lr)
            return {"q": res.q_packed, "b": res.b, "a": res.a}
        return init_quantized_linear(key, n, m, quant, w=w)

    def walk(node):
        if isinstance(node, dict) and set(node) >= {"w"} and hasattr(
                node["w"], "ndim") and len(node) <= 2:
            w = node["w"].astype(jnp.float32)
            if w.ndim == 2:
                return convert_one(w)
            if w.ndim == 3:  # stacked scan periods: vmap the conversion
                return jax.vmap(convert_one)(w)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params_fp)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
