"""Attention fast-path benchmark — fused flash kernels vs the einsum oracle.

Times the two serving-critical attention primitives through
``dispatch.qattention`` at three sequence lengths, fused (interpret-mode
Pallas kernel bodies — the code TPU runs) against the materializing einsum
ref path, and derives the analytic per-token decode cache traffic for bf16
vs int8 KV (the hardware-independent roofline content; CPU timings are for
plumbing and ordering, not speed).  Writes ``BENCH_attn.json``:

    PYTHONPATH=src python -m benchmarks.bench_attn [--batch 2] [--heads 8]
        [--kv-heads 2] [--head-dim 64] [--seqs 128,256,512]

Also runnable via ``python -m benchmarks.run attn`` / ``make bench-attn``.
"""
from __future__ import annotations

import argparse
import json
import time

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)
import jax
import jax.numpy as jnp

from repro.kernels.dispatch import qattention
from repro.models.common import kv_quantize


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args).block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def cache_bytes_per_token(cap: int, nkv: int, hd: int) -> dict:
    """Decode-step cache HBM reads per sequence: K+V, bf16 vs int8+scale."""
    return {
        "bf16": 2 * cap * nkv * hd * 2,
        "int8": 2 * cap * nkv * (hd * 1 + 4),   # codes + one f32 scale
    }


def bench(*, batch: int = 2, heads: int = 8, kv_heads: int = 2,
          head_dim: int = 64, seqs=(128, 256, 512), iters: int = 3) -> dict:
    scale = 1.0 / head_dim ** 0.5
    prefill, decode = {}, {}
    for s in seqs:
        q = jax.random.normal(jax.random.PRNGKey(0),
                              (batch, s, heads, head_dim), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(1),
                              (batch, s, kv_heads, head_dim), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(2),
                              (batch, s, kv_heads, head_dim), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                               (batch, s))
        row = {}
        for name, backend in (("fused", "interpret"), ("einsum", "ref")):
            fn = jax.jit(lambda qq, kk, vv, pp, b=backend: qattention(
                "prefill", qq, kk, vv, pp, logit_scale=scale, backend=b))
            row[f"{name}_ms"] = round(_time(fn, q, k, v, pos,
                                            iters=iters) * 1e3, 3)
        prefill[str(s)] = row

        qd = jax.random.normal(jax.random.PRNGKey(3),
                               (batch, heads, head_dim), jnp.float32)
        kcod, ks = kv_quantize(k)
        vcod, vs = kv_quantize(v)
        posd = jnp.full((batch,), s - 1, jnp.int32)
        row = {}
        for kv, args in (("bf16", (qd, k, v, posd)),
                         ("int8", (qd, kcod, vcod, posd, ks, vs))):
            for name, backend in (("fused", "interpret"), ("einsum", "ref")):
                fn = jax.jit(lambda *a, b=backend: qattention(
                    "decode", *a, logit_scale=scale, backend=b))
                t = _time(fn, *args, iters=iters)
                row[f"{name}_kv_{kv}_tok_s"] = round(batch / t, 1)
        row["bytes_per_token"] = cache_bytes_per_token(s, kv_heads, head_dim)
        decode[str(s)] = row
    return {
        "batch": batch, "heads": heads, "kv_heads": kv_heads,
        "head_dim": head_dim, "seqs": list(seqs),
        "prefill": prefill, "decode": decode,
    }


def run(report):
    """benchmarks.run entry point: small shapes, BENCH_attn.json."""
    rec = bench(seqs=(64, 128, 256), iters=2)
    for s, row in rec["prefill"].items():
        report(f"attn/prefill_ms/s{s}", row["fused_ms"],
               f"einsum_ms={row['einsum_ms']}")
    for s, row in rec["decode"].items():
        bpt = row["bytes_per_token"]
        report(f"attn/decode_tok_s/s{s}", row["fused_kv_int8_tok_s"],
               f"fused_bf16={row['fused_kv_bf16_tok_s']} "
               f"einsum_int8={row['einsum_kv_int8_tok_s']} "
               f"bytes_bf16={bpt['bf16']} bytes_int8={bpt['int8']}")
    with open("BENCH_attn.json", "w") as f:
        json.dump(rec, f, indent=1)
    report("attn/json", 0.0, "wrote BENCH_attn.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=64)
    ap.add_argument("--seqs", default="128,256,512")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default="BENCH_attn.json")
    args = ap.parse_args(argv)
    seqs = tuple(int(s) for s in args.seqs.split(","))
    rec = bench(batch=args.batch, heads=args.heads, kv_heads=args.kv_heads,
                head_dim=args.head_dim, seqs=seqs, iters=args.iters)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec["decode"], indent=1))
    print(f"[bench_attn] -> {args.out}")


if __name__ == "__main__":
    main()
