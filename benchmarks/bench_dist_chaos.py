"""Distributed chaos benchmark — elastic recovery drills (PR 10).

Everything here runs under a forced-8-device host mesh in a *subprocess*
(the device count must be set before jax initializes), and every scenario
**self-asserts** its recovery invariant before any number is reported —
the emitted ``BENCH_dist_chaos.json`` is a proof-of-recovery artifact, not
a scoreboard:

  * **train/device_loss** — an injected ``dist.device_loss`` mid-run
    rebuilds a smaller host mesh (2×4 → 1×4), elastically restores from
    the latest checkpoint, reseeks the data iterator, and finishes; the
    final loss must land within tolerance of the fault-free run.
  * **train/desync** — a per-replica digest divergence injected at the
    comparison point is detected within one ``desync_every`` interval and
    rolled back to the latest checkpoint; the run still completes.
  * **train/host_crash** — ``dist.host_crash`` kills the run with no
    graceful save; a fresh ``run_training`` on the same ``ckpt_dir``
    resumes from the latest checkpoint and completes.
  * **engine/device_loss** — the serving engine absorbs a device loss via
    elastic mesh rebuild + param reshard + full recompute, and its output
    tokens stay **bit-identical** to the single-mesh run.
  * **engine/collective_timeout + straggler** — injected collective
    timeouts ride the retry/requeue path; per-shard straggler injections
    are flagged by the watchdog in ``stats['straggler_flags']``.
  * **ptq/sharded kill+resume** — the data-parallel streaming PTQ killed
    at a block boundary and resumed across a mesh shrink reproduces the
    single-host bytes exactly (the full boundary sweep lives in
    ``bench_ptq_stream``; this drill repeats the crash-plus-shrink case so
    the dist-chaos artifact is self-contained).

Run directly (``python -m benchmarks.bench_dist_chaos``) or through the
registry (``python -m benchmarks.run dist_chaos``); either way the parent
process only orchestrates and the asserting child writes
``BENCH_dist_chaos.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

_TRAIN_STEPS = 6


def _drills(root: str) -> dict:
    """The in-child body: every scenario asserts its invariant."""
    import jax
    import numpy as np

    from repro.configs import ShapeCfg, get_config, smoke_variant
    from repro.launch.engine import Engine, Request
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import run_training
    from repro.models import model_init, split_tree
    from repro.ptq_stream import (
        ResidualMLPSource,
        StreamPlan,
        audit_artifact,
        read_shard,
        stream_quantize,
    )
    from repro.ptq_stream.shards import shard_name
    from repro.robustness import FaultPlan, InjectedFault

    assert jax.device_count() >= 8, (
        f"dist chaos needs 8 forced devices, found {jax.device_count()}")
    results: dict = {"devices": jax.device_count(), "invariants": []}

    def invariant(name: str, ok: bool, detail: str):
        results["invariants"].append(
            {"name": name, "ok": bool(ok), "detail": detail})
        assert ok, f"invariant violated: {name} — {detail}"

    # ---- training ---------------------------------------------------------
    cfg = smoke_variant(get_config("llama3-8b")).with_(num_layers=2,
                                                       d_model=64)
    shape = ShapeCfg("t", 32, 4, "train")
    ref = run_training(cfg, shape, steps=_TRAIN_STEPS, lr=1e-3,
                       log_every=1000)
    ref_loss = float(ref["losses"][-1])

    out = run_training(cfg, shape, steps=_TRAIN_STEPS, lr=1e-3,
                       log_every=1000, mesh=make_host_mesh(data=2, model=4),
                       faults=FaultPlan(0, {"dist.device_loss": {"at": (3,)}}),
                       ckpt_dir=os.path.join(root, "ck_dl"), ckpt_every=2)
    loss = float(out["losses"][-1])
    tol = 0.15 * abs(ref_loss) + 0.05
    invariant(
        "train_device_loss_elastic_restore",
        out["status"] == "complete" and out["mesh_rebuilds"] == 1
        and out["resharded_restores"] == 1 and abs(loss - ref_loss) <= tol,
        f"status={out['status']} rebuilds={out['mesh_rebuilds']} "
        f"restores={out['resharded_restores']} final_mesh="
        f"{out['final_mesh']} loss={loss:.4f} vs fault-free {ref_loss:.4f} "
        f"(tol {tol:.4f})")
    results["train_device_loss"] = {
        "mesh_rebuilds": out["mesh_rebuilds"],
        "lost_devices": out["lost_devices"],
        "resharded_restores": out["resharded_restores"],
        "final_mesh": out["final_mesh"], "loss": loss, "ref_loss": ref_loss}

    out = run_training(
        cfg, shape, steps=_TRAIN_STEPS, lr=1e-3, log_every=1000,
        mesh=make_host_mesh(data=2, model=4), desync_every=2,
        faults=FaultPlan(0, {"dist.replica_desync":
                             {"prob": 1.0, "max_fires": 1, "only_index": 1}}),
        ckpt_dir=os.path.join(root, "ck_ds"), ckpt_every=1)
    invariant(
        "train_desync_detected_and_rolled_back",
        out["status"] == "complete" and out["desyncs_detected"] == 1
        and out["desync_rollbacks"] == 1,
        f"status={out['status']} detected={out['desyncs_detected']} "
        f"rollbacks={out['desync_rollbacks']} (interval=2 steps)")
    results["train_desync"] = {"detected": out["desyncs_detected"],
                               "rollbacks": out["desync_rollbacks"]}

    ck_hc = os.path.join(root, "ck_hc")
    crashed = False
    try:
        run_training(cfg, shape, steps=_TRAIN_STEPS, lr=1e-3, log_every=1000,
                     ckpt_dir=ck_hc, ckpt_every=2,
                     faults=FaultPlan(0, {"dist.host_crash": {"at": (3,)}}))
    except InjectedFault:
        crashed = True
    out = run_training(cfg, shape, steps=_TRAIN_STEPS, lr=1e-3,
                       log_every=1000, ckpt_dir=ck_hc, ckpt_every=2)
    invariant(
        "train_host_crash_resume",
        crashed and out["status"] == "complete",
        f"crashed={crashed} resume_status={out['status']} "
        f"resume_losses={len(out['losses'])}")
    results["train_host_crash"] = {"resumed_losses": len(out["losses"])}

    # ---- engine -----------------------------------------------------------
    ecfg = smoke_variant(get_config("llama3-8b")).with_(
        num_layers=2, d_model=64, kv_cache_dtype="int8")
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), ecfg))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, ecfg.vocab_size, (p,)).astype(np.int32)
               for p in (10, 6, 13)]
    geom = dict(slots=2, total_pages=12, page_size=8, max_pages=4, chunk=16,
                burst=4, kernel_backend="interpret", params=params)

    def reqs():
        return [Request(rid=i, tokens=p, max_new=5, arrival=0.0)
                for i, p in enumerate(prompts)]

    base = Engine(ecfg, **geom).run(reqs(), timeout_s=600)
    base_toks = {r["rid"]: r["tokens"] for r in base["records"]}

    eng = Engine(ecfg, mesh=make_host_mesh(data=2, model=4),
                 faults=FaultPlan(0, {"dist.device_loss": {"at": (3,)}}),
                 **geom)
    st = eng.run(reqs(), timeout_s=600)
    toks = {r["rid"]: r["tokens"] for r in st["records"]}
    invariant(
        "engine_device_loss_tokens_bit_identical",
        st["all_completed"] and st["mesh_rebuilds"] == 1
        and st["page_audit"]["ok"] and toks == base_toks,
        f"statuses={st['statuses']} rebuilds={st['mesh_rebuilds']} "
        f"lost={st['lost_devices']} audit_ok={st['page_audit']['ok']} "
        f"identical={toks == base_toks}")
    results["engine_device_loss"] = {
        "mesh_rebuilds": st["mesh_rebuilds"],
        "lost_devices": st["lost_devices"],
        "resharded_restores": st["resharded_restores"]}

    st = Engine(ecfg, faults=FaultPlan(
        0, {"dist.collective_timeout": {"at": (1,)},
            "dist.straggler": {"prob": 0.3, "delay_s": 0.05,
                               "max_fires": 3}}), **geom
                ).run(reqs(), timeout_s=600)
    toks = {r["rid"]: r["tokens"] for r in st["records"]}
    injected_flags = [f for f in st["straggler_flags"] if f["injected"]]
    invariant(
        "engine_collective_timeout_and_straggler",
        st["all_completed"] and st["collective_timeouts"] == 1
        and bool(injected_flags) and toks == base_toks,
        f"collective_timeouts={st['collective_timeouts']} "
        f"straggler_flags={len(injected_flags)} identical={toks == base_toks}")
    results["engine_faults"] = {
        "collective_timeouts": st["collective_timeouts"],
        "straggler_flags": len(injected_flags)}

    # ---- sharded streaming PTQ: crash + mesh shrink ----------------------
    src = ResidualMLPSource.create(os.path.join(root, "ptq_model"),
                                   num_blocks=4, d=64, d_ff=128, tokens=32,
                                   seed=0)
    plan = StreamPlan(block_size=32, rank=4, refine_steps=10)
    ref_dir = os.path.join(root, "ptq_single")
    stream_quantize(src, ref_dir, plan)
    out_dir = os.path.join(root, "ptq_sharded")
    killed = False
    try:
        stream_quantize(src, out_dir, plan,
                        faults=FaultPlan(17, {"ptq.kill_at_block":
                                              {"at": (2,)}}),
                        mesh=make_host_mesh(data=2, model=4))
    except InjectedFault:
        killed = True
    s = stream_quantize(src, out_dir, plan, resume=True,
                        mesh=make_host_mesh(data=1, model=4))
    identical = all(
        all(np.array_equal(a[k], b[k]) for k in a)
        for a, b in ((read_shard(os.path.join(ref_dir, shard_name(i))),
                      read_shard(os.path.join(out_dir, shard_name(i))))
                     for i in range(src.num_blocks)))
    invariant(
        "ptq_sharded_kill_mesh_shrink_bit_identical",
        killed and s["status"] == "complete" and s["reused"] == 2
        and identical and audit_artifact(out_dir, src, plan)["clean"],
        f"killed={killed} status={s['status']} reused={s['reused']} "
        f"bit_identical={identical} (killed on 2x4, resumed on 1x4, "
        "oracle = single host)")
    results["ptq_sharded"] = {"reused": s["reused"],
                              "recomputed": s["recomputed"],
                              "bit_identical": identical}
    return results


def child_main(argv):
    root, out_json = argv
    results = _drills(root)
    with open(out_json, "w") as f:
        json.dump(results, f, indent=1)
    ok = sum(1 for i in results["invariants"] if i["ok"])
    print(f"[bench_dist_chaos] {ok}/{len(results['invariants'])} "
          "recovery invariants hold")


def run_subprocess() -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    with tempfile.TemporaryDirectory() as root:
        out_json = os.path.join(root, "dist_chaos.json")
        subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_dist_chaos",
             "--child", root, out_json],
            env=env, check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        with open(out_json) as f:
            return json.load(f)


def run(report):
    """benchmarks.run entry point -> BENCH_dist_chaos.json."""
    results = run_subprocess()
    for inv in results["invariants"]:
        report(f"dist_chaos/{inv['name']}", 0.0,
               f"ok={inv['ok']} {inv['detail']}")
    with open("BENCH_dist_chaos.json", "w") as f:
        json.dump(results, f, indent=1)
    report("dist_chaos/json", 0.0,
           f"wrote BENCH_dist_chaos.json ({len(results['invariants'])} "
           "self-asserted invariants)")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if argv[:1] == ["--child"]:
        child_main(argv[1:])
        return

    def _p(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
    run(_p)


if __name__ == "__main__":
    main()
