"""Serving benchmark — decode fast path (§4.4 analogue).

Measures the end-to-end serve driver (prefill + single jitted on-device
generation loop) for both KV-cache formats and derives the analytic decode
roofline (HBM bytes per generated token: every weight byte streams once,
plus the live KV cache), then writes ``BENCH_serve.json``:

    PYTHONPATH=src python -m benchmarks.bench_serve [--arch llama3-8b]
        [--batch 2] [--prompt-len 16] [--gen 8] [--backend interpret]

Also runnable via ``python -m benchmarks.run serve``.  CPU numbers are for
plumbing (CI smoke), not speed — the roofline section is the
hardware-independent content.
"""
from __future__ import annotations

import argparse
import json
import time

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)
import jax
import jax.numpy as jnp

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.core.quantize import pack_spec
from repro.models import cache_init, model_init

_SCALE_LEAVES = ("b", "a", "s_blk")  # fold into Ŵ on the dense path


def _path_names(path) -> list[str]:
    """All key names along a tree path (the leaf itself usually sits behind
    a FlattenedIndexKey, so the meaningful name is an ancestor dict key)."""
    return [str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", ""))))
            for p in path]


def weight_stream_bytes(cfg) -> dict:
    """Per-decode-token weight HBM traffic: packed (as stored: uint8 codes +
    low-rank/block scales) vs dense (bf16 Ŵ).  The embedding table is
    excluded (decode gathers one row); a separate head counts (it's a full
    matmul every token).  Also breaks out the quantized linears alone:
    ``q_codes`` / ``q_scales`` bytes over ``q_weights`` logical weights
    (``bytes_per_weight`` = true storage incl. scales, e.g. nf3 = 0.375 +
    factor overhead)."""
    ps = pack_spec(cfg.quant.codebook)
    ptree = jax.eval_shape(
        lambda k: model_init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))
    leaves = jax.tree_util.tree_flatten_with_path(ptree)[0]
    packed = dense = q_codes = q_scales = q_weights = 0
    for path, leaf in leaves:
        names = _path_names(path)
        nbytes = leaf.size * leaf.dtype.itemsize
        if "embed" in names:
            continue
        if leaf.dtype == jnp.uint8:      # packed codes
            packed += nbytes
            q_codes += nbytes
            # logical weight count from the packed bytes (true bit packing:
            # e.g. 8 nf3 codes per 3 bytes)
            n_logical = leaf.size // ps.group_bytes * ps.group_codes
            q_weights += n_logical
            dense += n_logical * 2
        elif any(n in _SCALE_LEAVES for n in names):
            packed += nbytes             # rides along only on the fused path
            q_scales += nbytes
        else:                            # norms, head, dense convs, biases
            packed += nbytes
            dense += nbytes
    return {
        "packed": packed,
        "dense": dense,
        "q_codes": q_codes,
        "q_scales": q_scales,
        "q_weights": q_weights,
        "bytes_per_weight": ((q_codes + q_scales) / q_weights
                             if q_weights else 0.0),
    }


def cache_bytes(cfg, batch: int, capacity: int) -> int:
    """Live-cache HBM bytes read per decode step at capacity."""
    ctree = jax.eval_shape(lambda: cache_init(cfg, batch, capacity))
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree.leaves(ctree))


def paired_decode_tok_s(cfg, *, batch: int, prompt_len: int, gen: int,
                        backend: str | None, reps: int) -> dict:
    """Drift-free bf16-vs-int8 decode comparison: compile both KV formats'
    generation loops up front, then *interleave* their executions and
    min-time each — sequential serve_batch calls let allocator warm-up and
    background load drift bias whichever format runs second, which is
    exactly how the pre-fusion int8 'regression' hid inside noise."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_generate_plan
    from repro.models import cache_init, model_init, split_tree

    mesh = make_host_mesh()
    cap = prompt_len + gen
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    tok0 = jnp.zeros((batch,), jnp.int32)
    pos0 = jnp.full((batch,), prompt_len, jnp.int32)
    key = jax.random.PRNGKey(1)
    best = {}
    with mesh:
        fns, caches = {}, {}
        for kv in ("bf16", "int8"):
            c = cfg.with_(kv_cache_dtype=kv)
            plan = build_generate_plan(
                c, mesh, ShapeCfg("bench", cap, batch, "decode"), gen=gen,
                kernel_backend=backend)
            cache, _ = split_tree(cache_init(c, batch, cap))
            caches[kv] = [jax.tree.map(jnp.copy, cache) for _ in range(reps)]
            fns[kv] = jax.jit(plan.step_fn, donate_argnums=(2,)).lower(
                params, tok0, cache, pos0, key, None).compile()
            best[kv] = float("inf")
        for r in range(reps):
            for kv in ("bf16", "int8"):
                t0 = time.perf_counter()
                toks, _ = fns[kv](params, tok0, caches[kv][r], pos0, key,
                                  None)
                jax.block_until_ready(toks)
                best[kv] = min(best[kv], time.perf_counter() - t0)
    return {kv: batch * gen / t for kv, t in best.items()}


def paired_paged_tok_s(cfg, *, batch: int, prompt_len: int, gen: int,
                       page_size: int, backend: str | None,
                       reps: int) -> dict:
    """Paged-vs-contiguous decode at equal batch and capacity: compile the
    contiguous generate loop and the paged one up front, then interleave
    and min-time both.  The contiguous kv tile is pinned to ``page_size``
    so both paths sweep the cache in the same number of kernel tiles — the
    measured delta is the page-table indirection itself (scalar-prefetch
    lookup per tile + scatter writes), not tile geometry."""
    import numpy as np

    from repro.kernels import dispatch
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import (build_generate_plan,
                                    build_paged_generate_plan)
    from repro.models import (cache_init, model_init, paged_cache_init,
                              split_tree)

    mesh = make_host_mesh()
    cap = prompt_len + gen
    if cap % page_size:
        raise ValueError(f"capacity {cap} % page_size {page_size}")
    npages = cap // page_size
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    tok0 = jnp.zeros((batch,), jnp.int32)
    pos0 = jnp.full((batch,), prompt_len, jnp.int32)
    key = jax.random.PRNGKey(1)
    kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
    dispatch.register_tiles(
        "attn_gqa", cap, cfg.num_heads, cfg.head_dim,
        dispatch._ATTN_CODEBOOK, kv_dt,
        (dispatch.DECODE_ROWS, page_size, 1))
    best = {"contiguous": float("inf"), "paged": float("inf")}
    with mesh:
        plan_c = build_generate_plan(
            cfg, mesh, ShapeCfg("paired_paged_c", cap, batch, "decode"),
            gen=gen, kernel_backend=backend)
        cache, _ = split_tree(cache_init(cfg, batch, cap))
        caches = [jax.tree.map(jnp.copy, cache) for _ in range(reps)]
        fn_c = jax.jit(plan_c.step_fn, donate_argnums=(2,)).lower(
            params, tok0, cache, pos0, key, None).compile()

        total_pages = batch * npages + 1           # page 0 stays the dummy
        plan_p = build_paged_generate_plan(
            cfg, mesh, slots=batch, gen=gen, total_pages=total_pages,
            page_size=page_size, max_pages=npages, kernel_backend=backend)
        pools, _ = split_tree(paged_cache_init(cfg, total_pages, page_size))
        poolss = [jax.tree.map(jnp.copy, pools) for _ in range(reps)]
        pt = jnp.asarray(np.arange(1, total_pages, dtype=np.int32)
                         .reshape(batch, npages))
        fn_p = jax.jit(plan_p.step_fn, donate_argnums=(2,)).lower(
            params, tok0, pools, pt, pos0, key).compile()

        for r in range(reps):
            t0 = time.perf_counter()
            toks, _ = fn_c(params, tok0, caches[r], pos0, key, None)
            jax.block_until_ready(toks)
            best["contiguous"] = min(best["contiguous"],
                                     time.perf_counter() - t0)
            t0 = time.perf_counter()
            toks, _ = fn_p(params, tok0, poolss[r], pt, pos0, key)
            jax.block_until_ready(toks)
            best["paged"] = min(best["paged"], time.perf_counter() - t0)
    out = {kv: round(batch * gen / t, 3) for kv, t in best.items()}
    out["ratio"] = round(out["paged"] / out["contiguous"], 4)
    out["kv_cache_dtype"] = cfg.kv_cache_dtype
    out["page_size"] = page_size
    out["timing"] = f"paired-min-of-{reps}"
    return out


def make_trace(cfg, n: int, *, rate_hz: float, plen: tuple, gen: tuple,
               seed: int = 0, gen_skew: float = 1.0) -> list:
    """Poisson request trace: exponential inter-arrival gaps at ``rate_hz``,
    prompt lengths uniform over the inclusive ``plen`` range, generation
    budgets drawn from ``gen`` with a power-law skew — ``gen_skew`` > 1
    concentrates mass at short outputs with a rare long tail, the
    real-traffic shape that makes fixed-capacity servers scan their whole
    provisioned budget for requests that wanted a few tokens."""
    import numpy as np

    from repro.launch.engine import Request

    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, n))
    t -= t[0]
    glo, ghi = gen
    gens = [glo + int(round((ghi - glo) * rng.random() ** gen_skew))
            for _ in range(n)]
    return [
        Request(
            rid=i,
            tokens=rng.integers(
                0, cfg.vocab_size,
                (int(rng.integers(plen[0], plen[1] + 1)),)).astype(np.int32),
            max_new=gens[i],
            arrival=float(t[i]))
        for i in range(n)
    ]


def _fixed_capacity_baseline(cfg, trace, *, slots: int,
                             backend: str | None, params,
                             reps: int = 1) -> dict:
    """The server the engine replaces: requests grouped in arrival order
    into batches of ``slots``, every batch padded to the trace-max prompt
    and generation budget, batches run back-to-back (each starts once its
    last member has arrived).  Same scan pipeline as ``serve_batch`` but
    compiled once outside the timed region, so the engine's goodput win is
    admission/eviction + paging — not a compile-time artifact."""
    import numpy as np

    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_generate_plan, build_plan, \
        sample_token
    from repro.models import cache_init, split_tree

    mesh = make_host_mesh()
    pmax = max(len(r.tokens) for r in trace)
    gmax = max(r.max_new for r in trace)
    cap = pmax + gmax
    pre = build_plan(cfg, mesh, ShapeCfg("trace_pre", cap, slots, "prefill"),
                     kernel_backend=backend)
    genp = build_generate_plan(
        cfg, mesh, ShapeCfg("trace_dec", cap, slots, "decode"), gen=gmax - 1,
        kernel_backend=backend)
    positions = jnp.arange(cap, dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(
        jnp.where(positions < pmax, positions, -1), (slots, cap))
    pos0 = jnp.full((slots,), pmax, jnp.int32)
    key0, gkey = jax.random.split(jax.random.PRNGKey(1))
    with mesh:
        prefill = jax.jit(pre.step_fn, donate_argnums=(2,))
        generate = jax.jit(genp.step_fn, donate_argnums=(2,))

        def serve_group(prompts):
            cache, _ = split_tree(cache_init(cfg, slots, cap))
            logits, cache = prefill(
                params, {"tokens": jnp.asarray(prompts),
                         "positions": positions}, cache)
            tok = sample_token(logits[:, -1, : cfg.vocab_size], key0, 0.0)
            if gmax > 1:
                toks, cache = generate(params, tok, cache, pos0, gkey, None)
                jax.block_until_ready(toks)
            else:
                jax.block_until_ready(tok)

        serve_group(np.zeros((slots, cap), np.int32))   # compile, untimed
        wall, records = float("inf"), []
        for _ in range(reps):                           # best-of-reps
            rep_records = []
            t0 = time.perf_counter()
            for g0 in range(0, len(trace), slots):
                group = trace[g0: g0 + slots]
                start = max(r.arrival for r in group)
                lag = start - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
                prompts = np.zeros((slots, cap), np.int32)
                for i, r in enumerate(group):
                    prompts[i, : len(r.tokens)] = r.tokens
                serve_group(prompts)
                fin = time.perf_counter() - t0
                rep_records.extend({"rid": r.rid, "latency": fin - r.arrival}
                                   for r in group)
            rep_wall = time.perf_counter() - t0
            if rep_wall < wall:
                wall, records = rep_wall, rep_records
    lat = sorted(r["latency"] for r in records)

    def pct(p):
        return lat[min(int(p * len(lat)), len(lat) - 1)]

    gen_tokens = sum(r.max_new for r in trace)   # requested tokens only
    return {
        "wall_s": round(wall, 3),
        "goodput_tok_s": round(gen_tokens / max(wall, 1e-9), 3),
        "latency_p50_s": round(pct(0.50), 3),
        "latency_p99_s": round(pct(0.99), 3),
        "capacity": cap, "slots": slots,
    }


def replay_trace(cfg, trace, *, slots: int, page_size: int, max_pages: int,
                 total_pages: int, chunk: int, burst: int,
                 backend: str | None, seed: int = 0,
                 baseline_slots: int | None = None, reps: int = 1) -> dict:
    """Trace-replay benchmark: the continuous-batching engine vs the
    fixed-capacity batch baseline on the same Poisson trace and params.
    Both sides compile outside their timed regions (``Engine.warmup``
    compiles every step function up front).

    ``baseline_slots`` defaults to ``slots``; pass a smaller value for a
    *memory-normalized* comparison — the engine's page pool holds
    ``total_pages * page_size`` KV tokens while the baseline holds
    ``baseline_slots * (max_prompt + max_gen)``, so at an equal token
    budget paging admits more concurrent sequences than worst-case
    padding.  That extra concurrency, not per-step speed, is where the
    paged engine's goodput comes from."""
    from repro.launch.engine import Engine
    from repro.models import model_init, split_tree

    params, _ = split_tree(model_init(jax.random.PRNGKey(seed), cfg))
    eng = Engine(cfg, slots=slots, total_pages=total_pages,
                 page_size=page_size, max_pages=max_pages, chunk=chunk,
                 burst=burst, kernel_backend=backend, params=params)
    eng.warmup()
    stats = eng.run(trace, timeout_s=600.0)
    for _ in range(reps - 1):                          # best-of-reps
        again = eng.run(trace, timeout_s=600.0)
        if again["goodput_tok_s"] > stats["goodput_tok_s"]:
            stats = again
    base = _fixed_capacity_baseline(cfg, trace,
                                    slots=baseline_slots or slots,
                                    backend=backend, params=params,
                                    reps=reps)
    engine = {
        "wall_s": round(stats["wall_s"], 3),
        "goodput_tok_s": round(stats["goodput_tok_s"], 3),
        "latency_p50_s": round(stats["latency_p50_s"], 3),
        "latency_p99_s": round(stats["latency_p99_s"], 3),
        "prefill_ms": round(stats["prefill_ms"], 3),
        "decode_ms": round(stats["decode_ms"], 3),
        "chunk_steps": stats["chunk_steps"],
        "decode_steps": stats["decode_steps"],
        "evictions": stats["evictions"],
        "all_completed": stats["all_completed"],
    }
    return {
        "requests": len(trace),
        "prompt_lens": [int(len(r.tokens)) for r in trace],
        "gen_lens": [int(r.max_new) for r in trace],
        "kv_budget_tokens": {
            "engine": total_pages * page_size,
            "baseline": (baseline_slots or slots) * base["capacity"],
        },
        "engine": engine,
        "baseline": base,
        "goodput_ratio": round(engine["goodput_tok_s"]
                               / max(base["goodput_tok_s"], 1e-9), 3),
    }


def chaos_replay(cfg, trace, *, slots: int, page_size: int, max_pages: int,
                 total_pages: int, chunk: int, burst: int,
                 backend: str | None, faults, seed: int = 0,
                 admission_budget: int | None = None,
                 preemption_guard=None, timeout_s: float = 600.0) -> dict:
    """Clean-vs-chaos differential replay: run the same trace + params
    through the engine twice — once fault-free, once under ``faults`` (a
    :class:`repro.robustness.FaultPlan`) — and check the degradation
    contract:

      * ``Engine.run`` returns (never raises) under injection;
      * every request ends in exactly one terminal status;
      * requests the faults didn't touch (chaos status ``completed``)
        produce **token-for-token identical** output vs the clean run
        (greedy decoding, shared params — failure isolation, not just
        liveness);
      * the page-pool audit is clean after every recovery and at exit.

    Returns both runs' summaries, the goodput retained under chaos, the
    recovery counters and the fault-plan consult/fire log.
    """
    from repro.launch.engine import TERMINAL_STATUSES, Engine
    from repro.models import model_init, split_tree

    params, _ = split_tree(model_init(jax.random.PRNGKey(seed), cfg))
    eng = Engine(cfg, slots=slots, total_pages=total_pages,
                 page_size=page_size, max_pages=max_pages, chunk=chunk,
                 burst=burst, kernel_backend=backend, params=params,
                 admission_budget=admission_budget,
                 preemption_guard=preemption_guard)
    eng.audit_every = True
    clean = eng.run(trace, timeout_s=timeout_s)
    assert clean["all_completed"], clean["statuses"]
    clean_toks = {r["rid"]: r["tokens"] for r in clean["records"]}

    faults.reset()
    eng.faults = faults
    chaos = eng.run(trace, timeout_s=timeout_s)

    records = chaos["records"]
    assert len(records) == len(trace), (
        f"{len(records)} terminal records for {len(trace)} requests")
    bad = [r for r in records if r["status"] not in TERMINAL_STATUSES]
    assert not bad, f"non-terminal statuses: {bad}"
    mismatched = [r["rid"] for r in records if r["status"] == "completed"
                  and r["tokens"] != clean_toks[r["rid"]]]

    def summarize(stats):
        return {
            "goodput_tok_s": round(stats["goodput_tok_s"], 3),
            "wall_s": round(stats["wall_s"], 3),
            "statuses": stats["statuses"],
            "evictions": stats["evictions"],
        }

    return {
        "requests": len(trace),
        "clean": summarize(clean),
        "chaos": dict(summarize(chaos), **{
            k: chaos[k] for k in ("step_failures", "retries", "quarantined",
                                  "shed", "deadline_cancels",
                                  "nan_injections", "preempted", "drained")}),
        "identical_completed": not mismatched,
        "mismatched_rids": mismatched,
        "page_audit": chaos["page_audit"],
        "audit_failures": chaos.get("audit_failures", []),
        "faults": chaos["faults"],
        "goodput_retained": round(
            chaos["goodput_tok_s"] / max(clean["goodput_tok_s"], 1e-9), 3),
    }


def bench(arch: str = "llama3-8b", *, smoke: bool = True, batch: int = 2,
          prompt_len: int = 16, gen: int = 8,
          backend: str | None = None, reps: int = 1,
          head_dim: int | None = None,
          assert_int8: bool = False) -> dict:
    """``reps`` > 1 re-times decode via :func:`paired_decode_tok_s` (both
    KV formats' compiled loops interleaved, min-timed).  ``assert_int8``
    enforces the fused-attention roofline ordering: with the cache read
    in-kernel at int8 width, int8 KV decode must be at least as fast as
    bf16 (the pre-fusion einsum path *inverted* this by dequantizing the
    whole cache out of kernel every step).  ``head_dim`` overrides the
    smoke config's head_dim — the assertion config uses 64 so the decode
    step is attention-traffic-bound, the regime the roofline claim is
    about, rather than dominated by the tiny smoke model's linears."""
    from repro.launch.serve import serve_batch

    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    if head_dim is not None:
        cfg = cfg.with_(head_dim=head_dim)
    capacity = prompt_len + gen
    wb = weight_stream_bytes(cfg)
    roofline = {
        "weight_bytes_packed": wb["packed"],
        "weight_bytes_dense": wb["dense"],
        "cache_bytes_bf16": cache_bytes(
            cfg.with_(kv_cache_dtype="bf16"), batch, capacity),
        "cache_bytes_int8": cache_bytes(
            cfg.with_(kv_cache_dtype="int8"), batch, capacity),
    }
    roofline["bytes_per_token"] = {
        "packed_kv_bf16": wb["packed"] + roofline["cache_bytes_bf16"],
        "packed_kv_int8": wb["packed"] + roofline["cache_bytes_int8"],
        "dense_kv_bf16": wb["dense"] + roofline["cache_bytes_bf16"],
    }
    runs = {}
    for kv in ("bf16", "int8"):
        out = serve_batch(cfg, batch=batch, prompt_len=prompt_len, gen=gen,
                          kernel_backend=backend, kv_cache=kv)
        runs[kv] = {
            "prefill_ms": round(out["prefill_ms"], 3),
            "decode_ms": round(out["decode_ms"], 3),
            "decode_tok_s": round(out["decode_tok_s"], 3),
            "decode_loop": out["decode_loop"],
            "kernel_backend": out["kernel_backend"],
            "attention": out["attention"],
        }
    if reps > 1:
        paired = paired_decode_tok_s(cfg, batch=batch,
                                     prompt_len=prompt_len, gen=gen,
                                     backend=backend, reps=reps)
        for kv, tok_s in paired.items():
            runs[kv]["decode_tok_s"] = round(tok_s, 3)
            runs[kv]["timing"] = f"paired-min-of-{reps}"
    if assert_int8:
        assert runs["int8"]["decode_tok_s"] >= runs["bf16"]["decode_tok_s"], (
            "int8 KV decode regressed below bf16 despite the fused "
            f"attention path: {runs}")
    return {
        "arch": cfg.name, "smoke": smoke, "batch": batch,
        "prompt_len": prompt_len, "gen": gen, "capacity": capacity,
        "reps": reps, "roofline": roofline, "runs": runs,
    }


def run(report):
    """benchmarks.run entry point: smoke-scale serve + BENCH_serve.json.

    Pins the interpret backend so the fused attention + decode-GEMV kernel
    bodies execute, interleave-min-times 5 reps per KV format at the
    attention-bound shape (head_dim 64, capacity 128), and *asserts*
    int8-KV decode >= bf16 — the roofline ordering the fused path restores
    is enforced, not aspirational."""
    rec = bench(backend="interpret", reps=5, prompt_len=112, gen=16,
                head_dim=64, assert_int8=True)
    rl = rec["roofline"]
    for kv, r in rec["runs"].items():
        report(f"serve/decode_tok_s/kv_{kv}", r["decode_tok_s"],
               f"prefill_ms={r['prefill_ms']} decode_ms={r['decode_ms']} "
               f"loop={r['decode_loop']} backend={r['kernel_backend']} "
               f"attention={r['attention']}")
    for name, byts in rl["bytes_per_token"].items():
        report(f"serve/bytes_per_token/{name}", float(byts),
               f"roofline_us_v5e={byts/819e3:.2f}")

    # paged decode vs contiguous at equal batch/capacity (int8 KV,
    # attention-bound shape, tile-count-matched): the page indirection
    # must cost < 10% — recorded in the JSON, enforced here
    cfg = smoke_variant(get_config("llama3-8b")).with_(
        head_dim=64, kv_cache_dtype="int8")
    rec["paged_decode"] = paired_paged_tok_s(
        cfg, batch=2, prompt_len=240, gen=16, page_size=128,
        backend="interpret", reps=5)
    report("serve/paged_decode_tok_s", rec["paged_decode"]["paged"],
           f"contiguous={rec['paged_decode']['contiguous']} "
           f"ratio={rec['paged_decode']['ratio']}")
    assert rec["paged_decode"]["ratio"] >= 0.9, (
        "paged int8 decode fell >10% below contiguous at equal batch: "
        f"{rec['paged_decode']}")

    # Poisson trace replay: continuous-batching engine vs the
    # fixed-capacity batch baseline on a heavy-tailed trace (most
    # requests want a few tokens, the rare long one sets the budget the
    # baseline must scan for everyone).  Memory-normalized: the engine's
    # 12-page pool (96 KV tokens) runs 4 slots where worst-case padding
    # (cap ~ 48) affords the baseline 2.  The ref backend vectorizes
    # over batch (interpret python-loops the kernel grid, which hides
    # any batching win); best-of-3 replays per side tame CPU jitter
    trace = make_trace(cfg, 10, rate_hz=50.0, plen=(8, 16), gen=(2, 32),
                       seed=5, gen_skew=3.0)
    rec["trace"] = replay_trace(
        cfg, trace, slots=4, page_size=8, max_pages=6, total_pages=12,
        chunk=16, burst=16, backend="ref", baseline_slots=2, reps=3)
    eng, base = rec["trace"]["engine"], rec["trace"]["baseline"]
    assert eng["all_completed"] and eng["goodput_tok_s"] > 0, rec["trace"]
    assert rec["trace"]["goodput_ratio"] > 1.0, (
        "continuous-batching engine failed to beat the fixed-capacity "
        f"baseline on goodput: {rec['trace']}")
    report("serve/trace/engine_goodput_tok_s", eng["goodput_tok_s"],
           f"p50={eng['latency_p50_s']}s p99={eng['latency_p99_s']}s "
           f"evictions={eng['evictions']} chunk_steps={eng['chunk_steps']}")
    report("serve/trace/baseline_goodput_tok_s", base["goodput_tok_s"],
           f"p50={base['latency_p50_s']}s p99={base['latency_p99_s']}s "
           f"capacity={base['capacity']}")
    report("serve/trace/goodput_ratio", rec["trace"]["goodput_ratio"],
           "engine / fixed-capacity baseline, equal KV budget")

    # CI correctness smoke on the fused interpret backend: tiny pool
    # (7 usable pages vs ~10 pages of concurrent demand) so eviction,
    # recompute-readmission and chunked-prefill interleave all fire on
    # the real kernel bodies; completion is the assertion
    smoke_trace = make_trace(cfg, 4, rate_hz=50.0, plen=(10, 16),
                             gen=(16, 24), seed=3)
    rec["trace_smoke"] = replay_trace(
        cfg, smoke_trace, slots=2, page_size=8, max_pages=5,
        total_pages=8, chunk=16, burst=4, backend="interpret")
    sm = rec["trace_smoke"]["engine"]
    assert sm["all_completed"] and sm["goodput_tok_s"] > 0, \
        rec["trace_smoke"]
    report("serve/trace_smoke/goodput_tok_s", sm["goodput_tok_s"],
           f"interpret backend, evictions={sm['evictions']} "
           f"all_completed={sm['all_completed']}")

    with open("BENCH_serve.json", "w") as f:
        json.dump(rec, f, indent=1)
    report("serve/json", 0.0, "wrote BENCH_serve.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "interpret", "ref", "dense"])
    ap.add_argument("--reps", type=int, default=1,
                    help="interleave-min-time the compiled generate loops "
                         "over N reps per KV format")
    ap.add_argument("--head-dim", type=int, default=None,
                    help="override the config's head_dim (the int8>=bf16 "
                         "assertion wants an attention-bound shape)")
    ap.add_argument("--assert-int8", action="store_true",
                    help="fail unless int8 KV decode tok/s >= bf16 "
                         "(use with a fused backend)")
    ap.add_argument("--trace", type=int, default=0, metavar="N",
                    help="also replay an N-request Poisson trace through "
                         "the continuous-batching engine vs the "
                         "fixed-capacity baseline (0 = off)")
    ap.add_argument("--trace-rate", type=float, default=2.0,
                    help="trace arrival rate in requests/s")
    ap.add_argument("--trace-seed", type=int, default=7)
    ap.add_argument("--slots", type=int, default=2,
                    help="engine: concurrent sequences")
    ap.add_argument("--page-size", type=int, default=8,
                    help="engine: KV page size in tokens")
    ap.add_argument("--total-pages", type=int, default=8,
                    help="engine: global pool size (small pools force "
                         "eviction/recompute)")
    ap.add_argument("--max-pages", type=int, default=6,
                    help="engine: per-request page-table width")
    ap.add_argument("--chunk", type=int, default=16,
                    help="engine: prefill chunk (multiple of page size)")
    ap.add_argument("--burst", type=int, default=4,
                    help="engine: decode steps per on-device burst when "
                         "no prefill/arrival is waiting")
    ap.add_argument("--paged", action="store_true",
                    help="also paired-time paged vs contiguous decode at "
                         "equal batch/capacity")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the seeded fault-injection scenarios "
                         "(clean-vs-chaos differential replay: terminal "
                         "statuses, failure isolation, page-pool audit)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    rec = bench(args.arch, smoke=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen,
                backend=args.backend, reps=args.reps,
                head_dim=args.head_dim, assert_int8=args.assert_int8)
    cfg = get_config(args.arch)
    if not args.full:
        cfg = smoke_variant(cfg)
    if args.head_dim is not None:
        cfg = cfg.with_(head_dim=args.head_dim)
    cfg = cfg.with_(kv_cache_dtype="int8")
    if args.paged:
        import math
        cap = args.prompt_len + args.gen
        ps = math.gcd(cap, 128)   # largest power-of-two page <= 128
        if ps % 8:
            raise SystemExit(f"--paged needs capacity {cap} divisible by 8")
        rec["paged_decode"] = paired_paged_tok_s(
            cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen,
            page_size=ps, backend=args.backend, reps=max(args.reps, 2))
        print(f"[bench_serve] paged decode: {rec['paged_decode']}")
    if args.trace:
        trace = make_trace(cfg, args.trace, rate_hz=args.trace_rate,
                           plen=(8, 24), gen=(4, 16), seed=args.trace_seed)
        rec["trace"] = replay_trace(
            cfg, trace, slots=args.slots, page_size=args.page_size,
            max_pages=args.max_pages, total_pages=args.total_pages,
            chunk=args.chunk, burst=args.burst, backend=args.backend,
            seed=args.trace_seed)
        eng = rec["trace"]["engine"]
        assert eng["all_completed"] and eng["goodput_tok_s"] > 0, rec["trace"]
        print(f"[bench_serve] trace: engine "
              f"goodput={eng['goodput_tok_s']} tok/s "
              f"p50={eng['latency_p50_s']}s p99={eng['latency_p99_s']}s "
              f"evictions={eng['evictions']} | baseline "
              f"goodput={rec['trace']['baseline']['goodput_tok_s']} tok/s "
              f"(ratio {rec['trace']['goodput_ratio']}x)")
    if args.chaos:
        from benchmarks.bench_chaos import chaos_scenarios
        rec["chaos"] = chaos_scenarios(backend=args.backend or "ref")
        for name, sc in rec["chaos"].items():
            print(f"[bench_serve] chaos/{name}: "
                  f"statuses={sc['chaos']['statuses']} "
                  f"identical={sc['identical_completed']} "
                  f"audit_ok={sc['page_audit']['ok']} "
                  f"goodput_retained={sc['goodput_retained']}")
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec["roofline"]["bytes_per_token"]
    print(json.dumps(rec["runs"], indent=1))
    print(f"[bench_serve] bytes/token: packed+bf16kv={rl['packed_kv_bf16']} "
          f"packed+int8kv={rl['packed_kv_int8']} "
          f"dense+bf16kv={rl['dense_kv_bf16']} -> {args.out}")


if __name__ == "__main__":
    main()
