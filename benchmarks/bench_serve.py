"""Serving benchmark — decode fast path (§4.4 analogue).

Measures the end-to-end serve driver (prefill + single jitted on-device
generation loop) for both KV-cache formats and derives the analytic decode
roofline (HBM bytes per generated token: every weight byte streams once,
plus the live KV cache), then writes ``BENCH_serve.json``:

    PYTHONPATH=src python -m benchmarks.bench_serve [--arch llama3-8b]
        [--batch 2] [--prompt-len 16] [--gen 8] [--backend interpret]

Also runnable via ``python -m benchmarks.run serve``.  CPU numbers are for
plumbing (CI smoke), not speed — the roofline section is the
hardware-independent content.
"""
from __future__ import annotations

import argparse
import json

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core.quantize import codes_per_byte
from repro.models import cache_init, model_init

_SCALE_LEAVES = ("b", "a", "s_blk")  # fold into Ŵ on the dense path


def _leaf_name(path) -> str:
    return str(path[-1].key) if path else ""


def weight_stream_bytes(cfg) -> dict:
    """Per-decode-token weight HBM traffic: packed (as stored: uint8 codes +
    low-rank/block scales) vs dense (bf16 Ŵ).  The embedding table is
    excluded (decode gathers one row); a separate head counts (it's a full
    matmul every token)."""
    pack = codes_per_byte(cfg.quant.codebook)
    ptree = jax.eval_shape(
        lambda k: model_init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))
    leaves = jax.tree_util.tree_flatten_with_path(ptree)[0]
    packed = dense = 0
    for path, leaf in leaves:
        name = _leaf_name(path)
        nbytes = leaf.size * leaf.dtype.itemsize
        if any(str(p.key) == "embed" for p in path if hasattr(p, "key")):
            continue
        if leaf.dtype == jnp.uint8:      # packed codes
            packed += nbytes
            dense += leaf.size * pack * 2
        elif name in _SCALE_LEAVES:      # rides along only on the fused path
            packed += nbytes
        else:                            # norms, head, dense convs, biases
            packed += nbytes
            dense += nbytes
    return {"packed": packed, "dense": dense}


def cache_bytes(cfg, batch: int, capacity: int) -> int:
    """Live-cache HBM bytes read per decode step at capacity."""
    ctree = jax.eval_shape(lambda: cache_init(cfg, batch, capacity))
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree.leaves(ctree))


def bench(arch: str = "llama3-8b", *, smoke: bool = True, batch: int = 2,
          prompt_len: int = 16, gen: int = 8,
          backend: str | None = None) -> dict:
    from repro.launch.serve import serve_batch

    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    capacity = prompt_len + gen
    wb = weight_stream_bytes(cfg)
    roofline = {
        "weight_bytes_packed": wb["packed"],
        "weight_bytes_dense": wb["dense"],
        "cache_bytes_bf16": cache_bytes(
            cfg.with_(kv_cache_dtype="bf16"), batch, capacity),
        "cache_bytes_int8": cache_bytes(
            cfg.with_(kv_cache_dtype="int8"), batch, capacity),
    }
    roofline["bytes_per_token"] = {
        "packed_kv_bf16": wb["packed"] + roofline["cache_bytes_bf16"],
        "packed_kv_int8": wb["packed"] + roofline["cache_bytes_int8"],
        "dense_kv_bf16": wb["dense"] + roofline["cache_bytes_bf16"],
    }
    runs = {}
    for kv in ("bf16", "int8"):
        out = serve_batch(cfg, batch=batch, prompt_len=prompt_len, gen=gen,
                          kernel_backend=backend, kv_cache=kv)
        runs[kv] = {
            "prefill_ms": round(out["prefill_ms"], 3),
            "decode_tok_s": round(out["decode_tok_s"], 3),
            "decode_loop": out["decode_loop"],
            "kernel_backend": out["kernel_backend"],
        }
    return {
        "arch": cfg.name, "smoke": smoke, "batch": batch,
        "prompt_len": prompt_len, "gen": gen, "capacity": capacity,
        "roofline": roofline, "runs": runs,
    }


def run(report):
    """benchmarks.run entry point: smoke-scale serve + BENCH_serve.json."""
    rec = bench()
    rl = rec["roofline"]
    for kv, r in rec["runs"].items():
        report(f"serve/decode_tok_s/kv_{kv}", r["decode_tok_s"],
               f"prefill_ms={r['prefill_ms']} loop={r['decode_loop']} "
               f"backend={r['kernel_backend']}")
    for name, byts in rl["bytes_per_token"].items():
        report(f"serve/bytes_per_token/{name}", float(byts),
               f"roofline_us_v5e={byts/819e3:.2f}")
    with open("BENCH_serve.json", "w") as f:
        json.dump(rec, f, indent=1)
    report("serve/json", 0.0, "wrote BENCH_serve.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "interpret", "ref", "dense"])
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    rec = bench(args.arch, smoke=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen,
                backend=args.backend)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec["roofline"]["bytes_per_token"]
    print(json.dumps(rec["runs"], indent=1))
    print(f"[bench_serve] bytes/token: packed+bf16kv={rl['packed_kv_bf16']} "
          f"packed+int8kv={rl['packed_kv_int8']} "
          f"dense+bf16kv={rl['dense_kv_bf16']} -> {args.out}")


if __name__ == "__main__":
    main()
