"""Serving benchmark — decode fast path (§4.4 analogue).

Measures the end-to-end serve driver (prefill + single jitted on-device
generation loop) for both KV-cache formats and derives the analytic decode
roofline (HBM bytes per generated token: every weight byte streams once,
plus the live KV cache), then writes ``BENCH_serve.json``:

    PYTHONPATH=src python -m benchmarks.bench_serve [--arch llama3-8b]
        [--batch 2] [--prompt-len 16] [--gen 8] [--backend interpret]

Also runnable via ``python -m benchmarks.run serve``.  CPU numbers are for
plumbing (CI smoke), not speed — the roofline section is the
hardware-independent content.
"""
from __future__ import annotations

import argparse
import json
import time

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)
import jax
import jax.numpy as jnp

from repro.configs import ShapeCfg, get_config, smoke_variant
from repro.core.quantize import codes_per_byte
from repro.models import cache_init, model_init

_SCALE_LEAVES = ("b", "a", "s_blk")  # fold into Ŵ on the dense path


def _leaf_name(path) -> str:
    return str(path[-1].key) if path else ""


def weight_stream_bytes(cfg) -> dict:
    """Per-decode-token weight HBM traffic: packed (as stored: uint8 codes +
    low-rank/block scales) vs dense (bf16 Ŵ).  The embedding table is
    excluded (decode gathers one row); a separate head counts (it's a full
    matmul every token)."""
    pack = codes_per_byte(cfg.quant.codebook)
    ptree = jax.eval_shape(
        lambda k: model_init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))
    leaves = jax.tree_util.tree_flatten_with_path(ptree)[0]
    packed = dense = 0
    for path, leaf in leaves:
        name = _leaf_name(path)
        nbytes = leaf.size * leaf.dtype.itemsize
        if any(str(p.key) == "embed" for p in path if hasattr(p, "key")):
            continue
        if leaf.dtype == jnp.uint8:      # packed codes
            packed += nbytes
            dense += leaf.size * pack * 2
        elif name in _SCALE_LEAVES:      # rides along only on the fused path
            packed += nbytes
        else:                            # norms, head, dense convs, biases
            packed += nbytes
            dense += nbytes
    return {"packed": packed, "dense": dense}


def cache_bytes(cfg, batch: int, capacity: int) -> int:
    """Live-cache HBM bytes read per decode step at capacity."""
    ctree = jax.eval_shape(lambda: cache_init(cfg, batch, capacity))
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree.leaves(ctree))


def paired_decode_tok_s(cfg, *, batch: int, prompt_len: int, gen: int,
                        backend: str | None, reps: int) -> dict:
    """Drift-free bf16-vs-int8 decode comparison: compile both KV formats'
    generation loops up front, then *interleave* their executions and
    min-time each — sequential serve_batch calls let allocator warm-up and
    background load drift bias whichever format runs second, which is
    exactly how the pre-fusion int8 'regression' hid inside noise."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import build_generate_plan
    from repro.models import cache_init, model_init, split_tree

    mesh = make_host_mesh()
    cap = prompt_len + gen
    params, _ = split_tree(model_init(jax.random.PRNGKey(0), cfg))
    tok0 = jnp.zeros((batch,), jnp.int32)
    pos0 = jnp.full((batch,), prompt_len, jnp.int32)
    key = jax.random.PRNGKey(1)
    best = {}
    with mesh:
        fns, caches = {}, {}
        for kv in ("bf16", "int8"):
            c = cfg.with_(kv_cache_dtype=kv)
            plan = build_generate_plan(
                c, mesh, ShapeCfg("bench", cap, batch, "decode"), gen=gen,
                kernel_backend=backend)
            cache, _ = split_tree(cache_init(c, batch, cap))
            caches[kv] = [jax.tree.map(jnp.copy, cache) for _ in range(reps)]
            fns[kv] = jax.jit(plan.step_fn, donate_argnums=(2,)).lower(
                params, tok0, cache, pos0, key, None).compile()
            best[kv] = float("inf")
        for r in range(reps):
            for kv in ("bf16", "int8"):
                t0 = time.perf_counter()
                toks, _ = fns[kv](params, tok0, caches[kv][r], pos0, key,
                                  None)
                jax.block_until_ready(toks)
                best[kv] = min(best[kv], time.perf_counter() - t0)
    return {kv: batch * gen / t for kv, t in best.items()}


def bench(arch: str = "llama3-8b", *, smoke: bool = True, batch: int = 2,
          prompt_len: int = 16, gen: int = 8,
          backend: str | None = None, reps: int = 1,
          head_dim: int | None = None,
          assert_int8: bool = False) -> dict:
    """``reps`` > 1 re-times decode via :func:`paired_decode_tok_s` (both
    KV formats' compiled loops interleaved, min-timed).  ``assert_int8``
    enforces the fused-attention roofline ordering: with the cache read
    in-kernel at int8 width, int8 KV decode must be at least as fast as
    bf16 (the pre-fusion einsum path *inverted* this by dequantizing the
    whole cache out of kernel every step).  ``head_dim`` overrides the
    smoke config's head_dim — the assertion config uses 64 so the decode
    step is attention-traffic-bound, the regime the roofline claim is
    about, rather than dominated by the tiny smoke model's linears."""
    from repro.launch.serve import serve_batch

    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    if head_dim is not None:
        cfg = cfg.with_(head_dim=head_dim)
    capacity = prompt_len + gen
    wb = weight_stream_bytes(cfg)
    roofline = {
        "weight_bytes_packed": wb["packed"],
        "weight_bytes_dense": wb["dense"],
        "cache_bytes_bf16": cache_bytes(
            cfg.with_(kv_cache_dtype="bf16"), batch, capacity),
        "cache_bytes_int8": cache_bytes(
            cfg.with_(kv_cache_dtype="int8"), batch, capacity),
    }
    roofline["bytes_per_token"] = {
        "packed_kv_bf16": wb["packed"] + roofline["cache_bytes_bf16"],
        "packed_kv_int8": wb["packed"] + roofline["cache_bytes_int8"],
        "dense_kv_bf16": wb["dense"] + roofline["cache_bytes_bf16"],
    }
    runs = {}
    for kv in ("bf16", "int8"):
        out = serve_batch(cfg, batch=batch, prompt_len=prompt_len, gen=gen,
                          kernel_backend=backend, kv_cache=kv)
        runs[kv] = {
            "prefill_ms": round(out["prefill_ms"], 3),
            "decode_tok_s": round(out["decode_tok_s"], 3),
            "decode_loop": out["decode_loop"],
            "kernel_backend": out["kernel_backend"],
            "attention": out["attention"],
        }
    if reps > 1:
        paired = paired_decode_tok_s(cfg, batch=batch,
                                     prompt_len=prompt_len, gen=gen,
                                     backend=backend, reps=reps)
        for kv, tok_s in paired.items():
            runs[kv]["decode_tok_s"] = round(tok_s, 3)
            runs[kv]["timing"] = f"paired-min-of-{reps}"
    if assert_int8:
        assert runs["int8"]["decode_tok_s"] >= runs["bf16"]["decode_tok_s"], (
            "int8 KV decode regressed below bf16 despite the fused "
            f"attention path: {runs}")
    return {
        "arch": cfg.name, "smoke": smoke, "batch": batch,
        "prompt_len": prompt_len, "gen": gen, "capacity": capacity,
        "reps": reps, "roofline": roofline, "runs": runs,
    }


def run(report):
    """benchmarks.run entry point: smoke-scale serve + BENCH_serve.json.

    Pins the interpret backend so the fused attention + decode-GEMV kernel
    bodies execute, interleave-min-times 5 reps per KV format at the
    attention-bound shape (head_dim 64, capacity 128), and *asserts*
    int8-KV decode >= bf16 — the roofline ordering the fused path restores
    is enforced, not aspirational."""
    rec = bench(backend="interpret", reps=5, prompt_len=112, gen=16,
                head_dim=64, assert_int8=True)
    rl = rec["roofline"]
    for kv, r in rec["runs"].items():
        report(f"serve/decode_tok_s/kv_{kv}", r["decode_tok_s"],
               f"prefill_ms={r['prefill_ms']} loop={r['decode_loop']} "
               f"backend={r['kernel_backend']} attention={r['attention']}")
    for name, byts in rl["bytes_per_token"].items():
        report(f"serve/bytes_per_token/{name}", float(byts),
               f"roofline_us_v5e={byts/819e3:.2f}")
    with open("BENCH_serve.json", "w") as f:
        json.dump(rec, f, indent=1)
    report("serve/json", 0.0, "wrote BENCH_serve.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "interpret", "ref", "dense"])
    ap.add_argument("--reps", type=int, default=1,
                    help="interleave-min-time the compiled generate loops "
                         "over N reps per KV format")
    ap.add_argument("--head-dim", type=int, default=None,
                    help="override the config's head_dim (the int8>=bf16 "
                         "assertion wants an attention-bound shape)")
    ap.add_argument("--assert-int8", action="store_true",
                    help="fail unless int8 KV decode tok/s >= bf16 "
                         "(use with a fused backend)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    rec = bench(args.arch, smoke=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen,
                backend=args.backend, reps=args.reps,
                head_dim=args.head_dim, assert_int8=args.assert_int8)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec["roofline"]["bytes_per_token"]
    print(json.dumps(rec["runs"], indent=1))
    print(f"[bench_serve] bytes/token: packed+bf16kv={rl['packed_kv_bf16']} "
          f"packed+int8kv={rl['packed_kv_int8']} "
          f"dense+bf16kv={rl['dense_kv_bf16']} -> {args.out}")


if __name__ == "__main__":
    main()
