"""Streaming-PTQ chaos benchmark — resume parity at every block boundary.

The ``bench_chaos`` pattern applied to the quantization pipeline: run the
layer-streaming PTQ once clean, then re-run it under injected faults and
*assert* the crash-safety contract instead of just recording numbers:

  * **boundary sweep** — for *every* block boundary b, kill a fresh run at
    b, resume it, and require (i) the resumed artifact is bit-identical to
    the clean run's shards, (ii) blocks < b were reused (never recomputed),
    and (iii) the post-resume ledger/checksum audit is clean;
  * **mid-write / pre-commit kills** — the same contract when the kill
    lands inside a shard write (stray temp file) or between a published
    shard and its ledger entry (un-journaled work is re-done, to the same
    bytes);
  * **bitrot** — a corrupted published shard is detected by the resume
    audit and exactly that block is recomputed;
  * **memory watchdog** — an injected allocation spike trips
    :class:`MemoryBudgetExceeded` (fail fast, diagnosable), and the run
    still resumes to the identical artifact afterwards.

Writes ``BENCH_ptq_stream.json`` with the scenario records and the peak
streaming footprint vs the dense model size.
"""
from __future__ import annotations

import json
import os
import tempfile

import numpy as np

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)

from repro.ptq_stream import (
    MemoryBudgetExceeded,
    ResidualMLPSource,
    StreamPlan,
    audit_artifact,
    read_shard,
    stream_quantize,
)
from repro.ptq_stream.shards import shard_name
from repro.robustness import FaultPlan, InjectedFault

_MODEL = dict(num_blocks=4, d=64, d_ff=128, tokens=32, seed=0)


def _shards(directory: str, n: int) -> list[dict]:
    return [read_shard(os.path.join(directory, shard_name(i)))
            for i in range(n)]


def _identical(ref: list[dict], directory: str) -> bool:
    got = _shards(directory, len(ref))
    return all(
        sorted(a) == sorted(b) and all(np.array_equal(a[k], b[k]) for k in a)
        for a, b in zip(ref, got))


def _expect_kill(src, out, plan, faults):
    try:
        stream_quantize(src, out, plan, faults=faults)
    except InjectedFault:
        return True
    return False


def run_scenarios(root: str) -> dict:
    src = ResidualMLPSource.create(os.path.join(root, "model"), **_MODEL)
    plan = StreamPlan(block_size=32, rank=4, refine_steps=10)
    n = src.num_blocks

    clean_dir = os.path.join(root, "clean")
    clean = stream_quantize(src, clean_dir, plan)
    assert clean["status"] == "complete", clean
    ref = _shards(clean_dir, n)
    results = {"clean": {"peak_bytes": clean["peak_bytes"],
                         "dense_bytes": src.dense_bytes(),
                         "wall_s": clean["wall_s"]},
               "boundary_sweep": [], "scenarios": {}}

    # -- kill + resume at EVERY block boundary ------------------------------
    for b in range(n):
        out = os.path.join(root, f"kill_b{b}")
        faults = FaultPlan(b, {"ptq.kill_at_block": {"at": (b,)}})
        assert _expect_kill(src, out, plan, faults), f"kill at {b} never fired"
        s = stream_quantize(src, out, plan, resume=True)
        rec = {"boundary": b, "reused": s["reused"],
               "recomputed": s["recomputed"],
               "bit_identical": _identical(ref, out),
               "audit_clean": audit_artifact(out, src, plan)["clean"]}
        assert rec["bit_identical"], f"boundary {b}: artifact diverged"
        assert rec["audit_clean"], f"boundary {b}: dirty audit"
        assert s["reused"] == b, (
            f"boundary {b}: expected {b} reused blocks, got {s['reused']}")
        results["boundary_sweep"].append(rec)

    # -- kill inside the shard write / before the ledger commit -------------
    for name, point in [("mid_write", "ptq.kill_mid_write"),
                        ("pre_commit", "ptq.kill_before_commit")]:
        out = os.path.join(root, name)
        faults = FaultPlan(7, {point: {"at": (n // 2,)}})
        assert _expect_kill(src, out, plan, faults), f"{name} never fired"
        s = stream_quantize(src, out, plan, resume=True)
        rec = {"reused": s["reused"], "recomputed": s["recomputed"],
               "stray_tmp_removed": s["stray_tmp_removed"],
               "bit_identical": _identical(ref, out),
               "audit_clean": audit_artifact(out, src, plan)["clean"]}
        assert rec["bit_identical"] and rec["audit_clean"], (name, rec)
        results["scenarios"][name] = rec

    # -- bitrot on a published shard ----------------------------------------
    out = os.path.join(root, "bitrot")
    faults = FaultPlan(3, {"ptq.corrupt_shard": {"at": (1,)},
                           "ptq.kill_at_block": {"at": (n - 1,)}})
    assert _expect_kill(src, out, plan, faults)
    pre = audit_artifact(out, src, plan)
    s = stream_quantize(src, out, plan, resume=True)
    rec = {"audit_caught_corruption": not pre["clean"],
           "recomputed": s["recomputed"],
           "bit_identical": _identical(ref, out),
           "audit_clean": audit_artifact(out, src, plan)["clean"]}
    assert rec["audit_caught_corruption"], "bitrot escaped the audit"
    assert 1 in rec["recomputed"], rec
    assert rec["bit_identical"] and rec["audit_clean"], rec
    results["scenarios"]["bitrot"] = rec

    # -- injected memory spike trips the watchdog, run still resumes --------
    out = os.path.join(root, "oom")
    budget = int(clean["peak_bytes"] * 1.2)
    plan_b = StreamPlan(block_size=32, rank=4, refine_steps=10,
                        memory_budget=budget)
    oom_raised = False
    try:
        stream_quantize(src, out, plan_b,
                        faults=FaultPlan(5, {"ptq.oom_spike": {"at": (9,)}}))
    except MemoryBudgetExceeded as e:
        oom_raised = "live charges" in str(e)
    s = stream_quantize(src, out, plan_b, resume=True)
    rec = {"oom_diagnostic": oom_raised, "budget": budget,
           "peak_bytes": s["peak_bytes"],
           "bit_identical": _identical(ref, out)}
    assert rec["oom_diagnostic"], "oom spike produced no diagnostic"
    assert rec["bit_identical"], rec
    results["scenarios"]["oom_spike"] = rec
    return results


def run(report):
    """benchmarks.run entry point -> BENCH_ptq_stream.json."""
    with tempfile.TemporaryDirectory() as root:
        results = run_scenarios(root)
    c = results["clean"]
    report("ptq_stream/clean", c["wall_s"] * 1e6,
           f"peak_bytes={c['peak_bytes']} dense_bytes={c['dense_bytes']}")
    for rec in results["boundary_sweep"]:
        report(f"ptq_stream/kill_b{rec['boundary']}", 0.0,
               f"reused={rec['reused']} redone={len(rec['recomputed'])} "
               f"bit_identical={rec['bit_identical']}")
    for name, rec in results["scenarios"].items():
        report(f"ptq_stream/{name}", 0.0,
               f"bit_identical={rec['bit_identical']}")
    with open("BENCH_ptq_stream.json", "w") as f:
        json.dump(results, f, indent=1)
    report("ptq_stream/json", 0.0, "wrote BENCH_ptq_stream.json")


if __name__ == "__main__":
    def _p(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
    run(_p)
