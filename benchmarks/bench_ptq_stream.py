"""Streaming-PTQ chaos benchmark — resume parity at every block boundary.

The ``bench_chaos`` pattern applied to the quantization pipeline: run the
layer-streaming PTQ once clean, then re-run it under injected faults and
*assert* the crash-safety contract instead of just recording numbers:

  * **boundary sweep** — for *every* block boundary b, kill a fresh run at
    b, resume it, and require (i) the resumed artifact is bit-identical to
    the clean run's shards, (ii) blocks < b were reused (never recomputed),
    and (iii) the post-resume ledger/checksum audit is clean;
  * **mid-write / pre-commit kills** — the same contract when the kill
    lands inside a shard write (stray temp file) or between a published
    shard and its ledger entry (un-journaled work is re-done, to the same
    bytes);
  * **bitrot** — a corrupted published shard is detected by the resume
    audit and exactly that block is recomputed;
  * **memory watchdog** — an injected allocation spike trips
    :class:`MemoryBudgetExceeded` (fail fast, diagnosable), and the run
    still resumes to the identical artifact afterwards.

  * **sharded drill** (forced 8 host devices, run in a subprocess so the
    device count can be forced before jax initializes) — the data-parallel
    sharded pipeline killed at *every* block boundary resumes bit-identical
    to the uninterrupted **single-host** run, including once across a mesh
    shrink (killed on 2×4, resumed on 1×4, and once resumed with no mesh at
    all): the canonical chunked math makes the mesh pure placement, so
    bytes never depend on the device count — not even across a crash.

Writes ``BENCH_ptq_stream.json`` with the scenario records and the peak
streaming footprint vs the dense model size.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

import numpy as np

import benchmarks.common  # noqa: F401  (sets REPRO_CPU_EXEC before jax use)

from repro.ptq_stream import (
    MemoryBudgetExceeded,
    ResidualMLPSource,
    StreamPlan,
    audit_artifact,
    read_shard,
    stream_quantize,
)
from repro.ptq_stream.shards import shard_name
from repro.robustness import FaultPlan, InjectedFault

_MODEL = dict(num_blocks=4, d=64, d_ff=128, tokens=32, seed=0)


def _shards(directory: str, n: int) -> list[dict]:
    return [read_shard(os.path.join(directory, shard_name(i)))
            for i in range(n)]


def _identical(ref: list[dict], directory: str) -> bool:
    got = _shards(directory, len(ref))
    return all(
        sorted(a) == sorted(b) and all(np.array_equal(a[k], b[k]) for k in a)
        for a, b in zip(ref, got))


def _expect_kill(src, out, plan, faults):
    try:
        stream_quantize(src, out, plan, faults=faults)
    except InjectedFault:
        return True
    return False


def run_scenarios(root: str) -> dict:
    src = ResidualMLPSource.create(os.path.join(root, "model"), **_MODEL)
    plan = StreamPlan(block_size=32, rank=4, refine_steps=10)
    n = src.num_blocks

    clean_dir = os.path.join(root, "clean")
    clean = stream_quantize(src, clean_dir, plan)
    assert clean["status"] == "complete", clean
    ref = _shards(clean_dir, n)
    results = {"clean": {"peak_bytes": clean["peak_bytes"],
                         "dense_bytes": src.dense_bytes(),
                         "wall_s": clean["wall_s"]},
               "boundary_sweep": [], "scenarios": {}}

    # -- kill + resume at EVERY block boundary ------------------------------
    for b in range(n):
        out = os.path.join(root, f"kill_b{b}")
        faults = FaultPlan(b, {"ptq.kill_at_block": {"at": (b,)}})
        assert _expect_kill(src, out, plan, faults), f"kill at {b} never fired"
        s = stream_quantize(src, out, plan, resume=True)
        rec = {"boundary": b, "reused": s["reused"],
               "recomputed": s["recomputed"],
               "bit_identical": _identical(ref, out),
               "audit_clean": audit_artifact(out, src, plan)["clean"]}
        assert rec["bit_identical"], f"boundary {b}: artifact diverged"
        assert rec["audit_clean"], f"boundary {b}: dirty audit"
        assert s["reused"] == b, (
            f"boundary {b}: expected {b} reused blocks, got {s['reused']}")
        results["boundary_sweep"].append(rec)

    # -- kill inside the shard write / before the ledger commit -------------
    for name, point in [("mid_write", "ptq.kill_mid_write"),
                        ("pre_commit", "ptq.kill_before_commit")]:
        out = os.path.join(root, name)
        faults = FaultPlan(7, {point: {"at": (n // 2,)}})
        assert _expect_kill(src, out, plan, faults), f"{name} never fired"
        s = stream_quantize(src, out, plan, resume=True)
        rec = {"reused": s["reused"], "recomputed": s["recomputed"],
               "stray_tmp_removed": s["stray_tmp_removed"],
               "bit_identical": _identical(ref, out),
               "audit_clean": audit_artifact(out, src, plan)["clean"]}
        assert rec["bit_identical"] and rec["audit_clean"], (name, rec)
        results["scenarios"][name] = rec

    # -- bitrot on a published shard ----------------------------------------
    out = os.path.join(root, "bitrot")
    faults = FaultPlan(3, {"ptq.corrupt_shard": {"at": (1,)},
                           "ptq.kill_at_block": {"at": (n - 1,)}})
    assert _expect_kill(src, out, plan, faults)
    pre = audit_artifact(out, src, plan)
    s = stream_quantize(src, out, plan, resume=True)
    rec = {"audit_caught_corruption": not pre["clean"],
           "recomputed": s["recomputed"],
           "bit_identical": _identical(ref, out),
           "audit_clean": audit_artifact(out, src, plan)["clean"]}
    assert rec["audit_caught_corruption"], "bitrot escaped the audit"
    assert 1 in rec["recomputed"], rec
    assert rec["bit_identical"] and rec["audit_clean"], rec
    results["scenarios"]["bitrot"] = rec

    # -- injected memory spike trips the watchdog, run still resumes --------
    out = os.path.join(root, "oom")
    budget = int(clean["peak_bytes"] * 1.2)
    plan_b = StreamPlan(block_size=32, rank=4, refine_steps=10,
                        memory_budget=budget)
    oom_raised = False
    try:
        stream_quantize(src, out, plan_b,
                        faults=FaultPlan(5, {"ptq.oom_spike": {"at": (9,)}}))
    except MemoryBudgetExceeded as e:
        oom_raised = "live charges" in str(e)
    s = stream_quantize(src, out, plan_b, resume=True)
    rec = {"oom_diagnostic": oom_raised, "budget": budget,
           "peak_bytes": s["peak_bytes"],
           "bit_identical": _identical(ref, out)}
    assert rec["oom_diagnostic"], "oom spike produced no diagnostic"
    assert rec["bit_identical"], rec
    results["scenarios"]["oom_spike"] = rec
    return results


def dist_drill(root: str) -> dict:
    """Forced-8-device sharded kill/resume/mesh-shrink drill (see module
    docstring).  Must run in a process whose jax sees >= 8 devices."""
    import jax

    from repro.launch.mesh import make_host_mesh

    if jax.device_count() < 8:
        raise RuntimeError(
            f"dist drill needs 8 devices, found {jax.device_count()} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "before the first jax import")
    src = ResidualMLPSource.create(os.path.join(root, "model"), **_MODEL)
    plan = StreamPlan(block_size=32, rank=4, refine_steps=10)
    n = src.num_blocks

    # the oracle is the *single-host* run: every sharded variant below must
    # reproduce these bytes exactly
    clean_dir = os.path.join(root, "clean_single")
    clean = stream_quantize(src, clean_dir, plan)
    assert clean["status"] == "complete", clean
    ref = _shards(clean_dir, n)

    full = os.path.join(root, "sharded_full")
    s = stream_quantize(src, full, plan, mesh=make_host_mesh(data=2, model=4))
    assert s["status"] == "complete" and _identical(ref, full), (
        "uninterrupted sharded run diverged from single-host bytes")
    results = {"devices": jax.device_count(), "sharded_parity": True,
               "boundary_sweep": [], "mesh_shrink": {}}

    # kill the 2x4 sharded run at EVERY block boundary; resume on the same
    # mesh — bytes must match the single-host oracle and prefixes reuse
    for b in range(n):
        out = os.path.join(root, f"dist_kill_b{b}")
        faults = FaultPlan(b, {"ptq.kill_at_block": {"at": (b,)}})
        killed = False
        try:
            stream_quantize(src, out, plan, faults=faults,
                            mesh=make_host_mesh(data=2, model=4))
        except InjectedFault:
            killed = True
        assert killed, f"dist kill at {b} never fired"
        s = stream_quantize(src, out, plan, resume=True,
                            mesh=make_host_mesh(data=2, model=4))
        rec = {"boundary": b, "reused": s["reused"],
               "recomputed": s["recomputed"],
               "bit_identical": _identical(ref, out),
               "audit_clean": audit_artifact(out, src, plan)["clean"]}
        assert rec["bit_identical"], f"dist boundary {b}: bytes diverged"
        assert rec["audit_clean"], f"dist boundary {b}: dirty audit"
        assert s["reused"] == b, (b, s["reused"])
        results["boundary_sweep"].append(rec)

    # mid-mesh-shrink: killed on 2x4, resumed on 1x4 (half the devices
    # gone), then a second drill resumed with no mesh at all — a crash plus
    # an elastic reshard still lands on the oracle bytes
    for name, resume_mesh in (("to_1x4", make_host_mesh(data=1, model=4)),
                              ("to_single", None)):
        out = os.path.join(root, f"shrink_{name}")
        faults = FaultPlan(17, {"ptq.kill_at_block": {"at": (n // 2,)}})
        killed = False
        try:
            stream_quantize(src, out, plan, faults=faults,
                            mesh=make_host_mesh(data=2, model=4))
        except InjectedFault:
            killed = True
        assert killed
        s = stream_quantize(src, out, plan, resume=True, mesh=resume_mesh)
        rec = {"reused": s["reused"], "recomputed": s["recomputed"],
               "bit_identical": _identical(ref, out),
               "audit_clean": audit_artifact(out, src, plan)["clean"]}
        assert rec["bit_identical"], f"mesh shrink {name}: bytes diverged"
        assert rec["audit_clean"] and s["reused"] == n // 2, (name, rec)
        results["mesh_shrink"][name] = rec
    return results


def dist_drill_subprocess() -> dict:
    """Run :func:`dist_drill` in a child process with 8 forced host devices
    (the parent's jax is already initialized with 1)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    with tempfile.TemporaryDirectory() as root:
        out_json = os.path.join(root, "dist_drill.json")
        subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_ptq_stream",
             "--dist-drill", root, "--json", out_json],
            env=env, check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        with open(out_json) as f:
            return json.load(f)


def run(report):
    """benchmarks.run entry point -> BENCH_ptq_stream.json."""
    with tempfile.TemporaryDirectory() as root:
        results = run_scenarios(root)
    results["dist_drill"] = dist_drill_subprocess()
    c = results["clean"]
    report("ptq_stream/clean", c["wall_s"] * 1e6,
           f"peak_bytes={c['peak_bytes']} dense_bytes={c['dense_bytes']}")
    for rec in results["boundary_sweep"]:
        report(f"ptq_stream/kill_b{rec['boundary']}", 0.0,
               f"reused={rec['reused']} redone={len(rec['recomputed'])} "
               f"bit_identical={rec['bit_identical']}")
    for name, rec in results["scenarios"].items():
        report(f"ptq_stream/{name}", 0.0,
               f"bit_identical={rec['bit_identical']}")
    dd = results["dist_drill"]
    report("ptq_stream/dist_drill", 0.0,
           f"devices={dd['devices']} sharded_parity={dd['sharded_parity']} "
           f"boundaries={len(dd['boundary_sweep'])} "
           f"all_bit_identical="
           f"{all(r['bit_identical'] for r in dd['boundary_sweep'])} "
           f"mesh_shrink_ok="
           f"{all(r['bit_identical'] for r in dd['mesh_shrink'].values())}")
    with open("BENCH_ptq_stream.json", "w") as f:
        json.dump(results, f, indent=1)
    report("ptq_stream/json", 0.0, "wrote BENCH_ptq_stream.json")


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dist-drill", default=None, metavar="ROOT",
                    help="run only the forced-8-device sharded drill into "
                         "ROOT (needs XLA_FLAGS host device forcing)")
    ap.add_argument("--json", default=None,
                    help="with --dist-drill: write the drill record here")
    args = ap.parse_args(argv)
    if args.dist_drill is not None:
        results = dist_drill(args.dist_drill)
        if args.json:
            with open(args.json, "w") as f:
                json.dump(results, f, indent=1)
        print(f"[bench_ptq_stream] dist drill: {len(results['boundary_sweep'])}"
              f" boundaries + {len(results['mesh_shrink'])} mesh-shrink "
              "resumes, all bit-identical to the single-host run")
        return

    def _p(name, us, derived):
        print(f"{name},{us:.1f},{derived}")
    run(_p)


if __name__ == "__main__":
    main()
