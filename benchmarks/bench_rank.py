"""Fig. 3 / Appendix C analogue — singular-value spectrum of the PEFT ΔW.

QLoRA's additive update truncates exactly at rank r; LoRDS's multiplicative
update Q ⊙ (B'A' − BA) has a smooth long tail spanning the full dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import realistic_weight
from repro.core import QuantSpec, dequantize_weight, init_quantized_linear
from repro.core import metrics


def run(report):
    n, m, r = 256, 512, 4
    key = jax.random.PRNGKey(3)
    w = realistic_weight(key, n, m)

    # LoRDS update
    spec = QuantSpec(method="lords", block_size=64, rank=r, mode="peft")
    params = init_quantized_linear(key, n, m, spec, w=w)
    w0 = dequantize_weight(params, spec, n, m).astype(jnp.float32)
    kb, ka = jax.random.split(jax.random.PRNGKey(9))
    p2 = dict(params,
              b=params["b"] + 0.05 * jax.random.normal(kb, params["b"].shape),
              a=params["a"] + 0.05 * jax.random.normal(ka, params["a"].shape))
    dw_lords = dequantize_weight(p2, spec, n, m).astype(jnp.float32) - w0

    # QLoRA update (additive, same r)
    db = jax.random.normal(kb, (n, r)) * 0.05
    da = jax.random.normal(ka, (r, m)) * 0.05
    dw_qlora = db @ da

    s_l = metrics.singular_values(dw_lords)
    s_q = metrics.singular_values(dw_qlora)
    er_l = int(metrics.effective_rank(dw_lords, 1e-2))
    er_q = int(metrics.effective_rank(dw_qlora, 1e-2))
    report("rank_fig3/lords", 0.0,
           f"effective_rank={er_l} sigma_r+1/sigma_1="
           f"{float(s_l[r] / s_l[0]):.4f}")
    report("rank_fig3/qlora", 0.0,
           f"effective_rank={er_q} sigma_r+1/sigma_1="
           f"{float(s_q[r] / s_q[0]):.2e}")
    assert er_l > 10 * er_q, "LoRDS ΔW must be high-rank; QLoRA truncates"
