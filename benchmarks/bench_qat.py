"""Table 4 analogue — QAT: block-wise INT4-QAT vs LoRDS-QAT on a tiny LM.

Same data/steps/schedule; metric = held-out eval loss (log-PPL).  Paper
claims LoRDS-QAT < INT4-QAT < PTQ-only.
"""
from __future__ import annotations

from benchmarks.common import eval_loss, timer, tiny_lm, train_tiny
from repro.core import QuantSpec

STEPS = 150


def run(report):
    specs = {
        "fp": QuantSpec(method="none", mode="qat"),
        "int4_qat": QuantSpec(method="blockwise", codebook="int4",
                              block_size=32, mode="qat"),
        "lords_qat": QuantSpec(method="lords", codebook="int4",
                               block_size=32, rank=4, mode="qat"),
    }
    losses = {}
    for name, q in specs.items():
        cfg = tiny_lm(q)
        with timer() as t:
            params, hist = train_tiny(cfg, steps=STEPS, lr=2e-3, seed=7)
        losses[name] = eval_loss(params, cfg)
        report(f"qat_t4/{name}", t.dt * 1e6 / STEPS,
               f"eval_loss={losses[name]:.4f} train_last={hist[-1]:.4f}")
    report("qat_t4/ordering", 0.0,
           f"lords_beats_int4={losses['lords_qat'] < losses['int4_qat']}")
