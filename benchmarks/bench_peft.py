"""Table 5 analogue — quantized PEFT: QLoRA vs LoftQ vs LoRDS.

Protocol: pretrain a tiny LM (fp) on stream A; quantize; fine-tune on a
*shifted* stream B with each method at matched trainable-parameter budgets;
metric = held-out eval loss on B.  Paper claim: LoRDS wins with FEWER float
parameters (multiplicative high-rank updates).
"""
from __future__ import annotations

import jax

from benchmarks.common import (
    eval_loss,
    quantize_model_weights,
    timer,
    tiny_lm,
    train_tiny,
)
from repro.core import QuantSpec, peft

PRETRAIN_STEPS = 150
TUNE_STEPS = 120
TASK_SEED = 777  # stream B


def _float_params(params, quant):
    t, _ = peft.partition(params, quant)
    return sum(x.size for x in jax.tree.leaves(t))


def run(report):
    fp = QuantSpec(method="none", mode="qat")
    cfg_fp = tiny_lm(fp)
    params_fp, _ = train_tiny(cfg_fp, steps=PRETRAIN_STEPS, lr=2e-3, seed=0)

    specs = {
        "qlora": QuantSpec(method="qlora", block_size=32, adapter_rank=4,
                           mode="peft"),
        "loftq": QuantSpec(method="loftq", block_size=32, adapter_rank=4,
                           loftq_iters=3, mode="peft"),
        "lords": QuantSpec(method="lords", block_size=32, rank=4,
                           mode="peft"),
    }
    results = {}
    for name, q in specs.items():
        params_q = quantize_model_weights(params_fp, cfg_fp, q)
        cfg_q = cfg_fp.with_(quant=q)
        before = eval_loss(params_q, cfg_q, seed=TASK_SEED)
        n_train = _float_params(params_q, q)
        with timer() as t:
            tuned, hist = train_tiny(cfg_q, steps=TUNE_STEPS, lr=3e-3,
                                     seed=TASK_SEED, params=params_q)
        after = eval_loss(tuned, cfg_q, seed=TASK_SEED)
        results[name] = after
        report(f"peft_t5/{name}", t.dt * 1e6 / TUNE_STEPS,
               f"task_loss {before:.4f}->{after:.4f} trainable={n_train}")
    report("peft_t5/ordering", 0.0,
           f"lords={results['lords']:.4f} loftq={results['loftq']:.4f} "
           f"qlora={results['qlora']:.4f}")
