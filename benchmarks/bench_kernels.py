"""Fig. 2 / Table 6 analogue — kernel-level efficiency comparison.

All variants now run through the unified dispatch entry point
(``repro.kernels.dispatch.qmatmul``) — the same code path the model
forwards use — so the numbers measure what serving actually executes:

  1. fused-vs-oracle wall-time per (M tokens) point, q_proj-shaped
     (llama3-8b / 4): the *fused* backend is whatever the platform
     dispatches to (Pallas on TPU; interpret-mode kernel bodies on CPU,
     timed only at the smallest M — the interpreter is for correctness,
     not speed), and the *oracle* is the pure-jnp ``ref`` backend,
  2. autotuned tile choices: the (bm, bn, bk) the dispatcher registered
     for each shape (consulted by every later ``qmatmul`` trace),
  3. analytic TPU-roofline bytes per variant (HBM traffic of packed codes
     + scales + activations) — the quantity the paper's kernels optimize.

Paper claims reproduced: QLoRA pays an un-mergeable adapter GEMM
(~1.3-2×); LoRDS matches block-wise NF4 since S=BA rides along with the
weight tiles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import realistic_weight
from repro.core import QuantSpec, init_quantized_linear
from repro.kernels import dispatch

N, K = 1024, 1024          # q_proj/4
ADAPTER_R = 16
LORDS_R = 4                # parity at block 64 -> nm/(B(n+m)) = 8 … use 8
TOKENS = (256, 1024, 4096)
BLOCK = 64


def _bytes_per_call(m, variant):
    """Analytic HBM bytes (TPU target): activations + packed weights + scales
    + output, assuming perfect fusion (weights never materialize in HBM)."""
    x = m * K * 2
    out = m * N * 4
    q_packed = N * K // 2
    if variant == "block":
        scales = N * (K // BLOCK) * 4
        return x + q_packed + scales + out
    if variant == "lords":
        scales = (N * LORDS_R + LORDS_R * K) * 4
        return x + q_packed + scales + out
    if variant == "qlora":
        scales = N * (K // BLOCK) * 4
        adapter = (N * ADAPTER_R + ADAPTER_R * K) * 4
        extra_act = m * ADAPTER_R * 4
        return x + q_packed + scales + adapter + extra_act + out


def _time(fn, x, iters=3):
    fn(x).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(x).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(report):
    key = jax.random.PRNGKey(4)
    w = realistic_weight(key, N, K)

    cd = jnp.float32
    variants = {
        "bnb_nf4": ("block", QuantSpec(method="blockwise", block_size=BLOCK,
                                       compute_dtype=cd)),
        "qlora": ("qlora", QuantSpec(method="qlora", block_size=BLOCK,
                                     adapter_rank=ADAPTER_R,
                                     compute_dtype=cd)),
        "lords": ("lords", QuantSpec(method="lords", block_size=BLOCK,
                                     rank=LORDS_R, compute_dtype=cd)),
    }
    params = {name: init_quantized_linear(key, N, K, spec, w=w)
              for name, (_, spec) in variants.items()}

    fused = dispatch.default_backend()
    interp_only = fused not in ("pallas",)  # CPU: interpreter, smallest M only

    for m in TOKENS:
        x = jax.random.normal(jax.random.PRNGKey(m), (m, K))
        for name, (variant, spec) in variants.items():
            p = params[name]
            # autotune registers the best tiling for this (shape, codebook);
            # on CPU only at the smallest M (interpreter timings are for
            # plumbing, not speed) with a 2-candidate sweep.  qlora's base
            # shares bnb_nf4's blockwise table key — tuning it again would
            # only overwrite that entry with adapter-GEMM-polluted timings
            if name != "qlora":
                if not interp_only:
                    dispatch.autotune_qmatmul(p, x, spec, N, K)
                elif m == min(TOKENS):
                    dispatch.autotune_qmatmul(
                        p, x, spec, N, K, backend="interpret", iters=1,
                        candidates=[(128, 256, 512), (128, 128, 512)])
            # the tiling a fused trace of this shape would actually use
            # (autotune-table hit, else the lane-aligned heuristic)
            tiles = dispatch.tile_for(
                "lords" if spec.method == "lords" else "blockwise",
                m, N, K, spec.codebook, spec.compute_dtype,
                block_size=None if spec.method == "lords" else BLOCK)
            oracle = jax.jit(lambda xx, p=p, s=spec: dispatch.qmatmul(
                p, xx, s, N, K, backend="ref"))
            us_ref = _time(oracle, x)
            byts = _bytes_per_call(m, variant)
            report(f"kernels_fig2/M{m}/{name}", us_ref,
                   f"backend=ref tiles={tiles} tpu_bytes={byts} "
                   f"roofline_us_v5e={byts/819e3:.2f}")
            if fused == "pallas" or (interp_only and m == min(TOKENS)):
                fb = "pallas" if fused == "pallas" else "interpret"
                fused_fn = jax.jit(lambda xx, p=p, s=spec: dispatch.qmatmul(
                    p, xx, s, N, K, backend=fb))
                us_fused = _time(fused_fn, x, iters=1 if fb == "interpret"
                                 else 3)
                report(f"kernels_fig2/M{m}/{name}_fused", us_fused,
                       f"backend={fb} vs_ref_x={us_fused/max(us_ref,1e-9):.2f}")

    table = dispatch.autotune_table()
    report("kernels_fig2/autotune_entries", float(len(table)),
           ";".join(f"{k}->{v}" for k, v in sorted(table.items(),
                                                   key=str)[:6]))
