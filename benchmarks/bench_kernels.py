"""Fig. 2 / Table 6 analogue — kernel-level efficiency comparison.

Three measurements per (M tokens) point, q_proj-shaped (llama3-8b / 4):
  1. wall-time of the jitted CPU graphs (bnb-style block-NF4 dequant-matmul
     vs QLoRA = dequant-matmul + extra adapter GEMM vs LoRDS fused) — the
     *relative* QLoRA overhead is hardware-independent program structure,
  2. analytic TPU-roofline bytes per variant (HBM traffic of packed codes +
     scales + activations) — the quantity the paper's Triton kernels
     optimize,
  3. interpret-mode execution of the real Pallas kernel for correctness
     (already covered by tests; here we record its op counts).

Paper claims reproduced: QLoRA pays an un-mergeable adapter GEMM (~1.3-2×);
LoRDS matches block-wise NF4 since S=BA rides along with the tiles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import realistic_weight
from repro.core import quantize, scaling
from repro.kernels import ref

N, K = 1024, 1024          # q_proj/4
ADAPTER_R = 16
LORDS_R = 4                # parity at block 64 -> nm/(B(n+m)) = 8 … use 8
TOKENS = (256, 1024, 4096)


def _bytes_per_call(m, variant):
    """Analytic HBM bytes (TPU target): activations + packed weights + scales
    + output, assuming perfect fusion (weights never materialize in HBM)."""
    x = m * K * 2
    out = m * N * 4
    q_packed = N * K // 2
    if variant == "block":
        scales = N * (K // 64) * 4
        return x + q_packed + scales + out
    if variant == "lords":
        scales = (N * LORDS_R + LORDS_R * K) * 4
        return x + q_packed + scales + out
    if variant == "qlora":
        scales = N * (K // 64) * 4
        adapter = (N * ADAPTER_R + ADAPTER_R * K) * 4
        extra_act = m * ADAPTER_R * 4
        return x + q_packed + scales + adapter + extra_act + out


def run(report):
    key = jax.random.PRNGKey(4)
    w = realistic_weight(key, N, K)
    qb, sb = quantize.quantize_blockwise(w, 64, "nf4")
    b, a = scaling.lords_init_from_weight(w, 64, rank=LORDS_R)
    s = scaling.scale_matrix(b, a)
    qp = quantize.pack_codes(quantize.quantize_codes(w, s, "nf4"), "nf4")
    lb = jax.random.normal(key, (N, ADAPTER_R)) * 0.01
    la = jax.random.normal(key, (ADAPTER_R, K)) * 0.01

    block_f = jax.jit(lambda x: ref.block_matmul_ref(x, qb, sb, 64, "nf4"))
    lords_f = jax.jit(lambda x: ref.lords_matmul_ref(x, qp, b, a, "nf4"))
    qlora_f = jax.jit(
        lambda x: ref.block_matmul_ref(x, qb, sb, 64, "nf4")
        + (x @ la.T) @ lb.T)

    for m in TOKENS:
        x = jax.random.normal(jax.random.PRNGKey(m), (m, K))
        for name, f in (("bnb_nf4", block_f), ("qlora", qlora_f),
                        ("lords", lords_f)):
            f(x).block_until_ready()  # compile+warm
            t0 = time.perf_counter()
            for _ in range(3):
                f(x).block_until_ready()
            us = (time.perf_counter() - t0) / 3 * 1e6
            variant = {"bnb_nf4": "block", "qlora": "qlora",
                       "lords": "lords"}[name]
            byts = _bytes_per_call(m, variant)
            report(f"kernels_fig2/M{m}/{name}", us,
                   f"tpu_bytes={byts} roofline_us_v5e={byts/819e3:.2f}")
